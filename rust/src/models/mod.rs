//! Model-graph constructors for the paper's evaluation set
//! (BERT / GPT / GShard-MoE / LLAMA-2, §5.1), decomposed to fine-grained
//! primitives exactly as the XLA front-end would emit them — layernorm,
//! softmax and dropout all appear as reduce/broadcast/elementwise chains,
//! so a single transformer layer contributes hundreds of ops (§2.3).

pub mod common;
pub mod presets;

use crate::graph::{append_backward, Graph};

pub use presets::ModelCfg;

/// Architecture selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Arch {
    Bert,
    Gpt,
    Llama,
    Moe,
}

impl Arch {
    pub fn name(self) -> &'static str {
        match self {
            Arch::Bert => "bert",
            Arch::Gpt => "gpt",
            Arch::Llama => "llama",
            Arch::Moe => "moe",
        }
    }

    pub fn parse(s: &str) -> Option<Arch> {
        match s {
            "bert" => Some(Arch::Bert),
            "gpt" => Some(Arch::Gpt),
            "llama" => Some(Arch::Llama),
            "moe" => Some(Arch::Moe),
            _ => None,
        }
    }
}

/// Build the full training-step graph (fwd + loss + bwd + SGD updates).
pub fn build_training(cfg: &ModelCfg) -> Graph {
    let (mut g, loss) = common::build_forward_loss(cfg);
    append_backward(&mut g, loss, 1e-3);
    g
}

/// Build only the forward + loss graph.
pub fn build_forward(cfg: &ModelCfg) -> (Graph, crate::graph::OpId) {
    common::build_forward_loss(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{OpKind, Role};

    #[test]
    fn gpt_two_layers_produce_hundreds_of_ops() {
        // paper §2.3: "just two GPT hidden layers ... over 1k fine-grained
        // operators" after XLA lowering. Our IR is slightly coarser (scale/
        // offset stay fused) but the same order of magnitude — the point is
        // that per-op search spaces explode and per-block ones don't.
        let cfg = ModelCfg::preset("gpt-2.6b").with_layers(2).with_batch(16);
        let g = build_training(&cfg);
        assert!(g.ops.len() > 450, "got {} ops", g.ops.len());
    }

    #[test]
    fn all_archs_build_and_have_updates() {
        for name in ["bert-large", "gpt-2.6b", "llama-7b", "moe-7.1b"] {
            let cfg = ModelCfg::preset(name).with_layers(2).with_batch(8);
            let g = build_training(&cfg);
            assert!(
                g.ops.iter().any(|o| o.role == Role::Opt),
                "{name}: no optimizer ops"
            );
            assert!(
                g.ops.iter().any(|o| matches!(o.kind, OpKind::Dot(_))),
                "{name}: no contractions"
            );
            assert!(!g.outputs.is_empty(), "{name}: no outputs");
        }
    }

    #[test]
    fn moe_has_expert_batched_bmm() {
        let cfg = ModelCfg::preset("moe-7.1b").with_layers(2).with_batch(8);
        let g = build_training(&cfg);
        // an (E, T, H)·(E, H, F) dot with batch=1 whose batch dim size == experts
        let found = g.ops.iter().any(|o| {
            matches!(&o.kind, OpKind::Dot(d) if d.batch == 1)
                && o.shape[0] == cfg.experts
        });
        assert!(found, "no expert-batched BMM found");
    }

    #[test]
    fn llama_uses_rmsnorm_not_layernorm() {
        let cfg = ModelCfg::preset("llama-7b").with_layers(1).with_batch(4);
        let g = build_training(&cfg);
        assert!(g.ops.iter().any(|o| o.name.contains("rmsnorm")));
        assert!(!g.ops.iter().any(|o| o.name.contains("/mean_b")));
    }

    #[test]
    fn flops_scale_with_batch() {
        let base = ModelCfg::preset("gpt-2.6b").with_layers(2);
        let f8 = build_training(&base.clone().with_batch(8)).total_flops();
        let f16 = build_training(&base.with_batch(16)).total_flops();
        // parameter-only ops (optimizer) don't scale; everything else ~2x
        assert!(f16 > f8 * 3 / 2, "f8={f8} f16={f16}");
    }

    #[test]
    fn dropout_rng_present_iff_enabled() {
        let on = ModelCfg::preset("gpt-2.6b").with_layers(1).with_batch(4);
        let off = on.clone().without_dropout();
        assert!(build_training(&on).ops.iter().any(|o| matches!(o.kind, OpKind::Rng)));
        assert!(!build_training(&off).ops.iter().any(|o| matches!(o.kind, OpKind::Rng)));
    }
}
