//! Shared fine-grained builders: embedding, attention, MLPs, MoE FFN,
//! cross-entropy — the decomposed structures the analysis passes chew on.

use crate::graph::{ElemOp, Graph, OpId, ParamClass, ReduceKind};

use super::{Arch, ModelCfg};

/// Multi-head self-attention decomposed to primitives.
///
/// Returns the (B, S, H) output. This is Fig. 4's parallelism-preserving
/// structure: two BMMs whose batch dims (B, heads) propagate partitions
/// seamlessly; softmax/dropout stay elementwise+lastdim-reduce.
pub fn attention(g: &mut Graph, x: OpId, cfg: &ModelCfg, li: usize, normed: OpId) -> OpId {
    let (b, s, h) = (cfg.batch, cfg.seq, cfg.hidden);
    let (nh, hd) = (cfg.heads, cfg.head_dim());
    let p = format!("l{li}/attn");

    let x2d = g.reshape(normed, vec![b * s, h], &format!("{p}/x2d"));
    // Fused QKV projection, columns ordered [heads][qkv][head_dim] so a
    // column shard is a whole-heads shard (Megatron's fused layout).
    let wqkv = g.param(&format!("{p}/wqkv"), vec![h, 3 * h], ParamClass::Weight);
    let wo = g.param(&format!("{p}/wo"), vec![h, h], ParamClass::Weight);

    let qkv = g.matmul(x2d, wqkv, &format!("{p}/qkv_proj")); // (T, 3H)
    let qkv5 = g.reshape(qkv, vec![b, s, nh, 3, hd], &format!("{p}/qkv_5d"));
    let qkv_t = g.transpose(qkv5, vec![3, 0, 2, 1, 4], &format!("{p}/qkv_t")); // (3,B,nh,S,hd)
    let q = g.slice(qkv_t, 0, 0, &format!("{p}/q")); // (B, nh, S, hd)
    let k = g.slice(qkv_t, 0, 1, &format!("{p}/k"));
    let v = g.slice(qkv_t, 0, 2, &format!("{p}/v"));

    let (q, k) = if cfg.arch == Arch::Llama {
        // RoPE as an elementwise rotation against precomputed tables —
        // partition-transparent, matching its parallel behaviour.
        let rope = g.constant(0.5, vec![s, hd]);
        let rope_b = g.broadcast(rope, vec![2, 3], vec![b, nh, s, hd], &format!("{p}/rope_b"));
        let qr = g.binary(ElemOp::Mul, q, rope_b, &format!("{p}/q_rope"));
        let kr = g.binary(ElemOp::Mul, k, rope_b, &format!("{p}/k_rope"));
        (qr, kr)
    } else {
        (q, k)
    };

    let kt = g.transpose(k, vec![0, 1, 3, 2], &format!("{p}/k_T")); // (B,nh,hd,S)
    let scores = g.dot(q, kt, 2, &format!("{p}/qk_bmm")); // (B,nh,S,S)
    let scaled = g.unary(
        ElemOp::Scale(1.0 / (hd as f64).sqrt()),
        scores,
        &format!("{p}/scale"),
    );
    let probs = g.softmax(scaled, &format!("{p}/softmax"));
    let probs = if cfg.dropout {
        g.dropout(probs, 0.1, &format!("{p}/drop"))
    } else {
        probs
    };
    let ctx = g.dot(probs, v, 2, &format!("{p}/pv_bmm")); // (B,nh,S,hd)
    let ctx_t = g.transpose(ctx, vec![0, 2, 1, 3], &format!("{p}/ctx_t"));
    let ctx2d = g.reshape(ctx_t, vec![b * s, h], &format!("{p}/ctx2d"));
    let out = g.matmul(ctx2d, wo, &format!("{p}/out_proj"));
    let out3d = g.reshape(out, vec![b, s, h], &format!("{p}/out3d"));
    // residual dropout sits AFTER the row-parallel AllReduce point — under
    // Megatron TP its mask is replicated, which is exactly the §2.2 RNG
    // device-restriction AllReduce; under DP it is batch-sharded and free.
    let out3d = if cfg.dropout {
        g.dropout(out3d, 0.1, &format!("{p}/resid_drop"))
    } else {
        out3d
    };
    g.binary(ElemOp::Add, x, out3d, &format!("{p}/residual"))
}

/// GeLU MLP (gpt/bert/moe-even-layers).
pub fn dense_mlp(g: &mut Graph, x3d: OpId, normed: OpId, cfg: &ModelCfg, li: usize) -> OpId {
    let (b, s, h, f) = (cfg.batch, cfg.seq, cfg.hidden, cfg.ffn);
    let p = format!("l{li}/mlp");
    let x2d = g.reshape(normed, vec![b * s, h], &format!("{p}/x2d"));
    let w1 = g.param(&format!("{p}/w1"), vec![h, f], ParamClass::Weight);
    let w2 = g.param(&format!("{p}/w2"), vec![f, h], ParamClass::Weight);
    let h1 = g.matmul(x2d, w1, &format!("{p}/fc1"));
    let a = g.unary(ElemOp::Gelu, h1, &format!("{p}/gelu"));
    let a = if cfg.dropout {
        g.dropout(a, 0.1, &format!("{p}/drop"))
    } else {
        a
    };
    let h2 = g.matmul(a, w2, &format!("{p}/fc2"));
    let y = g.reshape(h2, vec![b, s, h], &format!("{p}/out3d"));
    let y = if cfg.dropout {
        g.dropout(y, 0.1, &format!("{p}/resid_drop"))
    } else {
        y
    };
    g.binary(ElemOp::Add, x3d, y, &format!("{p}/residual"))
}

/// SwiGLU MLP (llama).
pub fn swiglu_mlp(g: &mut Graph, x3d: OpId, normed: OpId, cfg: &ModelCfg, li: usize) -> OpId {
    let (b, s, h, f) = (cfg.batch, cfg.seq, cfg.hidden, cfg.ffn);
    let p = format!("l{li}/swiglu");
    let x2d = g.reshape(normed, vec![b * s, h], &format!("{p}/x2d"));
    let wg = g.param(&format!("{p}/w_gate"), vec![h, f], ParamClass::Weight);
    let wu = g.param(&format!("{p}/w_up"), vec![h, f], ParamClass::Weight);
    let wd = g.param(&format!("{p}/w_down"), vec![f, h], ParamClass::Weight);
    let gate = g.matmul(x2d, wg, &format!("{p}/gate"));
    let gact = g.unary(ElemOp::Silu, gate, &format!("{p}/silu"));
    let up = g.matmul(x2d, wu, &format!("{p}/up"));
    let prod = g.binary(ElemOp::Mul, gact, up, &format!("{p}/prod"));
    let down = g.matmul(prod, wd, &format!("{p}/down"));
    let y = g.reshape(down, vec![b, s, h], &format!("{p}/out3d"));
    g.binary(ElemOp::Add, x3d, y, &format!("{p}/residual"))
}

/// GShard-style top-1 MoE FFN: gate softmax, one-hot dispatch, expert-
/// batched BMMs, weighted combine (paper §5.7's case-study structure).
pub fn moe_ffn(g: &mut Graph, x3d: OpId, normed: OpId, cfg: &ModelCfg, li: usize) -> OpId {
    let (b, s, h, f, e) = (cfg.batch, cfg.seq, cfg.hidden, cfg.ffn, cfg.experts);
    let t = b * s;
    let p = format!("l{li}/moe");

    let x2d = g.reshape(normed, vec![t, h], &format!("{p}/x2d"));
    let wg = g.param(&format!("{p}/gate_w"), vec![h, e], ParamClass::Weight);
    let logits = g.matmul(x2d, wg, &format!("{p}/gate_logits")); // (T, E)
    let probs = g.softmax(logits, &format!("{p}/gate_softmax"));

    // top-1 one-hot: max over E, compare-eq, f32-ify
    let m = g.reduce(probs, vec![1], ReduceKind::Max, &format!("{p}/gate_max"));
    let mb = g.broadcast(m, vec![0], vec![t, e], &format!("{p}/gate_max_b"));
    let mask = g.binary(ElemOp::CmpEq, probs, mb, &format!("{p}/onehot_mask"));
    let one = g.constant(1.0, vec![]);
    let one_b = g.broadcast(one, vec![], vec![t, e], &format!("{p}/one_b"));
    let zero = g.constant(0.0, vec![]);
    let zero_b = g.broadcast(zero, vec![], vec![t, e], &format!("{p}/zero_b"));
    let onehot = g.elem(ElemOp::Select, vec![mask, one_b, zero_b], &format!("{p}/onehot"));

    // combine weight per token
    let pw = g.binary(ElemOp::Mul, probs, onehot, &format!("{p}/probs_sel"));
    let weight = g.reduce(pw, vec![1], ReduceKind::Sum, &format!("{p}/weight")); // (T)

    // capacity-based dispatch (GShard, capacity factor 1): a data-dependent
    // token permutation (T,H) → (E, C, H) with C = T/E. Crossing a Route
    // with a sharded token/expert dim costs an All-to-All — the §5.7
    // expert-parallelism kernel that collapses to SendRecv on PCIe.
    let c = t / e;
    let xd = g.route(x2d, vec![e, c, h], &format!("{p}/dispatch"));

    // expert-batched BMMs: the extra batch dim (experts) is the extra
    // candidate partition dimension the paper calls out in §5.5.
    let w1e = g.param(&format!("{p}/w1_e"), vec![e, h, f], ParamClass::Weight);
    let w2e = g.param(&format!("{p}/w2_e"), vec![e, f, h], ParamClass::Weight);
    let h1 = g.dot(xd, w1e, 1, &format!("{p}/expert_fc1")); // (E,C,F)
    let a = g.unary(ElemOp::Gelu, h1, &format!("{p}/gelu"));
    let h2 = g.dot(a, w2e, 1, &format!("{p}/expert_fc2")); // (E,C,H)

    // combine: route back to token order, then scale by the gate weight
    let y2d = g.route(h2, vec![t, h], &format!("{p}/combine")); // (T,H)
    let w_b = g.broadcast(weight, vec![0], vec![t, h], &format!("{p}/weight_b"));
    let yw = g.binary(ElemOp::Mul, y2d, w_b, &format!("{p}/weighted"));
    let y3d = g.reshape(yw, vec![b, s, h], &format!("{p}/out3d"));
    g.binary(ElemOp::Add, x3d, y3d, &format!("{p}/residual"))
}

/// SP-DAG MoE FFN: the same GShard top-1 structure as [`moe_ffn`], but
/// each expert is its own *branch* — slice that expert's capacity rows
/// out of the dispatch, run a dense per-expert FFN, pad back into the
/// (E, C, H) layout — with the branch op ranges recorded in
/// [`Graph::branch_groups`]. `segment::extract_with_topology` turns each
/// branch into its own segment instance, so the spdag planner searches
/// every expert's parallelism independently (expert parallelism as a
/// first-class axis) and prices the fork/merge junctions with the
/// ordinary reshard matrices.
pub fn moe_ffn_branched(
    g: &mut Graph,
    x3d: OpId,
    normed: OpId,
    cfg: &ModelCfg,
    li: usize,
) -> OpId {
    let (b, s, h, f, e) = (cfg.batch, cfg.seq, cfg.hidden, cfg.ffn, cfg.experts);
    let t = b * s;
    let p = format!("l{li}/moe");

    // shared router trunk — identical to moe_ffn up to the dispatch
    let x2d = g.reshape(normed, vec![t, h], &format!("{p}/x2d"));
    let wg = g.param(&format!("{p}/gate_w"), vec![h, e], ParamClass::Weight);
    let logits = g.matmul(x2d, wg, &format!("{p}/gate_logits")); // (T, E)
    let probs = g.softmax(logits, &format!("{p}/gate_softmax"));
    let m = g.reduce(probs, vec![1], ReduceKind::Max, &format!("{p}/gate_max"));
    let mb = g.broadcast(m, vec![0], vec![t, e], &format!("{p}/gate_max_b"));
    let mask = g.binary(ElemOp::CmpEq, probs, mb, &format!("{p}/onehot_mask"));
    let one = g.constant(1.0, vec![]);
    let one_b = g.broadcast(one, vec![], vec![t, e], &format!("{p}/one_b"));
    let zero = g.constant(0.0, vec![]);
    let zero_b = g.broadcast(zero, vec![], vec![t, e], &format!("{p}/zero_b"));
    let onehot = g.elem(ElemOp::Select, vec![mask, one_b, zero_b], &format!("{p}/onehot"));
    let pw = g.binary(ElemOp::Mul, probs, onehot, &format!("{p}/probs_sel"));
    let weight = g.reduce(pw, vec![1], ReduceKind::Sum, &format!("{p}/weight")); // (T)
    let c = t / e;
    let xd = g.route(x2d, vec![e, c, h], &format!("{p}/dispatch"));

    // one branch per expert: slice its capacity rows, dense FFN, pad back
    let mut padded = Vec::with_capacity(e);
    let mut ranges = Vec::with_capacity(e);
    for ei in 0..e {
        let start = g.ops.len();
        let bp = format!("{p}/e{ei}");
        let xe = g.slice(xd, 0, ei, &format!("{bp}/in")); // (C, H)
        let w1 = g.param(&format!("{bp}/w1"), vec![h, f], ParamClass::Weight);
        let w2 = g.param(&format!("{bp}/w2"), vec![f, h], ParamClass::Weight);
        let h1 = g.matmul(xe, w1, &format!("{bp}/fc1")); // (C, F)
        let a = g.unary(ElemOp::Gelu, h1, &format!("{bp}/gelu"));
        let h2 = g.matmul(a, w2, &format!("{bp}/fc2")); // (C, H)
        padded.push(g.pad(h2, 0, ei, e, &format!("{bp}/out"))); // (E, C, H)
        ranges.push((start, g.ops.len()));
    }
    g.record_branch_group(ranges);

    // merge: sum the disjoint pads, route back, gate-weight, residual
    let mut acc = padded[0];
    for (ei, &pd) in padded.iter().enumerate().skip(1) {
        acc = g.binary(ElemOp::Add, acc, pd, &format!("{p}/merge{ei}"));
    }
    let y2d = g.route(acc, vec![t, h], &format!("{p}/combine")); // (T, H)
    let w_b = g.broadcast(weight, vec![0], vec![t, h], &format!("{p}/weight_b"));
    let yw = g.binary(ElemOp::Mul, y2d, w_b, &format!("{p}/weighted"));
    let y3d = g.reshape(yw, vec![b, s, h], &format!("{p}/out3d"));
    g.binary(ElemOp::Add, x3d, y3d, &format!("{p}/residual"))
}

/// One transformer block (arch-dispatched norm + ffn flavor).
pub fn block(g: &mut Graph, x: OpId, cfg: &ModelCfg, li: usize) -> OpId {
    g.set_layer(Some(li));
    let p = format!("l{li}");
    let normed1 = norm(g, x, cfg, &format!("{p}/ln1"));
    let x = attention(g, x, cfg, li, normed1);
    let normed2 = norm(g, x, cfg, &format!("{p}/ln2"));
    let out = match (cfg.arch, li % 2) {
        (Arch::Llama, _) => swiglu_mlp(g, x, normed2, cfg, li),
        (Arch::Moe, 1) if cfg.expert_branches => moe_ffn_branched(g, x, normed2, cfg, li),
        (Arch::Moe, 1) => moe_ffn(g, x, normed2, cfg, li),
        _ => dense_mlp(g, x, normed2, cfg, li),
    };
    g.set_layer(None);
    out
}

fn norm(g: &mut Graph, x: OpId, cfg: &ModelCfg, name: &str) -> OpId {
    let h = cfg.hidden;
    if cfg.arch == Arch::Llama {
        let w = g.param(&format!("{name}/w"), vec![h], ParamClass::Weight);
        g.rmsnorm(x, w, &format!("{name}/rmsnorm"))
    } else {
        let w = g.param(&format!("{name}/w"), vec![h], ParamClass::Weight);
        let b = g.param(&format!("{name}/b"), vec![h], ParamClass::Weight);
        g.layernorm(x, w, b, name)
    }
}

/// Embedding + blocks + final norm + LM head + CE loss → (graph, loss id).
pub fn build_forward_loss(cfg: &ModelCfg) -> (Graph, OpId) {
    let mut g = Graph::new();
    let (b, s, h, v) = (cfg.batch, cfg.seq, cfg.hidden, cfg.vocab);

    let tokens = g.param("tokens", vec![b, s], ParamClass::Input);
    let embed = g.param("embed", vec![v, h], ParamClass::Weight);
    let mut x = g.gather(embed, tokens, "embed_lookup"); // (B,S,H)
    if cfg.arch != Arch::Llama {
        let pos = g.param("pos_embed", vec![s, h], ParamClass::Weight);
        let pos_b = g.broadcast(pos, vec![1, 2], vec![b, s, h], "pos_b");
        x = g.binary(ElemOp::Add, x, pos_b, "embed_add_pos");
    }

    for li in 0..cfg.layers {
        x = block(&mut g, x, cfg, li);
    }

    let normed = norm(&mut g, x, cfg, "final_norm");
    let x2d = g.reshape(normed, vec![b * s, h], "final_2d");
    let unembed = g.param("unembed", vec![h, v], ParamClass::Weight);
    let logits = g.matmul(x2d, unembed, "lm_head"); // (T, V)

    // CE with one-hot targets (an Input param, as jax would feed them)
    let t = b * s;
    let targets = g.param("targets_onehot", vec![t, v], ParamClass::Input);
    let m = g.reduce(logits, vec![1], ReduceKind::Max, "ce/max");
    let mb = g.broadcast(m, vec![0], vec![t, v], "ce/max_b");
    let shifted = g.binary(ElemOp::Sub, logits, mb, "ce/shift");
    let e = g.unary(ElemOp::Exp, shifted, "ce/exp");
    let se = g.reduce(e, vec![1], ReduceKind::Sum, "ce/sumexp");
    let lse = g.unary(ElemOp::Log, se, "ce/logsumexp");
    let lse_b = g.broadcast(lse, vec![0], vec![t, v], "ce/lse_b");
    let logp = g.binary(ElemOp::Sub, shifted, lse_b, "ce/logp");
    let picked = g.binary(ElemOp::Mul, targets, logp, "ce/picked");
    let sum = g.reduce(picked, vec![0, 1], ReduceKind::Sum, "ce/sum");
    let loss = g.unary(ElemOp::Scale(-1.0 / t as f64), sum, "ce/loss");
    g.outputs.push(loss);
    (g, loss)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::presets::ModelCfg;

    #[test]
    fn forward_loss_is_scalar() {
        let cfg = ModelCfg::preset("gpt-tiny");
        let (g, loss) = build_forward_loss(&cfg);
        assert!(g.shape(loss).is_empty());
    }

    #[test]
    fn attention_preserves_shape() {
        let cfg = ModelCfg::preset("gpt-tiny");
        let (b, s, h) = (cfg.batch, cfg.seq, cfg.hidden);
        let mut g = Graph::new();
        let x = g.param("x", vec![b, s, h], ParamClass::Input);
        let out = attention(&mut g, x, &cfg, 0, x);
        assert_eq!(g.shape(out), &[b, s, h]);
    }

    #[test]
    fn moe_ffn_preserves_shape() {
        let cfg = ModelCfg::preset("moe-tiny");
        let (b, s, h) = (cfg.batch, cfg.seq, cfg.hidden);
        let mut g = Graph::new();
        let x = g.param("x", vec![b, s, h], ParamClass::Input);
        let out = moe_ffn(&mut g, x, x, &cfg, 1);
        assert_eq!(g.shape(out), &[b, s, h]);
    }

    #[test]
    fn moe_ffn_branched_preserves_shape_and_records_branches() {
        let cfg = ModelCfg::preset("moe-ep-tiny");
        let (b, s, h) = (cfg.batch, cfg.seq, cfg.hidden);
        let mut g = Graph::new();
        let x = g.param("x", vec![b, s, h], ParamClass::Input);
        let out = moe_ffn_branched(&mut g, x, x, &cfg, 1);
        assert_eq!(g.shape(out), &[b, s, h], "branched MoE keeps the residual shape");
        assert_eq!(g.branch_groups.len(), 1, "one fork/join group per MoE layer");
        let group = &g.branch_groups[0];
        assert_eq!(group.len(), cfg.experts, "one branch per expert");
        for w in group.windows(2) {
            assert!(w[0].1 <= w[1].0, "branch op ranges are disjoint and ascending");
        }
    }

    #[test]
    fn six_contractions_per_dense_layer_plus_head() {
        // paper §5.5: a transformer layer has 4 ParallelBlock seeds after
        // the two attention BMMs merge into the QKV block. At op level:
        // qkv, qk_bmm, pv_bmm, wo, w1, w2 = 6 forward dots + lm_head.
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(1).without_dropout();
        let (g, _) = build_forward_loss(&cfg);
        let dots = g.contraction_ops().len();
        assert_eq!(dots, 7, "got {dots}");
    }
}
