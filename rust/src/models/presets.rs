//! Model configurations, including the paper's evaluated sizes (§5.1).

use super::Arch;

#[derive(Clone, Debug)]
pub struct ModelCfg {
    pub arch: Arch,
    pub name: String,
    pub hidden: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub seq: usize,
    pub batch: usize,
    pub experts: usize,
    pub dropout: bool,
    /// Build MoE layers with per-expert *branches* (router segment → N
    /// expert branches → merge) instead of one expert-batched block — the
    /// SP-DAG form planned by `spdag` where expert parallelism is a
    /// first-class axis. `false` on every chain preset.
    pub expert_branches: bool,
}

impl ModelCfg {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.heads
    }

    /// Paper-scale presets. Analysis cost does not depend on tensor sizes,
    /// so these use the real dimensions. Panics on unknown names — the
    /// serving path uses [`ModelCfg::try_preset`] instead.
    pub fn preset(name: &str) -> ModelCfg {
        ModelCfg::try_preset(name).unwrap_or_else(|| panic!("unknown preset {name:?}"))
    }

    /// Non-panicking [`ModelCfg::preset`]: `None` for unknown names, so a
    /// bad request gets an error response instead of crashing a server.
    pub fn try_preset(name: &str) -> Option<ModelCfg> {
        let cfg = match name {
            "bert-large" => ModelCfg {
                arch: Arch::Bert,
                name: name.into(),
                hidden: 1024,
                layers: 24,
                heads: 16,
                ffn: 4096,
                vocab: 30528,
                seq: 512,
                batch: 8,
                experts: 0,
                dropout: true,
                expert_branches: false,
            },
            "gpt-2.6b" => ModelCfg {
                arch: Arch::Gpt,
                name: name.into(),
                hidden: 2560,
                layers: 32,
                heads: 32,
                ffn: 10240,
                vocab: 50304,
                seq: 1024,
                batch: 8,
                experts: 0,
                dropout: true,
                expert_branches: false,
            },
            "gpt-6.7b" => ModelCfg {
                arch: Arch::Gpt,
                name: name.into(),
                hidden: 4096,
                layers: 32,
                heads: 32,
                ffn: 16384,
                vocab: 50304,
                seq: 1024,
                batch: 8,
                experts: 0,
                dropout: true,
                expert_branches: false,
            },
            "llama-7b" => ModelCfg {
                arch: Arch::Llama,
                name: name.into(),
                hidden: 4096,
                layers: 32,
                heads: 32,
                ffn: 11008,
                vocab: 32000,
                seq: 1024,
                batch: 8,
                experts: 0,
                dropout: true,
                expert_branches: false,
            },
            "moe-7.1b" => ModelCfg {
                arch: Arch::Moe,
                name: name.into(),
                hidden: 2048,
                layers: 16,
                heads: 16,
                ffn: 8192,
                vocab: 32000,
                seq: 1024,
                batch: 8,
                experts: 16,
                dropout: true,
                expert_branches: false,
            },
            // small configs for tests / e2e
            "gpt-tiny" => ModelCfg {
                arch: Arch::Gpt,
                name: name.into(),
                hidden: 64,
                layers: 2,
                heads: 4,
                ffn: 128,
                vocab: 512,
                seq: 32,
                batch: 4,
                experts: 0,
                dropout: true,
                expert_branches: false,
            },
            "moe-tiny" => ModelCfg {
                arch: Arch::Moe,
                name: name.into(),
                hidden: 64,
                layers: 2,
                heads: 4,
                ffn: 128,
                vocab: 512,
                seq: 32,
                batch: 4,
                experts: 4,
                dropout: true,
                expert_branches: false,
            },
            // SP-DAG presets: the same MoE dimensions with per-expert
            // branches, so expert parallelism is searched per branch by
            // the spdag planner (router → E expert branches → merge)
            "moe-ep-tiny" => ModelCfg {
                expert_branches: true,
                name: name.into(),
                ..ModelCfg::preset("moe-tiny")
            },
            "moe-ep-7.1b" => ModelCfg {
                expert_branches: true,
                name: name.into(),
                ..ModelCfg::preset("moe-7.1b")
            },
            _ => return None,
        };
        Some(cfg)
    }

    pub fn with_layers(mut self, layers: usize) -> Self {
        self.layers = layers;
        self
    }

    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    pub fn with_seq(mut self, seq: usize) -> Self {
        self.seq = seq;
        self
    }

    pub fn without_dropout(mut self) -> Self {
        self.dropout = false;
        self
    }

    /// Total trainable parameters (analytic).
    pub fn num_params(&self) -> u64 {
        let h = self.hidden as u64;
        let f = self.ffn as u64;
        let v = self.vocab as u64;
        let s = self.seq as u64;
        let mut per_layer = 4 * h * h; // wq wk wv wo
        per_layer += match self.arch {
            Arch::Llama => 3 * h * f + 2 * h,
            _ => 2 * h * f + 4 * h,
        };
        let mut total = v * h + per_layer * self.layers as u64 + h * v;
        if self.arch != Arch::Llama {
            total += s * h; // learned positions
        }
        if self.arch == Arch::Moe {
            // every odd layer swaps its dense FFN for E experts
            let moe_layers = (self.layers / 2) as u64;
            let e = self.experts as u64;
            total += moe_layers * (e * 2 * h * f + h * e) - moe_layers * 2 * h * f;
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_have_expected_scale() {
        // ballpark param counts (±20%): the names should mean what they say
        let gpt26 = ModelCfg::preset("gpt-2.6b").num_params() as f64;
        assert!((gpt26 / 2.6e9 - 1.0).abs() < 0.25, "gpt-2.6b = {gpt26}");
        let gpt67 = ModelCfg::preset("gpt-6.7b").num_params() as f64;
        assert!((gpt67 / 6.7e9 - 1.0).abs() < 0.25, "gpt-6.7b = {gpt67}");
        let llama = ModelCfg::preset("llama-7b").num_params() as f64;
        assert!((llama / 6.7e9 - 1.0).abs() < 0.25, "llama-7b = {llama}");
        let moe = ModelCfg::preset("moe-7.1b").num_params() as f64;
        assert!((moe / 7.1e9 - 1.0).abs() < 0.35, "moe-7.1b = {moe}");
    }

    #[test]
    fn builders_chain() {
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(3).with_batch(2).with_seq(16);
        assert_eq!(cfg.layers, 3);
        assert_eq!(cfg.batch, 2);
        assert_eq!(cfg.seq, 16);
    }
}
