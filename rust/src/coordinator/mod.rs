//! The CFP coordinator: the end-to-end pipeline of paper Fig. 3 —
//! AnalysisPasses → ExecCompiling ∥ MetricsProfiling → ComposeSearch —
//! with per-phase timing (the §5.5 search-overhead breakdown) and the
//! baseline searchers for comparison.

use std::time::Instant;

use crate::baselines;
use crate::cluster::sim::ComputeModel;
use crate::cluster::{simulate, Platform};
use crate::cost::{self, Plan};
use crate::graph::Graph;
use crate::interop;
use crate::interop::{candidate_stage_counts, StageSpec};
use crate::memory::RecomputeSpec;
use crate::models::{build_training, ModelCfg};
use crate::pblock::{build_parallel_blocks, BlockSet};
use crate::profiler::{
    profile_model_handle, CacheHandle, ProfileCache, ProfileDb, ProfileOptions,
    SharedProfileCache,
};
use crate::segment::{extract_with_topology, SegmentSet};
use crate::spdag::{self, SpTopology};
use crate::spmd::Mesh;
use crate::util::cli::Args;

#[derive(Clone)]
pub struct CfpOptions {
    pub model: ModelCfg,
    pub platform: Platform,
    pub mesh: Mesh,
    /// per-device memory cap (None → platform capacity)
    pub mem_cap: Option<u64>,
    pub threads: usize,
    /// PJRT-calibrated compute model (from runtime::calibrate_compute)
    pub compute: Option<ComputeModel>,
    /// persistent profile-cache file; None disables caching. A warm cache
    /// turns the MetricsProfiling phase into a lookup (`--cache` in the
    /// CLI; format documented in ROADMAP.md "Profile cache").
    pub cache_path: Option<std::path::PathBuf>,
    /// LRU bound on persistent-cache entries (`--cache-max-entries`);
    /// None → unbounded (the pre-PR-2 behaviour)
    pub cache_max_entries: Option<usize>,
    /// inter-op pipeline stages for [`run_cfp_two_level`] (`--stages`);
    /// `Single` keeps today's one-level behaviour
    pub stages: StageSpec,
    /// gradient-accumulation microbatches for the pipeline bubble model
    /// (`--microbatches`)
    pub microbatches: usize,
    /// whether the two-level planner may trade recomputation for
    /// activation memory (`--recompute auto|off`); with `Off` and no
    /// `mem_cap` the planner is bit-identical to PR 2
    pub recompute: RecomputeSpec,
    /// which intra-op searcher ComposeSearch runs (`--engine`):
    /// the production DP, the branch-and-bound exact lane, or `Auto`
    /// (exact on small spans, DP otherwise — see cost::exact)
    pub engine: cost::SearchEngine,
    /// observability sink (`--trace-out`); disabled by default. Counting
    /// never shapes the plan — with tracing off every hook is one
    /// `Option` branch (see [`crate::obs`]).
    pub trace: crate::obs::Trace,
}

impl CfpOptions {
    pub fn new(model: ModelCfg, platform: Platform) -> CfpOptions {
        let mesh = Mesh { intra: platform.gpus_per_node, nodes: platform.nodes };
        CfpOptions {
            model,
            platform,
            mesh,
            mem_cap: None,
            threads: 1,
            compute: None,
            cache_path: None,
            cache_max_entries: None,
            stages: StageSpec::Single,
            microbatches: 8,
            recompute: RecomputeSpec::Off,
            engine: cost::SearchEngine::Dp,
            trace: crate::obs::Trace::disabled(),
        }
    }

    /// Attach an observability trace; every phase of the run counts into
    /// it (see [`crate::obs`]).
    pub fn with_trace(mut self, trace: crate::obs::Trace) -> CfpOptions {
        self.trace = trace;
        self
    }

    pub fn with_cache(mut self, path: impl Into<std::path::PathBuf>) -> CfpOptions {
        self.cache_path = Some(path.into());
        self
    }

    pub fn with_stages(mut self, spec: StageSpec) -> CfpOptions {
        self.stages = spec;
        self
    }

    pub fn with_microbatches(mut self, m: usize) -> CfpOptions {
        self.microbatches = m.max(1);
        self
    }

    pub fn with_recompute(mut self, spec: RecomputeSpec) -> CfpOptions {
        self.recompute = spec;
        self
    }

    /// Intra-op search engine (`--engine dp|exact|auto`). `Exact` trades
    /// time for a certified-optimal plan on small spans; `Auto` picks
    /// exact only when the search space is tiny.
    pub fn with_engine(mut self, engine: cost::SearchEngine) -> CfpOptions {
        self.engine = engine;
        self
    }

    /// Per-device memory cap in bytes (`--mem-cap`, given in GB on the
    /// CLI). Setting a cap makes the two-level planner memory-aware.
    pub fn with_mem_cap(mut self, bytes: u64) -> CfpOptions {
        self.mem_cap = Some(bytes);
        self
    }

    /// The inter-op planner's view of these options.
    pub fn pipeline_options(&self) -> interop::PipelineOptions {
        interop::PipelineOptions {
            platform: self.platform,
            mesh: self.mesh,
            mem_cap: self.mem_cap,
            threads: self.threads,
            compute: self.compute.clone(),
            microbatches: self.microbatches,
            spec: self.stages,
            recompute: self.recompute,
            trace: self.trace.clone(),
        }
    }

    fn open_cache(&self) -> Option<ProfileCache> {
        let mut cache = self.cache_path.as_ref().map(ProfileCache::open)?;
        cache.set_max_entries(self.cache_max_entries);
        Some(cache)
    }
}

/// Which planner a request drives. Decides the option defaults: the
/// `pipeline` subcommand (and `pipeline` service requests) defaults to
/// memory-aware auto staging, everything else to the single-level
/// planner's defaults.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlannerKind {
    SingleLevel,
    TwoLevel,
}

/// Options built from CLI-shaped arguments plus any soft warnings
/// (optional flags that did not parse and fell back to their defaults).
/// The CLI prints the warnings to stderr and proceeds; `cfp serve`
/// rejects the request instead — but both interpret *valid* flags
/// through this one builder, so they can never read the same request
/// differently.
pub struct BuiltOptions {
    pub opts: CfpOptions,
    pub warnings: Vec<String>,
}

impl CfpOptions {
    /// The one flag → options mapping shared by the `cfp` subcommands
    /// and the `cfp serve` request path. Unknown model/platform names are
    /// hard errors (a plan against the wrong hardware is worse than no
    /// plan); malformed optional flags produce warnings and keep their
    /// defaults.
    pub fn from_args(args: &Args, kind: PlannerKind) -> Result<BuiltOptions, String> {
        let mut warnings = Vec::new();
        let name = args.get_or("model", "gpt-2.6b");
        let mut model = ModelCfg::try_preset(name)
            .ok_or_else(|| format!("unknown model preset {name:?}"))?;
        if let Some(l) = args.get("layers") {
            match l.parse::<usize>() {
                Ok(n) if n > 0 => model = model.with_layers(n),
                _ => warnings
                    .push(format!("invalid --layers value {l:?} (want a positive integer)")),
            }
        }
        if let Some(b) = args.get("batch") {
            match b.parse::<usize>() {
                Ok(n) if n > 0 => model = model.with_batch(n),
                _ => warnings
                    .push(format!("invalid --batch value {b:?} (want a positive integer)")),
            }
        }
        if args.has_flag("scaled") {
            model = model.scaled_for_eval();
        }
        let pname = args.get_or("platform", "a100-pcie");
        let platform =
            Platform::by_name(pname).ok_or_else(|| format!("unknown platform {pname:?}"))?;
        let mut opts = CfpOptions::new(model, platform);
        if kind == PlannerKind::TwoLevel {
            // the pipeline planner defaults to memory-aware planning
            // against the device capacity; `--recompute off` restores the
            // PR 2 behaviour
            opts.stages = StageSpec::Auto;
            opts.recompute = RecomputeSpec::Auto;
        }
        opts.threads = args.get_usize("threads", 1);
        opts.cache_path = args.get_path("cache");
        opts.cache_max_entries = args.get_usize_opt("cache-max-entries");
        opts.microbatches = args.get_usize("microbatches", 8);
        if let Some(s) = args.get("stages") {
            match StageSpec::parse(s) {
                Some(spec) => opts.stages = spec,
                None => warnings
                    .push(format!("unknown --stages value {s:?} (want auto|single|K)")),
            }
        }
        // --mem-cap is given in GB (fractions allowed: --mem-cap 12.5)
        if let Some(mc) = args.get("mem-cap") {
            match mc.parse::<f64>() {
                Ok(gb) if gb > 0.0 => opts.mem_cap = Some((gb * (1u64 << 30) as f64) as u64),
                _ => warnings
                    .push(format!("invalid --mem-cap value {mc:?} (want GB, e.g. 12.5)")),
            }
        }
        if let Some(r) = args.get("recompute") {
            match RecomputeSpec::parse(r) {
                Some(spec) => opts.recompute = spec,
                None => {
                    warnings.push(format!("unknown --recompute value {r:?} (want auto|off)"))
                }
            }
        }
        if let Some(e) = args.get("engine") {
            match cost::SearchEngine::parse(e) {
                Some(engine) => opts.engine = engine,
                None => {
                    warnings.push(format!("unknown --engine value {e:?} (want dp|exact|auto)"))
                }
            }
        }
        Ok(BuiltOptions { opts, warnings })
    }
}

/// Strict validation of pipeline-planner requests (the `pipeline`
/// subcommand and `pipeline` service requests): a stage count that
/// cannot tile the cluster, or zero microbatches, is a user error —
/// reject with a message instead of silently normalizing.
pub fn validate_pipeline_args(args: &Args, opts: &CfpOptions) -> Result<(), String> {
    if let Some(mb) = args.get("microbatches") {
        match mb.parse::<usize>() {
            Ok(0) => {
                return Err(
                    "--microbatches must be ≥ 1 (0 microbatches cannot fill a pipeline)".into()
                )
            }
            Ok(_) => {}
            Err(_) => return Err(format!("--microbatches {mb:?} is not a number")),
        }
    }
    if let Some(s) = args.get("stages") {
        if let Ok(k) = s.parse::<usize>() {
            let valid = candidate_stage_counts(StageSpec::Auto, opts.mesh);
            if k == 0 || (k > 1 && !valid.contains(&k)) {
                return Err(format!(
                    "--stages {k} does not tile the {}-device cluster \
                     (valid stage counts: {valid:?})",
                    opts.mesh.total()
                ));
            }
        }
    }
    if let Some(mc) = args.get("mem-cap") {
        match mc.parse::<f64>() {
            Ok(gb) if gb > 0.0 => {}
            _ => return Err(format!("--mem-cap {mc:?} is not a positive GB value")),
        }
    }
    Ok(())
}

/// Per-phase timing (paper Fig. 12/13 vocabulary).
#[derive(Clone, Debug, Default)]
pub struct PhaseTimings {
    pub analysis_passes_s: f64,
    pub exec_compiling_s: f64,
    pub metrics_profiling_s: f64,
    pub compose_search_s: f64,
    /// estimated real-testbed compile+profile (unoptimized / optimized)
    pub est_compile_s: f64,
    pub est_profile_s: f64,
    pub est_optimized_s: f64,
}

pub struct CfpResult {
    pub graph: Graph,
    pub blocks: BlockSet,
    pub segments: SegmentSet,
    /// series-parallel shape of `segments` — `chain(n)` for linear models,
    /// fork/join branch groups for MoE expert-parallel models
    pub topo: SpTopology,
    pub db: ProfileDb,
    pub plan: Plan,
    pub timings: PhaseTimings,
    pub mesh: Mesh,
}

impl CfpResult {
    /// Human-readable per-segment strategy description (Fig. 14 case study).
    pub fn describe_plan(&self) -> Vec<String> {
        let mut out = Vec::new();
        for (n, inst) in self.segments.instances.iter().enumerate() {
            let cfg = &self.db.segments[inst.unique_id].configs[self.plan.choice[n]];
            let labels: Vec<String> = inst
                .blocks
                .iter()
                .zip(&cfg.strategy)
                .map(|(&b, &s)| {
                    let blk = &self.blocks.blocks[b];
                    let entry = &self.graph.ops[blk.entry].name;
                    let short = entry.rsplit('/').next().unwrap_or(entry);
                    format!("{}={}", short, pretty(&blk.strategies[s].label))
                })
                .collect();
            out.push(format!("segment {n} (u{}): {}", inst.unique_id, labels.join(" ")));
        }
        out
    }

    /// Simulated step time of the selected plan over the WHOLE graph
    /// (cross-check against the composed Eq. 8 estimate — Fig. 10).
    pub fn whole_graph_step_us(&self, opts: &CfpOptions) -> f64 {
        let plan = self.global_plan();
        let mut prog = crate::spmd::lower(&self.graph, &self.blocks, &plan);
        crate::spmd::passes::bucket_gradients(&mut prog, 64 << 20);
        if opts.platform.name.contains("pcie") {
            crate::spmd::passes::dispatch_alltoall_sendrecv(&mut prog, opts.mesh.intra);
        }
        let cm = opts
            .compute
            .clone()
            .unwrap_or_else(|| ComputeModel::for_platform(&opts.platform));
        simulate(&prog, &opts.platform, opts.mesh.intra, &cm).total_us
    }

    /// Expand the per-segment choice into a per-block GlobalPlan.
    pub fn global_plan(&self) -> crate::spmd::GlobalPlan {
        self.global_plan_for(&self.plan.choice)
    }

    /// Expand any per-segment choice (incl. baseline plans) into a
    /// per-block GlobalPlan.
    pub fn global_plan_for(&self, seg_choice: &[usize]) -> crate::spmd::GlobalPlan {
        let mut choice = vec![0usize; self.blocks.blocks.len()];
        for (n, inst) in self.segments.instances.iter().enumerate() {
            let cfg = &self.db.segments[inst.unique_id].configs[seg_choice[n]];
            for (i, &b) in inst.blocks.iter().enumerate() {
                choice[b] = cfg.strategy[i];
            }
        }
        crate::spmd::GlobalPlan { choice, mesh: self.segments_mesh() }
    }

    /// Whole-graph simulation of an arbitrary per-segment choice.
    pub fn simulate_choice(
        &self,
        opts: &CfpOptions,
        seg_choice: &[usize],
    ) -> crate::cluster::SimReport {
        let plan = self.global_plan_for(seg_choice);
        let mut prog = crate::spmd::lower(&self.graph, &self.blocks, &plan);
        crate::spmd::passes::bucket_gradients(&mut prog, 64 << 20);
        if opts.mesh.nodes > 1 {
            crate::spmd::passes::bucket_gradients_inter(&mut prog, 64 << 20);
        }
        if opts.platform.name.contains("pcie") || opts.platform.name.contains("2node") {
            crate::spmd::passes::dispatch_alltoall_sendrecv(&mut prog, opts.mesh.intra);
        }
        let cm = opts
            .compute
            .clone()
            .unwrap_or_else(|| ComputeModel::for_platform(&opts.platform));
        simulate(&prog, &opts.platform, opts.mesh.intra, &cm)
    }

    fn segments_mesh(&self) -> Mesh {
        self.mesh
    }
}

fn pretty(label: &str) -> &str {
    match label {
        "m" => "dp",
        "n" => "tp-col",
        "k" => "tp-row",
        "b0" => "expert/batch",
        other => other,
    }
}

/// Run the full CFP pipeline. With `opts.cache_path` set, profiles are
/// served from / written back to the persistent cache, so a repeat run on
/// the same model + platform skips MetricsProfiling entirely.
pub fn run_cfp(opts: &CfpOptions) -> CfpResult {
    let mut cache = opts.open_cache();
    let result = run_cfp_with_cache(opts, cache.as_mut());
    save_cache(cache.as_mut());
    result
}

fn save_cache(cache: Option<&mut ProfileCache>) {
    if let Some(c) = cache {
        if let Err(e) = c.save() {
            crate::obs::diag::diag(&format!("cfp: could not persist profile cache: {e}"));
        }
    }
}

/// [`run_cfp`] against a caller-owned cache (in-memory or file-backed);
/// the caller decides when to [`ProfileCache::save`].
pub fn run_cfp_with_cache(opts: &CfpOptions, cache: Option<&mut ProfileCache>) -> CfpResult {
    run_cfp_with_handle(opts, CacheHandle::from_option(cache))
}

/// Re-entrant [`run_cfp`]: profiles through a process-wide shared cache,
/// so concurrent runs (the `cfp serve` worker pool) reuse each other's
/// freshly profiled segments instead of re-profiling. The planned output
/// is bit-identical to the exclusive-cache path — profiled values are
/// deterministic, so it cannot matter *which* run computed an entry.
pub fn run_cfp_shared(opts: &CfpOptions, shared: &SharedProfileCache) -> CfpResult {
    run_cfp_with_handle(opts, shared.handle())
}

/// [`run_cfp`] over any cache ownership shape ([`CacheHandle`]).
pub fn run_cfp_with_handle(opts: &CfpOptions, mut cache: CacheHandle<'_>) -> CfpResult {
    // search-panic fault: a poisoned request dies inside the pipeline;
    // the serve leader's catch_unwind must turn this into a structured
    // internal_error without taking the daemon (or its ledger) with it
    crate::util::failpoint::trip_panic("search.panic");
    let mut timings = PhaseTimings::default();
    let trace = &opts.trace;

    // AnalysisPasses: graph build + ParallelBlocks + segments
    let t0 = Instant::now();
    let analysis_span = trace.span("coordinator.analysis_passes");
    let graph = build_training(&opts.model);
    let blocks = build_parallel_blocks(&graph, opts.mesh.intra);
    let (segments, topo) = extract_with_topology(&graph, &blocks);
    drop(analysis_span);
    timings.analysis_passes_s = t0.elapsed().as_secs_f64();
    if trace.is_enabled() {
        trace.count(crate::obs::Counter::SegmentInstances, segments.instances.len() as u64);
        trace.count(crate::obs::Counter::SegmentUnique, segments.unique.len() as u64);
    }

    // ExecCompiling + MetricsProfiling (overlapped inside profile_model).
    // MetricsProfiling is charged at the measured per-config
    // lower+simulate wall (exactly 0 on a fully warm cache); the residual
    // profiling wall (config enumeration, cache lookups, reshard pricing)
    // is the compile-side bookkeeping.
    let t1 = Instant::now();
    let mut popts = ProfileOptions::new(opts.platform, opts.mesh)
        .with_threads(opts.threads)
        .with_trace(opts.trace.clone());
    if let Some(cm) = &opts.compute {
        popts = popts.with_compute(cm.clone());
    }
    let db = profile_model_handle(&graph, &blocks, &segments, &popts, cache.reborrow());
    let profiling_wall = t1.elapsed().as_secs_f64();
    timings.metrics_profiling_s = db.stats.profile_wall_s;
    timings.exec_compiling_s = (profiling_wall - db.stats.profile_wall_s).max(0.0);
    timings.est_compile_s = db.stats.est_compile_s;
    timings.est_profile_s = db.stats.est_profile_s;
    timings.est_optimized_s = db.stats.est_optimized_s;

    // ComposeSearch (one SearchCtx serves the capped pass and the
    // unconstrained fallback)
    let t2 = Instant::now();
    let search_span = trace.span("coordinator.compose_search");
    let cap = opts.mem_cap.or(Some(opts.platform.mem_capacity()));
    let sctx = cost::SearchCtx::with_trace(&segments, &db, opts.trace.clone());
    let n = segments.instances.len();
    trace.note("engine", opts.engine.as_str());
    trace.note("topology", topo.signature());
    // chain models take the chain DP verbatim (bit-identical fast path);
    // DAG models go through the spdag lanes with the same engine portfolio
    let plan = if topo.is_chain() {
        match cost::search_span_engine(&sctx, cap, 0, n, opts.engine) {
            Some(p) => {
                trace.note("lane", "capped-pareto");
                p
            }
            None => {
                trace.note("lane", "unconstrained-scalar");
                cost::search_span_engine(&sctx, None, 0, n, opts.engine)
                    .expect("no feasible plan")
            }
        }
    } else {
        let sp = spdag::SpCtx::new(&sctx, &topo, &db);
        match spdag::sp_search_span_engine(&sctx, &sp, cap, 0, n, opts.engine) {
            Some(p) => {
                trace.note("lane", "capped-pareto");
                p
            }
            None => {
                trace.note("lane", "unconstrained-scalar");
                spdag::sp_search_span_engine(&sctx, &sp, None, 0, n, opts.engine)
                    .expect("no feasible plan")
            }
        }
    };
    drop(search_span);
    timings.compose_search_s = t2.elapsed().as_secs_f64();

    CfpResult { graph, blocks, segments, topo, db, plan, timings, mesh: opts.mesh }
}

/// Output of the two-level (inter-op × intra-op) planner.
pub struct TwoLevelResult {
    /// the single-stage CFP result; its whole-cluster artifacts back the
    /// `k = 1` pipeline context, so the two runs share one profile pass
    pub single: CfpResult,
    /// best composed pipeline plan (never slower than `single` under
    /// `StageSpec::Auto`, since `k = 1` is a candidate). `None` only in
    /// memory-aware mode, when no candidate's 1F1B peak fits the cap even
    /// with checkpointing — the honest "this model does not fit" answer
    pub pipeline: Option<interop::PipelinePlan>,
    /// naive equal-layer-split + DDP-inside baseline over the same
    /// contexts (same memory accounting) — the bar the two-level planner
    /// has to clear; `None` when the naive recipe cannot fit the cap
    pub naive: Option<interop::PipelinePlan>,
    /// unique segments served from the profile cache, summed over the
    /// single-stage pass and every stage context (warm-path tracking for
    /// the harness eval tables and `cfp serve` counters)
    pub profile_hits: usize,
    /// unique segments actually profiled across the same passes
    pub profile_misses: usize,
    /// wall-clock µs spent inside plan search: the single-stage
    /// ComposeSearch plus the inter-op planning (span sweeps + stage DP,
    /// CFP and the naive baseline) — what `cfp serve`'s `search_us`
    /// counter and the harness `search µs` column accumulate, so serving
    /// deployments can observe search-side speedups directly
    pub search_us: f64,
}

/// Run the two-level planner: the single-stage CFP pipeline first (its
/// artifacts are adopted as the whole-cluster stage context), then the
/// inter-op stage DP over every candidate stage count, plus the naive
/// equal-split pipeline baseline. All sub-mesh profiling goes through the
/// same persistent cache as `run_cfp`, so warm two-level runs skip
/// MetricsProfiling for every stage count at once.
pub fn run_cfp_two_level(opts: &CfpOptions) -> TwoLevelResult {
    let mut cache = opts.open_cache();
    let result = run_cfp_two_level_with_cache(opts, cache.as_mut());
    save_cache(cache.as_mut());
    result
}

/// [`run_cfp_two_level`] against a caller-owned cache.
pub fn run_cfp_two_level_with_cache(
    opts: &CfpOptions,
    cache: Option<&mut ProfileCache>,
) -> TwoLevelResult {
    run_cfp_two_level_with_handle(opts, CacheHandle::from_option(cache))
}

/// Re-entrant [`run_cfp_two_level`] against a process-wide shared cache
/// — see [`run_cfp_shared`].
pub fn run_cfp_two_level_shared(
    opts: &CfpOptions,
    shared: &SharedProfileCache,
) -> TwoLevelResult {
    run_cfp_two_level_with_handle(opts, shared.handle())
}

/// [`run_cfp_two_level`] over any cache ownership shape.
pub fn run_cfp_two_level_with_handle(
    opts: &CfpOptions,
    mut cache: CacheHandle<'_>,
) -> TwoLevelResult {
    let single = run_cfp_with_handle(opts, cache.reborrow());

    let popts = opts.pipeline_options();
    let mut ctxs = interop::StageContexts::new();
    // the single-stage artifacts ARE the whole-cluster context: k = 1
    // reuses them verbatim (bit-identical plan, no second profile pass)
    ctxs.adopt(interop::StageContext {
        devices: opts.mesh.total(),
        mesh: opts.mesh,
        blocks: single.blocks.clone(),
        segments: single.segments.clone(),
        topo: single.topo.clone(),
        db: single.db.clone(),
    });
    ctxs.ensure_all(&single.graph, &popts, cache.reborrow());

    // warm-path accounting: the adopted context carries the single-stage
    // pass's stats, the rest were profiled (or cache-served) just above
    let profile_hits = ctxs.iter().map(|c| c.db.stats.cache_hits).sum();
    let profile_misses = ctxs.iter().map(|c| c.db.stats.cache_misses).sum();

    // outside memory-aware mode k = 1 is always feasible, so both plans
    // are Some; under a cap, None means "does not fit, even checkpointed"
    // (for the naive baseline exactly as for the CFP planner)
    let t_plan = Instant::now();
    let interop_span = opts.trace.span("coordinator.interop_plan");
    let pipeline = interop::plan_pipeline(&single.graph, &ctxs, &popts);
    let naive = baselines::naive_pipeline_plan(&single.graph, &ctxs, &popts);
    drop(interop_span);
    let search_us =
        (single.timings.compose_search_s + t_plan.elapsed().as_secs_f64()) * 1e6;
    TwoLevelResult { single, pipeline, naive, profile_hits, profile_misses, search_us }
}

/// Plans from every framework for a model/platform (Fig. 7 row).
pub struct Comparison {
    pub cfp: Plan,
    pub alpa: Plan,
    pub megatron: Plan,
    pub ddp: Plan,
    pub result: CfpResult,
}

pub fn compare_frameworks(opts: &CfpOptions) -> Comparison {
    let result = run_cfp(opts);
    let alpa = baselines::alpa_plan(&result.segments, &result.db);
    let megatron =
        baselines::megatron_plan(&result.graph, &result.blocks, &result.segments, &result.db);
    let ddp = baselines::ddp_plan(&result.graph, &result.blocks, &result.segments, &result.db);
    Comparison { cfp: result.plan.clone(), alpa, megatron, ddp, result }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pipeline_runs_end_to_end() {
        let opts = CfpOptions::new(
            ModelCfg::preset("gpt-tiny").with_layers(2),
            Platform::a100_pcie(4),
        );
        let r = run_cfp(&opts);
        assert!(r.plan.time_us > 0.0);
        assert!(!r.describe_plan().is_empty());
        assert!(r.timings.analysis_passes_s > 0.0);
    }

    #[test]
    fn moe_branched_model_plans_end_to_end_and_replays_bitwise() {
        let opts = CfpOptions::new(
            ModelCfg::preset("moe-ep-tiny").with_layers(2),
            Platform::a100_pcie(4),
        );
        let r = run_cfp(&opts);
        assert!(!r.topo.is_chain(), "moe-ep models must plan as a DAG");
        assert!(r.plan.time_us > 0.0);
        assert_eq!(r.plan.choice.len(), r.segments.instances.len());
        // the planner's reported time is the DAG closed form: replaying
        // the chosen assignment must reproduce it bit-for-bit
        let sctx = cost::SearchCtx::new(&r.segments, &r.db);
        let sp = spdag::SpCtx::new(&sctx, &r.topo, &r.db);
        let n = r.segments.instances.len();
        let (t, m) = spdag::sp_plan_cost_span(&sctx, &sp, &r.plan.choice, 0, n);
        assert!(t == r.plan.time_us, "replay {t} vs plan {}", r.plan.time_us);
        assert_eq!(m, r.plan.mem_bytes);
    }

    #[test]
    fn chain_models_keep_the_chain_planner_bitwise() {
        // the chain fast path: linear models must produce exactly the
        // plan the chain DP produces, bit for bit
        let opts = CfpOptions::new(
            ModelCfg::preset("gpt-tiny").with_layers(2),
            Platform::a100_pcie(4),
        );
        let r = run_cfp(&opts);
        assert!(r.topo.is_chain());
        let sctx = cost::SearchCtx::new(&r.segments, &r.db);
        let n = r.segments.instances.len();
        let cap = Some(opts.platform.mem_capacity());
        let direct = cost::search_span_engine(&sctx, cap, 0, n, cost::SearchEngine::Dp)
            .or_else(|| cost::search_span_engine(&sctx, None, 0, n, cost::SearchEngine::Dp))
            .unwrap();
        assert_eq!(r.plan.choice, direct.choice);
        assert!(r.plan.time_us == direct.time_us, "bit-identical time");
        assert_eq!(r.plan.mem_bytes, direct.mem_bytes);
    }

    #[test]
    fn comparison_orders_cfp_first() {
        let opts = CfpOptions::new(
            ModelCfg::preset("gpt-tiny").with_layers(2),
            Platform::a100_pcie(4),
        );
        let c = compare_frameworks(&opts);
        for (name, p) in
            [("alpa", &c.alpa), ("megatron", &c.megatron), ("ddp", &c.ddp)]
        {
            assert!(c.cfp.time_us <= p.time_us + 1e-6, "{name}");
        }
    }

    #[test]
    fn two_level_auto_never_loses_to_single_stage() {
        let opts = CfpOptions::new(
            ModelCfg::preset("gpt-tiny").with_layers(2),
            Platform::a100_pcie(4),
        )
        .with_stages(StageSpec::Auto);
        let r = run_cfp_two_level(&opts);
        let pipeline = r.pipeline.expect("legacy mode always yields a plan");
        let naive = r.naive.expect("legacy mode always yields a naive plan");
        // k = 1 is in the candidate set with exactly the single-stage time
        assert!(
            pipeline.step_time_us <= r.single.plan.time_us + 1e-9,
            "two-level {} vs single {}",
            pipeline.step_time_us,
            r.single.plan.time_us
        );
        assert!(naive.step_time_us > 0.0);
        assert!(!pipeline.stages.is_empty());
    }

    fn args_of(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn options_builder_mirrors_the_cli_flags() {
        let args = args_of(
            "pipeline --model gpt-tiny --layers 3 --batch 2 --threads 2 \
             --microbatches 4 --stages 2 --mem-cap 1.5 --recompute off \
             --cache-max-entries 64",
        );
        let built = CfpOptions::from_args(&args, PlannerKind::TwoLevel).unwrap();
        assert!(built.warnings.is_empty(), "{:?}", built.warnings);
        let o = built.opts;
        assert_eq!(o.model.name, "gpt-tiny");
        assert_eq!((o.model.layers, o.model.batch), (3, 2));
        assert_eq!(o.threads, 2);
        assert_eq!(o.microbatches, 4);
        assert_eq!(o.stages, StageSpec::Fixed(2));
        assert_eq!(o.mem_cap, Some((1.5 * (1u64 << 30) as f64) as u64));
        assert_eq!(o.recompute, RecomputeSpec::Off);
        assert_eq!(o.cache_max_entries, Some(64));
    }

    #[test]
    fn options_builder_defaults_depend_on_planner_kind() {
        let args = args_of("x --model gpt-tiny");
        let single = CfpOptions::from_args(&args, PlannerKind::SingleLevel).unwrap().opts;
        assert_eq!(single.stages, StageSpec::Single);
        assert_eq!(single.recompute, RecomputeSpec::Off);
        let two = CfpOptions::from_args(&args, PlannerKind::TwoLevel).unwrap().opts;
        assert_eq!(two.stages, StageSpec::Auto);
        assert_eq!(two.recompute, RecomputeSpec::Auto);
    }

    #[test]
    fn options_builder_rejects_unknown_names_and_warns_on_bad_values() {
        let args = args_of("x --model not-a-model");
        assert!(CfpOptions::from_args(&args, PlannerKind::SingleLevel).is_err());
        let args = args_of("x --platform not-a-platform");
        assert!(CfpOptions::from_args(&args, PlannerKind::SingleLevel).is_err());

        let args = args_of("x --model gpt-tiny --layers nope --mem-cap -3 --stages wat");
        let built = CfpOptions::from_args(&args, PlannerKind::SingleLevel).unwrap();
        assert_eq!(built.warnings.len(), 3, "{:?}", built.warnings);
        // warned flags keep their defaults
        assert_eq!(built.opts.model.layers, ModelCfg::preset("gpt-tiny").layers);
        assert_eq!(built.opts.mem_cap, None);
        assert_eq!(built.opts.stages, StageSpec::Single);
    }

    #[test]
    fn options_builder_parses_the_engine_flag() {
        let args = args_of("x --model gpt-tiny --engine exact");
        let built = CfpOptions::from_args(&args, PlannerKind::SingleLevel).unwrap();
        assert!(built.warnings.is_empty(), "{:?}", built.warnings);
        assert_eq!(built.opts.engine, cost::SearchEngine::Exact);

        let args = args_of("x --model gpt-tiny --engine ilp");
        let built = CfpOptions::from_args(&args, PlannerKind::SingleLevel).unwrap();
        assert_eq!(built.warnings.len(), 1, "{:?}", built.warnings);
        assert_eq!(built.opts.engine, cost::SearchEngine::Dp, "bad value keeps the default");
    }

    #[test]
    fn pipeline_validation_rejects_untileable_requests() {
        let args = args_of("pipeline --model gpt-tiny --stages 3");
        let built = CfpOptions::from_args(&args, PlannerKind::TwoLevel).unwrap();
        assert!(validate_pipeline_args(&args, &built.opts).is_err(), "3 ∤ 4 devices");
        let args = args_of("pipeline --model gpt-tiny --microbatches 0");
        let built = CfpOptions::from_args(&args, PlannerKind::TwoLevel).unwrap();
        assert!(validate_pipeline_args(&args, &built.opts).is_err(), "0 microbatches");
        let args = args_of("pipeline --model gpt-tiny --stages 2 --microbatches 4");
        let built = CfpOptions::from_args(&args, PlannerKind::TwoLevel).unwrap();
        assert!(validate_pipeline_args(&args, &built.opts).is_ok());
    }

    #[test]
    fn shared_cache_run_is_bit_identical_to_exclusive() {
        let opts = CfpOptions::new(
            ModelCfg::preset("gpt-tiny").with_layers(2),
            Platform::a100_pcie(4),
        );
        let exclusive = run_cfp(&opts);
        let shared = SharedProfileCache::in_memory();
        let a = run_cfp_shared(&opts, &shared);
        assert_eq!(a.plan.choice, exclusive.plan.choice);
        assert!(a.plan.time_us == exclusive.plan.time_us, "bit-identical time");
        assert_eq!(a.plan.mem_bytes, exclusive.plan.mem_bytes);
        assert!(a.db.stats.cache_misses > 0, "first shared run profiles");
        // a second shared run is fully warm off the same shared cache
        let b = run_cfp_shared(&opts, &shared);
        assert_eq!(b.db.stats.cache_misses, 0);
        assert_eq!(b.db.stats.cache_hits, a.db.stats.cache_misses);
        assert_eq!(b.plan.choice, exclusive.plan.choice);
        assert!(b.plan.time_us == exclusive.plan.time_us);
    }

    #[test]
    fn two_level_reports_profile_traffic() {
        let opts = CfpOptions::new(
            ModelCfg::preset("gpt-tiny").with_layers(2),
            Platform::a100_pcie(4),
        )
        .with_stages(StageSpec::Auto);
        let shared = SharedProfileCache::in_memory();
        let cold = run_cfp_two_level_shared(&opts, &shared);
        assert!(cold.profile_misses > 0, "cold two-level run profiles every context");
        assert_eq!(cold.profile_hits, 0);
        let warm = run_cfp_two_level_shared(&opts, &shared);
        assert_eq!(warm.profile_misses, 0, "warm run is all lookups");
        assert_eq!(warm.profile_hits, cold.profile_misses);
        let (p, q) = (warm.pipeline.expect("feasible"), cold.pipeline.expect("feasible"));
        assert!(p.step_time_us == q.step_time_us, "warm plan is bit-identical");
    }

    #[test]
    fn whole_graph_simulation_close_to_composed_estimate() {
        // Fig. 10 in miniature: Eq. 8 composition vs whole-graph lowering
        let opts = CfpOptions::new(
            ModelCfg::preset("gpt-tiny").with_layers(2),
            Platform::a100_pcie(4),
        );
        let r = run_cfp(&opts);
        let whole = r.whole_graph_step_us(&opts);
        let composed = r.plan.time_us;
        let ratio = whole / composed;
        assert!(
            (0.5..2.0).contains(&ratio),
            "whole {whole} vs composed {composed} (ratio {ratio})"
        );
    }
}
