//! Model-segment extraction (paper §4.1).
//!
//! The ParallelBlock chain is cut into segments at *narrow* boundaries —
//! points where exactly one tensor crosses between the prefix and suffix of
//! the chain (layer boundaries: only the residual stream crosses; intra-
//! layer boundaries carry ≥ 2 live tensors). Segments are then matched by
//! *fingerprint*: the fine-grained data-dependency structure of their
//! tensor-contraction entries (composed affine dependency classes between
//! consecutive entries + entry signatures + member histograms). Instances
//! with equal fingerprints share one profile (§4.2) — this is what makes
//! CFP's search overhead independent of model depth (§5.5).
//!
//! # Invariants
//!
//! * **Chain contiguity.** `SegmentSet::instances` is a partition of the
//!   block chain into contiguous, non-overlapping runs in chain order;
//!   every block belongs to exactly one instance. The Eq. 8/9 composition
//!   in [`crate::cost`] and the stage spans in [`crate::interop`] both
//!   index adjacent instances and are meaningless without this.
//! * **Fingerprint soundness.** Two instances share a `unique_id` only if
//!   their full fingerprint (entry structure, strategy labels, inter-entry
//!   affine dependency classes, and the orphan-op count) matches — sharing
//!   a profile is then safe because profiling only reads what the
//!   fingerprint pins down. The converse is not required: distinct
//!   fingerprints for behaviourally equal segments merely cost an extra
//!   profile.
//! * `fwd_range`s are disjoint, ascending, and cover `[0, fwd_end)`, so
//!   `op_to_instance` is total over forward ops.

pub mod fingerprint;

use crate::graph::{Graph, Role};
use crate::pblock::BlockSet;

pub use fingerprint::{fingerprint_digest, segment_fingerprint};

/// A segment instance: a contiguous run of ParallelBlocks.
#[derive(Clone, Debug)]
pub struct SegmentInstance {
    /// index into `SegmentSet::unique`
    pub unique_id: usize,
    /// block ids (ascending chain order)
    pub blocks: Vec<usize>,
    /// op-id range `[fwd_start, fwd_end)` of forward ops owned by this
    /// segment (blocks + orphan ops between them)
    pub fwd_range: (usize, usize),
}

/// A unique segment (distinct fingerprint).
#[derive(Clone, Debug)]
pub struct UniqueSegment {
    pub id: usize,
    pub fingerprint: String,
    /// representative instance index
    pub rep: usize,
    /// number of instances sharing this fingerprint
    pub count: usize,
}

#[derive(Clone, Debug)]
pub struct SegmentSet {
    pub instances: Vec<SegmentInstance>,
    pub unique: Vec<UniqueSegment>,
}

impl SegmentSet {
    pub fn num_unique(&self) -> usize {
        self.unique.len()
    }

    /// op → owning segment instance (fwd via range; bwd via grad_of; opt via
    /// the updated param's consumer segment).
    pub fn op_to_instance(&self, g: &Graph) -> Vec<usize> {
        let n = g.ops.len();
        let mut seg = vec![0usize; n];
        for (si, inst) in self.instances.iter().enumerate() {
            for o in inst.fwd_range.0..inst.fwd_range.1.min(n) {
                seg[o] = si;
            }
        }
        // params/constants dragged to their first consumer's segment
        let users = g.users();
        for op in &g.ops {
            if op.role == Role::Fwd && op.inputs.is_empty() {
                if let Some(&u) = users[op.id].first() {
                    seg[op.id] = seg[u];
                }
            }
        }
        // bwd ops follow their forward origin; opt ops follow their grad
        for op in &g.ops {
            match op.role {
                Role::Bwd => {
                    if let Some(f) = op.grad_of {
                        seg[op.id] = seg[f];
                    }
                }
                Role::Opt => {
                    if let Some(&i) = op.inputs.first() {
                        seg[op.id] = seg[i];
                    }
                }
                Role::Fwd => {}
            }
        }
        seg
    }
}

/// Minimum blocks per segment — a dense transformer layer's 4 ParallelBlocks
/// (paper §5.5); segments are never split below this, so the profiled unit
/// stays at layer granularity (81 joint configs per dense segment).
pub const MIN_SEG_BLOCKS: usize = 4;

/// Cut the block chain into segments and deduplicate by fingerprint.
///
/// Stage 1: detect the repetition period of the block-signature sequence
/// (the "ParallelBlock sequence matching" of §4.1) and chunk the periodic
/// region into aligned period-sized segments.
/// Stage 2: split chunks at internal *narrow* boundaries (≤1 crossing
/// activation tensor — layer boundaries) while every piece keeps
/// ≥ [`MIN_SEG_BLOCKS`] blocks. This separates alternating MoE/dense layers
/// into their own unique segments (paper §5.5) without fragmenting a dense
/// layer below the 4-block/81-config granularity.
pub fn extract_segments(g: &Graph, bs: &BlockSet) -> SegmentSet {
    let chain = block_chain(bs);
    let n = chain.len();
    let sig: Vec<String> = chain
        .iter()
        .map(|&b| {
            let blk = &bs.blocks[b];
            let mut s = String::new();
            fingerprint::entry_signature_str(g, blk.entry, &mut s);
            for st in &blk.strategies {
                s.push_str(&st.label);
            }
            s
        })
        .collect();

    // stage 1: smallest period covering a maximal aligned region
    let mut chunks: Vec<Vec<usize>> = Vec::new();
    let mut chosen: Option<(usize, usize, usize)> = None; // (p, a, b)
    for p in 1..=12.min(n.saturating_sub(1)) {
        // maximal [a, b) with sig[j] == sig[j+p] for all j in [a, b-p)
        let mut a = 0;
        while a + p < n && sig[a] != sig[a + p] {
            a += 1;
        }
        let mut b = a;
        while b + p < n && sig[b] == sig[b + p] {
            b += 1;
        }
        let span = (b + p).saturating_sub(a);
        if b > a && span >= 2 * p {
            chosen = Some((p, a, b + p));
            break; // smallest period wins
        }
    }
    match chosen {
        Some((p, a, b)) => {
            if a > 0 {
                chunks.push(chain[..a].to_vec());
            }
            let mut i = a;
            while i + p <= b {
                chunks.push(chain[i..i + p].to_vec());
                i += p;
            }
            if i < n {
                chunks.push(chain[i..].to_vec());
            }
        }
        None => chunks.push(chain.clone()),
    }

    // stage 2: split at internal narrow cuts, respecting MIN_SEG_BLOCKS
    let cuts = narrow_boundaries(g, bs, &chain);
    let mut pos_of: std::collections::BTreeMap<usize, usize> = Default::default();
    for (pos, &b) in chain.iter().enumerate() {
        pos_of.insert(b, pos);
    }
    let mut instances = Vec::new();
    for chunk in chunks {
        let start_pos = pos_of[&chunk[0]];
        let mut pieces: Vec<Vec<usize>> = vec![Vec::new()];
        for (off, &b) in chunk.iter().enumerate() {
            let pos = start_pos + off;
            let last_len = pieces.last().unwrap().len();
            if off > 0
                && cuts.contains(&pos)
                && last_len >= MIN_SEG_BLOCKS
                && chunk.len() - off >= MIN_SEG_BLOCKS
            {
                pieces.push(Vec::new());
            }
            pieces.last_mut().unwrap().push(b);
        }
        for piece in pieces {
            if !piece.is_empty() {
                instances.push(SegmentInstance {
                    unique_id: usize::MAX,
                    blocks: piece,
                    fwd_range: (0, 0),
                });
            }
        }
    }

    // forward op-id ranges: segment k owns ops from its first block's first
    // op (or 0 for the first segment) up to the next segment's start.
    let mut starts: Vec<usize> = instances
        .iter()
        .map(|inst| inst.blocks.iter().map(|&b| bs.blocks[b].ops[0]).min().unwrap())
        .collect();
    if !starts.is_empty() {
        starts[0] = 0;
    }
    let fwd_end = g
        .ops
        .iter()
        .filter(|o| o.role == Role::Fwd)
        .map(|o| o.id + 1)
        .max()
        .unwrap_or(0);
    for i in 0..instances.len() {
        let end = if i + 1 < instances.len() { starts[i + 1] } else { fwd_end };
        instances[i].fwd_range = (starts[i], end);
    }

    // fingerprint-based dedup. The block fingerprint is extended with the
    // count of orphan (non-block) forward ops the instance owns: the first
    // hidden layer owns the embedding prefix and therefore profiles
    // differently from subsequent layers — the paper found the same split
    // ("one unique segment for the first hidden layer and another for each
    // subsequent hidden layer", §5.5).
    let in_block: Vec<bool> = {
        let mut v = vec![false; g.ops.len()];
        for blk in &bs.blocks {
            for &o in &blk.ops {
                v[o] = true;
            }
        }
        v
    };
    let mut unique: Vec<UniqueSegment> = Vec::new();
    for i in 0..instances.len() {
        let orphans = (instances[i].fwd_range.0..instances[i].fwd_range.1.min(g.ops.len()))
            .filter(|&o| !in_block[o] && g.ops[o].role == Role::Fwd && !g.ops[o].inputs.is_empty())
            .count();
        let fp = format!(
            "{}|orphans:{orphans}",
            segment_fingerprint(g, bs, &instances[i].blocks)
        );
        match unique.iter().position(|u| u.fingerprint == fp) {
            Some(uid) => {
                instances[i].unique_id = uid;
                unique[uid].count += 1;
            }
            None => {
                let uid = unique.len();
                unique.push(UniqueSegment { id: uid, fingerprint: fp, rep: i, count: 1 });
                instances[i].unique_id = uid;
            }
        }
    }
    SegmentSet { instances, unique }
}

/// Blocks in chain order (by entry op id — builder order is topo order).
pub fn block_chain(bs: &BlockSet) -> Vec<usize> {
    let mut chain: Vec<usize> = (0..bs.blocks.len()).collect();
    chain.sort_by_key(|&b| bs.blocks[b].entry);
    chain
}

/// Boundaries (chain positions `i` meaning "cut before chain[i]") where at
/// most one activation tensor crosses the cut.
fn narrow_boundaries(g: &Graph, bs: &BlockSet, chain: &[usize]) -> Vec<usize> {
    let users = g.users();
    // cut position i ⇒ boundary right after the last member op of blocks
    // chain[0..i]; orphan lead-in ops (norm chains feeding block i) belong
    // to the segment of block i.
    let mut prev_end = 0usize;
    let mut cuts = Vec::new();
    for i in 1..chain.len() {
        prev_end = prev_end.max(*bs.blocks[chain[i - 1]].ops.last().unwrap());
        let boundary = prev_end + 1;
        let mut crossing = 0usize;
        for op in &g.ops[..boundary.min(g.ops.len())] {
            if op.role != Role::Fwd || op.inputs.is_empty() {
                continue;
            }
            let crosses = users[op.id]
                .iter()
                .any(|&u| u >= boundary && g.ops[u].role == Role::Fwd);
            if crosses {
                crossing += 1;
            }
        }
        if crossing <= 1 {
            cuts.push(i);
        }
    }
    cuts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_training, ModelCfg};
    use crate::pblock::build_parallel_blocks;

    fn segs(preset: &str, layers: usize) -> (Graph, BlockSet, SegmentSet) {
        let cfg = ModelCfg::preset(preset).with_layers(layers);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let ss = extract_segments(&g, &bs);
        (g, bs, ss)
    }

    #[test]
    fn gpt_layers_become_repeated_segments() {
        let (_, _, ss) = segs("gpt-tiny", 4);
        let layer_seg = ss.unique.iter().map(|u| u.count).max().unwrap();
        assert!(layer_seg >= 3, "repeated layer segments: {layer_seg}");
        let (_, _, ss8) = segs("gpt-tiny", 8);
        assert_eq!(
            ss.num_unique(),
            ss8.num_unique(),
            "unique segments independent of depth: {} vs {}",
            ss.num_unique(),
            ss8.num_unique()
        );
    }

    #[test]
    fn segments_cover_all_blocks_exactly_once() {
        let (_, bs, ss) = segs("gpt-tiny", 4);
        let mut seen = vec![false; bs.blocks.len()];
        for inst in &ss.instances {
            for &b in &inst.blocks {
                assert!(!seen[b], "block {b} in two segments");
                seen[b] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "all blocks covered");
    }

    #[test]
    fn moe_alternating_layers_get_distinct_segments() {
        // 6 layers: dense-l0 (owns embedding prefix → own unique),
        // moe ×3, dense ×2, head — both layer flavours repeat
        let (_, _, ss) = segs("moe-tiny", 6);
        assert!(ss.num_unique() >= 4, "unique: {}", ss.num_unique());
        let counts: Vec<usize> = ss.unique.iter().map(|u| u.count).collect();
        assert!(counts.iter().filter(|&&c| c >= 2).count() >= 2, "{counts:?}");
    }

    #[test]
    fn op_to_instance_total() {
        let (g, _, ss) = segs("gpt-tiny", 2);
        let m = ss.op_to_instance(&g);
        assert_eq!(m.len(), g.ops.len());
        for si in 0..ss.instances.len() {
            assert!(m.iter().any(|&s| s == si), "segment {si} owns no ops");
        }
    }

    #[test]
    fn fingerprints_differ_for_different_shapes() {
        let cfg_a = ModelCfg::preset("gpt-tiny").with_layers(2);
        let cfg_b = ModelCfg::preset("gpt-tiny").with_layers(2).with_batch(8);
        let ga = build_training(&cfg_a);
        let gb = build_training(&cfg_b);
        let ba = build_parallel_blocks(&ga, 4);
        let bb = build_parallel_blocks(&gb, 4);
        let sa = extract_segments(&ga, &ba);
        let sb = extract_segments(&gb, &bb);
        let fa = &sa.unique.iter().map(|u| u.fingerprint.clone()).collect::<Vec<_>>();
        let fb = &sb.unique.iter().map(|u| u.fingerprint.clone()).collect::<Vec<_>>();
        assert_ne!(fa, fb);
    }
}
