//! Model-segment extraction (paper §4.1).
//!
//! The ParallelBlock chain is cut into segments at *narrow* boundaries —
//! points where exactly one tensor crosses between the prefix and suffix of
//! the chain (layer boundaries: only the residual stream crosses; intra-
//! layer boundaries carry ≥ 2 live tensors). Segments are then matched by
//! *fingerprint*: the fine-grained data-dependency structure of their
//! tensor-contraction entries (composed affine dependency classes between
//! consecutive entries + entry signatures + member histograms). Instances
//! with equal fingerprints share one profile (§4.2) — this is what makes
//! CFP's search overhead independent of model depth (§5.5).
//!
//! # Invariants
//!
//! * **Chain contiguity.** `SegmentSet::instances` is a partition of the
//!   block chain into contiguous, non-overlapping runs in chain order;
//!   every block belongs to exactly one instance. The Eq. 8/9 composition
//!   in [`crate::cost`] and the stage spans in [`crate::interop`] both
//!   index adjacent instances and are meaningless without this.
//! * **Fingerprint soundness.** Two instances share a `unique_id` only if
//!   their full fingerprint (entry structure, strategy labels, inter-entry
//!   affine dependency classes, and the orphan-op count) matches — sharing
//!   a profile is then safe because profiling only reads what the
//!   fingerprint pins down. The converse is not required: distinct
//!   fingerprints for behaviourally equal segments merely cost an extra
//!   profile.
//! * `fwd_range`s are disjoint, ascending, and cover `[0, fwd_end)`, so
//!   `op_to_instance` is total over forward ops.

pub mod fingerprint;

use crate::graph::{Graph, Role};
use crate::pblock::BlockSet;

pub use fingerprint::{fingerprint_digest, segment_fingerprint};

/// A segment instance: a contiguous run of ParallelBlocks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SegmentInstance {
    /// index into `SegmentSet::unique`
    pub unique_id: usize,
    /// block ids (ascending chain order)
    pub blocks: Vec<usize>,
    /// op-id range `[fwd_start, fwd_end)` of forward ops owned by this
    /// segment (blocks + orphan ops between them)
    pub fwd_range: (usize, usize),
}

/// A unique segment (distinct fingerprint).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct UniqueSegment {
    pub id: usize,
    pub fingerprint: String,
    /// representative instance index
    pub rep: usize,
    /// number of instances sharing this fingerprint
    pub count: usize,
}

#[derive(Clone, Debug)]
pub struct SegmentSet {
    pub instances: Vec<SegmentInstance>,
    pub unique: Vec<UniqueSegment>,
}

impl SegmentSet {
    pub fn num_unique(&self) -> usize {
        self.unique.len()
    }

    /// op → owning segment instance (fwd via range; bwd via grad_of; opt via
    /// the updated param's consumer segment).
    pub fn op_to_instance(&self, g: &Graph) -> Vec<usize> {
        let n = g.ops.len();
        let mut seg = vec![0usize; n];
        for (si, inst) in self.instances.iter().enumerate() {
            for o in inst.fwd_range.0..inst.fwd_range.1.min(n) {
                seg[o] = si;
            }
        }
        // params/constants dragged to their first consumer's segment
        let users = g.users();
        for op in &g.ops {
            if op.role == Role::Fwd && op.inputs.is_empty() {
                if let Some(&u) = users[op.id].first() {
                    seg[op.id] = seg[u];
                }
            }
        }
        // bwd ops follow their forward origin; opt ops follow their grad
        for op in &g.ops {
            match op.role {
                Role::Bwd => {
                    if let Some(f) = op.grad_of {
                        seg[op.id] = seg[f];
                    }
                }
                Role::Opt => {
                    if let Some(&i) = op.inputs.first() {
                        seg[op.id] = seg[i];
                    }
                }
                Role::Fwd => {}
            }
        }
        seg
    }
}

/// Minimum blocks per segment — a dense transformer layer's 4 ParallelBlocks
/// (paper §5.5); segments are never split below this, so the profiled unit
/// stays at layer granularity (81 joint configs per dense segment).
pub const MIN_SEG_BLOCKS: usize = 4;

/// Cut the block chain into segments and deduplicate by fingerprint.
///
/// Stage 1: detect the repetition period of the block-signature sequence
/// (the "ParallelBlock sequence matching" of §4.1) and chunk the periodic
/// region into aligned period-sized segments.
/// Stage 2: split chunks at internal *narrow* boundaries (≤1 crossing
/// activation tensor — layer boundaries) while every piece keeps
/// ≥ [`MIN_SEG_BLOCKS`] blocks. This separates alternating MoE/dense layers
/// into their own unique segments (paper §5.5) without fragmenting a dense
/// layer below the 4-block/81-config granularity.
pub fn extract_segments(g: &Graph, bs: &BlockSet) -> SegmentSet {
    let chain = block_chain(bs);
    let n = chain.len();
    let sig: Vec<String> = chain
        .iter()
        .map(|&b| {
            let blk = &bs.blocks[b];
            let mut s = String::new();
            fingerprint::entry_signature_str(g, blk.entry, &mut s);
            for st in &blk.strategies {
                s.push_str(&st.label);
            }
            s
        })
        .collect();

    // stage 1: smallest period covering a maximal aligned region
    let mut chunks: Vec<Vec<usize>> = Vec::new();
    let mut chosen: Option<(usize, usize, usize)> = None; // (p, a, b)
    for p in 1..=12.min(n.saturating_sub(1)) {
        // maximal [a, b) with sig[j] == sig[j+p] for all j in [a, b-p)
        let mut a = 0;
        while a + p < n && sig[a] != sig[a + p] {
            a += 1;
        }
        let mut b = a;
        while b + p < n && sig[b] == sig[b + p] {
            b += 1;
        }
        let span = (b + p).saturating_sub(a);
        if b > a && span >= 2 * p {
            chosen = Some((p, a, b + p));
            break; // smallest period wins
        }
    }
    match chosen {
        Some((p, a, b)) => {
            if a > 0 {
                chunks.push(chain[..a].to_vec());
            }
            let mut i = a;
            while i + p <= b {
                chunks.push(chain[i..i + p].to_vec());
                i += p;
            }
            if i < n {
                chunks.push(chain[i..].to_vec());
            }
        }
        None => chunks.push(chain.clone()),
    }

    // stage 2: split at internal narrow cuts, respecting MIN_SEG_BLOCKS
    let cuts = narrow_boundaries(g, bs, &chain);
    let mut pos_of: std::collections::BTreeMap<usize, usize> = Default::default();
    for (pos, &b) in chain.iter().enumerate() {
        pos_of.insert(b, pos);
    }
    let mut instances = Vec::new();
    for chunk in chunks {
        let start_pos = pos_of[&chunk[0]];
        let mut pieces: Vec<Vec<usize>> = vec![Vec::new()];
        for (off, &b) in chunk.iter().enumerate() {
            let pos = start_pos + off;
            let last_len = pieces.last().unwrap().len();
            if off > 0
                && cuts.contains(&pos)
                && last_len >= MIN_SEG_BLOCKS
                && chunk.len() - off >= MIN_SEG_BLOCKS
            {
                pieces.push(Vec::new());
            }
            pieces.last_mut().unwrap().push(b);
        }
        for piece in pieces {
            if !piece.is_empty() {
                instances.push(SegmentInstance {
                    unique_id: usize::MAX,
                    blocks: piece,
                    fwd_range: (0, 0),
                });
            }
        }
    }

    // forward op-id ranges: segment k owns ops from its first block's first
    // op (or 0 for the first segment) up to the next segment's start.
    let mut starts: Vec<usize> = instances
        .iter()
        .map(|inst| inst.blocks.iter().map(|&b| bs.blocks[b].ops[0]).min().unwrap())
        .collect();
    if !starts.is_empty() {
        starts[0] = 0;
    }
    let fwd_end = g
        .ops
        .iter()
        .filter(|o| o.role == Role::Fwd)
        .map(|o| o.id + 1)
        .max()
        .unwrap_or(0);
    for i in 0..instances.len() {
        let end = if i + 1 < instances.len() { starts[i + 1] } else { fwd_end };
        instances[i].fwd_range = (starts[i], end);
    }

    let unique = dedup_by_fingerprint(g, bs, &mut instances);
    SegmentSet { instances, unique }
}

/// Fingerprint-based dedup shared by [`extract_segments`] and
/// [`extract_with_topology`]. The block fingerprint is extended with the
/// count of orphan (non-block) forward ops the instance owns: the first
/// hidden layer owns the embedding prefix and therefore profiles
/// differently from subsequent layers — the paper found the same split
/// ("one unique segment for the first hidden layer and another for each
/// subsequent hidden layer", §5.5). Structural fingerprints also make
/// identical MoE expert branches share one unique segment — `E` experts
/// cost one profile pass, not `E`.
fn dedup_by_fingerprint(
    g: &Graph,
    bs: &BlockSet,
    instances: &mut [SegmentInstance],
) -> Vec<UniqueSegment> {
    let in_block: Vec<bool> = {
        let mut v = vec![false; g.ops.len()];
        for blk in &bs.blocks {
            for &o in &blk.ops {
                v[o] = true;
            }
        }
        v
    };
    let mut unique: Vec<UniqueSegment> = Vec::new();
    for i in 0..instances.len() {
        let orphans = (instances[i].fwd_range.0..instances[i].fwd_range.1.min(g.ops.len()))
            .filter(|&o| !in_block[o] && g.ops[o].role == Role::Fwd && !g.ops[o].inputs.is_empty())
            .count();
        let fp = format!(
            "{}|orphans:{orphans}",
            segment_fingerprint(g, bs, &instances[i].blocks)
        );
        match unique.iter().position(|u| u.fingerprint == fp) {
            Some(uid) => {
                instances[i].unique_id = uid;
                unique[uid].count += 1;
            }
            None => {
                let uid = unique.len();
                unique.push(UniqueSegment { id: uid, fingerprint: fp, rep: i, count: 1 });
                instances[i].unique_id = uid;
            }
        }
    }
    unique
}

/// DAG-aware extraction: like [`extract_segments`], but when the graph
/// records fork/join branch groups ([`Graph::record_branch_group`] — MoE
/// expert parallelism), each branch becomes **one segment instance** and
/// the returned [`crate::spdag::SpTopology`] places those instances in
/// per-group parallel branches. Chain graphs (no recorded groups) take
/// the existing extractor verbatim and return the chain topology, so the
/// chain path is bit-identical by construction.
///
/// Instance layout for a branched graph, in linearized chain order:
///
/// * **Trunk runs** (maximal runs of blocks outside every branch op
///   range, classified by block entry op) are split at narrow boundaries
///   with the [`MIN_SEG_BLOCKS`] floor, like stage 2 of the chain
///   extractor.
/// * **Branches**: one instance per recorded branch; its `fwd_range` is
///   exactly the recorded op range, so router/dispatch orphans stay with
///   the *fork* (preceding trunk) instance.
/// * **Merge ownership**: the trunk instance after a group starts at the
///   group's last op — combine/weighting orphan ops belong to the
///   *successor*, which is why the topology never needs a separate merge
///   node.
pub fn extract_with_topology(g: &Graph, bs: &BlockSet) -> (SegmentSet, crate::spdag::SpTopology) {
    use crate::spdag::{BranchGroup, SpTopology};

    if g.branch_groups.is_empty() {
        let ss = extract_segments(g, bs);
        let n = ss.instances.len();
        return (ss, SpTopology::chain(n));
    }

    let chain = block_chain(bs);
    // classify each chain position by entry op: trunk or (group, branch)
    let klass: Vec<Option<(usize, usize)>> = chain
        .iter()
        .map(|&b| {
            let entry = bs.blocks[b].entry;
            g.branch_groups.iter().enumerate().find_map(|(gi, group)| {
                group
                    .iter()
                    .position(|&(s, e)| (s..e).contains(&entry))
                    .map(|bi| (gi, bi))
            })
        })
        .collect();

    let cuts = narrow_boundaries(g, bs, &chain);
    let mut instances: Vec<SegmentInstance> = Vec::new();
    // fwd start per instance (usize::MAX = default first-block rule)
    let mut starts: Vec<usize> = Vec::new();
    let mut topo_groups: Vec<BranchGroup> = Vec::new();
    // set after a group: the successor trunk instance owns the merge ops
    let mut merge_start: Option<usize> = None;
    let mut pos = 0usize;
    while pos < chain.len() {
        match klass[pos] {
            None => {
                let run_end =
                    (pos..chain.len()).find(|&p| klass[p].is_some()).unwrap_or(chain.len());
                let mut piece_start = pos;
                let mut pieces: Vec<(usize, usize)> = Vec::new();
                for p in pos + 1..run_end {
                    if cuts.binary_search(&p).is_ok()
                        && p - piece_start >= MIN_SEG_BLOCKS
                        && run_end - p >= MIN_SEG_BLOCKS
                    {
                        pieces.push((piece_start, p));
                        piece_start = p;
                    }
                }
                pieces.push((piece_start, run_end));
                for (a, b) in pieces {
                    instances.push(SegmentInstance {
                        unique_id: usize::MAX,
                        blocks: chain[a..b].to_vec(),
                        fwd_range: (0, 0),
                    });
                    starts.push(merge_start.take().unwrap_or(usize::MAX));
                }
                pos = run_end;
            }
            Some((gi, _)) => {
                let group = &g.branch_groups[gi];
                let first_inst = instances.len();
                for (bi, &(s, _)) in group.iter().enumerate() {
                    let blocks: Vec<usize> = (pos..chain.len())
                        .take_while(|&p| klass[p] == Some((gi, bi)))
                        .map(|p| chain[p])
                        .collect();
                    assert!(
                        !blocks.is_empty(),
                        "branch {bi} of group {gi} owns no parallel blocks"
                    );
                    pos += blocks.len();
                    instances.push(SegmentInstance {
                        unique_id: usize::MAX,
                        blocks,
                        fwd_range: (0, 0),
                    });
                    starts.push(s);
                }
                topo_groups.push(BranchGroup {
                    branches: (first_inst..instances.len()).map(|i| (i, i + 1)).collect(),
                });
                merge_start = Some(group.last().unwrap().1);
            }
        }
    }
    assert!(merge_start.is_none(), "a branch group has no successor instance");

    // fwd op ranges: explicit starts for branch/successor instances, the
    // first-block rule elsewhere; instance 0 owns the graph prefix
    for (i, inst) in instances.iter().enumerate() {
        if starts[i] == usize::MAX {
            starts[i] = inst.blocks.iter().map(|&b| bs.blocks[b].ops[0]).min().unwrap();
        }
    }
    starts[0] = 0;
    let fwd_end = g
        .ops
        .iter()
        .filter(|o| o.role == Role::Fwd)
        .map(|o| o.id + 1)
        .max()
        .unwrap_or(0);
    for i in 0..instances.len() {
        let end = if i + 1 < instances.len() { starts[i + 1] } else { fwd_end };
        instances[i].fwd_range = (starts[i], end);
    }

    let unique = dedup_by_fingerprint(g, bs, &mut instances);
    let topo = SpTopology { n: instances.len(), groups: topo_groups };
    topo.validate().expect("graph branch groups produced an invalid SP topology");
    (SegmentSet { instances, unique }, topo)
}

/// Blocks in chain order (by entry op id — builder order is topo order).
pub fn block_chain(bs: &BlockSet) -> Vec<usize> {
    let mut chain: Vec<usize> = (0..bs.blocks.len()).collect();
    chain.sort_by_key(|&b| bs.blocks[b].entry);
    chain
}

/// Boundaries (chain positions `i` meaning "cut before chain[i]") where at
/// most one activation tensor crosses the cut.
fn narrow_boundaries(g: &Graph, bs: &BlockSet, chain: &[usize]) -> Vec<usize> {
    let users = g.users();
    // cut position i ⇒ boundary right after the last member op of blocks
    // chain[0..i]; orphan lead-in ops (norm chains feeding block i) belong
    // to the segment of block i.
    let mut prev_end = 0usize;
    let mut cuts = Vec::new();
    for i in 1..chain.len() {
        prev_end = prev_end.max(*bs.blocks[chain[i - 1]].ops.last().unwrap());
        let boundary = prev_end + 1;
        let mut crossing = 0usize;
        for op in &g.ops[..boundary.min(g.ops.len())] {
            if op.role != Role::Fwd || op.inputs.is_empty() {
                continue;
            }
            let crosses = users[op.id]
                .iter()
                .any(|&u| u >= boundary && g.ops[u].role == Role::Fwd);
            if crosses {
                crossing += 1;
            }
        }
        if crossing <= 1 {
            cuts.push(i);
        }
    }
    cuts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_training, ModelCfg};
    use crate::pblock::build_parallel_blocks;

    fn segs(preset: &str, layers: usize) -> (Graph, BlockSet, SegmentSet) {
        let cfg = ModelCfg::preset(preset).with_layers(layers);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let ss = extract_segments(&g, &bs);
        (g, bs, ss)
    }

    #[test]
    fn gpt_layers_become_repeated_segments() {
        let (_, _, ss) = segs("gpt-tiny", 4);
        let layer_seg = ss.unique.iter().map(|u| u.count).max().unwrap();
        assert!(layer_seg >= 3, "repeated layer segments: {layer_seg}");
        let (_, _, ss8) = segs("gpt-tiny", 8);
        assert_eq!(
            ss.num_unique(),
            ss8.num_unique(),
            "unique segments independent of depth: {} vs {}",
            ss.num_unique(),
            ss8.num_unique()
        );
    }

    #[test]
    fn segments_cover_all_blocks_exactly_once() {
        let (_, bs, ss) = segs("gpt-tiny", 4);
        let mut seen = vec![false; bs.blocks.len()];
        for inst in &ss.instances {
            for &b in &inst.blocks {
                assert!(!seen[b], "block {b} in two segments");
                seen[b] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "all blocks covered");
    }

    #[test]
    fn moe_alternating_layers_get_distinct_segments() {
        // 6 layers: dense-l0 (owns embedding prefix → own unique),
        // moe ×3, dense ×2, head — both layer flavours repeat
        let (_, _, ss) = segs("moe-tiny", 6);
        assert!(ss.num_unique() >= 4, "unique: {}", ss.num_unique());
        let counts: Vec<usize> = ss.unique.iter().map(|u| u.count).collect();
        assert!(counts.iter().filter(|&&c| c >= 2).count() >= 2, "{counts:?}");
    }

    #[test]
    fn op_to_instance_total() {
        let (g, _, ss) = segs("gpt-tiny", 2);
        let m = ss.op_to_instance(&g);
        assert_eq!(m.len(), g.ops.len());
        for si in 0..ss.instances.len() {
            assert!(m.iter().any(|&s| s == si), "segment {si} owns no ops");
        }
    }

    #[test]
    fn fingerprints_differ_for_different_shapes() {
        let cfg_a = ModelCfg::preset("gpt-tiny").with_layers(2);
        let cfg_b = ModelCfg::preset("gpt-tiny").with_layers(2).with_batch(8);
        let ga = build_training(&cfg_a);
        let gb = build_training(&cfg_b);
        let ba = build_parallel_blocks(&ga, 4);
        let bb = build_parallel_blocks(&gb, 4);
        let sa = extract_segments(&ga, &ba);
        let sb = extract_segments(&gb, &bb);
        let fa = &sa.unique.iter().map(|u| u.fingerprint.clone()).collect::<Vec<_>>();
        let fb = &sb.unique.iter().map(|u| u.fingerprint.clone()).collect::<Vec<_>>();
        assert_ne!(fa, fb);
    }

    #[test]
    fn chain_models_take_the_chain_extractor_verbatim() {
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(4);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let chain = extract_segments(&g, &bs);
        let (ss, topo) = extract_with_topology(&g, &bs);
        assert!(topo.is_chain());
        assert_eq!(topo.n, chain.instances.len());
        assert_eq!(ss.instances, chain.instances);
        assert_eq!(ss.unique, chain.unique);
    }

    #[test]
    fn moe_expert_branches_become_parallel_instances() {
        // 4 layers, 4 experts: dense-l0, moe-l1, dense-l2, moe-l3, head
        // → two branch groups of 4 single-instance branches each
        let cfg = ModelCfg::preset("moe-ep-tiny").with_layers(4);
        let g = build_training(&cfg);
        assert_eq!(g.branch_groups.len(), 2);
        let bs = build_parallel_blocks(&g, 4);
        let (ss, topo) = extract_with_topology(&g, &bs);
        assert!(!topo.is_chain());
        assert_eq!(topo.n, ss.instances.len());
        assert_eq!(topo.groups.len(), 2);
        for bg in &topo.groups {
            assert_eq!(bg.branches.len(), 4);
            for &(lo, hi) in &bg.branches {
                assert_eq!(hi, lo + 1, "each expert branch is one instance");
            }
        }
        topo.validate().unwrap();
    }

    #[test]
    fn expert_branches_share_one_unique_segment() {
        let cfg = ModelCfg::preset("moe-ep-tiny").with_layers(2);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let (ss, topo) = extract_with_topology(&g, &bs);
        let bg = &topo.groups[0];
        let uids: Vec<usize> = bg
            .branches
            .iter()
            .map(|&(lo, _)| ss.instances[lo].unique_id)
            .collect();
        assert!(
            uids.windows(2).all(|w| w[0] == w[1]),
            "identical experts must dedup to one unique segment, got {uids:?}"
        );
    }

    #[test]
    fn dag_instances_cover_blocks_and_ops_exactly_once() {
        let cfg = ModelCfg::preset("moe-ep-tiny").with_layers(4);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let (ss, _) = extract_with_topology(&g, &bs);
        let mut seen = vec![false; bs.blocks.len()];
        for inst in &ss.instances {
            for &b in &inst.blocks {
                assert!(!seen[b], "block {b} owned twice");
                seen[b] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "some block is unowned");
        // fwd ranges: disjoint, ascending, covering [0, fwd_end)
        let fwd_end = g
            .ops
            .iter()
            .filter(|o| o.role == Role::Fwd)
            .map(|o| o.id + 1)
            .max()
            .unwrap();
        assert_eq!(ss.instances[0].fwd_range.0, 0);
        assert_eq!(ss.instances.last().unwrap().fwd_range.1, fwd_end);
        for w in ss.instances.windows(2) {
            assert_eq!(w[0].fwd_range.1, w[1].fwd_range.0);
            assert!(w[0].fwd_range.0 < w[0].fwd_range.1);
        }
    }
}
