//! Segment fingerprints (paper §4.1, Fig. 6): the fine-grained data
//! dependency graph of tensor-contraction operators, encoded canonically.
//!
//! Two segments with equal fingerprints have (a) the same parallel space —
//! entry signatures determine the strategies — and (b) the same
//! communication behaviour under equal configurations — the composed affine
//! dependencies between consecutive contractions determine where reshards
//! appear. Trivial data-reorganization differences do NOT change the
//! fingerprint (Fig. 6's point), because only dependency *classes*
//! (point/block/all/free) are encoded, not the op lists.

use std::fmt::Write as _;

use crate::affine::{compose, op_dim_map, DimDep, DimMap};
use crate::graph::{Graph, OpId, OpKind};
use crate::pblock::BlockSet;

/// Canonical fingerprint of a run of blocks.
pub fn segment_fingerprint(g: &Graph, bs: &BlockSet, blocks: &[usize]) -> String {
    let mut s = String::new();
    for (i, &b) in blocks.iter().enumerate() {
        let blk = &bs.blocks[b];
        entry_signature(g, blk.entry, &mut s);
        // strategy labels are part of the parallel space
        let labels: Vec<&str> = blk.strategies.iter().map(|st| st.label.as_str()).collect();
        let _ = write!(s, "[{}]", labels.join(","));
        if i + 1 < blocks.len() {
            let next = &bs.blocks[blocks[i + 1]];
            let dep = entry_dependency(g, blk.entry, next.entry);
            let _ = write!(s, "={}=>", dep);
        }
    }
    s
}

/// Entry contraction signature: dot structure + operand shapes.
pub fn entry_signature_str(g: &Graph, entry: OpId, out: &mut String) {
    entry_signature(g, entry, out)
}

fn entry_signature(g: &Graph, entry: OpId, out: &mut String) {
    let op = &g.ops[entry];
    if let OpKind::Dot(d) = &op.kind {
        let l = g.shape(op.inputs[0]);
        let r = g.shape(op.inputs[1]);
        let _ = write!(out, "dot{}({l:?}x{r:?})", d.batch);
    } else {
        let _ = write!(out, "{:?}", op.kind);
    }
}

/// Composed affine dependency classes from `from`'s output to `to`'s lhs
/// input (the fingerprint edges of Fig. 6). Walks producer chains of `to`'s
/// inputs backwards through non-contraction ops; encodes each consumer dim
/// as P(oint)/B(lock)/A(ll)/F(ree)/S(plit)/M(erge).
pub fn entry_dependency(g: &Graph, from: OpId, to: OpId) -> String {
    for (idx, _) in g.ops[to].inputs.iter().enumerate() {
        if let Some(map) = path_map(g, g.ops[to].inputs[idx], from, 0) {
            // prepend the to-op's own dependency on that input
            let first = op_dim_map(g, to, idx);
            let total = compose(&first, &map);
            return encode(&total);
        }
    }
    "-".into()
}

/// DimMap from tensor `t`'s dims to `target`'s output dims, composed along
/// producer chains (None if `target` unreachable without crossing another
/// contraction).
fn path_map(g: &Graph, t: OpId, target: OpId, depth: usize) -> Option<DimMap> {
    if t == target {
        return Some(DimMap::identity(g.shape(t).len()));
    }
    if depth > 24 {
        return None;
    }
    let op = &g.ops[t];
    if op.kind.is_contraction() || op.inputs.is_empty() {
        return None;
    }
    for (idx, &inp) in op.inputs.iter().enumerate() {
        if let Some(inner) = path_map(g, inp, target, depth + 1) {
            let m = op_dim_map(g, t, idx);
            return Some(compose(&m, &inner));
        }
    }
    None
}

/// Compact stable 64-bit digest of a fingerprint string (FNV-1a). Used
/// for human-scannable cache/CLI output; equality decisions always use
/// the full string (the digest is display-only, collisions are harmless).
pub fn fingerprint_digest(fp: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in fp.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn encode(m: &DimMap) -> String {
    m.deps
        .iter()
        .map(|d| match d {
            DimDep::Point { .. } => 'P',
            DimDep::Block { .. } => 'B',
            DimDep::All { .. } => 'A',
            DimDep::Free => 'F',
            DimDep::SplitHi { .. } => 'S',
            DimDep::SplitLo { .. } => 's',
            DimDep::Merge { .. } => 'M',
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_training, ModelCfg};
    use crate::pblock::build_parallel_blocks;

    #[test]
    fn equal_layers_equal_fingerprints() {
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(3);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        // per-layer block quadruples must fingerprint-match
        let l0: Vec<usize> = (0..bs.blocks.len())
            .filter(|&b| g.ops[bs.blocks[b].entry].name.starts_with("l0/"))
            .collect();
        let l1: Vec<usize> = (0..bs.blocks.len())
            .filter(|&b| g.ops[bs.blocks[b].entry].name.starts_with("l1/"))
            .collect();
        assert_eq!(l0.len(), l1.len());
        assert_eq!(
            segment_fingerprint(&g, &bs, &l0),
            segment_fingerprint(&g, &bs, &l1)
        );
    }

    #[test]
    fn moe_layer_fingerprint_differs_from_dense() {
        let cfg = ModelCfg::preset("moe-tiny").with_layers(2);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 2);
        let l0: Vec<usize> = (0..bs.blocks.len())
            .filter(|&b| g.ops[bs.blocks[b].entry].name.starts_with("l0/"))
            .collect();
        let l1: Vec<usize> = (0..bs.blocks.len())
            .filter(|&b| g.ops[bs.blocks[b].entry].name.starts_with("l1/"))
            .collect();
        assert_ne!(
            segment_fingerprint(&g, &bs, &l0),
            segment_fingerprint(&g, &bs, &l1)
        );
    }

    #[test]
    fn digest_is_stable_and_separates_strings() {
        assert_eq!(fingerprint_digest(""), 0xcbf29ce484222325);
        assert_eq!(fingerprint_digest("dotPA"), fingerprint_digest("dotPA"));
        assert_ne!(fingerprint_digest("dotPA"), fingerprint_digest("dotPB"));
    }

    #[test]
    fn entry_dependency_finds_path() {
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(1);
        let g = build_training(&cfg);
        let w1 = g.ops.iter().find(|o| o.name == "l0/mlp/fc1").unwrap().id;
        let w2 = g.ops.iter().find(|o| o.name == "l0/mlp/fc2").unwrap().id;
        let dep = entry_dependency(&g, w1, w2);
        // fc2's output: M dim pointwise on fc1's output; N dim sweeps the
        // contracted lhs K — "PA"
        assert_eq!(dep, "PA", "{dep}");
    }
}
