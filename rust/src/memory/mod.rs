//! Memory subsystem: activation-memory accounting and the
//! rematerialization (activation-checkpointing) trade-off for the
//! two-level planner.
//!
//! CFP's intra-op DP (§4.4) caps plans by the *whole-batch* per-device
//! memory of one in-flight batch. A pipeline stage under 1F1B holds more:
//! stage `i` of `k` keeps the forward activations of up to `k − i`
//! in-flight microbatches alive until their backwards drain back through
//! it. This module makes that footprint a first-class, *searched*
//! quantity:
//!
//! * [`stage_peak_bytes`] — the closed-form per-device peak of a stage:
//!   `static + f · (retained/m) + transient/m`, where `static` is weights
//!   + gradient buckets + optimizer state (profile memory minus
//!   activations), `retained` the whole-batch activation bytes the stage
//!   must hold to backward, `transient` the recompute scratch of the one
//!   microbatch currently in backward, and `f =`
//!   [`inflight_microbatches`]` = min(m, k − i)` the 1F1B window.
//!   [`crate::cluster::simulate_pipeline_memory`] event-simulates the
//!   same schedule and the integration tests pin the two to each other
//!   *exactly*.
//! * [`remat_points`] — the per-(segment, config) rematerialization
//!   frontier: keep all activations (no extra time), or checkpoint the
//!   segment boundary and recompute the forward during backward
//!   (`retained` collapses to the boundary stash — the `ckpt_bytes`
//!   profile column — `transient` becomes the full activation set, and
//!   time grows by the profiled forward pass `t_fwd_us`).
//! * [`SpanMemPlan`] / [`select_feasible`] — one point of the span
//!   frontier produced by [`crate::cost::search_span_mem`] (per-instance
//!   config *and* remat choices), and the deterministic min-time
//!   selection under a peak-memory cap.
//!
//! # Invariants
//!
//! * **Accounting consistency.** A [`SpanFootprint`] derived from a
//!   choice vector ([`span_footprint`]) and one carried by a
//!   [`SpanMemPlan`] from the DP agree by construction: both sum
//!   [`seg_static_bytes`] and the chosen remat point's retained bytes and
//!   max the transient bytes. The closed-form peak is a pure function of
//!   the footprint, so every consumer (stage planner, naive baseline,
//!   event sim cross-check, CLI report) prices the same plan the same
//!   way.
//! * **Off means off.** With [`RecomputeSpec::Off`] the remat frontier is
//!   the single keep-everything point, so checkpointing can never leak
//!   into a plan; the accounting is then report-only unless a cap is set.

use crate::profiler::{ProfileDb, SegmentProfile};
use crate::segment::SegmentSet;

/// Whether the planner may trade recomputation for activation memory.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RecomputeSpec {
    /// Never checkpoint: plans keep every forward activation (the PR 2
    /// behaviour; with no `--mem-cap` this is bit-identical to PR 2).
    #[default]
    Off,
    /// Per-segment choice: the span DP searches keep-vs-checkpoint per
    /// instance and a memory-capped stage falls back to checkpointed
    /// variants instead of becoming infeasible.
    Auto,
}

impl RecomputeSpec {
    /// Parse a `--recompute` CLI value: `auto` or `off`.
    pub fn parse(s: &str) -> Option<RecomputeSpec> {
        match s {
            "auto" => Some(RecomputeSpec::Auto),
            "off" => Some(RecomputeSpec::Off),
            _ => None,
        }
    }

    pub fn is_auto(&self) -> bool {
        *self == RecomputeSpec::Auto
    }
}

/// One point of a segment's rematerialization trade-off.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RematPoint {
    /// activation bytes retained until the microbatch's backward
    pub retained_bytes: u64,
    /// recompute scratch live only while the backward runs
    pub transient_bytes: u64,
    /// extra whole-batch time (the recomputed forward pass), µs
    pub extra_us: f64,
    /// true for the checkpoint-and-recompute point
    pub checkpoint: bool,
}

/// Static (non-activation) bytes of one segment config: weights +
/// gradient buckets + optimizer state — the profile's peak memory with
/// the retained activations subtracted back out.
pub fn seg_static_bytes(p: &SegmentProfile, cfg: usize) -> u64 {
    p.mem_bytes[cfg].saturating_sub(p.act_bytes[cfg])
}

/// The rematerialization frontier of one (segment, config): the
/// keep-everything point, plus — under [`RecomputeSpec::Auto`], and only
/// when it actually saves memory — the checkpoint-boundary point.
pub fn remat_points(p: &SegmentProfile, cfg: usize, spec: RecomputeSpec) -> Vec<RematPoint> {
    let act = p.act_bytes[cfg];
    let mut out = vec![RematPoint {
        retained_bytes: act,
        transient_bytes: 0,
        extra_us: 0.0,
        checkpoint: false,
    }];
    if spec.is_auto() {
        let ckpt = p.ckpt_bytes[cfg];
        if ckpt < act {
            out.push(RematPoint {
                retained_bytes: ckpt,
                transient_bytes: act,
                extra_us: p.t_fwd_us[cfg],
                checkpoint: true,
            });
        }
    }
    out
}

/// Precomputed rematerialization frontiers for every (unique segment,
/// config) of a [`ProfileDb`] — the reuse buffer behind the span DP's
/// hot loop. [`remat_points`] allocates a fresh `Vec` per call; the
/// memory-axis DP used to call it per *(position, config)* inside its
/// innermost loop. A `RematTable` is built once per `(SegmentSet,
/// ProfileDb)` (it lives inside [`crate::cost::SearchCtx`]) and hands
/// out borrowed slices instead.
///
/// Both [`RecomputeSpec`] variants are served from one flat buffer: the
/// stored per-config list is the `Auto` frontier, whose first point is
/// always the keep-everything point — exactly the `Off` frontier — so
/// `Off` is the length-1 prefix of `Auto` by construction.
#[derive(Clone, Debug, Default)]
pub struct RematTable {
    points: Vec<RematPoint>,
    /// offsets per flat (unique, config) index, len = total configs + 1;
    /// flat index = (configs of uniques < u) + cfg, the same layout as
    /// `SearchCtx`'s per-config columns
    off: Vec<usize>,
}

impl RematTable {
    /// Build the table for every (unique, config) of `db`, in unique-id
    /// then config order (the `SearchCtx` flat-column layout).
    pub fn build(db: &ProfileDb) -> RematTable {
        let mut points = Vec::new();
        let mut off = Vec::with_capacity(
            db.segments.iter().map(|p| p.configs.len()).sum::<usize>() + 1,
        );
        off.push(0);
        for p in &db.segments {
            for cfg in 0..p.configs.len() {
                points.extend(remat_points(p, cfg, RecomputeSpec::Auto));
                off.push(points.len());
            }
        }
        RematTable { points, off }
    }

    /// The remat frontier of flat config index `flat` under `spec` —
    /// identical to [`remat_points`] on the owning profile, without the
    /// per-call allocation.
    pub fn points(&self, flat: usize, spec: RecomputeSpec) -> &[RematPoint] {
        let s = &self.points[self.off[flat]..self.off[flat + 1]];
        if spec.is_auto() {
            s
        } else {
            &s[..1]
        }
    }
}

/// The microbatch count the memory accounting of a `stages`-deep plan
/// divides by: a single stage bypasses the microbatch division entirely
/// (the PR 2 whole-batch convention), deeper pipelines split the batch
/// into `m` microbatches. Single source of the convention — the planner
/// (`interop`), the composed-plan reporting, and the sim cross-check all
/// call this.
pub fn memory_microbatches(stages: usize, m: usize) -> usize {
    if stages <= 1 {
        1
    } else {
        m.max(1)
    }
}

/// 1F1B in-flight window of stage `stage_idx` (0-based) in a `stages`-deep
/// pipeline running `m_eff` microbatches: stage `i` holds at most
/// `min(m, k − i)` microbatches' activations before their backwards drain.
pub fn inflight_microbatches(stages: usize, stage_idx: usize, m_eff: usize) -> usize {
    stages.saturating_sub(stage_idx).min(m_eff.max(1)).max(1)
}

/// Closed-form per-device peak memory of a pipeline stage under 1F1B.
/// `retained_bytes`/`transient_bytes` are whole-batch quantities; the
/// per-microbatch share is the floor division by `m_eff` — the event
/// simulation uses the *same* per-microbatch values, so the two match
/// exactly.
pub fn stage_peak_bytes(
    static_bytes: u64,
    retained_bytes: u64,
    transient_bytes: u64,
    m_eff: usize,
    inflight: usize,
) -> u64 {
    let m = m_eff.max(1) as u64;
    static_bytes + inflight.max(1) as u64 * (retained_bytes / m) + transient_bytes / m
}

/// The memory footprint of a contiguous span of segment instances
/// (whole-batch quantities; see [`stage_peak_bytes`] for the 1F1B peak).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SpanFootprint {
    /// weights + gradient buckets + optimizer state
    pub static_bytes: u64,
    /// activations retained until backward (whole batch)
    pub retained_bytes: u64,
    /// recompute scratch of the microbatch in backward (whole batch)
    pub transient_bytes: u64,
    /// whole-batch recompute time added by checkpointing, µs
    pub recompute_us: f64,
}

impl SpanFootprint {
    pub fn peak_bytes(&self, m_eff: usize, inflight: usize) -> u64 {
        stage_peak_bytes(
            self.static_bytes,
            self.retained_bytes,
            self.transient_bytes,
            m_eff,
            inflight,
        )
    }
}

/// Footprint of an explicit choice vector over span `[lo, hi)` with no
/// rematerialization (every activation kept) — the accounting the PR 2
/// planner and the naive baseline report.
pub fn span_footprint(
    ss: &SegmentSet,
    db: &ProfileDb,
    choice: &[usize],
    lo: usize,
    hi: usize,
) -> SpanFootprint {
    assert_eq!(choice.len(), hi - lo);
    let mut fp = SpanFootprint::default();
    for (i, n) in (lo..hi).enumerate() {
        let p = &db.segments[ss.instances[n].unique_id];
        fp.static_bytes += seg_static_bytes(p, choice[i]);
        fp.retained_bytes += p.act_bytes[choice[i]];
    }
    fp
}

/// Footprint of the all-or-nothing checkpointing fallback: every segment
/// whose boundary stash is smaller than its activations is checkpointed.
/// Returns the footprint and the per-instance checkpoint flags — the
/// naive pipeline's recovery path when its DDP stage overflows the cap.
pub fn span_footprint_checkpointed(
    ss: &SegmentSet,
    db: &ProfileDb,
    choice: &[usize],
    lo: usize,
    hi: usize,
) -> (SpanFootprint, Vec<bool>) {
    assert_eq!(choice.len(), hi - lo);
    let mut fp = SpanFootprint::default();
    let mut remat = vec![false; hi - lo];
    for (i, n) in (lo..hi).enumerate() {
        let p = &db.segments[ss.instances[n].unique_id];
        let c = choice[i];
        fp.static_bytes += seg_static_bytes(p, c);
        let act = p.act_bytes[c];
        let ckpt = p.ckpt_bytes[c];
        if ckpt < act {
            remat[i] = true;
            fp.retained_bytes += ckpt;
            fp.transient_bytes = fp.transient_bytes.max(act);
            fp.recompute_us += p.t_fwd_us[c];
        } else {
            fp.retained_bytes += act;
        }
    }
    (fp, remat)
}

/// One point of a span's (time × 1F1B-memory) frontier: per-instance
/// config *and* rematerialization choices, the resulting whole-batch time
/// (recompute included) and memory footprint. Produced by
/// [`crate::cost::search_span_mem`].
#[derive(Clone, Debug)]
pub struct SpanMemPlan {
    /// config index per instance (`choice[i]` is instance `lo + i`)
    pub choice: Vec<usize>,
    /// checkpoint-and-recompute flag per instance
    pub remat: Vec<bool>,
    /// whole-batch span time including recompute, µs
    pub time_us: f64,
    /// whole-batch memory footprint (its `recompute_us` is the recompute
    /// share already included in `time_us`)
    pub footprint: SpanFootprint,
}

impl SpanMemPlan {
    pub fn peak_bytes(&self, m_eff: usize, inflight: usize) -> u64 {
        self.footprint.peak_bytes(m_eff, inflight)
    }
}

/// Deterministic min-time selection from a span frontier under a
/// per-device peak-memory cap (first of time-equal candidates wins).
pub fn select_feasible(
    frontier: &[SpanMemPlan],
    m_eff: usize,
    inflight: usize,
    cap: u64,
) -> Option<&SpanMemPlan> {
    frontier
        .iter()
        .filter(|p| p.peak_bytes(m_eff, inflight) <= cap)
        .min_by(|a, b| a.time_us.partial_cmp(&b.time_us).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::SegmentConfig;
    use crate::spmd::ShardState;

    fn profile() -> SegmentProfile {
        // cfg 0: fast but activation-fat; cfg 1: slower, leaner
        SegmentProfile {
            configs: vec![SegmentConfig { strategy: vec![0] }, SegmentConfig { strategy: vec![1] }],
            t_c_us: vec![10.0, 30.0],
            t_p_us: vec![100.0, 100.0],
            mem_bytes: vec![1000, 700],
            act_bytes: vec![600, 300],
            ckpt_bytes: vec![50, 50],
            t_fwd_us: vec![40.0, 45.0],
            symbolic_volume: vec![0, 0],
            boundary_out: vec![ShardState::Replicated; 2],
            boundary_in: vec![ShardState::Replicated; 2],
        }
    }

    #[test]
    fn static_bytes_subtract_activations() {
        let p = profile();
        assert_eq!(seg_static_bytes(&p, 0), 400);
        assert_eq!(seg_static_bytes(&p, 1), 400);
    }

    #[test]
    fn remat_frontier_off_is_keep_only() {
        let p = profile();
        let pts = remat_points(&p, 0, RecomputeSpec::Off);
        assert_eq!(pts.len(), 1);
        assert!(!pts[0].checkpoint);
        assert_eq!(pts[0].retained_bytes, 600);
        assert_eq!(pts[0].transient_bytes, 0);
        assert_eq!(pts[0].extra_us, 0.0);
    }

    #[test]
    fn remat_frontier_auto_adds_checkpoint_point_only_when_it_saves() {
        let p = profile();
        let pts = remat_points(&p, 0, RecomputeSpec::Auto);
        assert_eq!(pts.len(), 2);
        assert!(pts[1].checkpoint);
        assert_eq!(pts[1].retained_bytes, 50);
        assert_eq!(pts[1].transient_bytes, 600);
        assert!(pts[1].extra_us > 0.0);

        // a boundary stash as large as the activations buys nothing
        let mut fat = profile();
        fat.ckpt_bytes = vec![600, 300];
        assert_eq!(remat_points(&fat, 0, RecomputeSpec::Auto).len(), 1);
    }

    #[test]
    fn single_stage_bypasses_the_microbatch_division() {
        assert_eq!(memory_microbatches(1, 8), 1, "PR 2 whole-batch convention");
        assert_eq!(memory_microbatches(4, 8), 8);
        assert_eq!(memory_microbatches(4, 0), 1, "m clamps to ≥ 1");
        assert_eq!(memory_microbatches(0, 8), 1);
    }

    #[test]
    fn inflight_window_is_min_of_depth_and_microbatches() {
        assert_eq!(inflight_microbatches(4, 0, 8), 4);
        assert_eq!(inflight_microbatches(4, 1, 8), 3);
        assert_eq!(inflight_microbatches(4, 3, 8), 1);
        assert_eq!(inflight_microbatches(4, 0, 2), 2, "m caps the window");
        assert_eq!(inflight_microbatches(1, 0, 8), 1);
    }

    #[test]
    fn closed_form_peak_arithmetic() {
        // static 400, retained 600, transient 0, m = 8: per-mb = 75
        assert_eq!(stage_peak_bytes(400, 600, 0, 8, 4), 400 + 4 * 75);
        // transient joins once, not per in-flight microbatch
        assert_eq!(stage_peak_bytes(400, 600, 80, 8, 4), 400 + 4 * 75 + 10);
        // single-stage whole-batch accounting (m_eff = 1)
        assert_eq!(stage_peak_bytes(400, 600, 0, 1, 1), 1000);
    }

    #[test]
    fn select_feasible_prefers_time_within_the_cap() {
        let fast_fat = SpanMemPlan {
            choice: vec![0],
            remat: vec![false],
            time_us: 100.0,
            footprint: SpanFootprint {
                static_bytes: 400,
                retained_bytes: 600,
                transient_bytes: 0,
                recompute_us: 0.0,
            },
        };
        let slow_lean = SpanMemPlan {
            choice: vec![0],
            remat: vec![true],
            time_us: 140.0,
            footprint: SpanFootprint {
                static_bytes: 400,
                retained_bytes: 50,
                transient_bytes: 600,
                recompute_us: 40.0,
            },
        };
        let frontier = [fast_fat, slow_lean];
        // at pipeline depth (m = 8, 4 in flight): keep-everything peaks at
        // 400 + 4·75 = 700, the checkpointed point at 400 + 4·6 + 75 = 499
        let loose = select_feasible(&frontier, 8, 4, 1_000).unwrap();
        assert_eq!(loose.time_us, 100.0, "loose cap: the fast point wins");
        let tight = select_feasible(&frontier, 8, 4, 500).unwrap();
        assert!(tight.remat[0], "tight cap: only the checkpointed point fits");
        // impossible cap: nothing fits
        assert!(select_feasible(&frontier, 8, 4, 100).is_none());
        // whole-batch accounting (m = 1): checkpointing cannot help — the
        // transient recompute set is as large as what it saved
        assert!(select_feasible(&frontier, 1, 1, 1_000).unwrap().time_us == 100.0);
        assert!(select_feasible(&frontier, 1, 1, 999).is_none());
    }

    #[test]
    fn remat_table_matches_per_call_frontiers() {
        let mut db = ProfileDb::default();
        db.segments.push(profile());
        // a profile whose checkpoint stash buys nothing (single-point frontier)
        let mut fat = profile();
        fat.ckpt_bytes = vec![600, 300];
        db.segments.push(fat);
        let table = RematTable::build(&db);
        let mut flat = 0;
        for p in &db.segments {
            for cfg in 0..p.configs.len() {
                for spec in [RecomputeSpec::Off, RecomputeSpec::Auto] {
                    assert_eq!(
                        table.points(flat, spec),
                        remat_points(p, cfg, spec).as_slice(),
                        "flat {flat} {spec:?}"
                    );
                }
                flat += 1;
            }
        }
    }

    #[test]
    fn footprints_accumulate_and_checkpoint_fallback_maxes_transient() {
        use crate::segment::{SegmentInstance, UniqueSegment};
        let inst = |_| SegmentInstance { unique_id: 0, blocks: vec![], fwd_range: (0, 0) };
        let uniq = UniqueSegment { id: 0, fingerprint: "fp".into(), rep: 0, count: 3 };
        let ss = SegmentSet { instances: (0..3).map(inst).collect(), unique: vec![uniq] };
        let mut db = ProfileDb::default();
        db.segments.push(profile());

        let fp = span_footprint(&ss, &db, &[0, 1, 0], 0, 3);
        assert_eq!(fp.static_bytes, 1200);
        assert_eq!(fp.retained_bytes, 600 + 300 + 600);
        assert_eq!(fp.transient_bytes, 0);

        let (cfp, remat) = span_footprint_checkpointed(&ss, &db, &[0, 1, 0], 0, 3);
        assert_eq!(remat, vec![true, true, true]);
        assert_eq!(cfp.retained_bytes, 150, "boundary stashes only");
        assert_eq!(cfp.transient_bytes, 600, "max over segments, not the sum");
        assert!(cfp.recompute_us > 0.0);
        assert!(cfp.peak_bytes(1, 1) < fp.peak_bytes(1, 1));
    }
}
