//! Segment configuration enumeration: the cartesian product of the
//! segment's ParallelBlock strategies (paper §3.3 / §4.2), with tiny
//! blocks pinned to cut the space (the MoE gate matmul is ~0.01% of a
//! layer's flops; profiling 3× more programs for it is waste — the paper
//! prunes comparably, e.g. pinning batch dims on 2D meshes, §5.2).

use crate::graph::Graph;
use crate::pblock::BlockSet;
use crate::util::Json;

/// One segment configuration: strategy index per block (parallel to the
/// segment's block list).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct SegmentConfig {
    pub strategy: Vec<usize>,
}

impl SegmentConfig {
    /// JSON form for the persistent profile cache: a plain index array.
    pub fn to_json(&self) -> Json {
        Json::Arr(self.strategy.iter().map(|&s| Json::num(s as f64)).collect())
    }

    pub fn from_json(j: &Json) -> Option<SegmentConfig> {
        let arr = j.as_arr()?;
        let strategy = arr
            .iter()
            .map(|v| v.as_u64().map(|u| u as usize))
            .collect::<Option<Vec<usize>>>()?;
        Some(SegmentConfig { strategy })
    }
}

/// Blocks contributing less than this fraction of the segment's entry
/// flops get pinned to a single strategy.
pub const PIN_FLOPS_FRACTION: f64 = 0.02;

/// Enumerate the segment's config space. `blocks` are block ids.
pub fn enumerate_configs(g: &Graph, bs: &BlockSet, blocks: &[usize]) -> Vec<SegmentConfig> {
    let entry_flops: Vec<f64> = blocks
        .iter()
        .map(|&b| g.ops[bs.blocks[b].entry].flops(g) as f64)
        .collect();
    let total: f64 = entry_flops.iter().sum();
    let choices: Vec<usize> = blocks
        .iter()
        .zip(&entry_flops)
        .map(|(&b, &f)| {
            let n = bs.blocks[b].strategies.len().max(1);
            if total > 0.0 && f / total < PIN_FLOPS_FRACTION {
                1 // pinned to its first strategy
            } else {
                n
            }
        })
        .collect();

    let mut out = Vec::new();
    let mut cur = vec![0usize; blocks.len()];
    loop {
        out.push(SegmentConfig { strategy: cur.clone() });
        // odometer increment
        let mut i = 0;
        loop {
            if i == cur.len() {
                return out;
            }
            cur[i] += 1;
            if cur[i] < choices[i] {
                break;
            }
            cur[i] = 0;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_training, ModelCfg};
    use crate::pblock::build_parallel_blocks;
    use crate::segment::extract_segments;

    #[test]
    fn gpt_layer_segment_has_81_configs() {
        // paper §5.5: 4 blocks × 3 strategies = 81 configs per segment
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(2);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let ss = extract_segments(&g, &bs);
        let layer = ss
            .instances
            .iter()
            .find(|i| i.blocks.len() == 4)
            .expect("layer segment");
        let configs = enumerate_configs(&g, &bs, &layer.blocks);
        assert_eq!(configs.len(), 81);
    }

    #[test]
    fn moe_segment_pins_gate_block() {
        let cfg = ModelCfg::preset("moe-tiny").with_layers(4);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 2);
        let ss = extract_segments(&g, &bs);
        let moe_seg = ss
            .instances
            .iter()
            .find(|i| {
                i.blocks
                    .iter()
                    .any(|&b| g.ops[bs.blocks[b].entry].name.contains("expert"))
            })
            .expect("moe segment");
        let configs = enumerate_configs(&g, &bs, &moe_seg.blocks);
        // attn(3) × wo(3) × gate(pinned 1) × fc1(4) × fc2(4) = 144
        assert_eq!(configs.len(), 144, "got {}", configs.len());
    }

    #[test]
    fn config_json_round_trip() {
        let c = SegmentConfig { strategy: vec![0, 3, 1, 2] };
        let j = c.to_json();
        assert_eq!(SegmentConfig::from_json(&j), Some(c));
        assert_eq!(SegmentConfig::from_json(&crate::util::Json::Null), None);
    }

    #[test]
    fn config_odometer_is_exhaustive_and_unique() {
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(1);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let blocks: Vec<usize> = (0..3).collect();
        let configs = enumerate_configs(&g, &bs, &blocks);
        let mut set = std::collections::HashSet::new();
        for c in &configs {
            assert!(set.insert(c.clone()), "dup {c:?}");
        }
    }
}
