//! The profiling driver (paper §4.2–4.3).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::cluster::sim::ComputeModel;
use crate::cluster::{collective_time_us, simulate, Platform};
use crate::graph::{Graph, OpId, Role};
use crate::pblock::BlockSet;
use crate::segment::SegmentSet;
use crate::spmd::{passes, CollKind, Mesh, ShardState};
use crate::util::ThreadPool;

use super::config::{enumerate_configs, SegmentConfig};
use super::db::{ProfileDb, ProfilerStats, ReshardTable, SegmentProfile};

#[derive(Clone)]
pub struct ProfileOptions {
    pub platform: Platform,
    pub mesh: Mesh,
    /// gradient bucket size after fusion (XLA aggregation)
    pub bucket_bytes: u64,
    /// Adam ≈ 2.0 (m+v); SGD 0.0
    pub opt_factor: f64,
    pub compute: ComputeModel,
    /// worker threads for parallel profiling (§4.3 parallel compilation)
    pub threads: usize,
}

impl ProfileOptions {
    pub fn new(platform: Platform, mesh: Mesh) -> ProfileOptions {
        ProfileOptions {
            platform,
            mesh,
            bucket_bytes: 64 << 20,
            opt_factor: 2.0,
            compute: ComputeModel::for_platform(&platform),
            threads: 1,
        }
    }

    pub fn with_compute(mut self, cm: ComputeModel) -> Self {
        self.compute = cm;
        self
    }

    pub fn with_threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    fn pcie_alltoall(&self) -> bool {
        self.platform.name.contains("pcie") || self.platform.name.contains("2node")
    }
}

/// Lower one segment configuration into a finished ("compiled") program.
pub fn compile_segment(
    g: &Graph,
    bs: &BlockSet,
    blocks: &[usize],
    cfg: &SegmentConfig,
    filter: &[bool],
    opts: &ProfileOptions,
) -> (crate::spmd::SpmdProgram, Vec<Option<ShardState>>) {
    // plan choice: chosen strategies for segment blocks; 0 elsewhere (their
    // seeds are not consulted because seed construction is restricted).
    let mut choice = vec![usize::MAX; bs.blocks.len()];
    for (i, &b) in blocks.iter().enumerate() {
        choice[b] = cfg.strategy[i];
    }
    let plan = SegmentPlan { choice, mesh: opts.mesh };
    let mut seeds = plan.seeds(bs);
    // incoming boundary tensor: infer the sharding the segment's first
    // block wants (inverse propagation through the orphan lead-in chain) so
    // the isolated lowering sees a steady-state input — boundary
    // mismatches are T_R's job, not the segment profile's.
    let first_op = filter.iter().position(|&f| f).unwrap_or(0);
    if let Some(t0) = boundary_tensor(g, first_op) {
        if !seeds.contains_key(&t0) {
            let inferred = infer_incoming_state(g, filter, &seeds, t0, opts.mesh.intra);
            seeds.insert(t0, inferred);
        }
    }
    let (mut prog, states) = lower_with_states(g, bs, &seeds, opts.mesh, Some(filter));
    passes::bucket_gradients(&mut prog, opts.bucket_bytes);
    if opts.mesh.nodes > 1 {
        passes::bucket_gradients_inter(&mut prog, opts.bucket_bytes);
    }
    if opts.pcie_alltoall() {
        passes::dispatch_alltoall_sendrecv(&mut prog, opts.mesh.intra);
    }
    (prog, states)
}

/// Internal plan carrying a partial choice (only segment blocks set).
struct SegmentPlan {
    choice: Vec<usize>,
    mesh: Mesh,
}

impl SegmentPlan {
    fn seeds(&self, bs: &BlockSet) -> HashMap<OpId, ShardState> {
        let mut seeds = HashMap::new();
        for (b, blk) in bs.blocks.iter().enumerate() {
            let c = self.choice[b];
            if c == usize::MAX {
                continue;
            }
            for (&op, &sh) in &blk.strategies[c].assignment {
                seeds.entry(op).or_insert_with(|| sh.into());
            }
        }
        seeds
    }
}

/// lower_filtered wrapper also returning final tensor states.
fn lower_with_states(
    g: &Graph,
    bs: &BlockSet,
    seeds: &HashMap<OpId, ShardState>,
    mesh: Mesh,
    filter: Option<&[bool]>,
) -> (crate::spmd::SpmdProgram, Vec<Option<ShardState>>) {
    let _ = bs;
    crate::spmd::lower::lower_with_seeds(g, seeds, mesh, filter)
}

/// Profile every unique segment and boundary pair of a model.
pub fn profile_model(g: &Graph, bs: &BlockSet, ss: &SegmentSet, opts: &ProfileOptions) -> ProfileDb {
    let wall = Instant::now();
    let op_to_inst = ss.op_to_instance(g);
    let mut db = ProfileDb::default();
    let mut stats = ProfilerStats::default();

    let g = Arc::new(g.clone());
    let bs = Arc::new(bs.clone());
    let pool = (opts.threads > 1).then(|| ThreadPool::new(opts.threads));

    // total weight bytes: the steady-state gradient bucket spans the whole
    // backward pass, so each segment's grad sync runs at the efficiency of
    // its proportional share of the global bucket.
    let total_weight_bytes: u64 = g.params().iter().map(|&p| g.ops[p].bytes() as u64).sum();
    for u in &ss.unique {
        let inst = &ss.instances[u.rep];
        let filter: Vec<bool> = (0..g.ops.len())
            .map(|o| op_to_inst[o] == u.rep)
            .collect();
        let configs = enumerate_configs(&g, &bs, &inst.blocks);
        let n_ops = filter.iter().filter(|&&f| f).count();

        let boundary_in_op = boundary_tensor(&g, inst.fwd_range.0);
        let boundary_out_op = boundary_tensor(&g, inst.fwd_range.1);

        let results: Vec<(f64, f64, u64, u64, ShardState, ShardState)> = {
            #[derive(Clone)]
            struct RunCtx {
                g: Arc<Graph>,
                bs: Arc<BlockSet>,
                filter: Vec<bool>,
                blocks: Vec<usize>,
                opts: ProfileOptions,
            }
            let _ = (); // (closure clonability handled below)
            let run_one = {
                let g = Arc::clone(&g);
                let bs = Arc::clone(&bs);
                let filter = filter.clone();
                let blocks = inst.blocks.clone();
                let opts = opts.clone();
                move |cfg: SegmentConfig| {
                    let (prog, states) =
                        compile_segment(&g, &bs, &blocks, &cfg, &filter, &opts);
                    let rep = simulate(&prog, &opts.platform, opts.mesh.intra, &opts.compute);
                    // steady-state correction: gradient buckets fuse ACROSS
                    // segments in the whole model, so this segment's grad
                    // sync runs at the efficiency of the globally
                    // aggregated message: t(R·b)/R with R = global/segment.
                    let fusion_delta =
                        grad_fusion_correction_us(&prog, total_weight_bytes, &opts);
                    let sym = passes::symbolic_volume(&prog, &g);
                    let b_out = boundary_out_op
                        .and_then(|t| states[t])
                        .unwrap_or(ShardState::Replicated);
                    let b_in = boundary_in_op
                        .and_then(|t| states[t])
                        .unwrap_or(ShardState::Replicated);
                    (
                        rep.comm_us + rep.comm_inter_us + fusion_delta,
                        rep.compute_us,
                        prog.peak_memory(opts.opt_factor),
                        sym,
                        b_in,
                        b_out,
                    )
                }
            };
            match &pool {
                // chunked dispatch: per-config jobs are ~0.5–1 ms, far too
                // small for per-job channel overhead (§Perf iteration 2:
                // threads=4 was SLOWER than serial before chunking)
                Some(p) => {
                    let chunk = (configs.len() / (opts.threads * 4)).max(1);
                    let chunks: Vec<Vec<SegmentConfig>> =
                        configs.chunks(chunk).map(|c| c.to_vec()).collect();
                    let run_chunk = {
                        let run_one = run_one.clone();
                        move |chunk: Vec<SegmentConfig>| -> Vec<_> {
                            chunk.into_iter().map(&run_one).collect()
                        }
                    };
                    p.map(chunks, run_chunk).into_iter().flatten().collect()
                }
                None => configs.clone().into_iter().map(run_one).collect(),
            }
        };

        let mut prof = SegmentProfile::default();
        prof.configs = configs;
        let mut best_step = f64::INFINITY;
        for (t_c, t_p, mem, sym, b_in, b_out) in results {
            let step_s = (t_c + t_p) * 1e-6;
            // estimated real-testbed costs (Fig. 12 model): XLA backend
            // compile + 5 warmup + 10 timed runs, dynamic limit at 3× best
            stats.programs_compiled += 1;
            stats.programs_profiled += 1;
            stats.est_compile_s += 0.25 + 2.5e-4 * n_ops as f64;
            stats.est_profile_s += 0.1 + 15.0 * step_s;
            let limited = 0.1 + 5.0 * step_s + (10.0 * step_s).min(30.0 * best_step);
            stats.est_optimized_s += limited;
            best_step = best_step.min(step_s);

            prof.t_c_us.push(t_c);
            prof.t_p_us.push(t_p);
            prof.mem_bytes.push(mem);
            prof.symbolic_volume.push(sym);
            prof.boundary_in.push(b_in);
            prof.boundary_out.push(b_out);
        }
        db.segments.push(prof);
    }

    // boundary reshard tables for adjacent unique pairs (§4.2: pinpointed
    // to the crossing tensor; cost = the collective moving out→in state)
    for w in ss.instances.windows(2) {
        let (a, b) = (w[0].unique_id, w[1].unique_id);
        if db.reshard.contains_key(&(a, b)) {
            continue;
        }
        let boundary = boundary_tensor(&g, w[1].fwd_range.0);
        let bytes = boundary.map(|t| g.ops[t].bytes() as u64).unwrap_or(0);
        let pa = &db.segments[a];
        let pb = &db.segments[b];
        // §4.2: resharding depends only on the boundary ParallelBlock pair's
        // strategies — i.e. on the distinct (out_state, in_state) pairs, not
        // on full config pairs. Price each distinct pair once (these are the
        // "3×3 = 9 groups of communication primitives" of §5.5).
        let mut priced: HashMap<(ShardState, ShardState), f64> = HashMap::new();
        let mut table = vec![vec![0.0; pb.configs.len()]; pa.configs.len()];
        let mut sym = vec![vec![0u64; pb.configs.len()]; pa.configs.len()];
        for (i, row) in table.iter_mut().enumerate() {
            for (j, cell) in row.iter_mut().enumerate() {
                let key = (pa.boundary_out[i], pb.boundary_in[j]);
                let cost = *priced.entry(key).or_insert_with(|| {
                    let c = reshard_cost_us(key.0, key.1, bytes, opts);
                    stats.programs_compiled += 1;
                    stats.est_compile_s += 0.05;
                    stats.est_profile_s += 0.02 + 15.0 * c * 1e-6;
                    stats.est_optimized_s += 0.02 + 5.0 * c * 1e-6;
                    c
                });
                *cell = cost;
                sym[i][j] = symbolic_reshard_bytes(key.0, key.1, bytes);
            }
        }
        db.reshard.insert(
            (a, b),
            ReshardTable { t_r_us: table, sym_vol: sym, programs: priced.len() },
        );
    }

    // §4.3: parallel compilation overlapped with profiling
    let threads = opts.threads.max(1) as f64;
    stats.est_optimized_s = (stats.est_compile_s / threads).max(stats.est_optimized_s);
    stats.wall_s = wall.elapsed().as_secs_f64();
    db.stats = stats;
    db
}

/// Infer the sharding a segment expects on its incoming boundary tensor:
/// BFS forward through in-segment ops until a seeded tensor is reached,
/// then invert the per-op dim mappings back down the path.
pub fn infer_incoming_state(
    g: &Graph,
    filter: &[bool],
    seeds: &HashMap<OpId, ShardState>,
    t0: OpId,
    parts: usize,
) -> ShardState {
    use crate::affine::{propagate, Prop};
    let users = g.users();
    // BFS for a path t0 → ... → seeded tensor
    let mut prev: HashMap<OpId, (OpId, usize)> = HashMap::new(); // op -> (producer tensor, input idx)
    let mut queue = std::collections::VecDeque::new();
    queue.push_back(t0);
    let mut seeded_end: Option<OpId> = None;
    let mut visited = std::collections::HashSet::new();
    visited.insert(t0);
    'bfs: while let Some(t) = queue.pop_front() {
        for &u in &users[t] {
            if !filter.get(u).copied().unwrap_or(false) || visited.contains(&u) {
                continue;
            }
            let idx = g.ops[u].inputs.iter().position(|&i| i == t).unwrap();
            prev.insert(u, (t, idx));
            if seeds.contains_key(&u) {
                seeded_end = Some(u);
                break 'bfs;
            }
            visited.insert(u);
            queue.push_back(u);
        }
    }
    let Some(end) = seeded_end else {
        return ShardState::Replicated;
    };
    // reconstruct the path end → t0 and invert
    let mut path = Vec::new();
    let mut cur = end;
    while let Some(&(t, idx)) = prev.get(&cur) {
        path.push((cur, idx));
        if t == t0 {
            break;
        }
        cur = t;
    }
    let mut state = seeds[&end];
    for &(op, idx) in path.iter() {
        state = match state {
            ShardState::Split(dy) => {
                let rank = g.shape(g.ops[op].inputs[idx]).len();
                let mut found = ShardState::Replicated;
                for dx in 0..rank {
                    if let Prop::To { out_dim, .. } = propagate(g, op, idx, dx, parts) {
                        if out_dim == dy {
                            found = ShardState::Split(dx);
                            break;
                        }
                    }
                }
                found
            }
            other => other,
        };
    }
    state
}

/// Steady-state gradient-bucket fusion: the whole model's grad sync fuses
/// into large buckets, so a segment's share should be priced at the fused
/// message's efficiency: t(R·b)/R where R = total grad volume / this
/// segment's grad volume. Returns the (usually negative) delta to add to
/// the segment's simulated comm time.
fn grad_fusion_correction_us(
    prog: &crate::spmd::SpmdProgram,
    total_weight_bytes: u64,
    opts: &ProfileOptions,
) -> f64 {
    let seg_bytes: u64 = prog
        .instrs
        .iter()
        .filter_map(|i| match i {
            crate::spmd::Instr::Coll { bytes, grad_sync: true, .. }
            | crate::spmd::Instr::CollInter { bytes, grad_sync: true, .. } => Some(*bytes),
            _ => None,
        })
        .sum();
    if seg_bytes == 0 {
        return 0.0;
    }
    let r = (total_weight_bytes as f64 / seg_bytes as f64).clamp(1.0, 64.0);
    if r <= 1.01 {
        return 0.0;
    }
    let mut delta = 0.0;
    for instr in &prog.instrs {
        match instr {
            crate::spmd::Instr::Coll { kind, bytes, grad_sync: true, .. } => {
                let t1 = collective_time_us(*kind, *bytes, opts.mesh.intra, &opts.platform.intra);
                let tr = collective_time_us(
                    *kind,
                    (*bytes as f64 * r) as u64,
                    opts.mesh.intra,
                    &opts.platform.intra,
                ) / r;
                delta += tr - t1;
            }
            crate::spmd::Instr::CollInter { kind, bytes, grad_sync: true, .. } => {
                let t1 =
                    collective_time_us(*kind, *bytes, opts.platform.nodes, &opts.platform.inter);
                let tr = collective_time_us(
                    *kind,
                    (*bytes as f64 * r) as u64,
                    opts.platform.nodes,
                    &opts.platform.inter,
                ) / r;
                delta += tr - t1;
            }
            _ => {}
        }
    }
    delta
}

/// The single activation tensor crossing op-id `boundary` (max-bytes one if
/// several; None at graph edges).
pub fn boundary_tensor(g: &Graph, boundary: usize) -> Option<OpId> {
    if boundary == 0 {
        return None;
    }
    let users = g.users();
    let mut best: Option<(usize, OpId)> = None;
    for op in &g.ops[..boundary.min(g.ops.len())] {
        if op.role != Role::Fwd || op.inputs.is_empty() {
            continue;
        }
        let crosses = users[op.id]
            .iter()
            .any(|&u| u >= boundary && g.ops[u].role == Role::Fwd);
        if crosses {
            let b = op.bytes();
            if best.map_or(true, |(bb, _)| b > bb) {
                best = Some((b, op.id));
            }
        }
    }
    best.map(|(_, id)| id)
}

/// Symbolic volume a volume-based cost model charges for a boundary —
/// notably Partial→Split is charged as a full AllReduce rather than the
/// ReduceScatter the compiler actually emits (§5.7).
pub fn symbolic_reshard_bytes(out: ShardState, inn: ShardState, bytes: u64) -> u64 {
    match (out, inn) {
        (a, b) if a == b => 0,
        (ShardState::Replicated, _) => 0,
        (ShardState::Split(_), ShardState::Replicated) => bytes,
        (ShardState::Split(_), ShardState::Split(_)) => bytes,
        (ShardState::Partial, _) => 2 * bytes,
        (_, ShardState::Partial) => 0,
    }
}

/// Price the boundary reshard between two segment configs.
fn reshard_cost_us(out: ShardState, inn: ShardState, bytes: u64, opts: &ProfileOptions) -> f64 {
    let n = opts.mesh.intra;
    let link = &opts.platform.intra;
    match (out, inn) {
        (a, b) if a == b => 0.0,
        (ShardState::Replicated, ShardState::Replicated) => 0.0,
        (ShardState::Split(_), ShardState::Replicated) => {
            collective_time_us(CollKind::AllGather, bytes, n, link)
        }
        (ShardState::Split(_), ShardState::Split(_)) => {
            if opts.pcie_alltoall() {
                (0..n.saturating_sub(1))
                    .map(|_| {
                        collective_time_us(CollKind::SendRecv, bytes / n as u64, n, link)
                    })
                    .sum()
            } else {
                collective_time_us(CollKind::AllToAll, bytes, n, link)
            }
        }
        (ShardState::Replicated, ShardState::Split(_)) => 0.0, // local slice
        (ShardState::Partial, ShardState::Replicated) => {
            collective_time_us(CollKind::AllReduce, bytes, n, link)
        }
        (ShardState::Partial, ShardState::Split(_)) => {
            // the compiler's AllReduce→ReduceScatter rewrite (§5.7)
            collective_time_us(CollKind::ReduceScatter, bytes, n, link)
        }
        (_, ShardState::Partial) => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_training, ModelCfg};
    use crate::pblock::build_parallel_blocks;
    use crate::segment::extract_segments;

    fn profiled(preset: &str, layers: usize) -> (Graph, BlockSet, SegmentSet, ProfileDb) {
        let cfg = ModelCfg::preset(preset).with_layers(layers);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let ss = extract_segments(&g, &bs);
        let opts = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
        let db = profile_model(&g, &bs, &ss, &opts);
        (g, bs, ss, db)
    }

    #[test]
    fn gpt_profile_space_matches_paper_scale() {
        // paper §5.5: 2·81 + 2·9 = 180 programs for GPT. We have ONE
        // unique hidden-layer segment (no lowering noise) + head: 81 + head
        // configs + reshard pairs — same order of magnitude.
        let (_, _, ss, db) = profiled("gpt-tiny", 4);
        let space = db.profile_space();
        assert!(space >= 81, "space {space}");
        assert!(space <= 400, "space {space}");
        assert_eq!(ss.num_unique(), db.segments.len());
    }

    #[test]
    fn profiles_are_positive_and_distinct() {
        let (_, _, _, db) = profiled("gpt-tiny", 2);
        let layer = db.segments.iter().find(|s| s.configs.len() == 81).unwrap();
        assert!(layer.t_p_us.iter().all(|&t| t > 0.0));
        // strategies genuinely differ in communication time
        let min = layer.t_c_us.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = layer.t_c_us.iter().cloned().fold(0.0, f64::max);
        assert!(max > 2.0 * min.max(1.0), "min {min} max {max}");
    }

    #[test]
    fn memory_varies_across_configs() {
        let (_, _, _, db) = profiled("gpt-tiny", 2);
        let layer = db.segments.iter().find(|s| s.configs.len() == 81).unwrap();
        let min = layer.mem_bytes.iter().min().unwrap();
        let max = layer.mem_bytes.iter().max().unwrap();
        assert!(max > min, "memory must differ across configs");
    }

    #[test]
    fn stats_model_overheads() {
        let (_, _, _, db) = profiled("gpt-tiny", 2);
        assert!(db.stats.programs_compiled > 81);
        assert!(db.stats.est_compile_s > 0.0);
        assert!(db.stats.est_optimized_s <= db.stats.est_compile_s + db.stats.est_profile_s);
    }

    #[test]
    fn reshard_tables_exist_for_adjacent_uniques() {
        let (_, _, ss, db) = profiled("gpt-tiny", 4);
        // layer→layer (same unique) and layer→head pairs
        let mut expected = std::collections::HashSet::new();
        for w in ss.instances.windows(2) {
            expected.insert((w[0].unique_id, w[1].unique_id));
        }
        for pair in &expected {
            assert!(db.reshard.contains_key(pair), "{pair:?} missing");
        }
    }
}
