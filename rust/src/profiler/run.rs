//! The profiling driver (paper §4.2–4.3).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::cluster::sim::ComputeModel;
use crate::cluster::{collective_time_us, simulate, Platform};
use crate::graph::{Graph, OpId, Role};
use crate::pblock::BlockSet;
use crate::segment::SegmentSet;
use crate::spmd::{passes, CollKind, Mesh, ShardState};
use crate::util::ThreadPool;

use super::cache::{CacheHandle, CacheKey, ProfileCache};
use super::config::{enumerate_configs, SegmentConfig};
use super::db::{ProfileDb, ProfilerStats, ReshardTable, SegmentProfile};

#[derive(Clone)]
pub struct ProfileOptions {
    pub platform: Platform,
    pub mesh: Mesh,
    /// gradient bucket size after fusion (XLA aggregation)
    pub bucket_bytes: u64,
    /// Adam ≈ 2.0 (m+v); SGD 0.0
    pub opt_factor: f64,
    pub compute: ComputeModel,
    /// worker threads for parallel profiling (§4.3 parallel compilation)
    pub threads: usize,
    /// observability sink (disabled by default; deliberately excluded
    /// from [`ProfileOptions::cache_signature`] — tracing never shapes
    /// profiled numbers, so it must never invalidate cached profiles)
    pub trace: crate::obs::Trace,
}

impl ProfileOptions {
    pub fn new(platform: Platform, mesh: Mesh) -> ProfileOptions {
        ProfileOptions {
            platform,
            mesh,
            bucket_bytes: 64 << 20,
            opt_factor: 2.0,
            compute: ComputeModel::for_platform(&platform),
            threads: 1,
            trace: crate::obs::Trace::disabled(),
        }
    }

    pub fn with_compute(mut self, cm: ComputeModel) -> Self {
        self.compute = cm;
        self
    }

    pub fn with_threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    pub fn with_trace(mut self, trace: crate::obs::Trace) -> Self {
        self.trace = trace;
        self
    }

    /// The non-fingerprint part of a profile-cache key: every knob that
    /// shapes profiled numbers (platform links + compute capability, mesh,
    /// gradient bucket size, optimizer state factor, compute model). Any
    /// change here invalidates cached entries by construction.
    pub fn cache_signature(&self) -> String {
        format!(
            "{};mesh{}x{};bb{};of{};{}",
            self.platform.signature(),
            self.mesh.intra,
            self.mesh.nodes,
            self.bucket_bytes,
            self.opt_factor,
            self.compute.signature()
        )
    }

    fn pcie_alltoall(&self) -> bool {
        self.platform.name.contains("pcie") || self.platform.name.contains("2node")
    }
}

/// Lower one segment configuration into a finished ("compiled") program.
pub fn compile_segment(
    g: &Graph,
    bs: &BlockSet,
    blocks: &[usize],
    cfg: &SegmentConfig,
    filter: &[bool],
    opts: &ProfileOptions,
) -> (crate::spmd::SpmdProgram, Vec<Option<ShardState>>) {
    // plan choice: chosen strategies for segment blocks; 0 elsewhere (their
    // seeds are not consulted because seed construction is restricted).
    let mut choice = vec![usize::MAX; bs.blocks.len()];
    for (i, &b) in blocks.iter().enumerate() {
        choice[b] = cfg.strategy[i];
    }
    let plan = SegmentPlan { choice, mesh: opts.mesh };
    let mut seeds = plan.seeds(bs);
    // incoming boundary tensor: infer the sharding the segment's first
    // block wants (inverse propagation through the orphan lead-in chain) so
    // the isolated lowering sees a steady-state input — boundary
    // mismatches are T_R's job, not the segment profile's.
    let first_op = filter.iter().position(|&f| f).unwrap_or(0);
    if let Some(t0) = boundary_tensor(g, first_op) {
        if !seeds.contains_key(&t0) {
            let inferred = infer_incoming_state(g, filter, &seeds, t0, opts.mesh.intra);
            seeds.insert(t0, inferred);
        }
    }
    let (mut prog, states) = lower_with_states(g, bs, &seeds, opts.mesh, Some(filter));
    passes::bucket_gradients(&mut prog, opts.bucket_bytes);
    if opts.mesh.nodes > 1 {
        passes::bucket_gradients_inter(&mut prog, opts.bucket_bytes);
    }
    if opts.pcie_alltoall() {
        passes::dispatch_alltoall_sendrecv(&mut prog, opts.mesh.intra);
    }
    (prog, states)
}

/// Internal plan carrying a partial choice (only segment blocks set).
struct SegmentPlan {
    choice: Vec<usize>,
    mesh: Mesh,
}

impl SegmentPlan {
    fn seeds(&self, bs: &BlockSet) -> HashMap<OpId, ShardState> {
        let mut seeds = HashMap::new();
        for (b, blk) in bs.blocks.iter().enumerate() {
            let c = self.choice[b];
            if c == usize::MAX {
                continue;
            }
            for (&op, &sh) in &blk.strategies[c].assignment {
                seeds.entry(op).or_insert_with(|| sh.into());
            }
        }
        seeds
    }
}

/// lower_filtered wrapper also returning final tensor states.
fn lower_with_states(
    g: &Graph,
    bs: &BlockSet,
    seeds: &HashMap<OpId, ShardState>,
    mesh: Mesh,
    filter: Option<&[bool]>,
) -> (crate::spmd::SpmdProgram, Vec<Option<ShardState>>) {
    let _ = bs;
    crate::spmd::lower::lower_with_seeds(g, seeds, mesh, filter)
}

/// Profile every unique segment and boundary pair of a model.
pub fn profile_model(
    g: &Graph,
    bs: &BlockSet,
    ss: &SegmentSet,
    opts: &ProfileOptions,
) -> ProfileDb {
    profile_model_cached(g, bs, ss, opts, None)
}

/// Per-unique-segment lowering context shared with pool workers.
struct WorkerCtx {
    filter: Vec<bool>,
    blocks: Vec<usize>,
    boundary_in_op: Option<OpId>,
    boundary_out_op: Option<OpId>,
}

/// One profiled configuration's measurements (worker → assembly order is
/// preserved by the pool, so these reassemble positionally).
struct ConfigMeasurement {
    t_c_us: f64,
    t_p_us: f64,
    mem_bytes: u64,
    act_bytes: u64,
    ckpt_bytes: u64,
    t_fwd_us: f64,
    symbolic_volume: u64,
    boundary_in: ShardState,
    boundary_out: ShardState,
}

/// Cache-aware [`profile_model`]: unique segments (and boundary reshard
/// tables) already present in `cache` under the current
/// `(fingerprint, platform signature, parts)` key are reused verbatim —
/// a fully warm cache skips the MetricsProfiling phase entirely
/// (`stats.profile_wall_s == 0.0`). Misses are profiled — all
/// `(unique segment, config)` pairs flattened into one job list over the
/// `opts.threads` pool workers, with order-preserving collection so the
/// resulting [`ProfileDb`] is identical to a serial run — and written
/// back to the cache.
pub fn profile_model_cached(
    g: &Graph,
    bs: &BlockSet,
    ss: &SegmentSet,
    opts: &ProfileOptions,
    cache: Option<&mut ProfileCache>,
) -> ProfileDb {
    profile_model_handle(g, bs, ss, opts, CacheHandle::from_option(cache))
}

/// [`profile_model_cached`] over any cache ownership shape — exclusive,
/// absent, or process-wide shared ([`CacheHandle`]). The shared shape is
/// what makes the profiler re-entrant: every lookup/insert is one short
/// lock-hold, profiling runs outside the lock, and concurrent runs for
/// overlapping segments reuse each other's freshly profiled entries.
pub fn profile_model_handle(
    g: &Graph,
    bs: &BlockSet,
    ss: &SegmentSet,
    opts: &ProfileOptions,
    mut cache: CacheHandle<'_>,
) -> ProfileDb {
    let wall = Instant::now();
    let mut phase_span = opts.trace.span("profiler.profile_model");
    let op_to_inst = ss.op_to_instance(g);
    let mut stats = ProfilerStats::default();

    // total weight bytes: the steady-state gradient bucket spans the whole
    // backward pass, so each segment's grad sync runs at the efficiency of
    // its proportional share of the global bucket. Profiles therefore
    // depend on the model's total gradient volume, so it joins the
    // cache-key signature alongside the platform.
    let total_weight_bytes: u64 = g.params().iter().map(|&p| g.ops[p].bytes() as u64).sum();
    let sig = format!("{};tw{}", opts.cache_signature(), total_weight_bytes);
    let parts = opts.mesh.intra;

    // ---- partition unique segments into cache hits and profiling jobs
    let mut ctxs: Vec<WorkerCtx> = Vec::with_capacity(ss.unique.len());
    let mut all_configs: Vec<Vec<SegmentConfig>> = Vec::with_capacity(ss.unique.len());
    let mut n_ops_per_u: Vec<usize> = Vec::with_capacity(ss.unique.len());
    let mut cached: Vec<Option<SegmentProfile>> = Vec::with_capacity(ss.unique.len());
    for u in &ss.unique {
        let inst = &ss.instances[u.rep];
        let filter: Vec<bool> = (0..g.ops.len())
            .map(|o| op_to_inst[o] == u.rep)
            .collect();
        let configs = enumerate_configs(g, bs, &inst.blocks);
        let key =
            CacheKey { fingerprint: u.fingerprint.clone(), platform: sig.clone(), parts };
        let hit = cache
            .get_segment(&key)
            // defensive: an entry whose config space disagrees with this
            // build (foreign or hand-edited file) is a miss, never a
            // wrong answer
            .filter(|p| p.configs == configs)
            // miss-storm fault: force the cold path even on warm caches —
            // costs re-profiling, which must still yield identical plans
            .filter(|_| !crate::util::failpoint::should_trip("profile_cache.miss_storm"));
        if hit.is_some() {
            stats.cache_hits += 1;
        } else {
            stats.cache_misses += 1;
        }
        cached.push(hit);
        n_ops_per_u.push(filter.iter().filter(|&&f| f).count());
        ctxs.push(WorkerCtx {
            filter,
            blocks: inst.blocks.clone(),
            boundary_in_op: boundary_tensor(g, inst.fwd_range.0),
            boundary_out_op: boundary_tensor(g, inst.fwd_range.1),
        });
        all_configs.push(configs);
    }

    // ---- profile all missing (unique, config) pairs as one flat job list
    let jobs: Vec<(usize, SegmentConfig)> = (0..ss.unique.len())
        .filter(|&u| cached[u].is_none())
        .flat_map(|u| all_configs[u].iter().cloned().map(move |c| (u, c)))
        .collect();

    let results: Vec<ConfigMeasurement> = if jobs.is_empty() {
        Vec::new()
    } else {
        let t_profile = Instant::now();
        let run_one = {
            let g = Arc::new(g.clone());
            let bs = Arc::new(bs.clone());
            let wctx: Arc<Vec<WorkerCtx>> = Arc::new(ctxs);
            let opts = opts.clone();
            move |(u, cfg): (usize, SegmentConfig)| {
                let ctx = &wctx[u];
                let (prog, states) =
                    compile_segment(&g, &bs, &ctx.blocks, &cfg, &ctx.filter, &opts);
                let rep = simulate(&prog, &opts.platform, opts.mesh.intra, &opts.compute);
                // steady-state correction: gradient buckets fuse ACROSS
                // segments in the whole model, so this segment's grad
                // sync runs at the efficiency of the globally
                // aggregated message: t(R·b)/R with R = global/segment.
                let fusion_delta =
                    grad_fusion_correction_us(&prog, total_weight_bytes, &opts);
                let sym = passes::symbolic_volume(&prog, &g);
                let b_out = ctx
                    .boundary_out_op
                    .and_then(|t| states[t])
                    .unwrap_or(ShardState::Replicated);
                let b_in = ctx
                    .boundary_in_op
                    .and_then(|t| states[t])
                    .unwrap_or(ShardState::Replicated);
                // checkpoint stash: the incoming boundary activation at
                // this config's required input sharding — what remains
                // resident when the segment recomputes on backward
                let ckpt_bytes = ctx
                    .boundary_in_op
                    .map(|t| {
                        let bytes = g.ops[t].bytes() as u64;
                        match b_in {
                            ShardState::Split(_) => bytes / opts.mesh.intra.max(1) as u64,
                            _ => bytes,
                        }
                    })
                    .unwrap_or(0);
                ConfigMeasurement {
                    t_c_us: rep.comm_us + rep.comm_inter_us + fusion_delta,
                    t_p_us: rep.compute_us,
                    mem_bytes: prog.peak_memory(opts.opt_factor),
                    act_bytes: prog.act_bytes,
                    ckpt_bytes,
                    t_fwd_us: forward_time_us(&prog, &g, &opts),
                    symbolic_volume: sym,
                    boundary_in: b_in,
                    boundary_out: b_out,
                }
            }
        };
        // chunked dispatch: per-config jobs are ~0.5–1 ms, far too small
        // for per-job channel overhead (§Perf iteration 2: threads=4 was
        // SLOWER than serial before chunking)
        let out = if opts.threads > 1 && jobs.len() > 1 {
            ThreadPool::new(opts.threads).map_chunked(jobs, run_one)
        } else {
            jobs.into_iter().map(run_one).collect()
        };
        stats.profile_wall_s = t_profile.elapsed().as_secs_f64();
        out
    };

    // ---- reassemble per-unique profiles in order (results are ordered)
    let mut db = ProfileDb::default();
    let mut results = results.into_iter();
    for (u, hit) in cached.into_iter().enumerate() {
        if let Some(p) = hit {
            // the Fig.-12 real-testbed estimate is model-intrinsic, not a
            // function of local cache state — reproduce the exact cold-run
            // charges from the cached step times (only wall-clock
            // profiling is skipped on a hit)
            let n_ops = n_ops_per_u[u];
            let mut best_step = f64::INFINITY;
            for cfg in 0..p.configs.len() {
                let step_s = (p.t_c_us[cfg] + p.t_p_us[cfg]) * 1e-6;
                charge_config(&mut stats, n_ops, step_s, &mut best_step);
            }
            db.segments.push(p);
            continue;
        }
        let n_ops = n_ops_per_u[u];
        let mut prof =
            SegmentProfile { configs: all_configs[u].clone(), ..SegmentProfile::default() };
        let mut best_step = f64::INFINITY;
        for _ in 0..prof.configs.len() {
            let m = results.next().expect("one result per profiled config");
            charge_config(&mut stats, n_ops, (m.t_c_us + m.t_p_us) * 1e-6, &mut best_step);

            prof.t_c_us.push(m.t_c_us);
            prof.t_p_us.push(m.t_p_us);
            prof.mem_bytes.push(m.mem_bytes);
            prof.act_bytes.push(m.act_bytes);
            prof.ckpt_bytes.push(m.ckpt_bytes);
            prof.t_fwd_us.push(m.t_fwd_us);
            prof.symbolic_volume.push(m.symbolic_volume);
            prof.boundary_in.push(m.boundary_in);
            prof.boundary_out.push(m.boundary_out);
        }
        cache.put_segment(
            CacheKey {
                fingerprint: ss.unique[u].fingerprint.clone(),
                platform: sig.clone(),
                parts,
            },
            &prof,
        );
        db.segments.push(prof);
    }

    // boundary reshard tables for adjacent unique pairs (§4.2: pinpointed
    // to the crossing tensor; cost = the collective moving out→in state)
    for w in ss.instances.windows(2) {
        let (a, b) = (w[0].unique_id, w[1].unique_id);
        if db.reshard.contains_key(&(a, b)) {
            continue;
        }
        let boundary = boundary_tensor(g, w[1].fwd_range.0);
        let bytes = boundary.map(|t| g.ops[t].bytes() as u64).unwrap_or(0);
        let pa = &db.segments[a];
        let pb = &db.segments[b];
        let fp_a = &ss.unique[a].fingerprint;
        let fp_b = &ss.unique[b].fingerprint;
        // the crossing tensor's size is not pinned down by the fingerprint
        // pair alone, so it joins the reshard cache key
        let rsig = format!("{sig};bytes{bytes}");
        if let Some(t) = cache.get_reshard(fp_a, fp_b, &rsig, parts) {
            let rows_ok = t.t_r_us.len() == pa.configs.len()
                && t.sym_vol.len() == pa.configs.len()
                && t.t_r_us.iter().all(|r| r.len() == pb.configs.len())
                && t.sym_vol.iter().all(|r| r.len() == pb.configs.len());
            if rows_ok {
                // reproduce the cold-run charges for the distinct
                // boundary-state pairs (model-intrinsic, like segments)
                let mut seen: std::collections::HashSet<(ShardState, ShardState)> =
                    std::collections::HashSet::new();
                for i in 0..pa.configs.len() {
                    for j in 0..pb.configs.len() {
                        if seen.insert((pa.boundary_out[i], pb.boundary_in[j])) {
                            charge_reshard(&mut stats, t.t_r_us[i][j]);
                        }
                    }
                }
                db.reshard.insert((a, b), t);
                continue;
            }
        }
        // §4.2: resharding depends only on the boundary ParallelBlock pair's
        // strategies — i.e. on the distinct (out_state, in_state) pairs, not
        // on full config pairs. Price each distinct pair once (these are the
        // "3×3 = 9 groups of communication primitives" of §5.5).
        let mut priced: HashMap<(ShardState, ShardState), f64> = HashMap::new();
        let mut table = vec![vec![0.0; pb.configs.len()]; pa.configs.len()];
        let mut sym = vec![vec![0u64; pb.configs.len()]; pa.configs.len()];
        for (i, row) in table.iter_mut().enumerate() {
            for (j, cell) in row.iter_mut().enumerate() {
                let key = (pa.boundary_out[i], pb.boundary_in[j]);
                let cost = *priced.entry(key).or_insert_with(|| {
                    let c = reshard_cost_us(key.0, key.1, bytes, opts);
                    charge_reshard(&mut stats, c);
                    c
                });
                *cell = cost;
                sym[i][j] = symbolic_reshard_bytes(key.0, key.1, bytes);
            }
        }
        let fresh = ReshardTable { t_r_us: table, sym_vol: sym, programs: priced.len() };
        cache.put_reshard(fp_a, fp_b, &rsig, parts, &fresh);
        db.reshard.insert((a, b), fresh);
    }

    // §4.3: parallel compilation overlapped with profiling
    let threads = opts.threads.max(1) as f64;
    stats.est_optimized_s = (stats.est_compile_s / threads).max(stats.est_optimized_s);
    stats.wall_s = wall.elapsed().as_secs_f64();
    if opts.trace.is_enabled() {
        // counters take cache-state-INVARIANT sums only: hits + misses
        // and the Fig.-12 program count are identical on warm and cold
        // runs (the warm-replay invariant); the hit/miss split is
        // wall-clock-side information and rides on the span's args
        let trace = &opts.trace;
        trace.count(
            crate::obs::Counter::ProfilerSegments,
            (stats.cache_hits + stats.cache_misses) as u64,
        );
        trace.count(crate::obs::Counter::ProfilerPrograms, stats.programs_compiled as u64);
        phase_span.arg("cache_hits", stats.cache_hits.to_string());
        phase_span.arg("cache_misses", stats.cache_misses.to_string());
    }
    drop(phase_span);
    db.stats = stats;
    db
}

/// Fig.-12 real-testbed cost model for one profiled configuration: XLA
/// backend compile + 5 warmup + 10 timed runs, dynamic limit at 3× best.
/// Single source of truth for cold profiling AND the warm-hit replay —
/// the warm==cold stats invariant depends on both paths charging here.
fn charge_config(stats: &mut ProfilerStats, n_ops: usize, step_s: f64, best_step: &mut f64) {
    stats.programs_compiled += 1;
    stats.programs_profiled += 1;
    stats.est_compile_s += 0.25 + 2.5e-4 * n_ops as f64;
    stats.est_profile_s += 0.1 + 15.0 * step_s;
    let limited = 0.1 + 5.0 * step_s + (10.0 * step_s).min(30.0 * *best_step);
    stats.est_optimized_s += limited;
    *best_step = best_step.min(step_s);
}

/// Fig.-12 charge for one distinct boundary-reshard program; like
/// [`charge_config`], shared by the cold pricing path and warm-hit replay.
fn charge_reshard(stats: &mut ProfilerStats, cost_us: f64) {
    stats.programs_compiled += 1;
    stats.est_compile_s += 0.05;
    stats.est_profile_s += 0.02 + 15.0 * cost_us * 1e-6;
    stats.est_optimized_s += 0.02 + 5.0 * cost_us * 1e-6;
}

/// Infer the sharding a segment expects on its incoming boundary tensor:
/// BFS forward through in-segment ops until a seeded tensor is reached,
/// then invert the per-op dim mappings back down the path.
pub fn infer_incoming_state(
    g: &Graph,
    filter: &[bool],
    seeds: &HashMap<OpId, ShardState>,
    t0: OpId,
    parts: usize,
) -> ShardState {
    use crate::affine::{propagate, Prop};
    let users = g.users();
    // BFS for a path t0 → ... → seeded tensor
    // op -> (producer tensor, input idx)
    let mut prev: HashMap<OpId, (OpId, usize)> = HashMap::new();
    let mut queue = std::collections::VecDeque::new();
    queue.push_back(t0);
    let mut seeded_end: Option<OpId> = None;
    let mut visited = std::collections::HashSet::new();
    visited.insert(t0);
    'bfs: while let Some(t) = queue.pop_front() {
        for &u in &users[t] {
            if !filter.get(u).copied().unwrap_or(false) || visited.contains(&u) {
                continue;
            }
            let idx = g.ops[u].inputs.iter().position(|&i| i == t).unwrap();
            prev.insert(u, (t, idx));
            if seeds.contains_key(&u) {
                seeded_end = Some(u);
                break 'bfs;
            }
            visited.insert(u);
            queue.push_back(u);
        }
    }
    let Some(end) = seeded_end else {
        return ShardState::Replicated;
    };
    // reconstruct the path end → t0 and invert
    let mut path = Vec::new();
    let mut cur = end;
    while let Some(&(t, idx)) = prev.get(&cur) {
        path.push((cur, idx));
        if t == t0 {
            break;
        }
        cur = t;
    }
    let mut state = seeds[&end];
    for &(op, idx) in path.iter() {
        state = match state {
            ShardState::Split(dy) => {
                let rank = g.shape(g.ops[op].inputs[idx]).len();
                let mut found = ShardState::Replicated;
                for dx in 0..rank {
                    if let Prop::To { out_dim, .. } = propagate(g, op, idx, dx, parts) {
                        if out_dim == dy {
                            found = ShardState::Split(dx);
                            break;
                        }
                    }
                }
                found
            }
            other => other,
        };
    }
    state
}

/// Forward-pass time of a lowered segment program: the compute kernels of
/// Fwd-role ops plus the activation collectives they trigger (grad-sync
/// and backward/optimizer kernels excluded). This is exactly what a
/// checkpoint-and-recompute backward re-executes, so it is the recompute
/// price the memory planner charges (`SegmentProfile::t_fwd_us`).
///
/// Deliberately a second simulation pass over the forward subset (~1/3 of
/// the instructions) rather than a per-role split threaded through
/// [`simulate`]'s report — the added cold-profiling cost is tracked by
/// the profiling/memory benches, and warm cache runs skip it entirely.
fn forward_time_us(
    prog: &crate::spmd::SpmdProgram,
    g: &Graph,
    opts: &ProfileOptions,
) -> f64 {
    use crate::spmd::Instr;
    let mut instrs = Vec::new();
    for instr in &prog.instrs {
        let fwd = match instr {
            Instr::Compute { op, .. } => g.ops[*op].role == Role::Fwd,
            Instr::Coll { tensor, grad_sync, .. }
            | Instr::CollInter { tensor, grad_sync, .. } => {
                !*grad_sync && g.ops[*tensor].role == Role::Fwd
            }
        };
        if fwd {
            instrs.push(instr.clone());
        }
    }
    let fwd_prog = crate::spmd::SpmdProgram { instrs, ..Default::default() };
    simulate(&fwd_prog, &opts.platform, opts.mesh.intra, &opts.compute).total_us
}

/// Steady-state gradient-bucket fusion: the whole model's grad sync fuses
/// into large buckets, so a segment's share should be priced at the fused
/// message's efficiency: t(R·b)/R where R = total grad volume / this
/// segment's grad volume. Returns the (usually negative) delta to add to
/// the segment's simulated comm time.
fn grad_fusion_correction_us(
    prog: &crate::spmd::SpmdProgram,
    total_weight_bytes: u64,
    opts: &ProfileOptions,
) -> f64 {
    let seg_bytes: u64 = prog
        .instrs
        .iter()
        .filter_map(|i| match i {
            crate::spmd::Instr::Coll { bytes, grad_sync: true, .. }
            | crate::spmd::Instr::CollInter { bytes, grad_sync: true, .. } => Some(*bytes),
            _ => None,
        })
        .sum();
    if seg_bytes == 0 {
        return 0.0;
    }
    let r = (total_weight_bytes as f64 / seg_bytes as f64).clamp(1.0, 64.0);
    if r <= 1.01 {
        return 0.0;
    }
    let mut delta = 0.0;
    for instr in &prog.instrs {
        match instr {
            crate::spmd::Instr::Coll { kind, bytes, grad_sync: true, .. } => {
                let t1 = collective_time_us(*kind, *bytes, opts.mesh.intra, &opts.platform.intra);
                let tr = collective_time_us(
                    *kind,
                    (*bytes as f64 * r) as u64,
                    opts.mesh.intra,
                    &opts.platform.intra,
                ) / r;
                delta += tr - t1;
            }
            crate::spmd::Instr::CollInter { kind, bytes, grad_sync: true, .. } => {
                let t1 =
                    collective_time_us(*kind, *bytes, opts.platform.nodes, &opts.platform.inter);
                let tr = collective_time_us(
                    *kind,
                    (*bytes as f64 * r) as u64,
                    opts.platform.nodes,
                    &opts.platform.inter,
                ) / r;
                delta += tr - t1;
            }
            _ => {}
        }
    }
    delta
}

/// The single activation tensor crossing op-id `boundary` (max-bytes one if
/// several; None at graph edges).
pub fn boundary_tensor(g: &Graph, boundary: usize) -> Option<OpId> {
    if boundary == 0 {
        return None;
    }
    let users = g.users();
    let mut best: Option<(usize, OpId)> = None;
    for op in &g.ops[..boundary.min(g.ops.len())] {
        if op.role != Role::Fwd || op.inputs.is_empty() {
            continue;
        }
        let crosses = users[op.id]
            .iter()
            .any(|&u| u >= boundary && g.ops[u].role == Role::Fwd);
        if crosses {
            let b = op.bytes();
            if best.map_or(true, |(bb, _)| b > bb) {
                best = Some((b, op.id));
            }
        }
    }
    best.map(|(_, id)| id)
}

/// Symbolic volume a volume-based cost model charges for a boundary —
/// notably Partial→Split is charged as a full AllReduce rather than the
/// ReduceScatter the compiler actually emits (§5.7).
pub fn symbolic_reshard_bytes(out: ShardState, inn: ShardState, bytes: u64) -> u64 {
    match (out, inn) {
        (a, b) if a == b => 0,
        (ShardState::Replicated, _) => 0,
        (ShardState::Split(_), ShardState::Replicated) => bytes,
        (ShardState::Split(_), ShardState::Split(_)) => bytes,
        (ShardState::Partial, _) => 2 * bytes,
        (_, ShardState::Partial) => 0,
    }
}

/// Price the boundary reshard between two segment configs.
fn reshard_cost_us(out: ShardState, inn: ShardState, bytes: u64, opts: &ProfileOptions) -> f64 {
    let n = opts.mesh.intra;
    let link = &opts.platform.intra;
    match (out, inn) {
        (a, b) if a == b => 0.0,
        (ShardState::Replicated, ShardState::Replicated) => 0.0,
        (ShardState::Split(_), ShardState::Replicated) => {
            collective_time_us(CollKind::AllGather, bytes, n, link)
        }
        (ShardState::Split(_), ShardState::Split(_)) => {
            if opts.pcie_alltoall() {
                (0..n.saturating_sub(1))
                    .map(|_| {
                        collective_time_us(CollKind::SendRecv, bytes / n as u64, n, link)
                    })
                    .sum()
            } else {
                collective_time_us(CollKind::AllToAll, bytes, n, link)
            }
        }
        (ShardState::Replicated, ShardState::Split(_)) => 0.0, // local slice
        (ShardState::Partial, ShardState::Replicated) => {
            collective_time_us(CollKind::AllReduce, bytes, n, link)
        }
        (ShardState::Partial, ShardState::Split(_)) => {
            // the compiler's AllReduce→ReduceScatter rewrite (§5.7)
            collective_time_us(CollKind::ReduceScatter, bytes, n, link)
        }
        (_, ShardState::Partial) => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_training, ModelCfg};
    use crate::pblock::build_parallel_blocks;
    use crate::segment::extract_segments;

    fn profiled(preset: &str, layers: usize) -> (Graph, BlockSet, SegmentSet, ProfileDb) {
        let cfg = ModelCfg::preset(preset).with_layers(layers);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let ss = extract_segments(&g, &bs);
        let opts = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
        let db = profile_model(&g, &bs, &ss, &opts);
        (g, bs, ss, db)
    }

    #[test]
    fn gpt_profile_space_matches_paper_scale() {
        // paper §5.5: 2·81 + 2·9 = 180 programs for GPT. We have ONE
        // unique hidden-layer segment (no lowering noise) + head: 81 + head
        // configs + reshard pairs — same order of magnitude.
        let (_, _, ss, db) = profiled("gpt-tiny", 4);
        let space = db.profile_space();
        assert!(space >= 81, "space {space}");
        assert!(space <= 400, "space {space}");
        assert_eq!(ss.num_unique(), db.segments.len());
    }

    #[test]
    fn profiles_are_positive_and_distinct() {
        let (_, _, _, db) = profiled("gpt-tiny", 2);
        let layer = db.segments.iter().find(|s| s.configs.len() == 81).unwrap();
        assert!(layer.t_p_us.iter().all(|&t| t > 0.0));
        // strategies genuinely differ in communication time
        let min = layer.t_c_us.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = layer.t_c_us.iter().cloned().fold(0.0, f64::max);
        assert!(max > 2.0 * min.max(1.0), "min {min} max {max}");
    }

    #[test]
    fn memory_varies_across_configs() {
        let (_, _, _, db) = profiled("gpt-tiny", 2);
        let layer = db.segments.iter().find(|s| s.configs.len() == 81).unwrap();
        let min = layer.mem_bytes.iter().min().unwrap();
        let max = layer.mem_bytes.iter().max().unwrap();
        assert!(max > min, "memory must differ across configs");
    }

    #[test]
    fn memory_columns_are_recorded() {
        let (_, _, _, db) = profiled("gpt-tiny", 2);
        let layer = db.segments.iter().find(|s| s.configs.len() == 81).unwrap();
        let n = layer.configs.len();
        assert_eq!(layer.act_bytes.len(), n);
        assert_eq!(layer.ckpt_bytes.len(), n);
        assert_eq!(layer.t_fwd_us.len(), n);
        for c in 0..n {
            assert!(layer.act_bytes[c] > 0, "retained activations exist");
            assert!(
                layer.act_bytes[c] <= layer.mem_bytes[c],
                "activations are a component of peak memory"
            );
            assert!(layer.t_fwd_us[c] > 0.0, "forward pass takes time");
            assert!(
                layer.t_fwd_us[c] < layer.t_c_us[c] + layer.t_p_us[c],
                "forward is a strict share of the whole step"
            );
        }
        // somewhere the boundary stash undercuts the full activation set —
        // otherwise checkpointing could never pay
        assert!(
            layer.ckpt_bytes.iter().zip(&layer.act_bytes).any(|(&c, &a)| c < a),
            "checkpoint stash must be able to beat full retention"
        );
    }

    #[test]
    fn stats_model_overheads() {
        let (_, _, _, db) = profiled("gpt-tiny", 2);
        assert!(db.stats.programs_compiled > 81);
        assert!(db.stats.est_compile_s > 0.0);
        assert!(db.stats.est_optimized_s <= db.stats.est_compile_s + db.stats.est_profile_s);
    }

    #[test]
    fn warm_cache_skips_profiling_and_reproduces_db() {
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(3);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let ss = extract_segments(&g, &bs);
        let opts = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
        let mut cache = crate::profiler::ProfileCache::in_memory();

        let cold = profile_model_cached(&g, &bs, &ss, &opts, Some(&mut cache));
        assert_eq!(cold.stats.cache_hits, 0);
        assert!(cold.stats.cache_misses > 0);
        assert!(cold.stats.profile_wall_s > 0.0);
        assert_eq!(cache.num_segments(), ss.num_unique());

        let warm = profile_model_cached(&g, &bs, &ss, &opts, Some(&mut cache));
        assert_eq!(warm.stats.cache_misses, 0);
        assert_eq!(warm.stats.cache_hits, cold.stats.cache_misses);
        assert_eq!(warm.stats.profile_wall_s, 0.0, "warm run must not profile");
        assert_eq!(warm.segments, cold.segments);
        assert_eq!(warm.reshard, cold.reshard);
        assert_eq!(warm.profile_space(), cold.profile_space());
        // the Fig.-12 estimate is model-intrinsic: identical on hits
        assert!(warm.stats.est_compile_s == cold.stats.est_compile_s);
        assert!(warm.stats.est_profile_s == cold.stats.est_profile_s);
        assert!(warm.stats.est_optimized_s == cold.stats.est_optimized_s);
        assert_eq!(warm.stats.programs_compiled, cold.stats.programs_compiled);
    }

    #[test]
    fn different_platform_signature_misses_cache() {
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(2);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let ss = extract_segments(&g, &bs);
        let mut cache = crate::profiler::ProfileCache::in_memory();
        let a100 = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
        let v100 = ProfileOptions::new(Platform::v100_nvlink(), Mesh::flat(4));
        profile_model_cached(&g, &bs, &ss, &a100, Some(&mut cache));
        let other = profile_model_cached(&g, &bs, &ss, &v100, Some(&mut cache));
        assert_eq!(other.stats.cache_hits, 0, "v100 must not reuse a100 profiles");
        assert!(other.stats.cache_misses > 0);
    }

    #[test]
    fn threaded_profiling_matches_serial_exactly() {
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(2);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let ss = extract_segments(&g, &bs);
        let serial = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
        let threaded = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4)).with_threads(4);
        let a = profile_model(&g, &bs, &ss, &serial);
        let b = profile_model(&g, &bs, &ss, &threaded);
        assert_eq!(a.segments, b.segments, "pool must preserve result order");
        assert_eq!(a.reshard, b.reshard);
    }

    #[test]
    fn reshard_tables_exist_for_adjacent_uniques() {
        let (_, _, ss, db) = profiled("gpt-tiny", 4);
        // layer→layer (same unique) and layer→head pairs
        let mut expected = std::collections::HashSet::new();
        for w in ss.instances.windows(2) {
            expected.insert((w[0].unique_id, w[1].unique_id));
        }
        for pair in &expected {
            assert!(db.reshard.contains_key(pair), "{pair:?} missing");
        }
    }
}
