//! Segment profiling (paper §4.2–4.3): enumerate each unique segment's
//! sub-search space, "compile" (lower + passes) and "run" (simulate on the
//! substituted cluster, with compute costs from the PJRT-calibrated model)
//! every configuration, plus pairwise boundary resharding profiles T_R.
//!
//! Bookkeeping mirrors the paper's four overhead classes: AnalysisPasses,
//! ExecCompiling, MetricsProfiling, ComposeSearch (Fig. 12/13). Because our
//! testbed is simulated, `stats` records both our actual wall-clock and the
//! *estimated* real-testbed compile/run cost (what an XLA backend + 15
//! timed runs would have cost), including the §4.3 optimizations: parallel
//! compilation, compile/profile overlap, and the dynamic profiling limit.

pub mod cache;
pub mod config;
pub mod db;
pub mod run;

pub use cache::{CacheHandle, CacheKey, ProfileCache, SharedProfileCache};
pub use config::{enumerate_configs, SegmentConfig};
pub use db::{ProfileDb, ProfilerStats, ReshardTable, SegmentProfile};
pub use run::{profile_model, profile_model_cached, profile_model_handle, ProfileOptions};
