//! Persistent, fingerprint-keyed profile cache.
//!
//! The paper's central economics (§4.2, §5.5): a moderate number of
//! representative segment profiles amortizes across a huge repetitive
//! graph. This module extends the amortization across *runs and
//! processes*: every profiled unique segment is stored under
//! `(segment fingerprint, platform signature, parts)` and every boundary
//! reshard table under `(from fingerprint, to fingerprint, platform
//! signature, parts)`. A second `run_cfp` on the same model/cluster then
//! skips `MetricsProfiling` entirely — the dominant phase becomes a cache
//! lookup.
//!
//! File format (see ROADMAP.md "Profile cache" for invalidation rules):
//! a single JSON document written atomically (tmp file + rename) via
//! [`crate::util::json`] — no external serialization deps.
//!
//! ```text
//! { "version": 2,
//!   "segments": [ {"fingerprint", "platform", "parts", "profile"} ... ],
//!   "reshard":  [ {"from_fp", "to_fp", "platform", "parts", "table"} ... ] }
//! ```
//!
//! Version 2 (PR 3) adds the `act_bytes`/`ckpt_bytes`/`t_fwd_us` memory
//! columns to segment profiles; version-1 files are discarded wholesale
//! and rebuilt (never migrated in place).
//!
//! Unknown versions and unparseable files are ignored wholesale (the cache
//! is rebuilt and rewritten) — a cache must never turn a valid run into an
//! error.
//!
//! # Invariants
//!
//! * **Invalidation by key construction.** There is no in-place
//!   migration: every knob that shapes a profiled number (model
//!   structure, platform links, mesh, bucket size, optimizer factor,
//!   compute model, total gradient volume, partition count) is folded
//!   into the lookup key, so any change *misses* instead of returning a
//!   stale profile. A wrong answer is impossible; the worst case is
//!   re-profiling.
//! * **Bounded growth (LRU).** Entries carry a monotonically increasing
//!   recency stamp (persisted in the file as `stamp` per entry plus a
//!   top-level `clock`). With a `max_entries` bound set (CLI
//!   `--cache-max-entries`), [`ProfileCache::save`] evicts the
//!   least-recently-used entries — segments and reshard tables counted
//!   together — until the bound holds. Files without stamps (or from
//!   older writers) parse with stamp 0, i.e. oldest-first eviction.
//! * **Crash/corruption safety.** Writes are atomic (tmp + rename); a
//!   truncated or hand-edited file degrades to an empty cache, never an
//!   error, and internally inconsistent entries are rejected at lookup.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::spmd::ShardState;
use crate::util::Json;

use super::config::SegmentConfig;
use super::db::{ReshardTable, SegmentProfile};

/// Bump whenever the on-disk schema or any profiled quantity's meaning
/// changes; old files are then ignored (never migrated).
pub const CACHE_VERSION: i64 = 2;

/// Validity domain of one unique segment's profile.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct CacheKey {
    /// full segment fingerprint (incl. the orphan-count suffix)
    pub fingerprint: String,
    /// everything else that shapes profiled numbers: platform, mesh,
    /// bucket size, optimizer factor, compute model, total grad volume —
    /// see `ProfileOptions::cache_signature`
    pub platform: String,
    /// intra-op partitions the strategies were profiled at
    pub parts: usize,
}

type ReshardKey = (String, String, String, usize); // (from_fp, to_fp, platform, parts)

/// In-memory cache, optionally bound to an on-disk JSON file. Every
/// entry carries a recency stamp (`u64` draw from `clock`) used for the
/// optional LRU bound — see the module docs.
#[derive(Clone, Debug, Default)]
pub struct ProfileCache {
    segments: BTreeMap<CacheKey, (SegmentProfile, u64)>,
    reshard: BTreeMap<ReshardKey, (ReshardTable, u64)>,
    path: Option<PathBuf>,
    dirty: bool,
    /// monotonically increasing recency counter (persisted)
    clock: u64,
    /// the clock value when this handle was opened — stamps above it were
    /// drawn by *this* process (runtime-only, not persisted); used to
    /// rebase only our own draws across process clock domains at save
    open_clock: u64,
    /// optional LRU bound on segments + reshard entries combined
    max_entries: Option<usize>,
}

impl ProfileCache {
    /// Cache with no backing file (tests, single-process reuse).
    pub fn in_memory() -> ProfileCache {
        ProfileCache::default()
    }

    /// Cache bound to `path`, pre-populated from it when a valid cache
    /// file exists there. Missing/corrupt/old-version files yield an
    /// empty cache that will overwrite the file on [`ProfileCache::save`].
    pub fn open(path: impl Into<PathBuf>) -> ProfileCache {
        let path = path.into();
        let mut cache = std::fs::read_to_string(&path)
            .ok()
            // load-time corruption fault: treat the file's bytes as
            // garbage, exercising the degrade-to-empty lane on demand
            .filter(|_| !crate::util::failpoint::should_trip("profile_cache.load_corrupt"))
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|json| ProfileCache::from_json(&json))
            .unwrap_or_default();
        cache.path = Some(path);
        cache.open_clock = cache.clock;
        cache
    }

    pub fn path(&self) -> Option<&Path> {
        self.path.as_deref()
    }

    pub fn num_segments(&self) -> usize {
        self.segments.len()
    }

    pub fn num_reshards(&self) -> usize {
        self.reshard.len()
    }

    pub fn is_empty(&self) -> bool {
        self.segments.is_empty() && self.reshard.is_empty()
    }

    /// Bound the cache to `n` entries (segments + reshard tables counted
    /// together); `None` disables eviction. Least-recently-used entries
    /// are evicted at [`ProfileCache::save`] time, after the concurrent-
    /// writer merge, so the bound holds on the written file.
    pub fn set_max_entries(&mut self, n: Option<usize>) {
        self.max_entries = n;
    }

    pub fn max_entries(&self) -> Option<usize> {
        self.max_entries
    }

    /// Lookup bumps the entry's recency stamp (this is what makes the
    /// eviction LRU rather than FIFO). The bump is persisted only when an
    /// entry bound is set — an unbounded warm run stays a no-op save.
    pub fn get_segment(&mut self, key: &CacheKey) -> Option<&SegmentProfile> {
        let clock = self.clock + 1;
        match self.segments.get_mut(key) {
            Some(e) => {
                self.clock = clock;
                e.1 = clock;
                if self.max_entries.is_some() {
                    self.dirty = true;
                }
                Some(&e.0)
            }
            None => None,
        }
    }

    pub fn put_segment(&mut self, key: CacheKey, profile: SegmentProfile) {
        self.clock += 1;
        let stamp = self.clock;
        self.segments.insert(key, (profile, stamp));
        self.dirty = true;
    }

    /// See [`ProfileCache::get_segment`] for the recency-stamp behaviour.
    pub fn get_reshard(
        &mut self,
        from_fp: &str,
        to_fp: &str,
        platform: &str,
        parts: usize,
    ) -> Option<&ReshardTable> {
        // BTreeMap<(String,..)> lookup needs owned keys; reshard tables are
        // fetched once per unique pair so the allocation is negligible.
        let key: ReshardKey =
            (from_fp.to_string(), to_fp.to_string(), platform.to_string(), parts);
        let clock = self.clock + 1;
        match self.reshard.get_mut(&key) {
            Some(e) => {
                self.clock = clock;
                e.1 = clock;
                if self.max_entries.is_some() {
                    self.dirty = true;
                }
                Some(&e.0)
            }
            None => None,
        }
    }

    pub fn put_reshard(
        &mut self,
        from_fp: &str,
        to_fp: &str,
        platform: &str,
        parts: usize,
        table: ReshardTable,
    ) {
        let key: ReshardKey =
            (from_fp.to_string(), to_fp.to_string(), platform.to_string(), parts);
        self.clock += 1;
        let stamp = self.clock;
        self.reshard.insert(key, (table, stamp));
        self.dirty = true;
    }

    /// Evict least-recently-used entries until the configured bound
    /// holds. Ties (equal stamps, e.g. entries from stamp-less files)
    /// break by key order — deterministic. O(evicted · entries), which is
    /// fine at the file sizes a bound is meant to enforce.
    fn evict_to_cap(&mut self) {
        let Some(cap) = self.max_entries else { return };
        while self.segments.len() + self.reshard.len() > cap {
            let seg_min = self
                .segments
                .iter()
                .map(|(k, (_, s))| (*s, k.clone()))
                .min();
            let rs_min = self
                .reshard
                .iter()
                .map(|(k, (_, s))| (*s, k.clone()))
                .min();
            match (seg_min, rs_min) {
                (Some((ss, sk)), Some((rs, _))) if ss <= rs => {
                    self.segments.remove(&sk);
                }
                (_, Some((_, rk))) => {
                    self.reshard.remove(&rk);
                }
                (Some((_, sk)), None) => {
                    self.segments.remove(&sk);
                }
                (None, None) => break,
            }
            self.dirty = true;
        }
    }

    /// Persist to the backing file if bound and modified. Atomic against
    /// readers: writes a sibling tmp file, then renames over the target.
    /// Before writing, entries another process added since
    /// [`ProfileCache::open`] are folded back in (ours win on conflict).
    ///
    /// The read-merge-rename sequence runs under a sibling `.lock` file
    /// (atomic `O_CREAT|O_EXCL` acquisition, stale-lock takeover — see
    /// `acquire_save_lock`) so two racing savers serialize instead of
    /// one dropping the other's entries. If the lock cannot be acquired
    /// within `LOCK_WAIT` the saver proceeds locklessly — the pre-lock
    /// best-effort merge, which can drop a racing saver's entries but
    /// costs re-profiling on a later run, never a wrong plan.
    pub fn save(&mut self) -> std::io::Result<()> {
        let Some(path) = self.path.clone() else {
            return Ok(());
        };
        if !self.dirty {
            return Ok(());
        }
        let _lock = acquire_save_lock(&path, LOCK_STALE, LOCK_WAIT);
        if let Some(disk) = std::fs::read_to_string(&path)
            .ok()
            .and_then(|text| Json::parse(&text).ok())
            .and_then(|json| ProfileCache::from_json(&json))
        {
            // recency stamps are per-process clock draws: a fresh process
            // merging into a long-lived file would otherwise see its own
            // just-used entries stamped "older" than everything on disk
            // and evict them first. Rebase the stamps *this process drew*
            // (those above the clock it opened at — loaded-but-untouched
            // entries keep their old shared-timeline stamps) past the
            // disk clock, preserving relative order, so entries this
            // process actually touched stay the most recent.
            if disk.clock > self.clock {
                let base = self.open_clock.min(self.clock);
                let delta = disk.clock - base;
                for e in self.segments.values_mut() {
                    if e.1 > base {
                        e.1 += delta;
                    }
                }
                for e in self.reshard.values_mut() {
                    if e.1 > base {
                        e.1 += delta;
                    }
                }
                self.clock += delta;
            }
            for (k, v) in disk.segments {
                self.segments.entry(k).or_insert(v);
            }
            for (k, v) in disk.reshard {
                self.reshard.entry(k).or_insert(v);
            }
        }
        self.evict_to_cap();
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
        let text = self.to_json().to_string();
        // torn-write fault: persist only a prefix of the document (the
        // rename still lands, so the corruption is silent); the next
        // open() must discard the file wholesale and re-profile
        let bytes: &[u8] = if crate::util::failpoint::should_trip("profile_cache.torn_save") {
            &text.as_bytes()[..text.len() / 2]
        } else {
            text.as_bytes()
        };
        std::fs::write(&tmp, bytes)?;
        std::fs::rename(&tmp, &path)?;
        self.dirty = false;
        Ok(())
    }

    // ---------------------------------------------------------------- json

    pub fn to_json(&self) -> Json {
        let segments = self
            .segments
            .iter()
            .map(|(k, (p, stamp))| {
                Json::obj(vec![
                    ("fingerprint", Json::str(k.fingerprint.clone())),
                    ("platform", Json::str(k.platform.clone())),
                    ("parts", Json::num(k.parts as f64)),
                    ("stamp", Json::num(*stamp as f64)),
                    ("profile", segment_profile_to_json(p)),
                ])
            })
            .collect();
        let reshard = self
            .reshard
            .iter()
            .map(|((from, to, platform, parts), (t, stamp))| {
                Json::obj(vec![
                    ("from_fp", Json::str(from.clone())),
                    ("to_fp", Json::str(to.clone())),
                    ("platform", Json::str(platform.clone())),
                    ("parts", Json::num(*parts as f64)),
                    ("stamp", Json::num(*stamp as f64)),
                    ("table", reshard_table_to_json(t)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("version", Json::num(CACHE_VERSION as f64)),
            ("clock", Json::num(self.clock as f64)),
            ("segments", Json::Arr(segments)),
            ("reshard", Json::Arr(reshard)),
        ])
    }

    pub fn from_json(j: &Json) -> Option<ProfileCache> {
        if j.get("version")?.as_i64()? != CACHE_VERSION {
            return None;
        }
        let mut cache = ProfileCache::default();
        // `stamp`/`clock` are optional: files written before the LRU bound
        // existed parse with stamp 0 (oldest-first eviction order)
        let stamp_of = |e: &Json| e.get("stamp").and_then(Json::as_u64).unwrap_or(0);
        for e in j.get("segments")?.as_arr()? {
            let key = CacheKey {
                fingerprint: e.get("fingerprint")?.as_str()?.to_string(),
                platform: e.get("platform")?.as_str()?.to_string(),
                parts: e.get("parts")?.as_u64()? as usize,
            };
            let profile = segment_profile_from_json(e.get("profile")?)?;
            let stamp = stamp_of(e);
            if stamp > cache.clock {
                cache.clock = stamp;
            }
            cache.segments.insert(key, (profile, stamp));
        }
        for e in j.get("reshard")?.as_arr()? {
            let key: ReshardKey = (
                e.get("from_fp")?.as_str()?.to_string(),
                e.get("to_fp")?.as_str()?.to_string(),
                e.get("platform")?.as_str()?.to_string(),
                e.get("parts")?.as_u64()? as usize,
            );
            let table = reshard_table_from_json(e.get("table")?)?;
            let stamp = stamp_of(e);
            if stamp > cache.clock {
                cache.clock = stamp;
            }
            cache.reshard.insert(key, (table, stamp));
        }
        if let Some(c) = j.get("clock").and_then(Json::as_u64) {
            if c > cache.clock {
                cache.clock = c;
            }
        }
        Some(cache)
    }
}

// --------------------------------------------------------------- save lock

/// A saver holding this lock is mid `read-merge-rename`, which is
/// milliseconds of work on one JSON file — a lock untouched for this
/// long belongs to a crashed process and is taken over.
pub(crate) const LOCK_STALE: Duration = Duration::from_secs(10);

/// How long a saver waits for the lock before falling back to the
/// lockless best-effort merge.
pub(crate) const LOCK_WAIT: Duration = Duration::from_millis(500);

/// Per-acquisition sequence number, making lock tokens unique within a
/// process (the pid disambiguates across processes).
static LOCK_SEQ: AtomicU64 = AtomicU64::new(0);

/// RAII guard for the sibling `.lock` file; releases on drop — but only
/// if the lock still carries this acquisition's token. A saver paused
/// past the stale window may have been taken over; removing blindly
/// would delete the new holder's lock.
pub(crate) struct SaveLock {
    path: PathBuf,
    token: String,
}

impl Drop for SaveLock {
    fn drop(&mut self) {
        let ours = std::fs::read_to_string(&self.path)
            .map_or(false, |body| body.trim() == self.token);
        if ours {
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// `<cache file>.lock` — a sibling, so it lives on the same filesystem
/// (rename atomicity) and is found by every process sharing the cache.
pub(crate) fn save_lock_path(target: &Path) -> PathBuf {
    let mut name = target.file_name().unwrap_or_default().to_os_string();
    name.push(".lock");
    target.with_file_name(name)
}

/// Acquire the save lock for `target`: atomic `O_CREAT|O_EXCL` creation
/// of the sibling `.lock` file, retried until `wait` elapses. Each
/// acquisition writes a unique token into the file and then re-reads it:
/// ownership is confirmed only if the token survived, so a racing
/// stale-takeover that swapped the file out from under us is detected
/// as a lost race, not a double acquisition. A lock whose mtime is
/// older than `stale` is presumed abandoned by a crashed saver and
/// claimed by renaming it aside (atomic: exactly one racer wins the
/// rename; losers just retry). Returns `None` on timeout or when the
/// directory is unwritable — locking is best-effort, the caller falls
/// back to the lockless merge.
pub(crate) fn acquire_save_lock(target: &Path, stale: Duration, wait: Duration) -> Option<SaveLock> {
    // lock-acquire timeout fault: behave exactly as if `wait` elapsed
    // with the lock held — the caller proceeds with the lockless
    // best-effort merge, which can cost re-profiling, never a wrong plan
    if crate::util::failpoint::should_trip("profile_cache.lock_timeout") {
        return None;
    }
    let lock = save_lock_path(target);
    if let Some(dir) = target.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).ok()?;
        }
    }
    let deadline = Instant::now() + wait;
    loop {
        let token = format!("{}.{}", std::process::id(), LOCK_SEQ.fetch_add(1, Ordering::Relaxed));
        match std::fs::OpenOptions::new().write(true).create_new(true).open(&lock) {
            Ok(mut f) => {
                let _ = writeln!(f, "{token}");
                drop(f);
                // confirm ownership: between create_new and here another
                // saver could have judged our file stale (clock skew) and
                // swapped it; whoever's token is in the file owns it
                let confirmed = std::fs::read_to_string(&lock)
                    .map_or(false, |body| body.trim() == token);
                if confirmed {
                    return Some(SaveLock { path: lock, token });
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                let abandoned = std::fs::metadata(&lock)
                    .and_then(|md| md.modified())
                    .ok()
                    .and_then(|m| m.elapsed().ok())
                    .map_or(false, |age| age > stale);
                if abandoned {
                    claim_stale_lock(&lock, stale, &token);
                    continue;
                }
            }
            Err(_) => return None,
        }
        if Instant::now() >= deadline {
            return None;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Claim a lock file whose mtime looked older than `stale`: rename it to
/// a name unique to this attempt, then delete the carcass. Renaming is
/// the atomic step — exactly one racer wins, losers just retry.
///
/// The staleness probe above and the rename here are not one atomic
/// action, and that gap is a real race: the dead lock can be released
/// and a *new* holder's fresh lock created at the same path in between,
/// so the rename may have grabbed a live holder's lock. Deleting it
/// anyway would unlock a mid-save critical section and let two savers
/// run the read-merge-rename concurrently. So after winning the rename,
/// re-check the mtime of what was actually grabbed: if it is fresh (or
/// unreadable), put it back via `hard_link` — which fails rather than
/// clobber if yet another racer already created a newer lock, in which
/// case the fresh lock we grabbed is the one that lost a create_new race
/// and the newer file is authoritative. Only a genuinely stale carcass
/// is discarded. Returns whether a stale lock was actually cleared.
fn claim_stale_lock(lock: &Path, stale: Duration, token: &str) -> bool {
    let aside = lock.with_extension(format!("stale.{token}"));
    if std::fs::rename(lock, &aside).is_err() {
        return false; // another racer claimed it first
    }
    let still_stale = std::fs::metadata(&aside)
        .and_then(|md| md.modified())
        .ok()
        .and_then(|m| m.elapsed().ok())
        .map_or(false, |age| age > stale)
        // takeover-race fault: pretend the re-check found a fresh lock
        // (we grabbed a live holder's lock mid-save) — forces the
        // hard_link restore path below
        && !crate::util::failpoint::should_trip("profile_cache.stale_race");
    if still_stale {
        let _ = std::fs::remove_file(&aside);
        return true;
    }
    // grabbed a live holder's lock — restore it (or discard our copy if
    // an even newer lock already took the path)
    let _ = std::fs::hard_link(&aside, lock);
    let _ = std::fs::remove_file(&aside);
    false
}

// ------------------------------------------------------- shared-handle view

/// Process-wide shareable [`ProfileCache`]: the same cache behind an
/// `Arc<Mutex<..>>`, so concurrent planning runs (the `cfp serve` worker
/// pool) reuse each other's freshly profiled segments instead of
/// re-profiling. Every access is a short lock-hold (one get or one put);
/// profiling itself runs outside the lock, so distinct requests profile
/// concurrently and publish results as they finish. Profiled values are
/// deterministic, so concurrent writers of the same key store identical
/// entries — sharing can never change a planned output.
#[derive(Clone, Debug, Default)]
pub struct SharedProfileCache {
    inner: Arc<Mutex<ProfileCache>>,
}

impl SharedProfileCache {
    /// Shared cache with no backing file.
    pub fn in_memory() -> SharedProfileCache {
        SharedProfileCache::default()
    }

    /// Shared cache bound to (and pre-populated from) `path` — see
    /// [`ProfileCache::open`].
    pub fn open(path: impl Into<PathBuf>) -> SharedProfileCache {
        SharedProfileCache::from_cache(ProfileCache::open(path))
    }

    /// Wrap an already-open cache.
    pub fn from_cache(cache: ProfileCache) -> SharedProfileCache {
        SharedProfileCache { inner: Arc::new(Mutex::new(cache)) }
    }

    /// Run `f` under the cache lock. Poisoning is ignored deliberately:
    /// every individual cache operation is atomic (one map get/insert),
    /// so a panic elsewhere while the lock was held cannot leave the map
    /// half-updated.
    pub fn with<R>(&self, f: impl FnOnce(&mut ProfileCache) -> R) -> R {
        let mut guard = self.inner.lock().unwrap_or_else(|p| p.into_inner());
        f(&mut *guard)
    }

    /// Number of segment + reshard entries combined.
    pub fn len(&self) -> usize {
        self.with(|c| c.num_segments() + c.num_reshards())
    }

    pub fn is_empty(&self) -> bool {
        self.with(|c| c.is_empty())
    }

    /// A profiling-time [`CacheHandle`] view of this cache.
    pub fn handle(&self) -> CacheHandle<'_> {
        CacheHandle::Shared(self)
    }

    /// Persist through [`ProfileCache::save`] (lock-file protocol incl.)
    /// — WITHOUT holding the in-process mutex across the file work. The
    /// cache is snapshotted under the lock; the snapshot performs the
    /// (possibly slow: lock-file wait + whole-file merge) save outside
    /// it, so concurrent searches' lookups never stall behind disk I/O.
    /// The live cache is marked clean only if nothing changed while the
    /// snapshot was being written; disk entries merged by the snapshot
    /// are not folded back into the live cache — the cost is a possible
    /// re-profile on a later miss, never a wrong plan.
    pub fn save(&self) -> std::io::Result<()> {
        let snapshot = self.with(|c| {
            // nothing to do for clean or unbacked caches — and no clone
            (c.dirty && c.path.is_some()).then(|| (c.clone(), c.clock))
        });
        let Some((mut snap, clock_at_snapshot)) = snapshot else {
            return Ok(());
        };
        snap.save()?;
        self.with(|c| {
            if c.clock == clock_at_snapshot {
                c.dirty = false;
            }
        });
        Ok(())
    }

    pub fn set_max_entries(&self, n: Option<usize>) {
        self.with(|c| c.set_max_entries(n));
    }

    pub fn num_segments(&self) -> usize {
        self.with(|c| c.num_segments())
    }

    pub fn num_reshards(&self) -> usize {
        self.with(|c| c.num_reshards())
    }
}

/// How a profiling run sees its (optional) cache: not at all, exclusively
/// (`&mut`, the one-shot CLI path), or shared process-wide behind the
/// [`SharedProfileCache`] lock (the serving path). Getters return owned
/// clones so both ownership shapes expose one API; `None` never
/// allocates.
pub enum CacheHandle<'a> {
    None,
    Own(&'a mut ProfileCache),
    Shared(&'a SharedProfileCache),
}

impl<'a> CacheHandle<'a> {
    pub fn from_option(opt: Option<&'a mut ProfileCache>) -> CacheHandle<'a> {
        match opt {
            Some(c) => CacheHandle::Own(c),
            None => CacheHandle::None,
        }
    }

    /// Reborrow (the `Option::as_deref_mut` idiom) so the handle can be
    /// passed down repeatedly.
    pub fn reborrow(&mut self) -> CacheHandle<'_> {
        match self {
            CacheHandle::None => CacheHandle::None,
            CacheHandle::Own(c) => CacheHandle::Own(&mut **c),
            CacheHandle::Shared(s) => CacheHandle::Shared(*s),
        }
    }

    pub fn is_none(&self) -> bool {
        matches!(self, CacheHandle::None)
    }

    pub fn get_segment(&mut self, key: &CacheKey) -> Option<SegmentProfile> {
        match self {
            CacheHandle::None => None,
            CacheHandle::Own(c) => c.get_segment(key).cloned(),
            CacheHandle::Shared(s) => s.with(|c| c.get_segment(key).cloned()),
        }
    }

    /// Store a segment profile (no-op without a cache; the clone happens
    /// only when there is one).
    pub fn put_segment(&mut self, key: CacheKey, profile: &SegmentProfile) {
        match self {
            CacheHandle::None => {}
            CacheHandle::Own(c) => c.put_segment(key, profile.clone()),
            CacheHandle::Shared(s) => s.with(|c| c.put_segment(key, profile.clone())),
        }
    }

    pub fn get_reshard(
        &mut self,
        from_fp: &str,
        to_fp: &str,
        platform: &str,
        parts: usize,
    ) -> Option<ReshardTable> {
        match self {
            CacheHandle::None => None,
            CacheHandle::Own(c) => c.get_reshard(from_fp, to_fp, platform, parts).cloned(),
            CacheHandle::Shared(s) => {
                s.with(|c| c.get_reshard(from_fp, to_fp, platform, parts).cloned())
            }
        }
    }

    pub fn put_reshard(
        &mut self,
        from_fp: &str,
        to_fp: &str,
        platform: &str,
        parts: usize,
        table: &ReshardTable,
    ) {
        match self {
            CacheHandle::None => {}
            CacheHandle::Own(c) => c.put_reshard(from_fp, to_fp, platform, parts, table.clone()),
            CacheHandle::Shared(s) => {
                s.with(|c| c.put_reshard(from_fp, to_fp, platform, parts, table.clone()))
            }
        }
    }
}

// ------------------------------------------------------------- serializers

pub fn shard_state_to_json(s: &ShardState) -> Json {
    Json::str(match s {
        ShardState::Replicated => "r".to_string(),
        ShardState::Partial => "p".to_string(),
        ShardState::Split(d) => format!("s{d}"),
    })
}

pub fn shard_state_from_json(j: &Json) -> Option<ShardState> {
    let s = j.as_str()?;
    match s {
        "r" => Some(ShardState::Replicated),
        "p" => Some(ShardState::Partial),
        _ => s
            .strip_prefix('s')
            .and_then(|d| d.parse::<usize>().ok())
            .map(ShardState::Split),
    }
}

fn f64_arr(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::num(x)).collect())
}

fn u64_arr(v: &[u64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::num(x as f64)).collect())
}

fn f64_arr_from(j: &Json) -> Option<Vec<f64>> {
    j.as_arr()?.iter().map(|x| x.as_f64()).collect()
}

fn u64_arr_from(j: &Json) -> Option<Vec<u64>> {
    j.as_arr()?.iter().map(|x| x.as_u64()).collect()
}

pub fn segment_profile_to_json(p: &SegmentProfile) -> Json {
    Json::obj(vec![
        ("configs", Json::Arr(p.configs.iter().map(SegmentConfig::to_json).collect())),
        ("t_c_us", f64_arr(&p.t_c_us)),
        ("t_p_us", f64_arr(&p.t_p_us)),
        ("mem_bytes", u64_arr(&p.mem_bytes)),
        ("act_bytes", u64_arr(&p.act_bytes)),
        ("ckpt_bytes", u64_arr(&p.ckpt_bytes)),
        ("t_fwd_us", f64_arr(&p.t_fwd_us)),
        ("symbolic_volume", u64_arr(&p.symbolic_volume)),
        ("boundary_out", Json::Arr(p.boundary_out.iter().map(shard_state_to_json).collect())),
        ("boundary_in", Json::Arr(p.boundary_in.iter().map(shard_state_to_json).collect())),
    ])
}

pub fn segment_profile_from_json(j: &Json) -> Option<SegmentProfile> {
    let configs = j
        .get("configs")?
        .as_arr()?
        .iter()
        .map(SegmentConfig::from_json)
        .collect::<Option<Vec<_>>>()?;
    let p = SegmentProfile {
        configs,
        t_c_us: f64_arr_from(j.get("t_c_us")?)?,
        t_p_us: f64_arr_from(j.get("t_p_us")?)?,
        mem_bytes: u64_arr_from(j.get("mem_bytes")?)?,
        act_bytes: u64_arr_from(j.get("act_bytes")?)?,
        ckpt_bytes: u64_arr_from(j.get("ckpt_bytes")?)?,
        t_fwd_us: f64_arr_from(j.get("t_fwd_us")?)?,
        symbolic_volume: u64_arr_from(j.get("symbolic_volume")?)?,
        boundary_out: j
            .get("boundary_out")?
            .as_arr()?
            .iter()
            .map(shard_state_from_json)
            .collect::<Option<Vec<_>>>()?,
        boundary_in: j
            .get("boundary_in")?
            .as_arr()?
            .iter()
            .map(shard_state_from_json)
            .collect::<Option<Vec<_>>>()?,
    };
    // a profile is internally consistent only if every per-config column
    // has one entry per config — reject truncated/hand-edited entries
    let n = p.configs.len();
    let consistent = p.t_c_us.len() == n
        && p.t_p_us.len() == n
        && p.mem_bytes.len() == n
        && p.act_bytes.len() == n
        && p.ckpt_bytes.len() == n
        && p.t_fwd_us.len() == n
        && p.symbolic_volume.len() == n
        && p.boundary_out.len() == n
        && p.boundary_in.len() == n;
    consistent.then_some(p)
}

pub fn reshard_table_to_json(t: &ReshardTable) -> Json {
    Json::obj(vec![
        ("t_r_us", Json::Arr(t.t_r_us.iter().map(|row| f64_arr(row)).collect())),
        ("sym_vol", Json::Arr(t.sym_vol.iter().map(|row| u64_arr(row)).collect())),
        ("programs", Json::num(t.programs as f64)),
    ])
}

pub fn reshard_table_from_json(j: &Json) -> Option<ReshardTable> {
    let t_r_us = j
        .get("t_r_us")?
        .as_arr()?
        .iter()
        .map(f64_arr_from)
        .collect::<Option<Vec<_>>>()?;
    let sym_vol = j
        .get("sym_vol")?
        .as_arr()?
        .iter()
        .map(u64_arr_from)
        .collect::<Option<Vec<_>>>()?;
    let programs = j.get("programs")?.as_u64()? as usize;
    Some(ReshardTable { t_r_us, sym_vol, programs })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_profile() -> SegmentProfile {
        SegmentProfile {
            configs: vec![
                SegmentConfig { strategy: vec![0, 1] },
                SegmentConfig { strategy: vec![2, 0] },
            ],
            t_c_us: vec![12.5, 0.0625],
            t_p_us: vec![100.0, 250.75],
            mem_bytes: vec![1 << 30, 3 << 20],
            act_bytes: vec![1 << 28, 1 << 20],
            ckpt_bytes: vec![1 << 22, 1 << 14],
            t_fwd_us: vec![33.125, 80.25],
            symbolic_volume: vec![0, 42],
            boundary_out: vec![ShardState::Replicated, ShardState::Split(1)],
            boundary_in: vec![ShardState::Partial, ShardState::Split(0)],
        }
    }

    fn sample_table() -> ReshardTable {
        ReshardTable {
            t_r_us: vec![vec![0.0, 33.25], vec![7.5, 0.0]],
            sym_vol: vec![vec![0, 64], vec![128, 0]],
            programs: 3,
        }
    }

    #[test]
    fn profile_json_round_trip_is_exact() {
        let p = sample_profile();
        let j = Json::parse(&segment_profile_to_json(&p).to_string()).unwrap();
        assert_eq!(segment_profile_from_json(&j), Some(p));
    }

    #[test]
    fn truncated_profile_rejected() {
        let p = sample_profile();
        let mut j = segment_profile_to_json(&p);
        if let Json::Obj(m) = &mut j {
            m.insert("t_c_us".into(), Json::Arr(vec![Json::num(1.0)]));
        }
        assert_eq!(segment_profile_from_json(&j), None);
    }

    #[test]
    fn shard_states_round_trip() {
        let states = [
            ShardState::Replicated,
            ShardState::Partial,
            ShardState::Split(0),
            ShardState::Split(3),
        ];
        for s in states {
            assert_eq!(shard_state_from_json(&shard_state_to_json(&s)), Some(s));
        }
        assert_eq!(shard_state_from_json(&Json::str("x9")), None);
    }

    #[test]
    fn cache_file_round_trip() {
        let mut c = ProfileCache::in_memory();
        let key = CacheKey {
            fingerprint: "dot2([4, 8]x[8, 8])[m,n,k]|orphans:2".into(),
            platform: "a100-pcie/sig".into(),
            parts: 4,
        };
        c.put_segment(key.clone(), sample_profile());
        c.put_reshard("fpA", "fpB", "a100-pcie/sig", 4, sample_table());

        let mut parsed = ProfileCache::from_json(
            &Json::parse(&c.to_json().to_string()).unwrap(),
        )
        .unwrap();
        assert_eq!(parsed.get_segment(&key), Some(&sample_profile()));
        assert_eq!(
            parsed.get_reshard("fpA", "fpB", "a100-pcie/sig", 4),
            Some(&sample_table())
        );
        assert_eq!(parsed.get_reshard("fpA", "fpB", "other", 4), None);
    }

    #[test]
    fn version_mismatch_and_garbage_ignored() {
        let mut j = ProfileCache::in_memory().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("version".into(), Json::num(999.0));
        }
        assert!(ProfileCache::from_json(&j).is_none());
        assert!(ProfileCache::from_json(&Json::Null).is_none());
    }

    #[test]
    fn open_and_save_persist_across_instances() {
        let dir = std::env::temp_dir().join(format!("cfp-cache-unit-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("profiles.json");

        let mut c = ProfileCache::open(&path);
        assert!(c.is_empty());
        let key = CacheKey { fingerprint: "fp".into(), platform: "sig".into(), parts: 2 };
        c.put_segment(key.clone(), sample_profile());
        c.save().unwrap();
        assert!(path.exists());

        let mut reloaded = ProfileCache::open(&path);
        assert_eq!(reloaded.num_segments(), 1);
        assert_eq!(reloaded.get_segment(&key), Some(&sample_profile()));

        // corrupt file → open degrades to empty, does not panic
        std::fs::write(&path, "{not json").unwrap();
        assert!(ProfileCache::open(&path).is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_merges_entries_from_concurrent_writers() {
        let dir = std::env::temp_dir().join(format!("cfp-cache-merge-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("profiles.json");

        // two cache handles opened from the same (empty) file, as two
        // processes would; each adds a different entry and saves
        let mut a = ProfileCache::open(&path);
        let mut b = ProfileCache::open(&path);
        let key_a = CacheKey { fingerprint: "fpA".into(), platform: "sig".into(), parts: 2 };
        let key_b = CacheKey { fingerprint: "fpB".into(), platform: "sig".into(), parts: 2 };
        a.put_segment(key_a.clone(), sample_profile());
        a.save().unwrap();
        b.put_segment(key_b.clone(), sample_profile());
        b.save().unwrap(); // must fold A's entry back in, not drop it

        let merged = ProfileCache::open(&path);
        assert_eq!(merged.num_segments(), 2);
        let mut merged = merged;
        assert!(merged.get_segment(&key_a).is_some());
        assert!(merged.get_segment(&key_b).is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_rebases_young_process_stamps_above_the_disk_clock() {
        let dir = std::env::temp_dir().join(format!("cfp-cache-rebase-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("profiles.json");
        let key = |s: &str| CacheKey {
            fingerprint: s.to_string(),
            platform: "sig".into(),
            parts: 2,
        };

        // both handles open the same (empty) file, as two processes would
        let mut a = ProfileCache::open(&path);
        let mut b = ProfileCache::open(&path);
        // long-lived writer A inflates the shared clock with many bumps
        a.put_segment(key("a0"), sample_profile());
        a.put_segment(key("a1"), sample_profile());
        for _ in 0..100 {
            assert!(a.get_segment(&key("a0")).is_some());
            assert!(a.get_segment(&key("a1")).is_some());
        }
        a.save().unwrap();
        // fresh writer B's own entry carries a tiny local stamp; the merge
        // must rebase it above the disk clock, not evict it as ancient
        b.set_max_entries(Some(2));
        b.put_segment(key("fresh"), sample_profile());
        b.save().unwrap();

        let mut merged = ProfileCache::open(&path);
        assert_eq!(merged.num_segments(), 2, "bound holds");
        assert!(
            merged.get_segment(&key("fresh")).is_some(),
            "the young writer's own entry survives the cross-clock merge"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_rebase_leaves_untouched_warm_entries_stale() {
        let dir = std::env::temp_dir().join(format!("cfp-cache-stale-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("profiles.json");
        let key = |s: &str| CacheKey {
            fingerprint: s.to_string(),
            platform: "sig".into(),
            parts: 2,
        };

        // seed the file with two old entries (shared-timeline stamps 1, 2)
        let mut seed = ProfileCache::open(&path);
        seed.put_segment(key("old0"), sample_profile());
        seed.put_segment(key("old1"), sample_profile());
        seed.save().unwrap();

        // A opens warm (loading the old entries, touching neither)...
        let mut a = ProfileCache::open(&path);
        // ...while concurrent writer B adds two genuinely fresh entries
        let mut b = ProfileCache::open(&path);
        b.put_segment(key("b0"), sample_profile());
        b.put_segment(key("b1"), sample_profile());
        b.save().unwrap();
        // A profiles one new segment and saves under a bound: the rebase
        // must lift only A's own draw past the disk clock — the loaded
        // and untouched old entries stay stale and are evicted before
        // B's fresh ones
        a.set_max_entries(Some(3));
        a.put_segment(key("a_new"), sample_profile());
        a.save().unwrap();

        let mut merged = ProfileCache::open(&path);
        assert_eq!(merged.num_segments(), 3, "bound holds");
        assert!(merged.get_segment(&key("a_new")).is_some(), "own draw survives");
        assert!(merged.get_segment(&key("b0")).is_some(), "concurrent fresh survives");
        assert!(merged.get_segment(&key("b1")).is_some(), "concurrent fresh survives");
        assert!(merged.get_segment(&key("old0")).is_none(), "untouched stale evicted");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn eviction_respects_the_entry_bound() {
        let dir = std::env::temp_dir().join(format!("cfp-cache-evict-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("profiles.json");

        let mut c = ProfileCache::open(&path);
        c.set_max_entries(Some(3));
        for i in 0..5 {
            let key = CacheKey {
                fingerprint: format!("fp{i}"),
                platform: "sig".into(),
                parts: 2,
            };
            c.put_segment(key, sample_profile());
        }
        c.put_reshard("fpA", "fpB", "sig", 2, sample_table());
        c.save().unwrap();

        let reloaded = ProfileCache::open(&path);
        assert_eq!(reloaded.num_segments() + reloaded.num_reshards(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn eviction_is_least_recently_used() {
        let dir = std::env::temp_dir().join(format!("cfp-cache-lru-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("profiles.json");

        let key = |i: usize| CacheKey {
            fingerprint: format!("fp{i}"),
            platform: "sig".into(),
            parts: 2,
        };
        let mut c = ProfileCache::open(&path);
        c.set_max_entries(Some(2));
        c.put_segment(key(0), sample_profile());
        c.put_segment(key(1), sample_profile());
        c.put_segment(key(2), sample_profile());
        // touch the oldest entry so it becomes the most recent
        assert!(c.get_segment(&key(0)).is_some());
        c.save().unwrap();

        let mut reloaded = ProfileCache::open(&path);
        assert_eq!(reloaded.num_segments(), 2);
        assert!(reloaded.get_segment(&key(0)).is_some(), "recently used survives");
        assert!(reloaded.get_segment(&key(2)).is_some(), "newest survives");
        assert!(reloaded.get_segment(&key(1)).is_none(), "LRU entry evicted");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn save_lock_acquire_release_and_timeout() {
        let dir = std::env::temp_dir().join(format!("cfp-cache-lock-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let target = dir.join("profiles.json");
        let lock_file = save_lock_path(&target);

        let held = acquire_save_lock(&target, LOCK_STALE, LOCK_WAIT).expect("uncontended");
        assert!(lock_file.exists(), "lock file created");
        // a second saver times out while the lock is fresh and held
        let t0 = Instant::now();
        let contended =
            acquire_save_lock(&target, Duration::from_secs(10), Duration::from_millis(40));
        assert!(contended.is_none(), "fresh lock must not be stolen");
        assert!(t0.elapsed() >= Duration::from_millis(40), "waited for the deadline");
        drop(held);
        assert!(!lock_file.exists(), "lock released on drop");
        // release makes reacquisition immediate
        assert!(acquire_save_lock(&target, LOCK_STALE, LOCK_WAIT).is_some());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn stale_save_lock_is_taken_over() {
        let dir = std::env::temp_dir().join(format!("cfp-cache-stale-lock-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let target = dir.join("profiles.json");
        // a crashed saver left its lock behind
        std::fs::write(save_lock_path(&target), "42\n").unwrap();
        std::thread::sleep(Duration::from_millis(60));
        let taken =
            acquire_save_lock(&target, Duration::from_millis(20), Duration::from_millis(200));
        assert!(taken.is_some(), "a stale lock must be taken over");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dead_holder_lock_is_taken_over_and_double_release_is_harmless() {
        // a holder that dies without unlinking: simulate by leaking the
        // guard, so the lock file sits there with a real token in it
        let dir = std::env::temp_dir().join(format!("cfp-cache-dead-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let target = dir.join("profiles.json");
        let lock_file = save_lock_path(&target);

        let dead = acquire_save_lock(&target, LOCK_STALE, LOCK_WAIT).expect("uncontended");
        let dead_path = dead.path.clone();
        let dead_token = dead.token.clone();
        std::mem::forget(dead); // the "crash": Drop never runs

        // within the stale window the carcass is honored, not stolen
        let early =
            acquire_save_lock(&target, Duration::from_secs(10), Duration::from_millis(40));
        assert!(early.is_none(), "fresh-looking carcass must not be stolen early");
        assert!(lock_file.exists());

        // past the stale window the takeover succeeds
        std::thread::sleep(Duration::from_millis(30));
        let new_holder =
            acquire_save_lock(&target, Duration::from_millis(20), Duration::from_millis(200))
                .expect("stale dead-holder lock must be taken over");

        // the dead holder's guard resurfacing (e.g. a paused thread
        // finally dropping) must not release the new holder's lock: the
        // token check in Drop makes the double release a no-op
        drop(SaveLock { path: dead_path, token: dead_token });
        assert!(lock_file.exists(), "new holder's lock survives the dead guard's drop");

        drop(new_holder);
        assert!(!lock_file.exists(), "real holder still releases normally");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fresh_lock_survives_a_mistimed_stale_claim() {
        // the takeover race: a racer probes staleness, the stale lock is
        // cleared and a NEW holder acquires, and only then does the
        // racer's rename land — grabbing the live lock. claim_stale_lock
        // must detect the fresh mtime and put the lock back.
        let dir = std::env::temp_dir().join(format!("cfp-cache-claim-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let target = dir.join("profiles.json");
        let lock_file = save_lock_path(&target);

        let live = acquire_save_lock(&target, LOCK_STALE, LOCK_WAIT).expect("uncontended");
        let body = std::fs::read_to_string(&lock_file).unwrap();
        assert!(!claim_stale_lock(&lock_file, Duration::from_secs(10), "racer.0"));
        assert!(lock_file.exists(), "live lock restored after the mistimed claim");
        assert_eq!(
            std::fs::read_to_string(&lock_file).unwrap(),
            body,
            "restored lock still carries the live holder's token"
        );
        drop(live);
        assert!(!lock_file.exists(), "live holder's release still works");

        // and a genuinely stale carcass is still cleared by the same path
        std::fs::write(&lock_file, "99\n").unwrap();
        std::thread::sleep(Duration::from_millis(30));
        assert!(claim_stale_lock(&lock_file, Duration::from_millis(20), "racer.1"));
        assert!(!lock_file.exists(), "stale carcass removed");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn racing_savers_drop_no_entries() {
        // ROADMAP open item "concurrent cache savers", closed by the lock
        // protocol: N savers race open→put→save on one file; the locked
        // read-merge-rename must keep every saver's entry.
        let dir = std::env::temp_dir().join(format!("cfp-cache-race-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("profiles.json");
        const N: usize = 8;
        std::thread::scope(|s| {
            for i in 0..N {
                let path = path.clone();
                s.spawn(move || {
                    let mut c = ProfileCache::open(&path);
                    let key = CacheKey {
                        fingerprint: format!("fp{i}"),
                        platform: "sig".into(),
                        parts: 2,
                    };
                    c.put_segment(key, sample_profile());
                    c.save().unwrap();
                });
            }
        });
        let merged = ProfileCache::open(&path);
        assert_eq!(merged.num_segments(), N, "every racing saver's entry survives");
        assert!(!save_lock_path(&path).exists(), "no lock left behind");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shared_cache_serves_concurrent_handles() {
        let shared = SharedProfileCache::in_memory();
        std::thread::scope(|s| {
            for i in 0..4 {
                let shared = shared.clone();
                s.spawn(move || {
                    let mut h = shared.handle();
                    let key = CacheKey {
                        fingerprint: format!("fp{i}"),
                        platform: "sig".into(),
                        parts: 2,
                    };
                    assert!(h.get_segment(&key).is_none());
                    h.put_segment(key.clone(), &sample_profile());
                    assert_eq!(h.get_segment(&key), Some(sample_profile()));
                    h.put_reshard("a", &format!("b{i}"), "sig", 2, &sample_table());
                });
            }
        });
        assert_eq!(shared.num_segments(), 4);
        assert_eq!(shared.num_reshards(), 4);
        // a late handle sees every thread's entries (the serve-path reuse)
        let mut h = shared.handle();
        for i in 0..4 {
            let key = CacheKey {
                fingerprint: format!("fp{i}"),
                platform: "sig".into(),
                parts: 2,
            };
            assert!(h.get_segment(&key).is_some(), "fp{i} shared across handles");
        }
    }

    #[test]
    fn cache_handle_none_is_inert() {
        let mut h = CacheHandle::from_option(None);
        assert!(h.is_none());
        let key = CacheKey { fingerprint: "fp".into(), platform: "sig".into(), parts: 2 };
        assert!(h.get_segment(&key).is_none());
        h.put_segment(key.clone(), &sample_profile());
        assert!(h.reborrow().get_segment(&key).is_none());
    }

    #[test]
    fn unbounded_cache_never_evicts_and_stamps_round_trip() {
        let mut c = ProfileCache::in_memory();
        for i in 0..10 {
            let key = CacheKey {
                fingerprint: format!("fp{i}"),
                platform: "sig".into(),
                parts: 2,
            };
            c.put_segment(key, sample_profile());
        }
        c.evict_to_cap(); // no bound → no-op
        assert_eq!(c.num_segments(), 10);
        let parsed =
            ProfileCache::from_json(&Json::parse(&c.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(parsed.clock, c.clock, "clock persists");
        assert_eq!(parsed.to_json().to_string(), c.to_json().to_string());
    }
}
