//! Profile database: T_C, T_P, M per (unique segment, config) and T_R per
//! (unique segment pair, config pair) — the paper's §4.2 deliverables.

use std::collections::HashMap;

use super::config::SegmentConfig;

/// Profiles of one unique segment across its config space.
#[derive(Clone, Debug, Default)]
pub struct SegmentProfile {
    pub configs: Vec<SegmentConfig>,
    /// communication kernel time per config, µs (T_C)
    pub t_c_us: Vec<f64>,
    /// computation kernel time per config, µs (T_P)
    pub t_p_us: Vec<f64>,
    /// peak memory per device per config, bytes (M)
    pub mem_bytes: Vec<u64>,
    /// symbolic (volume-model) cost per config — the Alpa baseline's view
    pub symbolic_volume: Vec<u64>,
    /// outgoing boundary-tensor sharding per config (for T_R)
    pub boundary_out: Vec<crate::spmd::ShardState>,
    /// required incoming boundary sharding per config
    pub boundary_in: Vec<crate::spmd::ShardState>,
}

impl SegmentProfile {
    pub fn total_us(&self, cfg: usize) -> f64 {
        self.t_c_us[cfg] + self.t_p_us[cfg]
    }

    pub fn best_config(&self) -> usize {
        (0..self.configs.len())
            .min_by(|&a, &b| self.total_us(a).partial_cmp(&self.total_us(b)).unwrap())
            .unwrap_or(0)
    }
}

/// Resharding costs between two unique segments: t_r[from_cfg][to_cfg] µs.
/// `programs` counts the *distinct* boundary-state pairs actually profiled
/// (§5.5: "3×3 = 9 groups of communication primitives"), which is what the
/// profile space is charged for — the full table is a lookup expansion.
#[derive(Clone, Debug, Default)]
pub struct ReshardTable {
    pub t_r_us: Vec<Vec<f64>>,
    /// symbolic (volume-model) bytes per config pair — what Alpa's cost
    /// model charges for the same boundary (Partial→Split priced as a full
    /// AllReduce: the §5.7 8× overestimate)
    pub sym_vol: Vec<Vec<u64>>,
    pub programs: usize,
}

/// Estimated real-testbed overheads (paper Fig. 12) plus our wall-clock.
#[derive(Clone, Debug, Default)]
pub struct ProfilerStats {
    pub programs_compiled: usize,
    pub programs_profiled: usize,
    /// estimated serial XLA-backend compile time, seconds
    pub est_compile_s: f64,
    /// estimated profiling run time (5 warmup + 10 timed runs), seconds
    pub est_profile_s: f64,
    /// estimate with §4.3 optimizations (parallel compile, overlap,
    /// dynamic time limit), seconds
    pub est_optimized_s: f64,
    /// our actual analysis wall-clock, seconds
    pub wall_s: f64,
}

#[derive(Clone, Debug, Default)]
pub struct ProfileDb {
    /// indexed by unique segment id
    pub segments: Vec<SegmentProfile>,
    /// (from_unique, to_unique) → reshard table
    pub reshard: HashMap<(usize, usize), ReshardTable>,
    pub stats: ProfilerStats,
}

impl ProfileDb {
    pub fn reshard_us(&self, from_u: usize, from_cfg: usize, to_u: usize, to_cfg: usize) -> f64 {
        self.reshard
            .get(&(from_u, to_u))
            .and_then(|t| t.t_r_us.get(from_cfg).and_then(|row| row.get(to_cfg)))
            .copied()
            .unwrap_or(0.0)
    }

    /// Total programs that a real testbed would compile+profile (Eq. 7).
    pub fn profile_space(&self) -> usize {
        let seg: usize = self.segments.iter().map(|s| s.configs.len()).sum();
        let rs: usize = self.reshard.values().map(|t| t.programs).sum();
        seg + rs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmd::ShardState;

    #[test]
    fn best_config_picks_minimum() {
        let p = SegmentProfile {
            configs: vec![SegmentConfig { strategy: vec![0] }, SegmentConfig { strategy: vec![1] }],
            t_c_us: vec![10.0, 1.0],
            t_p_us: vec![5.0, 5.0],
            mem_bytes: vec![0, 0],
            symbolic_volume: vec![0, 0],
            boundary_out: vec![ShardState::Replicated; 2],
            boundary_in: vec![ShardState::Replicated; 2],
        };
        assert_eq!(p.best_config(), 1);
    }

    #[test]
    fn reshard_lookup_defaults_zero() {
        let db = ProfileDb::default();
        assert_eq!(db.reshard_us(0, 0, 1, 0), 0.0);
    }
}
