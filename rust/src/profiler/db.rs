//! Profile database: T_C, T_P, M per (unique segment, config) and T_R per
//! (unique segment pair, config pair) — the paper's §4.2 deliverables.

use std::collections::HashMap;

use crate::util::Json;

use super::config::SegmentConfig;

/// Profiles of one unique segment across its config space.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SegmentProfile {
    pub configs: Vec<SegmentConfig>,
    /// communication kernel time per config, µs (T_C)
    pub t_c_us: Vec<f64>,
    /// computation kernel time per config, µs (T_P)
    pub t_p_us: Vec<f64>,
    /// peak memory per device per config, bytes (M)
    pub mem_bytes: Vec<u64>,
    /// retained forward-activation bytes per device per config (whole
    /// batch) — the share of `mem_bytes` that 1F1B multiplies by the
    /// in-flight microbatch count and checkpointing can trade away
    pub act_bytes: Vec<u64>,
    /// bytes retained per config when the segment is checkpointed: the
    /// local footprint of the incoming boundary activation (the
    /// recompute-on-backward stash)
    pub ckpt_bytes: Vec<u64>,
    /// forward-pass time per config, µs — the price of recomputing the
    /// segment's activations during backward
    pub t_fwd_us: Vec<f64>,
    /// symbolic (volume-model) cost per config — the Alpa baseline's view
    pub symbolic_volume: Vec<u64>,
    /// outgoing boundary-tensor sharding per config (for T_R)
    pub boundary_out: Vec<crate::spmd::ShardState>,
    /// required incoming boundary sharding per config
    pub boundary_in: Vec<crate::spmd::ShardState>,
}

impl SegmentProfile {
    pub fn total_us(&self, cfg: usize) -> f64 {
        self.t_c_us[cfg] + self.t_p_us[cfg]
    }

    pub fn best_config(&self) -> usize {
        (0..self.configs.len())
            .min_by(|&a, &b| self.total_us(a).partial_cmp(&self.total_us(b)).unwrap())
            .unwrap_or(0)
    }
}

/// Resharding costs between two unique segments: t_r[from_cfg][to_cfg] µs.
/// `programs` counts the *distinct* boundary-state pairs actually profiled
/// (§5.5: "3×3 = 9 groups of communication primitives"), which is what the
/// profile space is charged for — the full table is a lookup expansion.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ReshardTable {
    pub t_r_us: Vec<Vec<f64>>,
    /// symbolic (volume-model) bytes per config pair — what Alpa's cost
    /// model charges for the same boundary (Partial→Split priced as a full
    /// AllReduce: the §5.7 8× overestimate)
    pub sym_vol: Vec<Vec<u64>>,
    pub programs: usize,
}

/// Estimated real-testbed overheads (paper Fig. 12) plus our wall-clock.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ProfilerStats {
    pub programs_compiled: usize,
    pub programs_profiled: usize,
    /// estimated serial XLA-backend compile time, seconds
    pub est_compile_s: f64,
    /// estimated profiling run time (5 warmup + 10 timed runs), seconds
    pub est_profile_s: f64,
    /// estimate with §4.3 optimizations (parallel compile, overlap,
    /// dynamic time limit), seconds
    pub est_optimized_s: f64,
    /// our actual analysis wall-clock, seconds
    pub wall_s: f64,
    /// unique segments served from the persistent profile cache
    pub cache_hits: usize,
    /// unique segments actually profiled this run
    pub cache_misses: usize,
    /// wall-clock seconds spent lowering+simulating configs (exactly 0.0
    /// on a fully warm cache — the MetricsProfiling phase was skipped)
    pub profile_wall_s: f64,
}

#[derive(Clone, Debug, Default, PartialEq)]
pub struct ProfileDb {
    /// indexed by unique segment id
    pub segments: Vec<SegmentProfile>,
    /// (from_unique, to_unique) → reshard table
    pub reshard: HashMap<(usize, usize), ReshardTable>,
    pub stats: ProfilerStats,
}

impl ProfileDb {
    pub fn reshard_us(&self, from_u: usize, from_cfg: usize, to_u: usize, to_cfg: usize) -> f64 {
        self.reshard
            .get(&(from_u, to_u))
            .and_then(|t| t.t_r_us.get(from_cfg).and_then(|row| row.get(to_cfg)))
            .copied()
            .unwrap_or(0.0)
    }

    /// Total programs that a real testbed would compile+profile (Eq. 7).
    pub fn profile_space(&self) -> usize {
        let seg: usize = self.segments.iter().map(|s| s.configs.len()).sum();
        let rs: usize = self.reshard.values().map(|t| t.programs).sum();
        seg + rs
    }

    /// Full-database JSON snapshot (experiment logs, debugging, and the
    /// save→load round-trip property test). The persistent cache stores
    /// per-segment entries instead — see [`super::cache::ProfileCache`].
    pub fn to_json(&self) -> Json {
        let stats = &self.stats;
        // sorted for deterministic output (HashMap iteration order is not)
        let mut pairs: Vec<(&(usize, usize), &ReshardTable)> = self.reshard.iter().collect();
        pairs.sort_by_key(|(k, _)| **k);
        let reshard = pairs
            .into_iter()
            .map(|(&(a, b), t)| {
                Json::obj(vec![
                    ("from", Json::num(a as f64)),
                    ("to", Json::num(b as f64)),
                    ("table", super::cache::reshard_table_to_json(t)),
                ])
            })
            .collect();
        Json::obj(vec![
            (
                "segments",
                Json::Arr(
                    self.segments.iter().map(super::cache::segment_profile_to_json).collect(),
                ),
            ),
            ("reshard", Json::Arr(reshard)),
            (
                "stats",
                Json::obj(vec![
                    ("programs_compiled", Json::num(stats.programs_compiled as f64)),
                    ("programs_profiled", Json::num(stats.programs_profiled as f64)),
                    ("est_compile_s", Json::num(stats.est_compile_s)),
                    ("est_profile_s", Json::num(stats.est_profile_s)),
                    ("est_optimized_s", Json::num(stats.est_optimized_s)),
                    ("wall_s", Json::num(stats.wall_s)),
                    ("cache_hits", Json::num(stats.cache_hits as f64)),
                    ("cache_misses", Json::num(stats.cache_misses as f64)),
                    ("profile_wall_s", Json::num(stats.profile_wall_s)),
                ]),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Option<ProfileDb> {
        let segments = j
            .get("segments")?
            .as_arr()?
            .iter()
            .map(super::cache::segment_profile_from_json)
            .collect::<Option<Vec<_>>>()?;
        let mut reshard = HashMap::new();
        for e in j.get("reshard")?.as_arr()? {
            let a = e.get("from")?.as_u64()? as usize;
            let b = e.get("to")?.as_u64()? as usize;
            reshard.insert((a, b), super::cache::reshard_table_from_json(e.get("table")?)?);
        }
        let s = j.get("stats")?;
        let stats = ProfilerStats {
            programs_compiled: s.get("programs_compiled")?.as_u64()? as usize,
            programs_profiled: s.get("programs_profiled")?.as_u64()? as usize,
            est_compile_s: s.get("est_compile_s")?.as_f64()?,
            est_profile_s: s.get("est_profile_s")?.as_f64()?,
            est_optimized_s: s.get("est_optimized_s")?.as_f64()?,
            wall_s: s.get("wall_s")?.as_f64()?,
            cache_hits: s.get("cache_hits")?.as_u64()? as usize,
            cache_misses: s.get("cache_misses")?.as_u64()? as usize,
            profile_wall_s: s.get("profile_wall_s")?.as_f64()?,
        };
        Some(ProfileDb { segments, reshard, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmd::ShardState;

    #[test]
    fn best_config_picks_minimum() {
        let p = SegmentProfile {
            configs: vec![SegmentConfig { strategy: vec![0] }, SegmentConfig { strategy: vec![1] }],
            t_c_us: vec![10.0, 1.0],
            t_p_us: vec![5.0, 5.0],
            mem_bytes: vec![0, 0],
            act_bytes: vec![0, 0],
            ckpt_bytes: vec![0, 0],
            t_fwd_us: vec![0.0, 0.0],
            symbolic_volume: vec![0, 0],
            boundary_out: vec![ShardState::Replicated; 2],
            boundary_in: vec![ShardState::Replicated; 2],
        };
        assert_eq!(p.best_config(), 1);
    }

    #[test]
    fn reshard_lookup_defaults_zero() {
        let db = ProfileDb::default();
        assert_eq!(db.reshard_us(0, 0, 1, 0), 0.0);
    }

    #[test]
    fn db_json_round_trip_is_exact() {
        let mut db = ProfileDb::default();
        db.segments.push(SegmentProfile {
            configs: vec![SegmentConfig { strategy: vec![0] }, SegmentConfig { strategy: vec![1] }],
            t_c_us: vec![10.125, 1.0],
            t_p_us: vec![5.5, 5.0078125],
            mem_bytes: vec![1 << 33, 7],
            act_bytes: vec![1 << 30, 3],
            ckpt_bytes: vec![1 << 20, 1],
            t_fwd_us: vec![3.375, 1.5],
            symbolic_volume: vec![3, 0],
            boundary_out: vec![ShardState::Split(1); 2],
            boundary_in: vec![ShardState::Partial; 2],
        });
        db.reshard.insert(
            (0, 0),
            ReshardTable {
                t_r_us: vec![vec![0.0, 2.25], vec![3.5, 0.0]],
                sym_vol: vec![vec![0, 8], vec![8, 0]],
                programs: 2,
            },
        );
        db.stats = ProfilerStats {
            programs_compiled: 4,
            programs_profiled: 4,
            est_compile_s: 1.25,
            est_profile_s: 0.5,
            est_optimized_s: 0.75,
            wall_s: 0.0625,
            cache_hits: 1,
            cache_misses: 2,
            profile_wall_s: 0.03125,
        };
        let text = db.to_json().to_string_pretty();
        let parsed = ProfileDb::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, db);
    }
}
