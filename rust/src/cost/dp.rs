//! The span-DP engines behind [`super::search_span`] and
//! [`super::search_span_mem`], all running on a [`SearchCtx`]:
//!
//! * **Scalar lane** (`scalar_*`) — the unconstrained (`mem_cap = None`)
//!   DP. Without a cap the per-(position, config) Pareto set of the
//!   reference DP collapses to its min-time point (every frontier is
//!   time-sorted and the terminal rule is strict-min-time, so only each
//!   set's head can ever be an ancestor of the winner); the state is one
//!   `(time, mem, backpointer)` scalar per config, selected by the
//!   reference's exact tie order — lexicographic `(time, mem)`, earliest
//!   predecessor config on full ties. On top of it sits the
//!   **steady-state splice**: for runs of identical adjacent transitions
//!   (same unique pair, same reshard matrix), once two consecutive full
//!   steps produce the *same* backpointer vector and *uniform* per-config
//!   deltas, every further step of the run is the same min-plus map — the
//!   argmin is invariant under a uniform shift — so the run is
//!   fast-forwarded with the fixed backpointers at `O(C)` per position
//!   instead of `O(C²)`. Values are still produced by replaying the
//!   reference's own float additions (never by multiplying the delta),
//!   and every `VERIFY_EVERY` positions (plus the last of each run) a
//!   full argmin step cross-checks the spliced state; a mismatch rolls
//!   back to the last verified position and recomputes per-position.
//! * **Pareto lane** (`pareto_*`) — the memory-capped DP, identical in
//!   values and tie-breaks to the reference ([`super::oracle`]), with the
//!   hash lookups replaced by dense matrix reads and the per-(position,
//!   config) candidate buffer reused across the whole span.
//! * **Memory lane** (`mem_*`) — the (config × remat) frontier DP of
//!   `search_span_mem`, same treatment: dense transitions, precomputed
//!   remat frontiers ([`crate::memory::RematTable`]), in-place pruning,
//!   one scratch buffer per span.
//!
//! Every lane is *prefix-closed*: the state at position `i` does not
//! depend on where the span ends, which is what lets
//! [`super::sweep`] answer every `[lo, hi)` from one forward pass.
//!
//! Residual float caveat (documented in ARCHITECTURE.md "plan search"):
//! a ulp-scale collision between two independently-computed candidate
//! sums could give the reference a lower-memory tied ancestor the
//! heads-only scalar state never tracks, or slip an argmin flip past a
//! splice checkpoint (which cross-checks one step from the spliced
//! state, not the whole window). Both require exact f64 ties between
//! unrelated sums — measure-zero on profiled values, impossible in
//! exact-arithmetic regimes, and plan *time* is unaffected either way;
//! the property suite pins full bit-identity on randomized inputs.

use crate::memory::{RecomputeSpec, SpanFootprint, SpanMemPlan};
use crate::obs::Counter;

use super::ctx::SearchCtx;
use super::Plan;

pub(super) const FRONTIER_CAP: usize = 24;
pub(super) const MEM_FRONTIER_CAP: usize = 16;
/// Full-argmin cross-check cadence inside a steady-state splice.
const VERIFY_EVERY: usize = 32;

/// Test instrumentation: positions fast-forwarded by the splice, across
/// the whole process (tests assert it *increases*, never its absolute
/// value — suites run concurrently).
#[cfg(test)]
pub(super) static SPLICED_STEPS: std::sync::atomic::AtomicUsize =
    std::sync::atomic::AtomicUsize::new(0);

// ---------------------------------------------------------------- scalar lane

/// One unconstrained DP state: min-(time, mem) prefix ending at a config,
/// with the predecessor config it came through.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(super) struct Scalar {
    pub time: f64,
    pub mem: u64,
    pub bp: u32,
}

/// Signature of one repeated full step, for steady-state detection.
struct StepSig {
    dt: f64,
    dm: u64,
    bp: Vec<u32>,
}

fn scalar_first(ctx: &SearchCtx, pos: usize) -> Vec<Scalar> {
    let o = ctx.off[ctx.uid[pos]];
    (0..ctx.ncfg[ctx.uid[pos]])
        .map(|c| Scalar { time: ctx.time[o + c], mem: ctx.mem[o + c], bp: u32::MAX })
        .collect()
}

/// One full argmin step into `pos`. Candidate values replay the
/// reference's float ops exactly: `(prev + tr) + seg_t`.
fn scalar_step(ctx: &SearchCtx, prev: &[Scalar], pos: usize, out: &mut Vec<Scalar>) {
    let u = ctx.uid[pos];
    let o = ctx.off[u];
    let cc = ctx.ncfg[u];
    let mat = &ctx.mats[ctx.step_mat[pos]];
    out.clear();
    for c in 0..cc {
        let seg_t = ctx.time[o + c];
        let seg_m = ctx.mem[o + c];
        let mut best = Scalar { time: f64::INFINITY, mem: u64::MAX, bp: 0 };
        for (p, pp) in prev.iter().enumerate() {
            let t = pp.time + mat[p * cc + c] + seg_t;
            let m = pp.mem + seg_m;
            if t < best.time || (t == best.time && m < best.mem) {
                best = Scalar { time: t, mem: m, bp: p as u32 };
            }
        }
        out.push(best);
    }
}

/// One spliced step: the argmin is pinned to `bp`, the values replay the
/// same additions the full step would have performed through it.
fn scalar_fast_step(
    ctx: &SearchCtx,
    prev: &[Scalar],
    pos: usize,
    bp: &[u32],
    out: &mut Vec<Scalar>,
) {
    let u = ctx.uid[pos];
    let o = ctx.off[u];
    let cc = ctx.ncfg[u];
    let mat = &ctx.mats[ctx.step_mat[pos]];
    out.clear();
    for c in 0..cc {
        let p = bp[c] as usize;
        let pp = prev[p];
        out.push(Scalar {
            time: pp.time + mat[p * cc + c] + ctx.time[o + c],
            mem: pp.mem + ctx.mem[o + c],
            bp: bp[c],
        });
    }
}

/// Per-position scalar states of the span `[lo, hi)` — the shared
/// substrate of the single-span search (which backtracks from any
/// position) and the span sweeps (which read a terminal per position).
/// The returned vector is truncated at the first position with an empty
/// config space (no plan can cross it); a full-length result covers the
/// whole span.
pub(super) fn scalar_states(ctx: &SearchCtx, lo: usize, hi: usize) -> Vec<Vec<Scalar>> {
    debug_assert!(lo <= hi && hi <= ctx.len());
    let n = hi - lo;
    let mut states: Vec<Vec<Scalar>> = Vec::with_capacity(n);
    if n == 0 || ctx.ncfg[ctx.uid[lo]] == 0 {
        return states;
    }
    states.push(scalar_first(ctx, lo));
    let mut sig: Option<StepSig> = None;
    let mut steady: Option<Vec<u32>> = None;
    let mut last_verified = 0usize;
    let mut scratch: Vec<Scalar> = Vec::new();
    // local tallies, flushed once at the end (keeps the disabled-trace
    // cost of this hot loop at plain u64 adds)
    let (mut full_steps, mut spliced, mut rollbacks) = (0u64, 0u64, 0u64);
    for i in 1..n {
        let pos = lo + i;
        if ctx.ncfg[ctx.uid[pos]] == 0 {
            break;
        }
        // a repeated step needs BOTH transitions inside the span
        let repeated = i >= 2 && ctx.repeated_step(pos);
        if !repeated {
            sig = None;
            steady = None;
            last_verified = i - 1;
        }
        if let Some(bp) = steady.clone() {
            scalar_fast_step(ctx, &states[i - 1], pos, &bp, &mut scratch);
            spliced += 1;
            #[cfg(test)]
            SPLICED_STEPS.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            let run_ends = i + 1 >= n
                || ctx.ncfg[ctx.uid[pos + 1]] == 0
                || !ctx.repeated_step(pos + 1);
            if run_ends || i - last_verified >= VERIFY_EVERY {
                let mut full = Vec::new();
                scalar_step(ctx, &states[i - 1], pos, &mut full);
                full_steps += 1;
                if full == scratch {
                    states.push(full);
                } else {
                    // float rounding broke the splice invariant:
                    // recompute the unverified tail per-position
                    steady = None;
                    sig = None;
                    rollbacks += 1;
                    for j in (last_verified + 1)..i {
                        let mut redo = Vec::new();
                        scalar_step(ctx, &states[j - 1], lo + j, &mut redo);
                        full_steps += 1;
                        states[j] = redo;
                    }
                    let mut redo = Vec::new();
                    scalar_step(ctx, &states[i - 1], pos, &mut redo);
                    full_steps += 1;
                    states.push(redo);
                }
                last_verified = i;
            } else {
                states.push(scratch.clone());
            }
            continue;
        }
        let mut cur = Vec::new();
        scalar_step(ctx, &states[i - 1], pos, &mut cur);
        full_steps += 1;
        if repeated {
            // detection: two consecutive repeated steps with the same
            // backpointers and uniform (time, mem) deltas — from there the
            // argmin is shift-invariant and the run can be spliced
            let prev = &states[i - 1];
            let dt = cur[0].time - prev[0].time;
            let dm = cur[0].mem.wrapping_sub(prev[0].mem);
            let uniform = cur
                .iter()
                .zip(prev.iter())
                .all(|(c, p)| c.time - p.time == dt && c.mem.wrapping_sub(p.mem) == dm);
            if uniform {
                let bp: Vec<u32> = cur.iter().map(|s| s.bp).collect();
                if let Some(s) = &sig {
                    if s.dt == dt && s.dm == dm && s.bp == bp {
                        steady = Some(bp.clone());
                    }
                }
                sig = Some(StepSig { dt, dm, bp });
            } else {
                sig = None;
            }
        }
        last_verified = i;
        states.push(cur);
    }
    if ctx.trace.is_enabled() {
        ctx.trace.count(Counter::ScalarSteps, full_steps);
        ctx.trace.count(Counter::ScalarSpliced, spliced);
        ctx.trace.count(Counter::ScalarRollbacks, rollbacks);
    }
    states
}

/// Best terminal time of a scalar state vector (the reference's strict
/// min-time, earliest-config rule).
pub(super) fn scalar_best_time(states: &[Scalar]) -> Option<f64> {
    let mut best: Option<f64> = None;
    for s in states {
        if best.map_or(true, |b| s.time < b) {
            best = Some(s.time);
        }
    }
    best
}

/// Unconstrained min-time plan for `[lo, hi)` via the scalar lane.
pub(super) fn scalar_plan(ctx: &SearchCtx, lo: usize, hi: usize) -> Option<Plan> {
    let n = hi - lo;
    if n == 0 {
        return None;
    }
    let states = scalar_states(ctx, lo, hi);
    if states.len() < n {
        return None;
    }
    let last = &states[n - 1];
    let mut best: Option<usize> = None;
    for (c, s) in last.iter().enumerate() {
        if best.map_or(true, |b| s.time < last[b].time) {
            best = Some(c);
        }
    }
    let mut c = best?;
    let terminal = last[c];
    let mut choice = vec![0usize; n];
    for i in (0..n).rev() {
        choice[i] = c;
        if i > 0 {
            c = states[i][c].bp as usize;
        }
    }
    Some(Plan { choice, time_us: terminal.time, mem_bytes: terminal.mem })
}

// ---------------------------------------------------------------- pareto lane

/// Pareto point with backpointer (the capped DP's state).
#[derive(Clone, Copy, Debug, PartialEq)]
pub(super) struct Point {
    pub time: f64,
    pub mem: u64,
    pub prev_cfg: usize,
    pub prev_idx: usize,
}

pub(super) fn pareto_first(ctx: &SearchCtx, pos: usize, cap: u64) -> Vec<Vec<Point>> {
    let o = ctx.off[ctx.uid[pos]];
    (0..ctx.ncfg[ctx.uid[pos]])
        .map(|c| {
            let mem = ctx.mem[o + c];
            if mem <= cap {
                vec![Point {
                    time: ctx.time[o + c],
                    mem,
                    prev_cfg: usize::MAX,
                    prev_idx: usize::MAX,
                }]
            } else {
                Vec::new()
            }
        })
        .collect()
}

/// One capped Pareto step into `pos`. `scratch` is the candidate buffer
/// reused across every (position, config) of a span.
pub(super) fn pareto_step(
    ctx: &SearchCtx,
    prev: &[Vec<Point>],
    pos: usize,
    cap: u64,
    scratch: &mut Vec<Point>,
) -> Vec<Vec<Point>> {
    let u = ctx.uid[pos];
    let o = ctx.off[u];
    let cc = ctx.ncfg[u];
    let mat = &ctx.mats[ctx.step_mat[pos]];
    let mut cur: Vec<Vec<Point>> = Vec::with_capacity(cc);
    let (mut generated, mut kept) = (0u64, 0u64);
    for c in 0..cc {
        let seg_t = ctx.time[o + c];
        let seg_m = ctx.mem[o + c];
        scratch.clear();
        for (pcfg, pset) in prev.iter().enumerate() {
            if pset.is_empty() {
                continue;
            }
            let tr = mat[pcfg * cc + c];
            for (pidx, pp) in pset.iter().enumerate() {
                let time = pp.time + tr + seg_t;
                let mem = pp.mem + seg_m;
                if mem <= cap {
                    scratch.push(Point { time, mem, prev_cfg: pcfg, prev_idx: pidx });
                }
            }
        }
        generated += scratch.len() as u64;
        pareto_prune(scratch);
        kept += scratch.len() as u64;
        cur.push(scratch.clone());
    }
    if ctx.trace.is_enabled() {
        ctx.trace.count(Counter::ParetoStates, generated);
        ctx.trace.count(Counter::ParetoKept, kept);
    }
    cur
}

/// Best terminal time across a Pareto frontier (strict min-time,
/// earliest (config, index) — the reference's terminal rule).
pub(super) fn pareto_best_time(front: &[Vec<Point>]) -> Option<f64> {
    let mut best: Option<f64> = None;
    for pts in front {
        for p in pts {
            if best.map_or(true, |b| p.time < b) {
                best = Some(p.time);
            }
        }
    }
    best
}

/// Memory-capped min-time plan for `[lo, hi)` via the Pareto lane.
pub(super) fn pareto_plan(ctx: &SearchCtx, cap: u64, lo: usize, hi: usize) -> Option<Plan> {
    let n = hi - lo;
    if n == 0 {
        return None;
    }
    let mut frontiers: Vec<Vec<Vec<Point>>> = Vec::with_capacity(n);
    frontiers.push(pareto_first(ctx, lo, cap));
    let mut scratch: Vec<Point> = Vec::new();
    for i in 1..n {
        let next = pareto_step(ctx, &frontiers[i - 1], lo + i, cap, &mut scratch);
        frontiers.push(next);
    }
    let last = &frontiers[n - 1];
    let mut best: Option<(usize, usize)> = None;
    for (cfg, pts) in last.iter().enumerate() {
        for (idx, p) in pts.iter().enumerate() {
            if best.map_or(true, |(bc, bi)| p.time < last[bc][bi].time) {
                best = Some((cfg, idx));
            }
        }
    }
    let (mut cfg, mut idx) = best?;
    let terminal = last[cfg][idx];
    let mut choice = vec![0usize; n];
    for i in (0..n).rev() {
        choice[i] = cfg;
        let p = frontiers[i][cfg][idx];
        cfg = p.prev_cfg;
        idx = p.prev_idx;
    }
    Some(Plan { choice, time_us: terminal.time, mem_bytes: terminal.mem })
}

/// In-place Pareto prune: time-sorted, strictly-decreasing memory, then
/// thinned to `FRONTIER_CAP` evenly spaced representatives incl.
/// endpoints — the reference's exact kept set, without its two
/// intermediate allocations.
pub(super) fn pareto_prune(pts: &mut Vec<Point>) {
    pts.sort_by(|a, b| a.time.partial_cmp(&b.time).unwrap().then(a.mem.cmp(&b.mem)));
    let mut best_mem = u64::MAX;
    let mut w = 0usize;
    for r in 0..pts.len() {
        let p = pts[r];
        if p.mem < best_mem {
            best_mem = p.mem;
            pts[w] = p;
            w += 1;
        }
    }
    pts.truncate(w);
    if pts.len() > FRONTIER_CAP {
        // source index ≥ write index (step > 1), so in-place is safe
        let step = (pts.len() - 1) as f64 / (FRONTIER_CAP - 1) as f64;
        for k in 0..FRONTIER_CAP {
            pts[k] = pts[(k as f64 * step).round() as usize];
        }
        pts.truncate(FRONTIER_CAP);
    }
}

// ---------------------------------------------------------------- memory lane

/// Pareto point of the memory-axis span DP: time (recompute included) and
/// the three components of the 1F1B footprint, with backpointers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(super) struct MemPoint {
    pub time: f64,
    pub recompute: f64,
    pub stat: u64,
    pub ret: u64,
    pub tra: u64,
    pub ckpt: bool,
    pub prev_cfg: usize,
    pub prev_idx: usize,
}

pub(super) fn mem_first(ctx: &SearchCtx, pos: usize, spec: RecomputeSpec) -> Vec<Vec<MemPoint>> {
    let u = ctx.uid[pos];
    let o = ctx.off[u];
    (0..ctx.ncfg[u])
        .map(|c| {
            let seg_t = ctx.time[o + c];
            let stat = ctx.stat[o + c];
            let mut pts: Vec<MemPoint> = ctx
                .remat
                .points(o + c, spec)
                .iter()
                .map(|r| MemPoint {
                    time: seg_t + r.extra_us,
                    recompute: r.extra_us,
                    stat,
                    ret: r.retained_bytes,
                    tra: r.transient_bytes,
                    ckpt: r.checkpoint,
                    prev_cfg: usize::MAX,
                    prev_idx: usize::MAX,
                })
                .collect();
            prune_mem(&mut pts);
            pts
        })
        .collect()
}

/// One memory-axis step into `pos`: the (config × remat) product, with
/// the reshard row read from the dense matrix and the remat frontier
/// from the precomputed table — nothing allocated but the kept set.
pub(super) fn mem_step(
    ctx: &SearchCtx,
    prev: &[Vec<MemPoint>],
    pos: usize,
    spec: RecomputeSpec,
    scratch: &mut Vec<MemPoint>,
) -> Vec<Vec<MemPoint>> {
    let u = ctx.uid[pos];
    let o = ctx.off[u];
    let cc = ctx.ncfg[u];
    let mat = &ctx.mats[ctx.step_mat[pos]];
    let mut cur: Vec<Vec<MemPoint>> = Vec::with_capacity(cc);
    let (mut generated, mut kept) = (0u64, 0u64);
    for c in 0..cc {
        let seg_t = ctx.time[o + c];
        let stat = ctx.stat[o + c];
        let rpts = ctx.remat.points(o + c, spec);
        scratch.clear();
        for (pcfg, pset) in prev.iter().enumerate() {
            if pset.is_empty() {
                continue;
            }
            let tr = mat[pcfg * cc + c];
            for (pidx, pp) in pset.iter().enumerate() {
                for r in rpts {
                    scratch.push(MemPoint {
                        time: pp.time + tr + seg_t + r.extra_us,
                        recompute: pp.recompute + r.extra_us,
                        stat: pp.stat + stat,
                        ret: pp.ret + r.retained_bytes,
                        tra: pp.tra.max(r.transient_bytes),
                        ckpt: r.checkpoint,
                        prev_cfg: pcfg,
                        prev_idx: pidx,
                    });
                }
            }
        }
        generated += scratch.len() as u64;
        prune_mem(scratch);
        kept += scratch.len() as u64;
        cur.push(scratch.clone());
    }
    if ctx.trace.is_enabled() {
        ctx.trace.count(Counter::MemStates, generated);
        ctx.trace.count(Counter::MemKept, kept);
    }
    cur
}

/// Kept terminal points of a memory-axis frontier, in the canonical
/// (time, stat, ret, tra)-sorted, dominance-filtered order the reference
/// emits — shared by the single-span search and the sweeps.
pub(super) fn mem_terminals(last: &[Vec<MemPoint>]) -> Vec<(usize, usize)> {
    let mut terminals: Vec<(usize, usize)> = Vec::new();
    for (cfg, pts) in last.iter().enumerate() {
        for idx in 0..pts.len() {
            terminals.push((cfg, idx));
        }
    }
    terminals.sort_by(|a, b| {
        let (pa, pb) = (&last[a.0][a.1], &last[b.0][b.1]);
        pa.time
            .partial_cmp(&pb.time)
            .unwrap()
            .then(pa.stat.cmp(&pb.stat))
            .then(pa.ret.cmp(&pb.ret))
            .then(pa.tra.cmp(&pb.tra))
    });
    let mut kept: Vec<(usize, usize)> = Vec::new();
    for t in terminals {
        let p = &last[t.0][t.1];
        let dominated = kept.iter().any(|&(c, i)| {
            let q = &last[c][i];
            q.stat <= p.stat && q.ret <= p.ret && q.tra <= p.tra
        });
        if !dominated {
            kept.push(t);
        }
    }
    kept
}

/// The full memory-axis span search: frontier DP + terminal extraction +
/// backtrack into [`SpanMemPlan`]s.
pub(super) fn mem_span(
    ctx: &SearchCtx,
    lo: usize,
    hi: usize,
    spec: RecomputeSpec,
) -> Vec<SpanMemPlan> {
    let n = hi - lo;
    if n == 0 {
        return Vec::new();
    }
    let mut frontiers: Vec<Vec<Vec<MemPoint>>> = Vec::with_capacity(n);
    frontiers.push(mem_first(ctx, lo, spec));
    let mut scratch: Vec<MemPoint> = Vec::new();
    for i in 1..n {
        let next = mem_step(ctx, &frontiers[i - 1], lo + i, spec, &mut scratch);
        frontiers.push(next);
    }
    mem_terminals(&frontiers[n - 1])
        .into_iter()
        .map(|(cfg, idx)| backtrack_mem(&frontiers, n, cfg, idx))
        .collect()
}

/// In-place memory-axis prune: keep points that lower the running
/// minimum of any footprint component in time order, thin to
/// `MEM_FRONTIER_CAP` — the reference's exact kept set.
pub(super) fn prune_mem(pts: &mut Vec<MemPoint>) {
    pts.sort_by(|a, b| {
        a.time
            .partial_cmp(&b.time)
            .unwrap()
            .then(a.stat.cmp(&b.stat))
            .then(a.ret.cmp(&b.ret))
            .then(a.tra.cmp(&b.tra))
    });
    let (mut min_stat, mut min_ret, mut min_tra) = (u64::MAX, u64::MAX, u64::MAX);
    let mut w = 0usize;
    for r in 0..pts.len() {
        let p = pts[r];
        if w == 0 || p.stat < min_stat || p.ret < min_ret || p.tra < min_tra {
            min_stat = min_stat.min(p.stat);
            min_ret = min_ret.min(p.ret);
            min_tra = min_tra.min(p.tra);
            pts[w] = p;
            w += 1;
        }
    }
    pts.truncate(w);
    if pts.len() > MEM_FRONTIER_CAP {
        let step = (pts.len() - 1) as f64 / (MEM_FRONTIER_CAP - 1) as f64;
        for k in 0..MEM_FRONTIER_CAP {
            pts[k] = pts[(k as f64 * step).round() as usize];
        }
        pts.truncate(MEM_FRONTIER_CAP);
    }
}

fn backtrack_mem(
    frontiers: &[Vec<Vec<MemPoint>>],
    n: usize,
    mut cfg: usize,
    mut idx: usize,
) -> SpanMemPlan {
    let terminal = frontiers[n - 1][cfg][idx];
    let mut choice = vec![0usize; n];
    let mut remat = vec![false; n];
    for i in (0..n).rev() {
        let p = frontiers[i][cfg][idx];
        choice[i] = cfg;
        remat[i] = p.ckpt;
        cfg = p.prev_cfg;
        idx = p.prev_idx;
    }
    SpanMemPlan {
        choice,
        remat,
        time_us: terminal.time,
        footprint: SpanFootprint {
            static_bytes: terminal.stat,
            retained_bytes: terminal.ret,
            transient_bytes: terminal.tra,
            recompute_us: terminal.recompute,
        },
    }
}
