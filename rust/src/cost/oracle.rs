//! The pre-refactor span DP, kept verbatim as the bit-identity oracle.
//!
//! The repetition-aware search core ([`super::SearchCtx`] + the scalar
//! steady-state DP + the shared-prefix sweeps) must return plans that are
//! *bit-identical* — same `choice`, same `time_us` down to the last float
//! bit, same `mem_bytes` — to what this reference implementation
//! produces. The property suite (`rust/tests/prop_search_equivalence.rs`)
//! pins that across randomized profiles, caps, and span bounds, and
//! `rust/benches/search.rs` uses this as the speedup baseline recorded in
//! `BENCH_search.json`.
//!
//! Nothing in the production path calls into this module; it exists so
//! the fast path has a fixed point to be measured and verified against.
//! Do not "optimize" it — its per-position Pareto walk with hash-table
//! reshard lookups IS the baseline.

use crate::memory::{self, RecomputeSpec, SpanMemPlan};
use crate::profiler::ProfileDb;
use crate::segment::SegmentSet;

use super::Plan;

/// Pareto point with backpointer (reference copy).
#[derive(Clone, Copy, Debug)]
struct Point {
    time: f64,
    mem: u64,
    prev_cfg: usize,
    prev_idx: usize,
}

const FRONTIER_CAP: usize = 24;

/// Pre-refactor [`super::search_span`]: per-position Pareto DP with
/// `db.reshard_us` hash lookups in the inner loop. Test/bench oracle.
pub fn search_span_reference(
    ss: &SegmentSet,
    db: &ProfileDb,
    mem_cap: Option<u64>,
    lo: usize,
    hi: usize,
) -> Option<Plan> {
    assert!(lo <= hi && hi <= ss.instances.len());
    let n = hi - lo;
    if n == 0 {
        return None;
    }
    // frontier[cfg] = pareto set of (time, mem) for prefixes ending at cfg
    let mut frontiers: Vec<Vec<Vec<Point>>> = Vec::with_capacity(n);
    let u0 = ss.instances[lo].unique_id;
    let p0 = &db.segments[u0];
    let mut first: Vec<Vec<Point>> = Vec::new();
    for cfg in 0..p0.configs.len() {
        let mem = p0.mem_bytes[cfg];
        let time = p0.t_c_us[cfg] + p0.t_p_us[cfg];
        let mut pts = Vec::new();
        if mem_cap.map_or(true, |cap| mem <= cap) {
            pts.push(Point { time, mem, prev_cfg: usize::MAX, prev_idx: usize::MAX });
        }
        first.push(pts);
    }
    frontiers.push(first);

    for i in 1..n {
        let u = ss.instances[lo + i].unique_id;
        let pu = ss.instances[lo + i - 1].unique_id;
        let prof = &db.segments[u];
        let prev = &frontiers[i - 1];
        let mut cur: Vec<Vec<Point>> = Vec::with_capacity(prof.configs.len());
        for cfg in 0..prof.configs.len() {
            let seg_t = prof.t_c_us[cfg] + prof.t_p_us[cfg];
            let seg_m = prof.mem_bytes[cfg];
            let mut pts: Vec<Point> = Vec::new();
            for (pcfg, pset) in prev.iter().enumerate() {
                if pset.is_empty() {
                    continue;
                }
                let tr = db.reshard_us(pu, pcfg, u, cfg);
                for (pidx, pp) in pset.iter().enumerate() {
                    let time = pp.time + tr + seg_t;
                    let mem = pp.mem + seg_m;
                    if mem_cap.map_or(true, |cap| mem <= cap) {
                        pts.push(Point { time, mem, prev_cfg: pcfg, prev_idx: pidx });
                    }
                }
            }
            pareto_prune(&mut pts);
            cur.push(pts);
        }
        frontiers.push(cur);
    }

    // best terminal point
    let last = &frontiers[n - 1];
    let mut best: Option<(usize, usize)> = None;
    for (cfg, pts) in last.iter().enumerate() {
        for (idx, p) in pts.iter().enumerate() {
            if best.map_or(true, |(bc, bi)| p.time < last[bc][bi].time) {
                best = Some((cfg, idx));
            }
        }
    }
    let (mut cfg, mut idx) = best?;
    let terminal = last[cfg][idx];
    let mut choice = vec![0usize; n];
    for i in (0..n).rev() {
        choice[i] = cfg;
        let p = frontiers[i][cfg][idx];
        cfg = p.prev_cfg;
        idx = p.prev_idx;
    }
    Some(Plan { choice, time_us: terminal.time, mem_bytes: terminal.mem })
}

fn pareto_prune(pts: &mut Vec<Point>) {
    pts.sort_by(|a, b| a.time.partial_cmp(&b.time).unwrap().then(a.mem.cmp(&b.mem)));
    let mut out: Vec<Point> = Vec::new();
    let mut best_mem = u64::MAX;
    for p in pts.drain(..) {
        if p.mem < best_mem {
            best_mem = p.mem;
            out.push(p);
        }
    }
    if out.len() > FRONTIER_CAP {
        // keep evenly spaced representatives incl. endpoints
        let step = (out.len() - 1) as f64 / (FRONTIER_CAP - 1) as f64;
        let kept: Vec<Point> =
            (0..FRONTIER_CAP).map(|k| out[(k as f64 * step).round() as usize]).collect();
        out = kept;
    }
    *pts = out;
}

/// Pareto point of the memory-axis span DP (reference copy).
#[derive(Clone, Copy, Debug)]
struct MemPoint {
    time: f64,
    recompute: f64,
    stat: u64,
    ret: u64,
    tra: u64,
    ckpt: bool,
    prev_cfg: usize,
    prev_idx: usize,
}

const MEM_FRONTIER_CAP: usize = 16;

/// Pre-refactor [`super::search_span_mem`]: the memory-axis span DP with
/// per-call `remat_points` allocation and hash-table reshard lookups in
/// the inner loop. Test/bench oracle.
pub fn search_span_mem_reference(
    ss: &SegmentSet,
    db: &ProfileDb,
    lo: usize,
    hi: usize,
    spec: RecomputeSpec,
) -> Vec<SpanMemPlan> {
    assert!(lo <= hi && hi <= ss.instances.len());
    let n = hi - lo;
    if n == 0 {
        return Vec::new();
    }
    let mut frontiers: Vec<Vec<Vec<MemPoint>>> = Vec::with_capacity(n);
    let u0 = ss.instances[lo].unique_id;
    let p0 = &db.segments[u0];
    let mut first: Vec<Vec<MemPoint>> = Vec::with_capacity(p0.configs.len());
    for cfg in 0..p0.configs.len() {
        let seg_t = p0.t_c_us[cfg] + p0.t_p_us[cfg];
        let stat = memory::seg_static_bytes(p0, cfg);
        let mut pts: Vec<MemPoint> = Vec::new();
        for r in memory::remat_points(p0, cfg, spec) {
            pts.push(MemPoint {
                time: seg_t + r.extra_us,
                recompute: r.extra_us,
                stat,
                ret: r.retained_bytes,
                tra: r.transient_bytes,
                ckpt: r.checkpoint,
                prev_cfg: usize::MAX,
                prev_idx: usize::MAX,
            });
        }
        prune_mem(&mut pts);
        first.push(pts);
    }
    frontiers.push(first);

    for i in 1..n {
        let u = ss.instances[lo + i].unique_id;
        let pu = ss.instances[lo + i - 1].unique_id;
        let prof = &db.segments[u];
        let prev = &frontiers[i - 1];
        let mut cur: Vec<Vec<MemPoint>> = Vec::with_capacity(prof.configs.len());
        for cfg in 0..prof.configs.len() {
            let seg_t = prof.t_c_us[cfg] + prof.t_p_us[cfg];
            let stat = memory::seg_static_bytes(prof, cfg);
            let rpts = memory::remat_points(prof, cfg, spec);
            let mut pts: Vec<MemPoint> = Vec::new();
            for (pcfg, pset) in prev.iter().enumerate() {
                if pset.is_empty() {
                    continue;
                }
                let tr = db.reshard_us(pu, pcfg, u, cfg);
                for (pidx, pp) in pset.iter().enumerate() {
                    for r in &rpts {
                        pts.push(MemPoint {
                            time: pp.time + tr + seg_t + r.extra_us,
                            recompute: pp.recompute + r.extra_us,
                            stat: pp.stat + stat,
                            ret: pp.ret + r.retained_bytes,
                            tra: pp.tra.max(r.transient_bytes),
                            ckpt: r.checkpoint,
                            prev_cfg: pcfg,
                            prev_idx: pidx,
                        });
                    }
                }
            }
            prune_mem(&mut pts);
            cur.push(pts);
        }
        frontiers.push(cur);
    }

    // terminal frontier across configs: keep undominated points, then
    // backtrack each into a full span plan
    let last = &frontiers[n - 1];
    let mut terminals: Vec<(usize, usize)> = Vec::new();
    for (cfg, pts) in last.iter().enumerate() {
        for idx in 0..pts.len() {
            terminals.push((cfg, idx));
        }
    }
    terminals.sort_by(|a, b| {
        let (pa, pb) = (&last[a.0][a.1], &last[b.0][b.1]);
        pa.time
            .partial_cmp(&pb.time)
            .unwrap()
            .then(pa.stat.cmp(&pb.stat))
            .then(pa.ret.cmp(&pb.ret))
            .then(pa.tra.cmp(&pb.tra))
    });
    let mut kept: Vec<(usize, usize)> = Vec::new();
    for t in terminals {
        let p = &last[t.0][t.1];
        let dominated = kept.iter().any(|&(c, i)| {
            let q = &last[c][i];
            q.stat <= p.stat && q.ret <= p.ret && q.tra <= p.tra
        });
        if !dominated {
            kept.push(t);
        }
    }
    kept.into_iter().map(|(cfg, idx)| backtrack_mem(&frontiers, n, cfg, idx)).collect()
}

fn prune_mem(pts: &mut Vec<MemPoint>) {
    pts.sort_by(|a, b| {
        a.time
            .partial_cmp(&b.time)
            .unwrap()
            .then(a.stat.cmp(&b.stat))
            .then(a.ret.cmp(&b.ret))
            .then(a.tra.cmp(&b.tra))
    });
    let mut out: Vec<MemPoint> = Vec::new();
    let (mut min_stat, mut min_ret, mut min_tra) = (u64::MAX, u64::MAX, u64::MAX);
    for p in pts.drain(..) {
        if out.is_empty() || p.stat < min_stat || p.ret < min_ret || p.tra < min_tra {
            min_stat = min_stat.min(p.stat);
            min_ret = min_ret.min(p.ret);
            min_tra = min_tra.min(p.tra);
            out.push(p);
        }
    }
    if out.len() > MEM_FRONTIER_CAP {
        let step = (out.len() - 1) as f64 / (MEM_FRONTIER_CAP - 1) as f64;
        out = (0..MEM_FRONTIER_CAP).map(|k| out[(k as f64 * step).round() as usize]).collect();
    }
    *pts = out;
}

fn backtrack_mem(
    frontiers: &[Vec<Vec<MemPoint>>],
    n: usize,
    mut cfg: usize,
    mut idx: usize,
) -> SpanMemPlan {
    let terminal = frontiers[n - 1][cfg][idx];
    let mut choice = vec![0usize; n];
    let mut remat = vec![false; n];
    for i in (0..n).rev() {
        let p = frontiers[i][cfg][idx];
        choice[i] = cfg;
        remat[i] = p.ckpt;
        cfg = p.prev_cfg;
        idx = p.prev_idx;
    }
    SpanMemPlan {
        choice,
        remat,
        time_us: terminal.time,
        footprint: crate::memory::SpanFootprint {
            static_bytes: terminal.stat,
            retained_bytes: terminal.ret,
            transient_bytes: terminal.tra,
            recompute_us: terminal.recompute,
        },
    }
}
