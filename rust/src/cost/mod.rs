//! Cost composition (Eq. 8/9) and memory-constrained plan search (§4.4).
//!
//! `C_T = Σ (T_C[n][iₙ] + T_P[n][iₙ]) + Σ T_R[n][iₙ₋₁][iₙ]` and
//! `C_M = Σ M[n][iₙ]` — composed entirely from unique-segment profiles.
//! The search walks the segment chain with a Pareto frontier on
//! (time, memory) per (position, config) state, so fingerprint-equal
//! segments may pick *different* configs to ride the memory cap — the
//! §4.4 "some segments fast-but-fat, others lean-but-slow" behaviour.
//!
//! # Invariants
//!
//! * **Chain contiguity.** Every searcher walks `SegmentSet::instances`
//!   in chain order and charges `T_R` only between *adjacent* instances;
//!   a [`Plan`] for the span `[lo, hi)` is meaningful only for that
//!   contiguous run (the inter-op planner in [`crate::interop`] relies on
//!   this: a pipeline stage is a contiguous span, and the reshard at a
//!   stage cut is replaced by the pipeline's point-to-point transfer).
//! * **Pareto-prune correctness.** The per-(position, config) frontier
//!   keeps only (time, memory)-undominated prefixes. Dropping a dominated
//!   point is exact: both the remaining time-to-go and the memory cap are
//!   monotone in (time, mem), so a dominated prefix can never complete
//!   into a strictly better full plan. The `FRONTIER_CAP` thinning step
//!   is the only approximation (it keeps endpoints, so the unconstrained
//!   optimum and the min-memory plan always survive; the
//!   `dp_matches_brute_force_*` tests bound its error).
//! * **Span composition.** `search(ss, ..) == search_span(ss, .., 0, n)`
//!   by construction — the whole-chain search is the degenerate span, so
//!   single-stage plans and `k = 1` pipeline stages are bit-identical.

use std::sync::Arc;

use crate::memory::{self, RecomputeSpec, SpanMemPlan};
use crate::profiler::ProfileDb;
use crate::segment::SegmentSet;
use crate::util::ThreadPool;

/// A selected global configuration: one config index per segment instance.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub choice: Vec<usize>,
    pub time_us: f64,
    pub mem_bytes: u64,
}

/// Eq. 8 + Eq. 9 for an explicit choice vector.
pub fn plan_cost(ss: &SegmentSet, db: &ProfileDb, choice: &[usize]) -> (f64, u64) {
    plan_cost_span(ss, db, choice, 0, ss.instances.len())
}

/// Eq. 8 + Eq. 9 restricted to the contiguous instance span `[lo, hi)`.
/// `choice[i]` is the config of instance `lo + i`; boundary resharding is
/// charged only *inside* the span (the cost of entering the span is the
/// caller's problem — a stage cut replaces it with a pipeline transfer).
pub fn plan_cost_span(
    ss: &SegmentSet,
    db: &ProfileDb,
    choice: &[usize],
    lo: usize,
    hi: usize,
) -> (f64, u64) {
    assert!(lo <= hi && hi <= ss.instances.len());
    assert_eq!(choice.len(), hi - lo);
    let mut time = 0.0;
    let mut mem = 0u64;
    for (i, n) in (lo..hi).enumerate() {
        let inst = &ss.instances[n];
        let u = inst.unique_id;
        let prof = &db.segments[u];
        time += prof.t_c_us[choice[i]] + prof.t_p_us[choice[i]];
        mem += prof.mem_bytes[choice[i]];
        if n > lo {
            let pu = ss.instances[n - 1].unique_id;
            time += db.reshard_us(pu, choice[i - 1], u, choice[i]);
        }
    }
    (time, mem)
}

/// Pareto point with backpointer.
#[derive(Clone, Copy, Debug)]
struct Point {
    time: f64,
    mem: u64,
    prev_cfg: usize,
    prev_idx: usize,
}

const FRONTIER_CAP: usize = 24;

/// Min-time plan with `C_M ≤ mem_cap` (None = unconstrained).
/// Returns None if no feasible plan exists.
pub fn search(ss: &SegmentSet, db: &ProfileDb, mem_cap: Option<u64>) -> Option<Plan> {
    search_span(ss, db, mem_cap, 0, ss.instances.len())
}

/// [`search`] restricted to the contiguous instance span `[lo, hi)` — the
/// unit the inter-op stage planner solves per (stage-span, sub-mesh). The
/// returned plan's `choice[i]` is the config of instance `lo + i`; its
/// time/memory are the span's own (no entering reshard — see
/// [`plan_cost_span`]). `search(ss, ..)` is exactly the `[0, n)` span.
pub fn search_span(
    ss: &SegmentSet,
    db: &ProfileDb,
    mem_cap: Option<u64>,
    lo: usize,
    hi: usize,
) -> Option<Plan> {
    assert!(lo <= hi && hi <= ss.instances.len());
    let n = hi - lo;
    if n == 0 {
        return None;
    }
    // frontier[cfg] = pareto set of (time, mem) for prefixes ending at cfg
    let mut frontiers: Vec<Vec<Vec<Point>>> = Vec::with_capacity(n);
    let u0 = ss.instances[lo].unique_id;
    let p0 = &db.segments[u0];
    let mut first: Vec<Vec<Point>> = Vec::new();
    for cfg in 0..p0.configs.len() {
        let mem = p0.mem_bytes[cfg];
        let time = p0.t_c_us[cfg] + p0.t_p_us[cfg];
        let mut pts = Vec::new();
        if mem_cap.map_or(true, |cap| mem <= cap) {
            pts.push(Point { time, mem, prev_cfg: usize::MAX, prev_idx: usize::MAX });
        }
        first.push(pts);
    }
    frontiers.push(first);

    for i in 1..n {
        let u = ss.instances[lo + i].unique_id;
        let pu = ss.instances[lo + i - 1].unique_id;
        let prof = &db.segments[u];
        let prev = &frontiers[i - 1];
        let mut cur: Vec<Vec<Point>> = Vec::with_capacity(prof.configs.len());
        for cfg in 0..prof.configs.len() {
            let seg_t = prof.t_c_us[cfg] + prof.t_p_us[cfg];
            let seg_m = prof.mem_bytes[cfg];
            let mut pts: Vec<Point> = Vec::new();
            for (pcfg, pset) in prev.iter().enumerate() {
                if pset.is_empty() {
                    continue;
                }
                let tr = db.reshard_us(pu, pcfg, u, cfg);
                for (pidx, pp) in pset.iter().enumerate() {
                    let time = pp.time + tr + seg_t;
                    let mem = pp.mem + seg_m;
                    if mem_cap.map_or(true, |cap| mem <= cap) {
                        pts.push(Point { time, mem, prev_cfg: pcfg, prev_idx: pidx });
                    }
                }
            }
            pareto_prune(&mut pts);
            cur.push(pts);
        }
        frontiers.push(cur);
    }

    // best terminal point
    let last = &frontiers[n - 1];
    let mut best: Option<(usize, usize)> = None;
    for (cfg, pts) in last.iter().enumerate() {
        for (idx, p) in pts.iter().enumerate() {
            if best.map_or(true, |(bc, bi)| p.time < last[bc][bi].time) {
                best = Some((cfg, idx));
            }
        }
    }
    let (mut cfg, mut idx) = best?;
    let terminal = last[cfg][idx];
    let mut choice = vec![0usize; n];
    for i in (0..n).rev() {
        choice[i] = cfg;
        let p = frontiers[i][cfg][idx];
        cfg = p.prev_cfg;
        idx = p.prev_idx;
    }
    Some(Plan { choice, time_us: terminal.time, mem_bytes: terminal.mem })
}

/// Pareto point of the memory-axis span DP: time (recompute included) and
/// the three components of the 1F1B footprint, with backpointers.
#[derive(Clone, Copy, Debug)]
struct MemPoint {
    time: f64,
    recompute: f64,
    stat: u64,
    ret: u64,
    tra: u64,
    ckpt: bool,
    prev_cfg: usize,
    prev_idx: usize,
}

/// Per-(position, config) cap on the memory-axis frontier (like
/// `FRONTIER_CAP`, thinning keeps the min-time endpoint, so the
/// unconstrained optimum is exact).
const MEM_FRONTIER_CAP: usize = 16;

/// Memory-axis variant of [`search_span`]: the DP state is enlarged with
/// the per-instance rematerialization choice ([`memory::remat_points`]),
/// and instead of one min-time plan it returns the span's frontier of
/// (time, 1F1B-footprint) trade-off points — the inter-op stage planner
/// picks the min-time point whose [`memory::stage_peak_bytes`] fits the
/// device cap at the stage's in-flight depth.
///
/// Pruning: points are kept when they improve the running minimum of any
/// footprint component in time order. That keeps the min-time point (so a
/// loose cap reproduces [`search_span`]'s unconstrained optimum exactly)
/// and the memory-frugal endpoints; intermediate points may be thinned
/// (same approximation class as `FRONTIER_CAP`).
pub fn search_span_mem(
    ss: &SegmentSet,
    db: &ProfileDb,
    lo: usize,
    hi: usize,
    spec: RecomputeSpec,
) -> Vec<SpanMemPlan> {
    assert!(lo <= hi && hi <= ss.instances.len());
    let n = hi - lo;
    if n == 0 {
        return Vec::new();
    }
    let mut frontiers: Vec<Vec<Vec<MemPoint>>> = Vec::with_capacity(n);
    let u0 = ss.instances[lo].unique_id;
    let p0 = &db.segments[u0];
    let mut first: Vec<Vec<MemPoint>> = Vec::with_capacity(p0.configs.len());
    for cfg in 0..p0.configs.len() {
        let seg_t = p0.t_c_us[cfg] + p0.t_p_us[cfg];
        let stat = memory::seg_static_bytes(p0, cfg);
        let mut pts: Vec<MemPoint> = Vec::new();
        for r in memory::remat_points(p0, cfg, spec) {
            pts.push(MemPoint {
                time: seg_t + r.extra_us,
                recompute: r.extra_us,
                stat,
                ret: r.retained_bytes,
                tra: r.transient_bytes,
                ckpt: r.checkpoint,
                prev_cfg: usize::MAX,
                prev_idx: usize::MAX,
            });
        }
        prune_mem(&mut pts);
        first.push(pts);
    }
    frontiers.push(first);

    for i in 1..n {
        let u = ss.instances[lo + i].unique_id;
        let pu = ss.instances[lo + i - 1].unique_id;
        let prof = &db.segments[u];
        let prev = &frontiers[i - 1];
        let mut cur: Vec<Vec<MemPoint>> = Vec::with_capacity(prof.configs.len());
        for cfg in 0..prof.configs.len() {
            let seg_t = prof.t_c_us[cfg] + prof.t_p_us[cfg];
            let stat = memory::seg_static_bytes(prof, cfg);
            let rpts = memory::remat_points(prof, cfg, spec);
            let mut pts: Vec<MemPoint> = Vec::new();
            for (pcfg, pset) in prev.iter().enumerate() {
                if pset.is_empty() {
                    continue;
                }
                let tr = db.reshard_us(pu, pcfg, u, cfg);
                for (pidx, pp) in pset.iter().enumerate() {
                    for r in &rpts {
                        pts.push(MemPoint {
                            time: pp.time + tr + seg_t + r.extra_us,
                            recompute: pp.recompute + r.extra_us,
                            stat: pp.stat + stat,
                            ret: pp.ret + r.retained_bytes,
                            tra: pp.tra.max(r.transient_bytes),
                            ckpt: r.checkpoint,
                            prev_cfg: pcfg,
                            prev_idx: pidx,
                        });
                    }
                }
            }
            prune_mem(&mut pts);
            cur.push(pts);
        }
        frontiers.push(cur);
    }

    // terminal frontier across configs: keep undominated points, then
    // backtrack each into a full span plan
    let last = &frontiers[n - 1];
    let mut terminals: Vec<(usize, usize)> = Vec::new();
    for (cfg, pts) in last.iter().enumerate() {
        for idx in 0..pts.len() {
            terminals.push((cfg, idx));
        }
    }
    terminals.sort_by(|a, b| {
        let (pa, pb) = (&last[a.0][a.1], &last[b.0][b.1]);
        pa.time
            .partial_cmp(&pb.time)
            .unwrap()
            .then(pa.stat.cmp(&pb.stat))
            .then(pa.ret.cmp(&pb.ret))
            .then(pa.tra.cmp(&pb.tra))
    });
    let mut kept: Vec<(usize, usize)> = Vec::new();
    for t in terminals {
        let p = &last[t.0][t.1];
        let dominated = kept.iter().any(|&(c, i)| {
            let q = &last[c][i];
            q.stat <= p.stat && q.ret <= p.ret && q.tra <= p.tra
        });
        if !dominated {
            kept.push(t);
        }
    }
    kept.into_iter().map(|(cfg, idx)| backtrack_mem(&frontiers, n, cfg, idx)).collect()
}

/// Keep points that lower the running minimum of any footprint component
/// in time order (min-time point always survives), then thin to
/// `MEM_FRONTIER_CAP` evenly spaced representatives incl. endpoints.
fn prune_mem(pts: &mut Vec<MemPoint>) {
    pts.sort_by(|a, b| {
        a.time
            .partial_cmp(&b.time)
            .unwrap()
            .then(a.stat.cmp(&b.stat))
            .then(a.ret.cmp(&b.ret))
            .then(a.tra.cmp(&b.tra))
    });
    let mut out: Vec<MemPoint> = Vec::new();
    let (mut min_stat, mut min_ret, mut min_tra) = (u64::MAX, u64::MAX, u64::MAX);
    for p in pts.drain(..) {
        if out.is_empty() || p.stat < min_stat || p.ret < min_ret || p.tra < min_tra {
            min_stat = min_stat.min(p.stat);
            min_ret = min_ret.min(p.ret);
            min_tra = min_tra.min(p.tra);
            out.push(p);
        }
    }
    if out.len() > MEM_FRONTIER_CAP {
        let step = (out.len() - 1) as f64 / (MEM_FRONTIER_CAP - 1) as f64;
        out = (0..MEM_FRONTIER_CAP).map(|k| out[(k as f64 * step).round() as usize]).collect();
    }
    *pts = out;
}

fn backtrack_mem(
    frontiers: &[Vec<Vec<MemPoint>>],
    n: usize,
    mut cfg: usize,
    mut idx: usize,
) -> SpanMemPlan {
    let terminal = frontiers[n - 1][cfg][idx];
    let mut choice = vec![0usize; n];
    let mut remat = vec![false; n];
    for i in (0..n).rev() {
        let p = frontiers[i][cfg][idx];
        choice[i] = cfg;
        remat[i] = p.ckpt;
        cfg = p.prev_cfg;
        idx = p.prev_idx;
    }
    SpanMemPlan {
        choice,
        remat,
        time_us: terminal.time,
        footprint: crate::memory::SpanFootprint {
            static_bytes: terminal.stat,
            retained_bytes: terminal.ret,
            transient_bytes: terminal.tra,
            recompute_us: terminal.recompute,
        },
    }
}

/// Constrained variant: all instances of a unique segment use the same
/// config (the Fig. 10 prediction-evaluation mode).
pub fn search_uniform(ss: &SegmentSet, db: &ProfileDb, mem_cap: Option<u64>) -> Option<Plan> {
    search_uniform_slice(ss, db, mem_cap, None)
}

/// Parallel [`search_uniform`]: the combo space is partitioned by the
/// most-significant odometer axis (the last unique's config) and the
/// partitions evaluated over the in-repo thread pool. Partitions are
/// merged in ascending axis order with a strict `<` on time — byte-for-
/// byte the sequential tie-break, so the returned plan is identical.
///
/// The pool requires `'static` jobs, so `ss`/`db` are deep-cloned into
/// `Arc`s once per call — amortized across the exponential enumeration
/// this buys; prefer the serial entry points for tiny spaces.
pub fn search_uniform_with(
    ss: &SegmentSet,
    db: &ProfileDb,
    mem_cap: Option<u64>,
    threads: usize,
) -> Option<Plan> {
    let uniques = ss.unique.len();
    let last = if uniques == 0 { 0 } else { db.segments[uniques - 1].configs.len() };
    if threads <= 1 || last <= 1 {
        return search_uniform_slice(ss, db, mem_cap, None);
    }
    let ss = Arc::new(ss.clone());
    let db = Arc::new(db.clone());
    let pool = ThreadPool::new(threads.min(last));
    let slices = pool.map((0..last).collect::<Vec<usize>>(), move |v| {
        search_uniform_slice(&ss, &db, mem_cap, Some(v))
    });
    merge_in_order(slices)
}

/// Enumerate per-unique config combos (index 0 fastest). With
/// `fixed_last = Some(v)` only the subspace whose most-significant axis
/// equals `v` is visited — the unit of parallel partitioning.
fn search_uniform_slice(
    ss: &SegmentSet,
    db: &ProfileDb,
    mem_cap: Option<u64>,
    fixed_last: Option<usize>,
) -> Option<Plan> {
    let uniques = ss.unique.len();
    let sizes: Vec<usize> = (0..uniques).map(|u| db.segments[u].configs.len()).collect();
    let mut cur = vec![0usize; uniques];
    let free = match fixed_last {
        Some(v) if uniques > 0 => {
            cur[uniques - 1] = v;
            uniques - 1
        }
        _ => uniques,
    };
    let mut best: Option<Plan> = None;
    loop {
        let choice: Vec<usize> = ss.instances.iter().map(|i| cur[i.unique_id]).collect();
        let (time, mem) = plan_cost(ss, db, &choice);
        if mem_cap.map_or(true, |cap| mem <= cap)
            && best.as_ref().map_or(true, |b| time < b.time_us)
        {
            best = Some(Plan { choice, time_us: time, mem_bytes: mem });
        }
        // odometer
        let mut i = 0;
        loop {
            if i == free {
                return best;
            }
            cur[i] += 1;
            if cur[i] < sizes[i] {
                break;
            }
            cur[i] = 0;
            i += 1;
        }
    }
}

/// Exhaustive search (tests/baselines only — exponential).
pub fn brute_force(ss: &SegmentSet, db: &ProfileDb, mem_cap: Option<u64>) -> Option<Plan> {
    brute_force_slice(ss, db, mem_cap, None)
}

/// Parallel [`brute_force`] over the in-repo thread pool; same
/// partition-by-last-axis scheme as [`search_uniform_with`], so results
/// are bit-identical to the sequential path.
pub fn brute_force_with(
    ss: &SegmentSet,
    db: &ProfileDb,
    mem_cap: Option<u64>,
    threads: usize,
) -> Option<Plan> {
    let n = ss.instances.len();
    let last = if n == 0 { 0 } else { db.segments[ss.instances[n - 1].unique_id].configs.len() };
    if threads <= 1 || last <= 1 {
        return brute_force_slice(ss, db, mem_cap, None);
    }
    let ss = Arc::new(ss.clone());
    let db = Arc::new(db.clone());
    let pool = ThreadPool::new(threads.min(last));
    let slices = pool.map((0..last).collect::<Vec<usize>>(), move |v| {
        brute_force_slice(&ss, &db, mem_cap, Some(v))
    });
    merge_in_order(slices)
}

fn brute_force_slice(
    ss: &SegmentSet,
    db: &ProfileDb,
    mem_cap: Option<u64>,
    fixed_last: Option<usize>,
) -> Option<Plan> {
    let n = ss.instances.len();
    let sizes: Vec<usize> = ss
        .instances
        .iter()
        .map(|i| db.segments[i.unique_id].configs.len())
        .collect();
    let mut cur = vec![0usize; n];
    let free = match fixed_last {
        Some(v) if n > 0 => {
            cur[n - 1] = v;
            n - 1
        }
        _ => n,
    };
    let mut best: Option<Plan> = None;
    loop {
        let (time, mem) = plan_cost(ss, db, &cur);
        if mem_cap.map_or(true, |cap| mem <= cap)
            && best.as_ref().map_or(true, |b| time < b.time_us)
        {
            best = Some(Plan { choice: cur.clone(), time_us: time, mem_bytes: mem });
        }
        let mut i = 0;
        loop {
            if i == free {
                return best;
            }
            cur[i] += 1;
            if cur[i] < sizes[i] {
                break;
            }
            cur[i] = 0;
            i += 1;
        }
    }
}

/// Merge per-partition optima in ascending partition order. Partition `v`
/// contains exactly the combos enumerated after every combo of partitions
/// `< v` in the sequential order (index 0 is the fastest-moving axis), so
/// an in-order scan with strict `<` reproduces the sequential "first
/// optimum wins" tie-break exactly.
fn merge_in_order(slices: Vec<Option<Plan>>) -> Option<Plan> {
    let mut best: Option<Plan> = None;
    for p in slices.into_iter().flatten() {
        if best.as_ref().map_or(true, |b| p.time_us < b.time_us) {
            best = Some(p);
        }
    }
    best
}

fn pareto_prune(pts: &mut Vec<Point>) {
    pts.sort_by(|a, b| a.time.partial_cmp(&b.time).unwrap().then(a.mem.cmp(&b.mem)));
    let mut out: Vec<Point> = Vec::new();
    let mut best_mem = u64::MAX;
    for p in pts.drain(..) {
        if p.mem < best_mem {
            best_mem = p.mem;
            out.push(p);
        }
    }
    if out.len() > FRONTIER_CAP {
        // keep evenly spaced representatives incl. endpoints
        let step = (out.len() - 1) as f64 / (FRONTIER_CAP - 1) as f64;
        let kept: Vec<Point> =
            (0..FRONTIER_CAP).map(|k| out[(k as f64 * step).round() as usize]).collect();
        out = kept;
    }
    *pts = out;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Platform;
    use crate::models::{build_training, ModelCfg};
    use crate::pblock::build_parallel_blocks;
    use crate::profiler::{profile_model, ProfileOptions};
    use crate::segment::extract_segments;
    use crate::spmd::Mesh;

    fn setup(layers: usize) -> (SegmentSet, ProfileDb) {
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(layers);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let ss = extract_segments(&g, &bs);
        let opts = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
        let db = profile_model(&g, &bs, &ss, &opts);
        (ss, db)
    }

    #[test]
    fn dp_matches_brute_force_unconstrained() {
        let (ss, db) = setup(2);
        let dp = search(&ss, &db, None).unwrap();
        let bf = brute_force(&ss, &db, None).unwrap();
        assert!((dp.time_us - bf.time_us).abs() < 1e-6, "{} vs {}", dp.time_us, bf.time_us);
    }

    #[test]
    fn dp_matches_brute_force_under_memory_caps() {
        let (ss, db) = setup(2);
        let unconstrained = search(&ss, &db, None).unwrap();
        // sweep caps from tight to loose
        for frac in [0.7, 0.85, 1.0, 1.3] {
            let cap = (unconstrained.mem_bytes as f64 * frac) as u64;
            let dp = search(&ss, &db, Some(cap));
            let bf = brute_force(&ss, &db, Some(cap));
            match (dp, bf) {
                (Some(d), Some(b)) => {
                    assert!(
                        d.time_us <= b.time_us * 1.02 + 1e-6,
                        "cap {frac}: dp {} vs bf {}",
                        d.time_us,
                        b.time_us
                    );
                    assert!(d.mem_bytes <= cap);
                }
                (None, None) => {}
                (d, b) => panic!("feasibility mismatch at {frac}: {d:?} vs {b:?}"),
            }
        }
    }

    #[test]
    fn tighter_memory_never_speeds_up() {
        let (ss, db) = setup(3);
        let loose = search(&ss, &db, None).unwrap();
        let tight = search(&ss, &db, Some(loose.mem_bytes - 1));
        if let Some(t) = tight {
            assert!(t.time_us >= loose.time_us - 1e-9);
            assert!(t.mem_bytes < loose.mem_bytes);
        }
    }

    #[test]
    fn mixed_configs_can_beat_uniform_under_cap() {
        // §4.4: per-instance freedom dominates uniform-per-fingerprint
        let (ss, db) = setup(3);
        let free = search(&ss, &db, None).unwrap();
        for frac in [0.8, 0.9] {
            let cap = (free.mem_bytes as f64 * frac) as u64;
            let mixed = search(&ss, &db, Some(cap));
            let uni = search_uniform(&ss, &db, Some(cap));
            if let (Some(m), Some(u)) = (mixed, uni) {
                assert!(m.time_us <= u.time_us + 1e-9, "mixed {} uniform {}", m.time_us, u.time_us);
            }
        }
    }

    #[test]
    fn parallel_brute_force_identical_to_sequential() {
        // parallel partitions merge with the sequential tie-break, so the
        // whole Plan (not just its cost) must match bit-for-bit
        let (ss, db) = setup(2);
        let free = brute_force(&ss, &db, None).unwrap();
        for threads in [2usize, 4, 7] {
            let par = brute_force_with(&ss, &db, None, threads).unwrap();
            assert_eq!(par.choice, free.choice, "threads={threads}");
            assert!(par.time_us == free.time_us, "threads={threads}");
            assert_eq!(par.mem_bytes, free.mem_bytes, "threads={threads}");
        }
        let cap = Some((free.mem_bytes as f64 * 0.9) as u64);
        assert_eq!(brute_force(&ss, &db, cap), brute_force_with(&ss, &db, cap, 4));
    }

    #[test]
    fn parallel_search_uniform_identical_to_sequential() {
        let (ss, db) = setup(3);
        let seq = search_uniform(&ss, &db, None);
        assert_eq!(seq, search_uniform_with(&ss, &db, None, 4));
        if let Some(p) = &seq {
            let cap = Some(p.mem_bytes);
            assert_eq!(search_uniform(&ss, &db, cap), search_uniform_with(&ss, &db, cap, 3));
        }
        // an infeasible cap must agree on None, too
        assert_eq!(
            search_uniform(&ss, &db, Some(1)),
            search_uniform_with(&ss, &db, Some(1), 4)
        );
    }

    #[test]
    fn span_search_full_range_equals_whole_chain() {
        let (ss, db) = setup(3);
        let whole = search(&ss, &db, None).unwrap();
        let span = search_span(&ss, &db, None, 0, ss.instances.len()).unwrap();
        assert_eq!(whole, span);
    }

    #[test]
    fn span_search_solves_every_sub_chain_consistently() {
        let (ss, db) = setup(3);
        let n = ss.instances.len();
        for lo in 0..n {
            for hi in (lo + 1)..=n {
                let p = search_span(&ss, &db, None, lo, hi).unwrap();
                let (t, m) = plan_cost_span(&ss, &db, &p.choice, lo, hi);
                assert!((t - p.time_us).abs() < 1e-6, "[{lo},{hi}) {t} vs {}", p.time_us);
                assert_eq!(m, p.mem_bytes, "[{lo},{hi})");
                assert_eq!(p.choice.len(), hi - lo);
            }
        }
    }

    #[test]
    fn mem_frontier_min_time_equals_unconstrained_search() {
        let (ss, db) = setup(3);
        let n = ss.instances.len();
        let plain = search(&ss, &db, None).unwrap();
        for spec in [RecomputeSpec::Off, RecomputeSpec::Auto] {
            let frontier = search_span_mem(&ss, &db, 0, n, spec);
            assert!(!frontier.is_empty());
            let best = frontier
                .iter()
                .min_by(|a, b| a.time_us.partial_cmp(&b.time_us).unwrap())
                .unwrap();
            assert!(
                (best.time_us - plain.time_us).abs() < 1e-9 * plain.time_us.max(1.0),
                "{spec:?}: {} vs {}",
                best.time_us,
                plain.time_us
            );
            assert!(best.remat.iter().all(|&r| !r), "the min-time point never recomputes");
            let fp = memory::span_footprint(&ss, &db, &best.choice, 0, n);
            assert_eq!(fp.static_bytes, best.footprint.static_bytes);
            assert_eq!(fp.retained_bytes, best.footprint.retained_bytes);
            assert_eq!(best.footprint.transient_bytes, 0);
        }
    }

    #[test]
    fn mem_frontier_times_recompose_from_plan_cost() {
        let (ss, db) = setup(2);
        let n = ss.instances.len();
        let frontier = search_span_mem(&ss, &db, 0, n, RecomputeSpec::Auto);
        for p in &frontier {
            let (t, _) = plan_cost_span(&ss, &db, &p.choice, 0, n);
            assert!(
                (p.time_us - p.footprint.recompute_us - t).abs() <= 1e-6 * t.max(1.0),
                "time {} − recompute {} vs composed {t}",
                p.time_us,
                p.footprint.recompute_us
            );
            assert_eq!(p.choice.len(), n);
            assert_eq!(p.remat.len(), n);
        }
    }

    #[test]
    fn mem_frontier_auto_reaches_lower_peaks_with_slower_plans() {
        let (ss, db) = setup(3);
        let n = ss.instances.len();
        let off = search_span_mem(&ss, &db, 0, n, RecomputeSpec::Off);
        let auto_ = search_span_mem(&ss, &db, 0, n, RecomputeSpec::Auto);
        // at pipeline depth (several microbatches in flight) checkpointing
        // must unlock strictly lower peaks than any keep-everything plan
        let min_peak = |f: &[SpanMemPlan]| f.iter().map(|p| p.peak_bytes(8, 4)).min().unwrap();
        assert!(
            min_peak(&auto_) < min_peak(&off),
            "auto {} vs off {}",
            min_peak(&auto_),
            min_peak(&off)
        );
        // and every checkpointed point pays for it in time
        let best_time = off.iter().map(|p| p.time_us).fold(f64::INFINITY, f64::min);
        for p in &auto_ {
            if p.remat.iter().any(|&r| r) {
                assert!(p.time_us > best_time, "recompute is never free");
            }
        }
    }

    #[test]
    fn plan_cost_is_consistent_with_search_result() {
        let (ss, db) = setup(2);
        let plan = search(&ss, &db, None).unwrap();
        let (t, m) = plan_cost(&ss, &db, &plan.choice);
        assert!((t - plan.time_us).abs() < 1e-6);
        assert_eq!(m, plan.mem_bytes);
    }
}
