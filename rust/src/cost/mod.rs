//! Cost composition (Eq. 8/9) and memory-constrained plan search (§4.4).
//!
//! `C_T = Σ (T_C[n][iₙ] + T_P[n][iₙ]) + Σ T_R[n][iₙ₋₁][iₙ]` and
//! `C_M = Σ M[n][iₙ]` — composed entirely from unique-segment profiles.
//! The search walks the segment chain with a Pareto frontier on
//! (time, memory) per (position, config) state, so fingerprint-equal
//! segments may pick *different* configs to ride the memory cap — the
//! §4.4 "some segments fast-but-fat, others lean-but-slow" behaviour.
//!
//! Since PR 5 the search core is *repetition-aware*: every span solver
//! runs on a [`SearchCtx`] (flat SoA config columns + dense per-adjacent-
//! unique-pair reshard matrices + precomputed remat frontiers, built once
//! per `(SegmentSet, ProfileDb)`), the unconstrained DP collapses runs of
//! identical transitions through a verified steady-state splice (the
//! private `dp` engine module), and [`sweep`] answers *every* span `[lo, hi)`
//! sharing a prefix from one forward pass — the unit the inter-op
//! planner fans out over the thread pool. The pre-refactor DP survives
//! verbatim in [`oracle`] as the bit-identity baseline.
//!
//! # Invariants
//!
//! * **Chain contiguity.** Every searcher walks `SegmentSet::instances`
//!   in chain order and charges `T_R` only between *adjacent* instances;
//!   a [`Plan`] for the span `[lo, hi)` is meaningful only for that
//!   contiguous run (the inter-op planner in [`crate::interop`] relies on
//!   this: a pipeline stage is a contiguous span, and the reshard at a
//!   stage cut is replaced by the pipeline's point-to-point transfer).
//! * **Pareto-prune correctness.** The per-(position, config) frontier
//!   keeps only (time, memory)-undominated prefixes. Dropping a dominated
//!   point is exact: both the remaining time-to-go and the memory cap are
//!   monotone in (time, mem), so a dominated prefix can never complete
//!   into a strictly better full plan. The `FRONTIER_CAP` thinning step
//!   is the only approximation (it keeps endpoints, so the unconstrained
//!   optimum and the min-memory plan always survive; the
//!   `dp_matches_brute_force_*` tests bound its error).
//! * **Span composition.** `search(ss, ..) == search_span(ss, .., 0, n)`
//!   by construction — the whole-chain search is the degenerate span, so
//!   single-stage plans and `k = 1` pipeline stages are bit-identical.
//! * **Reference equivalence.** `search_span` / `search_span_mem` return
//!   plans bit-identical (choice, time, mem) to [`oracle`]'s per-position
//!   DP — pinned by `rust/tests/prop_search_equivalence.rs` across
//!   randomized profiles, caps, and span bounds.

mod ctx;
mod dp;
pub mod exact;
pub mod oracle;
pub mod sweep;

use std::sync::Arc;

use crate::memory::{RecomputeSpec, SpanMemPlan};
use crate::profiler::ProfileDb;
use crate::segment::SegmentSet;
use crate::util::ThreadPool;

pub use ctx::SearchCtx;
pub use exact::{search_span_exact, search_span_mem_exact, space_bits, SearchEngine};
pub use sweep::{select_time, sweep_span_frontiers, sweep_span_times, FrontierRow};

/// A selected global configuration: one config index per segment instance.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    pub choice: Vec<usize>,
    pub time_us: f64,
    pub mem_bytes: u64,
}

/// Eq. 8 + Eq. 9 for an explicit choice vector.
pub fn plan_cost(ss: &SegmentSet, db: &ProfileDb, choice: &[usize]) -> (f64, u64) {
    plan_cost_span(ss, db, choice, 0, ss.instances.len())
}

/// Eq. 8 + Eq. 9 restricted to the contiguous instance span `[lo, hi)`.
/// `choice[i]` is the config of instance `lo + i`; boundary resharding is
/// charged only *inside* the span (the cost of entering the span is the
/// caller's problem — a stage cut replaces it with a pipeline transfer).
pub fn plan_cost_span(
    ss: &SegmentSet,
    db: &ProfileDb,
    choice: &[usize],
    lo: usize,
    hi: usize,
) -> (f64, u64) {
    assert!(lo <= hi && hi <= ss.instances.len());
    assert_eq!(choice.len(), hi - lo);
    let mut time = 0.0;
    let mut mem = 0u64;
    for (i, n) in (lo..hi).enumerate() {
        let inst = &ss.instances[n];
        let u = inst.unique_id;
        let prof = &db.segments[u];
        time += prof.t_c_us[choice[i]] + prof.t_p_us[choice[i]];
        mem += prof.mem_bytes[choice[i]];
        if n > lo {
            let pu = ss.instances[n - 1].unique_id;
            time += db.reshard_us(pu, choice[i - 1], u, choice[i]);
        }
    }
    (time, mem)
}

/// Min-time plan with `C_M ≤ mem_cap` (None = unconstrained).
/// Returns None if no feasible plan exists.
pub fn search(ss: &SegmentSet, db: &ProfileDb, mem_cap: Option<u64>) -> Option<Plan> {
    search_span(ss, db, mem_cap, 0, ss.instances.len())
}

/// [`search`] restricted to the contiguous instance span `[lo, hi)` — the
/// unit the inter-op stage planner solves per (stage-span, sub-mesh). The
/// returned plan's `choice[i]` is the config of instance `lo + i`; its
/// time/memory are the span's own (no entering reshard — see
/// [`plan_cost_span`]). `search(ss, ..)` is exactly the `[0, n)` span.
///
/// Builds a throwaway [`SearchCtx`]; callers solving many spans of one
/// chain should build the context once and use [`search_span_ctx`].
pub fn search_span(
    ss: &SegmentSet,
    db: &ProfileDb,
    mem_cap: Option<u64>,
    lo: usize,
    hi: usize,
) -> Option<Plan> {
    assert!(lo <= hi && hi <= ss.instances.len());
    if lo == hi {
        return None;
    }
    let ctx = SearchCtx::new(ss, db);
    search_span_ctx(&ctx, mem_cap, lo, hi)
}

/// [`search_span`] over a prebuilt [`SearchCtx`]. Without a cap the
/// repetition-collapsing scalar lane runs; with one, the capped Pareto
/// lane (bit-identical to the reference in both cases).
pub fn search_span_ctx(
    ctx: &SearchCtx,
    mem_cap: Option<u64>,
    lo: usize,
    hi: usize,
) -> Option<Plan> {
    assert!(lo <= hi && hi <= ctx.len());
    if lo == hi {
        return None;
    }
    match mem_cap {
        None => dp::scalar_plan(ctx, lo, hi),
        Some(cap) => dp::pareto_plan(ctx, cap, lo, hi),
    }
}

/// [`search_span_ctx`] behind an engine switch (`--engine` on the CLI):
///
/// * [`SearchEngine::Dp`] — the production DP lanes, unchanged.
/// * [`SearchEngine::Exact`] — branch-and-bound with
///   [`exact::EXACT_NODE_BUDGET`]; only if the budget exhausts does it
///   fall back to the DP (with a stderr note — the answer is then the
///   usual approximation, not certified optimal).
/// * [`SearchEngine::Auto`] — exact when the assignment space is at most
///   [`exact::AUTO_EXACT_BITS`] bits, DP otherwise.
///
/// All three are deterministic; the dispatch depends only on the inputs.
pub fn search_span_engine(
    ctx: &SearchCtx,
    mem_cap: Option<u64>,
    lo: usize,
    hi: usize,
    engine: SearchEngine,
) -> Option<Plan> {
    let budget = match engine {
        SearchEngine::Dp => {
            ctx.trace().note("engine_path", "dp");
            return search_span_ctx(ctx, mem_cap, lo, hi);
        }
        SearchEngine::Exact => exact::EXACT_NODE_BUDGET,
        SearchEngine::Auto => {
            if space_bits(ctx, lo, hi) > exact::AUTO_EXACT_BITS {
                ctx.trace().note("engine_path", "auto-dp");
                return search_span_ctx(ctx, mem_cap, lo, hi);
            }
            exact::AUTO_NODE_BUDGET
        }
    };
    match exact::search_span_exact_budget(ctx, mem_cap, lo, hi, budget) {
        Ok(plan) => {
            ctx.trace().note(
                "engine_path",
                if engine == SearchEngine::Auto { "auto-exact" } else { "exact" },
            );
            plan
        }
        Err(exact::Exhausted) => {
            ctx.trace().note("engine_path", "exact-exhausted-dp-fallback");
            crate::obs::diag::diag(&format!(
                "cfp: exact engine exhausted its {budget}-node budget on span \
                 [{lo},{hi}); falling back to the DP (result not certified optimal)"
            ));
            search_span_ctx(ctx, mem_cap, lo, hi)
        }
    }
}

/// Memory-axis variant of [`search_span`]: the DP state is enlarged with
/// the per-instance rematerialization choice ([`crate::memory::remat_points`]),
/// and instead of one min-time plan it returns the span's frontier of
/// (time, 1F1B-footprint) trade-off points — the inter-op stage planner
/// picks the min-time point whose [`crate::memory::stage_peak_bytes`] fits the
/// device cap at the stage's in-flight depth.
///
/// Pruning: points are kept when they improve the running minimum of any
/// footprint component in time order. That keeps the min-time point (so a
/// loose cap reproduces [`search_span`]'s unconstrained optimum exactly)
/// and the memory-frugal endpoints; intermediate points may be thinned
/// (same approximation class as `FRONTIER_CAP`).
///
/// Builds a throwaway [`SearchCtx`]; use [`search_span_mem_ctx`] when
/// solving many spans of one chain.
pub fn search_span_mem(
    ss: &SegmentSet,
    db: &ProfileDb,
    lo: usize,
    hi: usize,
    spec: RecomputeSpec,
) -> Vec<SpanMemPlan> {
    assert!(lo <= hi && hi <= ss.instances.len());
    if lo == hi {
        return Vec::new();
    }
    let ctx = SearchCtx::new(ss, db);
    search_span_mem_ctx(&ctx, lo, hi, spec)
}

/// [`search_span_mem`] over a prebuilt [`SearchCtx`].
pub fn search_span_mem_ctx(
    ctx: &SearchCtx,
    lo: usize,
    hi: usize,
    spec: RecomputeSpec,
) -> Vec<SpanMemPlan> {
    assert!(lo <= hi && hi <= ctx.len());
    dp::mem_span(ctx, lo, hi, spec)
}

/// Constrained variant: all instances of a unique segment use the same
/// config (the Fig. 10 prediction-evaluation mode).
pub fn search_uniform(ss: &SegmentSet, db: &ProfileDb, mem_cap: Option<u64>) -> Option<Plan> {
    search_uniform_slice(ss, db, mem_cap, None)
}

/// Parallel [`search_uniform`]: the combo space is partitioned by the
/// most-significant odometer axis (the last unique's config) and the
/// partitions evaluated over the in-repo thread pool. Partitions are
/// merged in ascending axis order with a strict `<` on time — byte-for-
/// byte the sequential tie-break, so the returned plan is identical.
///
/// The pool requires `'static` jobs, so `ss`/`db` are deep-cloned into
/// `Arc`s once per call — amortized across the exponential enumeration
/// this buys; prefer the serial entry points for tiny spaces.
pub fn search_uniform_with(
    ss: &SegmentSet,
    db: &ProfileDb,
    mem_cap: Option<u64>,
    threads: usize,
) -> Option<Plan> {
    let uniques = ss.unique.len();
    let last = if uniques == 0 { 0 } else { db.segments[uniques - 1].configs.len() };
    if threads <= 1 || last <= 1 {
        return search_uniform_slice(ss, db, mem_cap, None);
    }
    let ss = Arc::new(ss.clone());
    let db = Arc::new(db.clone());
    let pool = ThreadPool::new(threads.min(last));
    let slices = pool.map((0..last).collect::<Vec<usize>>(), move |v| {
        search_uniform_slice(&ss, &db, mem_cap, Some(v))
    });
    merge_in_order(slices)
}

/// Enumerate per-unique config combos (index 0 fastest). With
/// `fixed_last = Some(v)` only the subspace whose most-significant axis
/// equals `v` is visited — the unit of parallel partitioning.
fn search_uniform_slice(
    ss: &SegmentSet,
    db: &ProfileDb,
    mem_cap: Option<u64>,
    fixed_last: Option<usize>,
) -> Option<Plan> {
    let uniques = ss.unique.len();
    let sizes: Vec<usize> = (0..uniques).map(|u| db.segments[u].configs.len()).collect();
    let mut cur = vec![0usize; uniques];
    let free = match fixed_last {
        Some(v) if uniques > 0 => {
            cur[uniques - 1] = v;
            uniques - 1
        }
        _ => uniques,
    };
    let mut best: Option<Plan> = None;
    loop {
        let choice: Vec<usize> = ss.instances.iter().map(|i| cur[i.unique_id]).collect();
        let (time, mem) = plan_cost(ss, db, &choice);
        if mem_cap.map_or(true, |cap| mem <= cap)
            && best.as_ref().map_or(true, |b| time < b.time_us)
        {
            best = Some(Plan { choice, time_us: time, mem_bytes: mem });
        }
        // odometer
        let mut i = 0;
        loop {
            if i == free {
                return best;
            }
            cur[i] += 1;
            if cur[i] < sizes[i] {
                break;
            }
            cur[i] = 0;
            i += 1;
        }
    }
}

/// Exhaustive search (tests/baselines only — exponential).
pub fn brute_force(ss: &SegmentSet, db: &ProfileDb, mem_cap: Option<u64>) -> Option<Plan> {
    brute_force_slice(ss, db, mem_cap, None)
}

/// Parallel [`brute_force`] over the in-repo thread pool; same
/// partition-by-last-axis scheme as [`search_uniform_with`], so results
/// are bit-identical to the sequential path.
pub fn brute_force_with(
    ss: &SegmentSet,
    db: &ProfileDb,
    mem_cap: Option<u64>,
    threads: usize,
) -> Option<Plan> {
    let n = ss.instances.len();
    let last = if n == 0 { 0 } else { db.segments[ss.instances[n - 1].unique_id].configs.len() };
    if threads <= 1 || last <= 1 {
        return brute_force_slice(ss, db, mem_cap, None);
    }
    let ss = Arc::new(ss.clone());
    let db = Arc::new(db.clone());
    let pool = ThreadPool::new(threads.min(last));
    let slices = pool.map((0..last).collect::<Vec<usize>>(), move |v| {
        brute_force_slice(&ss, &db, mem_cap, Some(v))
    });
    merge_in_order(slices)
}

fn brute_force_slice(
    ss: &SegmentSet,
    db: &ProfileDb,
    mem_cap: Option<u64>,
    fixed_last: Option<usize>,
) -> Option<Plan> {
    let n = ss.instances.len();
    let sizes: Vec<usize> = ss
        .instances
        .iter()
        .map(|i| db.segments[i.unique_id].configs.len())
        .collect();
    let mut cur = vec![0usize; n];
    let free = match fixed_last {
        Some(v) if n > 0 => {
            cur[n - 1] = v;
            n - 1
        }
        _ => n,
    };
    let mut best: Option<Plan> = None;
    loop {
        let (time, mem) = plan_cost(ss, db, &cur);
        if mem_cap.map_or(true, |cap| mem <= cap)
            && best.as_ref().map_or(true, |b| time < b.time_us)
        {
            best = Some(Plan { choice: cur.clone(), time_us: time, mem_bytes: mem });
        }
        let mut i = 0;
        loop {
            if i == free {
                return best;
            }
            cur[i] += 1;
            if cur[i] < sizes[i] {
                break;
            }
            cur[i] = 0;
            i += 1;
        }
    }
}

/// Merge per-partition optima in ascending partition order. Partition `v`
/// contains exactly the combos enumerated after every combo of partitions
/// `< v` in the sequential order (index 0 is the fastest-moving axis), so
/// an in-order scan with strict `<` reproduces the sequential "first
/// optimum wins" tie-break exactly.
fn merge_in_order(slices: Vec<Option<Plan>>) -> Option<Plan> {
    let mut best: Option<Plan> = None;
    for p in slices.into_iter().flatten() {
        if best.as_ref().map_or(true, |b| p.time_us < b.time_us) {
            best = Some(p);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Platform;
    use crate::models::{build_training, ModelCfg};
    use crate::pblock::build_parallel_blocks;
    use crate::profiler::{profile_model, ProfileOptions};
    use crate::segment::extract_segments;
    use crate::spmd::Mesh;

    fn setup(layers: usize) -> (SegmentSet, ProfileDb) {
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(layers);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let ss = extract_segments(&g, &bs);
        let opts = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
        let db = profile_model(&g, &bs, &ss, &opts);
        (ss, db)
    }

    fn assert_plan_bits_eq(a: &Plan, b: &Plan, what: &str) {
        assert_eq!(a.choice, b.choice, "{what}: choice");
        assert!(
            a.time_us.to_bits() == b.time_us.to_bits(),
            "{what}: time {} vs {}",
            a.time_us,
            b.time_us
        );
        assert_eq!(a.mem_bytes, b.mem_bytes, "{what}: mem");
    }

    #[test]
    fn dp_matches_brute_force_unconstrained() {
        let (ss, db) = setup(2);
        let dp = search(&ss, &db, None).unwrap();
        let bf = brute_force(&ss, &db, None).unwrap();
        assert!((dp.time_us - bf.time_us).abs() < 1e-6, "{} vs {}", dp.time_us, bf.time_us);
    }

    #[test]
    fn dp_matches_brute_force_under_memory_caps() {
        let (ss, db) = setup(2);
        let unconstrained = search(&ss, &db, None).unwrap();
        // sweep caps from tight to loose
        for frac in [0.7, 0.85, 1.0, 1.3] {
            let cap = (unconstrained.mem_bytes as f64 * frac) as u64;
            let dp = search(&ss, &db, Some(cap));
            let bf = brute_force(&ss, &db, Some(cap));
            match (dp, bf) {
                (Some(d), Some(b)) => {
                    assert!(
                        d.time_us <= b.time_us * 1.02 + 1e-6,
                        "cap {frac}: dp {} vs bf {}",
                        d.time_us,
                        b.time_us
                    );
                    assert!(d.mem_bytes <= cap);
                }
                (None, None) => {}
                (d, b) => panic!("feasibility mismatch at {frac}: {d:?} vs {b:?}"),
            }
        }
    }

    #[test]
    fn tighter_memory_never_speeds_up() {
        let (ss, db) = setup(3);
        let loose = search(&ss, &db, None).unwrap();
        let tight = search(&ss, &db, Some(loose.mem_bytes - 1));
        if let Some(t) = tight {
            assert!(t.time_us >= loose.time_us - 1e-9);
            assert!(t.mem_bytes < loose.mem_bytes);
        }
    }

    #[test]
    fn mixed_configs_can_beat_uniform_under_cap() {
        // §4.4: per-instance freedom dominates uniform-per-fingerprint
        let (ss, db) = setup(3);
        let free = search(&ss, &db, None).unwrap();
        for frac in [0.8, 0.9] {
            let cap = (free.mem_bytes as f64 * frac) as u64;
            let mixed = search(&ss, &db, Some(cap));
            let uni = search_uniform(&ss, &db, Some(cap));
            if let (Some(m), Some(u)) = (mixed, uni) {
                assert!(m.time_us <= u.time_us + 1e-9, "mixed {} uniform {}", m.time_us, u.time_us);
            }
        }
    }

    #[test]
    fn parallel_brute_force_identical_to_sequential() {
        // parallel partitions merge with the sequential tie-break, so the
        // whole Plan (not just its cost) must match bit-for-bit
        let (ss, db) = setup(2);
        let free = brute_force(&ss, &db, None).unwrap();
        for threads in [2usize, 4, 7] {
            let par = brute_force_with(&ss, &db, None, threads).unwrap();
            assert_eq!(par.choice, free.choice, "threads={threads}");
            assert!(par.time_us == free.time_us, "threads={threads}");
            assert_eq!(par.mem_bytes, free.mem_bytes, "threads={threads}");
        }
        let cap = Some((free.mem_bytes as f64 * 0.9) as u64);
        assert_eq!(brute_force(&ss, &db, cap), brute_force_with(&ss, &db, cap, 4));
    }

    #[test]
    fn parallel_search_uniform_identical_to_sequential() {
        let (ss, db) = setup(3);
        let seq = search_uniform(&ss, &db, None);
        assert_eq!(seq, search_uniform_with(&ss, &db, None, 4));
        if let Some(p) = &seq {
            let cap = Some(p.mem_bytes);
            assert_eq!(search_uniform(&ss, &db, cap), search_uniform_with(&ss, &db, cap, 3));
        }
        // an infeasible cap must agree on None, too
        assert_eq!(
            search_uniform(&ss, &db, Some(1)),
            search_uniform_with(&ss, &db, Some(1), 4)
        );
    }

    #[test]
    fn span_search_full_range_equals_whole_chain() {
        let (ss, db) = setup(3);
        let whole = search(&ss, &db, None).unwrap();
        let span = search_span(&ss, &db, None, 0, ss.instances.len()).unwrap();
        assert_eq!(whole, span);
    }

    #[test]
    fn span_search_solves_every_sub_chain_consistently() {
        let (ss, db) = setup(3);
        let n = ss.instances.len();
        for lo in 0..n {
            for hi in (lo + 1)..=n {
                let p = search_span(&ss, &db, None, lo, hi).unwrap();
                let (t, m) = plan_cost_span(&ss, &db, &p.choice, lo, hi);
                assert!((t - p.time_us).abs() < 1e-6, "[{lo},{hi}) {t} vs {}", p.time_us);
                assert_eq!(m, p.mem_bytes, "[{lo},{hi})");
                assert_eq!(p.choice.len(), hi - lo);
            }
        }
    }

    #[test]
    fn search_span_matches_reference_on_real_profiles() {
        // the repetition-aware core vs the pre-refactor DP, on a real
        // profiled chain (the property suite covers randomized ones)
        let (ss, db) = setup(4);
        let n = ss.instances.len();
        let free = search(&ss, &db, None).unwrap();
        let caps = [None, Some(free.mem_bytes), Some((free.mem_bytes as f64 * 0.9) as u64)];
        for lo in 0..n {
            for hi in (lo + 1)..=n {
                for cap in caps {
                    let new = search_span(&ss, &db, cap, lo, hi);
                    let reference = oracle::search_span_reference(&ss, &db, cap, lo, hi);
                    match (new, reference) {
                        (Some(a), Some(b)) => {
                            assert_plan_bits_eq(&a, &b, &format!("[{lo},{hi}) cap {cap:?}"))
                        }
                        (None, None) => {}
                        (a, b) => panic!("[{lo},{hi}) cap {cap:?}: {a:?} vs {b:?}"),
                    }
                }
            }
        }
    }

    #[test]
    fn steady_state_splice_matches_reference_on_deep_chains() {
        // 64 identical layers: the scalar lane must enter the splice and
        // still reproduce the reference bit-for-bit
        let (ss, db) = setup(64);
        let n = ss.instances.len();
        assert!(n > 60, "deep chain expected");
        let new = search(&ss, &db, None).unwrap();
        let reference = oracle::search_span_reference(&ss, &db, None, 0, n).unwrap();
        assert_plan_bits_eq(&new, &reference, "64-layer unconstrained");
        let (t, m) = plan_cost(&ss, &db, &new.choice);
        assert!((t - new.time_us).abs() < 1e-6 * t.max(1.0));
        assert_eq!(m, new.mem_bytes);
    }

    #[test]
    fn splice_engages_on_exact_arithmetic_chains_and_stays_exact() {
        // dyadic values: every DP addition is exact, so the steady state
        // has bitwise-uniform deltas and the splice MUST engage — and
        // still reproduce the reference bit-for-bit
        use crate::profiler::{ReshardTable, SegmentConfig, SegmentProfile};
        use crate::segment::{SegmentInstance, UniqueSegment};
        use crate::spmd::ShardState;
        let prof = SegmentProfile {
            configs: (0..3).map(|c| SegmentConfig { strategy: vec![c] }).collect(),
            t_c_us: vec![8.0, 4.0, 2.0],
            t_p_us: vec![16.0, 32.0, 64.0],
            mem_bytes: vec![100, 200, 400],
            act_bytes: vec![50, 100, 200],
            ckpt_bytes: vec![10, 10, 10],
            t_fwd_us: vec![4.0, 4.0, 4.0],
            symbolic_volume: vec![0; 3],
            boundary_out: vec![ShardState::Replicated; 3],
            boundary_in: vec![ShardState::Replicated; 3],
        };
        let mut db = ProfileDb::default();
        db.segments.push(prof);
        db.reshard.insert(
            (0, 0),
            ReshardTable {
                t_r_us: vec![
                    vec![0.5, 2.0, 8.0],
                    vec![2.0, 0.25, 4.0],
                    vec![8.0, 4.0, 0.125],
                ],
                sym_vol: vec![vec![0; 3]; 3],
                programs: 9,
            },
        );
        let n = 300;
        let ss = SegmentSet {
            instances: (0..n)
                .map(|_| SegmentInstance { unique_id: 0, blocks: vec![], fwd_range: (0, 0) })
                .collect(),
            unique: vec![UniqueSegment { id: 0, fingerprint: "u0".into(), rep: 0, count: n }],
        };
        let before = super::dp::SPLICED_STEPS.load(std::sync::atomic::Ordering::Relaxed);
        let new = search(&ss, &db, None).unwrap();
        let after = super::dp::SPLICED_STEPS.load(std::sync::atomic::Ordering::Relaxed);
        assert!(after > before, "the splice must engage on an exact-arithmetic repeated chain");
        let reference = oracle::search_span_reference(&ss, &db, None, 0, n).unwrap();
        assert_plan_bits_eq(&new, &reference, "exact 300-chain");
        // interior spans splice too (both transitions must be in-span)
        let a = search_span(&ss, &db, None, 3, n - 2).unwrap();
        let b = oracle::search_span_reference(&ss, &db, None, 3, n - 2).unwrap();
        assert_plan_bits_eq(&a, &b, "exact interior span");
    }

    #[test]
    fn mem_frontier_min_time_equals_unconstrained_search() {
        let (ss, db) = setup(3);
        let n = ss.instances.len();
        let plain = search(&ss, &db, None).unwrap();
        for spec in [RecomputeSpec::Off, RecomputeSpec::Auto] {
            let frontier = search_span_mem(&ss, &db, 0, n, spec);
            assert!(!frontier.is_empty());
            let best = frontier
                .iter()
                .min_by(|a, b| a.time_us.partial_cmp(&b.time_us).unwrap())
                .unwrap();
            assert!(
                (best.time_us - plain.time_us).abs() < 1e-9 * plain.time_us.max(1.0),
                "{spec:?}: {} vs {}",
                best.time_us,
                plain.time_us
            );
            assert!(best.remat.iter().all(|&r| !r), "the min-time point never recomputes");
            let fp = crate::memory::span_footprint(&ss, &db, &best.choice, 0, n);
            assert_eq!(fp.static_bytes, best.footprint.static_bytes);
            assert_eq!(fp.retained_bytes, best.footprint.retained_bytes);
            assert_eq!(best.footprint.transient_bytes, 0);
        }
    }

    #[test]
    fn mem_frontier_times_recompose_from_plan_cost() {
        let (ss, db) = setup(2);
        let n = ss.instances.len();
        let frontier = search_span_mem(&ss, &db, 0, n, RecomputeSpec::Auto);
        for p in &frontier {
            let (t, _) = plan_cost_span(&ss, &db, &p.choice, 0, n);
            assert!(
                (p.time_us - p.footprint.recompute_us - t).abs() <= 1e-6 * t.max(1.0),
                "time {} − recompute {} vs composed {t}",
                p.time_us,
                p.footprint.recompute_us
            );
            assert_eq!(p.choice.len(), n);
            assert_eq!(p.remat.len(), n);
        }
    }

    #[test]
    fn mem_frontier_matches_reference_on_real_profiles() {
        let (ss, db) = setup(3);
        let n = ss.instances.len();
        for spec in [RecomputeSpec::Off, RecomputeSpec::Auto] {
            for lo in 0..n {
                for hi in (lo + 1)..=n {
                    let new = search_span_mem(&ss, &db, lo, hi, spec);
                    let reference = oracle::search_span_mem_reference(&ss, &db, lo, hi, spec);
                    assert_eq!(new.len(), reference.len(), "[{lo},{hi}) {spec:?}");
                    for (a, b) in new.iter().zip(&reference) {
                        assert_eq!(a.choice, b.choice, "[{lo},{hi}) {spec:?}");
                        assert_eq!(a.remat, b.remat, "[{lo},{hi}) {spec:?}");
                        assert!(a.time_us.to_bits() == b.time_us.to_bits());
                        assert_eq!(a.footprint.static_bytes, b.footprint.static_bytes);
                        assert_eq!(a.footprint.retained_bytes, b.footprint.retained_bytes);
                        assert_eq!(a.footprint.transient_bytes, b.footprint.transient_bytes);
                        assert!(
                            a.footprint.recompute_us.to_bits() == b.footprint.recompute_us.to_bits()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn mem_frontier_auto_reaches_lower_peaks_with_slower_plans() {
        let (ss, db) = setup(3);
        let n = ss.instances.len();
        let off = search_span_mem(&ss, &db, 0, n, RecomputeSpec::Off);
        let auto_ = search_span_mem(&ss, &db, 0, n, RecomputeSpec::Auto);
        // at pipeline depth (several microbatches in flight) checkpointing
        // must unlock strictly lower peaks than any keep-everything plan
        let min_peak = |f: &[SpanMemPlan]| f.iter().map(|p| p.peak_bytes(8, 4)).min().unwrap();
        assert!(
            min_peak(&auto_) < min_peak(&off),
            "auto {} vs off {}",
            min_peak(&auto_),
            min_peak(&off)
        );
        // and every checkpointed point pays for it in time
        let best_time = off.iter().map(|p| p.time_us).fold(f64::INFINITY, f64::min);
        for p in &auto_ {
            if p.remat.iter().any(|&r| r) {
                assert!(p.time_us > best_time, "recompute is never free");
            }
        }
    }

    #[test]
    fn plan_cost_is_consistent_with_search_result() {
        let (ss, db) = setup(2);
        let plan = search(&ss, &db, None).unwrap();
        let (t, m) = plan_cost(&ss, &db, &plan.choice);
        assert!((t - plan.time_us).abs() < 1e-6);
        assert_eq!(m, plan.mem_bytes);
    }
}
