//! `SearchCtx`: the precomputed, flat view of a `(SegmentSet, ProfileDb)`
//! pair that the repetition-aware span DP runs on.
//!
//! The pre-refactor DP ([`super::oracle`]) paid three per-transition
//! costs in its innermost loop: a `HashMap` lookup plus an
//! `Option::and_then` chain for every reshard edge (`ProfileDb::
//! reshard_us`), a bounds-checked double index into the per-segment
//! column vectors, and (on the memory axis) a fresh `remat_points`
//! allocation per (position, config). A `SearchCtx` hoists all three
//! into construction time:
//!
//! * **SoA config columns** — `time[off[u] + cfg]` (= `t_c + t_p`),
//!   `mem`, `stat` (= profile memory minus activations) and `act` are
//!   flat vectors over all (unique, config) pairs, in unique-id then
//!   config order.
//! * **Dense transition matrices** — for every *adjacent unique pair*
//!   that actually occurs in the chain, a row-major `from_cfg × to_cfg`
//!   reshard matrix (`mats`), with `step_mat[i]` naming the matrix for
//!   the transition into chain position `i`. Absent tables dense-expand
//!   to the same `0.0` the hash lookup defaulted to, so values are
//!   unchanged bit-for-bit.
//! * **Remat frontiers** — one [`crate::memory::RematTable`] shared by
//!   every memory-axis search over this context.
//!
//! Construction is `O(chain + Σ_pairs C²)` — noise next to a single DP
//! pass — and the context is immutable afterwards, so the inter-op
//! planner wraps it in an `Arc` and fans sweep jobs over the thread
//! pool against one shared copy.

use std::collections::HashMap;

use crate::memory::{RecomputeSpec, RematPoint, RematTable};
use crate::profiler::ProfileDb;
use crate::segment::SegmentSet;

/// Precomputed flat view of one `(SegmentSet, ProfileDb)` pair.
pub struct SearchCtx {
    /// chain length (instances)
    pub(super) n: usize,
    /// unique id per chain position
    pub(super) uid: Vec<usize>,
    /// config count per unique
    pub(super) ncfg: Vec<usize>,
    /// flat-column offset per unique (len = uniques + 1)
    pub(super) off: Vec<usize>,
    /// `t_c + t_p` per (unique, config)
    pub(super) time: Vec<f64>,
    /// profile peak memory per (unique, config)
    pub(super) mem: Vec<u64>,
    /// static (non-activation) bytes per (unique, config)
    pub(super) stat: Vec<u64>,
    /// transition-matrix id per chain position (`step_mat[i]` prices the
    /// edge from position `i − 1` into `i`; `step_mat[0]` is unused)
    pub(super) step_mat: Vec<usize>,
    /// dense reshard matrices, row-major `[from_cfg * ncfg_to + to_cfg]`
    pub(super) mats: Vec<Vec<f64>>,
    /// rematerialization frontiers per flat (unique, config)
    pub(super) remat: RematTable,
    /// observability sink shared by every lane searching this context
    /// (disabled by default — one `Option` branch per counting site)
    pub(super) trace: crate::obs::Trace,
}

impl SearchCtx {
    pub fn new(ss: &SegmentSet, db: &ProfileDb) -> SearchCtx {
        SearchCtx::with_trace(ss, db, crate::obs::Trace::disabled())
    }

    /// Like [`SearchCtx::new`] but with a live [`crate::obs::Trace`]
    /// that every lane (scalar / Pareto / memory / exact / sweep /
    /// SP-DAG) searching this context will count into.
    pub fn with_trace(ss: &SegmentSet, db: &ProfileDb, trace: crate::obs::Trace) -> SearchCtx {
        let uniques = db.segments.len();
        let mut ncfg = Vec::with_capacity(uniques);
        let mut off = Vec::with_capacity(uniques + 1);
        off.push(0usize);
        for p in &db.segments {
            ncfg.push(p.configs.len());
            off.push(off.last().unwrap() + p.configs.len());
        }
        let total = *off.last().unwrap();
        let mut time = Vec::with_capacity(total);
        let mut mem = Vec::with_capacity(total);
        let mut stat = Vec::with_capacity(total);
        for p in &db.segments {
            for cfg in 0..p.configs.len() {
                // the same float op the oracle's inner loop performs
                time.push(p.t_c_us[cfg] + p.t_p_us[cfg]);
                mem.push(p.mem_bytes[cfg]);
                stat.push(crate::memory::seg_static_bytes(p, cfg));
            }
        }

        let n = ss.instances.len();
        let uid: Vec<usize> = ss.instances.iter().map(|i| i.unique_id).collect();
        let mut mats: Vec<Vec<f64>> = Vec::new();
        let mut by_pair: HashMap<(usize, usize), usize> = HashMap::new();
        let mut step_mat = vec![usize::MAX; n];
        for i in 1..n {
            let pair = (uid[i - 1], uid[i]);
            let id = *by_pair.entry(pair).or_insert_with(|| {
                let (a, b) = pair;
                let (ca, cb) = (ncfg[a], ncfg[b]);
                let mut m = Vec::with_capacity(ca * cb);
                for fc in 0..ca {
                    for tc in 0..cb {
                        m.push(db.reshard_us(a, fc, b, tc));
                    }
                }
                mats.push(m);
                mats.len() - 1
            });
            step_mat[i] = id;
        }

        SearchCtx {
            n,
            uid,
            ncfg,
            off,
            time,
            mem,
            stat,
            step_mat,
            mats,
            remat: RematTable::build(db),
            trace,
        }
    }

    /// The observability sink threaded through this context.
    pub fn trace(&self) -> &crate::obs::Trace {
        &self.trace
    }

    /// Chain length.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    // Flat-column accessors for searchers outside `cost` (the SP-DAG
    // planner in `crate::spdag` runs its branch DPs on these columns, so
    // its values replay this context's float ops exactly).

    /// Unique id at chain position `i`.
    pub fn uid_at(&self, i: usize) -> usize {
        self.uid[i]
    }

    /// Config count at chain position `i`.
    pub fn ncfg_at(&self, i: usize) -> usize {
        self.ncfg[self.uid[i]]
    }

    /// Flat column offset of position `i`'s unique (index with
    /// `off_at(i) + cfg` into the column slices).
    pub fn off_at(&self, i: usize) -> usize {
        self.off[self.uid[i]]
    }

    /// `t_c + t_p` per flat (unique, config).
    pub fn time_col(&self) -> &[f64] {
        &self.time
    }

    /// Profile peak memory per flat (unique, config).
    pub fn mem_col(&self) -> &[u64] {
        &self.mem
    }

    /// Static (non-activation) bytes per flat (unique, config).
    pub fn stat_col(&self) -> &[u64] {
        &self.stat
    }

    /// Dense reshard matrix pricing the chain edge `i − 1 → i`,
    /// row-major `[from_cfg * ncfg_at(i) + to_cfg]`.
    pub fn step_matrix(&self, i: usize) -> &[f64] {
        &self.mats[self.step_mat[i]]
    }

    /// Remat frontier for flat column index `flat` under `spec`.
    pub fn remat_at(&self, flat: usize, spec: RecomputeSpec) -> &[RematPoint] {
        self.remat.points(flat, spec)
    }

    /// True when the DP step into position `i` is the *same* min-plus
    /// transition as the step into `i − 1`: both endpoints and the
    /// transition matrix repeat, so the two steps are interchangeable —
    /// the unit the steady-state splice collapses.
    pub(super) fn repeated_step(&self, i: usize) -> bool {
        i >= 2 && self.uid[i] == self.uid[i - 1] && self.uid[i - 1] == self.uid[i - 2]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profiler::{ReshardTable, SegmentConfig, SegmentProfile};
    use crate::segment::{SegmentInstance, UniqueSegment};
    use crate::spmd::ShardState;

    fn profile(cfgs: usize, base: f64) -> SegmentProfile {
        SegmentProfile {
            configs: (0..cfgs).map(|c| SegmentConfig { strategy: vec![c] }).collect(),
            t_c_us: (0..cfgs).map(|c| base + c as f64).collect(),
            t_p_us: (0..cfgs).map(|c| 2.0 * base + c as f64).collect(),
            mem_bytes: (0..cfgs).map(|c| 1000 + 10 * c as u64).collect(),
            act_bytes: (0..cfgs).map(|c| 600 + c as u64).collect(),
            ckpt_bytes: vec![50; cfgs],
            t_fwd_us: vec![base; cfgs],
            symbolic_volume: vec![0; cfgs],
            boundary_out: vec![ShardState::Replicated; cfgs],
            boundary_in: vec![ShardState::Replicated; cfgs],
        }
    }

    fn chain(uids: &[usize], uniques: usize) -> SegmentSet {
        let instances: Vec<SegmentInstance> = uids
            .iter()
            .map(|&u| SegmentInstance { unique_id: u, blocks: vec![], fwd_range: (0, 0) })
            .collect();
        let unique = (0..uniques)
            .map(|u| UniqueSegment {
                id: u,
                fingerprint: format!("u{u}"),
                rep: uids.iter().position(|&x| x == u).unwrap_or(0),
                count: uids.iter().filter(|&&x| x == u).count(),
            })
            .collect();
        SegmentSet { instances, unique }
    }

    #[test]
    fn ctx_mirrors_db_columns_and_reshard_tables() {
        let mut db = ProfileDb::default();
        db.segments.push(profile(2, 10.0));
        db.segments.push(profile(3, 20.0));
        db.reshard.insert(
            (0, 1),
            ReshardTable {
                t_r_us: vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]],
                sym_vol: vec![vec![0; 3]; 2],
                programs: 6,
            },
        );
        let ss = chain(&[0, 1, 1, 0], 2);
        let ctx = SearchCtx::new(&ss, &db);
        assert_eq!(ctx.len(), 4);
        assert_eq!(ctx.off, vec![0, 2, 5]);
        for u in 0..2usize {
            let p = &db.segments[u];
            for cfg in 0..p.configs.len() {
                let f = ctx.off[u] + cfg;
                assert_eq!(ctx.time[f], p.t_c_us[cfg] + p.t_p_us[cfg]);
                assert_eq!(ctx.mem[f], p.mem_bytes[cfg]);
            }
        }
        // dense matrices reproduce reshard_us incl. the 0.0 default for
        // the absent (1, 1) and (1, 0) tables
        for i in 1..4 {
            let (a, b) = (ctx.uid[i - 1], ctx.uid[i]);
            let m = &ctx.mats[ctx.step_mat[i]];
            for fc in 0..ctx.ncfg[a] {
                for tc in 0..ctx.ncfg[b] {
                    assert_eq!(m[fc * ctx.ncfg[b] + tc], db.reshard_us(a, fc, b, tc));
                }
            }
        }
        // repeated-step detection: only position 2 follows an identical edge
        assert!(!ctx.repeated_step(1));
        assert!(!ctx.repeated_step(2), "edge (0,1) then (1,1) differ");
        let ss = chain(&[0, 0, 0, 1], 2);
        let ctx = SearchCtx::new(&ss, &db);
        assert!(ctx.repeated_step(2));
        assert!(!ctx.repeated_step(3));
    }
}
