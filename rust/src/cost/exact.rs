//! Exact optimality lane (PR 6): a deterministic, pure-std
//! branch-and-bound searcher over the same [`SearchCtx`] SoA columns the
//! DP lanes run on — an oracle that shares **no pruning assumptions**
//! with them.
//!
//! Every equivalence guarantee before this module checked the
//! repetition-aware search against the *pre-refactor version of the same
//! DP* ([`super::oracle`]) — a shared-blind-spot baseline that cannot
//! catch a bug both algorithms inherit, and in particular cannot see the
//! one approximation both share: `FRONTIER_CAP` / `MEM_FRONTIER_CAP`
//! thinning. This lane enumerates the assignment space itself:
//!
//! * **Scalar / capped lanes** ([`search_span_exact`]) — depth-first
//!   branch-and-bound over per-instance configs. The state is the prefix
//!   `(time, mem)` accumulated with the DP's *own* float association
//!   (`(acc + reshard) + seg_time` per step), so the optimum it finds is
//!   bit-identical to the DP's whenever the DP is exact. Bounding is the
//!   admissible suffix relaxation `Σ (min_cfg seg_time + min reshard
//!   edge)` with a deterministic downward slack (×(1 − 1e-9), covering
//!   the ≤ n·ε relative rounding of the true remaining float sums, so a
//!   bound can never over-prune), plus an exact-integer suffix-min-memory
//!   prune under a cap. Children expand in ascending config order and the
//!   incumbent improves on lexicographic `(time, mem)` — fixed tie order,
//!   identical results at any thread count (the search is single-
//!   threaded by construction).
//! * **Memory-frontier lane** ([`search_span_mem_exact`]) — the exact
//!   Pareto set over (time, 1F1B footprint): the same (config × remat)
//!   product walk as the DP, but with **true dominance filtering only** —
//!   no running-min keep rule, no `MEM_FRONTIER_CAP` thinning. Dropping a
//!   dominated point is exact because every transition is monotone in
//!   every kept coordinate (float add of a constant, integer sums, max).
//!   Terminals are canonicalized by the reference's own
//!   (time, stat, ret, tra) sort + dominance rule, so outputs compare
//!   directly against [`super::search_span_mem_ctx`].
//!
//! Both lanes take a node/point budget and report exhaustion as a
//! distinguishable [`Exhausted`] outcome (never a wrong answer); the
//! portfolio dispatch in [`super::search_span_engine`] falls back to the
//! DP when a budget runs out. The budget check is a deterministic
//! function of the visited-node count, so the fallback decision is
//! bit-reproducible too.

use crate::memory::{RecomputeSpec, SpanFootprint, SpanMemPlan};
use crate::obs::Counter;

use super::ctx::SearchCtx;
use super::Plan;

/// Which plan-search engine [`super::search_span_engine`] dispatches to
/// (`--engine` on the CLI).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SearchEngine {
    /// The repetition-aware DP lanes (the production default).
    #[default]
    Dp,
    /// Branch-and-bound enumeration with a large node budget; falls back
    /// to the DP (with a stderr warning) only if the budget runs out.
    Exact,
    /// Exact when the assignment space is small (≤ [`AUTO_EXACT_BITS`]
    /// bits), DP otherwise — the portfolio for small-but-gnarly spaces
    /// where the DP's thinning is weakest relative to the space size.
    Auto,
}

impl SearchEngine {
    /// Parse an `--engine` CLI value: `exact`, `dp` or `auto`.
    pub fn parse(s: &str) -> Option<SearchEngine> {
        match s {
            "dp" => Some(SearchEngine::Dp),
            "exact" => Some(SearchEngine::Exact),
            "auto" => Some(SearchEngine::Auto),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SearchEngine::Dp => "dp",
            SearchEngine::Exact => "exact",
            SearchEngine::Auto => "auto",
        }
    }
}

/// `auto` prefers the exact lane when `space_bits ≤ 16` (≤ 65 536
/// assignments): small enough that branch-and-bound with suffix bounds
/// is comfortably sub-millisecond, large enough to cover every space the
/// thinning approximation could plausibly distort end-to-end.
pub const AUTO_EXACT_BITS: f64 = 16.0;

/// Node budget for an explicit `--engine exact` request (generous: the
/// user asked for certainty, so only a genuinely exponential blow-up
/// falls back).
pub const EXACT_NODE_BUDGET: u64 = 50_000_000;

/// Node budget for `auto`'s exact probes (bounded so a pathological
/// small-bits-but-tie-heavy instance cannot stall the planner).
pub const AUTO_NODE_BUDGET: u64 = 4_000_000;

/// The search ran out of its node/point budget before proving
/// optimality. Never a wrong answer — callers fall back to the DP.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Exhausted;

/// log₂ of the per-instance config assignment space of span `[lo, hi)` —
/// the size measure `auto` dispatches on (remat choices are not counted:
/// they at most double per instance and the memory lane has its own
/// budget).
pub fn space_bits(ctx: &SearchCtx, lo: usize, hi: usize) -> f64 {
    let mut bits = 0.0;
    for pos in lo..hi {
        let cc = ctx.ncfg[ctx.uid[pos]];
        if cc > 1 {
            bits += (cc as f64).log2();
        }
    }
    bits
}

// ------------------------------------------------------------ scalar / capped

/// Exact min-time plan for `[lo, hi)` under an optional memory cap, with
/// an unbounded node budget — the property-suite entry point. Same
/// `None` semantics as [`super::search_span_ctx`]: empty span, an
/// empty config space, or a cap below every assignment.
pub fn search_span_exact(
    ctx: &SearchCtx,
    mem_cap: Option<u64>,
    lo: usize,
    hi: usize,
) -> Option<Plan> {
    match search_span_exact_budget(ctx, mem_cap, lo, hi, u64::MAX) {
        Ok(p) => p,
        Err(Exhausted) => unreachable!("unbounded budget cannot exhaust"),
    }
}

/// [`search_span_exact`] with a node budget: every (position, config)
/// trial counts one node, and exceeding `budget` aborts with
/// [`Exhausted`] instead of returning a possibly-suboptimal incumbent.
pub fn search_span_exact_budget(
    ctx: &SearchCtx,
    mem_cap: Option<u64>,
    lo: usize,
    hi: usize,
    budget: u64,
) -> Result<Option<Plan>, Exhausted> {
    assert!(lo <= hi && hi <= ctx.len());
    let n = hi - lo;
    if n == 0 {
        return Ok(None);
    }
    let (lb_time, lb_mem) = suffix_bounds(ctx, lo, hi);
    let mut bb = Bb {
        ctx,
        lo,
        n,
        cap: mem_cap,
        lb_time,
        lb_mem,
        cur: vec![0usize; n],
        best: None,
        nodes: 0,
        bound_pruned: 0,
        mem_pruned: 0,
        budget,
        exhausted: false,
    };
    bb.dfs(0, 0.0, 0);
    if ctx.trace.is_enabled() {
        ctx.trace.count(Counter::ExactNodes, bb.nodes);
        ctx.trace.count(Counter::ExactBoundPruned, bb.bound_pruned);
        ctx.trace.count(Counter::ExactMemPruned, bb.mem_pruned);
        if bb.exhausted {
            ctx.trace.count(Counter::ExactExhausted, 1);
        }
    }
    if bb.exhausted {
        return Err(Exhausted);
    }
    Ok(bb
        .best
        .map(|(time_us, mem_bytes, choice)| Plan { choice, time_us, mem_bytes }))
}

/// Admissible suffix relaxations for `[lo, hi)`, indexed span-relative
/// (`[i]` bounds the remainder *from* position `lo + i`; `[n]` is 0):
///
/// * time: `Σ_{j ≥ i} (min_cfg seg_time[j] + min entry of the reshard
///   matrix into j)`, deflated by ×(1 − 1e-9). The raw sum never exceeds
///   the real remaining cost; the deflation absorbs the ≤ n·ε relative
///   rounding of the float-evaluated completion (n·ε ≈ 1e-12 even at
///   10⁴ positions), so `partial + bound > incumbent` can never prune a
///   true optimum or a tie. Assumes non-negative profiled times (every
///   producer in this repo guarantees it).
/// * mem: exact integer `Σ_{j ≥ i} min_cfg seg_mem[j]` — the cap prune
///   needs no slack.
fn suffix_bounds(ctx: &SearchCtx, lo: usize, hi: usize) -> (Vec<f64>, Vec<u64>) {
    let n = hi - lo;
    let mut lb_time = vec![0.0f64; n + 1];
    let mut lb_mem = vec![0u64; n + 1];
    for i in (0..n).rev() {
        let pos = lo + i;
        let u = ctx.uid[pos];
        let o = ctx.off[u];
        let cc = ctx.ncfg[u];
        let mut min_t = f64::INFINITY;
        let mut min_m = u64::MAX;
        for c in 0..cc {
            min_t = min_t.min(ctx.time[o + c]);
            min_m = min_m.min(ctx.mem[o + c]);
        }
        if cc == 0 {
            // dead-end position: no completion exists, the DFS stops at
            // it anyway — keep the bounds harmless
            min_t = 0.0;
            min_m = 0;
        }
        debug_assert!(min_t >= 0.0, "profiled times must be non-negative");
        let mut edge = 0.0f64;
        if i > 0 {
            let mat = &ctx.mats[ctx.step_mat[pos]];
            if !mat.is_empty() {
                edge = mat.iter().copied().fold(f64::INFINITY, f64::min);
            }
        }
        lb_time[i] = lb_time[i + 1] + min_t + edge;
        lb_mem[i] = lb_mem[i + 1].saturating_add(min_m);
    }
    for v in lb_time.iter_mut() {
        *v *= 1.0 - 1e-9;
    }
    (lb_time, lb_mem)
}

struct Bb<'a> {
    ctx: &'a SearchCtx,
    lo: usize,
    n: usize,
    cap: Option<u64>,
    /// deflated admissible remaining-time bound per span-relative position
    lb_time: Vec<f64>,
    /// exact remaining-memory minimum per span-relative position
    lb_mem: Vec<u64>,
    cur: Vec<usize>,
    best: Option<(f64, u64, Vec<usize>)>,
    nodes: u64,
    /// children cut by the admissible suffix time bound
    bound_pruned: u64,
    /// children cut by the exact integer memory prune
    mem_pruned: u64,
    budget: u64,
    exhausted: bool,
}

impl Bb<'_> {
    /// Extend the prefix `cur[..i]` (accumulated `(acc_t, acc_m)`) by
    /// every config of position `i`, in ascending order. `acc_t` replays
    /// the DP's exact float association — `(acc + reshard) + seg_time` —
    /// so a completed leaf's value is bit-identical to the DP's value
    /// for the same assignment.
    fn dfs(&mut self, i: usize, acc_t: f64, acc_m: u64) {
        if i == self.n {
            let better = match &self.best {
                None => true,
                Some((bt, bm, _)) => acc_t < *bt || (acc_t == *bt && acc_m < *bm),
            };
            if better {
                self.best = Some((acc_t, acc_m, self.cur.clone()));
            }
            return;
        }
        let pos = self.lo + i;
        let u = self.ctx.uid[pos];
        let o = self.ctx.off[u];
        let cc = self.ctx.ncfg[u];
        let prev_cfg = if i == 0 { 0 } else { self.cur[i - 1] };
        for c in 0..cc {
            self.nodes += 1;
            if self.nodes > self.budget
                // budget-exhaustion fault at a chosen node; gated so the
                // unbounded wrappers' unreachable!() stays unreachable
                || (self.budget != u64::MAX
                    && crate::util::failpoint::should_trip("exact.budget_exhaust"))
            {
                self.exhausted = true;
                return;
            }
            let t = if i == 0 {
                self.ctx.time[o + c]
            } else {
                let mat = &self.ctx.mats[self.ctx.step_mat[pos]];
                (acc_t + mat[prev_cfg * cc + c]) + self.ctx.time[o + c]
            };
            let m = acc_m + self.ctx.mem[o + c];
            if let Some(cap) = self.cap {
                // exact integer prune: even the leanest completion busts the cap
                if m.saturating_add(self.lb_mem[i + 1]) > cap {
                    self.mem_pruned += 1;
                    continue;
                }
            }
            if let Some((bt, _, _)) = &self.best {
                // strict `>`: equal-bound subtrees are explored, so exact
                // time ties still reach the (time, mem) tie-break
                if t + self.lb_time[i + 1] > *bt {
                    self.bound_pruned += 1;
                    continue;
                }
            }
            self.cur[i] = c;
            self.dfs(i + 1, t, m);
            if self.exhausted {
                return;
            }
        }
    }
}

// ------------------------------------------------------------ memory frontier

/// One state of the exact memory-frontier enumeration — same coordinates
/// as the DP's point (time with recompute folded in, the three 1F1B
/// footprint components), kept as a *true* Pareto set.
#[derive(Clone, Copy, Debug)]
struct ExMemPoint {
    time: f64,
    recompute: f64,
    stat: u64,
    ret: u64,
    tra: u64,
    ckpt: bool,
    prev_cfg: usize,
    prev_idx: usize,
}

/// Exact (time, 1F1B-footprint) Pareto frontier of `[lo, hi)` — the
/// untruncated counterpart of [`super::search_span_mem_ctx`], with an
/// unbounded point budget. Every returned plan is achievable; every
/// achievable (config, remat) assignment is dominated by (or equal to)
/// a returned plan on (time, stat, ret, tra).
pub fn search_span_mem_exact(
    ctx: &SearchCtx,
    lo: usize,
    hi: usize,
    spec: RecomputeSpec,
) -> Vec<SpanMemPlan> {
    match search_span_mem_exact_budget(ctx, lo, hi, spec, u64::MAX) {
        Ok(f) => f,
        Err(Exhausted) => unreachable!("unbounded budget cannot exhaust"),
    }
}

/// [`search_span_mem_exact`] with a budget on generated candidate
/// points (the exact frontier can grow exponentially on adversarial
/// inputs; the DP's thinned frontier cannot).
pub fn search_span_mem_exact_budget(
    ctx: &SearchCtx,
    lo: usize,
    hi: usize,
    spec: RecomputeSpec,
    max_points: u64,
) -> Result<Vec<SpanMemPlan>, Exhausted> {
    assert!(lo <= hi && hi <= ctx.len());
    let n = hi - lo;
    if n == 0 {
        return Ok(Vec::new());
    }
    let mut generated = 0u64;
    let mut frontiers: Vec<Vec<Vec<ExMemPoint>>> = Vec::with_capacity(n);

    // first position: one candidate per (config, remat point)
    {
        let u = ctx.uid[lo];
        let o = ctx.off[u];
        let mut sets: Vec<Vec<ExMemPoint>> = Vec::with_capacity(ctx.ncfg[u]);
        for c in 0..ctx.ncfg[u] {
            let seg_t = ctx.time[o + c];
            let stat = ctx.stat[o + c];
            let mut pts: Vec<ExMemPoint> = ctx
                .remat
                .points(o + c, spec)
                .iter()
                .map(|r| ExMemPoint {
                    time: seg_t + r.extra_us,
                    recompute: r.extra_us,
                    stat,
                    ret: r.retained_bytes,
                    tra: r.transient_bytes,
                    ckpt: r.checkpoint,
                    prev_cfg: usize::MAX,
                    prev_idx: usize::MAX,
                })
                .collect();
            generated += pts.len() as u64;
            pareto_filter(&mut pts);
            sets.push(pts);
        }
        if generated > max_points
            || (max_points != u64::MAX
                && crate::util::failpoint::should_trip("exact.budget_exhaust"))
        {
            ctx.trace.count(Counter::ExactNodes, generated);
            ctx.trace.count(Counter::ExactExhausted, 1);
            return Err(Exhausted);
        }
        frontiers.push(sets);
    }

    for i in 1..n {
        let pos = lo + i;
        let u = ctx.uid[pos];
        let o = ctx.off[u];
        let cc = ctx.ncfg[u];
        let mat = &ctx.mats[ctx.step_mat[pos]];
        let prev = &frontiers[i - 1];
        let mut sets: Vec<Vec<ExMemPoint>> = Vec::with_capacity(cc);
        for c in 0..cc {
            let seg_t = ctx.time[o + c];
            let stat = ctx.stat[o + c];
            let rpts = ctx.remat.points(o + c, spec);
            let mut pts: Vec<ExMemPoint> = Vec::new();
            for (pcfg, pset) in prev.iter().enumerate() {
                if pset.is_empty() {
                    continue;
                }
                let tr = mat[pcfg * cc + c];
                for (pidx, pp) in pset.iter().enumerate() {
                    for r in rpts {
                        // the DP's exact float association:
                        // ((acc + tr) + seg_t) + extra
                        pts.push(ExMemPoint {
                            time: pp.time + tr + seg_t + r.extra_us,
                            recompute: pp.recompute + r.extra_us,
                            stat: pp.stat + stat,
                            ret: pp.ret + r.retained_bytes,
                            tra: pp.tra.max(r.transient_bytes),
                            ckpt: r.checkpoint,
                            prev_cfg: pcfg,
                            prev_idx: pidx,
                        });
                    }
                }
            }
            generated += pts.len() as u64;
            if generated > max_points
                || (max_points != u64::MAX
                    && crate::util::failpoint::should_trip("exact.budget_exhaust"))
            {
                ctx.trace.count(Counter::ExactNodes, generated);
                ctx.trace.count(Counter::ExactExhausted, 1);
                return Err(Exhausted);
            }
            pareto_filter(&mut pts);
            sets.push(pts);
        }
        frontiers.push(sets);
    }

    ctx.trace.count(Counter::ExactNodes, generated);

    // terminal canonicalization: the reference's exact rule — sort every
    // surviving point by (time, stat, ret, tra), keep unless a kept
    // point dominates on the three footprint components
    let last = &frontiers[n - 1];
    let mut terminals: Vec<(usize, usize)> = Vec::new();
    for (cfg, pts) in last.iter().enumerate() {
        for idx in 0..pts.len() {
            terminals.push((cfg, idx));
        }
    }
    terminals.sort_by(|a, b| {
        let (pa, pb) = (&last[a.0][a.1], &last[b.0][b.1]);
        pa.time
            .partial_cmp(&pb.time)
            .unwrap()
            .then(pa.stat.cmp(&pb.stat))
            .then(pa.ret.cmp(&pb.ret))
            .then(pa.tra.cmp(&pb.tra))
    });
    let mut kept: Vec<(usize, usize)> = Vec::new();
    for t in terminals {
        let p = &last[t.0][t.1];
        let dominated = kept.iter().any(|&(c, i)| {
            let q = &last[c][i];
            q.stat <= p.stat && q.ret <= p.ret && q.tra <= p.tra
        });
        if !dominated {
            kept.push(t);
        }
    }
    Ok(kept
        .into_iter()
        .map(|(cfg, idx)| backtrack(&frontiers, n, cfg, idx))
        .collect())
}

/// True Pareto filter on (time, stat, ret, tra): sort lexicographically,
/// keep a point unless an already-kept one is ≤ on every coordinate
/// (earlier in sort order ⇒ time already ≤). Exact duplicates collapse
/// to their first occurrence. O(k²) — the exact lane trades speed for
/// zero approximation.
fn pareto_filter(pts: &mut Vec<ExMemPoint>) {
    pts.sort_by(|a, b| {
        a.time
            .partial_cmp(&b.time)
            .unwrap()
            .then(a.stat.cmp(&b.stat))
            .then(a.ret.cmp(&b.ret))
            .then(a.tra.cmp(&b.tra))
    });
    let mut w = 0usize;
    for r in 0..pts.len() {
        let p = pts[r];
        let dominated = pts[..w]
            .iter()
            .any(|q| q.stat <= p.stat && q.ret <= p.ret && q.tra <= p.tra);
        if !dominated {
            pts[w] = p;
            w += 1;
        }
    }
    pts.truncate(w);
}

fn backtrack(
    frontiers: &[Vec<Vec<ExMemPoint>>],
    n: usize,
    mut cfg: usize,
    mut idx: usize,
) -> SpanMemPlan {
    let terminal = frontiers[n - 1][cfg][idx];
    let mut choice = vec![0usize; n];
    let mut remat = vec![false; n];
    for i in (0..n).rev() {
        let p = frontiers[i][cfg][idx];
        choice[i] = cfg;
        remat[i] = p.ckpt;
        cfg = p.prev_cfg;
        idx = p.prev_idx;
    }
    SpanMemPlan {
        choice,
        remat,
        time_us: terminal.time,
        footprint: SpanFootprint {
            static_bytes: terminal.stat,
            retained_bytes: terminal.ret,
            transient_bytes: terminal.tra,
            recompute_us: terminal.recompute,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::super::{brute_force, search_span_ctx, search_span_mem_ctx};
    use super::*;
    use crate::profiler::{ProfileDb, ReshardTable, SegmentConfig, SegmentProfile};
    use crate::segment::{SegmentInstance, SegmentSet, UniqueSegment};
    use crate::spmd::ShardState;

    /// A dyadic-valued chain (every float op exact) with two uniques —
    /// small enough for the brute force, rich enough to exercise
    /// reshards, caps and remat points.
    fn dyadic_setup() -> (SegmentSet, ProfileDb) {
        let mut db = ProfileDb::default();
        for (base, cfgs) in [(8.0, 3usize), (4.0, 2usize)] {
            db.segments.push(SegmentProfile {
                configs: (0..cfgs).map(|c| SegmentConfig { strategy: vec![c] }).collect(),
                t_c_us: (0..cfgs).map(|c| base + c as f64).collect(),
                t_p_us: (0..cfgs).map(|c| 2.0 * base - c as f64 * 0.5).collect(),
                mem_bytes: (0..cfgs).map(|c| 1000 - 100 * c as u64).collect(),
                act_bytes: (0..cfgs).map(|c| 600 - 50 * c as u64).collect(),
                ckpt_bytes: vec![40; cfgs],
                t_fwd_us: vec![base / 2.0; cfgs],
                symbolic_volume: vec![0; cfgs],
                boundary_out: vec![ShardState::Replicated; cfgs],
                boundary_in: vec![ShardState::Replicated; cfgs],
            });
        }
        db.reshard.insert(
            (0, 1),
            ReshardTable {
                t_r_us: vec![vec![0.5, 2.0], vec![1.0, 0.25], vec![4.0, 0.125]],
                sym_vol: vec![vec![0; 2]; 3],
                programs: 6,
            },
        );
        db.reshard.insert(
            (1, 0),
            ReshardTable {
                t_r_us: vec![vec![0.5, 1.0, 2.0], vec![0.25, 4.0, 8.0]],
                sym_vol: vec![vec![0; 3]; 2],
                programs: 6,
            },
        );
        let uids = [0usize, 1, 0, 0, 1, 1, 0];
        let instances: Vec<SegmentInstance> = uids
            .iter()
            .map(|&u| SegmentInstance { unique_id: u, blocks: vec![], fwd_range: (0, 0) })
            .collect();
        let unique: Vec<UniqueSegment> = (0..2)
            .map(|u| UniqueSegment {
                id: u,
                fingerprint: format!("u{u}"),
                rep: uids.iter().position(|&x| x == u).unwrap(),
                count: uids.iter().filter(|&&x| x == u).count(),
            })
            .collect();
        (SegmentSet { instances, unique }, db)
    }

    #[test]
    fn exact_matches_brute_force_and_dp_on_dyadic_chain() {
        let (ss, db) = dyadic_setup();
        let ctx = SearchCtx::new(&ss, &db);
        let n = ss.instances.len();
        let free = brute_force(&ss, &db, None).unwrap();
        for cap in [None, Some(free.mem_bytes), Some(free.mem_bytes - 1), Some(1)] {
            let ex = search_span_exact(&ctx, cap, 0, n);
            let bf = brute_force(&ss, &db, cap);
            let dp = search_span_ctx(&ctx, cap, 0, n);
            // optimal *times* agree bitwise everywhere (dyadic values:
            // even the differently-associated brute-force sums are
            // exact); choice/mem may legitimately differ on exact time
            // ties, where each searcher's documented tie rule applies
            match (&ex, &bf) {
                (Some(e), Some(b)) => {
                    assert!(e.time_us.to_bits() == b.time_us.to_bits(), "cap {cap:?}");
                }
                (None, None) => {}
                _ => panic!("cap {cap:?}: exact {ex:?} vs brute force {bf:?}"),
            }
            match (&ex, &dp) {
                (Some(e), Some(d)) => {
                    assert!(e.time_us.to_bits() == d.time_us.to_bits(), "cap {cap:?}");
                }
                (None, None) => {}
                _ => panic!("cap {cap:?}: exact {ex:?} vs dp {dp:?}"),
            }
            // the exact plan is genuine: its choice vector re-prices to
            // its reported cost and respects the cap
            if let Some(e) = &ex {
                let (t, m) = super::super::plan_cost_span(&ss, &db, &e.choice, 0, n);
                assert!(t.to_bits() == e.time_us.to_bits(), "cap {cap:?}: reprice");
                assert_eq!(m, e.mem_bytes, "cap {cap:?}: reprice mem");
                if let Some(cap) = cap {
                    assert!(e.mem_bytes <= cap, "cap {cap}: plan must fit");
                }
            }
        }
    }

    #[test]
    fn exact_sub_spans_match_dp() {
        let (ss, db) = dyadic_setup();
        let ctx = SearchCtx::new(&ss, &db);
        let n = ss.instances.len();
        for lo in 0..n {
            for hi in (lo + 1)..=n {
                let ex = search_span_exact(&ctx, None, lo, hi).unwrap();
                let dp = search_span_ctx(&ctx, None, lo, hi).unwrap();
                assert!(ex.time_us.to_bits() == dp.time_us.to_bits(), "[{lo},{hi})");
                let (t, m) = super::super::plan_cost_span(&ss, &db, &ex.choice, lo, hi);
                assert!(t.to_bits() == ex.time_us.to_bits(), "[{lo},{hi}) reprice");
                assert_eq!(m, ex.mem_bytes, "[{lo},{hi}) reprice mem");
            }
        }
    }

    #[test]
    fn budget_exhaustion_is_reported_not_wrong() {
        let (ss, db) = dyadic_setup();
        let ctx = SearchCtx::new(&ss, &db);
        let n = ss.instances.len();
        assert_eq!(
            search_span_exact_budget(&ctx, None, 0, n, 2),
            Err(Exhausted),
            "a 2-node budget cannot cover a 7-instance chain"
        );
        // a generous budget completes and matches the unbounded result
        let bounded = search_span_exact_budget(&ctx, None, 0, n, 1 << 20).unwrap();
        assert_eq!(bounded, search_span_exact(&ctx, None, 0, n));
    }

    #[test]
    fn mem_exact_contains_and_dominates_the_dp_frontier() {
        let (ss, db) = dyadic_setup();
        let ctx = SearchCtx::new(&ss, &db);
        let n = ss.instances.len();
        for spec in [RecomputeSpec::Off, RecomputeSpec::Auto] {
            let dp = search_span_mem_ctx(&ctx, 0, n, spec);
            let ex = search_span_mem_exact(&ctx, 0, n, spec);
            assert!(!ex.is_empty());
            // min-time heads agree bitwise (the DP never thins its head)
            assert!(dp[0].time_us.to_bits() == ex[0].time_us.to_bits(), "{spec:?}");
            // every DP point is matched or dominated by an exact point
            for p in &dp {
                assert!(
                    ex.iter().any(|q| q.time_us <= p.time_us
                        && q.footprint.static_bytes <= p.footprint.static_bytes
                        && q.footprint.retained_bytes <= p.footprint.retained_bytes
                        && q.footprint.transient_bytes <= p.footprint.transient_bytes),
                    "{spec:?}: DP point (t={}, stat={}) not covered",
                    p.time_us,
                    p.footprint.static_bytes
                );
            }
        }
    }

    #[test]
    fn mem_exact_budget_exhaustion_is_reported() {
        let (ss, db) = dyadic_setup();
        let ctx = SearchCtx::new(&ss, &db);
        let n = ss.instances.len();
        assert!(matches!(
            search_span_mem_exact_budget(&ctx, 0, n, RecomputeSpec::Auto, 3),
            Err(Exhausted)
        ));
    }

    #[test]
    fn space_bits_counts_only_multi_config_positions() {
        let (ss, db) = dyadic_setup();
        let ctx = SearchCtx::new(&ss, &db);
        // 4 positions of unique 0 (3 cfgs) + 3 of unique 1 (2 cfgs)
        let want = 4.0 * 3f64.log2() + 3.0;
        assert!((space_bits(&ctx, 0, ss.instances.len()) - want).abs() < 1e-12);
        assert_eq!(space_bits(&ctx, 0, 0), 0.0);
    }

    #[test]
    fn engine_parse_round_trips() {
        for e in [SearchEngine::Dp, SearchEngine::Exact, SearchEngine::Auto] {
            assert_eq!(SearchEngine::parse(e.as_str()), Some(e));
        }
        assert_eq!(SearchEngine::parse("ilp"), None);
        assert_eq!(SearchEngine::default(), SearchEngine::Dp);
    }
}
