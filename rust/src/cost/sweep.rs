//! Shared-prefix span sweeps: every `[lo, hi)` answer from one pass.
//!
//! All three DP lanes of the private `dp` engine module are
//! prefix-closed — the state at
//! position `i` is independent of where the span ends — so a single
//! forward walk from `lo` visits the terminal state of *every* span
//! `[lo, hi)`, `hi ∈ (lo, n]`. The inter-op stage planner needs exactly
//! those: its split DP prices all `O(n²)` contiguous spans, which used
//! to cost one full `search_span` *per span* (`O(n³)` DP steps per
//! stage count, re-done per stage count). A sweep replaces each origin's
//! column of that matrix with one `O(n)` pass, and independent origins
//! fan out over the thread pool (`interop` flattens `(context, origin)`
//! jobs with order-preserving collection, the profiler's determinism
//! pattern).
//!
//! Two sweep flavours, matching the two planner modes:
//!
//! * [`sweep_span_times`] — legacy mode. Runs the capped Pareto lane and
//!   the unconstrained scalar lane *simultaneously*, folding the old
//!   `search_span(cap).or_else(|| search_span(None))` double solve into
//!   the one pass: per `hi`, the capped terminal when the cap admits any
//!   plan, else the unconstrained terminal.
//! * [`sweep_span_frontiers`] — memory-aware mode. Rolls the memory-axis
//!   frontier and snapshots, per `hi`, the kept terminal rows
//!   ([`FrontierRow`]) that [`select_time`] probes under a per-stage
//!   in-flight window and device cap — the value-only twin of
//!   [`crate::memory::select_feasible`], same strict-first tie rule.
//!
//! Sweeps return *values* (times, frontier rows), not plans: the stage
//! DP only compares values, and the handful of spans the chosen split
//! actually uses are reconstructed afterwards with the single-span
//! searchers — which, being the same prefix-closed lanes, reproduce the
//! swept values bit-for-bit.

use crate::memory::{self, RecomputeSpec};
use crate::obs::Counter;

use super::ctx::SearchCtx;
use super::dp;

/// Folded solve times of every span starting at `lo`: entry `h` answers
/// `[lo, lo + 1 + h)` — the capped plan's time when `cap` admits one,
/// else the unconstrained plan's; `None` when the span has no plan at
/// all (an empty config space). Bit-identical to
/// `search_span(.., Some(cap), ..).or_else(|| search_span(.., None, ..))`
/// per span.
pub fn sweep_span_times(ctx: &SearchCtx, lo: usize, cap: u64) -> Vec<Option<f64>> {
    let n = ctx.len() - lo;
    let mut out = Vec::with_capacity(n);
    if n == 0 {
        return out;
    }
    if ctx.trace.is_enabled() {
        ctx.trace.count(Counter::SweepOrigins, 1);
        ctx.trace.count(Counter::SweepSpans, n as u64);
    }
    // unconstrained lane, per-position states (steady-state splice incl.)
    let scalar = dp::scalar_states(ctx, lo, ctx.len());
    // capped Pareto lane, rolling (values only — no backtrack storage)
    let mut front = dp::pareto_first(ctx, lo, cap);
    let mut scratch = Vec::new();
    for i in 0..n {
        if i > 0 {
            front = dp::pareto_step(ctx, &front, lo + i, cap, &mut scratch);
        }
        let time = match dp::pareto_best_time(&front) {
            Some(t) => Some(t),
            None => scalar.get(i).and_then(|s| dp::scalar_best_time(s)),
        };
        out.push(time);
    }
    out
}

/// One kept terminal point of a span's (time × 1F1B-footprint) frontier,
/// flattened for the inter-op DP's feasibility probes. Rows appear in
/// the same canonical order as [`super::search_span_mem`]'s plans, so a
/// row index identifies the plan a later reconstruction will return.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FrontierRow {
    pub time_us: f64,
    pub static_bytes: u64,
    pub retained_bytes: u64,
    pub transient_bytes: u64,
}

/// Memory-aware sweep: the kept terminal frontier of every span starting
/// at `lo` (entry `h` answers `[lo, lo + 1 + h)`), from one rolling pass
/// of the memory-axis DP.
pub fn sweep_span_frontiers(
    ctx: &SearchCtx,
    lo: usize,
    spec: RecomputeSpec,
) -> Vec<Vec<FrontierRow>> {
    let n = ctx.len() - lo;
    let mut out = Vec::with_capacity(n);
    if n == 0 {
        return out;
    }
    if ctx.trace.is_enabled() {
        ctx.trace.count(Counter::SweepOrigins, 1);
        ctx.trace.count(Counter::SweepSpans, n as u64);
    }
    let mut front = dp::mem_first(ctx, lo, spec);
    let mut scratch = Vec::new();
    for i in 0..n {
        if i > 0 {
            front = dp::mem_step(ctx, &front, lo + i, spec, &mut scratch);
        }
        let rows: Vec<FrontierRow> = dp::mem_terminals(&front)
            .into_iter()
            .map(|(c, idx)| {
                let p = &front[c][idx];
                FrontierRow {
                    time_us: p.time,
                    static_bytes: p.stat,
                    retained_bytes: p.ret,
                    transient_bytes: p.tra,
                }
            })
            .collect();
        out.push(rows);
    }
    out
}

/// Min-time row whose closed-form 1F1B peak fits `cap` — the value-only
/// twin of [`memory::select_feasible`] (strict `<`, first of time-equal
/// rows wins, exactly the plan a reconstruction will select).
pub fn select_time(rows: &[FrontierRow], m_eff: usize, inflight: usize, cap: u64) -> Option<f64> {
    let mut best: Option<f64> = None;
    for r in rows {
        let peak = memory::stage_peak_bytes(
            r.static_bytes,
            r.retained_bytes,
            r.transient_bytes,
            m_eff,
            inflight,
        );
        if peak <= cap && best.map_or(true, |b| r.time_us < b) {
            best = Some(r.time_us);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Platform;
    use crate::models::{build_training, ModelCfg};
    use crate::pblock::build_parallel_blocks;
    use crate::profiler::{profile_model, ProfileDb, ProfileOptions};
    use crate::segment::{extract_segments, SegmentSet};
    use crate::spmd::Mesh;

    fn setup(layers: usize) -> (SegmentSet, ProfileDb) {
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(layers);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let ss = extract_segments(&g, &bs);
        let opts = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
        let db = profile_model(&g, &bs, &ss, &opts);
        (ss, db)
    }

    #[test]
    fn sweep_times_fold_the_cap_retry_per_span() {
        let (ss, db) = setup(3);
        let ctx = SearchCtx::new(&ss, &db);
        let n = ss.instances.len();
        let free = super::super::search(&ss, &db, None).unwrap();
        for cap in [free.mem_bytes / 2, free.mem_bytes, u64::MAX] {
            for lo in 0..n {
                let swept = sweep_span_times(&ctx, lo, cap);
                assert_eq!(swept.len(), n - lo);
                for hi in (lo + 1)..=n {
                    let want = super::super::search_span(&ss, &db, Some(cap), lo, hi)
                        .or_else(|| super::super::search_span(&ss, &db, None, lo, hi))
                        .map(|p| p.time_us);
                    let got = swept[hi - lo - 1];
                    match (got, want) {
                        (Some(a), Some(b)) => assert!(
                            a.to_bits() == b.to_bits(),
                            "[{lo},{hi}) cap {cap}: {a} vs {b}"
                        ),
                        (None, None) => {}
                        (a, b) => panic!("[{lo},{hi}) cap {cap}: {a:?} vs {b:?}"),
                    }
                }
            }
        }
    }

    #[test]
    fn sweep_frontiers_match_per_span_searches() {
        let (ss, db) = setup(2);
        let ctx = SearchCtx::new(&ss, &db);
        let n = ss.instances.len();
        for spec in [RecomputeSpec::Off, RecomputeSpec::Auto] {
            for lo in 0..n {
                let swept = sweep_span_frontiers(&ctx, lo, spec);
                for hi in (lo + 1)..=n {
                    let frontier = super::super::search_span_mem(&ss, &db, lo, hi, spec);
                    let rows = &swept[hi - lo - 1];
                    assert_eq!(rows.len(), frontier.len(), "[{lo},{hi}) {spec:?}");
                    for (r, p) in rows.iter().zip(&frontier) {
                        assert!(r.time_us.to_bits() == p.time_us.to_bits());
                        assert_eq!(r.static_bytes, p.footprint.static_bytes);
                        assert_eq!(r.retained_bytes, p.footprint.retained_bytes);
                        assert_eq!(r.transient_bytes, p.footprint.transient_bytes);
                    }
                    // the value probe agrees with the plan-level selection
                    for (me, f) in [(1usize, 1usize), (8, 2), (8, 4)] {
                        let caps: Vec<u64> = frontier
                            .iter()
                            .map(|p| p.peak_bytes(me, f))
                            .chain([0, u64::MAX])
                            .collect();
                        for cap in caps {
                            let want = memory::select_feasible(&frontier, me, f, cap)
                                .map(|p| p.time_us);
                            let got = select_time(rows, me, f, cap);
                            match (got, want) {
                                (Some(a), Some(b)) => assert!(a.to_bits() == b.to_bits()),
                                (None, None) => {}
                                (a, b) => panic!("cap {cap}: {a:?} vs {b:?}"),
                            }
                        }
                    }
                }
            }
        }
    }
}
