//! Two-level planner: inter-operator pipeline staging over the intra-op DP.
//!
//! CFP (§4.4) searches intra-operator plans for a chain of segments that
//! owns the *whole* device mesh. This module adds the outer level of the
//! Alpa-style decomposition: partition the segment chain into `k`
//! contiguous pipeline stages, give each stage its own sub-mesh of the
//! cluster, solve the existing memory-constrained intra-op DP *per stage*
//! ([`crate::cost::search_span`]), and compose the per-stage plans with a
//! 1F1B-style pipeline schedule ([`crate::cluster::simulate_pipeline`]).
//!
//! # Cost model
//!
//! With `m` microbatches and stage `i`'s whole-batch intra-op plan time
//! `Tᵢ`, the per-microbatch stage latency is `lᵢ = Tᵢ/m + xᵢ`, where `xᵢ`
//! is the per-microbatch point-to-point activation transfer into stage
//! `i` (forward activation + backward gradient, priced by
//! [`crate::cluster::collective_time_us`] over the link the stage cut
//! crosses — inter-node when the cut coincides with a node boundary).
//! The composed step time is the flow-line makespan for `m` identical
//! microbatches:
//!
//! ```text
//! T_step = Σᵢ lᵢ + (m − 1) · maxᵢ lᵢ
//! ```
//!
//! which reduces to `(k − 1 + m)/m · l` for balanced stages — the
//! familiar 1F1B bubble formula. `k = 1` bypasses the microbatch
//! division entirely, so a degenerate pipeline reproduces today's
//! single-stage plan (and step time) bit-for-bit.
//!
//! # Search
//!
//! The stage-split search is a DP over split points with a per-prefix
//! Pareto state on `(Σ l, max l)`. Pruning a dominated state is exact:
//! both components only grow when a suffix is appended and the objective
//! is monotone in both, so the DP provably matches brute-force
//! enumeration of all `C(n−1, k−1)` split vectors (pinned by the
//! `integration_interop` tests). Every sub-mesh context is profiled
//! through [`crate::profiler::profile_model_cached`] so the persistent
//! fingerprint cache makes warm runs cheap across *all* stage counts.
//!
//! Since PR 5 the per-span intra-op values the split DP consumes come
//! from *shared-prefix sweeps* ([`SpanTables`]): one forward pass of the
//! prefix-closed span DP per origin yields the terminal value of every
//! `[lo, hi)` at once — `O(n)` sweeps instead of `O(n²)` independent
//! `search_span` calls, with the old capped/uncapped double solve folded
//! into the same pass. Tables are built once per context and shared by
//! every candidate stage count, and the independent `(context, origin)`
//! sweep jobs fan out over [`crate::util::ThreadPool`] with
//! order-preserving collection (the profiler's determinism pattern), so
//! `cfp pipeline --stages auto --threads N` uses all cores and returns
//! plans bit-identical to the serial path. Only the handful of spans the
//! winning split actually uses are reconstructed into full plans, via
//! the same prefix-closed single-span searchers.
//!
//! # Memory (PR 3)
//!
//! With a `--mem-cap` (or `--recompute auto`, against the device
//! capacity) the planner becomes *memory-aware*: every candidate stage is
//! priced by its closed-form 1F1B peak ([`crate::memory`]) — weights +
//! optimizer + gradient buckets plus the activations of the
//! `min(m, k − i)` in-flight microbatches stage `i` holds — and a split
//! whose peak exceeds the cap is rejected. Per-span solutions come from
//! [`crate::cost::search_span_mem`], whose frontier includes
//! checkpoint-and-recompute variants, so a rejected stage can be
//! recovered as a strictly slower but feasible plan. Without a cap and
//! with recompute off, planning is bit-identical to PR 2 (the accounting
//! is still computed, for reporting).
//!
//! # Invariants
//!
//! * Stages are contiguous, non-empty spans covering the chain exactly
//!   once, in order — required for [`crate::cost::plan_cost_span`]'s
//!   boundary-reshard accounting and for the p2p model (one activation
//!   tensor crosses each cut).
//! * All stages of a candidate plan share one sub-mesh size
//!   `d = total_devices / k`; a context profiled at `d` is valid for
//!   every span (profiles depend on the partition count, not the span).
//! * The candidate stage counts are the divisors of the device count, so
//!   `k · d` always uses the whole cluster.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::cluster::sim::ComputeModel;
use crate::cluster::{collective_time_us, simulate_pipeline, Platform};
use crate::cost::{self, FrontierRow, Plan, SearchCtx};
use crate::graph::Graph;
use crate::memory::{self, RecomputeSpec, SpanFootprint};
use crate::obs::Counter;
use crate::pblock::{build_parallel_blocks, BlockSet};
use crate::profiler::{profile_model_handle, CacheHandle, ProfileDb, ProfileOptions};
use crate::segment::{extract_with_topology, SegmentSet};
use crate::spdag::{self, SpCtx, SpTopology};
use crate::spmd::{CollKind, Mesh};
use crate::util::ThreadPool;

/// How many pipeline stages the two-level planner may use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageSpec {
    /// One stage — today's single-level CFP behaviour.
    Single,
    /// Search every stage count that divides the device count.
    Auto,
    /// Exactly `k` stages (normalized down to the nearest divisor of the
    /// device count; `Fixed(1)` ≡ `Single`).
    Fixed(usize),
}

impl StageSpec {
    /// Parse a `--stages` CLI value: `auto`, `single`, or a number.
    pub fn parse(s: &str) -> Option<StageSpec> {
        match s {
            "auto" => Some(StageSpec::Auto),
            "single" | "1" => Some(StageSpec::Single),
            _ => s.parse::<usize>().ok().map(|k| {
                if k <= 1 {
                    StageSpec::Single
                } else {
                    StageSpec::Fixed(k)
                }
            }),
        }
    }
}

/// Options for the two-level planner. The intra-op knobs mirror
/// `coordinator::CfpOptions`; `microbatches` and `spec` drive the outer
/// level.
#[derive(Clone)]
pub struct PipelineOptions {
    pub platform: Platform,
    /// full-cluster mesh; stages carve contiguous sub-meshes out of it
    pub mesh: Mesh,
    /// per-device memory cap (None → platform capacity)
    pub mem_cap: Option<u64>,
    pub threads: usize,
    pub compute: Option<ComputeModel>,
    /// gradient-accumulation microbatches per step (the `m` of the bubble
    /// formula)
    pub microbatches: usize,
    pub spec: StageSpec,
    /// whether the planner may trade recomputation for activation memory
    /// (`--recompute`). With `Off` and no `mem_cap`, planning is
    /// bit-identical to the PR 2 behaviour.
    pub recompute: RecomputeSpec,
    /// observability sink shared with the single-level run (see
    /// [`crate::obs`]); disabled by default, never shapes plans
    pub trace: crate::obs::Trace,
}

impl PipelineOptions {
    pub fn new(platform: Platform, mesh: Mesh) -> PipelineOptions {
        PipelineOptions {
            platform,
            mesh,
            mem_cap: None,
            threads: 1,
            compute: None,
            microbatches: 8,
            spec: StageSpec::Auto,
            recompute: RecomputeSpec::Off,
            trace: crate::obs::Trace::disabled(),
        }
    }

    /// True when the 1F1B activation-memory accounting constrains the
    /// search: an explicit `--mem-cap`, or recomputation enabled (which
    /// only matters under a cap — the device capacity by default). When
    /// false, planning takes exactly the PR 2 code path.
    pub fn memory_aware(&self) -> bool {
        self.mem_cap.is_some() || self.recompute.is_auto()
    }

    /// The per-device byte budget the 1F1B peak of every stage must fit.
    pub fn device_cap(&self) -> u64 {
        self.mem_cap.unwrap_or_else(|| self.platform.mem_capacity())
    }
}

/// Microbatch count for the *memory* accounting of a `k`-stage plan —
/// the single convention lives in [`memory::memory_microbatches`]
/// (`k = 1` bypasses the microbatch division, the PR 2 whole-batch rule).
fn m_eff(opts: &PipelineOptions, k: usize) -> usize {
    memory::memory_microbatches(k, opts.microbatches)
}

/// One intra-op planning context, profiled for a specific sub-mesh size.
/// ParallelBlocks, segments and profiles all depend on the partition
/// count, so each distinct `devices` gets its own context.
pub struct StageContext {
    /// devices per stage (the sub-mesh size `d`)
    pub devices: usize,
    pub mesh: Mesh,
    pub blocks: BlockSet,
    pub segments: SegmentSet,
    /// series-parallel shape of `segments` (`chain(n)` for linear models);
    /// stage cuts must fall on [`SpTopology::valid_cut`] positions
    pub topo: SpTopology,
    pub db: ProfileDb,
}

/// Memoized per-sub-mesh-size contexts shared by the CFP planner and the
/// naive baseline (one profiling pass per distinct `d`, cache-served when
/// warm).
#[derive(Default)]
pub struct StageContexts {
    by_devices: BTreeMap<usize, StageContext>,
}

impl StageContexts {
    pub fn new() -> StageContexts {
        StageContexts::default()
    }

    /// Build (and profile) the context for sub-mesh size `devices` if it
    /// is not already present.
    pub fn ensure(
        &mut self,
        g: &Graph,
        opts: &PipelineOptions,
        devices: usize,
        cache: CacheHandle<'_>,
    ) {
        if !self.by_devices.contains_key(&devices) {
            self.by_devices.insert(devices, build_context(g, opts, devices, cache));
        }
    }

    /// Ensure a context exists for every candidate stage count of
    /// `opts.spec`. Contexts whose segment chain is shorter than the
    /// stage count are skipped *before* the (expensive) profiling pass —
    /// a `k`-stage split of fewer than `k` instances is impossible, so
    /// profiling them would be pure waste (the analysis passes that
    /// determine the chain length are cheap).
    pub fn ensure_all(
        &mut self,
        g: &Graph,
        opts: &PipelineOptions,
        mut cache: CacheHandle<'_>,
    ) {
        let total = opts.mesh.total();
        for k in candidate_stage_counts(opts.spec, opts.mesh) {
            let devices = total / k;
            if self.by_devices.contains_key(&devices) {
                continue;
            }
            let mesh = sub_mesh(opts.mesh, devices);
            let blocks = build_parallel_blocks(g, mesh.intra);
            let (segments, topo) = extract_with_topology(g, &blocks);
            if segments.instances.len() < k {
                continue;
            }
            let db = profile_context(g, opts, mesh, &blocks, &segments, cache.reborrow());
            self.by_devices
                .insert(devices, StageContext { devices, mesh, blocks, segments, topo, db });
        }
    }

    /// Adopt an already-profiled context (e.g. the whole-cluster
    /// artifacts of a single-stage `run_cfp`) so `k = 1` reuses them
    /// verbatim instead of re-profiling.
    pub fn adopt(&mut self, ctx: StageContext) {
        self.by_devices.insert(ctx.devices, ctx);
    }

    pub fn get(&self, devices: usize) -> Option<&StageContext> {
        self.by_devices.get(&devices)
    }

    /// All built contexts, ascending by sub-mesh size (the adopted
    /// whole-cluster context included).
    pub fn iter(&self) -> impl Iterator<Item = &StageContext> {
        self.by_devices.values()
    }

    pub fn len(&self) -> usize {
        self.by_devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_devices.is_empty()
    }
}

/// Build one sub-mesh context: ParallelBlocks + segments at `devices`
/// partitions, profiled through the (optionally persistent) cache.
pub fn build_context(
    g: &Graph,
    opts: &PipelineOptions,
    devices: usize,
    cache: CacheHandle<'_>,
) -> StageContext {
    let mesh = sub_mesh(opts.mesh, devices);
    let blocks = build_parallel_blocks(g, mesh.intra);
    let (segments, topo) = extract_with_topology(g, &blocks);
    let db = profile_context(g, opts, mesh, &blocks, &segments, cache);
    StageContext { devices, mesh, blocks, segments, topo, db }
}

/// The MetricsProfiling half of [`build_context`]: profile an
/// already-analyzed (blocks, segments) pair at `mesh`.
fn profile_context(
    g: &Graph,
    opts: &PipelineOptions,
    mesh: Mesh,
    blocks: &BlockSet,
    segments: &SegmentSet,
    cache: CacheHandle<'_>,
) -> ProfileDb {
    let mut popts = ProfileOptions::new(opts.platform, mesh)
        .with_threads(opts.threads)
        .with_trace(opts.trace.clone());
    if let Some(cm) = &opts.compute {
        popts = popts.with_compute(cm.clone());
    }
    profile_model_handle(g, blocks, segments, &popts, cache)
}

/// Candidate stage counts for a spec: the divisors of the device count
/// (ascending) whose per-stage share `d = total/k` tiles the node
/// structure — `d` must divide the per-node GPU count (aligned
/// within-node slices) or be a whole multiple of it (whole nodes).
/// Anything else puts some stage across a node boundary, which
/// [`sub_mesh`] cannot express (e.g. intra 8 × 3 nodes: k = 2 ⇒ d = 12,
/// or k = 4 ⇒ d = 6, both straddle). Filtered/normalized per the spec;
/// `k = 1` (`d = total`) is always valid.
pub fn candidate_stage_counts(spec: StageSpec, mesh: Mesh) -> Vec<usize> {
    let total = mesh.total().max(1);
    let intra = mesh.intra.max(1);
    let divisors: Vec<usize> = (1..=total)
        .filter(|k| total % k == 0)
        .filter(|k| {
            let d = total / k;
            intra % d == 0 || d % intra == 0
        })
        .collect();
    match spec {
        StageSpec::Single => vec![1],
        StageSpec::Auto => divisors,
        StageSpec::Fixed(k) => {
            vec![divisors.iter().copied().filter(|&d| d <= k).max().unwrap_or(1)]
        }
    }
}

/// The sub-mesh a stage of `devices` devices occupies. Only called for
/// the sizes [`candidate_stage_counts`] admits: `devices ≤ intra`
/// (within-node slice) or a whole number of nodes — stages never
/// straddle node boundaries.
pub fn sub_mesh(full: Mesh, devices: usize) -> Mesh {
    if devices >= full.total() {
        full
    } else if devices <= full.intra {
        debug_assert_eq!(full.intra % devices.max(1), 0, "stage straddles a node boundary");
        Mesh::flat(devices)
    } else {
        debug_assert_eq!(devices % full.intra, 0, "stage straddles a node boundary");
        Mesh { intra: full.intra, nodes: devices / full.intra }
    }
}

/// One pipeline stage of a composed two-level plan.
#[derive(Clone, Debug)]
pub struct StagePlan {
    /// instance span `[lo, hi)` in the stage context's segment chain
    pub span: (usize, usize),
    /// global device range `[first, last)`
    pub devices: (usize, usize),
    /// intra-op plan for the span (whole-batch time/memory; time includes
    /// any recompute the memory planner chose)
    pub plan: Plan,
    /// per-microbatch incoming activation transfer, µs (0 for stage 0)
    pub p2p_in_us: f64,
    /// per-microbatch stage latency `Tᵢ/m + xᵢ`, µs
    pub latency_us: f64,
    /// whole-batch memory footprint (static / retained / transient /
    /// recompute) behind the 1F1B peak
    pub footprint: SpanFootprint,
    /// closed-form 1F1B peak per device: `static + f·retained/m +
    /// transient/m` with `f` this stage's in-flight window
    pub peak_mem_bytes: u64,
    /// checkpoint-and-recompute flag per instance of the span
    pub remat: Vec<bool>,
}

/// A composed two-level plan: contiguous stages, each with its own
/// sub-mesh and intra-op plan.
#[derive(Clone, Debug)]
pub struct PipelinePlan {
    pub stages: Vec<StagePlan>,
    pub devices_per_stage: usize,
    pub microbatches: usize,
    /// composed step time, µs (exactly the intra-op plan time when k = 1)
    pub step_time_us: f64,
    /// peak per-device *whole-batch plan* memory across stages (the PR 2
    /// quantity — see `peak_mem_bytes` for the 1F1B accounting)
    pub mem_bytes: u64,
    /// max over stages of the closed-form 1F1B peak (weights + optimizer
    /// + gradient buckets + in-flight microbatch activations)
    pub peak_mem_bytes: u64,
    /// pipeline-bubble share of the step (0 for k = 1)
    pub bubble_fraction: f64,
}

impl PipelinePlan {
    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// The microbatch count the memory accounting divides by: 1 for a
    /// single-stage plan (whole-batch convention), `m` otherwise — the
    /// same [`memory::memory_microbatches`] rule the planner priced with.
    pub fn memory_microbatches(&self) -> usize {
        memory::memory_microbatches(self.stages.len(), self.microbatches)
    }

    /// Human-readable per-stage summary lines.
    pub fn describe(&self) -> Vec<String> {
        self.stages
            .iter()
            .enumerate()
            .map(|(s, st)| {
                let ck = st.remat.iter().filter(|&&r| r).count();
                format!(
                    "stage {s}: segments [{}, {}) on devices [{}, {})  \
                     intra-op {:.1}µs  p2p/µb {:.1}µs  peak {} MB{}",
                    st.span.0,
                    st.span.1,
                    st.devices.0,
                    st.devices.1,
                    st.plan.time_us,
                    st.p2p_in_us,
                    st.peak_mem_bytes >> 20,
                    if ck > 0 {
                        format!("  (recompute {ck}/{} segs)", st.remat.len())
                    } else {
                        String::new()
                    },
                )
            })
            .collect()
    }
}

/// Per-context span-value tables, built by shared-prefix sweeps and
/// shared by *every* stage count planned over the context (the old code
/// re-solved every span per stage count with a fresh memo).
///
/// Legacy mode stores, per span, the folded solve time — the capped
/// plan's when the cap admits one, else the unconstrained plan's (the
/// old `search_span(cap)` / `search_span(None)` retry collapsed into the
/// sweep's single pass). Memory-aware mode stores the span's kept
/// terminal frontier rows, probed per (stage index, in-flight window)
/// by [`cost::select_time`]. Either way the tables hold *values* only;
/// the spans a winning split uses are reconstructed afterwards through
/// the same prefix-closed single-span searchers, bit-identically.
pub struct SpanTables {
    ctx: Arc<SearchCtx>,
    /// present iff the context's segment DAG is not a chain; routes every
    /// span solve through the spdag lanes
    sp: Option<SpCtx>,
    values: SpanValues,
}

enum SpanValues {
    /// `times[lo][hi - lo - 1]` = folded solve time of `[lo, hi)`
    Legacy { cap: u64, times: Vec<Vec<Option<f64>>> },
    /// `rows[lo][hi - lo - 1]` = kept terminal frontier of `[lo, hi)`
    Memory { spec: RecomputeSpec, rows: Vec<Vec<Vec<FrontierRow>>> },
}

impl SpanTables {
    /// Build the tables for one context with serial sweeps (the
    /// single-context entry; [`plan_pipeline`] fans multi-context sweep
    /// jobs over the pool instead).
    pub fn build(ctx: &StageContext, opts: &PipelineOptions) -> SpanTables {
        let sctx = Arc::new(SearchCtx::with_trace(&ctx.segments, &ctx.db, opts.trace.clone()));
        let sp = (!ctx.topo.is_chain()).then(|| SpCtx::new(&sctx, &ctx.topo, &ctx.db));
        if let Some(sp) = sp {
            let values = dag_span_values(&sctx, &sp, opts);
            return SpanTables { ctx: sctx, sp: Some(sp), values };
        }
        let n = sctx.len();
        let values = if opts.memory_aware() {
            let spec = opts.recompute;
            let rows = (0..n).map(|lo| cost::sweep_span_frontiers(&sctx, lo, spec)).collect();
            SpanValues::Memory { spec, rows }
        } else {
            let cap = opts.device_cap();
            let times = (0..n).map(|lo| cost::sweep_span_times(&sctx, lo, cap)).collect();
            SpanValues::Legacy { cap, times }
        };
        SpanTables { ctx: sctx, sp: None, values }
    }

    /// A table with the search context but no swept values — all a
    /// `k = 1` plan needs (its single whole-chain span goes straight to
    /// reconstruction, never through [`SpanTables::span_time`]), so the
    /// degenerate stage count stays `O(n)` instead of paying `O(n²)`
    /// sweeps it would never read.
    fn values_only_ctx(ctx: &StageContext, opts: &PipelineOptions) -> SpanTables {
        let sctx = Arc::new(SearchCtx::with_trace(&ctx.segments, &ctx.db, opts.trace.clone()));
        let sp = (!ctx.topo.is_chain()).then(|| SpCtx::new(&sctx, &ctx.topo, &ctx.db));
        let values = if opts.memory_aware() {
            SpanValues::Memory { spec: opts.recompute, rows: Vec::new() }
        } else {
            SpanValues::Legacy { cap: opts.device_cap(), times: Vec::new() }
        };
        SpanTables { ctx: sctx, sp, values }
    }

    /// Whole-batch intra-op time of span `[lo, hi)` as stage `stage_idx`
    /// of `k` — `None` if the span is infeasible under the mode's cap.
    fn span_time(
        &self,
        opts: &PipelineOptions,
        lo: usize,
        hi: usize,
        stage_idx: usize,
        k: usize,
    ) -> Option<f64> {
        match &self.values {
            SpanValues::Legacy { times, .. } => times[lo][hi - lo - 1],
            SpanValues::Memory { rows, .. } => {
                let me = m_eff(opts, k);
                let f = memory::inflight_microbatches(k, stage_idx, me);
                cost::select_time(&rows[lo][hi - lo - 1], me, f, opts.device_cap())
            }
        }
    }
}

/// Span-value tables for a DAG-shaped context: every *valid* span (both
/// ends on [`SpTopology::valid_cut`] positions — a stage boundary inside
/// a branch group would sever branches from their merge) is solved
/// directly through the spdag lanes; invalid spans store `None` / an
/// empty frontier, which [`SpanTables::span_time`] reports as infeasible,
/// so the stage-split DP never places a cut inside a group.
fn dag_span_values(ctx: &SearchCtx, sp: &SpCtx, opts: &PipelineOptions) -> SpanValues {
    let n = ctx.len();
    let valid = |lo: usize, hi: usize| sp.topo.valid_cut(lo) && sp.topo.valid_cut(hi);
    if opts.memory_aware() {
        let spec = opts.recompute;
        let rows = (0..n)
            .map(|lo| {
                (lo + 1..=n)
                    .map(|hi| {
                        if !valid(lo, hi) {
                            return Vec::new();
                        }
                        spdag::sp_search_mem_span(ctx, sp, lo, hi, spec)
                            .iter()
                            .map(|p| FrontierRow {
                                time_us: p.time_us,
                                static_bytes: p.footprint.static_bytes,
                                retained_bytes: p.footprint.retained_bytes,
                                transient_bytes: p.footprint.transient_bytes,
                            })
                            .collect()
                    })
                    .collect()
            })
            .collect();
        SpanValues::Memory { spec, rows }
    } else {
        let cap = opts.device_cap();
        let times = (0..n)
            .map(|lo| {
                (lo + 1..=n)
                    .map(|hi| {
                        if !valid(lo, hi) {
                            return None;
                        }
                        spdag::sp_search_span(ctx, sp, Some(cap), lo, hi)
                            .or_else(|| spdag::sp_search_span(ctx, sp, None, lo, hi))
                            .map(|p| p.time_us)
                    })
                    .collect()
            })
            .collect();
        SpanValues::Legacy { cap, times }
    }
}

/// Build [`SpanTables`] for every candidate context, fanning the
/// independent `(context, sweep-origin)` jobs over the thread pool with
/// order-preserving collection — each job is a pure function of the
/// shared immutable [`SearchCtx`], so any thread count produces the
/// byte-identical tables the serial loop would.
fn build_span_tables(
    ctxs: &StageContexts,
    opts: &PipelineOptions,
    ks: &[usize],
) -> BTreeMap<usize, SpanTables> {
    let total = opts.mesh.total();
    let mut out = BTreeMap::new();
    let mut arcs: BTreeMap<usize, Arc<SearchCtx>> = BTreeMap::new();
    for &k in ks {
        let d = total / k;
        if arcs.contains_key(&d) || out.contains_key(&d) {
            continue;
        }
        if let Some(ctx) = ctxs.get(d) {
            if k <= 1 || k > ctx.segments.instances.len() {
                // k = 1 solves one span (straight to reconstruction) and
                // k > n is structurally infeasible (the DP returns None
                // without reading the table) — sweeps would be waste
                out.insert(d, SpanTables::values_only_ctx(ctx, opts));
            } else if !ctx.topo.is_chain() {
                // DAG contexts fill their tables through the spdag
                // lanes (serial, deterministic) — the chain sweeps
                // below would misprice spans containing branch groups
                out.insert(d, SpanTables::build(ctx, opts));
            } else {
                arcs.insert(
                    d,
                    Arc::new(SearchCtx::with_trace(&ctx.segments, &ctx.db, opts.trace.clone())),
                );
            }
        }
    }
    // jobs in (devices ascending, origin ascending) order; the pool map
    // preserves it, so reassembly below is deterministic
    let jobs: Vec<(usize, usize)> = arcs
        .iter()
        .flat_map(|(&d, c)| (0..c.len()).map(move |lo| (d, lo)))
        .collect();
    if opts.trace.is_enabled() {
        opts.trace.count(Counter::InteropSweepJobs, jobs.len() as u64);
    }
    let threads = opts.threads.min(jobs.len().max(1));
    if opts.memory_aware() {
        let spec = opts.recompute;
        let results: Vec<Vec<Vec<FrontierRow>>> = if threads > 1 {
            let shared = arcs.clone();
            let pool = ThreadPool::new(threads);
            pool.map(jobs, move |(d, lo)| cost::sweep_span_frontiers(&shared[&d], lo, spec))
        } else {
            jobs.iter().map(|&(d, lo)| cost::sweep_span_frontiers(&arcs[&d], lo, spec)).collect()
        };
        let mut it = results.into_iter();
        for (&d, c) in &arcs {
            let rows: Vec<_> =
                (0..c.len()).map(|_| it.next().expect("one sweep per origin")).collect();
            out.insert(
                d,
                SpanTables {
                    ctx: Arc::clone(c),
                    sp: None,
                    values: SpanValues::Memory { spec, rows },
                },
            );
        }
    } else {
        let cap = opts.device_cap();
        let results: Vec<Vec<Option<f64>>> = if threads > 1 {
            let shared = arcs.clone();
            let pool = ThreadPool::new(threads);
            pool.map(jobs, move |(d, lo)| cost::sweep_span_times(&shared[&d], lo, cap))
        } else {
            jobs.iter().map(|&(d, lo)| cost::sweep_span_times(&arcs[&d], lo, cap)).collect()
        };
        let mut it = results.into_iter();
        for (&d, c) in &arcs {
            let times: Vec<_> =
                (0..c.len()).map(|_| it.next().expect("one sweep per origin")).collect();
            out.insert(
                d,
                SpanTables {
                    ctx: Arc::clone(c),
                    sp: None,
                    values: SpanValues::Legacy { cap, times },
                },
            );
        }
    }
    out
}

/// CFP two-level plan: best stage count × best split × best per-stage
/// intra-op plan. Returns None only if no candidate stage count yields a
/// feasible plan (never for `Auto`/`Single` on a chain the single-stage
/// search can solve, since `k = 1` is in the candidate set).
pub fn plan_pipeline(
    g: &Graph,
    ctxs: &StageContexts,
    opts: &PipelineOptions,
) -> Option<PipelinePlan> {
    let total = opts.mesh.total();
    let ks = candidate_stage_counts(opts.spec, opts.mesh);
    if opts.trace.is_enabled() {
        opts.trace.count(Counter::InteropStageCounts, ks.len() as u64);
    }
    let tables = build_span_tables(ctxs, opts, &ks);
    let mut best: Option<PipelinePlan> = None;
    let mut structurally_possible = false;
    for &k in &ks {
        let d = total / k;
        let Some(ctx) = ctxs.get(d) else { continue };
        if k <= ctx.segments.instances.len() {
            structurally_possible = true;
        }
        let Some(t) = tables.get(&d) else { continue };
        if let Some(p) = plan_fixed_stages_tables(g, ctx, opts, k, t) {
            if best.as_ref().map_or(true, |b| p.step_time_us < b.step_time_us) {
                best = Some(p);
            }
        }
    }
    if best.is_none() && !(opts.memory_aware() && structurally_possible) {
        // a structurally infeasible request (e.g. a Fixed(k) with more
        // stages than segments) degrades to the single-stage plan rather
        // than failing — in memory-aware mode that fallback is still
        // cap-checked, so None remains the honest "does not fit" answer
        // whenever some candidate was structurally possible
        if let Some(ctx) = ctxs.get(total) {
            best = match tables.get(&total) {
                Some(t) => plan_fixed_stages_tables(g, ctx, opts, 1, t),
                None => plan_fixed_stages(g, ctx, opts, 1),
            };
        }
    }
    best
}

/// Best `k`-stage plan over one context (the DP the tests verify against
/// brute-force split enumeration). Builds the context's span tables with
/// serial sweeps; [`plan_pipeline`] shares pool-built tables instead.
pub fn plan_fixed_stages(
    g: &Graph,
    ctx: &StageContext,
    opts: &PipelineOptions,
    k: usize,
) -> Option<PipelinePlan> {
    let tables = if k <= 1 || k > ctx.segments.instances.len() {
        SpanTables::values_only_ctx(ctx, opts)
    } else {
        SpanTables::build(ctx, opts)
    };
    plan_fixed_stages_tables(g, ctx, opts, k, &tables)
}

/// Pareto state of a stage-split DP prefix: the latency sum and max so
/// far, plus the start index of every stage chosen (for backtracking).
#[derive(Clone)]
struct SplitState {
    sum: f64,
    mx: f64,
    starts: Vec<usize>,
}

fn plan_fixed_stages_tables(
    g: &Graph,
    ctx: &StageContext,
    opts: &PipelineOptions,
    k: usize,
    tables: &SpanTables,
) -> Option<PipelinePlan> {
    let n = ctx.segments.instances.len();
    if k == 0 || k > n {
        return None;
    }
    let m = opts.microbatches.max(1);
    let mf = m as f64;
    if k == 1 {
        let st = build_stage_plan(g, ctx, opts, tables, 0, n, 0, 1)?;
        let step = st.plan.time_us;
        let mem = st.plan.mem_bytes;
        let peak = st.peak_mem_bytes;
        return Some(PipelinePlan {
            stages: vec![st],
            devices_per_stage: ctx.devices,
            microbatches: m,
            step_time_us: step,
            mem_bytes: mem,
            peak_mem_bytes: peak,
            bubble_fraction: 0.0,
        });
    }

    // DP over (stages used, instances consumed) with (sum, max) Pareto
    // states; dp[s][i] covers instances [0, i) with s stages.
    let mut dp: Vec<Vec<Vec<SplitState>>> = vec![vec![Vec::new(); n + 1]; k + 1];
    dp[0][0].push(SplitState { sum: 0.0, mx: 0.0, starts: Vec::new() });
    // local tally of Pareto-kept split states, flushed once after the DP
    let mut kept_states = 0u64;
    for s in 1..=k {
        // stage s ends at instance i; leave ≥ 1 instance per later stage
        for i in s..=(n - (k - s)) {
            let mut states: Vec<SplitState> = Vec::new();
            for j in (s - 1)..i {
                if dp[s - 1][j].is_empty() {
                    continue;
                }
                let Some(lat) = stage_latency(g, ctx, opts, tables, j, i, s - 1, k) else {
                    continue;
                };
                for st in &dp[s - 1][j] {
                    let mut starts = st.starts.clone();
                    starts.push(j);
                    states.push(SplitState {
                        sum: st.sum + lat,
                        mx: if lat > st.mx { lat } else { st.mx },
                        starts,
                    });
                }
            }
            prune_states(&mut states);
            kept_states += states.len() as u64;
            dp[s][i] = states;
        }
    }
    if opts.trace.is_enabled() {
        opts.trace.count(Counter::InteropSplitStates, kept_states);
    }

    let mut best: Option<&SplitState> = None;
    for st in &dp[k][n] {
        let v = st.sum + (mf - 1.0) * st.mx;
        if best.map_or(true, |b| v < b.sum + (mf - 1.0) * b.mx) {
            best = Some(st);
        }
    }
    let best = best?;
    let mut bounds = best.starts.clone();
    bounds.push(n);

    let mut stages = Vec::with_capacity(k);
    let mut lats = Vec::with_capacity(k);
    let mut mem_peak = 0u64;
    let mut peak_1f1b = 0u64;
    for s in 0..k {
        let (lo, hi) = (bounds[s], bounds[s + 1]);
        let st = build_stage_plan(g, ctx, opts, tables, lo, hi, s, k)
            .expect("span solved during DP");
        if st.plan.mem_bytes > mem_peak {
            mem_peak = st.plan.mem_bytes;
        }
        if st.peak_mem_bytes > peak_1f1b {
            peak_1f1b = st.peak_mem_bytes;
        }
        lats.push(st.latency_us);
        stages.push(st);
    }
    let step_time_us = compose_step_us(&lats, m);
    let bubble_fraction = simulate_pipeline(&lats, m).bubble_fraction;
    Some(PipelinePlan {
        stages,
        devices_per_stage: ctx.devices,
        microbatches: m,
        step_time_us,
        mem_bytes: mem_peak,
        peak_mem_bytes: peak_1f1b,
        bubble_fraction,
    })
}

/// Exhaustive split enumeration for a fixed stage count — tests only
/// (`C(n−1, k−1)` partitions). Same latency and composition arithmetic
/// as the DP, so the optimal *value* matches exactly.
pub fn brute_force_splits(
    g: &Graph,
    ctx: &StageContext,
    opts: &PipelineOptions,
    k: usize,
) -> Option<f64> {
    let n = ctx.segments.instances.len();
    if k == 0 || k > n {
        return None;
    }
    let tables = SpanTables::build(ctx, opts);
    if k == 1 {
        return build_stage_plan(g, ctx, opts, &tables, 0, n, 0, 1).map(|st| st.plan.time_us);
    }
    let m = opts.microbatches.max(1);
    let r = k - 1; // number of cut points, values in 1..n strictly increasing
    let mut cuts: Vec<usize> = (1..=r).collect();
    let mut best: Option<f64> = None;
    loop {
        let mut bounds = Vec::with_capacity(k + 1);
        bounds.push(0);
        bounds.extend(cuts.iter().copied());
        bounds.push(n);
        let mut lats = Vec::with_capacity(k);
        for s in 0..k {
            match stage_latency(g, ctx, opts, &tables, bounds[s], bounds[s + 1], s, k) {
                Some(l) => lats.push(l),
                None => break,
            }
        }
        if lats.len() == k {
            let v = compose_step_us(&lats, m);
            if best.map_or(true, |b| v < b) {
                best = Some(v);
            }
        }
        // next strictly-increasing cut combination
        let mut idx = r;
        loop {
            if idx == 0 {
                return best;
            }
            idx -= 1;
            if cuts[idx] < (n - 1) - (r - 1 - idx) {
                cuts[idx] += 1;
                for j in idx + 1..r {
                    cuts[j] = cuts[j - 1] + 1;
                }
                break;
            }
        }
    }
}

/// Naive equal-layer-split pipeline baseline: contiguous spans of (as
/// near as possible) equal instance counts, data-parallel config inside
/// every stage — the "shard by layers, DDP inside" recipe. It shares the
/// composition arithmetic with the CFP planner, so the comparison
/// isolates plan quality (split choice + intra-op configs).
pub fn naive_equal_split(
    g: &Graph,
    ctxs: &StageContexts,
    opts: &PipelineOptions,
) -> Option<PipelinePlan> {
    let total = opts.mesh.total();
    let mut best: Option<PipelinePlan> = None;
    let mut structurally_possible = false;
    for k in candidate_stage_counts(opts.spec, opts.mesh) {
        let Some(ctx) = ctxs.get(total / k) else { continue };
        if k <= ctx.segments.instances.len() {
            structurally_possible = true;
        }
        if let Some(p) = naive_fixed_stages(g, ctx, opts, k) {
            if best.as_ref().map_or(true, |b| p.step_time_us < b.step_time_us) {
                best = Some(p);
            }
        }
    }
    if best.is_none() && !(opts.memory_aware() && structurally_possible) {
        // same degradation rule as [`plan_pipeline`]: structural
        // infeasibility degrades to k = 1 (cap-checked when memory-aware);
        // memory infeasibility stays None — the baseline answers "does
        // not fit" exactly when the CFP planner does
        if let Some(ctx) = ctxs.get(total) {
            best = naive_fixed_stages(g, ctx, opts, 1);
        }
    }
    best
}

/// The naive baseline at one fixed stage count. It gets the *same* 1F1B
/// activation accounting as the CFP planner, so memory-capped comparisons
/// stay fair: when its DDP stage overflows the cap the naive recipe
/// checkpoints all-or-nothing (the "gradient checkpointing on" switch of
/// real training stacks), and the stage count is infeasible if that still
/// spills.
pub fn naive_fixed_stages(
    g: &Graph,
    ctx: &StageContext,
    opts: &PipelineOptions,
    k: usize,
) -> Option<PipelinePlan> {
    let n = ctx.segments.instances.len();
    if k == 0 || k > n {
        return None;
    }
    let m = opts.microbatches.max(1);
    let mf = m as f64;
    let me = m_eff(opts, k);
    let (ss, db) = (&ctx.segments, &ctx.db);
    let choice = ddp_choice(ctx);
    let mut bounds: Vec<usize> = (0..=k).map(|s| s * n / k).collect();
    // on a DAG chain the equal-split cut may land inside a branch group;
    // snap forward to the next valid cut (deterministic), or declare the
    // stage count infeasible when snapping runs out of room
    if !ctx.topo.is_chain() {
        for s in 1..k {
            let mut b = bounds[s].max(bounds[s - 1] + 1);
            while b < n && !ctx.topo.valid_cut(b) {
                b += 1;
            }
            if b >= n {
                return None;
            }
            bounds[s] = b;
        }
    }
    // the naive recipe prices each stage by replaying the DDP choice —
    // through the DAG closed form when the chain has branch groups
    let dag = (!ctx.topo.is_chain()).then(|| {
        let sctx = SearchCtx::new(ss, db);
        let sp = SpCtx::new(&sctx, &ctx.topo, db);
        (sctx, sp)
    });
    let mut stages = Vec::with_capacity(k);
    let mut lats = Vec::with_capacity(k);
    let mut mem_peak = 0u64;
    let mut peak_1f1b = 0u64;
    for s in 0..k {
        let (lo, hi) = (bounds[s], bounds[s + 1]);
        let (base_us, mem_bytes) = match &dag {
            Some((sctx, sp)) => spdag::sp_plan_cost_span(sctx, sp, &choice[lo..hi], lo, hi),
            None => cost::plan_cost_span(ss, db, &choice[lo..hi], lo, hi),
        };
        let f = memory::inflight_microbatches(k, s, me);
        let mut footprint = memory::span_footprint(ss, db, &choice[lo..hi], lo, hi);
        let mut remat = vec![false; hi - lo];
        if opts.memory_aware() && footprint.peak_bytes(me, f) > opts.device_cap() {
            if !opts.recompute.is_auto() {
                return None;
            }
            let ck = memory::span_footprint_checkpointed(ss, db, &choice[lo..hi], lo, hi);
            if ck.0.peak_bytes(me, f) > opts.device_cap() {
                return None;
            }
            footprint = ck.0;
            remat = ck.1;
        }
        let time_us = base_us + footprint.recompute_us;
        let p2p_in_us = if s == 0 { 0.0 } else { p2p_in_us(g, ctx, opts, lo, s) };
        let latency_us = time_us / mf + p2p_in_us;
        if mem_bytes > mem_peak {
            mem_peak = mem_bytes;
        }
        let peak = footprint.peak_bytes(me, f);
        if peak > peak_1f1b {
            peak_1f1b = peak;
        }
        lats.push(latency_us);
        stages.push(StagePlan {
            span: (lo, hi),
            devices: (s * ctx.devices, (s + 1) * ctx.devices),
            plan: Plan { choice: choice[lo..hi].to_vec(), time_us, mem_bytes },
            p2p_in_us,
            latency_us,
            footprint,
            peak_mem_bytes: peak,
            remat,
        });
    }
    let (step_time_us, bubble_fraction) = if k == 1 {
        (stages[0].plan.time_us, 0.0)
    } else {
        (compose_step_us(&lats, m), simulate_pipeline(&lats, m).bubble_fraction)
    };
    Some(PipelinePlan {
        stages,
        devices_per_stage: ctx.devices,
        microbatches: m,
        step_time_us,
        mem_bytes: mem_peak,
        peak_mem_bytes: peak_1f1b,
        bubble_fraction,
    })
}

// ------------------------------------------------------------------ internals

/// `Σ l + (m−1)·max l`, accumulated left-to-right — the single source of
/// the composition arithmetic for the DP, the brute force, and the naive
/// baseline, so their values are comparable bit-for-bit.
fn compose_step_us(lats: &[f64], microbatches: usize) -> f64 {
    let mut sum = 0.0;
    let mut mx = 0.0f64;
    for &l in lats {
        sum += l;
        if l > mx {
            mx = l;
        }
    }
    sum + (microbatches.max(1) as f64 - 1.0) * mx
}

/// Solve span `[lo, hi)` as stage `stage_idx` of a `k`-stage pipeline —
/// the *reconstruction* path, run only for the spans a winning split
/// actually uses (the DP itself compares swept values via
/// [`stage_latency`]).
///
/// * Legacy mode (no cap, recompute off): the PR 2 plan, with the 1F1B
///   accounting computed for *reporting* only — plans stay bit-identical.
///   The capped search with unconstrained fallback replays exactly the
///   fold the sweep recorded.
/// * Memory-aware mode: the min-time frontier point whose 1F1B peak
///   (`static + f·retained/m + transient/m`, `f = min(m, k − i)`) fits
///   the device cap; checkpointed variants recover stages the
///   keep-everything plan would spill. None = this split is rejected.
fn build_stage_plan(
    g: &Graph,
    ctx: &StageContext,
    opts: &PipelineOptions,
    tables: &SpanTables,
    lo: usize,
    hi: usize,
    stage_idx: usize,
    k: usize,
) -> Option<StagePlan> {
    let mf = opts.microbatches.max(1) as f64;
    let me = m_eff(opts, k);
    let f = memory::inflight_microbatches(k, stage_idx, me);
    let p2p_in_us = if stage_idx == 0 { 0.0 } else { p2p_in_us(g, ctx, opts, lo, stage_idx) };
    let (plan, footprint, remat) = match &tables.values {
        SpanValues::Memory { spec, .. } => {
            let frontier = match &tables.sp {
                Some(sp) => spdag::sp_search_mem_span(&tables.ctx, sp, lo, hi, *spec),
                None => cost::search_span_mem_ctx(&tables.ctx, lo, hi, *spec),
            };
            let sel = match memory::select_feasible(&frontier, me, f, opts.device_cap()) {
                Some(sel) => sel.clone(),
                None => {
                    if opts.trace.is_enabled() {
                        opts.trace.count(Counter::InteropMemRejects, 1);
                    }
                    return None;
                }
            };
            if opts.trace.is_enabled() && sel.remat.iter().any(|&r| r) {
                opts.trace.count(Counter::InteropMemRecovers, 1);
            }
            let fp = sel.footprint;
            let (_, mem_bytes) = cost::plan_cost_span(&ctx.segments, &ctx.db, &sel.choice, lo, hi);
            (Plan { choice: sel.choice, time_us: sel.time_us, mem_bytes }, fp, sel.remat)
        }
        SpanValues::Legacy { cap, .. } => {
            let plan = match &tables.sp {
                Some(sp) => spdag::sp_search_span(&tables.ctx, sp, Some(*cap), lo, hi)
                    .or_else(|| spdag::sp_search_span(&tables.ctx, sp, None, lo, hi)),
                None => cost::search_span_ctx(&tables.ctx, Some(*cap), lo, hi)
                    .or_else(|| cost::search_span_ctx(&tables.ctx, None, lo, hi)),
            }?;
            let fp = memory::span_footprint(&ctx.segments, &ctx.db, &plan.choice, lo, hi);
            (plan, fp, vec![false; hi - lo])
        }
    };
    let peak_mem_bytes = footprint.peak_bytes(me, f);
    let latency_us = plan.time_us / mf + p2p_in_us;
    Some(StagePlan {
        span: (lo, hi),
        devices: (stage_idx * ctx.devices, (stage_idx + 1) * ctx.devices),
        plan,
        p2p_in_us,
        latency_us,
        footprint,
        peak_mem_bytes,
        remat,
    })
}

/// Re-solve every stage span of a composed plan with the exact
/// branch-and-bound lane (`cost::exact`) and compare against the DP's
/// stage times bit-for-bit. Returns the number of stages actually
/// checked (spans whose search space exceeds `max_bits`, or that exhaust
/// the exact lane's node budget, are skipped — never guessed).
///
/// The two possible `Err` classes are deliberately distinguished:
/// a *known approximation* (the DP's frontier thinning dropped the true
/// optimum, or declared a cap infeasible that the exact lane can fit) is
/// reported as `DP suboptimal`; an exact time *worse* than the DP's is
/// impossible for a complete searcher and reported as a genuine bug.
pub fn exact_crosscheck_stages(
    ctxs: &StageContexts,
    opts: &PipelineOptions,
    plan: &PipelinePlan,
    max_bits: f64,
) -> Result<usize, String> {
    let ctx = ctxs
        .get(plan.devices_per_stage)
        .ok_or_else(|| format!("no stage context for d = {}", plan.devices_per_stage))?;
    let sctx = SearchCtx::new(&ctx.segments, &ctx.db);
    let sp = (!ctx.topo.is_chain()).then(|| SpCtx::new(&sctx, &ctx.topo, &ctx.db));
    let k = plan.num_stages();
    let me = memory::memory_microbatches(k, plan.microbatches);
    let cap = opts.device_cap();
    let mut checked = 0;
    for (i, st) in plan.stages.iter().enumerate() {
        let (lo, hi) = st.span;
        if cost::space_bits(&sctx, lo, hi) > max_bits {
            continue;
        }
        let got = st.plan.time_us;
        if opts.memory_aware() {
            let ex = match &sp {
                // the SP memory oracle is a full enumeration with true
                // dominance — no node budget to exhaust
                Some(sp) => spdag::sp_search_mem_span_exact(&sctx, sp, lo, hi, opts.recompute),
                None => match cost::exact::search_span_mem_exact_budget(
                    &sctx,
                    lo,
                    hi,
                    opts.recompute,
                    4_000_000,
                ) {
                    Ok(frontier) => frontier,
                    Err(cost::exact::Exhausted) => continue,
                },
            };
            let f = memory::inflight_microbatches(k, i, me);
            match memory::select_feasible(&ex, me, f, cap) {
                None => {
                    return Err(format!(
                        "stage {i} span [{lo},{hi}): genuine bug — exact frontier has no \
                         feasible point but the DP priced {got} µs"
                    ));
                }
                Some(e) if e.time_us.to_bits() == got.to_bits() => {}
                Some(e) if e.time_us < got => {
                    return Err(format!(
                        "stage {i} span [{lo},{hi}): DP suboptimal (frontier thinning) — \
                         exact {e} µs < DP {got} µs",
                        e = e.time_us
                    ));
                }
                Some(e) => {
                    return Err(format!(
                        "stage {i} span [{lo},{hi}): genuine bug — exact {e} µs > DP {got} µs",
                        e = e.time_us
                    ));
                }
            }
        } else {
            let dp_capped = match &sp {
                Some(sp) => spdag::sp_search_span(&sctx, sp, Some(cap), lo, hi),
                None => cost::search_span_ctx(&sctx, Some(cap), lo, hi),
            };
            let ex_capped = match &sp {
                Some(sp) => match spdag::sp_search_span_exact_budget(
                    &sctx,
                    sp,
                    Some(cap),
                    lo,
                    hi,
                    4_000_000,
                ) {
                    Ok(p) => p,
                    Err(cost::exact::Exhausted) => continue,
                },
                None => match cost::exact::search_span_exact_budget(
                    &sctx,
                    Some(cap),
                    lo,
                    hi,
                    4_000_000,
                ) {
                    Ok(p) => p,
                    Err(cost::exact::Exhausted) => continue,
                },
            };
            match (dp_capped, ex_capped) {
                (Some(_), None) => {
                    return Err(format!(
                        "stage {i} span [{lo},{hi}): genuine bug — the complete exact search \
                         found no capped plan but the DP did"
                    ));
                }
                (None, Some(e)) => {
                    return Err(format!(
                        "stage {i} span [{lo},{hi}): DP suboptimal (frontier thinning) — the \
                         DP declared the cap infeasible but the exact lane fits it in {t} µs",
                        t = e.time_us
                    ));
                }
                (Some(d), Some(e)) => {
                    if e.time_us < d.time_us {
                        return Err(format!(
                            "stage {i} span [{lo},{hi}): DP suboptimal (frontier thinning) — \
                             exact {e} µs < DP {d} µs",
                            e = e.time_us,
                            d = d.time_us
                        ));
                    }
                    if e.time_us > d.time_us {
                        return Err(format!(
                            "stage {i} span [{lo},{hi}): genuine bug — exact {e} µs > DP {d} µs",
                            e = e.time_us,
                            d = d.time_us
                        ));
                    }
                }
                (None, None) => {
                    // both searchers agree the cap is infeasible; the
                    // stage plan came from the uncapped fallback, where
                    // the scalar DP is provably exact — demand bit-parity
                    let e = match &sp {
                        Some(sp) => match spdag::sp_search_span_exact_budget(
                            &sctx, sp, None, lo, hi, 4_000_000,
                        ) {
                            Ok(p) => p,
                            Err(cost::exact::Exhausted) => continue,
                        },
                        None => match cost::exact::search_span_exact_budget(
                            &sctx, None, lo, hi, 4_000_000,
                        ) {
                            Ok(p) => p,
                            Err(cost::exact::Exhausted) => continue,
                        },
                    };
                    match e {
                        Some(e) if e.time_us.to_bits() == got.to_bits() => {}
                        other => {
                            return Err(format!(
                                "stage {i} span [{lo},{hi}): genuine bug — uncapped exact \
                                 {other:?} disagrees with the DP's {got} µs"
                            ));
                        }
                    }
                }
            }
        }
        checked += 1;
    }
    Ok(checked)
}

/// Per-microbatch stage latency `T/m + x` for span `[lo, hi)` as stage
/// `stage_idx` (0-based) of `k`; None if the span has no feasible plan
/// (under the 1F1B peak cap when memory-aware). This is the DP's hot
/// transition: one table read (legacy) or one frontier probe
/// (memory-aware) — the selection and arithmetic are shared with
/// [`build_stage_plan`], which materializes the identical stage during
/// final reconstruction.
fn stage_latency(
    g: &Graph,
    ctx: &StageContext,
    opts: &PipelineOptions,
    tables: &SpanTables,
    lo: usize,
    hi: usize,
    stage_idx: usize,
    k: usize,
) -> Option<f64> {
    let time_us = tables.span_time(opts, lo, hi, stage_idx, k)?;
    let mf = opts.microbatches.max(1) as f64;
    let p2p = if stage_idx == 0 { 0.0 } else { p2p_in_us(g, ctx, opts, lo, stage_idx) };
    Some(time_us / mf + p2p)
}

/// Per-microbatch point-to-point transfer into the stage whose span
/// starts at instance `lo`: the boundary activation (full-batch bytes
/// `B`) crosses as a `B/(m·d)` message per parallel device pair, once
/// forward (activation) and once backward (its gradient). The link is
/// the inter-node one when the stage cut coincides with a node boundary.
fn p2p_in_us(
    g: &Graph,
    ctx: &StageContext,
    opts: &PipelineOptions,
    lo: usize,
    stage_idx: usize,
) -> f64 {
    let inst = &ctx.segments.instances[lo];
    let Some(t) = crate::profiler::run::boundary_tensor(g, inst.fwd_range.0) else {
        return 0.0;
    };
    let bytes = g.ops[t].bytes() as u64;
    let m = opts.microbatches.max(1) as u64;
    let d = ctx.devices.max(1) as u64;
    let msg = (bytes / (m * d)).max(1);
    let first_dev = stage_idx * ctx.devices;
    let gpn = opts.platform.gpus_per_node.max(1);
    let link = if opts.platform.nodes > 1 && first_dev % gpn == 0 {
        &opts.platform.inter
    } else {
        &opts.platform.intra
    };
    2.0 * collective_time_us(CollKind::SendRecv, msg, 2, link)
}

/// DDP config per instance (uniform per unique segment): every block its
/// `m`/batch-split strategy where available — what the naive pipeline
/// runs inside each stage.
fn ddp_choice(ctx: &StageContext) -> Vec<usize> {
    let ss = &ctx.segments;
    let bs = &ctx.blocks;
    let per_unique: Vec<usize> = ss
        .unique
        .iter()
        .map(|u| {
            let inst = &ss.instances[u.rep];
            let desired: Vec<usize> = inst
                .blocks
                .iter()
                .map(|&b| {
                    bs.blocks[b].strategies.iter().position(|s| s.label == "m").unwrap_or(0)
                })
                .collect();
            ctx.db.segments[u.id]
                .configs
                .iter()
                .enumerate()
                .max_by_key(|(_, c)| {
                    c.strategy.iter().zip(&desired).filter(|(a, b)| a == b).count()
                })
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect();
    ss.instances.iter().map(|i| per_unique[i.unique_id]).collect()
}

/// Keep only `(sum, max)`-undominated states. Exact for any objective
/// monotone in both components (ours: `sum + (m−1)·max`).
fn prune_states(states: &mut Vec<SplitState>) {
    states.sort_by(|a, b| {
        a.sum
            .partial_cmp(&b.sum)
            .unwrap()
            .then(a.mx.partial_cmp(&b.mx).unwrap())
    });
    let mut out: Vec<SplitState> = Vec::new();
    let mut best_mx = f64::INFINITY;
    for st in states.drain(..) {
        if st.mx < best_mx {
            best_mx = st.mx;
            out.push(st);
        }
    }
    *states = out;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_counts_are_divisors() {
        let m4 = Mesh::flat(4);
        let m16 = Mesh { intra: 8, nodes: 2 };
        assert_eq!(candidate_stage_counts(StageSpec::Auto, m4), vec![1, 2, 4]);
        assert_eq!(candidate_stage_counts(StageSpec::Auto, m16), vec![1, 2, 4, 8, 16]);
        assert_eq!(candidate_stage_counts(StageSpec::Single, Mesh::flat(8)), vec![1]);
        assert_eq!(candidate_stage_counts(StageSpec::Fixed(2), m4), vec![2]);
        // non-divisor requests normalize down
        assert_eq!(candidate_stage_counts(StageSpec::Fixed(3), m4), vec![2]);
        assert_eq!(candidate_stage_counts(StageSpec::Fixed(99), m4), vec![4]);
    }

    #[test]
    fn stage_counts_skip_node_straddling_sub_meshes() {
        // 8 GPUs × 3 nodes: k = 2 ⇒ d = 12 (not a node multiple), k = 4 ⇒
        // d = 6 (stage [6, 12) crosses node 0 → 1), k = 8 ⇒ d = 3 (stage
        // [6, 9) likewise) — all must be filtered out
        let m = Mesh { intra: 8, nodes: 3 };
        let ks = candidate_stage_counts(StageSpec::Auto, m);
        assert_eq!(ks, vec![1, 3, 6, 12, 24], "d = 24, 8, 4, 2, 1");
        for bad in [2usize, 4, 8] {
            assert!(!ks.contains(&bad), "k = {bad} straddles a node boundary");
        }
        // a Fixed request for a filtered k normalizes to a valid one
        assert_eq!(candidate_stage_counts(StageSpec::Fixed(2), m), vec![1]);
        assert_eq!(candidate_stage_counts(StageSpec::Fixed(4), m), vec![3]);
    }

    #[test]
    fn stage_spec_parses() {
        assert_eq!(StageSpec::parse("auto"), Some(StageSpec::Auto));
        assert_eq!(StageSpec::parse("single"), Some(StageSpec::Single));
        assert_eq!(StageSpec::parse("1"), Some(StageSpec::Single));
        assert_eq!(StageSpec::parse("4"), Some(StageSpec::Fixed(4)));
        assert_eq!(StageSpec::parse("bogus"), None);
    }

    #[test]
    fn sub_meshes_stay_inside_nodes() {
        let full = Mesh { intra: 8, nodes: 2 };
        assert_eq!(sub_mesh(full, 16), full);
        assert_eq!(sub_mesh(full, 8), Mesh::flat(8));
        assert_eq!(sub_mesh(full, 4), Mesh::flat(4));
        assert_eq!(sub_mesh(Mesh { intra: 4, nodes: 4 }, 8), Mesh { intra: 4, nodes: 2 });
    }

    #[test]
    fn pruning_keeps_undominated_states() {
        let st = |sum: f64, mx: f64| SplitState { sum, mx, starts: vec![] };
        let mut states = vec![st(10.0, 5.0), st(8.0, 6.0), st(12.0, 4.0), st(9.0, 7.0)];
        prune_states(&mut states);
        let pairs: Vec<(f64, f64)> = states.iter().map(|s| (s.sum, s.mx)).collect();
        // (9,7) is dominated by (8,6); the rest trade sum against max
        assert_eq!(pairs, vec![(8.0, 6.0), (10.0, 5.0), (12.0, 4.0)]);
    }

    #[test]
    fn compose_step_reduces_to_bubble_formula_when_balanced() {
        let step = compose_step_us(&[10.0, 10.0, 10.0, 10.0], 8);
        // (k − 1 + m)/m · k·l/k ... = (m + k − 1) · l
        assert!((step - (8.0 + 3.0) * 10.0).abs() < 1e-9);
    }
}
