//! Two-level planner: inter-operator pipeline staging over the intra-op DP.
//!
//! CFP (§4.4) searches intra-operator plans for a chain of segments that
//! owns the *whole* device mesh. This module adds the outer level of the
//! Alpa-style decomposition: partition the segment chain into `k`
//! contiguous pipeline stages, give each stage its own sub-mesh of the
//! cluster, solve the existing memory-constrained intra-op DP *per stage*
//! ([`crate::cost::search_span`]), and compose the per-stage plans with a
//! 1F1B-style pipeline schedule ([`crate::cluster::simulate_pipeline`]).
//!
//! # Cost model
//!
//! With `m` microbatches and stage `i`'s whole-batch intra-op plan time
//! `Tᵢ`, the per-microbatch stage latency is `lᵢ = Tᵢ/m + xᵢ`, where `xᵢ`
//! is the per-microbatch point-to-point activation transfer into stage
//! `i` (forward activation + backward gradient, priced by
//! [`crate::cluster::collective_time_us`] over the link the stage cut
//! crosses — inter-node when the cut coincides with a node boundary).
//! The composed step time is the flow-line makespan for `m` identical
//! microbatches:
//!
//! ```text
//! T_step = Σᵢ lᵢ + (m − 1) · maxᵢ lᵢ
//! ```
//!
//! which reduces to `(k − 1 + m)/m · l` for balanced stages — the
//! familiar 1F1B bubble formula. `k = 1` bypasses the microbatch
//! division entirely, so a degenerate pipeline reproduces today's
//! single-stage plan (and step time) bit-for-bit.
//!
//! # Search
//!
//! The stage-split search is a DP over split points with a per-prefix
//! Pareto state on `(Σ l, max l)`. Pruning a dominated state is exact:
//! both components only grow when a suffix is appended and the objective
//! is monotone in both, so the DP provably matches brute-force
//! enumeration of all `C(n−1, k−1)` split vectors (pinned by the
//! `integration_interop` tests). Per-(stage-span, sub-mesh) intra-op
//! solutions are memoized, and every sub-mesh context is profiled through
//! [`crate::profiler::profile_model_cached`] so the persistent
//! fingerprint cache makes warm runs cheap across *all* stage counts.
//!
//! # Invariants
//!
//! * Stages are contiguous, non-empty spans covering the chain exactly
//!   once, in order — required for [`crate::cost::plan_cost_span`]'s
//!   boundary-reshard accounting and for the p2p model (one activation
//!   tensor crosses each cut).
//! * All stages of a candidate plan share one sub-mesh size
//!   `d = total_devices / k`; a context profiled at `d` is valid for
//!   every span (profiles depend on the partition count, not the span).
//! * The candidate stage counts are the divisors of the device count, so
//!   `k · d` always uses the whole cluster.

use std::collections::{BTreeMap, HashMap};

use crate::cluster::sim::ComputeModel;
use crate::cluster::{collective_time_us, simulate_pipeline, Platform};
use crate::cost::{self, Plan};
use crate::graph::Graph;
use crate::pblock::{build_parallel_blocks, BlockSet};
use crate::profiler::{profile_model_cached, ProfileCache, ProfileDb, ProfileOptions};
use crate::segment::{extract_segments, SegmentSet};
use crate::spmd::{CollKind, Mesh};

/// How many pipeline stages the two-level planner may use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StageSpec {
    /// One stage — today's single-level CFP behaviour.
    Single,
    /// Search every stage count that divides the device count.
    Auto,
    /// Exactly `k` stages (normalized down to the nearest divisor of the
    /// device count; `Fixed(1)` ≡ `Single`).
    Fixed(usize),
}

impl StageSpec {
    /// Parse a `--stages` CLI value: `auto`, `single`, or a number.
    pub fn parse(s: &str) -> Option<StageSpec> {
        match s {
            "auto" => Some(StageSpec::Auto),
            "single" | "1" => Some(StageSpec::Single),
            _ => s.parse::<usize>().ok().map(|k| {
                if k <= 1 {
                    StageSpec::Single
                } else {
                    StageSpec::Fixed(k)
                }
            }),
        }
    }
}

/// Options for the two-level planner. The intra-op knobs mirror
/// `coordinator::CfpOptions`; `microbatches` and `spec` drive the outer
/// level.
#[derive(Clone)]
pub struct PipelineOptions {
    pub platform: Platform,
    /// full-cluster mesh; stages carve contiguous sub-meshes out of it
    pub mesh: Mesh,
    /// per-device memory cap (None → platform capacity)
    pub mem_cap: Option<u64>,
    pub threads: usize,
    pub compute: Option<ComputeModel>,
    /// gradient-accumulation microbatches per step (the `m` of the bubble
    /// formula)
    pub microbatches: usize,
    pub spec: StageSpec,
}

impl PipelineOptions {
    pub fn new(platform: Platform, mesh: Mesh) -> PipelineOptions {
        PipelineOptions {
            platform,
            mesh,
            mem_cap: None,
            threads: 1,
            compute: None,
            microbatches: 8,
            spec: StageSpec::Auto,
        }
    }
}

/// One intra-op planning context, profiled for a specific sub-mesh size.
/// ParallelBlocks, segments and profiles all depend on the partition
/// count, so each distinct `devices` gets its own context.
pub struct StageContext {
    /// devices per stage (the sub-mesh size `d`)
    pub devices: usize,
    pub mesh: Mesh,
    pub blocks: BlockSet,
    pub segments: SegmentSet,
    pub db: ProfileDb,
}

/// Memoized per-sub-mesh-size contexts shared by the CFP planner and the
/// naive baseline (one profiling pass per distinct `d`, cache-served when
/// warm).
#[derive(Default)]
pub struct StageContexts {
    by_devices: BTreeMap<usize, StageContext>,
}

impl StageContexts {
    pub fn new() -> StageContexts {
        StageContexts::default()
    }

    /// Build (and profile) the context for sub-mesh size `devices` if it
    /// is not already present.
    pub fn ensure(
        &mut self,
        g: &Graph,
        opts: &PipelineOptions,
        devices: usize,
        cache: Option<&mut ProfileCache>,
    ) {
        if !self.by_devices.contains_key(&devices) {
            self.by_devices.insert(devices, build_context(g, opts, devices, cache));
        }
    }

    /// Ensure a context exists for every candidate stage count of
    /// `opts.spec`. Contexts whose segment chain is shorter than the
    /// stage count are skipped *before* the (expensive) profiling pass —
    /// a `k`-stage split of fewer than `k` instances is impossible, so
    /// profiling them would be pure waste (the analysis passes that
    /// determine the chain length are cheap).
    pub fn ensure_all(
        &mut self,
        g: &Graph,
        opts: &PipelineOptions,
        mut cache: Option<&mut ProfileCache>,
    ) {
        let total = opts.mesh.total();
        for k in candidate_stage_counts(opts.spec, opts.mesh) {
            let devices = total / k;
            if self.by_devices.contains_key(&devices) {
                continue;
            }
            let mesh = sub_mesh(opts.mesh, devices);
            let blocks = build_parallel_blocks(g, mesh.intra);
            let segments = extract_segments(g, &blocks);
            if segments.instances.len() < k {
                continue;
            }
            let db = profile_context(g, opts, mesh, &blocks, &segments, cache.as_deref_mut());
            self.by_devices.insert(devices, StageContext { devices, mesh, blocks, segments, db });
        }
    }

    /// Adopt an already-profiled context (e.g. the whole-cluster
    /// artifacts of a single-stage `run_cfp`) so `k = 1` reuses them
    /// verbatim instead of re-profiling.
    pub fn adopt(&mut self, ctx: StageContext) {
        self.by_devices.insert(ctx.devices, ctx);
    }

    pub fn get(&self, devices: usize) -> Option<&StageContext> {
        self.by_devices.get(&devices)
    }

    pub fn len(&self) -> usize {
        self.by_devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_devices.is_empty()
    }
}

/// Build one sub-mesh context: ParallelBlocks + segments at `devices`
/// partitions, profiled through the (optionally persistent) cache.
pub fn build_context(
    g: &Graph,
    opts: &PipelineOptions,
    devices: usize,
    cache: Option<&mut ProfileCache>,
) -> StageContext {
    let mesh = sub_mesh(opts.mesh, devices);
    let blocks = build_parallel_blocks(g, mesh.intra);
    let segments = extract_segments(g, &blocks);
    let db = profile_context(g, opts, mesh, &blocks, &segments, cache);
    StageContext { devices, mesh, blocks, segments, db }
}

/// The MetricsProfiling half of [`build_context`]: profile an
/// already-analyzed (blocks, segments) pair at `mesh`.
fn profile_context(
    g: &Graph,
    opts: &PipelineOptions,
    mesh: Mesh,
    blocks: &BlockSet,
    segments: &SegmentSet,
    cache: Option<&mut ProfileCache>,
) -> ProfileDb {
    let mut popts = ProfileOptions::new(opts.platform, mesh).with_threads(opts.threads);
    if let Some(cm) = &opts.compute {
        popts = popts.with_compute(cm.clone());
    }
    profile_model_cached(g, blocks, segments, &popts, cache)
}

/// Candidate stage counts for a spec: the divisors of the device count
/// (ascending) whose per-stage share `d = total/k` tiles the node
/// structure — `d` must divide the per-node GPU count (aligned
/// within-node slices) or be a whole multiple of it (whole nodes).
/// Anything else puts some stage across a node boundary, which
/// [`sub_mesh`] cannot express (e.g. intra 8 × 3 nodes: k = 2 ⇒ d = 12,
/// or k = 4 ⇒ d = 6, both straddle). Filtered/normalized per the spec;
/// `k = 1` (`d = total`) is always valid.
pub fn candidate_stage_counts(spec: StageSpec, mesh: Mesh) -> Vec<usize> {
    let total = mesh.total().max(1);
    let intra = mesh.intra.max(1);
    let divisors: Vec<usize> = (1..=total)
        .filter(|k| total % k == 0)
        .filter(|k| {
            let d = total / k;
            intra % d == 0 || d % intra == 0
        })
        .collect();
    match spec {
        StageSpec::Single => vec![1],
        StageSpec::Auto => divisors,
        StageSpec::Fixed(k) => {
            vec![divisors.iter().copied().filter(|&d| d <= k).max().unwrap_or(1)]
        }
    }
}

/// The sub-mesh a stage of `devices` devices occupies. Only called for
/// the sizes [`candidate_stage_counts`] admits: `devices ≤ intra`
/// (within-node slice) or a whole number of nodes — stages never
/// straddle node boundaries.
pub fn sub_mesh(full: Mesh, devices: usize) -> Mesh {
    if devices >= full.total() {
        full
    } else if devices <= full.intra {
        debug_assert_eq!(full.intra % devices.max(1), 0, "stage straddles a node boundary");
        Mesh::flat(devices)
    } else {
        debug_assert_eq!(devices % full.intra, 0, "stage straddles a node boundary");
        Mesh { intra: full.intra, nodes: devices / full.intra }
    }
}

/// One pipeline stage of a composed two-level plan.
#[derive(Clone, Debug)]
pub struct StagePlan {
    /// instance span `[lo, hi)` in the stage context's segment chain
    pub span: (usize, usize),
    /// global device range `[first, last)`
    pub devices: (usize, usize),
    /// intra-op plan for the span (whole-batch time/memory)
    pub plan: Plan,
    /// per-microbatch incoming activation transfer, µs (0 for stage 0)
    pub p2p_in_us: f64,
    /// per-microbatch stage latency `Tᵢ/m + xᵢ`, µs
    pub latency_us: f64,
}

/// A composed two-level plan: contiguous stages, each with its own
/// sub-mesh and intra-op plan.
#[derive(Clone, Debug)]
pub struct PipelinePlan {
    pub stages: Vec<StagePlan>,
    pub devices_per_stage: usize,
    pub microbatches: usize,
    /// composed step time, µs (exactly the intra-op plan time when k = 1)
    pub step_time_us: f64,
    /// peak per-device memory across stages
    pub mem_bytes: u64,
    /// pipeline-bubble share of the step (0 for k = 1)
    pub bubble_fraction: f64,
}

impl PipelinePlan {
    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// Human-readable per-stage summary lines.
    pub fn describe(&self) -> Vec<String> {
        self.stages
            .iter()
            .enumerate()
            .map(|(s, st)| {
                format!(
                    "stage {s}: segments [{}, {}) on devices [{}, {})  \
                     intra-op {:.1}µs  p2p/µb {:.1}µs  mem {} MB",
                    st.span.0,
                    st.span.1,
                    st.devices.0,
                    st.devices.1,
                    st.plan.time_us,
                    st.p2p_in_us,
                    st.plan.mem_bytes >> 20,
                )
            })
            .collect()
    }
}

/// CFP two-level plan: best stage count × best split × best per-stage
/// intra-op plan. Returns None only if no candidate stage count yields a
/// feasible plan (never for `Auto`/`Single` on a chain the single-stage
/// search can solve, since `k = 1` is in the candidate set).
pub fn plan_pipeline(
    g: &Graph,
    ctxs: &StageContexts,
    opts: &PipelineOptions,
) -> Option<PipelinePlan> {
    let total = opts.mesh.total();
    let mut best: Option<PipelinePlan> = None;
    for k in candidate_stage_counts(opts.spec, opts.mesh) {
        let Some(ctx) = ctxs.get(total / k) else { continue };
        let mut memo = HashMap::new();
        if let Some(p) = plan_fixed_stages_memo(g, ctx, opts, k, &mut memo) {
            if best.as_ref().map_or(true, |b| p.step_time_us < b.step_time_us) {
                best = Some(p);
            }
        }
    }
    if best.is_none() {
        // an infeasible Fixed(k) request (e.g. more stages than segments)
        // degrades to the single-stage plan rather than failing
        if let Some(ctx) = ctxs.get(total) {
            let mut memo = HashMap::new();
            best = plan_fixed_stages_memo(g, ctx, opts, 1, &mut memo);
        }
    }
    best
}

/// Best `k`-stage plan over one context (the DP the tests verify against
/// brute-force split enumeration).
pub fn plan_fixed_stages(
    g: &Graph,
    ctx: &StageContext,
    opts: &PipelineOptions,
    k: usize,
) -> Option<PipelinePlan> {
    let mut memo = HashMap::new();
    plan_fixed_stages_memo(g, ctx, opts, k, &mut memo)
}

/// Pareto state of a stage-split DP prefix: the latency sum and max so
/// far, plus the start index of every stage chosen (for backtracking).
#[derive(Clone)]
struct SplitState {
    sum: f64,
    mx: f64,
    starts: Vec<usize>,
}

fn plan_fixed_stages_memo(
    g: &Graph,
    ctx: &StageContext,
    opts: &PipelineOptions,
    k: usize,
    memo: &mut HashMap<(usize, usize), Option<Plan>>,
) -> Option<PipelinePlan> {
    let n = ctx.segments.instances.len();
    if k == 0 || k > n {
        return None;
    }
    let m = opts.microbatches.max(1);
    let mf = m as f64;
    if k == 1 {
        let plan = solve_span(ctx, opts, memo, 0, n)?;
        let step = plan.time_us;
        let mem = plan.mem_bytes;
        let latency_us = plan.time_us / mf;
        return Some(PipelinePlan {
            stages: vec![StagePlan {
                span: (0, n),
                devices: (0, ctx.devices),
                plan,
                p2p_in_us: 0.0,
                latency_us,
            }],
            devices_per_stage: ctx.devices,
            microbatches: m,
            step_time_us: step,
            mem_bytes: mem,
            bubble_fraction: 0.0,
        });
    }

    // DP over (stages used, instances consumed) with (sum, max) Pareto
    // states; dp[s][i] covers instances [0, i) with s stages.
    let mut dp: Vec<Vec<Vec<SplitState>>> = vec![vec![Vec::new(); n + 1]; k + 1];
    dp[0][0].push(SplitState { sum: 0.0, mx: 0.0, starts: Vec::new() });
    for s in 1..=k {
        // stage s ends at instance i; leave ≥ 1 instance per later stage
        for i in s..=(n - (k - s)) {
            let mut states: Vec<SplitState> = Vec::new();
            for j in (s - 1)..i {
                if dp[s - 1][j].is_empty() {
                    continue;
                }
                let Some(lat) = stage_latency(g, ctx, opts, memo, j, i, s - 1) else {
                    continue;
                };
                for st in &dp[s - 1][j] {
                    let mut starts = st.starts.clone();
                    starts.push(j);
                    states.push(SplitState {
                        sum: st.sum + lat,
                        mx: if lat > st.mx { lat } else { st.mx },
                        starts,
                    });
                }
            }
            prune_states(&mut states);
            dp[s][i] = states;
        }
    }

    let mut best: Option<&SplitState> = None;
    for st in &dp[k][n] {
        let v = st.sum + (mf - 1.0) * st.mx;
        if best.map_or(true, |b| v < b.sum + (mf - 1.0) * b.mx) {
            best = Some(st);
        }
    }
    let best = best?;
    let mut bounds = best.starts.clone();
    bounds.push(n);

    let mut stages = Vec::with_capacity(k);
    let mut lats = Vec::with_capacity(k);
    let mut mem_peak = 0u64;
    for s in 0..k {
        let (lo, hi) = (bounds[s], bounds[s + 1]);
        let plan = solve_span(ctx, opts, memo, lo, hi).expect("span solved during DP");
        let p2p_in_us = if s == 0 { 0.0 } else { p2p_in_us(g, ctx, opts, lo, s) };
        let latency_us = plan.time_us / mf + p2p_in_us;
        if plan.mem_bytes > mem_peak {
            mem_peak = plan.mem_bytes;
        }
        lats.push(latency_us);
        stages.push(StagePlan {
            span: (lo, hi),
            devices: (s * ctx.devices, (s + 1) * ctx.devices),
            plan,
            p2p_in_us,
            latency_us,
        });
    }
    let step_time_us = compose_step_us(&lats, m);
    let bubble_fraction = simulate_pipeline(&lats, m).bubble_fraction;
    Some(PipelinePlan {
        stages,
        devices_per_stage: ctx.devices,
        microbatches: m,
        step_time_us,
        mem_bytes: mem_peak,
        bubble_fraction,
    })
}

/// Exhaustive split enumeration for a fixed stage count — tests only
/// (`C(n−1, k−1)` partitions). Same latency and composition arithmetic
/// as the DP, so the optimal *value* matches exactly.
pub fn brute_force_splits(
    g: &Graph,
    ctx: &StageContext,
    opts: &PipelineOptions,
    k: usize,
) -> Option<f64> {
    let n = ctx.segments.instances.len();
    if k == 0 || k > n {
        return None;
    }
    let mut memo = HashMap::new();
    if k == 1 {
        return solve_span(ctx, opts, &mut memo, 0, n).map(|p| p.time_us);
    }
    let m = opts.microbatches.max(1);
    let r = k - 1; // number of cut points, values in 1..n strictly increasing
    let mut cuts: Vec<usize> = (1..=r).collect();
    let mut best: Option<f64> = None;
    loop {
        let mut bounds = Vec::with_capacity(k + 1);
        bounds.push(0);
        bounds.extend(cuts.iter().copied());
        bounds.push(n);
        let mut lats = Vec::with_capacity(k);
        for s in 0..k {
            match stage_latency(g, ctx, opts, &mut memo, bounds[s], bounds[s + 1], s) {
                Some(l) => lats.push(l),
                None => break,
            }
        }
        if lats.len() == k {
            let v = compose_step_us(&lats, m);
            if best.map_or(true, |b| v < b) {
                best = Some(v);
            }
        }
        // next strictly-increasing cut combination
        let mut idx = r;
        loop {
            if idx == 0 {
                return best;
            }
            idx -= 1;
            if cuts[idx] < (n - 1) - (r - 1 - idx) {
                cuts[idx] += 1;
                for j in idx + 1..r {
                    cuts[j] = cuts[j - 1] + 1;
                }
                break;
            }
        }
    }
}

/// Naive equal-layer-split pipeline baseline: contiguous spans of (as
/// near as possible) equal instance counts, data-parallel config inside
/// every stage — the "shard by layers, DDP inside" recipe. It shares the
/// composition arithmetic with the CFP planner, so the comparison
/// isolates plan quality (split choice + intra-op configs).
pub fn naive_equal_split(
    g: &Graph,
    ctxs: &StageContexts,
    opts: &PipelineOptions,
) -> Option<PipelinePlan> {
    let total = opts.mesh.total();
    let mut best: Option<PipelinePlan> = None;
    for k in candidate_stage_counts(opts.spec, opts.mesh) {
        let Some(ctx) = ctxs.get(total / k) else { continue };
        if let Some(p) = naive_fixed_stages(g, ctx, opts, k) {
            if best.as_ref().map_or(true, |b| p.step_time_us < b.step_time_us) {
                best = Some(p);
            }
        }
    }
    if best.is_none() {
        if let Some(ctx) = ctxs.get(total) {
            best = naive_fixed_stages(g, ctx, opts, 1);
        }
    }
    best
}

/// The naive baseline at one fixed stage count.
pub fn naive_fixed_stages(
    g: &Graph,
    ctx: &StageContext,
    opts: &PipelineOptions,
    k: usize,
) -> Option<PipelinePlan> {
    let n = ctx.segments.instances.len();
    if k == 0 || k > n {
        return None;
    }
    let m = opts.microbatches.max(1);
    let mf = m as f64;
    let choice = ddp_choice(ctx);
    let bounds: Vec<usize> = (0..=k).map(|s| s * n / k).collect();
    let mut stages = Vec::with_capacity(k);
    let mut lats = Vec::with_capacity(k);
    let mut mem_peak = 0u64;
    for s in 0..k {
        let (lo, hi) = (bounds[s], bounds[s + 1]);
        let (time_us, mem_bytes) =
            cost::plan_cost_span(&ctx.segments, &ctx.db, &choice[lo..hi], lo, hi);
        let p2p_in_us = if s == 0 { 0.0 } else { p2p_in_us(g, ctx, opts, lo, s) };
        let latency_us = time_us / mf + p2p_in_us;
        if mem_bytes > mem_peak {
            mem_peak = mem_bytes;
        }
        lats.push(latency_us);
        stages.push(StagePlan {
            span: (lo, hi),
            devices: (s * ctx.devices, (s + 1) * ctx.devices),
            plan: Plan { choice: choice[lo..hi].to_vec(), time_us, mem_bytes },
            p2p_in_us,
            latency_us,
        });
    }
    let (step_time_us, bubble_fraction) = if k == 1 {
        (stages[0].plan.time_us, 0.0)
    } else {
        (compose_step_us(&lats, m), simulate_pipeline(&lats, m).bubble_fraction)
    };
    Some(PipelinePlan {
        stages,
        devices_per_stage: ctx.devices,
        microbatches: m,
        step_time_us,
        mem_bytes: mem_peak,
        bubble_fraction,
    })
}

// ------------------------------------------------------------------ internals

/// `Σ l + (m−1)·max l`, accumulated left-to-right — the single source of
/// the composition arithmetic for the DP, the brute force, and the naive
/// baseline, so their values are comparable bit-for-bit.
fn compose_step_us(lats: &[f64], microbatches: usize) -> f64 {
    let mut sum = 0.0;
    let mut mx = 0.0f64;
    for &l in lats {
        sum += l;
        if l > mx {
            mx = l;
        }
    }
    sum + (microbatches.max(1) as f64 - 1.0) * mx
}

/// Memoized intra-op solution for span `[lo, hi)` under the per-device
/// memory cap, with the same unconstrained fallback as `run_cfp` (so the
/// `k = 1` span reproduces the single-stage plan exactly).
fn solve_span(
    ctx: &StageContext,
    opts: &PipelineOptions,
    memo: &mut HashMap<(usize, usize), Option<Plan>>,
    lo: usize,
    hi: usize,
) -> Option<Plan> {
    if let Some(p) = memo.get(&(lo, hi)) {
        return p.clone();
    }
    let cap = opts.mem_cap.or(Some(opts.platform.mem_capacity()));
    let plan = cost::search_span(&ctx.segments, &ctx.db, cap, lo, hi)
        .or_else(|| cost::search_span(&ctx.segments, &ctx.db, None, lo, hi));
    memo.insert((lo, hi), plan.clone());
    plan
}

/// Per-microbatch stage latency `T/m + x` for span `[lo, hi)` as stage
/// `stage_idx` (0-based); None if the span has no feasible intra-op plan.
fn stage_latency(
    g: &Graph,
    ctx: &StageContext,
    opts: &PipelineOptions,
    memo: &mut HashMap<(usize, usize), Option<Plan>>,
    lo: usize,
    hi: usize,
    stage_idx: usize,
) -> Option<f64> {
    let time_us = solve_span(ctx, opts, memo, lo, hi)?.time_us;
    let mf = opts.microbatches.max(1) as f64;
    let p2p = if stage_idx == 0 { 0.0 } else { p2p_in_us(g, ctx, opts, lo, stage_idx) };
    Some(time_us / mf + p2p)
}

/// Per-microbatch point-to-point transfer into the stage whose span
/// starts at instance `lo`: the boundary activation (full-batch bytes
/// `B`) crosses as a `B/(m·d)` message per parallel device pair, once
/// forward (activation) and once backward (its gradient). The link is
/// the inter-node one when the stage cut coincides with a node boundary.
fn p2p_in_us(
    g: &Graph,
    ctx: &StageContext,
    opts: &PipelineOptions,
    lo: usize,
    stage_idx: usize,
) -> f64 {
    let inst = &ctx.segments.instances[lo];
    let Some(t) = crate::profiler::run::boundary_tensor(g, inst.fwd_range.0) else {
        return 0.0;
    };
    let bytes = g.ops[t].bytes() as u64;
    let m = opts.microbatches.max(1) as u64;
    let d = ctx.devices.max(1) as u64;
    let msg = (bytes / (m * d)).max(1);
    let first_dev = stage_idx * ctx.devices;
    let gpn = opts.platform.gpus_per_node.max(1);
    let link = if opts.platform.nodes > 1 && first_dev % gpn == 0 {
        &opts.platform.inter
    } else {
        &opts.platform.intra
    };
    2.0 * collective_time_us(CollKind::SendRecv, msg, 2, link)
}

/// DDP config per instance (uniform per unique segment): every block its
/// `m`/batch-split strategy where available — what the naive pipeline
/// runs inside each stage.
fn ddp_choice(ctx: &StageContext) -> Vec<usize> {
    let ss = &ctx.segments;
    let bs = &ctx.blocks;
    let per_unique: Vec<usize> = ss
        .unique
        .iter()
        .map(|u| {
            let inst = &ss.instances[u.rep];
            let desired: Vec<usize> = inst
                .blocks
                .iter()
                .map(|&b| {
                    bs.blocks[b].strategies.iter().position(|s| s.label == "m").unwrap_or(0)
                })
                .collect();
            ctx.db.segments[u.id]
                .configs
                .iter()
                .enumerate()
                .max_by_key(|(_, c)| {
                    c.strategy.iter().zip(&desired).filter(|(a, b)| a == b).count()
                })
                .map(|(i, _)| i)
                .unwrap_or(0)
        })
        .collect();
    ss.instances.iter().map(|i| per_unique[i.unique_id]).collect()
}

/// Keep only `(sum, max)`-undominated states. Exact for any objective
/// monotone in both components (ours: `sum + (m−1)·max`).
fn prune_states(states: &mut Vec<SplitState>) {
    states.sort_by(|a, b| {
        a.sum
            .partial_cmp(&b.sum)
            .unwrap()
            .then(a.mx.partial_cmp(&b.mx).unwrap())
    });
    let mut out: Vec<SplitState> = Vec::new();
    let mut best_mx = f64::INFINITY;
    for st in states.drain(..) {
        if st.mx < best_mx {
            best_mx = st.mx;
            out.push(st);
        }
    }
    *states = out;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_counts_are_divisors() {
        let m4 = Mesh::flat(4);
        let m16 = Mesh { intra: 8, nodes: 2 };
        assert_eq!(candidate_stage_counts(StageSpec::Auto, m4), vec![1, 2, 4]);
        assert_eq!(candidate_stage_counts(StageSpec::Auto, m16), vec![1, 2, 4, 8, 16]);
        assert_eq!(candidate_stage_counts(StageSpec::Single, Mesh::flat(8)), vec![1]);
        assert_eq!(candidate_stage_counts(StageSpec::Fixed(2), m4), vec![2]);
        // non-divisor requests normalize down
        assert_eq!(candidate_stage_counts(StageSpec::Fixed(3), m4), vec![2]);
        assert_eq!(candidate_stage_counts(StageSpec::Fixed(99), m4), vec![4]);
    }

    #[test]
    fn stage_counts_skip_node_straddling_sub_meshes() {
        // 8 GPUs × 3 nodes: k = 2 ⇒ d = 12 (not a node multiple), k = 4 ⇒
        // d = 6 (stage [6, 12) crosses node 0 → 1), k = 8 ⇒ d = 3 (stage
        // [6, 9) likewise) — all must be filtered out
        let m = Mesh { intra: 8, nodes: 3 };
        let ks = candidate_stage_counts(StageSpec::Auto, m);
        assert_eq!(ks, vec![1, 3, 6, 12, 24], "d = 24, 8, 4, 2, 1");
        for bad in [2usize, 4, 8] {
            assert!(!ks.contains(&bad), "k = {bad} straddles a node boundary");
        }
        // a Fixed request for a filtered k normalizes to a valid one
        assert_eq!(candidate_stage_counts(StageSpec::Fixed(2), m), vec![1]);
        assert_eq!(candidate_stage_counts(StageSpec::Fixed(4), m), vec![3]);
    }

    #[test]
    fn stage_spec_parses() {
        assert_eq!(StageSpec::parse("auto"), Some(StageSpec::Auto));
        assert_eq!(StageSpec::parse("single"), Some(StageSpec::Single));
        assert_eq!(StageSpec::parse("1"), Some(StageSpec::Single));
        assert_eq!(StageSpec::parse("4"), Some(StageSpec::Fixed(4)));
        assert_eq!(StageSpec::parse("bogus"), None);
    }

    #[test]
    fn sub_meshes_stay_inside_nodes() {
        let full = Mesh { intra: 8, nodes: 2 };
        assert_eq!(sub_mesh(full, 16), full);
        assert_eq!(sub_mesh(full, 8), Mesh::flat(8));
        assert_eq!(sub_mesh(full, 4), Mesh::flat(4));
        assert_eq!(sub_mesh(Mesh { intra: 4, nodes: 4 }, 8), Mesh { intra: 4, nodes: 2 });
    }

    #[test]
    fn pruning_keeps_undominated_states() {
        let st = |sum: f64, mx: f64| SplitState { sum, mx, starts: vec![] };
        let mut states = vec![st(10.0, 5.0), st(8.0, 6.0), st(12.0, 4.0), st(9.0, 7.0)];
        prune_states(&mut states);
        let pairs: Vec<(f64, f64)> = states.iter().map(|s| (s.sum, s.mx)).collect();
        // (9,7) is dominated by (8,6); the rest trade sum against max
        assert_eq!(pairs, vec![(8.0, 6.0), (10.0, 5.0), (12.0, 4.0)]);
    }

    #[test]
    fn compose_step_reduces_to_bubble_formula_when_balanced() {
        let step = compose_step_us(&[10.0, 10.0, 10.0, 10.0], 8);
        // (k − 1 + m)/m · k·l/k ... = (m + k − 1) · l
        assert!((step - (8.0 + 3.0) * 10.0).abs() < 1e-9);
    }
}
