//! The lowered per-device SPMD program: a schedule of compute and
//! communication kernels (what XLA hands to the runtime after SPMD
//! partitioning — §2.1's "ultimately compiled into a SPMD form").

use crate::graph::OpId;

/// Collective kinds the lowering emits. Bytes are *global tensor bytes*
/// (the cluster model applies ring factors / hierarchy itself).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum CollKind {
    AllReduce,
    AllGather,
    ReduceScatter,
    AllToAll,
    Broadcast,
    /// Pairwise send/recv chain — what AllToAll degenerates to on PCIe
    /// (§5.7 "dispatched to ncclSendRecv kernels").
    SendRecv,
}

#[derive(Clone, Debug, PartialEq)]
pub enum Instr {
    /// Local kernel: `flops`/`bytes` are per-device (already divided by the
    /// sharding factor).
    Compute {
        op: OpId,
        flops: u64,
        bytes: u64,
    },
    /// Communication kernel over the intra-node group.
    Coll {
        kind: CollKind,
        bytes: u64,
        /// grad-sync collectives are bucketable (pass: bucket_gradients)
        grad_sync: bool,
        /// originating tensor (debug/bucketing identity)
        tensor: OpId,
    },
    /// Inter-node collective (2D mesh outer axis).
    CollInter {
        kind: CollKind,
        bytes: u64,
        grad_sync: bool,
        tensor: OpId,
    },
}

impl Instr {
    pub fn comm_bytes(&self) -> u64 {
        match self {
            Instr::Coll { bytes, .. } | Instr::CollInter { bytes, .. } => *bytes,
            _ => 0,
        }
    }

    pub fn is_comm(&self) -> bool {
        !matches!(self, Instr::Compute { .. })
    }
}

/// A lowered program plus its memory footprint.
#[derive(Clone, Debug, Default)]
pub struct SpmdProgram {
    pub instrs: Vec<Instr>,
    /// per-device parameter bytes
    pub param_bytes: u64,
    /// per-device gradient bytes
    pub grad_bytes: u64,
    /// per-device retained activation bytes (fwd outputs held for bwd)
    pub act_bytes: u64,
}

impl SpmdProgram {
    pub fn total_flops(&self) -> u64 {
        self.instrs
            .iter()
            .map(|i| match i {
                Instr::Compute { flops, .. } => *flops,
                _ => 0,
            })
            .sum()
    }

    /// Theoretical communication volume (bytes moved, the quantity Alpa's
    /// symbolic model minimizes — Fig. 1/9's x-axis).
    pub fn comm_volume(&self) -> u64 {
        self.instrs.iter().map(|i| i.comm_bytes()).sum()
    }

    pub fn comm_kernel_count(&self) -> usize {
        self.instrs.iter().filter(|i| i.is_comm()).count()
    }

    /// Peak memory per device, with optimizer state factor (1.0 = SGD,
    /// 3.0 ≈ Adam m+v+master) applied to params.
    pub fn peak_memory(&self, opt_factor: f64) -> u64 {
        let opt = (self.param_bytes as f64 * opt_factor) as u64;
        self.param_bytes + self.grad_bytes + self.act_bytes + opt
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn volume_and_counts() {
        let p = SpmdProgram {
            instrs: vec![
                Instr::Compute { op: 0, flops: 100, bytes: 8 },
                Instr::Coll { kind: CollKind::AllReduce, bytes: 64, grad_sync: true, tensor: 1 },
                Instr::CollInter {
                    kind: CollKind::AllGather,
                    bytes: 32,
                    grad_sync: false,
                    tensor: 2,
                },
            ],
            param_bytes: 10,
            grad_bytes: 10,
            act_bytes: 5,
        };
        assert_eq!(p.comm_volume(), 96);
        assert_eq!(p.comm_kernel_count(), 2);
        assert_eq!(p.total_flops(), 100);
        assert_eq!(p.peak_memory(1.0), 10 + 10 + 5 + 10);
    }
}
