//! SPMD lowering: turn (graph, plan) into a per-device program with
//! explicit communication kernels — the "downstream compilation" whose
//! behaviour symbolic cost models mispredict (paper §2.2).
//!
//! The mismatch sources are implemented for real here:
//!  * gradient-bucket fusion (many small AllReduces → few big ones) —
//!    why DP beats its volume-based estimate;
//!  * AllReduce→ReduceScatter rewriting when the consumer is sharded —
//!    why Alpa overestimated the MoE resharding cost 8× (§5.7);
//!  * RNG device restriction (replicated random tensors cost an AllReduce) —
//!    why TP lost to DP in Fig. 2 despite lower theoretical volume;
//!  * AllToAll dispatch to SendRecv kernels (priced by the cluster model,
//!    ruinous on PCIe) — why expert parallelism loses there.

pub mod lower;
pub mod passes;
pub mod plan;
pub mod program;

pub use lower::{lower, lower_filtered};
pub use plan::{GlobalPlan, Mesh, ShardState};
pub use program::{CollKind, Instr, SpmdProgram};
