//! Downstream optimization passes over lowered SPMD programs — the
//! compiler behaviours that decouple communication *volume* from
//! communication *time* (paper §2.2, §5.3).

use super::program::{CollKind, Instr, SpmdProgram};

/// Gradient bucketing: fuse same-kind grad-sync collectives into buckets of
/// up to `bucket_bytes`. This is XLA/DDP's gradient aggregation ("multiple
/// parameters synchronized and aggregated to a single large tensor ...
/// communicated using a single All-Reduce kernel with higher efficiency",
/// §2.2). Volume is unchanged; kernel count (and so launch/latency cost)
/// collapses.
pub fn bucket_gradients(prog: &mut SpmdProgram, bucket_bytes: u64) {
    let mut out: Vec<Instr> = Vec::with_capacity(prog.instrs.len());
    let mut pending: Vec<(CollKind, u64, usize)> = Vec::new(); // kind, bytes, tensor

    let flush = |pending: &mut Vec<(CollKind, u64, usize)>, out: &mut Vec<Instr>| {
        if pending.is_empty() {
            return;
        }
        // merge per kind, preserving first-seen order
        let mut kinds: Vec<CollKind> = Vec::new();
        for (k, _, _) in pending.iter() {
            if !kinds.contains(k) {
                kinds.push(*k);
            }
        }
        for k in kinds {
            let bytes: u64 = pending.iter().filter(|(pk, _, _)| *pk == k).map(|(_, b, _)| b).sum();
            let tensor = pending.iter().find(|(pk, _, _)| *pk == k).unwrap().2;
            out.push(Instr::Coll { kind: k, bytes, grad_sync: true, tensor });
        }
        pending.clear();
    };

    let mut pending_bytes = 0u64;
    for instr in prog.instrs.drain(..) {
        match instr {
            Instr::Coll { kind, bytes, grad_sync: true, tensor } => {
                pending.push((kind, bytes, tensor));
                pending_bytes += bytes;
                if pending_bytes >= bucket_bytes {
                    flush(&mut pending, &mut out);
                    pending_bytes = 0;
                }
            }
            // compute between grad syncs doesn't force a flush — buckets
            // accumulate across the optimizer region as DDP does
            other => out.push(other),
        }
    }
    flush(&mut pending, &mut out);
    prog.instrs = out;
}

/// Same bucketing for the inter-node axis.
pub fn bucket_gradients_inter(prog: &mut SpmdProgram, bucket_bytes: u64) {
    let mut out: Vec<Instr> = Vec::with_capacity(prog.instrs.len());
    let mut pending_bytes = 0u64;
    let mut pending: Vec<(CollKind, u64, usize)> = Vec::new();
    let flush = |pending: &mut Vec<(CollKind, u64, usize)>, out: &mut Vec<Instr>| {
        if let Some(&(kind, _, tensor)) = pending.first() {
            let bytes: u64 = pending.iter().map(|(_, b, _)| b).sum();
            out.push(Instr::CollInter { kind, bytes, grad_sync: true, tensor });
            pending.clear();
        }
    };
    for instr in prog.instrs.drain(..) {
        match instr {
            Instr::CollInter { kind, bytes, grad_sync: true, tensor } => {
                pending.push((kind, bytes, tensor));
                pending_bytes += bytes;
                if pending_bytes >= bucket_bytes {
                    flush(&mut pending, &mut out);
                    pending_bytes = 0;
                }
            }
            other => out.push(other),
        }
    }
    flush(&mut pending, &mut out);
    prog.instrs = out;
}

/// AllToAll → SendRecv dispatch (what NCCL does on PCIe-only hosts;
/// §5.7 "All-to-All operations would be dispatched to ncclSendRecv
/// kernels, which are highly inefficient on PCIe platforms").
pub fn dispatch_alltoall_sendrecv(prog: &mut SpmdProgram, parts: usize) {
    let mut out = Vec::with_capacity(prog.instrs.len());
    for instr in prog.instrs.drain(..) {
        match instr {
            Instr::Coll { kind: CollKind::AllToAll, bytes, grad_sync, tensor } => {
                // n-1 pairwise exchanges of bytes/n each
                for _ in 0..parts.saturating_sub(1) {
                    out.push(Instr::Coll {
                        kind: CollKind::SendRecv,
                        bytes: bytes / parts as u64,
                        grad_sync,
                        tensor,
                    });
                }
            }
            other => out.push(other),
        }
    }
    prog.instrs = out;
}

/// The *symbolic* (Alpa-view) communication volume of a program: what a
/// volume-based cost model believes before downstream optimization —
/// ReduceScatter rewrites charged as full AllReduces (the 8× MoE
/// overestimate of §5.7) and RNG replication syncs invisible (charged 0).
pub fn symbolic_volume(prog: &SpmdProgram, g: &crate::graph::Graph) -> u64 {
    let mut vol = 0u64;
    for i in &prog.instrs {
        match i {
            Instr::Coll { kind, bytes, grad_sync, tensor } => {
                let rng_sync = !grad_sync
                    && matches!(g.ops[*tensor].kind, crate::graph::OpKind::Rng);
                if rng_sync {
                    continue; // invisible to the symbolic model
                }
                vol += match kind {
                    // the symbolic model prices the pre-rewrite AllReduce
                    CollKind::ReduceScatter => bytes * 2,
                    _ => *bytes,
                };
            }
            Instr::CollInter { bytes, .. } => vol += bytes,
            Instr::Compute { .. } => {}
        }
    }
    vol
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grad_ar(bytes: u64, tensor: usize) -> Instr {
        Instr::Coll { kind: CollKind::AllReduce, bytes, grad_sync: true, tensor }
    }

    #[test]
    fn bucketing_reduces_kernel_count_not_volume() {
        let mut prog = SpmdProgram {
            instrs: (0..20).map(|i| grad_ar(1 << 20, i)).collect(),
            ..Default::default()
        };
        let vol_before = prog.comm_volume();
        bucket_gradients(&mut prog, 8 << 20);
        assert_eq!(prog.comm_volume(), vol_before);
        assert!(prog.comm_kernel_count() <= 3, "got {}", prog.comm_kernel_count());
    }

    #[test]
    fn bucketing_respects_bucket_size() {
        let mut prog = SpmdProgram {
            instrs: (0..4).map(|i| grad_ar(10 << 20, i)).collect(),
            ..Default::default()
        };
        bucket_gradients(&mut prog, 16 << 20);
        // 40MB in 16MB buckets → 2-3 kernels
        assert!(prog.comm_kernel_count() >= 2);
    }

    #[test]
    fn alltoall_dispatch_expands_to_pairwise() {
        let mut prog = SpmdProgram {
            instrs: vec![Instr::Coll {
                kind: CollKind::AllToAll,
                bytes: 4000,
                grad_sync: false,
                tensor: 0,
            }],
            ..Default::default()
        };
        dispatch_alltoall_sendrecv(&mut prog, 4);
        assert_eq!(prog.comm_kernel_count(), 3);
        assert_eq!(prog.comm_volume(), 3000);
        assert!(prog
            .instrs
            .iter()
            .all(|i| matches!(i, Instr::Coll { kind: CollKind::SendRecv, .. })));
    }

    #[test]
    fn bucketing_preserves_non_grad_collectives() {
        let mut prog = SpmdProgram {
            instrs: vec![
                Instr::Coll { kind: CollKind::AllGather, bytes: 7, grad_sync: false, tensor: 0 },
                grad_ar(5, 1),
                grad_ar(5, 2),
            ],
            ..Default::default()
        };
        bucket_gradients(&mut prog, 1 << 30);
        assert_eq!(prog.comm_volume(), 17);
        assert!(matches!(
            prog.instrs[0],
            Instr::Coll { kind: CollKind::AllGather, .. }
        ));
    }
}
