//! Global parallelization plans and device meshes.

use std::collections::HashMap;

use crate::graph::{Graph, OpId};
use crate::pblock::{BlockSet, Sharding};

/// Sharding state of a tensor during lowering. `Partial` means every device
/// holds a same-shaped partial sum (post K-split dot / sharded reduce).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ShardState {
    Split(usize),
    Replicated,
    Partial,
}

impl From<Sharding> for ShardState {
    fn from(s: Sharding) -> ShardState {
        match s {
            Sharding::Split(d) => ShardState::Split(d),
            Sharding::Replicated => ShardState::Replicated,
        }
    }
}

/// Device mesh. `intra` devices participate in intra-operator parallelism
/// (the ParallelBlock strategies); `nodes` replicas run data parallelism
/// across node boundaries (paper §5.6 case 1 / 2D mesh with the batch dim
/// pinned to the outer level).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Mesh {
    pub intra: usize,
    pub nodes: usize,
}

impl Mesh {
    pub fn flat(intra: usize) -> Mesh {
        Mesh { intra, nodes: 1 }
    }

    pub fn total(&self) -> usize {
        self.intra * self.nodes
    }
}

/// A full intra-operator parallelization plan: one strategy per
/// ParallelBlock (+ the sharding seeds those strategies pin down).
#[derive(Clone, Debug)]
pub struct GlobalPlan {
    /// strategy index per block id
    pub choice: Vec<usize>,
    pub mesh: Mesh,
}

impl GlobalPlan {
    pub fn uniform(bs: &BlockSet, label: &str, mesh: Mesh) -> Option<GlobalPlan> {
        let mut choice = Vec::with_capacity(bs.blocks.len());
        for b in &bs.blocks {
            let idx = b.strategies.iter().position(|s| s.label == label)?;
            choice.push(idx);
        }
        Some(GlobalPlan { choice, mesh })
    }

    /// Data parallelism: every block picks its M/batch-split strategy
    /// (PyTorch-DDP's implicit plan, §5).
    pub fn data_parallel(bs: &BlockSet, mesh: Mesh) -> GlobalPlan {
        let choice = bs
            .blocks
            .iter()
            .map(|b| {
                b.strategies
                    .iter()
                    .position(|s| s.label == "m")
                    .unwrap_or(0)
            })
            .collect();
        GlobalPlan { choice, mesh }
    }

    /// Seed sharding map: union of every chosen strategy's assignment.
    /// Later assignments never conflict with earlier ones inside a block;
    /// cross-block conflicts on shared tensors (Fig. 5c) resolve to the
    /// first writer — the lowering inserts reshards for the others.
    pub fn seed_shardings(&self, g: &Graph, bs: &BlockSet) -> HashMap<OpId, ShardState> {
        let _ = g;
        let mut seeds: HashMap<OpId, ShardState> = HashMap::new();
        for (b, blk) in bs.blocks.iter().enumerate() {
            let st = &blk.strategies[self.choice[b]];
            for (&op, &sh) in &st.assignment {
                seeds.entry(op).or_insert_with(|| sh.into());
            }
        }
        seeds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_training, ModelCfg};
    use crate::pblock::build_parallel_blocks;

    #[test]
    fn uniform_plans_exist_for_gpt() {
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(1);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        for label in ["m", "n", "k"] {
            assert!(
                GlobalPlan::uniform(&bs, label, Mesh::flat(4)).is_some(),
                "no uniform {label} plan"
            );
        }
    }

    #[test]
    fn seed_shardings_cover_block_members() {
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(1);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let plan = GlobalPlan::data_parallel(&bs, Mesh::flat(4));
        let seeds = plan.seed_shardings(&g, &bs);
        for blk in &bs.blocks {
            for &m in &blk.ops {
                assert!(seeds.contains_key(&m), "member {m} unseeded");
            }
        }
    }

    #[test]
    fn mesh_totals() {
        assert_eq!(Mesh { intra: 8, nodes: 2 }.total(), 16);
        assert_eq!(Mesh::flat(4).total(), 4);
    }
}
