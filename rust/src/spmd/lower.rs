//! The SPMD partitioner: sharding propagation + collective insertion.
//!
//! Seeds come from the chosen ParallelBlock strategies; every other op
//! (orphan norm chains, the whole backward pass, optimizer updates) gets
//! its sharding inferred here by forward propagation, with communication
//! materialized exactly where propagation is blocked or shardings
//! disagree. The DP gradient AllReduce, Megatron's TP AllReduces, MoE
//! resharding and the RNG replication sync all *emerge* from these rules —
//! nothing is special-cased per parallelism template.

use std::collections::HashMap;

use crate::affine::{propagate, Prop};
use crate::graph::{ElemOp, Graph, OpId, OpKind, ParamClass, ReduceKind, Role};
use crate::pblock::BlockSet;

use super::plan::{GlobalPlan, ShardState};
use super::program::{CollKind, Instr, SpmdProgram};

/// Lower `g` under `plan` into a per-device program.
pub fn lower(g: &Graph, bs: &BlockSet, plan: &GlobalPlan) -> SpmdProgram {
    lower_filtered(g, bs, plan, None)
}

/// Lower only the ops for which `filter(op) == true` (segment-local
/// profiling, §4.2). External input tensors are assumed to arrive in the
/// sharding the plan's seeds require (boundary resharding is profiled
/// separately as T_R), defaulting to replicated.
pub fn lower_filtered(
    g: &Graph,
    bs: &BlockSet,
    plan: &GlobalPlan,
    filter: Option<&[bool]>,
) -> SpmdProgram {
    let seeds = plan.seed_shardings(g, bs);
    lower_with_seeds(g, &seeds, plan.mesh, filter).0
}

/// Core lowering from an explicit seed-sharding map. Returns the program
/// and the final sharding state of every tensor (for boundary/T_R work).
pub fn lower_with_seeds(
    g: &Graph,
    seeds: &HashMap<OpId, ShardState>,
    mesh: super::plan::Mesh,
    filter: Option<&[bool]>,
) -> (SpmdProgram, Vec<Option<ShardState>>) {
    let parts = mesh.intra;
    let mut st: Vec<Option<ShardState>> = vec![None; g.ops.len()];
    let mut prog = SpmdProgram::default();
    // rng ops whose sharding is still undecided (the XLA one-device rule)
    let mut pending_rng: Vec<Vec<OpId>> = vec![Vec::new(); g.ops.len()];
    // route ops whose collective is deferred until a consumer fixes the
    // required sharding (local re-grouping vs All-to-All vs All-Gather)
    let mut pending_route: Vec<bool> = vec![false; g.ops.len()];

    if let Some(f) = filter {
        // pre-populate external tensor states
        for op in &g.ops {
            if !f[op.id] {
                st[op.id] =
                    Some(seeds.get(&op.id).copied().unwrap_or(ShardState::Replicated));
            }
        }
    }

    for op in &g.ops {
        let id = op.id;
        if let Some(f) = filter {
            if !f[id] {
                continue;
            }
        }
        match &op.kind {
            OpKind::Param { class } => {
                let s = seeds.get(&id).copied().unwrap_or(ShardState::Replicated);
                st[id] = Some(s);
                let local = local_bytes(op.bytes(), s, parts);
                if *class == ParamClass::Weight {
                    prog.param_bytes += local as u64;
                }
                continue;
            }
            OpKind::Constant { .. } => {
                st[id] = Some(ShardState::Replicated);
                continue;
            }
            OpKind::Rng => {
                // defer: adopts the consumer's sharding; replicated ⇒ sync
                pending_rng[id].push(id);
                st[id] = None;
                prog.instrs.push(Instr::Compute {
                    op: id,
                    flops: op.flops(g),
                    bytes: op.bytes() as u64,
                });
                continue;
            }
            _ => {}
        }

        // ---- gather input states; chain rng-deferred inputs
        let mut rng_roots: Vec<OpId> = Vec::new();
        let mut inputs: Vec<(usize, Option<ShardState>)> = Vec::new();
        for (idx, &i) in op.inputs.iter().enumerate() {
            if st[i].is_none() {
                rng_roots.extend(pending_rng[i].iter().copied());
            }
            inputs.push((idx, st[i]));
        }

        // fully-deferred op (pure rng chain): defer onward
        let any_known = inputs.iter().any(|(_, s)| s.is_some());
        if !any_known && !op.inputs.is_empty() {
            pending_rng[id] = rng_roots;
            st[id] = None;
            prog.instrs.push(Instr::Compute {
                op: id,
                flops: op.flops(g),
                bytes: op.bytes() as u64,
            });
            continue;
        }

        // ---- decide output sharding
        let decided = decide(g, seeds, &mut st, &mut prog, op, parts, &mut pending_route);
        st[id] = Some(decided);

        // resolve deferred rng chains: replicated adoption ⇒ AllReduce sync
        // (paper §2.2: compiler restricts RNG to one device)
        if !rng_roots.is_empty() {
            for root in rng_roots {
                if decided == ShardState::Replicated && parts > 1 {
                    prog.instrs.push(Instr::Coll {
                        kind: CollKind::AllReduce,
                        bytes: g.ops[root].bytes() as u64,
                        grad_sync: false,
                        tensor: root,
                    });
                }
                // back-fill chain state so it is not re-resolved
                st[root] = Some(decided);
            }
        }

        // ---- local compute cost
        let local_out = local_bytes(op.bytes(), decided, parts);
        let flops = op.flops(g);
        let local_flops = match decided {
            ShardState::Split(_) | ShardState::Partial => flops / parts as u64,
            ShardState::Replicated => flops,
        };
        prog.instrs.push(Instr::Compute {
            op: id,
            flops: local_flops,
            bytes: local_out as u64,
        });

        if op.role == Role::Fwd && !op.inputs.is_empty() {
            prog.act_bytes += local_out as u64;
        }

        // ---- gradient sync (DP emerges here)
        if let Some(p) = op.param_grad_for {
            let pstate = st[p].expect("param state");
            let gstate = st[id].unwrap();
            prog.grad_bytes += local_bytes(op.bytes(), pstate, parts) as u64;
            match (gstate, pstate) {
                (ShardState::Partial, ShardState::Replicated) => {
                    prog.instrs.push(Instr::Coll {
                        kind: CollKind::AllReduce,
                        bytes: op.bytes() as u64,
                        grad_sync: true,
                        tensor: id,
                    });
                    st[id] = Some(ShardState::Replicated);
                }
                (ShardState::Partial, ShardState::Split(d)) => {
                    // grads reduce-scattered straight into the shard
                    prog.instrs.push(Instr::Coll {
                        kind: CollKind::ReduceScatter,
                        bytes: op.bytes() as u64,
                        grad_sync: true,
                        tensor: id,
                    });
                    st[id] = Some(ShardState::Split(d));
                }
                (ShardState::Replicated, ShardState::Split(_))
                | (ShardState::Split(_), ShardState::Replicated)
                | (ShardState::Split(_), ShardState::Split(_)) => {
                    if gstate != pstate {
                        reshard(&mut prog, g, id, gstate, pstate, parts);
                        st[id] = Some(pstate);
                    }
                }
                _ => {}
            }
            // 2D mesh: inter-node data parallelism syncs every gradient
            if mesh.nodes > 1 {
                let bytes = local_bytes(op.bytes(), st[id].unwrap(), parts) as u64;
                prog.instrs.push(Instr::CollInter {
                    kind: CollKind::AllReduce,
                    bytes,
                    grad_sync: true,
                    tensor: id,
                });
            }
        }
    }
    (prog, st)
}

/// Decide `op`'s output sharding, inserting reshard collectives on inputs
/// as needed. May rewrite input states (post-reshard).
fn decide(
    g: &Graph,
    seeds: &HashMap<OpId, ShardState>,
    st: &mut [Option<ShardState>],
    prog: &mut SpmdProgram,
    op: &crate::graph::Op,
    parts: usize,
    pending_route: &mut [bool],
) -> ShardState {
    let id = op.id;

    // ---------- seeded (ParallelBlock member): enforce the strategy
    if let Some(&target) = seeds.get(&id) {
        // entry-op K-split: inputs seeded Split(K); partial output AllReduce
        // is represented by Partial→consumer materialization, EXCEPT the
        // block entry itself materializes immediately (strategy contract:
        // members see a replicated tensor).
        let required: Vec<ShardState> = op
            .inputs
            .iter()
            .map(|i| seeds.get(i).copied().or(st[*i]).unwrap_or(ShardState::Replicated))
            .collect();
        for (idx, &req) in required.iter().enumerate() {
            let i = op.inputs[idx];
            if pending_route[i] {
                resolve_route(prog, g, st, i, req, parts);
                pending_route[i] = false;
                st[i] = Some(req);
                continue;
            }
            let cur = st[i].unwrap_or(req);
            if cur != req {
                reshard(prog, g, i, cur, req, parts);
                st[i] = Some(req);
            }
        }
        // K-split dot: partial result → AllReduce now (entry contract)
        if let OpKind::Dot(d) = &op.kind {
            let b = d.batch;
            let lhs_k_split =
                matches!(st[op.inputs[0]], Some(ShardState::Split(dd)) if dd == b + 1);
            if lhs_k_split && target == ShardState::Replicated {
                // compute partial locally, then AllReduce the full output
                prog.instrs.push(Instr::Coll {
                    kind: CollKind::AllReduce,
                    bytes: op.bytes() as u64,
                    grad_sync: false,
                    tensor: id,
                });
            }
        }
        return target;
    }

    // ---------- inferred op
    // Partial inputs: linear ops carry partiality; others materialize.
    let has_partial = op
        .inputs
        .iter()
        .any(|&i| st[i] == Some(ShardState::Partial));
    if has_partial {
        if is_linear(op) {
            return ShardState::Partial;
        }
        for &i in op.inputs.iter() {
            if st[i] == Some(ShardState::Partial) {
                prog.instrs.push(Instr::Coll {
                    kind: CollKind::AllReduce,
                    bytes: g.ops[i].bytes() as u64,
                    grad_sync: false,
                    tensor: i,
                });
                st[i] = Some(ShardState::Replicated);
            }
        }
    }

    // pending-route inputs: resolve to the natural local sharding (token /
    // capacity side) — a consumer that needed the expert dim would be a
    // seeded entry handled above.
    for &i in &op.inputs {
        if pending_route[i] {
            let req = st[i].unwrap_or(ShardState::Replicated);
            resolve_route(prog, g, st, i, req, parts);
            pending_route[i] = false;
        }
    }

    let sharded: Vec<(usize, usize)> = op
        .inputs
        .iter()
        .enumerate()
        .filter_map(|(idx, &i)| match st[i] {
            Some(ShardState::Split(d)) => Some((idx, d)),
            _ => None,
        })
        .collect();

    if sharded.is_empty() {
        return ShardState::Replicated;
    }

    let (idx0, dim0) = sharded[0];
    match propagate(g, id, idx0, dim0, parts) {
        Prop::To { out_dim, co_shards } => {
            // siblings must agree or be replicated (sliced locally)
            for &(idxk, dimk) in &sharded[1..] {
                match propagate(g, id, idxk, dimk, parts) {
                    Prop::To { out_dim: od, .. } if od == out_dim => {}
                    _ => {
                        // reshard the disagreeing sibling to replicated
                        let i = op.inputs[idxk];
                        reshard(prog, g, i, ShardState::Split(dimk), ShardState::Replicated, parts);
                        st[i] = Some(ShardState::Replicated);
                    }
                }
            }
            let _ = co_shards; // replicated siblings satisfy any co-shard
            ShardState::Split(out_dim)
        }
        Prop::Blocked => {
            // token routing: defer — the collective (local regroup /
            // All-to-All / All-Gather) depends on what the consumer needs
            // (GShard dispatch/combine — the §5.7 MoE case-study kernel)
            if matches!(op.kind, OpKind::Route) {
                pending_route[id] = true;
                return ShardState::Split(if op.shape.len() == 3 { 1 } else { 0 });
            }
            // sum-reduce over the sharded dim (incl. dot K) ⇒ Partial
            let partial_ok = match &op.kind {
                OpKind::Reduce { dims, kind } => {
                    *kind == ReduceKind::Sum && dims.contains(&dim0)
                }
                OpKind::Dot(d) => {
                    // K sharded on the traversed side; other side must match
                    let b = d.batch;
                    let kdim = if idx0 == 0 { b + 1 } else { b };
                    dim0 == kdim
                }
                OpKind::Scatter { .. } => true, // partial tables, reduce later
                _ => false,
            };
            if partial_ok {
                if let OpKind::Dot(d) = &op.kind {
                    // other operand must be K-sharded too; reshard if not
                    let other = 1 - idx0;
                    let need = ShardState::Split(if other == 0 { d.batch + 1 } else { d.batch });
                    let i = op.inputs[other];
                    let cur = st[i].unwrap_or(ShardState::Replicated);
                    if cur != need {
                        // replicated → slice locally (free); split-elsewhere
                        // → AllToAll
                        if let ShardState::Split(_) = cur {
                            reshard(prog, g, i, cur, need, parts);
                        }
                        st[i] = Some(need);
                    }
                }
                ShardState::Partial
            } else {
                // gather the offending input and run replicated
                let i = op.inputs[idx0];
                reshard(prog, g, i, ShardState::Split(dim0), ShardState::Replicated, parts);
                st[i] = Some(ShardState::Replicated);
                // other sharded siblings propagate if they can
                for &(idxk, dimk) in &sharded[1..] {
                    if let Prop::To { out_dim, .. } = propagate(g, id, idxk, dimk, parts) {
                        return ShardState::Split(out_dim);
                    }
                    let ik = op.inputs[idxk];
                    reshard(prog, g, ik, ShardState::Split(dimk), ShardState::Replicated, parts);
                    st[ik] = Some(ShardState::Replicated);
                }
                ShardState::Replicated
            }
        }
    }

}

/// Resolve a deferred Route collective: the route op's INPUT sharding and
/// the consumer's requirement on the route OUTPUT determine the transfer:
///  * token/capacity ↔ token/capacity: local regrouping (free) — each
///    device re-buckets its own tokens (experts replicated or co-located);
///  * expert dim on either side: All-to-All (physical token exchange);
///  * requirement Replicated from a sharded side: All-Gather.
fn resolve_route(
    prog: &mut SpmdProgram,
    g: &Graph,
    st: &[Option<ShardState>],
    route: OpId,
    req: ShardState,
    parts: usize,
) {
    let op = &g.ops[route];
    let input = op.inputs[0];
    let in_shape_rank = g.shape(input).len();
    let out_rank = op.shape.len();
    let in_st = st[input].unwrap_or(ShardState::Replicated);
    let bytes = op.bytes() as u64;
    let expert_in = |st: ShardState, rank: usize| -> bool {
        matches!(st, ShardState::Split(0)) && rank == 3
    };
    let in_sharded = !matches!(in_st, ShardState::Replicated);
    match req {
        ShardState::Replicated => {
            if in_sharded && parts > 1 {
                prog.instrs.push(Instr::Coll {
                    kind: CollKind::AllGather,
                    bytes,
                    grad_sync: false,
                    tensor: route,
                });
            }
        }
        ShardState::Split(rd) => {
            if !in_sharded {
                return; // replicated input: slice locally
            }
            let expert_crossing =
                expert_in(in_st, in_shape_rank) || (rd == 0 && out_rank == 3);
            if expert_crossing && parts > 1 {
                prog.instrs.push(Instr::Coll {
                    kind: CollKind::AllToAll,
                    bytes,
                    grad_sync: false,
                    tensor: route,
                });
            }
            // token/capacity ↔ token/capacity: local regroup, free
        }
        ShardState::Partial => {}
    }
}

/// Emit the collective that moves `tensor` from `from` to `to`.
fn reshard(
    prog: &mut SpmdProgram,
    g: &Graph,
    tensor: OpId,
    from: ShardState,
    to: ShardState,
    parts: usize,
) {
    let bytes = g.ops[tensor].bytes() as u64;
    let _ = parts;
    let kind = match (from, to) {
        (ShardState::Split(_), ShardState::Replicated) => Some(CollKind::AllGather),
        (ShardState::Split(a), ShardState::Split(b)) if a != b => Some(CollKind::AllToAll),
        (ShardState::Replicated, ShardState::Split(_)) => None, // local slice
        (ShardState::Partial, ShardState::Replicated) => Some(CollKind::AllReduce),
        (ShardState::Partial, ShardState::Split(_)) => Some(CollKind::ReduceScatter),
        _ => None,
    };
    if let Some(kind) = kind {
        prog.instrs.push(Instr::Coll { kind, bytes, grad_sync: false, tensor });
    }
}

fn local_bytes(bytes: usize, s: ShardState, parts: usize) -> usize {
    match s {
        ShardState::Split(_) => bytes / parts,
        ShardState::Replicated | ShardState::Partial => bytes,
    }
}

/// Ops through which partial sums pass without materialization.
fn is_linear(op: &crate::graph::Op) -> bool {
    matches!(
        op.kind,
        OpKind::Reshape
            | OpKind::Transpose { .. }
            | OpKind::Slice { .. }
            | OpKind::Pad { .. }
            | OpKind::Broadcast { .. }
    ) || matches!(
        op.kind,
        OpKind::Elem(ElemOp::Add)
            | OpKind::Elem(ElemOp::Sub)
            | OpKind::Elem(ElemOp::Neg)
            | OpKind::Elem(ElemOp::Scale(_))
    ) || matches!(op.kind, OpKind::Reduce { kind: ReduceKind::Sum, .. })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_training, ModelCfg};
    use crate::pblock::build_parallel_blocks;
    use crate::spmd::plan::Mesh;

    fn lowered(label: &str, dropout: bool) -> (Graph, SpmdProgram) {
        let mut cfg = ModelCfg::preset("gpt-tiny").with_layers(2);
        if !dropout {
            cfg = cfg.without_dropout();
        }
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let plan = GlobalPlan::uniform(&bs, label, Mesh::flat(4)).unwrap();
        let prog = lower(&g, &bs, &plan);
        (g, prog)
    }

    #[test]
    fn dp_emits_gradient_allreduces_only() {
        let (g, prog) = lowered("m", false);
        let n_params = g.params().len();
        let grad_syncs = prog
            .instrs
            .iter()
            .filter(|i| matches!(i, Instr::Coll { grad_sync: true, .. }))
            .count();
        // every weight param's gradient is AllReduced under DP
        assert_eq!(grad_syncs, n_params, "grad syncs {grad_syncs} vs params {n_params}");
        // and (almost) nothing else communicates in steady state
        let others = prog
            .instrs
            .iter()
            .filter(|i| matches!(i, Instr::Coll { grad_sync: false, .. }))
            .count();
        assert!(others <= 4, "unexpected activation comm under DP: {others}");
    }

    #[test]
    fn dp_with_dropout_stays_communication_lean() {
        // batch-sharded dropout needs no RNG sync (§5.7: CFP's full-DP
        // LLAMA plan avoids the RNG AllReduce)
        let (_, prog) = lowered("m", true);
        let rng_syncs = prog
            .instrs
            .iter()
            .filter(|i| {
                matches!(i, Instr::Coll { grad_sync: false, kind: CollKind::AllReduce, .. })
            })
            .count();
        assert_eq!(rng_syncs, 0, "DP should not sync RNG");
    }

    #[test]
    fn splitk_emits_activation_allreduces() {
        let (_, prog) = lowered("k", false);
        let act_ar = prog
            .instrs
            .iter()
            .filter(|i| {
                matches!(
                    i,
                    Instr::Coll { grad_sync: false, kind: CollKind::AllReduce, .. }
                )
            })
            .count();
        // one AllReduce per block entry per direction at least
        assert!(act_ar >= 8, "row-TP must AllReduce activations: {act_ar}");
    }

    #[test]
    fn tp_with_dropout_pays_rng_sync() {
        // §2.2 / Fig 2: replicated dropout masks under TP ⇒ RNG AllReduce
        let (g, prog_tp) = lowered("k", true);
        let (_, prog_tp_nodrop) = lowered("k", false);
        let rng_bytes: u64 = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Rng))
            .map(|o| o.bytes() as u64)
            .sum();
        assert!(rng_bytes > 0);
        assert!(
            prog_tp.comm_volume() > prog_tp_nodrop.comm_volume(),
            "dropout must add comm under TP: {} vs {}",
            prog_tp.comm_volume(),
            prog_tp_nodrop.comm_volume()
        );
    }

    #[test]
    fn dp_memory_shards_activations_not_params() {
        let (_, dp) = lowered("m", false);
        let (_, tp) = lowered("n", false);
        assert!(dp.param_bytes > tp.param_bytes, "TP shards params");
        assert!(dp.act_bytes < tp.act_bytes * 4, "DP shards activations");
    }

    #[test]
    fn flops_are_conserved_across_plans() {
        // total work per device × parts ≈ serial work (± replicated orphans)
        let (g, dp) = lowered("m", false);
        let serial = g.total_flops();
        let dpf = dp.total_flops();
        assert!(dpf * 4 >= serial, "dp per-device {dpf} × 4 ≥ {serial}");
        assert!(dpf < serial, "dp per-device strictly less than serial");
    }
}
