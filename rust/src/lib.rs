//! # CFP — Communication-Free-Preserving intra-operator parallelism search
//!
//! A reproduction of *"CFP: Low-overhead Profiling-based Intra-operator
//! Parallelism Generation by Preserving Communication-Free Structures"*
//! as a three-layer Rust + JAX + Pallas stack (see DESIGN.md).
//!
//! Pipeline (paper Fig. 3):
//!
//! ```text
//!  models::build(..)            fine-grained computation graph (fwd+bwd+update)
//!    └─ affine::DimMap          Table-1 affine dependency expressions
//!        └─ pblock::build       Algorithm-1 ParallelBlock grouping
//!            └─ segment::extract  fingerprint-matched unique segments
//!                └─ profiler::profile_segments
//!                     ├─ spmd::lower        SPMD program + downstream passes
//!                     ├─ cluster::simulate  communication kernels on a platform
//!                     └─ runtime (PJRT)     measured compute kernel costs
//!                └─ cost::search   Eq-8/9 composition + memory-capped plan DP
//!                     ├─ memory     1F1B activation accounting + checkpointing
//!                     │             frontier (peak memory as a searched axis)
//!                     └─ interop::plan_pipeline  inter-op stage DP over
//!                        per-(stage-span, sub-mesh) intra-op plans (1F1B)
//! ```
//!
//! See `ARCHITECTURE.md` for the module ↔ paper-section map and the
//! end-to-end dataflow diagram.
//!
//! The crate is fully offline: the only external dependencies are the
//! vendored `xla` (PJRT bindings) and `anyhow`. Tokio/clap/serde/criterion
//! equivalents live in [`util`] (threadpool, CLI, JSON, bench & property-test
//! harnesses) — see DESIGN.md §Substitutions.

pub mod affine;
pub mod baselines;
pub mod cluster;
pub mod coordinator;
pub mod cost;
pub mod graph;
pub mod harness;
pub mod interop;
pub mod memory;
pub mod models;
pub mod obs;
pub mod pblock;
pub mod profiler;
pub mod runtime;
pub mod segment;
pub mod service;
pub mod spdag;
pub mod spmd;
pub mod trainer;
pub mod util;
