//! Shared experiment harness: model/platform matrices, table printing,
//! throughput math — used by every figure driver in examples/ and the
//! criterion-style benches.

use crate::cluster::Platform;
use crate::coordinator::{
    compare_frameworks, run_cfp_two_level, CfpOptions, Comparison, TwoLevelResult,
};
use crate::interop::StageSpec;
use crate::models::ModelCfg;
use crate::profiler::{ProfileDb, ReshardTable, SegmentConfig, SegmentProfile};
use crate::segment::{SegmentInstance, SegmentSet, UniqueSegment};
use crate::spdag::{BranchGroup, SpTopology};
use crate::spmd::{Mesh, ShardState};
use crate::util::Pcg64;

/// The paper's evaluation matrix (§5.1), at analysis-faithful structure
/// with reduced tensor sizes so the full sweep stays fast. `layers` is per
/// the profiled prefix — segment profiles are depth-independent, so deep
/// models are evaluated by instancing the layer segment.
pub fn eval_models() -> Vec<ModelCfg> {
    vec![
        ModelCfg::preset("bert-large").with_layers(4).with_batch(8).scaled_for_eval(),
        ModelCfg::preset("gpt-2.6b").with_layers(4).with_batch(8).scaled_for_eval(),
        ModelCfg::preset("moe-7.1b").with_layers(4).with_batch(8).scaled_for_eval(),
        ModelCfg::preset("llama-7b").with_layers(4).with_batch(8).scaled_for_eval(),
    ]
}

/// Platforms matched to the `scaled_for_eval` model sizes (scaled
/// testbeds — see Platform::scaled_testbed).
pub fn eval_platforms() -> Vec<(Platform, Mesh)> {
    vec![
        (Platform::a100_pcie(4).scaled_testbed(), Mesh::flat(4)),
        (Platform::a100_pcie(8).scaled_testbed(), Mesh::flat(8)),
        (Platform::a100_two_node().scaled_testbed(), Mesh { intra: 8, nodes: 2 }),
        (Platform::v100_nvlink().scaled_testbed(), Mesh::flat(4)),
    ]
}

/// One Fig. 7 cell: throughputs of the four frameworks.
pub struct ThroughputRow {
    pub model: String,
    pub platform: &'static str,
    pub gpus: usize,
    /// per-step time (µs) for PT / DS-M / Alpa / CFP
    pub pt_us: f64,
    pub dsm_us: f64,
    pub alpa_us: f64,
    pub cfp_us: f64,
    pub cfp_over_alpa: f64,
}

pub fn throughput_row(
    model: &ModelCfg,
    platform: Platform,
    mesh: Mesh,
) -> (ThroughputRow, Comparison) {
    let mut opts = CfpOptions::new(model.clone(), platform);
    opts.mesh = mesh;
    let c = compare_frameworks(&opts);
    let row = ThroughputRow {
        model: model.name.clone(),
        platform: platform.name,
        gpus: mesh.intra * mesh.nodes,
        pt_us: c.ddp.time_us,
        dsm_us: c.megatron.time_us,
        alpa_us: c.alpa.time_us,
        cfp_us: c.cfp.time_us,
        cfp_over_alpa: c.alpa.time_us / c.cfp.time_us,
    };
    (row, c)
}

/// The GPT/LLAMA/MoE presets the two-level planner is evaluated on
/// (scaled sizes, like [`eval_models`]).
pub fn pipeline_eval_models() -> Vec<ModelCfg> {
    vec![
        ModelCfg::preset("gpt-2.6b").with_layers(4).with_batch(8).scaled_for_eval(),
        ModelCfg::preset("llama-7b").with_layers(4).with_batch(8).scaled_for_eval(),
        ModelCfg::preset("moe-7.1b").with_layers(4).with_batch(8).scaled_for_eval(),
        // expert-parallel MoE: the SP-DAG workload (topology `sp-dag{E}`)
        ModelCfg::preset("moe-ep-7.1b").with_layers(4).with_batch(8).scaled_for_eval(),
    ]
}

/// One two-level eval row: single-stage CFP vs the two-level planner vs
/// the naive equal-split pipeline, on one model + platform.
pub struct PipelineRow {
    pub model: String,
    pub platform: &'static str,
    pub gpus: usize,
    pub microbatches: usize,
    /// segment-graph shape: `chain` for linear models, `sp-dag{E}` for
    /// expert-parallel MoE (the [`SpTopology::signature`] wire form)
    pub topology: String,
    /// single-stage CFP step time (µs)
    pub single_us: f64,
    /// two-level planner's composed step time (µs)
    pub two_level_us: f64,
    /// naive equal-split + DDP-inside pipeline baseline (µs);
    /// `f64::INFINITY` when no equal split lands on valid DAG cuts
    pub naive_us: f64,
    /// stage count the two-level planner chose
    pub stages: usize,
    /// pipeline-bubble share of the chosen plan's step
    pub bubble: f64,
    /// closed-form 1F1B peak memory per device of the chosen plan (max
    /// over stages: weights + optimizer + in-flight activations)
    pub peak_mem_bytes: u64,
    /// unique segments served from the profile cache across every stage
    /// context (warm-path effectiveness — 0 on cold cacheless runs)
    pub profile_hits: usize,
    /// unique segments actually profiled across the same passes
    pub profile_misses: usize,
    /// wall-clock µs inside plan search (ComposeSearch + inter-op
    /// planning) — the column BENCH trajectories track for search-side
    /// speedups, mirrored by `cfp serve`'s `search_us` counter
    pub search_us: f64,
}

/// Run the two-level planner (auto stage count) for one eval cell.
pub fn pipeline_row(
    model: &ModelCfg,
    platform: Platform,
    mesh: Mesh,
    microbatches: usize,
) -> (PipelineRow, TwoLevelResult) {
    let mut opts = CfpOptions::new(model.clone(), platform)
        .with_stages(StageSpec::Auto)
        .with_microbatches(microbatches);
    opts.mesh = mesh;
    let r = run_cfp_two_level(&opts);
    let pipeline = r.pipeline.as_ref().expect("uncapped two-level planning always plans");
    // a chain always has an equal split; a DAG's equal split can miss
    // every valid cut, in which case the baseline is simply infeasible
    let naive_us = r.naive.as_ref().map_or(f64::INFINITY, |n| n.step_time_us);
    let row = PipelineRow {
        model: model.name.clone(),
        platform: platform.name,
        gpus: mesh.total(),
        microbatches,
        topology: r.single.topo.signature(),
        single_us: r.single.plan.time_us,
        two_level_us: pipeline.step_time_us,
        naive_us,
        stages: pipeline.num_stages(),
        bubble: pipeline.bubble_fraction,
        peak_mem_bytes: pipeline.peak_mem_bytes,
        profile_hits: r.profile_hits,
        profile_misses: r.profile_misses,
        search_us: r.search_us,
    };
    (row, r)
}

/// Plan/profile cache effectiveness columns, printed by the eval drivers
/// and `cfp bench-serve` so BENCH trajectories can track warm-path wins
/// across PRs. Plan-level counters (hit/miss/coalesced) come from
/// [`crate::service::ServiceStats`]; profile-level ones also exist on
/// one-shot runs ([`PipelineRow::profile_hits`]).
#[derive(Clone, Debug, Default)]
pub struct CacheEffect {
    pub plan_hits: u64,
    pub plan_misses: u64,
    pub coalesced: u64,
    pub profile_hits: u64,
    pub profile_misses: u64,
    /// cumulative µs inside plan search across every executed search
    pub search_us: u64,
    /// admission-ledger totals: plan requests that reached admission,
    /// the subset admitted to planning, and the subset turned away with
    /// a structured rejection (overload or draining)
    pub received: u64,
    pub admitted: u64,
    pub rejected: u64,
}

impl CacheEffect {
    pub fn headers() -> &'static [&'static str] {
        &[
            "plan hit", "plan miss", "coalesced", "prof hit", "prof miss", "search µs",
            "received", "admitted", "rejected",
        ]
    }

    pub fn cells(&self) -> Vec<String> {
        vec![
            self.plan_hits.to_string(),
            self.plan_misses.to_string(),
            self.coalesced.to_string(),
            self.profile_hits.to_string(),
            self.profile_misses.to_string(),
            self.search_us.to_string(),
            self.received.to_string(),
            self.admitted.to_string(),
            self.rejected.to_string(),
        ]
    }

    pub fn from_service(s: &crate::service::ServiceStats) -> CacheEffect {
        CacheEffect {
            plan_hits: s.plan_hits,
            plan_misses: s.plan_misses,
            coalesced: s.coalesced,
            profile_hits: s.profile_hits,
            profile_misses: s.profile_misses,
            search_us: s.search_us,
            received: s.received,
            admitted: s.admitted,
            rejected: s.rejected,
        }
    }
}

/// A deterministic synthetic `(SegmentSet, ProfileDb)` chain: `n`
/// instances over `uniques` distinct segments, each with `cfgs` configs
/// and a dense reshard table for every unique pair. Entirely a function
/// of `seed` (one `Pcg64` stream), so benches and the exact-vs-DP
/// differential lanes can regenerate the identical instance across
/// processes and PRs without sharing fixture files.
pub fn synthetic_chain(n: usize, uniques: usize, cfgs: usize, seed: u64) -> (SegmentSet, ProfileDb) {
    assert!(n >= 1 && uniques >= 1 && cfgs >= 1);
    let mut rng = Pcg64::new(seed);
    let mut db = ProfileDb::default();
    for _ in 0..uniques {
        let mem_bytes: Vec<u64> = (0..cfgs).map(|_| 500 + rng.below(4000)).collect();
        let act_bytes: Vec<u64> = mem_bytes.iter().map(|&m| rng.below(m + 1)).collect();
        let ckpt_bytes: Vec<u64> = act_bytes.iter().map(|&a| rng.below(a + 1)).collect();
        db.segments.push(SegmentProfile {
            configs: (0..cfgs).map(|c| SegmentConfig { strategy: vec![c] }).collect(),
            t_c_us: (0..cfgs).map(|_| rng.f64() * 200.0).collect(),
            t_p_us: (0..cfgs).map(|_| rng.f64() * 400.0).collect(),
            mem_bytes,
            act_bytes,
            ckpt_bytes,
            t_fwd_us: (0..cfgs).map(|_| rng.f64() * 100.0).collect(),
            symbolic_volume: vec![0; cfgs],
            boundary_out: vec![ShardState::Replicated; cfgs],
            boundary_in: vec![ShardState::Replicated; cfgs],
        });
    }
    for a in 0..uniques {
        for b in 0..uniques {
            let t_r_us: Vec<Vec<f64>> =
                (0..cfgs).map(|_| (0..cfgs).map(|_| rng.f64() * 50.0).collect()).collect();
            db.reshard.insert(
                (a, b),
                ReshardTable { t_r_us, sym_vol: vec![vec![0; cfgs]; cfgs], programs: cfgs * cfgs },
            );
        }
    }
    // runs of one unique, like real layer chains (and the splice trigger)
    let mut uids: Vec<usize> = Vec::new();
    while uids.len() < n {
        let u = rng.below(uniques as u64) as usize;
        for _ in 0..1 + rng.below(4) {
            uids.push(u);
            if uids.len() >= n {
                break;
            }
        }
    }
    let instances: Vec<SegmentInstance> = uids
        .iter()
        .map(|&u| SegmentInstance { unique_id: u, blocks: vec![], fwd_range: (0, 0) })
        .collect();
    let unique: Vec<UniqueSegment> = (0..uniques)
        .map(|u| UniqueSegment {
            id: u,
            fingerprint: format!("u{u}"),
            rep: uids.iter().position(|&x| x == u).unwrap_or(0),
            count: uids.iter().filter(|&&x| x == u).count(),
        })
        .collect();
    (SegmentSet { instances, unique }, db)
}

/// A deterministic synthetic SP-DAG instance for the spdag bench and
/// property lanes: `trunk` leading trunk instances, then `groups`
/// fork/join groups of `branches` branches × `branch_len` instances,
/// each followed by one merge-successor trunk instance. Profiles and
/// unique assignments come from [`synthetic_chain`] over the same seed,
/// so the chain and DAG lanes price identical per-instance data and
/// differ only in topology.
pub fn synthetic_spdag(
    trunk: usize,
    groups: usize,
    branches: usize,
    branch_len: usize,
    uniques: usize,
    cfgs: usize,
    seed: u64,
) -> (SegmentSet, ProfileDb, SpTopology) {
    assert!(trunk >= 1 && groups >= 1 && branches >= 2 && branch_len >= 1);
    let n = trunk + groups * (branches * branch_len + 1);
    let (ss, db) = synthetic_chain(n, uniques, cfgs, seed);
    let mut topo_groups = Vec::with_capacity(groups);
    let mut pos = trunk;
    for _ in 0..groups {
        let ranges: Vec<(usize, usize)> = (0..branches)
            .map(|b| (pos + b * branch_len, pos + (b + 1) * branch_len))
            .collect();
        topo_groups.push(BranchGroup { branches: ranges });
        pos += branches * branch_len + 1; // branches, then the merge successor
    }
    let topo = SpTopology { n, groups: topo_groups };
    topo.validate().expect("synthetic SP topology is valid by construction");
    (ss, db, topo)
}

/// Markdown-ish aligned table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let s: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", s.join(" | "));
        };
        line(&self.headers);
        println!(
            "|{}|",
            widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
        );
        for row in &self.rows {
            line(row);
        }
    }
}

pub fn fmt_us(us: f64) -> String {
    if us < 1e3 {
        format!("{us:.1}µs")
    } else if us < 1e6 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

pub fn fmt_bytes(b: u64) -> String {
    if b < (1 << 20) {
        format!("{:.1}KB", b as f64 / 1e3)
    } else if b < (1 << 30) {
        format!("{:.1}MB", b as f64 / (1 << 20) as f64)
    } else {
        format!("{:.2}GB", b as f64 / (1 << 30) as f64)
    }
}

impl ModelCfg {
    /// Reduce tensor sizes for fast sweeps while keeping the structure
    /// (heads, layer alternation, expert count) analysis-faithful.
    pub fn scaled_for_eval(mut self) -> ModelCfg {
        self.hidden = (self.hidden / 8).max(64);
        self.ffn = (self.ffn / 8).max(128);
        self.vocab = (self.vocab / 16).max(512);
        self.seq = (self.seq / 8).max(32);
        self.heads = self.heads.min(8);
        self.experts = self.experts.min(8);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_matrix_is_well_formed() {
        for m in eval_models() {
            assert_eq!(m.hidden % m.heads, 0, "{}", m.name);
        }
        assert_eq!(eval_platforms().len(), 4);
    }

    #[test]
    fn pipeline_eval_presets_are_well_formed() {
        let models = pipeline_eval_models();
        assert_eq!(models.len(), 4, "GPT, LLAMA, MoE, expert-parallel MoE");
        for m in &models {
            assert_eq!(m.hidden % m.heads, 0, "{}", m.name);
            assert!(m.layers >= 2, "{}", m.name);
        }
        assert!(models.iter().any(|m| m.expert_branches), "the SP-DAG workload is present");
    }

    #[test]
    fn synthetic_spdag_layout_is_valid_and_deterministic() {
        let (ss, db, topo) = synthetic_spdag(2, 2, 3, 2, 3, 4, 0xDA6);
        assert_eq!(topo.n, 2 + 2 * (3 * 2 + 1));
        assert_eq!(ss.instances.len(), topo.n);
        assert_eq!(topo.groups.len(), 2);
        assert_eq!(topo.max_branches(), 3);
        assert_eq!(topo.signature(), "sp-dag3");
        assert_eq!(db.segments.len(), 3);
        // same seed ⇒ identical topology and identical profile bits
        let (_, db2, topo2) = synthetic_spdag(2, 2, 3, 2, 3, 4, 0xDA6);
        assert_eq!(topo, topo2);
        assert!(db.segments[0].t_c_us[0].to_bits() == db2.segments[0].t_c_us[0].to_bits());
        // the chain of the same shape prices identical per-instance data
        let (ss_chain, db_chain) = synthetic_chain(topo.n, 3, 4, 0xDA6);
        let uids: Vec<usize> = ss.instances.iter().map(|i| i.unique_id).collect();
        let uids_chain: Vec<usize> = ss_chain.instances.iter().map(|i| i.unique_id).collect();
        assert_eq!(uids, uids_chain);
        assert!(
            db.segments[0].t_p_us[0].to_bits() == db_chain.segments[0].t_p_us[0].to_bits(),
            "chain and DAG lanes share the profile stream"
        );
    }

    #[test]
    fn synthetic_chain_is_deterministic_and_well_formed() {
        let (ss, db) = synthetic_chain(10, 3, 4, 0xC0DE);
        assert_eq!(ss.instances.len(), 10);
        assert_eq!(ss.unique.len(), 3);
        assert!(ss.instances.iter().all(|i| i.unique_id < 3));
        assert_eq!(db.segments.len(), 3);
        assert!(db.segments.iter().all(|p| p.configs.len() == 4));
        assert_eq!(db.reshard.len(), 9, "dense reshard tables");
        // same seed ⇒ bit-identical instance, across calls and processes
        let (ss2, db2) = synthetic_chain(10, 3, 4, 0xC0DE);
        let uids: Vec<usize> = ss.instances.iter().map(|i| i.unique_id).collect();
        let uids2: Vec<usize> = ss2.instances.iter().map(|i| i.unique_id).collect();
        assert_eq!(uids, uids2);
        for (a, b) in db.segments.iter().zip(&db2.segments) {
            for (x, y) in a.t_c_us.iter().zip(&b.t_c_us) {
                assert!(x.to_bits() == y.to_bits());
            }
        }
        // different seed ⇒ a different instance
        let (ss3, _) = synthetic_chain(10, 3, 4, 0xC0DF);
        let uids3: Vec<usize> = ss3.instances.iter().map(|i| i.unique_id).collect();
        let (_, db3) = synthetic_chain(10, 3, 4, 0xC0DF);
        let same_uids = uids == uids3;
        let same_t0 = db.segments[0].t_c_us[0].to_bits() == db3.segments[0].t_c_us[0].to_bits();
        assert!(!(same_uids && same_t0), "seed must matter");
    }

    #[test]
    fn table_prints_without_panic() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.print();
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_us(500.0), "500.0µs");
        assert!(fmt_us(1.5e6).ends_with('s'));
        assert!(fmt_bytes(5 << 20).ends_with("MB"));
    }

    #[test]
    fn cache_effect_cells_align_with_headers() {
        let eff = CacheEffect { plan_hits: 3, coalesced: 2, ..CacheEffect::default() };
        assert_eq!(eff.cells().len(), CacheEffect::headers().len());
        let s = crate::service::ServiceStats {
            plan_hits: 7,
            profile_misses: 5,
            ..Default::default()
        };
        let from = CacheEffect::from_service(&s);
        assert_eq!(from.plan_hits, 7);
        assert_eq!(from.profile_misses, 5);
        // headers are usable as a Table header row
        let mut t = Table::new(CacheEffect::headers());
        t.row(eff.cells());
        t.print();
    }
}
