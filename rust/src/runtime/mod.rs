//! PJRT runtime: load AOT-compiled HLO-text artifacts (built once by
//! `make artifacts` — python never runs on this path) and execute them on
//! the CPU PJRT client via the `xla` crate.
//!
//! Two jobs:
//!  1. real kernel measurements (`measure`, `calibrate_compute`) feeding
//!     the simulator's compute-efficiency curve — the T_P side of the
//!     paper's profiling is *measured*, not modeled (§4.2);
//!  2. executing the full train-step executable for the e2e trainer.

pub mod manifest;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

use crate::cluster::sim::ComputeModel;
use crate::cluster::Platform;
use crate::util::{stats, Pcg64};

pub use manifest::{ArtifactMeta, TensorSpec};

pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Vec<ArtifactMeta>,
    cache: Mutex<HashMap<String, usize>>, // name → index into exes
    exes: Mutex<Vec<xla::PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Open an artifacts directory (requires `manifest.json` from aot.py).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e:?}"))?;
        Ok(Runtime {
            client,
            dir,
            manifest,
            cache: Mutex::new(HashMap::new()),
            exes: Mutex::new(Vec::new()),
        })
    }

    /// Default location: `$CFP_ARTIFACTS` or ./artifacts.
    pub fn open_default() -> Result<Runtime> {
        let dir = std::env::var("CFP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        Self::open(dir)
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.manifest.iter().find(|m| m.name == name)
    }

    /// Compile (and cache) an artifact.
    fn exe_index(&self, name: &str) -> Result<usize> {
        if let Some(&i) = self.cache.lock().unwrap().get(name) {
            return Ok(i);
        }
        let meta = self.meta(name).ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {name}: {e:?}"))?;
        let mut exes = self.exes.lock().unwrap();
        exes.push(exe);
        let idx = exes.len() - 1;
        self.cache.lock().unwrap().insert(name.to_string(), idx);
        Ok(idx)
    }

    /// Execute with given input literals; returns the flattened output
    /// tuple (aot.py lowers with return_tuple=True).
    pub fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let idx = self.exe_index(name)?;
        let exes = self.exes.lock().unwrap();
        let result = exes[idx]
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }

    /// Random inputs matching the artifact's manifest specs.
    pub fn random_inputs(&self, name: &str, rng: &mut Pcg64) -> Result<Vec<xla::Literal>> {
        let meta = self.meta(name).ok_or_else(|| anyhow!("unknown artifact {name}"))?;
        meta.inputs.iter().map(|spec| random_literal(spec, rng)).collect()
    }

    /// Median wall-clock seconds per execution (after warmup) — the paper's
    /// "5 warmup + N timed runs" protocol (§5.1).
    pub fn measure(&self, name: &str, warmup: usize, runs: usize) -> Result<f64> {
        let mut rng = Pcg64::new(0xCFB);
        let inputs = self.random_inputs(name, &mut rng)?;
        for _ in 0..warmup {
            self.run(name, &inputs)?;
        }
        let mut samples = Vec::with_capacity(runs);
        for _ in 0..runs {
            let t0 = Instant::now();
            self.run(name, &inputs)?;
            samples.push(t0.elapsed().as_secs_f64());
        }
        Ok(stats::median(&samples))
    }

    /// Fit the compute-efficiency curve from the calib_matmul_* artifacts:
    /// measure achieved flops/s per shape on the real PJRT backend, fit
    /// `1/eff ≈ a + b/flops` (the saturating-efficiency model), and map the
    /// fitted saturation point onto the target platform's peak.
    pub fn calibrate_compute(&self, platform: &Platform) -> Result<ComputeModel> {
        let mut points: Vec<(f64, f64)> = Vec::new(); // (flops, seconds)
        for meta in self.manifest.iter().filter(|m| m.kind == "calib_matmul") {
            let flops = meta
                .meta_f64("flops")
                .context("calib_matmul missing flops meta")?;
            let secs = self.measure(&meta.name, 2, 3)?;
            points.push((flops, secs));
        }
        if points.len() < 4 {
            return Ok(ComputeModel::for_platform(platform));
        }
        let rates: Vec<f64> = points.iter().map(|(f, s)| f / s).collect();
        let max_rate = rates.iter().cloned().fold(0.0, f64::max);
        // 1/eff_rel = a + b / flops  ⇒  sat = b/a
        let xs: Vec<f64> = points.iter().map(|(f, _)| 1.0 / f).collect();
        let ys: Vec<f64> = rates.iter().map(|r| max_rate / r.max(1.0)).collect();
        let (b, a) = stats::linfit(&xs, &ys);
        let sat = if a > 1e-9 { (b / a).clamp(1e6, 5e10) } else { 5e8 };
        // quantize to 2 significant figures: the fit rides on wall-clock
        // noise, and the profile cache keys on ComputeModel::signature() —
        // a bit-stable sat keeps repeat calibrated runs cache-hitting
        let mag = 10f64.powf(sat.log10().floor() - 1.0);
        let mut cm = ComputeModel::for_platform(platform);
        cm.sat_flops = (sat / mag).round() * mag;
        Ok(cm)
    }
}

/// Build a random literal for a tensor spec (normal f32, uniform i32).
pub fn random_literal(spec: &TensorSpec, rng: &mut Pcg64) -> Result<xla::Literal> {
    let n: usize = spec.shape.iter().product::<usize>().max(1);
    let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
    let lit = match spec.dtype.as_str() {
        "float32" => {
            let data: Vec<f32> = (0..n).map(|_| rng.normal() as f32 * 0.02).collect();
            xla::Literal::vec1(&data)
        }
        "int32" => {
            // token-ish: bounded by a safe small vocab unless spec says more
            let hi = 256u64;
            let data: Vec<i32> = (0..n).map(|_| rng.below(hi) as i32).collect();
            xla::Literal::vec1(&data)
        }
        other => return Err(anyhow!("unsupported dtype {other}")),
    };
    lit.reshape(&dims).map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Build a literal from explicit f32 data.
pub fn literal_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

pub fn literal_i32(data: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data)
        .reshape(&dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        // integration tests need `make artifacts` to have run
        Runtime::open("artifacts").ok()
    }

    #[test]
    fn quickstart_round_trip() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        let mut rng = Pcg64::new(7);
        let inputs = rt.random_inputs("quickstart", &mut rng).unwrap();
        let out = rt.run("quickstart", &inputs).unwrap();
        assert_eq!(out.len(), 1);
        let v = out[0].to_vec::<f32>().unwrap();
        assert!(v.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn calib_matmul_measures_positive() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let t = rt.measure("calib_matmul_256x256x256", 1, 2).unwrap();
        assert!(t > 0.0 && t < 1.0, "t = {t}");
    }

    #[test]
    fn calibration_produces_sane_model() {
        let Some(rt) = runtime() else {
            eprintln!("skipping: no artifacts");
            return;
        };
        let p = Platform::a100_pcie(4);
        let cm = rt.calibrate_compute(&p).unwrap();
        assert!(cm.sat_flops >= 1e6 && cm.sat_flops <= 5e10, "{}", cm.sat_flops);
        assert_eq!(cm.peak_tflops, p.peak_tflops);
    }
}
