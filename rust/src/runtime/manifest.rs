//! artifacts/manifest.json parsing (written by python/compile/aot.py).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::Json;

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

impl ArtifactMeta {
    pub fn meta_f64(&self, key: &str) -> Option<f64> {
        self.meta.get(key).and_then(|v| v.as_f64())
    }

    pub fn meta_usize(&self, key: &str) -> Option<usize> {
        self.meta_f64(key).map(|f| f as usize)
    }
}

pub fn load(path: &Path) -> Result<Vec<ArtifactMeta>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read {}", path.display()))?;
    let json = Json::parse(&text).map_err(|e| anyhow!("parse manifest: {e}"))?;
    let arr = json.as_arr().ok_or_else(|| anyhow!("manifest not an array"))?;
    arr.iter().map(parse_entry).collect()
}

fn parse_entry(e: &Json) -> Result<ArtifactMeta> {
    let get_str = |k: &str| -> Result<String> {
        Ok(e.get(k)
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("missing {k}"))?
            .to_string())
    };
    Ok(ArtifactMeta {
        name: get_str("name")?,
        file: get_str("file")?,
        kind: get_str("kind")?,
        inputs: parse_specs(e.get("inputs"))?,
        outputs: parse_specs(e.get("outputs"))?,
        meta: e.get("meta").cloned().unwrap_or(Json::Null),
    })
}

fn parse_specs(j: Option<&Json>) -> Result<Vec<TensorSpec>> {
    let Some(arr) = j.and_then(|v| v.as_arr()) else {
        return Ok(vec![]);
    };
    arr.iter()
        .map(|s| {
            let shape = s
                .get("shape")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("missing shape"))?
                .iter()
                .map(|d| d.as_i64().unwrap_or(0) as usize)
                .collect();
            Ok(TensorSpec {
                name: s.get("name").and_then(|v| v.as_str()).unwrap_or("").to_string(),
                shape,
                dtype: s
                    .get("dtype")
                    .and_then(|v| v.as_str())
                    .unwrap_or("float32")
                    .to_string(),
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_aot_schema() {
        let tmp = std::env::temp_dir().join("cfp_manifest_test.json");
        std::fs::write(
            &tmp,
            r#"[{"name":"m1","file":"m1.hlo.txt","kind":"calib_matmul",
                "inputs":[{"name":"a","shape":[4,4],"dtype":"float32"}],
                "outputs":[{"name":"out0","shape":[4,4],"dtype":"float32"}],
                "meta":{"flops":128}}]"#,
        )
        .unwrap();
        let m = load(&tmp).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].inputs[0].shape, vec![4, 4]);
        assert_eq!(m[0].meta_f64("flops"), Some(128.0));
        std::fs::remove_file(&tmp).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(load(Path::new("/nonexistent/manifest.json")).is_err());
    }
}
