//! ParallelBlock construction — the paper's core structure (§3).
//!
//! A ParallelBlock is a maximal subgraph rooted at a tensor-contraction
//! operator through which every *surviving* candidate partition of the
//! entry op propagates communication-free (Algorithm 1). The block's
//! strategy space is exactly those surviving candidates plus the
//! contraction-split (`SplitK`) strategy, matching §5.5's count of 3
//! strategies per dense-matmul block (M-split ≅ data parallel, N-split ≅
//! Megatron column parallel, K-split ≅ Megatron row parallel) and 4 for the
//! expert-batched BMM in MoE.
//!
//! The join rule is "all live candidates must propagate": an operator that
//! would block *any* candidate terminates the DFS on that path (and later
//! seeds or joins another block). This is what keeps a transformer layer at
//! 4 blocks — ln2's hidden-dim reduction stops the wo block's N candidate,
//! rather than being absorbed and silently shrinking the strategy space.
//!
//! # Invariants
//!
//! * Every forward op belongs to at most one block, and every strategy of
//!   a block assigns a propagation-consistent sharding to *every* member
//!   (re-checking any member against its inputs' assignments never yields
//!   a blocked propagation — pinned by the
//!   `strategies_are_communication_free_inside_blocks` test).
//! * Block construction depends on the partition count `parts`: a
//!   dimension indivisible by `parts` silently drops that strategy, so a
//!   [`BlockSet`] is only meaningful for the `parts` it was built with
//!   (the two-level planner builds one per sub-mesh size).
//! * Blocks are emitted in entry-op order, which is topological order —
//!   `segment::block_chain` relies on this to reconstruct the chain.

pub mod strategy;

use std::collections::BTreeMap;

use crate::affine::{propagate, CoShard, Prop};
use crate::graph::{Graph, OpId, OpKind, Role};

pub use strategy::{Sharding, Strategy, StrategyKind};

/// One ParallelBlock.
#[derive(Clone, Debug)]
pub struct ParallelBlock {
    pub id: usize,
    /// First tensor-contraction operator (the strategy carrier, §3.3).
    pub entry: OpId,
    /// Members (forward ops), ascending topo order; includes `entry`.
    pub ops: Vec<OpId>,
    /// Backward ops attached via their forward origin (§3.2).
    pub bwd_ops: Vec<OpId>,
    /// Surviving strategies; index = strategy id used everywhere downstream.
    pub strategies: Vec<Strategy>,
}

impl ParallelBlock {
    /// The block's frontier tensors: members whose users are outside.
    pub fn output_ops(&self, g: &Graph, block_of: &[Option<usize>]) -> Vec<OpId> {
        let users = g.users();
        self.ops
            .iter()
            .copied()
            .filter(|&t| {
                users[t].is_empty()
                    || users[t].iter().any(|&u| block_of[u] != Some(self.id))
            })
            .collect()
    }
}

/// Result of Algorithm 1 over a graph.
#[derive(Clone, Debug)]
pub struct BlockSet {
    pub blocks: Vec<ParallelBlock>,
    /// op id → owning block (fwd members + attached bwd ops).
    pub block_of: Vec<Option<usize>>,
    pub parts: usize,
}

impl BlockSet {
    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Product of per-block strategy counts (paper §3.3 `S = Π Dᵢ`).
    pub fn search_space_size(&self) -> f64 {
        self.blocks.iter().map(|b| b.strategies.len() as f64).product()
    }
}

/// Algorithm 1: BuildParallelBlocks.
pub fn build_parallel_blocks(g: &Graph, parts: usize) -> BlockSet {
    let users = g.users();
    let depths = g.depths();
    let mut block_of: Vec<Option<usize>> = vec![None; g.ops.len()];

    // SortTensorContractionOpSet: forward contraction ops by (depth, id)
    let mut seeds: Vec<OpId> = g
        .ops
        .iter()
        .filter(|o| o.kind.is_contraction() && o.role == Role::Fwd)
        .map(|o| o.id)
        .collect();
    seeds.sort_by_key(|&s| (depths[s], s));

    let mut blocks: Vec<ParallelBlock> = Vec::new();
    for s in seeds {
        if block_of[s].is_some() {
            continue; // IsGrouped
        }
        let id = blocks.len();
        let mut strategies = strategy::entry_strategies(g, s, parts);
        let mut ops = vec![s];
        block_of[s] = Some(id);

        // DFSAndGroup
        let mut stack = vec![s];
        while let Some(t) = stack.pop() {
            for &u in &users[t] {
                if block_of[u].is_some() || g.ops[u].role != Role::Fwd {
                    continue;
                }
                if try_join(g, &mut strategies, u, parts) {
                    block_of[u] = Some(id);
                    ops.push(u);
                    stack.push(u);
                }
            }
        }
        ops.sort();
        blocks.push(ParallelBlock { id, entry: s, ops, bwd_ops: vec![], strategies });
    }

    // Backward ops join their forward op's block (§3.2).
    for op in &g.ops {
        if op.role == Role::Bwd {
            if let Some(f) = op.grad_of {
                if let Some(b) = block_of[f] {
                    block_of[op.id] = Some(b);
                    blocks[b].bwd_ops.push(op.id);
                }
            }
        }
    }

    BlockSet { blocks, block_of, parts }
}

/// Try to absorb `u` into the block: every live strategy must extend
/// communication-free ("Check user, PB with Eq.(2)"). On success the
/// strategies' assignments are updated in place.
fn try_join(g: &Graph, strategies: &mut [Strategy], u: OpId, parts: usize) -> bool {
    if strategies.is_empty() {
        return false;
    }
    match g.ops[u].kind {
        OpKind::Param { .. } | OpKind::Constant { .. } => return false,
        _ => {}
    }
    let mut exts: Vec<BTreeMap<OpId, Sharding>> = Vec::with_capacity(strategies.len());
    for st in strategies.iter() {
        match try_extend(g, st, u, parts) {
            Some(e) => exts.push(e),
            None => return false,
        }
    }
    for (st, e) in strategies.iter_mut().zip(exts) {
        st.assignment.extend(e);
    }
    true
}

/// Extend one strategy's assignment through `u`. Returns the new
/// assignments (for `u` and any inferred input-branch requirements,
/// Fig. 5b/5c) or None if `u` blocks this strategy.
fn try_extend(
    g: &Graph,
    st: &Strategy,
    u: OpId,
    parts: usize,
) -> Option<BTreeMap<OpId, Sharding>> {
    let op = &g.ops[u];
    let mut new: BTreeMap<OpId, Sharding> = BTreeMap::new();

    let shardings: Vec<Option<Sharding>> = op
        .inputs
        .iter()
        .map(|i| st.assignment.get(i).copied())
        .collect();

    let sharded: Vec<(usize, usize)> = shardings
        .iter()
        .enumerate()
        .filter_map(|(idx, s)| match s {
            Some(Sharding::Split(d)) => Some((idx, *d)),
            _ => None,
        })
        .collect();

    if sharded.is_empty() {
        // All known inputs replicated ⇒ output replicated; free inputs can
        // always be replicated (no constraint).
        new.insert(u, Sharding::Replicated);
        for (idx, s) in shardings.iter().enumerate() {
            if s.is_none() {
                new.insert(op.inputs[idx], Sharding::Replicated);
            }
        }
        return Some(new);
    }

    // Propagate from the first sharded input; all other sharded inputs must
    // agree on the output dim, and co-shard requirements must be satisfied.
    let (idx0, dim0) = sharded[0];
    let (out_dim, co_shards) = match propagate(g, u, idx0, dim0, parts) {
        Prop::To { out_dim, co_shards } => (out_dim, co_shards),
        Prop::Blocked => return None,
    };
    for &(idxk, dimk) in &sharded[1..] {
        match propagate(g, u, idxk, dimk, parts) {
            Prop::To { out_dim: od, .. } if od == out_dim => {}
            _ => return None,
        }
    }
    for CoShard { input_index, dim } in co_shards {
        let have = shardings[input_index];
        match (have, dim) {
            // sibling unknown: record the inferred requirement (Fig. 5b)
            (None, Some(d)) => {
                new.insert(op.inputs[input_index], Sharding::Split(d));
            }
            (None, None) => {
                new.insert(op.inputs[input_index], Sharding::Replicated);
            }
            // sibling replicated satisfies any slice requirement locally
            (Some(Sharding::Replicated), _) => {}
            (Some(Sharding::Split(have_d)), Some(d)) if have_d == d => {}
            _ => return None,
        }
    }
    new.insert(u, Sharding::Split(out_dim));
    Some(new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_training, ModelCfg};

    fn gpt_blocks(layers: usize) -> (Graph, BlockSet) {
        let cfg = ModelCfg::preset("gpt-tiny").with_layers(layers);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        (g, bs)
    }

    #[test]
    fn transformer_layer_has_four_blocks() {
        // paper §5.5: 4 ParallelBlocks per transformer layer (qkv+BMMs
        // merge into one block; wo, w1, w2 each seed one).
        let (_, bs1) = gpt_blocks(1);
        let (_, bs2) = gpt_blocks(2);
        let per_layer = bs2.num_blocks() - bs1.num_blocks();
        assert_eq!(per_layer, 4, "blocks/layer: {per_layer}");
    }

    #[test]
    fn attention_bmm_merges_into_qkv_block() {
        let (g, bs) = gpt_blocks(1);
        let qkv = g.ops.iter().find(|o| o.name == "l0/attn/qkv_proj").unwrap().id;
        let qk = g.ops.iter().find(|o| o.name == "l0/attn/qk_bmm").unwrap().id;
        let pv = g.ops.iter().find(|o| o.name == "l0/attn/pv_bmm").unwrap().id;
        assert_eq!(bs.block_of[qk], bs.block_of[qkv], "qk_bmm in qkv block");
        assert_eq!(bs.block_of[pv], bs.block_of[qkv], "pv_bmm in qkv block");
    }

    #[test]
    fn dense_blocks_have_three_strategies() {
        // §5.5: "3 candidate partition dimensions" per matmul block.
        let (g, bs) = gpt_blocks(1);
        for b in &bs.blocks {
            let name = &g.ops[b.entry].name;
            if name.contains("mlp") || name.contains("attn/qkv") {
                assert_eq!(
                    b.strategies.len(),
                    3,
                    "block {} has {:?}",
                    name,
                    b.strategies.iter().map(|s| s.label.clone()).collect::<Vec<_>>()
                );
            }
        }
    }

    #[test]
    fn gpt_search_space_is_81_per_layer_segment() {
        let (_, bs1) = gpt_blocks(1);
        let (_, bs2) = gpt_blocks(2);
        let per_layer = bs2.search_space_size() / bs1.search_space_size();
        assert_eq!(per_layer, 81.0, "3^4 per layer");
    }

    #[test]
    fn moe_expert_block_has_four_strategies() {
        let cfg = ModelCfg::preset("moe-tiny").with_layers(2);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 2);
        let expert = g.ops.iter().find(|o| o.name == "l1/moe/expert_fc1").unwrap().id;
        let blk = &bs.blocks[bs.block_of[expert].unwrap()];
        assert_eq!(blk.entry, expert, "expert fc1 seeds its own block");
        // E (expert-parallel), T (dp), F (tp), K (row) — §5.5's extra dim
        assert_eq!(
            blk.strategies.len(),
            4,
            "{:?}",
            blk.strategies.iter().map(|s| s.label.clone()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn softmax_and_dropout_absorbed_into_attention_block() {
        let (g, bs) = gpt_blocks(1);
        let qkv = g.ops.iter().find(|o| o.name == "l0/attn/qkv_proj").unwrap().id;
        let qkv_block = bs.block_of[qkv].unwrap();
        for tag in ["softmax/exp", "softmax/div", "drop/select", "scale"] {
            let op = g
                .ops
                .iter()
                .find(|o| o.name == format!("l0/attn/{tag}"))
                .unwrap_or_else(|| panic!("no op l0/attn/{tag}"));
            assert_eq!(bs.block_of[op.id], Some(qkv_block), "{tag} not absorbed");
        }
    }

    #[test]
    fn backward_ops_join_forward_blocks() {
        // §3.2: every bwd op whose forward origin is grouped lands in the
        // SAME block (orphan fwd ops — norm chains, CE — keep orphan grads).
        let (g, bs) = gpt_blocks(1);
        let mut checked = 0;
        for o in &g.ops {
            if o.role == Role::Bwd {
                if let Some(f) = o.grad_of {
                    if let Some(b) = bs.block_of[f] {
                        assert_eq!(bs.block_of[o.id], Some(b), "bwd op {} strays", o.name);
                        checked += 1;
                    }
                }
            }
        }
        assert!(checked > 50, "checked only {checked} bwd ops");
    }

    /// Soundness invariant (DESIGN.md §6): within a block, every strategy
    /// assigns every member a sharding consistent with propagation — i.e.
    /// re-checking each member op against its inputs' assignments never
    /// yields a blocked propagation.
    #[test]
    fn strategies_are_communication_free_inside_blocks() {
        let (g, bs) = gpt_blocks(2);
        for blk in &bs.blocks {
            for st in &blk.strategies {
                for &m in &blk.ops {
                    if m == blk.entry {
                        continue;
                    }
                    let op = &g.ops[m];
                    for (idx, &inp) in op.inputs.iter().enumerate() {
                        if let Some(Sharding::Split(d)) = st.assignment.get(&inp) {
                            match propagate(&g, m, idx, *d, bs.parts) {
                                Prop::To { out_dim, .. } => {
                                    assert_eq!(
                                        st.assignment.get(&m),
                                        Some(&Sharding::Split(out_dim)),
                                        "block {} strat {} op {}",
                                        blk.id,
                                        st.label,
                                        op.name
                                    );
                                }
                                Prop::Blocked => panic!(
                                    "blocked propagation inside block {} strat {} at {}",
                                    blk.id, st.label, op.name
                                ),
                            }
                        }
                    }
                }
            }
        }
    }
}
