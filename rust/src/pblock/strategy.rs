//! Entry-operator strategy derivation (paper §3.3, Fig. 2a).
//!
//! A matrix multiplication can be split along three dimension classes
//! (Fig. 2a): the M/batch dims (≅ data parallelism when M = B·S), the N dim
//! (Megatron column parallelism) and the contracted K dim (Megatron row
//! parallelism — output needs an AllReduce and is then replicated). Batched
//! contractions add one strategy per batch dim (expert parallelism for the
//! MoE expert BMM, §5.5).

use std::collections::BTreeMap;

use crate::graph::{Graph, OpId, OpKind};

/// Per-tensor sharding under a fixed strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Sharding {
    /// Split along tensor dim `0` into the mesh's intra-op groups.
    Split(usize),
    Replicated,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub enum StrategyKind {
    /// Shard the entry output on dim `d` (communication-free within block).
    ShardOut(usize),
    /// Split the contracted dim: partial outputs ⇒ AllReduce at entry,
    /// members replicated afterwards.
    SplitK,
}

#[derive(Clone, Debug)]
pub struct Strategy {
    pub label: String,
    pub kind: StrategyKind,
    /// op id → sharding, covering block members and inferred input-branch
    /// requirements (params, residual inputs — Fig. 5b/5c).
    pub assignment: BTreeMap<OpId, Sharding>,
    pub entry_lhs: Sharding,
    pub entry_rhs: Sharding,
    /// Bytes AllReduced at the entry (SplitK only).
    pub entry_allreduce_bytes: usize,
}

impl Strategy {
    /// Sharding of the entry op's output under this strategy.
    pub fn entry_out(&self) -> Sharding {
        match self.kind {
            StrategyKind::ShardOut(d) => Sharding::Split(d),
            StrategyKind::SplitK => Sharding::Replicated,
        }
    }
}

/// All partition strategies of a contraction op (Fig. 2a generalized).
pub fn entry_strategies(g: &Graph, s: OpId, parts: usize) -> Vec<Strategy> {
    let op = &g.ops[s];
    let OpKind::Dot(dims) = &op.kind else {
        panic!("entry_strategies on non-contraction {}", op.name);
    };
    let b = dims.batch;
    let (lhs, rhs) = (op.inputs[0], op.inputs[1]);
    let lshape = g.shape(lhs);
    let rshape = g.shape(rhs);
    let oshape = &op.shape;
    let mut out = Vec::new();

    let mut push = |label: String,
                    kind: StrategyKind,
                    entry_lhs: Sharding,
                    entry_rhs: Sharding,
                    ar_bytes: usize| {
        let mut assignment = BTreeMap::new();
        let out_sh = match kind {
            StrategyKind::ShardOut(d) => Sharding::Split(d),
            StrategyKind::SplitK => Sharding::Replicated,
        };
        assignment.insert(s, out_sh);
        assignment.insert(lhs, entry_lhs);
        assignment.insert(rhs, entry_rhs);
        out.push(Strategy {
            label,
            kind,
            assignment,
            entry_lhs,
            entry_rhs,
            entry_allreduce_bytes: ar_bytes,
        });
    };

    // batch dims (expert parallelism for the MoE expert BMM)
    for d in 0..b {
        if oshape[d] % parts == 0 {
            push(
                format!("b{d}"),
                StrategyKind::ShardOut(d),
                Sharding::Split(d),
                Sharding::Split(d),
                0,
            );
        }
    }
    // M split (data parallelism when M = B·S)
    if oshape[b] % parts == 0 {
        push(
            "m".into(),
            StrategyKind::ShardOut(b),
            Sharding::Split(b),
            Sharding::Replicated,
            0,
        );
    }
    // N split (column tensor parallelism)
    if oshape[b + 1] % parts == 0 {
        push(
            "n".into(),
            StrategyKind::ShardOut(b + 1),
            Sharding::Replicated,
            Sharding::Split(b + 1),
            0,
        );
    }
    // K split (row tensor parallelism): AllReduce of the full output
    let k = lshape[b + 1];
    debug_assert_eq!(k, rshape[b]);
    if k % parts == 0 {
        let bytes = op.bytes();
        push(
            "k".into(),
            StrategyKind::SplitK,
            Sharding::Split(b + 1),
            Sharding::Split(b),
            bytes,
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::ParamClass;

    #[test]
    fn plain_matmul_has_three_strategies() {
        let mut g = Graph::new();
        let a = g.param("a", vec![64, 32], ParamClass::Input);
        let w = g.param("w", vec![32, 128], ParamClass::Weight);
        let c = g.matmul(a, w, "c");
        let sts = entry_strategies(&g, c, 4);
        let labels: Vec<_> = sts.iter().map(|s| s.label.as_str()).collect();
        assert_eq!(labels, vec!["m", "n", "k"]);
        assert_eq!(sts[0].entry_rhs, Sharding::Replicated);
        assert_eq!(sts[2].entry_allreduce_bytes, 64 * 128 * 4);
    }

    #[test]
    fn batched_bmm_adds_batch_strategies() {
        let mut g = Graph::new();
        let a = g.param("a", vec![8, 64, 32], ParamClass::Input);
        let w = g.param("w", vec![8, 32, 16], ParamClass::Weight);
        let c = g.dot(a, w, 1, "c");
        let sts = entry_strategies(&g, c, 4);
        let labels: Vec<_> = sts.iter().map(|s| s.label.as_str()).collect();
        assert_eq!(labels, vec!["b0", "m", "n", "k"]);
    }

    #[test]
    fn indivisible_dims_are_dropped() {
        let mut g = Graph::new();
        let a = g.param("a", vec![6, 32], ParamClass::Input); // 6 % 4 != 0
        let w = g.param("w", vec![32, 128], ParamClass::Weight);
        let c = g.matmul(a, w, "c");
        let sts = entry_strategies(&g, c, 4);
        let labels: Vec<_> = sts.iter().map(|s| s.label.as_str()).collect();
        assert_eq!(labels, vec!["n", "k"]);
    }

    #[test]
    fn splitk_output_is_replicated() {
        let mut g = Graph::new();
        let a = g.param("a", vec![16, 32], ParamClass::Input);
        let w = g.param("w", vec![32, 16], ParamClass::Weight);
        let c = g.matmul(a, w, "c");
        let k = entry_strategies(&g, c, 2).into_iter().find(|s| s.label == "k").unwrap();
        assert_eq!(k.entry_out(), Sharding::Replicated);
        assert_eq!(k.entry_lhs, Sharding::Split(1));
        assert_eq!(k.entry_rhs, Sharding::Split(0));
    }
}
