//! e2e training driver: runs the AOT-compiled train-step executable
//! (python/compile/aot.py → artifacts/train_step_*.hlo.txt) on the PJRT
//! CPU client from rust — the full three-layer stack with Python nowhere
//! on the step path. Used by examples/train_e2e.rs; the loss curve it
//! logs is recorded in EXPERIMENTS.md.

use anyhow::{anyhow, Result};

use crate::runtime::{literal_f32, literal_i32, Runtime, TensorSpec};
use crate::util::Pcg64;

pub struct Trainer<'rt> {
    rt: &'rt Runtime,
    artifact: String,
    specs: Vec<TensorSpec>,
    /// parameter leaf values (everything except tokens + lr inputs)
    params: Vec<Vec<f32>>,
    tokens_idx: usize,
    lr_idx: usize,
    vocab: usize,
    seq: usize,
    batch: usize,
    rng: Pcg64,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, artifact: &str, seed: u64) -> Result<Trainer<'rt>> {
        let meta = rt
            .meta(artifact)
            .ok_or_else(|| anyhow!("artifact {artifact} not in manifest"))?
            .clone();
        let specs = meta.inputs.clone();
        let tokens_idx = specs
            .iter()
            .position(|s| s.dtype == "int32")
            .ok_or_else(|| anyhow!("no tokens input"))?;
        let lr_idx = specs
            .iter()
            .position(|s| s.shape.is_empty() && s.dtype == "float32")
            .ok_or_else(|| anyhow!("no lr input"))?;
        let vocab = meta.meta_usize("vocab").unwrap_or(4096);
        let seq = meta.meta_usize("seq").unwrap_or(64);
        let batch = meta.meta_usize("batch").unwrap_or(8);

        let mut rng = Pcg64::new(seed);
        let params = specs
            .iter()
            .enumerate()
            .map(|(i, s)| {
                if i == tokens_idx || i == lr_idx {
                    return Vec::new();
                }
                init_leaf(s, &mut rng)
            })
            .collect();

        Ok(Trainer {
            rt,
            artifact: artifact.to_string(),
            specs,
            params,
            tokens_idx,
            lr_idx,
            vocab,
            seq,
            batch,
            rng,
        })
    }

    pub fn num_params(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }

    /// Synthetic corpus: an affine bigram process with 10% noise — enough
    /// structure that learning shows as a falling loss curve.
    pub fn sample_batch(&mut self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            let mut t = self.rng.below(self.vocab as u64) as i64;
            for _ in 0..self.seq {
                out.push(t as i32);
                t = if self.rng.f64() < 0.1 {
                    self.rng.below(self.vocab as u64) as i64
                } else {
                    (7 * t + 13) % self.vocab as i64
                };
            }
        }
        out
    }

    /// One SGD step; returns the loss.
    pub fn step(&mut self, lr: f32) -> Result<f32> {
        let tokens = self.sample_batch();
        let mut inputs = Vec::with_capacity(self.specs.len());
        for (i, spec) in self.specs.iter().enumerate() {
            if i == self.tokens_idx {
                inputs.push(literal_i32(&tokens, &spec.shape)?);
            } else if i == self.lr_idx {
                inputs.push(literal_f32(&[lr], &[])?);
            } else {
                inputs.push(literal_f32(&self.params[i], &spec.shape)?);
            }
        }
        let outputs = self.rt.run(&self.artifact, &inputs)?;
        // outputs: (loss, new_params...) in input-leaf order
        let loss = outputs[0]
            .to_vec::<f32>()
            .map_err(|e| anyhow!("loss fetch: {e:?}"))?[0];
        let mut oi = 1;
        for i in 0..self.specs.len() {
            if i == self.tokens_idx || i == self.lr_idx {
                continue;
            }
            self.params[i] = outputs[oi]
                .to_vec::<f32>()
                .map_err(|e| anyhow!("param fetch: {e:?}"))?;
            oi += 1;
        }
        Ok(loss)
    }

    /// Train for `steps`, returning the loss curve.
    pub fn train(&mut self, steps: usize, lr: f32, log_every: usize) -> Result<Vec<f32>> {
        let mut curve = Vec::with_capacity(steps);
        let t0 = std::time::Instant::now();
        for s in 0..steps {
            let loss = self.step(lr)?;
            curve.push(loss);
            if log_every > 0 && (s % log_every == 0 || s + 1 == steps) {
                println!(
                    "step {s:>5}  loss {loss:.4}  ({:.2} s elapsed)",
                    t0.elapsed().as_secs_f64()
                );
            }
        }
        Ok(curve)
    }
}

/// Parameter init mirroring python's init_params: norm weights → 1.0,
/// biases → 0.0, everything else N(0, 0.02).
fn init_leaf(spec: &TensorSpec, rng: &mut Pcg64) -> Vec<f32> {
    let n: usize = spec.shape.iter().product();
    let name = &spec.name;
    if name.contains("ln") && name.ends_with("_w']") || name.contains("lnf_w") {
        return vec![1.0; n];
    }
    if name.ends_with("_b']") || name.contains("lnf_b") {
        return vec![0.0; n];
    }
    (0..n).map(|_| rng.normal() as f32 * 0.02).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trainer_loss_decreases_if_artifacts_present() {
        let Ok(rt) = Runtime::open("artifacts") else {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        };
        if rt.meta("train_step_gpt").is_none() {
            eprintln!("skipping: no train_step_gpt artifact");
            return;
        }
        let mut tr = Trainer::new(&rt, "train_step_gpt", 42).unwrap();
        let first = tr.step(0.05).unwrap();
        let mut last = first;
        for _ in 0..8 {
            last = tr.step(0.05).unwrap();
        }
        assert!(last.is_finite() && first.is_finite());
        assert!(last < first + 0.5, "loss diverged: {first} → {last}");
    }

    #[test]
    fn synthetic_corpus_in_vocab_range() {
        let Ok(rt) = Runtime::open("artifacts") else {
            eprintln!("skipping: no artifacts");
            return;
        };
        if rt.meta("train_step_gpt").is_none() {
            return;
        }
        let mut tr = Trainer::new(&rt, "train_step_gpt", 1).unwrap();
        let batch = tr.sample_batch();
        assert_eq!(batch.len(), tr.batch * tr.seq);
        assert!(batch.iter().all(|&t| t >= 0 && (t as usize) < tr.vocab));
    }
}
