//! The three SP-DAG DP lanes: scalar (uncapped min-time), capped
//! Pareto, and the (config × remat) memory frontier.
//!
//! Each lane is the recursive-DP-over-the-SP-decomposition counterpart
//! of its chain lane in [`crate::cost`]: trunk steps replay the chain
//! step arithmetic verbatim on the same [`SearchCtx`] columns, branch
//! sub-DPs run on branch-local clocks seeded from `0.0`, and a branch
//! group is consumed in one "group step" that combines the per-branch
//! terminal states at the successor instance (time by max — concurrent
//! branches — memory components by the lane's own fold). States carry
//! their choice vectors inline instead of backpointers: DAG chains are
//! short (a group is one MoE layer) and the group step would otherwise
//! need three-way backpointers.
//!
//! Prune rules, tie orders, and frontier caps mirror `cost::dp`'s
//! exactly (`FRONTIER_CAP = 24`, `MEM_FRONTIER_CAP = 16`, the same
//! strictly-decreasing-mem keep rule, the same running-min memory keep
//! rule, the same evenly-spaced thinning): a dominated branch point
//! stays dominated under the group combine because every fold is
//! monotone per coordinate (max, integer sums) — so the chain lanes'
//! exactness arguments carry over unchanged. The memory lane doubles as
//! its own oracle via `exact = true` (true-dominance filter, no
//! thinning), mirroring [`crate::cost::exact::search_span_mem_exact`].

use crate::cost::{Plan, SearchCtx};
use crate::memory::{RecomputeSpec, SpanFootprint, SpanMemPlan};

use super::SpCtx;

/// Mirrors `cost::dp::FRONTIER_CAP` (private there by design — the SP
/// lanes must *behave* like the chain lanes, not reach into them).
const FRONTIER_CAP: usize = 24;
/// Mirrors `cost::dp::MEM_FRONTIER_CAP`.
const MEM_FRONTIER_CAP: usize = 16;

/// Branch-local clocks seed from this constant so the fork edge replays
/// the chain step shape `(prev + reshard) + seg_time` with `prev = 0.0`
/// — bit-exact for the non-negative costs profiles produce
/// (`0.0 + x == x`).
const SEED: f64 = 0.0;

// ---------------------------------------------------------------- scalar lane

/// One scalar state: min-(time, mem) prefix ending at a config, with
/// the full choice vector of the consumed span prefix.
#[derive(Clone, Debug)]
struct Cand {
    time: f64,
    mem: u64,
    choice: Vec<usize>,
}

fn scalar_first(ctx: &SearchCtx, pos: usize) -> Vec<Option<Cand>> {
    let o = ctx.off_at(pos);
    (0..ctx.ncfg_at(pos))
        .map(|c| {
            Some(Cand {
                time: ctx.time_col()[o + c],
                mem: ctx.mem_col()[o + c],
                choice: vec![c],
            })
        })
        .collect()
}

/// One trunk argmin step into `pos` — `(prev + tr) + seg_t`, lex
/// `(time, mem)` tie order, earliest predecessor on full ties.
fn scalar_step(ctx: &SearchCtx, pos: usize, prev: &[Option<Cand>]) -> Vec<Option<Cand>> {
    let o = ctx.off_at(pos);
    let cc = ctx.ncfg_at(pos);
    let mat = ctx.step_matrix(pos);
    scalar_step_mat(ctx, pos, o, cc, mat, prev)
}

/// The step body, parameterized on the transition matrix so branch
/// seeds can price the fork edge through the same code path.
fn scalar_step_mat(
    ctx: &SearchCtx,
    _pos: usize,
    o: usize,
    cc: usize,
    mat: &[f64],
    prev: &[Option<Cand>],
) -> Vec<Option<Cand>> {
    let mut out: Vec<Option<Cand>> = Vec::with_capacity(cc);
    for c in 0..cc {
        let seg_t = ctx.time_col()[o + c];
        let seg_m = ctx.mem_col()[o + c];
        let mut best: Option<(f64, u64, usize)> = None;
        for (p, cand) in prev.iter().enumerate() {
            let Some(pp) = cand else { continue };
            let t = pp.time + mat[p * cc + c] + seg_t;
            let m = pp.mem + seg_m;
            if best.map_or(true, |(bt, bm, _)| t < bt || (t == bt && m < bm)) {
                best = Some((t, m, p));
            }
        }
        out.push(best.map(|(t, m, p)| {
            let pp = prev[p].as_ref().unwrap();
            let mut choice = pp.choice.clone();
            choice.push(c);
            Cand { time: t, mem: m, choice }
        }));
    }
    out
}

/// Terminal state of branch `bi` of group `gi` under fork config `a`:
/// a branch-local chain DP seeded from the fork edge.
fn scalar_branch(ctx: &SearchCtx, sp: &SpCtx, gi: usize, bi: usize, a: usize) -> Vec<Option<Cand>> {
    let (blo, bhi) = sp.topo.groups[gi].branches[bi];
    let cc = ctx.ncfg_at(blo);
    let o = ctx.off_at(blo);
    let fmat = sp.fork_mat(gi, bi);
    let mut state: Vec<Option<Cand>> = (0..cc)
        .map(|c| {
            Some(Cand {
                time: SEED + fmat[a * cc + c] + ctx.time_col()[o + c],
                mem: ctx.mem_col()[o + c],
                choice: vec![c],
            })
        })
        .collect();
    for pos in blo + 1..bhi {
        state = scalar_step(ctx, pos, &state);
    }
    state
}

/// Consume a whole branch group: from the fork state, run every branch
/// under every fork config, take each branch's min completion per
/// successor config, max-fold the branch times (memory adds), and step
/// into the successor instance. Returns the successor state.
fn scalar_group(
    ctx: &SearchCtx,
    sp: &SpCtx,
    gi: usize,
    fork: &[Option<Cand>],
) -> Vec<Option<Cand>> {
    let g = &sp.topo.groups[gi];
    let succ = g.end();
    let so = ctx.off_at(succ);
    let scc = ctx.ncfg_at(succ);
    let nb = g.branches.len();
    let mut out: Vec<Option<Cand>> = vec![None; scc];
    for (a, fc) in fork.iter().enumerate() {
        let Some(fc) = fc else { continue };
        let terms: Vec<Vec<Option<Cand>>> =
            (0..nb).map(|bi| scalar_branch(ctx, sp, gi, bi, a)).collect();
        for cs in 0..scc {
            // per-branch independent min — exact for time (branches
            // share no choice variables, so min-of-max = max-of-min)
            let mut mx = f64::NEG_INFINITY;
            let mut mem_sum = 0u64;
            let mut picked: Vec<usize> = Vec::with_capacity(nb);
            let mut feasible = true;
            for bi in 0..nb {
                let mmat = sp.merge_mat(gi, bi);
                let mut best: Option<(f64, u64, usize)> = None;
                for (cb, cand) in terms[bi].iter().enumerate() {
                    let Some(bb) = cand else { continue };
                    let w = bb.time + mmat[cb * scc + cs];
                    if best.map_or(true, |(bt, bm, _)| w < bt || (w == bt && bb.mem < bm)) {
                        best = Some((w, bb.mem, cb));
                    }
                }
                let Some((w, bm, cb)) = best else {
                    feasible = false;
                    break;
                };
                if w > mx {
                    mx = w;
                }
                mem_sum += bm;
                picked.push(cb);
            }
            if !feasible {
                continue;
            }
            let t = fc.time + mx + ctx.time_col()[so + cs];
            let m = fc.mem + mem_sum + ctx.mem_col()[so + cs];
            let better =
                out[cs].as_ref().map_or(true, |o| t < o.time || (t == o.time && m < o.mem));
            if better {
                let mut choice = fc.choice.clone();
                for (bi, &cb) in picked.iter().enumerate() {
                    choice.extend_from_slice(&terms[bi][cb].as_ref().unwrap().choice);
                }
                choice.push(cs);
                out[cs] = Some(Cand { time: t, mem: m, choice });
            }
        }
    }
    out
}

/// Unconstrained min-time SP-DAG plan for `[lo, hi)`.
pub(super) fn scalar_plan(ctx: &SearchCtx, sp: &SpCtx, lo: usize, hi: usize) -> Option<Plan> {
    if hi == lo {
        return None;
    }
    let mut state = scalar_first(ctx, lo);
    let mut pos = lo + 1;
    while pos < hi {
        if let Some(gi) = sp.group_starting_at(pos) {
            state = scalar_group(ctx, sp, gi, &state);
            pos = sp.topo.groups[gi].end() + 1;
        } else {
            state = scalar_step(ctx, pos, &state);
            pos += 1;
        }
    }
    let mut best: Option<usize> = None;
    for (c, s) in state.iter().enumerate() {
        if let Some(sc) = s {
            if best.map_or(true, |b| sc.time < state[b].as_ref().unwrap().time) {
                best = Some(c);
            }
        }
    }
    best.map(|c| {
        let s = state[c].as_ref().unwrap();
        Plan { choice: s.choice.clone(), time_us: s.time, mem_bytes: s.mem }
    })
}

// ---------------------------------------------------------------- pareto lane

/// One capped-Pareto point with its choice vector inline.
#[derive(Clone, Debug)]
struct SpPoint {
    time: f64,
    mem: u64,
    choice: Vec<usize>,
}

/// Mirror of `cost::dp::pareto_prune`: (time, mem) sort, keep strictly
/// decreasing mem, thin to `FRONTIER_CAP` evenly spaced points.
fn pareto_prune_sp(pts: &mut Vec<SpPoint>) {
    pts.sort_by(|a, b| a.time.partial_cmp(&b.time).unwrap().then(a.mem.cmp(&b.mem)));
    let mut best_mem = u64::MAX;
    let mut w = 0usize;
    for r in 0..pts.len() {
        if pts[r].mem < best_mem {
            best_mem = pts[r].mem;
            pts.swap(w, r);
            w += 1;
        }
    }
    pts.truncate(w);
    if pts.len() > FRONTIER_CAP {
        let step = (pts.len() - 1) as f64 / (FRONTIER_CAP - 1) as f64;
        for k in 0..FRONTIER_CAP {
            let src = (k as f64 * step).round() as usize;
            pts.swap(k, src);
        }
        pts.truncate(FRONTIER_CAP);
    }
}

fn pareto_first(ctx: &SearchCtx, pos: usize, cap: u64) -> Vec<Vec<SpPoint>> {
    let o = ctx.off_at(pos);
    (0..ctx.ncfg_at(pos))
        .map(|c| {
            let mem = ctx.mem_col()[o + c];
            if mem <= cap {
                vec![SpPoint { time: ctx.time_col()[o + c], mem, choice: vec![c] }]
            } else {
                Vec::new()
            }
        })
        .collect()
}

fn pareto_step_mat(
    ctx: &SearchCtx,
    o: usize,
    cc: usize,
    mat: &[f64],
    cap: u64,
    prev: &[Vec<SpPoint>],
) -> Vec<Vec<SpPoint>> {
    let mut cur: Vec<Vec<SpPoint>> = Vec::with_capacity(cc);
    for c in 0..cc {
        let seg_t = ctx.time_col()[o + c];
        let seg_m = ctx.mem_col()[o + c];
        let mut pts: Vec<SpPoint> = Vec::new();
        for (pcfg, pset) in prev.iter().enumerate() {
            if pset.is_empty() {
                continue;
            }
            let tr = mat[pcfg * cc + c];
            for pp in pset {
                let time = pp.time + tr + seg_t;
                let mem = pp.mem + seg_m;
                if mem <= cap {
                    let mut choice = pp.choice.clone();
                    choice.push(c);
                    pts.push(SpPoint { time, mem, choice });
                }
            }
        }
        pareto_prune_sp(&mut pts);
        cur.push(pts);
    }
    cur
}

/// Branch-local capped frontier under fork config `a`. Filtering a
/// branch-local prefix against the *total* cap is sound: memory is
/// additive across the whole span, so a branch prefix alone exceeding
/// the cap can never complete feasibly.
fn pareto_branch(
    ctx: &SearchCtx,
    sp: &SpCtx,
    gi: usize,
    bi: usize,
    a: usize,
    cap: u64,
) -> Vec<Vec<SpPoint>> {
    let (blo, bhi) = sp.topo.groups[gi].branches[bi];
    let cc = ctx.ncfg_at(blo);
    let o = ctx.off_at(blo);
    let fmat = sp.fork_mat(gi, bi);
    let mut state: Vec<Vec<SpPoint>> = (0..cc)
        .map(|c| {
            let mem = ctx.mem_col()[o + c];
            if mem <= cap {
                vec![SpPoint {
                    time: SEED + fmat[a * cc + c] + ctx.time_col()[o + c],
                    mem,
                    choice: vec![c],
                }]
            } else {
                Vec::new()
            }
        })
        .collect();
    for pos in blo + 1..bhi {
        state = pareto_step_mat(
            ctx,
            ctx.off_at(pos),
            ctx.ncfg_at(pos),
            ctx.step_matrix(pos),
            cap,
            &state,
        );
    }
    state
}

fn pareto_group(
    ctx: &SearchCtx,
    sp: &SpCtx,
    gi: usize,
    cap: u64,
    fork: &[Vec<SpPoint>],
) -> Vec<Vec<SpPoint>> {
    let g = &sp.topo.groups[gi];
    let succ = g.end();
    let so = ctx.off_at(succ);
    let scc = ctx.ncfg_at(succ);
    let nb = g.branches.len();
    let mut pools: Vec<Vec<SpPoint>> = vec![Vec::new(); scc];
    for (a, fset) in fork.iter().enumerate() {
        if fset.is_empty() {
            continue;
        }
        let terms: Vec<Vec<Vec<SpPoint>>> =
            (0..nb).map(|bi| pareto_branch(ctx, sp, gi, bi, a, cap)).collect();
        for (cs, pool) in pools.iter_mut().enumerate() {
            // incremental cross-product fold over branches: time by max
            // (concurrent), memory by sum, pruned at every fold step
            let mut h: Option<Vec<SpPoint>> = None;
            for bi in 0..nb {
                let mmat = sp.merge_mat(gi, bi);
                let mut gset: Vec<SpPoint> = Vec::new();
                for (cb, pts) in terms[bi].iter().enumerate() {
                    let tr = mmat[cb * scc + cs];
                    for p in pts {
                        gset.push(SpPoint {
                            time: p.time + tr,
                            mem: p.mem,
                            choice: p.choice.clone(),
                        });
                    }
                }
                pareto_prune_sp(&mut gset);
                h = Some(match h {
                    None => gset,
                    Some(hs) => {
                        let mut combined: Vec<SpPoint> = Vec::new();
                        for hp in &hs {
                            for gp in &gset {
                                let mem = hp.mem + gp.mem;
                                if mem > cap {
                                    continue;
                                }
                                let time = if gp.time > hp.time { gp.time } else { hp.time };
                                let mut choice = hp.choice.clone();
                                choice.extend_from_slice(&gp.choice);
                                combined.push(SpPoint { time, mem, choice });
                            }
                        }
                        pareto_prune_sp(&mut combined);
                        combined
                    }
                });
                if h.as_ref().unwrap().is_empty() {
                    break;
                }
            }
            let Some(h) = h else { continue };
            if h.is_empty() {
                continue;
            }
            let seg_t = ctx.time_col()[so + cs];
            let seg_m = ctx.mem_col()[so + cs];
            for fp in fset {
                for hp in &h {
                    let time = fp.time + hp.time + seg_t;
                    let mem = fp.mem + hp.mem + seg_m;
                    if mem <= cap {
                        let mut choice = fp.choice.clone();
                        choice.extend_from_slice(&hp.choice);
                        choice.push(cs);
                        pool.push(SpPoint { time, mem, choice });
                    }
                }
            }
        }
    }
    for pool in pools.iter_mut() {
        pareto_prune_sp(pool);
    }
    pools
}

/// Memory-capped min-time SP-DAG plan for `[lo, hi)`.
pub(super) fn pareto_plan(
    ctx: &SearchCtx,
    sp: &SpCtx,
    cap: u64,
    lo: usize,
    hi: usize,
) -> Option<Plan> {
    if hi == lo {
        return None;
    }
    let mut state = pareto_first(ctx, lo, cap);
    let mut pos = lo + 1;
    while pos < hi {
        if let Some(gi) = sp.group_starting_at(pos) {
            state = pareto_group(ctx, sp, gi, cap, &state);
            pos = sp.topo.groups[gi].end() + 1;
        } else {
            state = pareto_step_mat(
                ctx,
                ctx.off_at(pos),
                ctx.ncfg_at(pos),
                ctx.step_matrix(pos),
                cap,
                &state,
            );
            pos += 1;
        }
    }
    let mut best: Option<(usize, usize)> = None;
    for (c, pts) in state.iter().enumerate() {
        for (i, p) in pts.iter().enumerate() {
            if best.map_or(true, |(bc, bi)| p.time < state[bc][bi].time) {
                best = Some((c, i));
            }
        }
    }
    best.map(|(c, i)| {
        let p = &state[c][i];
        Plan { choice: p.choice.clone(), time_us: p.time, mem_bytes: p.mem }
    })
}

// ---------------------------------------------------------------- memory lane

/// One memory-frontier point with choice and remat vectors inline.
#[derive(Clone, Debug)]
struct SpMemPoint {
    time: f64,
    recompute: f64,
    stat: u64,
    ret: u64,
    tra: u64,
    choice: Vec<usize>,
    remat: Vec<bool>,
}

fn mem_sort(pts: &mut [SpMemPoint]) {
    pts.sort_by(|a, b| {
        a.time
            .partial_cmp(&b.time)
            .unwrap()
            .then(a.stat.cmp(&b.stat))
            .then(a.ret.cmp(&b.ret))
            .then(a.tra.cmp(&b.tra))
    });
}

/// DP mode mirrors `cost::dp::prune_mem` (running-min keep +
/// `MEM_FRONTIER_CAP` thinning); exact mode mirrors
/// `cost::exact::pareto_filter` (true dominance, no thinning).
fn filter_mem(pts: &mut Vec<SpMemPoint>, exact: bool) {
    mem_sort(pts);
    if exact {
        let mut w = 0usize;
        for r in 0..pts.len() {
            let dominated = pts[..w].iter().any(|q| {
                q.stat <= pts[r].stat && q.ret <= pts[r].ret && q.tra <= pts[r].tra
            });
            if !dominated {
                pts.swap(w, r);
                w += 1;
            }
        }
        pts.truncate(w);
        return;
    }
    let (mut min_stat, mut min_ret, mut min_tra) = (u64::MAX, u64::MAX, u64::MAX);
    let mut w = 0usize;
    for r in 0..pts.len() {
        let p = &pts[r];
        if w == 0 || p.stat < min_stat || p.ret < min_ret || p.tra < min_tra {
            min_stat = min_stat.min(p.stat);
            min_ret = min_ret.min(p.ret);
            min_tra = min_tra.min(p.tra);
            pts.swap(w, r);
            w += 1;
        }
    }
    pts.truncate(w);
    if pts.len() > MEM_FRONTIER_CAP {
        let step = (pts.len() - 1) as f64 / (MEM_FRONTIER_CAP - 1) as f64;
        for k in 0..MEM_FRONTIER_CAP {
            let src = (k as f64 * step).round() as usize;
            pts.swap(k, src);
        }
        pts.truncate(MEM_FRONTIER_CAP);
    }
}

fn mem_first(ctx: &SearchCtx, pos: usize, spec: RecomputeSpec, exact: bool) -> Vec<Vec<SpMemPoint>> {
    let o = ctx.off_at(pos);
    (0..ctx.ncfg_at(pos))
        .map(|c| {
            let seg_t = ctx.time_col()[o + c];
            let stat = ctx.stat_col()[o + c];
            let mut pts: Vec<SpMemPoint> = ctx
                .remat_at(o + c, spec)
                .iter()
                .map(|r| SpMemPoint {
                    time: seg_t + r.extra_us,
                    recompute: r.extra_us,
                    stat,
                    ret: r.retained_bytes,
                    tra: r.transient_bytes,
                    choice: vec![c],
                    remat: vec![r.checkpoint],
                })
                .collect();
            filter_mem(&mut pts, exact);
            pts
        })
        .collect()
}

fn mem_step_mat(
    ctx: &SearchCtx,
    o: usize,
    cc: usize,
    mat: &[f64],
    spec: RecomputeSpec,
    exact: bool,
    prev: &[Vec<SpMemPoint>],
) -> Vec<Vec<SpMemPoint>> {
    let mut cur: Vec<Vec<SpMemPoint>> = Vec::with_capacity(cc);
    for c in 0..cc {
        let seg_t = ctx.time_col()[o + c];
        let stat = ctx.stat_col()[o + c];
        let rpts = ctx.remat_at(o + c, spec);
        let mut pts: Vec<SpMemPoint> = Vec::new();
        for (pcfg, pset) in prev.iter().enumerate() {
            if pset.is_empty() {
                continue;
            }
            let tr = mat[pcfg * cc + c];
            for pp in pset {
                for r in rpts {
                    let mut choice = pp.choice.clone();
                    choice.push(c);
                    let mut remat = pp.remat.clone();
                    remat.push(r.checkpoint);
                    pts.push(SpMemPoint {
                        time: pp.time + tr + seg_t + r.extra_us,
                        recompute: pp.recompute + r.extra_us,
                        stat: pp.stat + stat,
                        ret: pp.ret + r.retained_bytes,
                        tra: pp.tra.max(r.transient_bytes),
                        choice,
                        remat,
                    });
                }
            }
        }
        filter_mem(&mut pts, exact);
        cur.push(pts);
    }
    cur
}

fn mem_branch(
    ctx: &SearchCtx,
    sp: &SpCtx,
    gi: usize,
    bi: usize,
    a: usize,
    spec: RecomputeSpec,
    exact: bool,
) -> Vec<Vec<SpMemPoint>> {
    let (blo, bhi) = sp.topo.groups[gi].branches[bi];
    let cc = ctx.ncfg_at(blo);
    let o = ctx.off_at(blo);
    let fmat = sp.fork_mat(gi, bi);
    let mut state: Vec<Vec<SpMemPoint>> = (0..cc)
        .map(|c| {
            let seg_t = ctx.time_col()[o + c];
            let stat = ctx.stat_col()[o + c];
            let tr = fmat[a * cc + c];
            let mut pts: Vec<SpMemPoint> = ctx
                .remat_at(o + c, spec)
                .iter()
                .map(|r| SpMemPoint {
                    time: SEED + tr + seg_t + r.extra_us,
                    recompute: r.extra_us,
                    stat,
                    ret: r.retained_bytes,
                    tra: r.transient_bytes,
                    choice: vec![c],
                    remat: vec![r.checkpoint],
                })
                .collect();
            filter_mem(&mut pts, exact);
            pts
        })
        .collect();
    for pos in blo + 1..bhi {
        state = mem_step_mat(
            ctx,
            ctx.off_at(pos),
            ctx.ncfg_at(pos),
            ctx.step_matrix(pos),
            spec,
            exact,
            &state,
        );
    }
    state
}

fn mem_group(
    ctx: &SearchCtx,
    sp: &SpCtx,
    gi: usize,
    spec: RecomputeSpec,
    exact: bool,
    fork: &[Vec<SpMemPoint>],
) -> Vec<Vec<SpMemPoint>> {
    let g = &sp.topo.groups[gi];
    let succ = g.end();
    let so = ctx.off_at(succ);
    let scc = ctx.ncfg_at(succ);
    let nb = g.branches.len();
    let mut pools: Vec<Vec<SpMemPoint>> = vec![Vec::new(); scc];
    for (a, fset) in fork.iter().enumerate() {
        if fset.is_empty() {
            continue;
        }
        let terms: Vec<Vec<Vec<SpMemPoint>>> =
            (0..nb).map(|bi| mem_branch(ctx, sp, gi, bi, a, spec, exact)).collect();
        for (cs, pool) in pools.iter_mut().enumerate() {
            // branch combine: time by max (concurrent), recompute /
            // static / retained by sum, transient scratch by max (expert
            // backward passes are serialized per device, like the
            // chain's per-instance transient rule)
            let mut h: Option<Vec<SpMemPoint>> = None;
            for bi in 0..nb {
                let mmat = sp.merge_mat(gi, bi);
                let mut gset: Vec<SpMemPoint> = Vec::new();
                for (cb, pts) in terms[bi].iter().enumerate() {
                    let tr = mmat[cb * scc + cs];
                    for p in pts {
                        let mut q = p.clone();
                        q.time = p.time + tr;
                        gset.push(q);
                    }
                }
                filter_mem(&mut gset, exact);
                h = Some(match h {
                    None => gset,
                    Some(hs) => {
                        let mut combined: Vec<SpMemPoint> = Vec::new();
                        for hp in &hs {
                            for gp in &gset {
                                let time = if gp.time > hp.time { gp.time } else { hp.time };
                                let mut choice = hp.choice.clone();
                                choice.extend_from_slice(&gp.choice);
                                let mut remat = hp.remat.clone();
                                remat.extend_from_slice(&gp.remat);
                                combined.push(SpMemPoint {
                                    time,
                                    recompute: hp.recompute + gp.recompute,
                                    stat: hp.stat + gp.stat,
                                    ret: hp.ret + gp.ret,
                                    tra: hp.tra.max(gp.tra),
                                    choice,
                                    remat,
                                });
                            }
                        }
                        filter_mem(&mut combined, exact);
                        combined
                    }
                });
                if h.as_ref().unwrap().is_empty() {
                    break;
                }
            }
            let Some(h) = h else { continue };
            if h.is_empty() {
                continue;
            }
            let seg_t = ctx.time_col()[so + cs];
            let stat = ctx.stat_col()[so + cs];
            let rpts = ctx.remat_at(so + cs, spec);
            for fp in fset {
                for hp in &h {
                    for r in rpts {
                        let mut choice = fp.choice.clone();
                        choice.extend_from_slice(&hp.choice);
                        choice.push(cs);
                        let mut remat = fp.remat.clone();
                        remat.extend_from_slice(&hp.remat);
                        remat.push(r.checkpoint);
                        pool.push(SpMemPoint {
                            time: fp.time + hp.time + seg_t + r.extra_us,
                            recompute: fp.recompute + hp.recompute + r.extra_us,
                            stat: fp.stat + hp.stat + stat,
                            ret: fp.ret + hp.ret + r.retained_bytes,
                            tra: fp.tra.max(hp.tra).max(r.transient_bytes),
                            choice,
                            remat,
                        });
                    }
                }
            }
        }
    }
    for pool in pools.iter_mut() {
        filter_mem(pool, exact);
    }
    pools
}

/// The SP-DAG memory-frontier span search. `exact = false` is the DP
/// (production) mode; `exact = true` keeps true Pareto sets with no
/// thinning — the lane's own oracle.
pub(super) fn mem_frontier(
    ctx: &SearchCtx,
    sp: &SpCtx,
    lo: usize,
    hi: usize,
    spec: RecomputeSpec,
    exact: bool,
) -> Vec<SpanMemPlan> {
    if hi == lo {
        return Vec::new();
    }
    let mut state = mem_first(ctx, lo, spec, exact);
    let mut pos = lo + 1;
    while pos < hi {
        if let Some(gi) = sp.group_starting_at(pos) {
            state = mem_group(ctx, sp, gi, spec, exact, &state);
            pos = sp.topo.groups[gi].end() + 1;
        } else {
            state = mem_step_mat(
                ctx,
                ctx.off_at(pos),
                ctx.ncfg_at(pos),
                ctx.step_matrix(pos),
                spec,
                exact,
                &state,
            );
            pos += 1;
        }
    }
    // terminal canonicalization: the chain's exact (time, stat, ret,
    // tra) sort + footprint dominance rule
    let mut all: Vec<SpMemPoint> = state.into_iter().flatten().collect();
    mem_sort(&mut all);
    let mut kept: Vec<SpMemPoint> = Vec::new();
    for p in all {
        let dominated =
            kept.iter().any(|q| q.stat <= p.stat && q.ret <= p.ret && q.tra <= p.tra);
        if !dominated {
            kept.push(p);
        }
    }
    kept.into_iter()
        .map(|p| SpanMemPlan {
            choice: p.choice,
            remat: p.remat,
            time_us: p.time,
            footprint: SpanFootprint {
                static_bytes: p.stat,
                retained_bytes: p.ret,
                transient_bytes: p.tra,
                recompute_us: p.recompute,
            },
        })
        .collect()
}
