//! Series-parallel segment-DAG planner (PR 8).
//!
//! Every planner before this module assumed the segment *chain*: a
//! linear order of instances where each position reshards only into its
//! successor. MoE models with expert parallelism as a first-class axis
//! break that shape — a router segment forks into `E` expert branches
//! that execute concurrently and merge back into the combine segment.
//! This module represents that structure as a **series-parallel DAG over
//! today's segment instances** and solves it with a recursive DP over
//! the SP decomposition, one lane per existing chain lane:
//!
//! * **Scalar** ([`sp_search_span`] with no cap) — min-time plan. At a
//!   branch group the per-branch chain DPs run on *branch-local clocks*
//!   seeded from `0.0`; the merge takes, per successor config, the
//!   min-time completion of every branch independently and combines them
//!   with a max-fold (branches run concurrently; the slowest one gates
//!   the merge). Per-branch independence is exact here: the branches
//!   share no choice variables, so `min over assignments of max_b` equals
//!   `max_b of per-branch min` — the DP optimum is the true optimum.
//! * **Capped Pareto** ([`sp_search_span`] with a cap) — per-branch
//!   `(time, mem)` frontiers, combined at the merge by an incremental
//!   cross-product fold (time max, memory sum) with the chain lane's own
//!   prune rules, including its `FRONTIER_CAP` thinning.
//! * **Memory frontier** ([`sp_search_mem_span`]) — the 1F1B footprint
//!   lane: across branches time folds by max, static/retained/recompute
//!   add, and transient scratch folds by **max** (expert backward passes
//!   are serialized per device exactly like the chain's transient rule).
//!
//! All three lanes replay the chain DP's float association *per edge* —
//! `(prev + reshard) + seg_time`, branch seeds `(0.0 + reshard) +
//! seg_time`, merges `(fork + max_b(rel_b + merge_reshard)) + seg_time`
//! — so a chain-shaped span (no group intersects it) is not merely
//! equivalent: it is **delegated verbatim** to the `cost` searchers and
//! therefore bit-identical by construction ([`sp_search_span_engine`]).
//!
//! The exact lane ([`exact`]) runs the same branch-and-bound discipline
//! as [`crate::cost::exact`] over the SP decomposition: admissible
//! suffix bounds treat a branch group as `max_b(Σ min seg time)`,
//! deflated by the same `×(1 − 1e-9)` slack, with the exact-integer
//! memory prune unchanged (memory is additive across branches). The
//! `--engine dp|exact|auto` portfolio dispatch carries over unchanged.
//!
//! Junction reshards are priced from the same dense matrices the chain
//! uses: the fork edge into branch 0 and the edge out of the last branch
//! are chain-adjacent (covered by [`SearchCtx::step_matrix`]); the
//! remaining fork/merge edges dense-expand from
//! [`ProfileDb::reshard_us`] with the identical `0.0` default
//! ([`SpCtx::new`]).

use crate::cost::{self, Plan, SearchCtx, SearchEngine};
use crate::memory::{RecomputeSpec, SpanMemPlan};
use crate::profiler::ProfileDb;

mod dp;
pub mod exact;

pub use exact::{sp_search_span_exact, sp_search_span_exact_budget};

/// One fork/join group: `branches[k]` is the half-open, *consecutive*
/// instance-index range of branch `k` in the linearized chain order.
/// The fork instance is `first() − 1`; the join's reshard edges price
/// into the *successor* instance at `end()` (merge orphan ops belong to
/// it), so a group never owns a separate merge instance.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BranchGroup {
    pub branches: Vec<(usize, usize)>,
}

impl BranchGroup {
    /// First instance index of the first branch.
    pub fn first(&self) -> usize {
        self.branches[0].0
    }

    /// One past the last branch instance == the successor (merge-owning)
    /// instance index.
    pub fn end(&self) -> usize {
        self.branches.last().unwrap().1
    }

    /// The fork instance feeding every branch.
    pub fn fork(&self) -> usize {
        self.first() - 1
    }
}

/// Series-parallel topology over a segment chain of `n` instances:
/// a sorted list of disjoint branch groups, everything between them
/// plain trunk. `groups.is_empty()` is exactly today's chain.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpTopology {
    pub n: usize,
    pub groups: Vec<BranchGroup>,
}

impl SpTopology {
    /// The chain topology (no branch groups).
    pub fn chain(n: usize) -> SpTopology {
        SpTopology { n, groups: Vec::new() }
    }

    pub fn is_chain(&self) -> bool {
        self.groups.is_empty()
    }

    /// Largest branch count across groups (0 for a chain) — the `E` of
    /// the wire-format `sp-dag{E}` signature.
    pub fn max_branches(&self) -> usize {
        self.groups.iter().map(|g| g.branches.len()).max().unwrap_or(0)
    }

    /// Canonical wire/cache-key form: `chain` or `sp-dag{E}`.
    pub fn signature(&self) -> String {
        if self.is_chain() {
            "chain".into()
        } else {
            format!("sp-dag{}", self.max_branches())
        }
    }

    /// Structural invariants: every group has ≥ 2 contiguous branches, a
    /// fork (`first ≥ 1`) and a successor (`end ≤ n − 1`) instance, and
    /// groups are sorted with at least the successor instance between
    /// consecutive groups.
    pub fn validate(&self) -> Result<(), String> {
        let mut prev_end = 0usize;
        for (gi, g) in self.groups.iter().enumerate() {
            if g.branches.len() < 2 {
                return Err(format!("group {gi}: needs ≥ 2 branches"));
            }
            for (bi, &(blo, bhi)) in g.branches.iter().enumerate() {
                if blo >= bhi {
                    return Err(format!("group {gi} branch {bi}: empty range"));
                }
                if bi + 1 < g.branches.len() && bhi != g.branches[bi + 1].0 {
                    return Err(format!("group {gi}: branches not contiguous at {bi}"));
                }
            }
            if g.first() < 1 {
                return Err(format!("group {gi}: no fork instance before position 0"));
            }
            if g.end() > self.n.saturating_sub(1) {
                return Err(format!("group {gi}: no successor instance inside the chain"));
            }
            if gi > 0 && g.first() < prev_end + 1 {
                return Err(format!("group {gi}: overlaps or abuts the previous group's fork"));
            }
            prev_end = g.end();
        }
        Ok(())
    }

    /// Whether a stage cut *before* instance `p` is structurally valid:
    /// a cut may not separate a fork from its branches, branches from
    /// each other, or branches from their successor — i.e. `p` must not
    /// fall in any group's `[first, end]`.
    pub fn valid_cut(&self, p: usize) -> bool {
        !self.groups.iter().any(|g| g.first() <= p && p <= g.end())
    }

    /// Indices of the groups fully contained in span `[lo, hi)` (with
    /// valid cuts a group is always fully inside or fully outside).
    pub fn groups_in(&self, lo: usize, hi: usize) -> Vec<usize> {
        self.groups
            .iter()
            .enumerate()
            .filter(|(_, g)| g.fork() >= lo && g.end() < hi)
            .map(|(gi, _)| gi)
            .collect()
    }
}

/// The SP decomposition: a chain run is a [`SpTree::Leaf`], a branch
/// group is a [`SpTree::Parallel`] of per-branch leaves, and the whole
/// topology is the [`SpTree::Series`] of those in linear order. Branches
/// are chains in this PR (no nested parallelism), which is exactly the
/// shape [`recompose`] accepts — `decompose ∘ recompose` and
/// `recompose ∘ decompose` are identities (pinned by the property
/// suite).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum SpTree {
    /// Contiguous trunk run `[lo, hi)`.
    Leaf { lo: usize, hi: usize },
    Series(Vec<SpTree>),
    Parallel(Vec<SpTree>),
}

/// Canonical SP decomposition of a topology.
pub fn decompose(topo: &SpTopology) -> SpTree {
    let mut items = Vec::new();
    let mut cursor = 0usize;
    for g in &topo.groups {
        if cursor < g.first() {
            items.push(SpTree::Leaf { lo: cursor, hi: g.first() });
        }
        items.push(SpTree::Parallel(
            g.branches.iter().map(|&(lo, hi)| SpTree::Leaf { lo, hi }).collect(),
        ));
        cursor = g.end();
    }
    if cursor < topo.n {
        items.push(SpTree::Leaf { lo: cursor, hi: topo.n });
    }
    SpTree::Series(items)
}

/// Rebuild the topology from a canonical SP tree. Rejects shapes
/// [`decompose`] cannot produce (nested parallels, non-contiguous
/// leaves), so the round-trip is an identity exactly on valid trees.
pub fn recompose(tree: &SpTree) -> Result<SpTopology, String> {
    let SpTree::Series(items) = tree else {
        return Err("top level must be a Series".into());
    };
    let mut groups = Vec::new();
    let mut cursor = 0usize;
    for item in items {
        match item {
            SpTree::Leaf { lo, hi } => {
                if *lo != cursor || hi <= lo {
                    return Err(format!("trunk leaf [{lo}, {hi}) breaks contiguity at {cursor}"));
                }
                cursor = *hi;
            }
            SpTree::Parallel(branches) => {
                let mut ranges = Vec::with_capacity(branches.len());
                for b in branches {
                    let SpTree::Leaf { lo, hi } = b else {
                        return Err("nested parallelism is not supported".into());
                    };
                    if *lo != cursor || hi <= lo {
                        return Err(format!(
                            "branch leaf [{lo}, {hi}) breaks contiguity at {cursor}"
                        ));
                    }
                    ranges.push((*lo, *hi));
                    cursor = *hi;
                }
                groups.push(BranchGroup { branches: ranges });
            }
            SpTree::Series(_) => return Err("nested series is not supported".into()),
        }
    }
    let topo = SpTopology { n: cursor, groups };
    topo.validate()?;
    Ok(topo)
}

/// Junction reshard matrices for one topology over one [`SearchCtx`]:
/// per group, per branch, a dense fork matrix (`fork_cfg × branch_first
/// cfg`) and merge matrix (`branch_last cfg × successor cfg`), built
/// from the same [`ProfileDb::reshard_us`] lookups (0.0 default for
/// absent pairs) the chain's step matrices dense-expand from. Owns its
/// data, so the inter-op planner can cache it next to the `SearchCtx`.
pub struct SpCtx {
    pub topo: SpTopology,
    /// `fork_mats[gi][bi][a * ncfg_first + c]`
    fork_mats: Vec<Vec<Vec<f64>>>,
    /// `merge_mats[gi][bi][c_b * ncfg_succ + c_s]`
    merge_mats: Vec<Vec<Vec<f64>>>,
    /// `group_at[pos] = Some(gi)` iff `pos` is group `gi`'s first branch
    /// position
    group_at: Vec<Option<usize>>,
}

impl SpCtx {
    pub fn new(ctx: &SearchCtx, topo: &SpTopology, db: &ProfileDb) -> SpCtx {
        assert_eq!(topo.n, ctx.len(), "topology and context disagree on chain length");
        topo.validate().expect("invalid SP topology");
        let mut fork_mats = Vec::with_capacity(topo.groups.len());
        let mut merge_mats = Vec::with_capacity(topo.groups.len());
        let mut group_at = vec![None; topo.n];
        let mut junction_entries = 0u64;
        for (gi, g) in topo.groups.iter().enumerate() {
            group_at[g.first()] = Some(gi);
            let (fu, su) = (ctx.uid_at(g.fork()), ctx.uid_at(g.end()));
            let (fcc, scc) = (ctx.ncfg_at(g.fork()), ctx.ncfg_at(g.end()));
            let mut fm = Vec::with_capacity(g.branches.len());
            let mut mm = Vec::with_capacity(g.branches.len());
            for &(blo, bhi) in &g.branches {
                let (bu_in, bu_out) = (ctx.uid_at(blo), ctx.uid_at(bhi - 1));
                let (cc_in, cc_out) = (ctx.ncfg_at(blo), ctx.ncfg_at(bhi - 1));
                let mut f = Vec::with_capacity(fcc * cc_in);
                for a in 0..fcc {
                    for c in 0..cc_in {
                        f.push(db.reshard_us(fu, a, bu_in, c));
                    }
                }
                fm.push(f);
                let mut m = Vec::with_capacity(cc_out * scc);
                for cb in 0..cc_out {
                    for cs in 0..scc {
                        m.push(db.reshard_us(bu_out, cb, su, cs));
                    }
                }
                mm.push(m);
                junction_entries += (fcc * cc_in + cc_out * scc) as u64;
            }
            fork_mats.push(fm);
            merge_mats.push(mm);
        }
        let trace = ctx.trace();
        if trace.is_enabled() {
            trace.count(crate::obs::Counter::SpdagGroups, topo.groups.len() as u64);
            trace.count(crate::obs::Counter::SpdagJunctionEntries, junction_entries);
        }
        SpCtx { topo: topo.clone(), fork_mats, merge_mats, group_at }
    }

    /// Group starting (branch 0, first position) at `pos`, if any.
    pub(crate) fn group_starting_at(&self, pos: usize) -> Option<usize> {
        self.group_at[pos]
    }

    pub(crate) fn fork_mat(&self, gi: usize, bi: usize) -> &[f64] {
        &self.fork_mats[gi][bi]
    }

    pub(crate) fn merge_mat(&self, gi: usize, bi: usize) -> &[f64] {
        &self.merge_mats[gi][bi]
    }

    fn assert_valid_span(&self, lo: usize, hi: usize) {
        assert!(
            self.topo.valid_cut(lo) && self.topo.valid_cut(hi),
            "span [{lo}, {hi}) cuts through a branch group"
        );
    }
}

/// SP-DAG span search, the [`cost::search_span_ctx`] counterpart:
/// `cap = None` runs the scalar lane, `Some` the capped Pareto lane.
/// Chain-shaped spans delegate to the chain searchers verbatim (the
/// chain fast path — bit-identical by construction, pinned by a
/// regression test).
pub fn sp_search_span(
    ctx: &SearchCtx,
    sp: &SpCtx,
    cap: Option<u64>,
    lo: usize,
    hi: usize,
) -> Option<Plan> {
    sp.assert_valid_span(lo, hi);
    if sp.topo.groups_in(lo, hi).is_empty() {
        return cost::search_span_ctx(ctx, cap, lo, hi);
    }
    match cap {
        None => dp::scalar_plan(ctx, sp, lo, hi),
        Some(c) => dp::pareto_plan(ctx, sp, c, lo, hi),
    }
}

/// Engine-dispatched SP-DAG span search — the [`cost::search_span_engine`]
/// counterpart with identical portfolio semantics (`--engine` on DAG
/// models): `dp` runs the SP lanes, `exact` the SP branch-and-bound with
/// the chain lane's node budget, `auto` the exact lane only when the
/// assignment space fits [`cost::exact::AUTO_EXACT_BITS`]. A budget
/// exhaustion falls back to the DP with a stderr note, never a wrong
/// answer.
pub fn sp_search_span_engine(
    ctx: &SearchCtx,
    sp: &SpCtx,
    cap: Option<u64>,
    lo: usize,
    hi: usize,
    engine: SearchEngine,
) -> Option<Plan> {
    sp.assert_valid_span(lo, hi);
    if sp.topo.groups_in(lo, hi).is_empty() {
        return cost::search_span_engine(ctx, cap, lo, hi, engine);
    }
    let budget = match engine {
        SearchEngine::Dp => {
            ctx.trace().note("engine_path", "dp");
            return sp_search_span(ctx, sp, cap, lo, hi);
        }
        SearchEngine::Exact => cost::exact::EXACT_NODE_BUDGET,
        SearchEngine::Auto => {
            if cost::space_bits(ctx, lo, hi) > cost::exact::AUTO_EXACT_BITS {
                ctx.trace().note("engine_path", "auto-dp");
                return sp_search_span(ctx, sp, cap, lo, hi);
            }
            cost::exact::AUTO_NODE_BUDGET
        }
    };
    match exact::sp_search_span_exact_budget(ctx, sp, cap, lo, hi, budget) {
        Ok(p) => {
            ctx.trace().note(
                "engine_path",
                if engine == SearchEngine::Auto { "auto-exact" } else { "exact" },
            );
            p
        }
        Err(cost::exact::Exhausted) => {
            ctx.trace().note("engine_path", "exact-exhausted-dp-fallback");
            crate::obs::diag::diag(&format!(
                "cfp: sp-dag exact lane exhausted its node budget on [{lo}, {hi}); \
                 falling back to the DP"
            ));
            sp_search_span(ctx, sp, cap, lo, hi)
        }
    }
}

/// SP-DAG memory-frontier span search, the
/// [`cost::search_span_mem_ctx`] counterpart. Chain-shaped spans
/// delegate verbatim.
pub fn sp_search_mem_span(
    ctx: &SearchCtx,
    sp: &SpCtx,
    lo: usize,
    hi: usize,
    spec: RecomputeSpec,
) -> Vec<SpanMemPlan> {
    sp.assert_valid_span(lo, hi);
    if sp.topo.groups_in(lo, hi).is_empty() {
        return cost::search_span_mem_ctx(ctx, lo, hi, spec);
    }
    dp::mem_frontier(ctx, sp, lo, hi, spec, false)
}

/// Exact (untruncated, true-dominance) counterpart of
/// [`sp_search_mem_span`] — the memory lane's oracle: same float
/// association, no running-min keep rule, no frontier thinning.
pub fn sp_search_mem_span_exact(
    ctx: &SearchCtx,
    sp: &SpCtx,
    lo: usize,
    hi: usize,
    spec: RecomputeSpec,
) -> Vec<SpanMemPlan> {
    sp.assert_valid_span(lo, hi);
    if sp.topo.groups_in(lo, hi).is_empty() {
        return cost::search_span_mem_exact(ctx, lo, hi, spec);
    }
    dp::mem_frontier(ctx, sp, lo, hi, spec, true)
}

/// Replay a fixed choice vector over span `[lo, hi)` with the DP's own
/// float association, returning `(time_us, mem_bytes)` — the DAG
/// counterpart of [`cost::plan_cost_span`]'s role for baselines and
/// tests. The returned time is bit-identical to the DP/exact value for
/// the same assignment.
pub fn sp_plan_cost_span(
    ctx: &SearchCtx,
    sp: &SpCtx,
    choice: &[usize],
    lo: usize,
    hi: usize,
) -> (f64, u64) {
    sp.assert_valid_span(lo, hi);
    assert_eq!(choice.len(), hi - lo);
    let (time, mem) = (ctx.time_col(), ctx.mem_col());
    let mut fin = vec![0.0f64; hi - lo];
    let mut mem_sum = 0u64;
    let mut pos = lo;
    while pos < hi {
        let i = pos - lo;
        let c = choice[i];
        let o = ctx.off_at(pos);
        mem_sum += mem[o + c];
        if let Some(gi) = sp.group_starting_at(pos) {
            let g = &sp.topo.groups[gi];
            let fork_i = g.fork() - lo;
            let a = choice[fork_i];
            // branch-local clocks seeded from the fork edge
            for (bi, &(blo, bhi)) in g.branches.iter().enumerate() {
                for p in blo..bhi {
                    let j = p - lo;
                    let cj = choice[j];
                    let oj = ctx.off_at(p);
                    if p > blo {
                        mem_sum += mem[oj + cj];
                    }
                    let cc = ctx.ncfg_at(p);
                    fin[j] = if p == blo {
                        (0.0 + sp.fork_mat(gi, bi)[a * cc + cj]) + time[oj + cj]
                    } else {
                        (fin[j - 1] + ctx.step_matrix(p)[choice[j - 1] * cc + cj]) + time[oj + cj]
                    };
                }
            }
            // merge into the successor: fork clock + slowest branch
            let s = g.end();
            let si = s - lo;
            let cs = choice[si];
            let so = ctx.off_at(s);
            mem_sum += mem[so + cs];
            let scc = ctx.ncfg_at(s);
            let mut mx = f64::NEG_INFINITY;
            for (bi, &(_, bhi)) in g.branches.iter().enumerate() {
                let cb = choice[bhi - 1 - lo];
                let w = fin[bhi - 1 - lo] + sp.merge_mat(gi, bi)[cb * scc + cs];
                if w > mx {
                    mx = w;
                }
            }
            fin[si] = (fin[fork_i] + mx) + time[so + cs];
            pos = s + 1;
        } else {
            let cc = ctx.ncfg_at(pos);
            fin[i] = if pos == lo {
                time[o + c]
            } else {
                (fin[i - 1] + ctx.step_matrix(pos)[choice[i - 1] * cc + c]) + time[o + c]
            };
            pos += 1;
        }
    }
    (fin[hi - lo - 1], mem_sum)
}

/// Build the event-simulation task list for a fixed plan over
/// `[lo, hi)`: one [`crate::cluster::sim::SpTask`] per instance, with
/// fork/merge dependencies and reshard costs priced exactly as the DP
/// priced them, so [`crate::cluster::sim::simulate_sp_dag`] reproduces
/// the planner's closed form bit-for-bit.
pub fn sim_tasks(
    ctx: &SearchCtx,
    sp: &SpCtx,
    choice: &[usize],
    lo: usize,
    hi: usize,
) -> Vec<crate::cluster::sim::SpTask> {
    use crate::cluster::sim::SpTask;
    sp.assert_valid_span(lo, hi);
    assert_eq!(choice.len(), hi - lo);
    let time = ctx.time_col();
    let mut tasks = Vec::with_capacity(hi - lo);
    let mut pos = lo;
    while pos < hi {
        if let Some(gi) = sp.group_starting_at(pos) {
            let g = &sp.topo.groups[gi];
            let fork_i = g.fork() - lo;
            let a = choice[fork_i];
            for (bi, &(blo, bhi)) in g.branches.iter().enumerate() {
                for p in blo..bhi {
                    let j = p - lo;
                    let c = choice[j];
                    let o = ctx.off_at(p);
                    let cc = ctx.ncfg_at(p);
                    let (deps, seed_zero) = if p == blo {
                        (vec![(fork_i, sp.fork_mat(gi, bi)[a * cc + c])], true)
                    } else {
                        (vec![(j - 1, ctx.step_matrix(p)[choice[j - 1] * cc + c])], false)
                    };
                    tasks.push(SpTask { time_us: time[o + c], deps, seed_zero, rebase: None });
                }
            }
            let s = g.end();
            let cs = choice[s - lo];
            let so = ctx.off_at(s);
            let scc = ctx.ncfg_at(s);
            let deps: Vec<(usize, f64)> = g
                .branches
                .iter()
                .enumerate()
                .map(|(bi, &(_, bhi))| {
                    let cb = choice[bhi - 1 - lo];
                    (bhi - 1 - lo, sp.merge_mat(gi, bi)[cb * scc + cs])
                })
                .collect();
            tasks.push(SpTask {
                time_us: time[so + cs],
                deps,
                seed_zero: false,
                rebase: Some(fork_i),
            });
            pos = s + 1;
        } else {
            let i = pos - lo;
            let c = choice[i];
            let o = ctx.off_at(pos);
            let deps = if pos == lo {
                Vec::new()
            } else {
                let cc = ctx.ncfg_at(pos);
                vec![(i - 1, ctx.step_matrix(pos)[choice[i - 1] * cc + c])]
            };
            tasks.push(SpTask { time_us: time[o + c], deps, seed_zero: false, rebase: None });
            pos += 1;
        }
    }
    tasks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> SpTopology {
        SpTopology {
            n: 8,
            groups: vec![
                BranchGroup { branches: vec![(1, 2), (2, 3)] },
                BranchGroup { branches: vec![(5, 6), (6, 7)] },
            ],
        }
    }

    #[test]
    fn validate_accepts_well_formed_and_rejects_malformed() {
        assert!(topo().validate().is_ok());
        assert!(SpTopology::chain(5).validate().is_ok());
        // one branch only
        let t = SpTopology { n: 4, groups: vec![BranchGroup { branches: vec![(1, 2)] }] };
        assert!(t.validate().is_err());
        // no successor instance
        let t = SpTopology { n: 3, groups: vec![BranchGroup { branches: vec![(1, 2), (2, 3)] }] };
        assert!(t.validate().is_err());
        // no fork instance
        let t = SpTopology { n: 4, groups: vec![BranchGroup { branches: vec![(0, 1), (1, 2)] }] };
        assert!(t.validate().is_err());
        // non-contiguous branches
        let t = SpTopology { n: 6, groups: vec![BranchGroup { branches: vec![(1, 2), (3, 4)] }] };
        assert!(t.validate().is_err());
        // groups sharing the successor/fork instance
        let t = SpTopology {
            n: 7,
            groups: vec![
                BranchGroup { branches: vec![(1, 2), (2, 3)] },
                BranchGroup { branches: vec![(3, 4), (4, 5)] },
            ],
        };
        assert!(t.validate().is_err(), "second fork would be the first successor");
    }

    #[test]
    fn cut_validity_follows_group_spans() {
        let t = topo();
        // group 0 occupies [1, 3) with fork 0 and successor 3
        for p in 0..=t.n {
            let inside = (1..=2).contains(&p) || (5..=6).contains(&p);
            assert_eq!(t.valid_cut(p), !inside, "cut {p}");
        }
        assert_eq!(t.groups_in(0, 8), vec![0, 1]);
        assert_eq!(t.groups_in(0, 4), vec![0]);
        assert_eq!(t.groups_in(4, 8), vec![1]);
        assert_eq!(t.groups_in(3, 5), Vec::<usize>::new());
    }

    #[test]
    fn decompose_recompose_round_trips() {
        for t in [topo(), SpTopology::chain(4), SpTopology::chain(0)] {
            let tree = decompose(&t);
            assert_eq!(recompose(&tree).unwrap(), t);
            assert_eq!(decompose(&recompose(&tree).unwrap()), tree);
        }
    }

    #[test]
    fn recompose_rejects_non_canonical_trees() {
        assert!(recompose(&SpTree::Leaf { lo: 0, hi: 3 }).is_err(), "must be a series");
        let gap = SpTree::Series(vec![
            SpTree::Leaf { lo: 0, hi: 1 },
            SpTree::Leaf { lo: 2, hi: 3 },
        ]);
        assert!(recompose(&gap).is_err());
        let nested = SpTree::Series(vec![
            SpTree::Leaf { lo: 0, hi: 1 },
            SpTree::Parallel(vec![
                SpTree::Parallel(vec![SpTree::Leaf { lo: 1, hi: 2 }]),
                SpTree::Leaf { lo: 2, hi: 3 },
            ]),
            SpTree::Leaf { lo: 3, hi: 4 },
        ]);
        assert!(recompose(&nested).is_err());
    }

    #[test]
    fn signatures_encode_topology_class() {
        assert_eq!(SpTopology::chain(9).signature(), "chain");
        assert_eq!(topo().signature(), "sp-dag2");
        let t = SpTopology {
            n: 6,
            groups: vec![BranchGroup { branches: vec![(1, 2), (2, 3), (3, 4)] }],
        };
        assert_eq!(t.signature(), "sp-dag3");
    }
}
