//! Exact branch-and-bound span search over SP-DAG topologies — the
//! [`crate::cost::exact`] counterpart for branched models, and the
//! oracle the property suite holds the SP DP lanes to.
//!
//! The DFS enumerates configs position-by-position in the linearized
//! order, replaying the DP lanes' float association per edge exactly
//! (trunk `(prev + reshard) + seg_t`, branch seeds `(0.0 + fork_reshard)
//! + seg_t` on a branch-local clock, merges `(fork + max_b(rel_b +
//! merge_reshard)) + seg_t`), so a DP == exact comparison is meaningful
//! at the bit level, not merely within a tolerance.
//!
//! Admissible pruning mirrors the chain lane with one DAG twist: the
//! suffix time bound treats a branch group as `max_b(Σ min seg time over
//! branch b)` — branches run concurrently, so the remaining-work bound
//! from *inside* a branch must jump over its sibling branches straight
//! to the successor's tail (summing a sibling's minima would overshoot
//! the true completion and break admissibility). Memory is additive
//! across branches, so the exact-integer suffix-sum prune carries over
//! unchanged. Time bounds are deflated by the chain lane's own
//! `×(1 − 1e-9)` slack so float rounding in long sums can never prune
//! the true optimum.

use crate::cost::exact::Exhausted;
use crate::cost::{self, Plan, SearchCtx};

use super::SpCtx;

/// Same slack the chain exact lane applies to its suffix time bounds.
const BOUND_DEFLATE: f64 = 1.0 - 1e-9;

/// Exact SP-DAG span search with an unbounded node budget.
pub fn sp_search_span_exact(
    ctx: &SearchCtx,
    sp: &SpCtx,
    cap: Option<u64>,
    lo: usize,
    hi: usize,
) -> Option<Plan> {
    match sp_search_span_exact_budget(ctx, sp, cap, lo, hi, u64::MAX) {
        Ok(p) => p,
        Err(Exhausted) => unreachable!("unbounded budget cannot exhaust"),
    }
}

/// Exact SP-DAG span search under a node budget. Every `(position,
/// config)` trial costs one node; exceeding the budget returns
/// `Err(Exhausted)` — never a wrong answer. Chain-shaped spans delegate
/// to [`cost::search_span_exact_budget`] verbatim.
pub fn sp_search_span_exact_budget(
    ctx: &SearchCtx,
    sp: &SpCtx,
    cap: Option<u64>,
    lo: usize,
    hi: usize,
    budget: u64,
) -> Result<Option<Plan>, Exhausted> {
    assert!(lo <= hi && hi <= ctx.len());
    sp.assert_valid_span(lo, hi);
    if sp.topo.groups_in(lo, hi).is_empty() {
        return cost::exact::search_span_exact_budget(ctx, cap, lo, hi, budget);
    }
    let n = hi - lo;

    // span-relative roles: `branch_of[i] = (group, branch, first, last)`,
    // `merge_of[i]` marks a group's successor, `fork_of[i]` its fork
    let mut branch_of: Vec<Option<(usize, usize, bool, bool)>> = vec![None; n];
    let mut merge_of: Vec<Option<usize>> = vec![None; n];
    let mut fork_of: Vec<Option<usize>> = vec![None; n];
    for gi in sp.topo.groups_in(lo, hi) {
        let g = &sp.topo.groups[gi];
        fork_of[g.fork() - lo] = Some(gi);
        merge_of[g.end() - lo] = Some(gi);
        for (bi, &(blo, bhi)) in g.branches.iter().enumerate() {
            for p in blo..bhi {
                branch_of[p - lo] = Some((gi, bi, p == blo, p + 1 == bhi));
            }
        }
    }

    // per-position minima over configs
    let mut min_t = vec![0.0f64; n];
    let mut min_m = vec![0u64; n];
    for i in 0..n {
        let pos = lo + i;
        let o = ctx.off_at(pos);
        let (mut t, mut m) = (f64::INFINITY, u64::MAX);
        for c in 0..ctx.ncfg_at(pos) {
            t = t.min(ctx.time_col()[o + c]);
            m = m.min(ctx.mem_col()[o + c]);
        }
        min_t[i] = t;
        min_m[i] = m;
    }

    // exact-integer memory suffix sums (memory is additive across
    // branches, so the plain chain bound stays valid)
    let mut lb_mem = vec![0u64; n + 1];
    for i in (0..n).rev() {
        lb_mem[i] = min_m[i].saturating_add(lb_mem[i + 1]);
    }

    // group time lump: branches run concurrently, the group contributes
    // at least the largest per-branch sum of minima
    let lump = |gi: usize| -> f64 {
        sp.topo.groups[gi]
            .branches
            .iter()
            .map(|&(blo, bhi)| (blo..bhi).map(|p| min_t[p - lo]).sum::<f64>())
            .fold(0.0f64, f64::max)
    };

    // `after[i]`: admissible bound on (final time − clock after choosing
    // position i). From a branch-last position the remainder jumps to
    // the successor's tail (siblings fold by max, never sum); from a
    // fork it is the group lump plus the successor's tail.
    let succ_rel = |gi: usize| sp.topo.groups[gi].end() - lo;
    let mut tail = vec![0.0f64; n + 1];
    let mut after = vec![0.0f64; n];
    for i in (0..n).rev() {
        let a = match branch_of[i] {
            Some((gi, _, _, true)) => tail[succ_rel(gi)],
            Some(_) => tail[i + 1],
            None => match fork_of[i] {
                Some(gi) => lump(gi) + tail[succ_rel(gi)],
                None => tail[i + 1],
            },
        };
        after[i] = a;
        tail[i] = min_t[i] + a;
    }
    for a in after.iter_mut() {
        *a *= BOUND_DEFLATE;
    }

    let mut dfs = Dfs {
        ctx,
        sp,
        lo,
        n,
        cap: cap.unwrap_or(u64::MAX),
        branch_of,
        merge_of,
        after,
        lb_mem,
        cur: vec![0; n],
        clock: vec![0.0; n],
        nodes: 0,
        budget,
        best_t: f64::INFINITY,
        best_m: u64::MAX,
        best_choice: None,
    };
    dfs.go(0, 0)?;
    Ok(dfs
        .best_choice
        .map(|choice| Plan { choice, time_us: dfs.best_t, mem_bytes: dfs.best_m }))
}

struct Dfs<'a> {
    ctx: &'a SearchCtx,
    sp: &'a SpCtx,
    lo: usize,
    n: usize,
    cap: u64,
    branch_of: Vec<Option<(usize, usize, bool, bool)>>,
    merge_of: Vec<Option<usize>>,
    after: Vec<f64>,
    lb_mem: Vec<u64>,
    cur: Vec<usize>,
    /// per-position clock after its choice: absolute time for trunk and
    /// successor positions, branch-local (0.0-seeded) time for branch
    /// positions
    clock: Vec<f64>,
    nodes: u64,
    budget: u64,
    best_t: f64,
    best_m: u64,
    best_choice: Option<Vec<usize>>,
}

impl Dfs<'_> {
    fn go(&mut self, i: usize, mem: u64) -> Result<(), Exhausted> {
        if i == self.n {
            // the final position is trunk/successor (a valid cut cannot
            // end inside a group), so its clock is the span time
            let t = self.clock[self.n - 1];
            if self.best_choice.is_none()
                || t < self.best_t
                || (t == self.best_t && mem < self.best_m)
            {
                self.best_t = t;
                self.best_m = mem;
                self.best_choice = Some(self.cur.clone());
            }
            return Ok(());
        }
        let pos = self.lo + i;
        let o = self.ctx.off_at(pos);
        let cc = self.ctx.ncfg_at(pos);
        for c in 0..cc {
            self.nodes += 1;
            if self.nodes > self.budget {
                return Err(Exhausted);
            }
            let m = mem.saturating_add(self.ctx.mem_col()[o + c]);
            if m.saturating_add(self.lb_mem[i + 1]) > self.cap {
                continue;
            }
            let seg_t = self.ctx.time_col()[o + c];
            // (clock value to store, absolute completion lower bound K)
            let (clk, k) = match self.branch_of[i] {
                Some((gi, bi, first, _)) => {
                    let fork_rel = self.sp.topo.groups[gi].fork() - self.lo;
                    let rel = if first {
                        let a = self.cur[fork_rel];
                        (0.0 + self.sp.fork_mat(gi, bi)[a * cc + c]) + seg_t
                    } else {
                        (self.clock[i - 1] + self.ctx.step_matrix(pos)[self.cur[i - 1] * cc + c])
                            + seg_t
                    };
                    (rel, self.clock[fork_rel] + rel)
                }
                None => {
                    let t = if let Some(gi) = self.merge_of[i] {
                        let g = &self.sp.topo.groups[gi];
                        let fork_rel = g.fork() - self.lo;
                        let mut mx = f64::NEG_INFINITY;
                        for (bi, &(_, bhi)) in g.branches.iter().enumerate() {
                            let lb = bhi - 1 - self.lo;
                            let w = self.clock[lb]
                                + self.sp.merge_mat(gi, bi)[self.cur[lb] * cc + c];
                            if w > mx {
                                mx = w;
                            }
                        }
                        (self.clock[fork_rel] + mx) + seg_t
                    } else if i == 0 {
                        seg_t
                    } else {
                        (self.clock[i - 1] + self.ctx.step_matrix(pos)[self.cur[i - 1] * cc + c])
                            + seg_t
                    };
                    (t, t)
                }
            };
            if self.best_choice.is_some() && k + self.after[i] > self.best_t {
                continue;
            }
            self.clock[i] = clk;
            self.cur[i] = c;
            self.go(i + 1, m)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::{sp_search_span, sp_plan_cost_span, BranchGroup, SpCtx, SpTopology};
    use super::*;
    use crate::profiler::{ProfileDb, ReshardTable, SegmentConfig, SegmentProfile};
    use crate::segment::{SegmentInstance, SegmentSet, UniqueSegment};
    use crate::spmd::ShardState;

    fn profile(cfgs: usize, times: &[f64], mems: &[u64]) -> SegmentProfile {
        SegmentProfile {
            configs: (0..cfgs).map(|c| SegmentConfig { strategy: vec![c] }).collect(),
            t_c_us: times.to_vec(),
            t_p_us: vec![0.0; cfgs],
            mem_bytes: mems.to_vec(),
            act_bytes: vec![64; cfgs],
            ckpt_bytes: vec![16; cfgs],
            t_fwd_us: times.to_vec(),
            symbolic_volume: vec![0; cfgs],
            boundary_out: vec![ShardState::Replicated; cfgs],
            boundary_in: vec![ShardState::Replicated; cfgs],
        }
    }

    fn chain_set(uids: &[usize], uniques: usize) -> SegmentSet {
        SegmentSet {
            instances: uids
                .iter()
                .map(|&u| SegmentInstance { unique_id: u, blocks: vec![], fwd_range: (0, 0) })
                .collect(),
            unique: (0..uniques)
                .map(|u| UniqueSegment {
                    id: u,
                    fingerprint: format!("u{u}"),
                    rep: uids.iter().position(|&x| x == u).unwrap_or(0),
                    count: uids.iter().filter(|&&x| x == u).count(),
                })
                .collect(),
        }
    }

    fn dense(ca: usize, cb: usize, scale: f64) -> ReshardTable {
        ReshardTable {
            t_r_us: (0..ca)
                .map(|a| (0..cb).map(|b| scale * (1.0 + (a * cb + b) as f64)).collect())
                .collect(),
            sym_vol: vec![vec![0; cb]; ca],
            programs: ca * cb,
        }
    }

    /// Fork `u0`, two expert branches `u1`/`u2`, merge-owning `u1`
    /// successor, two trailing `u0` trunk instances — dyadic times so
    /// every sum is exact and tie behavior is visible.
    fn fixture() -> (SegmentSet, ProfileDb, SpTopology) {
        let mut db = ProfileDb::default();
        db.segments.push(profile(2, &[4.0, 6.0], &[100, 60]));
        db.segments.push(profile(3, &[8.0, 5.0, 7.0], &[200, 300, 150]));
        db.segments.push(profile(2, &[3.0, 9.0], &[120, 40]));
        db.reshard.insert((0, 1), dense(2, 3, 0.5));
        db.reshard.insert((0, 2), dense(2, 2, 0.25));
        db.reshard.insert((1, 1), dense(3, 3, 1.0));
        db.reshard.insert((2, 1), dense(2, 3, 2.0));
        db.reshard.insert((1, 0), dense(3, 2, 0.125));
        let ss = chain_set(&[0, 1, 2, 1, 0, 0], 3);
        let topo = SpTopology {
            n: 6,
            groups: vec![BranchGroup { branches: vec![(1, 2), (2, 3)] }],
        };
        topo.validate().unwrap();
        (ss, db, topo)
    }

    /// All config assignments of the fixture, priced by the replay
    /// helper (the reference association).
    fn brute_force(
        ctx: &SearchCtx,
        sp: &SpCtx,
        cap: Option<u64>,
    ) -> Option<(f64, u64)> {
        let ncfg = [2usize, 3, 2, 3, 2, 2];
        let mut best: Option<(f64, u64)> = None;
        let mut choice = [0usize; 6];
        loop {
            let (t, m) = sp_plan_cost_span(ctx, sp, &choice, 0, 6);
            if !cap.is_some_and(|cap| m > cap) {
                let better = best.map_or(true, |(bt, bm)| t < bt || (t == bt && m < bm));
                if better {
                    best = Some((t, m));
                }
            }
            // odometer over the per-position config counts
            let mut i = 6;
            loop {
                if i == 0 {
                    return best;
                }
                i -= 1;
                choice[i] += 1;
                if choice[i] < ncfg[i] {
                    break;
                }
                choice[i] = 0;
            }
        }
    }

    #[test]
    fn exact_matches_brute_force_and_dp_bitwise() {
        let (ss, db, topo) = fixture();
        let ctx = SearchCtx::new(&ss, &db);
        let sp = SpCtx::new(&ctx, &topo, &db);
        let (bt, _) = brute_force(&ctx, &sp, None).unwrap();
        let ex = sp_search_span_exact(&ctx, &sp, None, 0, 6).unwrap();
        assert_eq!(ex.time_us.to_bits(), bt.to_bits(), "exact vs brute force");
        let dp = sp_search_span(&ctx, &sp, None, 0, 6).unwrap();
        assert_eq!(dp.time_us.to_bits(), bt.to_bits(), "dp vs brute force");
        let (rt, rm) = sp_plan_cost_span(&ctx, &sp, &ex.choice, 0, 6);
        assert_eq!(rt.to_bits(), ex.time_us.to_bits(), "replay of the exact choice");
        assert_eq!(rm, ex.mem_bytes);
    }

    #[test]
    fn capped_exact_matches_brute_force_across_caps() {
        let (ss, db, topo) = fixture();
        let ctx = SearchCtx::new(&ss, &db);
        let sp = SpCtx::new(&ctx, &topo, &db);
        for cap in [450u64, 520, 600, 750, 10_000] {
            let bf = brute_force(&ctx, &sp, Some(cap));
            let ex = sp_search_span_exact(&ctx, &sp, Some(cap), 0, 6);
            match (bf, ex) {
                (None, None) => {}
                (Some((bt, _)), Some(p)) => {
                    assert_eq!(p.time_us.to_bits(), bt.to_bits(), "cap {cap}");
                    assert!(p.mem_bytes <= cap, "cap {cap}");
                    let dp = sp_search_span(&ctx, &sp, Some(cap), 0, 6).unwrap();
                    assert_eq!(dp.time_us.to_bits(), bt.to_bits(), "dp, cap {cap}");
                }
                (bf, ex) => panic!("cap {cap}: brute force {bf:?} vs exact {ex:?}"),
            }
        }
        // an infeasibly small cap: every assignment exceeds it
        assert!(sp_search_span_exact(&ctx, &sp, Some(100), 0, 6).is_none());
    }

    #[test]
    fn chain_shaped_spans_delegate_to_the_chain_exact_lane() {
        let (ss, db, topo) = fixture();
        let ctx = SearchCtx::new(&ss, &db);
        let sp = SpCtx::new(&ctx, &topo, &db);
        // [4, 6) is trunk-only (a cut at 4 is past the group's successor)
        let ours = sp_search_span_exact(&ctx, &sp, None, 4, 6).unwrap();
        let chain = cost::search_span_exact(&ctx, None, 4, 6).unwrap();
        assert_eq!(ours.time_us.to_bits(), chain.time_us.to_bits());
        assert_eq!(ours.choice, chain.choice);
    }

    #[test]
    fn tiny_budget_reports_exhaustion() {
        let (ss, db, topo) = fixture();
        let ctx = SearchCtx::new(&ss, &db);
        let sp = SpCtx::new(&ctx, &topo, &db);
        assert_eq!(
            sp_search_span_exact_budget(&ctx, &sp, None, 0, 6, 3),
            Err(Exhausted)
        );
        assert!(sp_search_span_exact_budget(&ctx, &sp, None, 0, 6, u64::MAX).is_ok());
    }
}
