//! Unified diagnostic sink for operational warnings.
//!
//! Every "the planner kept going but you should know" message — exact
//! engine budget exhaustion, cache-discard warnings, stale-lock
//! takeover — routes through [`diag`] instead of raw `eprintln!`, so
//! one `--quiet` flag silences the lot uniformly across CLI and
//! `cfp serve`, and tests can capture the stream instead of scraping
//! stderr. Diagnostics are advisory only: they never carry plan data
//! and suppressing them cannot change any output byte.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;

static QUIET: AtomicBool = AtomicBool::new(false);
static CAPTURE: Mutex<Option<Vec<String>>> = Mutex::new(None);

/// Emit one diagnostic line. Captured if a test capture is active,
/// otherwise printed to stderr unless `--quiet` suppressed it.
pub fn diag(msg: &str) {
    {
        let mut cap = CAPTURE.lock().unwrap();
        if let Some(buf) = cap.as_mut() {
            buf.push(msg.to_string());
            return;
        }
    }
    if !quiet() {
        eprintln!("{msg}");
    }
}

/// Suppress (or restore) stderr diagnostics process-wide (`--quiet`).
pub fn set_quiet(q: bool) {
    QUIET.store(q, Ordering::Relaxed);
}

pub fn quiet() -> bool {
    QUIET.load(Ordering::Relaxed)
}

/// Begin capturing diagnostics instead of printing them (tests only —
/// the capture buffer is process-global).
pub fn capture_begin() {
    *CAPTURE.lock().unwrap() = Some(Vec::new());
}

/// Stop capturing and return everything captured since
/// [`capture_begin`].
pub fn capture_end() -> Vec<String> {
    CAPTURE.lock().unwrap().take().unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capture_intercepts_diagnostics() {
        capture_begin();
        diag("cfp-test: marker-4242");
        let got = capture_end();
        // other tests may interleave lines into the global buffer; only
        // require that our marker arrived and nothing prints afterwards
        assert!(got.iter().any(|l| l == "cfp-test: marker-4242"));
        assert!(capture_end().is_empty(), "capture is one-shot");
    }

    #[test]
    fn quiet_flag_round_trips() {
        let was = quiet();
        set_quiet(true);
        assert!(quiet());
        set_quiet(was);
        assert_eq!(quiet(), was);
    }
}
