//! Observability spine (PR 9): deterministic search counters, wall-clock
//! spans, and Chrome trace-event export.
//!
//! Two strictly separated kinds of data live in one [`Trace`]:
//!
//! * **Counters** ([`Counter`]) — monotone `u64` tallies of *search work*
//!   (DP states visited, splice fast-forwards, B&B nodes, sweep fan-out,
//!   …). Every counting site tallies a quantity that is a pure function
//!   of the planning inputs: additive over a deterministic set of
//!   sub-tasks whose partition across threads never changes the sum, and
//!   invariant across cache states (e.g. the profiler counts
//!   `hits + misses`, never the split). Counter snapshots are therefore
//!   **bit-identical across thread counts, cache states, and
//!   serve-vs-CLI** — the determinism invariant the rest of the repo
//!   already holds for plans, extended to its observability.
//! * **Events** — wall-clock phase spans ([`Trace::span`]) recorded for
//!   the Chrome trace-event export ([`Trace::chrome_trace_json`],
//!   `--trace-out`, loadable in Perfetto / `chrome://tracing`).
//!   Wall-clock time is confined here: timestamps and durations never
//!   feed counters, notes, or `cfp explain` output.
//!
//! A disabled trace (the default — [`Trace::disabled`]) holds no
//! allocation and every operation is a single `Option` branch, so
//! tracing off is a no-op on plan bytes and adds ≤ 1% search overhead
//! (pinned by the `trace_overhead/{off,on}` rows in `BENCH_search.json`).
//! Cloning a [`Trace`] shares the underlying sink (`Arc`), which is how
//! one trace threads through `coordinator` → `cost`/`spdag`/`interop` →
//! worker threads.

pub mod diag;
pub mod explain;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::Json;

/// Deterministic search-work counters, one per instrumented site class.
/// The discriminant is the slot index; [`Counter::ALL`] fixes the
/// snapshot order (and therefore the `cfp explain` / `stats` byte
/// layout) permanently.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// chain positions entering ComposeSearch (`SegmentSet::instances`)
    SegmentInstances,
    /// fingerprint-deduplicated unique segments
    SegmentUnique,
    /// unique segments resolved by the profiler (cache hits + misses —
    /// the cache-state-invariant sum, never the split)
    ProfilerSegments,
    /// programs a real testbed would compile (Fig. 12 model; identical
    /// on warm and cold runs by the warm-replay invariant)
    ProfilerPrograms,
    /// full `O(C²)` scalar DP steps (per position × predecessor config)
    ScalarSteps,
    /// positions fast-forwarded by the steady-state splice (`O(C)` each)
    ScalarSpliced,
    /// splice checkpoint mismatches that rolled back to a verified state
    ScalarRollbacks,
    /// capped-Pareto lane candidate states generated
    ParetoStates,
    /// capped-Pareto lane states surviving pruning
    ParetoKept,
    /// memory-frontier lane candidate points generated
    MemStates,
    /// memory-frontier lane points surviving pruning
    MemKept,
    /// branch-and-bound nodes expanded (chain + sp-dag exact lanes)
    ExactNodes,
    /// B&B children cut by the admissible suffix time bound
    ExactBoundPruned,
    /// B&B children cut by the exact integer memory prune
    ExactMemPruned,
    /// exact-lane searches that exhausted their node budget (DP fallback)
    ExactExhausted,
    /// shared-prefix sweep passes (one per `(context, origin)` job)
    SweepOrigins,
    /// spans answered by sweep passes (each replaces one full span DP)
    SweepSpans,
    /// SP-DAG branch groups priced (`SpCtx` junction construction)
    SpdagGroups,
    /// dense fork/merge junction matrix entries expanded
    SpdagJunctionEntries,
    /// candidate stage counts tried by the inter-op planner
    InteropStageCounts,
    /// sweep jobs fanned over the thread pool by `SpanTables`
    InteropSweepJobs,
    /// stage-split DP states kept after Pareto pruning
    InteropSplitStates,
    /// stage candidates rejected for busting the 1F1B memory cap
    InteropMemRejects,
    /// stage plans recovered via checkpointed (remat) variants
    InteropMemRecovers,
}

/// Number of counter slots ([`Counter::ALL`] length).
pub const NUM_COUNTERS: usize = 24;

impl Counter {
    /// Every counter in snapshot order. Append-only: slot order is part
    /// of the `explain`/`stats` output contract.
    pub const ALL: [Counter; NUM_COUNTERS] = [
        Counter::SegmentInstances,
        Counter::SegmentUnique,
        Counter::ProfilerSegments,
        Counter::ProfilerPrograms,
        Counter::ScalarSteps,
        Counter::ScalarSpliced,
        Counter::ScalarRollbacks,
        Counter::ParetoStates,
        Counter::ParetoKept,
        Counter::MemStates,
        Counter::MemKept,
        Counter::ExactNodes,
        Counter::ExactBoundPruned,
        Counter::ExactMemPruned,
        Counter::ExactExhausted,
        Counter::SweepOrigins,
        Counter::SweepSpans,
        Counter::SpdagGroups,
        Counter::SpdagJunctionEntries,
        Counter::InteropStageCounts,
        Counter::InteropSweepJobs,
        Counter::InteropSplitStates,
        Counter::InteropMemRejects,
        Counter::InteropMemRecovers,
    ];

    /// Stable wire/display name (snake_case).
    pub fn name(self) -> &'static str {
        match self {
            Counter::SegmentInstances => "segment_instances",
            Counter::SegmentUnique => "segment_unique",
            Counter::ProfilerSegments => "profiler_segments",
            Counter::ProfilerPrograms => "profiler_programs",
            Counter::ScalarSteps => "scalar_steps",
            Counter::ScalarSpliced => "scalar_spliced",
            Counter::ScalarRollbacks => "scalar_rollbacks",
            Counter::ParetoStates => "pareto_states",
            Counter::ParetoKept => "pareto_kept",
            Counter::MemStates => "mem_states",
            Counter::MemKept => "mem_kept",
            Counter::ExactNodes => "exact_nodes",
            Counter::ExactBoundPruned => "exact_bound_pruned",
            Counter::ExactMemPruned => "exact_mem_pruned",
            Counter::ExactExhausted => "exact_exhausted",
            Counter::SweepOrigins => "sweep_origins",
            Counter::SweepSpans => "sweep_spans",
            Counter::SpdagGroups => "spdag_groups",
            Counter::SpdagJunctionEntries => "spdag_junction_entries",
            Counter::InteropStageCounts => "interop_stage_counts",
            Counter::InteropSweepJobs => "interop_sweep_jobs",
            Counter::InteropSplitStates => "interop_split_states",
            Counter::InteropMemRejects => "interop_mem_rejects",
            Counter::InteropMemRecovers => "interop_mem_recovers",
        }
    }
}

/// Per-site failpoint audit rows, `(site, evals, trips)` in site-name
/// order — the obs-layer export of [`crate::util::failpoint`] trip
/// counters that makes chaos runs auditable (`stats` responses grow a
/// `faults` object, `--trace-out` a `cfp.faults` event). Empty whenever
/// no fault schedule is armed, so every disarmed output stays
/// byte-identical to a build without the fault layer.
pub fn fault_counters() -> Vec<(String, u64, u64)> {
    crate::util::failpoint::snapshot()
}

/// The [`fault_counters`] rows as a JSON object (`site` →
/// `{evals, trips}`), or `None` when disarmed.
pub fn fault_counters_json() -> Option<Json> {
    let rows = fault_counters();
    if rows.is_empty() {
        return None;
    }
    let m: BTreeMap<String, Json> = rows
        .into_iter()
        .map(|(site, evals, trips)| {
            (
                site,
                Json::obj(vec![
                    ("evals", Json::num(evals as f64)),
                    ("trips", Json::num(trips as f64)),
                ]),
            )
        })
        .collect();
    Some(Json::Obj(m))
}

/// One completed wall-clock span (Chrome trace-event `ph: "X"`).
#[derive(Clone, Debug)]
pub struct Event {
    pub name: &'static str,
    /// microseconds since the trace epoch
    pub ts_us: f64,
    pub dur_us: f64,
    /// free-form span arguments (shown in the Perfetto detail pane);
    /// the non-deterministic side of the trace lives here
    pub args: Vec<(&'static str, String)>,
}

/// Event-buffer cap: long-running daemons must not grow without bound.
/// Counters keep accumulating past the cap; only span *events* drop.
const MAX_EVENTS: usize = 4096;

#[derive(Debug)]
struct Inner {
    counters: [AtomicU64; NUM_COUNTERS],
    events: Mutex<Vec<Event>>,
    notes: Mutex<BTreeMap<&'static str, String>>,
    epoch: Instant,
}

/// The trace handle threaded through the planning pipeline. `Clone`
/// shares the sink; [`Trace::default`] / [`Trace::disabled`] is the
/// allocation-free no-op every hot path pays one branch for.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    inner: Option<Arc<Inner>>,
}

impl Trace {
    /// The no-op trace: every operation is one `Option` branch.
    pub fn disabled() -> Trace {
        Trace { inner: None }
    }

    /// A live trace with its epoch at construction time.
    pub fn enabled() -> Trace {
        Trace {
            inner: Some(Arc::new(Inner {
                counters: std::array::from_fn(|_| AtomicU64::new(0)),
                events: Mutex::new(Vec::new()),
                notes: Mutex::new(BTreeMap::new()),
                epoch: Instant::now(),
            })),
        }
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Add `n` to a counter. Counting sites accumulate locally and flush
    /// once per call where loops are hot; the disabled cost is the
    /// branch alone.
    #[inline]
    pub fn count(&self, c: Counter, n: u64) {
        if let Some(inner) = &self.inner {
            inner.counters[c as usize].fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Current value of one counter (0 on a disabled trace).
    pub fn counter(&self, c: Counter) -> u64 {
        match &self.inner {
            Some(inner) => inner.counters[c as usize].load(Ordering::Relaxed),
            None => 0,
        }
    }

    /// Every counter in [`Counter::ALL`] order, zeros included — the
    /// deterministic artifact `prop_trace_determinism` pins across
    /// thread counts.
    pub fn snapshot(&self) -> Vec<(&'static str, u64)> {
        Counter::ALL.iter().map(|&c| (c.name(), self.counter(c))).collect()
    }

    /// Record (or overwrite) a deterministic provenance note — e.g.
    /// which lane/engine decided the plan. Notes feed `cfp explain`,
    /// so writers must only record values that are pure functions of
    /// the planning inputs.
    pub fn note(&self, key: &'static str, value: impl Into<String>) {
        if let Some(inner) = &self.inner {
            inner.notes.lock().unwrap().insert(key, value.into());
        }
    }

    pub fn note_get(&self, key: &str) -> Option<String> {
        self.inner.as_ref().and_then(|i| i.notes.lock().unwrap().get(key).cloned())
    }

    /// Open a wall-clock span; the guard records one [`Event`] on drop.
    /// On a disabled trace the guard is inert.
    pub fn span(&self, name: &'static str) -> SpanGuard {
        SpanGuard {
            inner: self.inner.clone(),
            name,
            start: Instant::now(),
            args: Vec::new(),
        }
    }

    /// Fold this trace's counters into another (additive — the serve
    /// aggregator's shape).
    pub fn merge_counters_into(&self, other: &Trace) {
        for &c in Counter::ALL.iter() {
            let v = self.counter(c);
            if v > 0 {
                other.count(c, v);
            }
        }
    }

    /// Chrome trace-event JSON (the `{"traceEvents": […]}` object
    /// format Perfetto and `chrome://tracing` load): one `ph: "X"`
    /// complete event per recorded span, plus a final zero-duration
    /// `cfp.counters` event carrying the deterministic counter snapshot
    /// as its args.
    pub fn chrome_trace_json(&self) -> Json {
        let mut events: Vec<Json> = Vec::new();
        let mut last_end = 0.0f64;
        if let Some(inner) = &self.inner {
            for e in inner.events.lock().unwrap().iter() {
                let args: BTreeMap<String, Json> = e
                    .args
                    .iter()
                    .map(|(k, v)| (k.to_string(), Json::str(v.clone())))
                    .collect();
                events.push(Json::obj(vec![
                    ("name", Json::str(e.name)),
                    ("ph", Json::str("X")),
                    ("ts", Json::num(e.ts_us)),
                    ("dur", Json::num(e.dur_us)),
                    ("pid", Json::num(1.0)),
                    ("tid", Json::num(0.0)),
                    ("args", Json::Obj(args)),
                ]));
                last_end = last_end.max(e.ts_us + e.dur_us);
            }
            let notes: BTreeMap<String, Json> = inner
                .notes
                .lock()
                .unwrap()
                .iter()
                .map(|(k, v)| (k.to_string(), Json::str(v.clone())))
                .collect();
            if !notes.is_empty() {
                events.push(Json::obj(vec![
                    ("name", Json::str("cfp.notes")),
                    ("ph", Json::str("X")),
                    ("ts", Json::num(last_end)),
                    ("dur", Json::num(0.0)),
                    ("pid", Json::num(1.0)),
                    ("tid", Json::num(0.0)),
                    ("args", Json::Obj(notes)),
                ]));
            }
        }
        let counters: BTreeMap<String, Json> = self
            .snapshot()
            .into_iter()
            .map(|(k, v)| (k.to_string(), Json::num(v as f64)))
            .collect();
        events.push(Json::obj(vec![
            ("name", Json::str("cfp.counters")),
            ("ph", Json::str("X")),
            ("ts", Json::num(last_end)),
            ("dur", Json::num(0.0)),
            ("pid", Json::num(1.0)),
            ("tid", Json::num(0.0)),
            ("args", Json::Obj(counters)),
        ]));
        // armed fault schedules append their audit rows; disarmed runs
        // emit nothing here, keeping trace bytes identical to a build
        // without the fault layer
        if let Some(faults) = fault_counters_json() {
            events.push(Json::obj(vec![
                ("name", Json::str("cfp.faults")),
                ("ph", Json::str("X")),
                ("ts", Json::num(last_end)),
                ("dur", Json::num(0.0)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(0.0)),
                ("args", faults),
            ]));
        }
        Json::obj(vec![
            ("displayTimeUnit", Json::str("ms")),
            ("traceEvents", Json::Arr(events)),
        ])
    }

    /// Write the Chrome trace-event JSON to `path`.
    pub fn write_chrome(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.chrome_trace_json().to_string())
    }
}

/// RAII span handle from [`Trace::span`]; records its event on drop.
pub struct SpanGuard {
    inner: Option<Arc<Inner>>,
    name: &'static str,
    start: Instant,
    args: Vec<(&'static str, String)>,
}

impl SpanGuard {
    /// Attach a key/value argument shown in the trace viewer's detail
    /// pane. Args live on the event (wall-clock) side of the trace and
    /// may carry non-deterministic values (cache hits, wall times).
    pub fn arg(&mut self, key: &'static str, value: impl Into<String>) {
        if self.inner.is_some() {
            self.args.push((key, value.into()));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(inner) = self.inner.take() else { return };
        let dur_us = self.start.elapsed().as_secs_f64() * 1e6;
        let ts_us = self.start.duration_since(inner.epoch).as_secs_f64() * 1e6;
        let mut events = inner.events.lock().unwrap();
        if events.len() < MAX_EVENTS {
            events.push(Event {
                name: self.name,
                ts_us,
                dur_us,
                args: std::mem::take(&mut self.args),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_is_inert() {
        let t = Trace::disabled();
        assert!(!t.is_enabled());
        t.count(Counter::ScalarSteps, 7);
        t.note("lane", "scalar");
        {
            let mut s = t.span("phase");
            s.arg("k", "v");
        }
        assert_eq!(t.counter(Counter::ScalarSteps), 0);
        assert_eq!(t.note_get("lane"), None);
        assert!(t.snapshot().iter().all(|&(_, v)| v == 0));
        // even a disabled trace emits well-formed (counters-only) JSON
        let j = t.chrome_trace_json();
        assert_eq!(j.get("traceEvents").unwrap().as_arr().unwrap().len(), 1);
    }

    #[test]
    fn counters_accumulate_and_share_across_clones() {
        let t = Trace::enabled();
        let u = t.clone();
        t.count(Counter::ExactNodes, 3);
        u.count(Counter::ExactNodes, 4);
        assert_eq!(t.counter(Counter::ExactNodes), 7);
        let snap = t.snapshot();
        assert_eq!(snap.len(), NUM_COUNTERS);
        let (name, v) = snap[Counter::ExactNodes as usize];
        assert_eq!((name, v), ("exact_nodes", 7));
    }

    #[test]
    fn snapshot_order_is_the_all_order() {
        let t = Trace::enabled();
        let names: Vec<&str> = t.snapshot().iter().map(|&(n, _)| n).collect();
        let want: Vec<&str> = Counter::ALL.iter().map(|&c| c.name()).collect();
        assert_eq!(names, want);
        assert_eq!(names[0], "segment_instances");
        assert_eq!(names[NUM_COUNTERS - 1], "interop_mem_recovers");
    }

    #[test]
    fn spans_notes_and_counters_land_in_chrome_json() {
        let t = Trace::enabled();
        t.count(Counter::SweepOrigins, 2);
        t.note("engine", "dp");
        {
            let mut s = t.span("compose_search");
            s.arg("spanned", "yes");
        }
        let j = t.chrome_trace_json();
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        // span + notes + counters
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].get("name").unwrap().as_str(), Some("compose_search"));
        assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("X"));
        assert!(evs[0].get("dur").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(
            evs[1].get("args").unwrap().get("engine").unwrap().as_str(),
            Some("dp")
        );
        assert_eq!(
            evs[2].get("args").unwrap().get("sweep_origins").unwrap().as_u64(),
            Some(2)
        );
        // the emitted text round-trips through the parser
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn merge_counters_is_additive() {
        let a = Trace::enabled();
        let b = Trace::enabled();
        a.count(Counter::ParetoStates, 5);
        b.count(Counter::ParetoStates, 2);
        a.merge_counters_into(&b);
        assert_eq!(b.counter(Counter::ParetoStates), 7);
        assert_eq!(a.counter(Counter::ParetoStates), 5, "source unchanged");
    }

    #[test]
    fn event_buffer_is_capped_but_counters_keep_counting() {
        let t = Trace::enabled();
        for _ in 0..(MAX_EVENTS + 10) {
            t.count(Counter::ScalarSteps, 1);
            let _ = t.span("tick");
        }
        let evs = t.chrome_trace_json();
        let n = evs.get("traceEvents").unwrap().as_arr().unwrap().len();
        assert!(n <= MAX_EVENTS + 1, "events must stay bounded, got {n}");
        assert_eq!(t.counter(Counter::ScalarSteps), (MAX_EVENTS + 10) as u64);
    }
}
