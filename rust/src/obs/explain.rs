//! `cfp explain` — per-segment plan provenance.
//!
//! Renders, for a finished run, *why* the plan looks the way it does:
//! the winning config per segment with its cost split
//! (compute / collective / reshard / remat penalty), the runner-up
//! config and its margin, which lane and engine decided the plan, and
//! the headline search-reduction counters (states actually explored vs
//! the naive enumeration bound of the config space).
//!
//! Every value in the rendered text is deterministic: plan numbers,
//! profile-table entries, [`crate::obs::Trace`] counters and notes —
//! never wall-clock. The output is therefore bit-identical across
//! thread counts, cache states and serve-vs-CLI, which
//! `prop_trace_determinism` and the CI explain step pin.

use std::fmt::Write as _;

use crate::coordinator::{CfpOptions, CfpResult, TwoLevelResult};
use crate::cost;
use crate::spdag;

use super::Counter;

/// Render the provenance report for a single-level run. `opts` must be
/// the options the run was made with — its trace carries the counters
/// and the lane/engine notes the report quotes.
pub fn render_explain(r: &CfpResult, opts: &CfpOptions) -> String {
    let mut out = String::new();
    let n = r.segments.instances.len();
    let trace = &opts.trace;
    let note = |k: &str| trace.note_get(k).unwrap_or_else(|| "-".to_string());

    let _ = writeln!(out, "cfp explain — plan provenance");
    let _ = writeln!(out, "=============================");
    let _ = writeln!(
        out,
        "model: {} (layers {}, batch {})",
        opts.model.name, opts.model.layers, opts.model.batch
    );
    let _ = writeln!(
        out,
        "platform: {} ({} devices, mesh {}x{})",
        opts.platform.name,
        opts.mesh.total(),
        opts.mesh.intra,
        opts.mesh.nodes
    );
    let _ = writeln!(out, "topology: {}", r.topo.signature());
    let _ = writeln!(out, "engine: {} (path: {})", opts.engine.as_str(), note("engine_path"));
    let _ = writeln!(out, "lane: {}", note("lane"));
    let _ = writeln!(
        out,
        "plan: step {:.3} µs, mem {} bytes over {n} segments",
        r.plan.time_us, r.plan.mem_bytes
    );
    let _ = writeln!(out);

    // search-reduction headline: DP/B&B states actually visited vs the
    // naive enumeration bound of the joint config space
    let sctx = cost::SearchCtx::new(&r.segments, &r.db);
    let bits = cost::space_bits(&sctx, 0, n);
    let explored: u64 =
        [Counter::ScalarSteps, Counter::ParetoStates, Counter::MemStates, Counter::ExactNodes]
            .iter()
            .map(|&c| trace.counter(c))
            .sum();
    let _ = writeln!(out, "search reduction");
    let _ = writeln!(out, "----------------");
    let _ = writeln!(out, "naive enumeration bound: 2^{bits:.1} assignments");
    let _ = writeln!(out, "profiled program space: {}", r.db.profile_space());
    let _ = writeln!(out, "states explored (dp + exact): {explored}");
    for (name, v) in trace.snapshot() {
        let _ = writeln!(out, "  {name} = {v}");
    }
    let _ = writeln!(out);

    let _ = writeln!(out, "per-segment provenance");
    let _ = writeln!(out, "----------------------");
    let chain = r.topo.is_chain();
    let sp = (!chain).then(|| spdag::SpCtx::new(&sctx, &r.topo, &r.db));
    let labels = r.describe_plan();
    let mut reshard_total = 0.0f64;
    for i in 0..n {
        let uid = r.segments.instances[i].unique_id;
        let c = r.plan.choice[i];
        let prof = &r.db.segments[uid];
        let _ = writeln!(out, "{}", labels[i]);
        let _ = writeln!(
            out,
            "  winner: cfg {c} of {}  compute {:.3} µs  collective {:.3} µs",
            prof.configs.len(),
            prof.t_p_us[c],
            prof.t_c_us[c]
        );
        if chain {
            let resh = if i == 0 {
                0.0
            } else {
                let pu = r.segments.instances[i - 1].unique_id;
                r.db.reshard_us(pu, r.plan.choice[i - 1], uid, c)
            };
            reshard_total += resh;
            let _ = writeln!(out, "  reshard-in: {resh:.3} µs  remat penalty: 0.000 µs (off)");
        }
        // runner-up: best whole-plan cost with this one segment flipped
        // to another config (pricing the decision margin — the memory
        // cap is deliberately not re-checked). Lowest config index wins
        // ties, so the line is deterministic.
        let mut best: Option<(usize, f64)> = None;
        for alt in 0..prof.configs.len() {
            if alt == c {
                continue;
            }
            let mut choice = r.plan.choice.clone();
            choice[i] = alt;
            let (t, _) = match &sp {
                Some(sp) => spdag::sp_plan_cost_span(&sctx, sp, &choice, 0, n),
                None => cost::plan_cost_span(&r.segments, &r.db, &choice, 0, n),
            };
            if best.map_or(true, |(_, bt)| t < bt) {
                best = Some((alt, t));
            }
        }
        match best {
            Some((alt, t)) => {
                let delta = t - r.plan.time_us;
                let _ = writeln!(out, "  runner-up: cfg {alt}  {delta:+.3} µs vs the winner");
            }
            None => {
                let _ = writeln!(out, "  runner-up: (no alternative config)");
            }
        }
    }
    if chain {
        let _ = writeln!(out, "reshard total: {reshard_total:.3} µs");
    } else {
        // DAG plans price boundary rework inside the closed form (branch
        // junctions included); report the aggregate residual instead of
        // inventing a per-segment attribution the lane never computed
        let seg_sum: f64 = (0..n)
            .map(|i| {
                let p = &r.db.segments[r.segments.instances[i].unique_id];
                p.t_p_us[r.plan.choice[i]] + p.t_c_us[r.plan.choice[i]]
            })
            .sum();
        let _ = writeln!(
            out,
            "reshard+junction residual: {:.3} µs (plan time − Σ segment kernels)",
            r.plan.time_us - seg_sum
        );
    }
    out
}

/// Render the provenance report for a two-level (pipeline) run: the
/// single-stage report plus per-stage summaries. Deliberately excludes
/// wall-clock fields (`search_us`) and the cache hit/miss *split* —
/// only their cache-state-invariant sum — so the text stays
/// bit-identical across warm and cold caches.
pub fn render_explain_pipeline(r: &TwoLevelResult, opts: &CfpOptions) -> String {
    let mut out = render_explain(&r.single, opts);
    let _ = writeln!(out);
    let _ = writeln!(out, "pipeline provenance");
    let _ = writeln!(out, "-------------------");
    let _ = writeln!(
        out,
        "profiled unique segments (all contexts): {}",
        r.profile_hits + r.profile_misses
    );
    match &r.pipeline {
        None => {
            let _ = writeln!(out, "no feasible pipeline under the memory cap");
        }
        Some(p) => {
            let _ = writeln!(
                out,
                "stages: {} × {} devices  microbatches {}  step {:.3} µs  bubble {:.3}",
                p.num_stages(),
                p.devices_per_stage,
                p.microbatches,
                p.step_time_us,
                p.bubble_fraction
            );
            for (s, st) in p.stages.iter().enumerate() {
                let _ = writeln!(
                    out,
                    "stage {s}: span [{}, {})  intra-op {:.3} µs  p2p-in {:.3} µs  \
                     latency {:.3} µs  remat penalty {:.3} µs ({}/{} segments)  \
                     peak {} bytes",
                    st.span.0,
                    st.span.1,
                    st.plan.time_us,
                    st.p2p_in_us,
                    st.latency_us,
                    st.footprint.recompute_us,
                    st.remat.iter().filter(|&&x| x).count(),
                    st.remat.len(),
                    st.peak_mem_bytes
                );
            }
            if let Some(nv) = &r.naive {
                let _ = writeln!(
                    out,
                    "naive equal-split baseline: {:.3} µs ({:.2}× the cfp plan)",
                    nv.step_time_us,
                    nv.step_time_us / p.step_time_us
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Platform;
    use crate::coordinator::{run_cfp, run_cfp_two_level};
    use crate::interop::StageSpec;
    use crate::models::ModelCfg;
    use crate::obs::Trace;

    fn opts(model: &str) -> CfpOptions {
        CfpOptions::new(ModelCfg::preset(model).with_layers(2), Platform::a100_pcie(4))
            .with_trace(Trace::enabled())
    }

    #[test]
    fn explain_carries_the_mandatory_provenance_fields() {
        let opts = opts("gpt-tiny");
        let r = run_cfp(&opts);
        let text = render_explain(&r, &opts);
        for field in
            ["winner", "runner-up", "compute", "collective", "reshard", "lane", "engine", "states"]
        {
            assert!(text.contains(field), "explain is missing {field:?}:\n{text}");
        }
        assert!(text.contains("lane: capped-pareto") || text.contains("lane: unconstrained"));
    }

    #[test]
    fn explain_handles_dag_models() {
        let opts = opts("moe-ep-tiny");
        let r = run_cfp(&opts);
        assert!(!r.topo.is_chain());
        let text = render_explain(&r, &opts);
        assert!(text.contains("topology: sp-dag"));
        assert!(text.contains("reshard+junction residual"));
    }

    #[test]
    fn pipeline_explain_appends_stage_provenance() {
        let opts = opts("gpt-tiny").with_stages(StageSpec::Auto);
        let r = run_cfp_two_level(&opts);
        let text = render_explain_pipeline(&r, &opts);
        assert!(text.contains("pipeline provenance"));
        assert!(text.contains("stage 0:"));
        assert!(text.contains("remat penalty"));
    }
}
