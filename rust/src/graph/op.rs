//! Operator vocabulary of the fine-grained computation graph.
//!
//! Mirrors the XLA-HLO level the paper works at (§2.1: "fine-grained
//! primitives in the compiler IR"): elementwise ops, general dot
//! contractions, reshape/transpose/broadcast/reduce data movement, RNG,
//! gather/scatter for embeddings. Model builders decompose layernorm /
//! softmax / dropout into these primitives, so two transformer layers
//! really do produce on the order of a thousand ops (paper §2.3).

/// Element dtype. Only what the models need.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    Bf16,
    I32,
    Pred,
}

impl DType {
    pub fn bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::Bf16 => 2,
            DType::Pred => 1,
        }
    }
}

/// Elementwise operator kinds (unary / binary / ternary select).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ElemOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Neg,
    Exp,
    Log,
    Tanh,
    Gelu,
    Silu,
    Rsqrt,
    /// d/dx gelu(x) given (x, g) — emitted by autodiff, fused in real XLA.
    GeluGrad,
    SiluGrad,
    /// x * c
    Scale(f64),
    /// x + c
    Offset(f64),
    CmpGe,
    CmpEq,
    /// select(pred, a, b)
    Select,
}

impl ElemOp {
    pub fn arity(self) -> usize {
        match self {
            ElemOp::Neg
            | ElemOp::Exp
            | ElemOp::Log
            | ElemOp::Tanh
            | ElemOp::Gelu
            | ElemOp::Silu
            | ElemOp::Rsqrt
            | ElemOp::Scale(_)
            | ElemOp::Offset(_) => 1,
            ElemOp::Select => 3,
            _ => 2,
        }
    }
}

/// Dot dimension numbers in *normal form*: shared leading batch dims,
/// lhs = (batch.., M, K), rhs = (batch.., K, N) → out (batch.., M, N).
/// Model builders insert explicit Transpose/Reshape to reach this form
/// (as XLA's dot canonicalization does), which keeps autodiff and the
/// partition-propagation rules exact.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct DotDims {
    pub batch: usize, // number of leading batch dims
}

/// What a Parameter op holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParamClass {
    /// Trainable weight — gets a gradient + optimizer update + DP sync.
    Weight,
    /// Per-step input (tokens, targets) — batch-dim shardable.
    Input,
}

/// Reduction kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ReduceKind {
    Sum,
    Max,
}

/// Which phase of the training step an op belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Fwd,
    Bwd,
    Opt,
}

#[derive(Clone, Debug, PartialEq)]
pub enum OpKind {
    Param {
        class: ParamClass,
    },
    Constant {
        value: f64,
    },
    /// Random uniform [0,1) — the §2.2 dropout story: XLA restricts RNG to
    /// one device, forcing a replication collective under TP configs.
    Rng,
    Elem(ElemOp),
    Dot(DotDims),
    Reshape,
    Transpose {
        perm: Vec<usize>,
    },
    /// `dims[i]` = output dim that input dim i maps to (strictly increasing).
    Broadcast {
        dims: Vec<usize>,
    },
    Reduce {
        dims: Vec<usize>,
        kind: ReduceKind,
    },
    /// inputs: [table (V, H..), indices (..)] → out indices.shape ++ table.shape[1:]
    Gather,
    /// grad of Gather: inputs [indices, updates] → table-shaped output
    Scatter {
        table_shape: Vec<usize>,
    },
    /// Token routing (GShard dispatch/combine): a data-dependent
    /// permutation regrouping (T, H) ⇄ (E, C, H) with C = T/E. Sharded
    /// token/expert dims can only cross a Route via All-to-All.
    Route,
    /// Pick index `index` along `dim` and drop the dim (q/k/v split of a
    /// fused QKV projection).
    Slice {
        dim: usize,
        index: usize,
    },
    /// grad of Slice: place the input at `index` along a new dim of `size`
    /// (zero elsewhere).
    Pad {
        dim: usize,
        index: usize,
        size: usize,
    },
}

impl OpKind {
    /// Tensor-contraction operators seed ParallelBlocks (paper §3.1).
    pub fn is_contraction(&self) -> bool {
        matches!(self, OpKind::Dot(_))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.bytes(), 4);
        assert_eq!(DType::Bf16.bytes(), 2);
        assert_eq!(DType::Pred.bytes(), 1);
    }

    #[test]
    fn elem_arities() {
        assert_eq!(ElemOp::Add.arity(), 2);
        assert_eq!(ElemOp::Exp.arity(), 1);
        assert_eq!(ElemOp::Select.arity(), 3);
        assert_eq!(ElemOp::Scale(2.0).arity(), 1);
    }

    #[test]
    fn contraction_flag() {
        assert!(OpKind::Dot(DotDims { batch: 0 }).is_contraction());
        assert!(!OpKind::Reshape.is_contraction());
    }
}
