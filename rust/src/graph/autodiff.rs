//! Reverse-mode autodiff over the graph IR.
//!
//! Mechanically appends backward + optimizer-update ops to a forward graph,
//! mirroring what jax.grad → XLA produces. This is what makes the analyzed
//! graphs *training* graphs: the paper's ParallelBlocks must absorb backward
//! operators (§3.2 "we group backward operators into the same ParallelBlocks
//! as their corresponding forward operators"), and DP's gradient-AllReduce /
//! fusion behaviour (§2.2) only exists because the param gradients do.

use std::collections::HashMap;

use super::build::{Graph, OpId};
use super::op::{ElemOp, OpKind, ReduceKind, Role};

/// Result of appending a backward pass.
pub struct Backward {
    /// weight param id → final grad op id
    pub param_grads: HashMap<OpId, OpId>,
    /// weight param id → updated-param op id
    pub updates: HashMap<OpId, OpId>,
}

/// Append d(loss)/d(*) ops for every op feeding `loss`, then SGD updates
/// for every Weight param. `loss` must be scalar.
pub fn append_backward(g: &mut Graph, loss: OpId, lr: f64) -> Backward {
    assert!(g.shape(loss).is_empty(), "loss must be scalar");
    let fwd_len = g.ops.len();
    g.set_role(Role::Bwd);

    // tensor id → accumulated grad id
    let mut grads: HashMap<OpId, OpId> = HashMap::new();
    let seed = g.constant(1.0, vec![]);
    grads.insert(loss, seed);

    for id in (0..fwd_len).rev() {
        let Some(&gout) = grads.get(&id) else { continue };
        let op = g.ops[id].clone();
        let contribs: Vec<(OpId, OpId)> = match &op.kind {
            OpKind::Param { .. } | OpKind::Constant { .. } | OpKind::Rng => vec![],
            OpKind::Elem(e) => elem_vjp(g, &op, *e, gout),
            OpKind::Dot(dims) => {
                let (lhs, rhs) = (op.inputs[0], op.inputs[1]);
                let b = dims.batch;
                let rank = g.shape(lhs).len();
                let mut perm: Vec<usize> = (0..rank).collect();
                perm.swap(rank - 1, rank - 2);
                let rhs_t = g.transpose(rhs, perm.clone(), &format!("{}/rhs_t", op.name));
                let dlhs = g.dot(gout, rhs_t, b, &format!("{}/dlhs", op.name));
                let lhs_t = g.transpose(lhs, perm, &format!("{}/lhs_t", op.name));
                let drhs = g.dot(lhs_t, gout, b, &format!("{}/drhs", op.name));
                vec![(lhs, dlhs), (rhs, drhs)]
            }
            OpKind::Reshape => {
                let x = op.inputs[0];
                let shape = g.shape(x).to_vec();
                let gx = g.reshape(gout, shape, &format!("{}/dx", op.name));
                vec![(x, gx)]
            }
            OpKind::Transpose { perm } => {
                let x = op.inputs[0];
                let mut inv = vec![0; perm.len()];
                for (i, &p) in perm.iter().enumerate() {
                    inv[p] = i;
                }
                let gx = g.transpose(gout, inv, &format!("{}/dx", op.name));
                vec![(x, gx)]
            }
            OpKind::Broadcast { dims } => {
                let x = op.inputs[0];
                let reduce_dims: Vec<usize> =
                    (0..op.shape.len()).filter(|d| !dims.contains(d)).collect();
                let gx = if reduce_dims.is_empty() {
                    gout
                } else {
                    g.reduce(gout, reduce_dims, ReduceKind::Sum, &format!("{}/dx", op.name))
                };
                vec![(x, gx)]
            }
            OpKind::Reduce { dims, kind } => {
                let x = op.inputs[0];
                let xshape = g.shape(x).to_vec();
                let kept: Vec<usize> =
                    (0..xshape.len()).filter(|d| !dims.contains(d)).collect();
                match kind {
                    ReduceKind::Sum => {
                        let gx = g.broadcast(gout, kept, xshape, &format!("{}/dx", op.name));
                        vec![(x, gx)]
                    }
                    ReduceKind::Max => {
                        let name = &op.name;
                        let yb =
                            g.broadcast(id, kept.clone(), xshape.clone(), &format!("{name}/y_b"));
                        let mask = g.binary(ElemOp::CmpEq, x, yb, &format!("{name}/mask"));
                        let gb = g.broadcast(gout, kept, xshape.clone(), &format!("{name}/g_b"));
                        let zero = g.constant(0.0, vec![]);
                        let zb = g.broadcast(zero, vec![], xshape, &format!("{name}/zero_b"));
                        let gx =
                            g.elem(ElemOp::Select, vec![mask, gb, zb], &format!("{name}/dx"));
                        vec![(x, gx)]
                    }
                }
            }
            OpKind::Gather => {
                let (table, idx) = (op.inputs[0], op.inputs[1]);
                let tshape = g.shape(table).to_vec();
                let gt = g.scatter(idx, gout, tshape, &format!("{}/dtable", op.name));
                vec![(table, gt)]
            }
            OpKind::Route => {
                let x = op.inputs[0];
                let shape = g.shape(x).to_vec();
                let gx = g.route(gout, shape, &format!("{}/dx", op.name));
                vec![(x, gx)]
            }
            OpKind::Slice { dim, index } => {
                let x = op.inputs[0];
                let size = g.shape(x)[*dim];
                let gx = g.pad(gout, *dim, *index, size, &format!("{}/dx", op.name));
                vec![(x, gx)]
            }
            OpKind::Pad { dim, index, .. } => {
                let x = op.inputs[0];
                let gx = g.slice(gout, *dim, *index, &format!("{}/dx", op.name));
                vec![(x, gx)]
            }
            OpKind::Scatter { .. } => vec![], // only produced by autodiff itself
        };
        // tag the new ops with their forward origin
        for o in g.ops.iter_mut().skip(fwd_len) {
            if o.grad_of.is_none() && o.role == Role::Bwd {
                o.grad_of = Some(id);
            }
        }
        for (tensor, contrib) in contribs {
            accumulate(g, &mut grads, tensor, contrib);
        }
    }

    // Final param grads + SGD updates.
    let mut param_grads = HashMap::new();
    let mut updates = HashMap::new();
    let params = g.params();
    g.set_role(Role::Opt);
    for p in params {
        let Some(&gp) = grads.get(&p) else { continue };
        g.ops[gp].param_grad_for = Some(p);
        param_grads.insert(p, gp);
        let name = g.ops[p].name.clone();
        let step = g.unary(ElemOp::Scale(lr), gp, &format!("opt/{name}/step"));
        let newp = g.binary(ElemOp::Sub, p, step, &format!("opt/{name}/update"));
        g.outputs.push(newp);
        updates.insert(p, newp);
    }
    g.set_role(Role::Fwd);
    Backward { param_grads, updates }
}

fn accumulate(g: &mut Graph, grads: &mut HashMap<OpId, OpId>, tensor: OpId, contrib: OpId) {
    match grads.get(&tensor) {
        None => {
            grads.insert(tensor, contrib);
        }
        Some(&prev) => {
            let name = g.ops[tensor].name.clone();
            let sum = g.binary(ElemOp::Add, prev, contrib, &format!("{name}/gacc"));
            grads.insert(tensor, sum);
        }
    }
}

fn elem_vjp(g: &mut Graph, op: &super::build::Op, e: ElemOp, gout: OpId) -> Vec<(OpId, OpId)> {
    let n = &op.name;
    let y = op.id;
    match e {
        ElemOp::Add => vec![(op.inputs[0], gout), (op.inputs[1], gout)],
        ElemOp::Sub => {
            let gb = g.unary(ElemOp::Neg, gout, &format!("{n}/db"));
            vec![(op.inputs[0], gout), (op.inputs[1], gb)]
        }
        ElemOp::Mul => {
            let (a, b) = (op.inputs[0], op.inputs[1]);
            let da = g.binary(ElemOp::Mul, gout, b, &format!("{n}/da"));
            let db = g.binary(ElemOp::Mul, gout, a, &format!("{n}/db"));
            vec![(a, da), (b, db)]
        }
        ElemOp::Div => {
            let (a, b) = (op.inputs[0], op.inputs[1]);
            let da = g.binary(ElemOp::Div, gout, b, &format!("{n}/da"));
            let gy = g.binary(ElemOp::Mul, gout, y, &format!("{n}/gy"));
            let gyb = g.binary(ElemOp::Div, gy, b, &format!("{n}/gyb"));
            let db = g.unary(ElemOp::Neg, gyb, &format!("{n}/db"));
            vec![(a, da), (b, db)]
        }
        ElemOp::Max => {
            let (a, b) = (op.inputs[0], op.inputs[1]);
            let mask = g.binary(ElemOp::CmpGe, a, b, &format!("{n}/mask"));
            let zero = g.constant(0.0, vec![]);
            let zb = g.broadcast(zero, vec![], op.shape.clone(), &format!("{n}/zero_b"));
            let da = g.elem(ElemOp::Select, vec![mask, gout, zb], &format!("{n}/da"));
            let db = g.elem(ElemOp::Select, vec![mask, zb, gout], &format!("{n}/db"));
            vec![(a, da), (b, db)]
        }
        ElemOp::Neg => {
            let da = g.unary(ElemOp::Neg, gout, &format!("{n}/da"));
            vec![(op.inputs[0], da)]
        }
        ElemOp::Exp => {
            let da = g.binary(ElemOp::Mul, gout, y, &format!("{n}/da"));
            vec![(op.inputs[0], da)]
        }
        ElemOp::Log => {
            let da = g.binary(ElemOp::Div, gout, op.inputs[0], &format!("{n}/da"));
            vec![(op.inputs[0], da)]
        }
        ElemOp::Tanh => {
            let y2 = g.binary(ElemOp::Mul, y, y, &format!("{n}/y2"));
            let one = g.constant(1.0, vec![]);
            let ob = g.broadcast(one, vec![], op.shape.clone(), &format!("{n}/one_b"));
            let omy2 = g.binary(ElemOp::Sub, ob, y2, &format!("{n}/omy2"));
            let da = g.binary(ElemOp::Mul, gout, omy2, &format!("{n}/da"));
            vec![(op.inputs[0], da)]
        }
        ElemOp::Gelu => {
            let da = g.binary(ElemOp::GeluGrad, op.inputs[0], gout, &format!("{n}/da"));
            vec![(op.inputs[0], da)]
        }
        ElemOp::Silu => {
            let da = g.binary(ElemOp::SiluGrad, op.inputs[0], gout, &format!("{n}/da"));
            vec![(op.inputs[0], da)]
        }
        ElemOp::Rsqrt => {
            let y2 = g.binary(ElemOp::Mul, y, y, &format!("{n}/y2"));
            let y3 = g.binary(ElemOp::Mul, y2, y, &format!("{n}/y3"));
            let t = g.binary(ElemOp::Mul, gout, y3, &format!("{n}/t"));
            let da = g.unary(ElemOp::Scale(-0.5), t, &format!("{n}/da"));
            vec![(op.inputs[0], da)]
        }
        ElemOp::Scale(c) => {
            let da = g.unary(ElemOp::Scale(c), gout, &format!("{n}/da"));
            vec![(op.inputs[0], da)]
        }
        ElemOp::Offset(_) => vec![(op.inputs[0], gout)],
        ElemOp::GeluGrad | ElemOp::SiluGrad => vec![], // 2nd order not needed
        ElemOp::CmpGe | ElemOp::CmpEq => vec![],
        ElemOp::Select => {
            let (pred, a, b) = (op.inputs[0], op.inputs[1], op.inputs[2]);
            let zero = g.constant(0.0, vec![]);
            let zb = g.broadcast(zero, vec![], op.shape.clone(), &format!("{n}/zero_b"));
            let da = g.elem(ElemOp::Select, vec![pred, gout, zb], &format!("{n}/da"));
            let db = g.elem(ElemOp::Select, vec![pred, zb, gout], &format!("{n}/db"));
            vec![(a, da), (b, db)]
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::op::ParamClass;

    /// loss = sum((x·w)²) — check the bwd graph exists and is marked.
    #[test]
    fn backward_of_matmul_chain() {
        let mut g = Graph::new();
        let x = g.param("x", vec![2, 3], ParamClass::Input);
        let w = g.param("w", vec![3, 4], ParamClass::Weight);
        let y = g.matmul(x, w, "y");
        let sq = g.binary(ElemOp::Mul, y, y, "sq");
        let loss = g.reduce(sq, vec![0, 1], ReduceKind::Sum, "loss");
        let bw = append_backward(&mut g, loss, 0.1);
        let gw = bw.param_grads[&w];
        assert_eq!(g.shape(gw), &[3, 4], "grad shape == param shape");
        assert_eq!(g.ops[gw].param_grad_for, Some(w));
        let up = bw.updates[&w];
        assert_eq!(g.shape(up), &[3, 4]);
        assert_eq!(g.ops[up].role, Role::Opt);
        // bwd ops carry their fwd origin
        assert!(g.ops.iter().any(|o| o.role == Role::Bwd && o.grad_of.is_some()));
    }

    #[test]
    fn grad_accumulates_over_multiple_uses() {
        // loss = sum(w ⊙ w_used_twice): y = w + w → grads add
        let mut g = Graph::new();
        let w = g.param("w", vec![4], ParamClass::Weight);
        let y = g.binary(ElemOp::Add, w, w, "y");
        let loss = g.reduce(y, vec![0], ReduceKind::Sum, "loss");
        let bw = append_backward(&mut g, loss, 0.1);
        let gw = bw.param_grads[&w];
        // accumulated grad is an Add of two broadcast-of-1 contributions
        assert!(matches!(g.ops[gw].kind, OpKind::Elem(ElemOp::Add)));
    }

    #[test]
    fn softmax_backward_builds() {
        let mut g = Graph::new();
        let x = g.param("x", vec![2, 8], ParamClass::Weight);
        let sm = g.softmax(x, "sm");
        let loss = g.reduce(sm, vec![0, 1], ReduceKind::Sum, "loss");
        let bw = append_backward(&mut g, loss, 0.1);
        assert!(bw.param_grads.contains_key(&x));
        assert_eq!(g.shape(bw.param_grads[&x]), &[2, 8]);
    }

    #[test]
    fn gather_grad_is_scatter() {
        let mut g = Graph::new();
        let table = g.param("emb", vec![16, 8], ParamClass::Weight);
        let idx = g.param("tokens", vec![4], ParamClass::Input);
        let e = g.gather(table, idx, "lookup");
        let loss = g.reduce(e, vec![0, 1], ReduceKind::Sum, "loss");
        let bw = append_backward(&mut g, loss, 0.1);
        let gt = bw.param_grads[&table];
        assert!(matches!(g.ops[gt].kind, OpKind::Scatter { .. }));
        assert_eq!(g.shape(gt), &[16, 8]);
    }

    #[test]
    fn bmm_grads_have_right_shapes() {
        let mut g = Graph::new();
        let a = g.param("a", vec![2, 4, 3, 5], ParamClass::Weight);
        let b = g.param("b", vec![2, 4, 5, 6], ParamClass::Weight);
        let y = g.dot(a, b, 2, "bmm");
        let loss = g.reduce(y, vec![0, 1, 2, 3], ReduceKind::Sum, "loss");
        let bw = append_backward(&mut g, loss, 0.1);
        assert_eq!(g.shape(bw.param_grads[&a]), &[2, 4, 3, 5]);
        assert_eq!(g.shape(bw.param_grads[&b]), &[2, 4, 5, 6]);
    }

    #[test]
    fn rng_and_dropout_get_no_grad() {
        let mut g = Graph::new();
        let x = g.param("x", vec![4, 4], ParamClass::Weight);
        let d = g.dropout(x, 0.1, "do");
        let loss = g.reduce(d, vec![0, 1], ReduceKind::Sum, "loss");
        let bw = append_backward(&mut g, loss, 0.1);
        assert!(bw.param_grads.contains_key(&x));
        // no grads flowed into the Rng op
        let rng_id = g.ops.iter().find(|o| matches!(o.kind, OpKind::Rng)).unwrap().id;
        assert!(g.ops.iter().all(|o| o.param_grad_for != Some(rng_id)));
    }
}
