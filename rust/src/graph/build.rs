//! Graph container + shape-checked builder methods.
//!
//! Ops are appended in topological order (builder discipline), so op id
//! order *is* a valid schedule; `users()` gives the reverse adjacency the
//! ParallelBlock DFS (Algorithm 1) traverses.

use super::op::{DType, DotDims, ElemOp, OpKind, ParamClass, ReduceKind, Role};

pub type OpId = usize;

#[derive(Clone, Debug)]
pub struct Op {
    pub id: OpId,
    pub kind: OpKind,
    pub inputs: Vec<OpId>,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub name: String,
    pub role: Role,
    /// For Bwd ops: the forward op this gradient belongs to (paper §3.2:
    /// backward ops join their forward op's ParallelBlock).
    pub grad_of: Option<OpId>,
    /// Set on the final gradient of a Weight param (the DP sync point).
    pub param_grad_for: Option<OpId>,
}

impl Op {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.numel() * self.dtype.bytes()
    }

    /// FLOPs attributed to this op (0 for pure data movement).
    pub fn flops(&self, graph: &Graph) -> u64 {
        match &self.kind {
            OpKind::Dot(_) => {
                let k = *graph.ops[self.inputs[0]].shape.last().unwrap();
                2 * self.numel() as u64 * k as u64
            }
            OpKind::Elem(e) => {
                let unit = match e {
                    ElemOp::Exp | ElemOp::Log | ElemOp::Tanh | ElemOp::Gelu | ElemOp::Silu => 8,
                    ElemOp::GeluGrad | ElemOp::SiluGrad => 12,
                    ElemOp::Rsqrt => 4,
                    _ => 1,
                };
                self.numel() as u64 * unit
            }
            OpKind::Reduce { .. } => graph.ops[self.inputs[0]].numel() as u64,
            OpKind::Rng => self.numel() as u64 * 4,
            _ => 0,
        }
    }
}

#[derive(Clone, Debug)]
pub struct Graph {
    pub ops: Vec<Op>,
    pub outputs: Vec<OpId>,
    /// Current layer label applied to newly built ops (builder context;
    /// used only for debugging/validation — segmentation derives its own).
    layer_ctx: Option<usize>,
    role_ctx: Role,
    pub layer_of: Vec<Option<usize>>,
    /// Parallel-branch metadata recorded by builders (empty on every chain
    /// model): one entry per fork/join group, each branch a half-open
    /// forward op-id range `[start, end)`. Ops inside a branch range depend
    /// only on pre-fork ops and other ops of the same branch, so the
    /// branches are mutually independent — `segment::extract_with_topology`
    /// turns each range into its own segment instance of an SP-DAG.
    pub branch_groups: Vec<Vec<(OpId, OpId)>>,
}

impl Graph {
    pub fn new() -> Self {
        Graph {
            ops: Vec::new(),
            outputs: Vec::new(),
            layer_ctx: None,
            role_ctx: Role::Fwd,
            layer_of: Vec::new(),
            branch_groups: Vec::new(),
        }
    }

    /// Record one fork/join group of mutually independent branch op
    /// ranges. Ranges must be non-empty, disjoint, and in ascending op
    /// order (the builder emits branches one after another).
    pub fn record_branch_group(&mut self, branches: Vec<(OpId, OpId)>) {
        assert!(branches.len() >= 2, "a branch group needs ≥ 2 branches");
        for w in branches.windows(2) {
            assert!(w[0].1 <= w[1].0, "branch ranges must be disjoint and ascending");
        }
        for &(s, e) in &branches {
            assert!(s < e && e <= self.ops.len(), "empty or out-of-range branch");
        }
        self.branch_groups.push(branches);
    }

    pub fn set_layer(&mut self, layer: Option<usize>) {
        self.layer_ctx = layer;
    }

    pub fn set_role(&mut self, role: Role) {
        self.role_ctx = role;
    }

    pub fn shape(&self, id: OpId) -> &[usize] {
        &self.ops[id].shape
    }

    pub fn add(
        &mut self,
        kind: OpKind,
        inputs: Vec<OpId>,
        shape: Vec<usize>,
        dtype: DType,
        name: impl Into<String>,
    ) -> OpId {
        let id = self.ops.len();
        for &i in &inputs {
            assert!(i < id, "input {i} of op {id} not yet defined");
        }
        self.ops.push(Op {
            id,
            kind,
            inputs,
            shape,
            dtype,
            name: name.into(),
            role: self.role_ctx,
            grad_of: None,
            param_grad_for: None,
        });
        self.layer_of.push(self.layer_ctx);
        id
    }

    // ------------------------------------------------------------ builders

    pub fn param(&mut self, name: &str, shape: Vec<usize>, class: ParamClass) -> OpId {
        let dtype = if class == ParamClass::Input && name.contains("tokens") {
            DType::I32
        } else {
            DType::F32
        };
        self.add(OpKind::Param { class }, vec![], shape, dtype, name)
    }

    pub fn constant(&mut self, value: f64, shape: Vec<usize>) -> OpId {
        self.add(OpKind::Constant { value }, vec![], shape, DType::F32, format!("const_{value}"))
    }

    pub fn rng(&mut self, shape: Vec<usize>, name: &str) -> OpId {
        self.add(OpKind::Rng, vec![], shape, DType::F32, name)
    }

    pub fn elem(&mut self, op: ElemOp, inputs: Vec<OpId>, name: &str) -> OpId {
        assert_eq!(inputs.len(), op.arity(), "{op:?} arity");
        let shape = self.ops[inputs[0]].shape.clone();
        let ref_shape = if op == ElemOp::Select { 1 } else { 0 };
        for &i in &inputs[ref_shape..] {
            assert_eq!(
                self.ops[i].shape,
                shape,
                "elem shape mismatch in {name}: {:?} vs {:?}",
                self.ops[i].shape,
                shape
            );
        }
        let dtype = match op {
            ElemOp::CmpGe | ElemOp::CmpEq => DType::Pred,
            ElemOp::Select => self.ops[inputs[1]].dtype,
            _ => self.ops[inputs[0]].dtype,
        };
        self.add(OpKind::Elem(op), inputs, shape, dtype, name)
    }

    pub fn binary(&mut self, op: ElemOp, a: OpId, b: OpId, name: &str) -> OpId {
        self.elem(op, vec![a, b], name)
    }

    pub fn unary(&mut self, op: ElemOp, a: OpId, name: &str) -> OpId {
        self.elem(op, vec![a], name)
    }

    /// Normal-form dot: lhs (batch.., M, K) · rhs (batch.., K, N).
    pub fn dot(&mut self, lhs: OpId, rhs: OpId, batch: usize, name: &str) -> OpId {
        let ls = self.ops[lhs].shape.clone();
        let rs = self.ops[rhs].shape.clone();
        assert_eq!(ls.len(), batch + 2, "lhs rank in {name}");
        assert_eq!(rs.len(), batch + 2, "rhs rank in {name}");
        assert_eq!(&ls[..batch], &rs[..batch], "batch dims in {name}");
        assert_eq!(ls[batch + 1], rs[batch], "contraction dim in {name}: {ls:?}·{rs:?}");
        let mut shape: Vec<usize> = ls[..batch].to_vec();
        shape.push(ls[batch]);
        shape.push(rs[batch + 1]);
        let dtype = self.ops[lhs].dtype;
        self.add(OpKind::Dot(DotDims { batch }), vec![lhs, rhs], shape, dtype, name)
    }

    /// 2-D matmul convenience.
    pub fn matmul(&mut self, a: OpId, b: OpId, name: &str) -> OpId {
        self.dot(a, b, 0, name)
    }

    pub fn reshape(&mut self, x: OpId, shape: Vec<usize>, name: &str) -> OpId {
        assert_eq!(
            self.ops[x].numel(),
            shape.iter().product::<usize>(),
            "reshape numel in {name}: {:?} -> {shape:?}",
            self.ops[x].shape
        );
        let dtype = self.ops[x].dtype;
        self.add(OpKind::Reshape, vec![x], shape, dtype, name)
    }

    pub fn transpose(&mut self, x: OpId, perm: Vec<usize>, name: &str) -> OpId {
        let xs = self.ops[x].shape.clone();
        assert_eq!(perm.len(), xs.len(), "perm rank in {name}");
        let shape: Vec<usize> = perm.iter().map(|&p| xs[p]).collect();
        let dtype = self.ops[x].dtype;
        self.add(OpKind::Transpose { perm }, vec![x], shape, dtype, name)
    }

    /// Broadcast input into `out_shape`; `dims[i]` is where input dim i lands.
    pub fn broadcast(
        &mut self,
        x: OpId,
        dims: Vec<usize>,
        out_shape: Vec<usize>,
        name: &str,
    ) -> OpId {
        let xs = self.ops[x].shape.clone();
        assert_eq!(dims.len(), xs.len(), "broadcast dims rank in {name}");
        for (i, &d) in dims.iter().enumerate() {
            assert_eq!(out_shape[d], xs[i], "broadcast dim {i} in {name}");
            if i > 0 {
                assert!(dims[i - 1] < d, "broadcast dims must be increasing in {name}");
            }
        }
        let dtype = self.ops[x].dtype;
        self.add(OpKind::Broadcast { dims }, vec![x], out_shape, dtype, name)
    }

    pub fn reduce(&mut self, x: OpId, dims: Vec<usize>, kind: ReduceKind, name: &str) -> OpId {
        let xs = self.ops[x].shape.clone();
        let shape: Vec<usize> = xs
            .iter()
            .enumerate()
            .filter(|(i, _)| !dims.contains(i))
            .map(|(_, &d)| d)
            .collect();
        let dtype = self.ops[x].dtype;
        self.add(OpKind::Reduce { dims, kind }, vec![x], shape, dtype, name)
    }

    pub fn gather(&mut self, table: OpId, indices: OpId, name: &str) -> OpId {
        let mut shape = self.ops[indices].shape.clone();
        shape.extend_from_slice(&self.ops[table].shape[1..]);
        let dtype = self.ops[table].dtype;
        self.add(OpKind::Gather, vec![table, indices], shape, dtype, name)
    }

    /// GShard-style token routing: regroup (T, H) ⇄ (E, C, H).
    pub fn route(&mut self, x: OpId, shape: Vec<usize>, name: &str) -> OpId {
        assert_eq!(
            self.ops[x].numel(),
            shape.iter().product::<usize>(),
            "route numel in {name}"
        );
        let dtype = self.ops[x].dtype;
        self.add(OpKind::Route, vec![x], shape, dtype, name)
    }

    /// Pick `index` along `dim`, dropping the dim.
    pub fn slice(&mut self, x: OpId, dim: usize, index: usize, name: &str) -> OpId {
        let xs = self.ops[x].shape.clone();
        assert!(index < xs[dim], "slice index in {name}");
        let shape: Vec<usize> = xs
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != dim)
            .map(|(_, &d)| d)
            .collect();
        let dtype = self.ops[x].dtype;
        self.add(OpKind::Slice { dim, index }, vec![x], shape, dtype, name)
    }

    /// Inverse of slice: embed at `index` along a new dim of `size`.
    pub fn pad(&mut self, x: OpId, dim: usize, index: usize, size: usize, name: &str) -> OpId {
        let xs = self.ops[x].shape.clone();
        let mut shape = xs.clone();
        shape.insert(dim, size);
        let dtype = self.ops[x].dtype;
        self.add(OpKind::Pad { dim, index, size }, vec![x], shape, dtype, name)
    }

    pub fn scatter(
        &mut self,
        indices: OpId,
        updates: OpId,
        table_shape: Vec<usize>,
        name: &str,
    ) -> OpId {
        let dtype = self.ops[updates].dtype;
        self.add(
            OpKind::Scatter { table_shape: table_shape.clone() },
            vec![indices, updates],
            table_shape,
            dtype,
            name,
        )
    }

    // -------------------------------------------------- composite helpers

    /// Softmax over the last dim, decomposed into primitives (max, sub,
    /// exp, sum, div) exactly as XLA lowers it.
    pub fn softmax(&mut self, x: OpId, name: &str) -> OpId {
        let shape = self.ops[x].shape.clone();
        let last = shape.len() - 1;
        let m = self.reduce(x, vec![last], ReduceKind::Max, &format!("{name}/max"));
        let mdims: Vec<usize> = (0..last).collect();
        let mb = self.broadcast(m, mdims.clone(), shape.clone(), &format!("{name}/max_b"));
        let sub = self.binary(ElemOp::Sub, x, mb, &format!("{name}/sub"));
        let e = self.unary(ElemOp::Exp, sub, &format!("{name}/exp"));
        let s = self.reduce(e, vec![last], ReduceKind::Sum, &format!("{name}/sum"));
        let sb = self.broadcast(s, mdims, shape, &format!("{name}/sum_b"));
        self.binary(ElemOp::Div, e, sb, &format!("{name}/div"))
    }

    /// Dropout: rng, compare, select, rescale — carries the RNG op whose
    /// device restriction drives the paper's §2.2 mismatch example.
    pub fn dropout(&mut self, x: OpId, rate: f64, name: &str) -> OpId {
        let shape = self.ops[x].shape.clone();
        let r = self.rng(shape.clone(), &format!("{name}/rng"));
        let thr = self.constant(rate, vec![]);
        let thr_b = self.broadcast(thr, vec![], shape.clone(), &format!("{name}/thr_b"));
        let mask = self.binary(ElemOp::CmpGe, r, thr_b, &format!("{name}/mask"));
        let zero = self.constant(0.0, vec![]);
        let zero_b = self.broadcast(zero, vec![], shape, &format!("{name}/zero_b"));
        let kept = self.elem(ElemOp::Select, vec![mask, x, zero_b], &format!("{name}/select"));
        self.unary(ElemOp::Scale(1.0 / (1.0 - rate)), kept, &format!("{name}/rescale"))
    }

    /// LayerNorm decomposed (mean, var, rsqrt, affine).
    pub fn layernorm(&mut self, x: OpId, w: OpId, b: OpId, name: &str) -> OpId {
        let shape = self.ops[x].shape.clone();
        let last = shape.len() - 1;
        let h = shape[last] as f64;
        let bdims: Vec<usize> = (0..last).collect();
        let sum = self.reduce(x, vec![last], ReduceKind::Sum, &format!("{name}/sum"));
        let mean = self.unary(ElemOp::Scale(1.0 / h), sum, &format!("{name}/mean"));
        let mean_b = self.broadcast(mean, bdims.clone(), shape.clone(), &format!("{name}/mean_b"));
        let centered = self.binary(ElemOp::Sub, x, mean_b, &format!("{name}/center"));
        let sq = self.binary(ElemOp::Mul, centered, centered, &format!("{name}/sq"));
        let var_sum = self.reduce(sq, vec![last], ReduceKind::Sum, &format!("{name}/var_sum"));
        let var = self.unary(ElemOp::Scale(1.0 / h), var_sum, &format!("{name}/var"));
        let var_eps = self.unary(ElemOp::Offset(1e-5), var, &format!("{name}/var_eps"));
        let rstd = self.unary(ElemOp::Rsqrt, var_eps, &format!("{name}/rstd"));
        let rstd_b = self.broadcast(rstd, bdims, shape.clone(), &format!("{name}/rstd_b"));
        let normed = self.binary(ElemOp::Mul, centered, rstd_b, &format!("{name}/normed"));
        let wdims = vec![last];
        let w_b = self.broadcast(w, wdims.clone(), shape.clone(), &format!("{name}/w_b"));
        let scaled = self.binary(ElemOp::Mul, normed, w_b, &format!("{name}/scaled"));
        let b_b = self.broadcast(b, wdims, shape, &format!("{name}/b_b"));
        self.binary(ElemOp::Add, scaled, b_b, &format!("{name}/out"))
    }

    /// RMSNorm decomposed.
    pub fn rmsnorm(&mut self, x: OpId, w: OpId, name: &str) -> OpId {
        let shape = self.ops[x].shape.clone();
        let last = shape.len() - 1;
        let h = shape[last] as f64;
        let bdims: Vec<usize> = (0..last).collect();
        let sq = self.binary(ElemOp::Mul, x, x, &format!("{name}/sq"));
        let ssum = self.reduce(sq, vec![last], ReduceKind::Sum, &format!("{name}/ssum"));
        let msq = self.unary(ElemOp::Scale(1.0 / h), ssum, &format!("{name}/msq"));
        let eps = self.unary(ElemOp::Offset(1e-6), msq, &format!("{name}/eps"));
        let r = self.unary(ElemOp::Rsqrt, eps, &format!("{name}/rsqrt"));
        let r_b = self.broadcast(r, bdims, shape.clone(), &format!("{name}/r_b"));
        let normed = self.binary(ElemOp::Mul, x, r_b, &format!("{name}/normed"));
        let w_b = self.broadcast(w, vec![last], shape, &format!("{name}/w_b"));
        self.binary(ElemOp::Mul, normed, w_b, &format!("{name}/out"))
    }

    // ------------------------------------------------------------ queries

    /// Reverse adjacency: users[t] = ops consuming tensor t.
    pub fn users(&self) -> Vec<Vec<OpId>> {
        let mut users = vec![Vec::new(); self.ops.len()];
        for op in &self.ops {
            for &i in &op.inputs {
                users[i].push(op.id);
            }
        }
        users
    }

    pub fn params(&self) -> Vec<OpId> {
        self.ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Param { class: ParamClass::Weight }))
            .map(|o| o.id)
            .collect()
    }

    pub fn contraction_ops(&self) -> Vec<OpId> {
        self.ops.iter().filter(|o| o.kind.is_contraction()).map(|o| o.id).collect()
    }

    pub fn total_flops(&self) -> u64 {
        self.ops.iter().map(|o| o.flops(self)).sum()
    }

    /// Depth (longest path from any source) per op — Algorithm 1 sorts
    /// contraction ops by this.
    pub fn depths(&self) -> Vec<usize> {
        let mut depth = vec![0usize; self.ops.len()];
        for op in &self.ops {
            for &i in &op.inputs {
                depth[op.id] = depth[op.id].max(depth[i] + 1);
            }
        }
        depth
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_shapes() {
        let mut g = Graph::new();
        let a = g.param("a", vec![4, 8], ParamClass::Input);
        let b = g.param("b", vec![8, 16], ParamClass::Weight);
        let c = g.matmul(a, b, "c");
        assert_eq!(g.shape(c), &[4, 16]);
        assert_eq!(g.ops[c].flops(&g), 2 * 4 * 16 * 8);
    }

    #[test]
    fn bmm_shapes() {
        let mut g = Graph::new();
        let a = g.param("a", vec![2, 3, 4, 8], ParamClass::Input);
        let b = g.param("b", vec![2, 3, 8, 5], ParamClass::Input);
        let c = g.dot(a, b, 2, "c");
        assert_eq!(g.shape(c), &[2, 3, 4, 5]);
    }

    #[test]
    #[should_panic(expected = "contraction dim")]
    fn dot_rejects_mismatched_k() {
        let mut g = Graph::new();
        let a = g.param("a", vec![4, 8], ParamClass::Input);
        let b = g.param("b", vec![9, 16], ParamClass::Input);
        g.matmul(a, b, "bad");
    }

    #[test]
    fn softmax_decomposition_op_count() {
        let mut g = Graph::new();
        let x = g.param("x", vec![2, 8], ParamClass::Input);
        let y = g.softmax(x, "sm");
        assert_eq!(g.shape(y), &[2, 8]);
        // max, bcast, sub, exp, sum, bcast, div = 7 ops after the param
        assert_eq!(g.ops.len(), 8);
    }

    #[test]
    fn layernorm_shape_preserved() {
        let mut g = Graph::new();
        let x = g.param("x", vec![4, 16], ParamClass::Input);
        let w = g.param("w", vec![16], ParamClass::Weight);
        let b = g.param("b", vec![16], ParamClass::Weight);
        let y = g.layernorm(x, w, b, "ln");
        assert_eq!(g.shape(y), &[4, 16]);
    }

    #[test]
    fn dropout_contains_rng() {
        let mut g = Graph::new();
        let x = g.param("x", vec![4, 4], ParamClass::Input);
        g.dropout(x, 0.1, "do");
        assert!(g.ops.iter().any(|o| matches!(o.kind, OpKind::Rng)));
    }

    #[test]
    fn users_reverse_adjacency() {
        let mut g = Graph::new();
        let a = g.param("a", vec![2, 2], ParamClass::Input);
        let b = g.unary(ElemOp::Exp, a, "e");
        let c = g.unary(ElemOp::Neg, a, "n");
        let _ = g.binary(ElemOp::Add, b, c, "s");
        let users = g.users();
        assert_eq!(users[a], vec![b, c]);
        assert_eq!(users[b].len(), 1);
    }

    #[test]
    fn depths_increase_along_chains() {
        let mut g = Graph::new();
        let a = g.param("a", vec![2], ParamClass::Input);
        let b = g.unary(ElemOp::Exp, a, "b");
        let c = g.unary(ElemOp::Exp, b, "c");
        let d = g.depths();
        assert_eq!(d[a], 0);
        assert_eq!(d[b], 1);
        assert_eq!(d[c], 2);
    }
}
