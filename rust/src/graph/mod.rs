//! Fine-grained computation graph IR (HLO-level) + autodiff.

pub mod autodiff;
pub mod build;
pub mod op;

pub use autodiff::{append_backward, Backward};
pub use build::{Graph, Op, OpId};
pub use op::{DType, DotDims, ElemOp, OpKind, ParamClass, ReduceKind, Role};
