//! NDJSON stream serving for [`PlanService`]: the stdin/stdout loop and
//! the `--listen` TCP acceptor (std::net only — no external deps).
//!
//! Requests on one stream are dispatched to the service's bounded worker
//! pool and therefore run (and may complete) concurrently — responses
//! can arrive out of request order, so clients match them by the echoed
//! `id`. Each response is written as one whole line under the stream's
//! writer lock, so lines never interleave.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::{Arc, Condvar, Mutex};

use super::PlanService;

/// Line-atomic shared writer: concurrent workers append whole response
/// lines, never interleaved bytes.
pub type SharedWriter = Arc<Mutex<Box<dyn Write + Send>>>;

pub fn shared_writer(w: impl Write + Send + 'static) -> SharedWriter {
    let boxed: Box<dyn Write + Send> = Box::new(w);
    Arc::new(Mutex::new(boxed))
}

impl PlanService {
    /// Serve NDJSON requests from `reader` until EOF, dispatching every
    /// line to the worker pool and writing one response line per request
    /// to `writer`. Blank lines are skipped. Returns only after every
    /// dispatched request has been answered, so a caller can safely
    /// persist caches or exit afterwards.
    pub fn serve_stream(&self, reader: impl BufRead, writer: SharedWriter) {
        let outstanding = Arc::new((Mutex::new(0usize), Condvar::new()));
        for line in reader.lines() {
            let Ok(line) = line else { break };
            if line.trim().is_empty() {
                continue;
            }
            {
                let (count, _) = &*outstanding;
                *count.lock().unwrap_or_else(|e| e.into_inner()) += 1;
            }
            let svc = self.clone();
            let writer = Arc::clone(&writer);
            let outstanding = Arc::clone(&outstanding);
            self.inner.pool.execute(move || {
                let resp = svc.handle_line(&line);
                {
                    let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
                    let _ = writeln!(w, "{resp}");
                    let _ = w.flush();
                }
                let (count, done) = &*outstanding;
                *count.lock().unwrap_or_else(|e| e.into_inner()) -= 1;
                done.notify_all();
            });
        }
        let (count, done) = &*outstanding;
        let mut pending = count.lock().unwrap_or_else(|e| e.into_inner());
        while *pending > 0 {
            pending = done.wait(pending).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Bind `addr` (e.g. `127.0.0.1:7070`, port 0 for ephemeral) and
    /// serve TCP connections — one NDJSON stream per connection — on a
    /// background acceptor thread for the life of the process. Returns
    /// the bound address.
    pub fn listen(&self, addr: &str) -> std::io::Result<SocketAddr> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let svc = self.clone();
        std::thread::Builder::new().name("cfp-serve-accept".into()).spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { continue };
                let svc = svc.clone();
                let _ = std::thread::Builder::new()
                    .name("cfp-serve-conn".into())
                    .spawn(move || serve_connection(&svc, stream));
            }
        })?;
        Ok(local)
    }
}

fn serve_connection(svc: &PlanService, stream: TcpStream) {
    let Ok(read_half) = stream.try_clone() else { return };
    svc.serve_stream(BufReader::new(read_half), shared_writer(stream));
}

#[cfg(test)]
mod tests {
    use super::super::ServeConfig;
    use super::*;
    use crate::util::Json;

    /// `Write` into a shared buffer the test can inspect afterwards.
    struct Sink(Arc<Mutex<Vec<u8>>>);

    impl Write for Sink {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn serve_stream_answers_every_line_and_returns_on_eof() {
        let svc = PlanService::new(ServeConfig { workers: 2, ..ServeConfig::default() });
        let input = "{\"id\": \"a\", \"type\": \"plan\", \"model\": \"gpt-tiny\"}\n\
                     \n\
                     {\"id\": \"b\", \"type\": \"stats\"}\n\
                     not json at all\n";
        let buf = Arc::new(Mutex::new(Vec::new()));
        svc.serve_stream(std::io::Cursor::new(input), shared_writer(Sink(Arc::clone(&buf))));
        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "three requests (blank line skipped): {text}");
        let mut kinds = Vec::new();
        for line in &lines {
            let j = Json::parse(line).expect("every response line is valid JSON");
            match j.get("ok").and_then(Json::as_bool) {
                Some(true) => kinds.push(j.get("kind").unwrap().as_str().unwrap().to_string()),
                Some(false) => kinds.push("error".to_string()),
                None => panic!("response without ok: {line}"),
            }
        }
        kinds.sort();
        assert_eq!(kinds, ["error", "plan", "stats"]);
    }
}
