//! NDJSON stream serving for [`PlanService`]: the stdin/stdout loop and
//! the `--listen` TCP acceptor (std::net only — no external deps).
//!
//! Requests on one stream are dispatched to the service's bounded worker
//! pool and therefore run (and may complete) concurrently — responses
//! can arrive out of request order, so clients match them by the echoed
//! `id`. Each response is written as one whole line under the stream's
//! writer lock, so lines never interleave.
//!
//! Backpressure: the service-wide count of dispatched-but-unanswered
//! requests is bounded by `--max-pending`. Past the bound, plan work is
//! answered inline on the reader thread with a structured `overloaded`
//! rejection instead of growing the pool's queue without bound; admin
//! requests (`stats`, `drain`) always pass — overload must never take
//! out the operator's view or the drain path.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use super::PlanService;

/// Line-atomic shared writer: concurrent workers append whole response
/// lines, never interleaved bytes.
pub type SharedWriter = Arc<Mutex<Box<dyn Write + Send>>>;

pub fn shared_writer(w: impl Write + Send + 'static) -> SharedWriter {
    let boxed: Box<dyn Write + Send> = Box::new(w);
    Arc::new(Mutex::new(boxed))
}

impl PlanService {
    /// Serve NDJSON requests from `reader` until EOF, dispatching every
    /// line to the worker pool and writing one response line per request
    /// to `writer`. Blank lines are skipped. Returns only after every
    /// dispatched request has been answered, so a caller can safely
    /// persist caches or exit afterwards.
    pub fn serve_stream(&self, reader: impl BufRead, writer: SharedWriter) {
        let outstanding = Arc::new((Mutex::new(0usize), Condvar::new()));
        for line in reader.lines() {
            let Ok(line) = line else { break };
            if line.trim().is_empty() {
                continue;
            }
            // frame-corruption fault: mangle the inbound line (as if the
            // peer sent garbage after N good frames) — it must come back
            // as a structured parse error, never kill the stream
            let line = if crate::util::failpoint::should_trip("serve.frame_corrupt") {
                format!("\u{1}corrupt{line}")
            } else {
                line
            };
            let max = self.inner.cfg.max_pending;
            if max > 0 && self.inner.pending.load(Ordering::Acquire) >= max {
                let t0 = Instant::now();
                if let Some(resp) = self.reject_overloaded_line(&line) {
                    self.inner
                        .telemetry
                        .record_latency("rejected", t0.elapsed().as_micros() as u64);
                    let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
                    let _ = writeln!(w, "{resp}");
                    let _ = w.flush();
                    continue;
                }
            }
            self.inner.pending.fetch_add(1, Ordering::AcqRel);
            {
                let (count, _) = &*outstanding;
                *count.lock().unwrap_or_else(|e| e.into_inner()) += 1;
            }
            let svc = self.clone();
            let writer = Arc::clone(&writer);
            let outstanding = Arc::clone(&outstanding);
            self.inner.pool.execute(move || {
                // pool-level isolation: a panicking request (injected via
                // serve.worker_panic, or a real bug below handle_line's
                // own guards) answers with a structured internal_error
                // instead of taking the worker thread — and the loop's
                // outstanding/pending bookkeeping below — down with it
                let resp = catch_unwind(AssertUnwindSafe(|| {
                    crate::util::failpoint::trip_panic("serve.worker_panic");
                    svc.handle_line(&line)
                }))
                .unwrap_or_else(|p| svc.internal_error_line(&line, &super::panic_msg(&p)));
                {
                    let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
                    // torn-write fault: emit only a prefix of the response
                    // before the newline, as a dying peer would observe
                    let bytes = resp.as_bytes();
                    let cut = if crate::util::failpoint::should_trip("serve.write_torn") {
                        bytes.len() / 2
                    } else {
                        bytes.len()
                    };
                    let _ = w.write_all(&bytes[..cut]);
                    let _ = w.write_all(b"\n");
                    let _ = w.flush();
                }
                svc.inner.pending.fetch_sub(1, Ordering::AcqRel);
                let (count, done) = &*outstanding;
                *count.lock().unwrap_or_else(|e| e.into_inner()) -= 1;
                done.notify_all();
            });
        }
        let (count, done) = &*outstanding;
        let mut pending = count.lock().unwrap_or_else(|e| e.into_inner());
        while *pending > 0 {
            pending = done.wait(pending).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Bind `addr` (e.g. `127.0.0.1:7070`, port 0 for ephemeral) and
    /// serve TCP connections — one NDJSON stream per connection — on a
    /// background acceptor thread for the life of the process. Returns
    /// the bound address.
    pub fn listen(&self, addr: &str) -> std::io::Result<SocketAddr> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        let svc = self.clone();
        std::thread::Builder::new().name("cfp-serve-accept".into()).spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { continue };
                // accept-error fault: drop the connection as if accept(2)
                // had failed; the acceptor loop must keep serving
                if crate::util::failpoint::should_trip("serve.accept_fail") {
                    continue;
                }
                let svc = svc.clone();
                let _ = std::thread::Builder::new()
                    .name("cfp-serve-conn".into())
                    .spawn(move || serve_connection(&svc, stream));
            }
        })?;
        Ok(local)
    }
}

fn serve_connection(svc: &PlanService, stream: TcpStream) {
    // socket deadlines: a wedged or dead peer errors out of its read or
    // write instead of parking a connection thread (and, transitively, a
    // worker blocked on the shared writer lock) forever
    let _ = stream.set_read_timeout(svc.inner.cfg.read_timeout);
    let _ = stream.set_write_timeout(svc.inner.cfg.write_timeout);
    let Ok(read_half) = stream.try_clone() else { return };
    svc.serve_stream(BufReader::new(read_half), shared_writer(stream));
}

#[cfg(test)]
mod tests {
    use super::super::ServeConfig;
    use super::*;
    use crate::util::Json;

    /// `Write` into a shared buffer the test can inspect afterwards.
    struct Sink(Arc<Mutex<Vec<u8>>>);

    impl Write for Sink {
        fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
            self.0.lock().unwrap().extend_from_slice(buf);
            Ok(buf.len())
        }

        fn flush(&mut self) -> std::io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn serve_stream_answers_every_line_and_returns_on_eof() {
        let svc = PlanService::new(ServeConfig { workers: 2, ..ServeConfig::default() });
        let input = "{\"id\": \"a\", \"type\": \"plan\", \"model\": \"gpt-tiny\"}\n\
                     \n\
                     {\"id\": \"b\", \"type\": \"stats\"}\n\
                     not json at all\n";
        let buf = Arc::new(Mutex::new(Vec::new()));
        svc.serve_stream(std::io::Cursor::new(input), shared_writer(Sink(Arc::clone(&buf))));
        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "three requests (blank line skipped): {text}");
        let mut kinds = Vec::new();
        for line in &lines {
            let j = Json::parse(line).expect("every response line is valid JSON");
            match j.get("ok").and_then(Json::as_bool) {
                Some(true) => kinds.push(j.get("kind").unwrap().as_str().unwrap().to_string()),
                Some(false) => kinds.push("error".to_string()),
                None => panic!("response without ok: {line}"),
            }
        }
        kinds.sort();
        assert_eq!(kinds, ["error", "plan", "stats"]);
    }

    #[test]
    fn pending_queue_bound_rejects_inline_under_overload() {
        let svc = PlanService::new(ServeConfig {
            workers: 1,
            max_pending: 1,
            ..ServeConfig::default()
        });
        // hold the only worker inside its search until the reader thread
        // has rejected both excess lines, making the overload window
        // deterministic rather than timing-dependent
        let probe = svc.clone();
        svc.set_search_hook(Arc::new(move || {
            while probe.stats().rejected_overload < 2 {
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
        }));
        let input = "{\"id\": 1, \"type\": \"plan\", \"model\": \"gpt-tiny\"}\n\
                     {\"id\": 2, \"type\": \"plan\", \"model\": \"gpt-tiny\"}\n\
                     {\"id\": 3, \"type\": \"plan\", \"model\": \"gpt-tiny\"}\n";
        let buf = Arc::new(Mutex::new(Vec::new()));
        svc.serve_stream(std::io::Cursor::new(input), shared_writer(Sink(Arc::clone(&buf))));
        let text = String::from_utf8(buf.lock().unwrap().clone()).unwrap();
        let (mut ok, mut overloaded) = (0, 0);
        for line in text.lines() {
            let j = Json::parse(line).unwrap();
            if j.get("ok").and_then(Json::as_bool) == Some(true) {
                ok += 1;
            } else {
                assert_eq!(j.get("reason").and_then(Json::as_str), Some("overloaded"));
                overloaded += 1;
            }
        }
        assert_eq!((ok, overloaded), (1, 2), "one admitted, two rejected inline: {text}");
        let s = svc.stats();
        assert_eq!(s.received, 3);
        assert_eq!(s.admitted, 1);
        assert_eq!(s.rejected_overload, 2);
        assert_eq!(s.received, s.admitted + s.rejected + s.coalesced, "counters reconcile");
    }
}
