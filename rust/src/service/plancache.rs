//! Persistent plan cache: the serve tier's in-memory LRU plan map,
//! flushed to disk so a restart serves byte-identical plans with zero
//! searches.
//!
//! The file reuses the `profiler::cache` durability machinery — the
//! sibling `.lock` protocol against concurrent savers and the
//! `tmp.{pid}` + atomic-rename write — and the same invalidation
//! philosophy: entries are keyed by the engine-aware canonical request
//! key (`request::canonical_key`), so any semantic change to planning
//! inputs changes the key, and a [`PLAN_CACHE_VERSION`] bump discards
//! the file wholesale. A cache can only ever cost a re-search, never a
//! wrong plan: *any* malformed byte — torn write, truncation, a single
//! corrupt entry — discards the whole file (`load` returns `None`)
//! rather than trusting the readable remainder.
//!
//! Format (version 1), one JSON object:
//!
//! ```json
//! {"version": 1, "clock": 17,
//!  "plans": [{"key": "plan|gpt-tiny...|dp", "stamp": 9, "payload": {...}}]}
//! ```
//!
//! `stamp` is the in-memory LRU clock value at last touch; persisting it
//! keeps eviction order stable across restarts. Payloads are stored as
//! parsed JSON but served as `Arc<Json>` re-rendered through the same
//! sorted-key writer that produced them, so a warm restart's response
//! bytes are identical to the run that populated the file.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use crate::profiler::cache::{acquire_save_lock, LOCK_STALE, LOCK_WAIT};
use crate::util::Json;

/// Bump to discard every persisted plan wholesale on format or planner
/// semantics changes that the canonical key cannot express.
pub const PLAN_CACHE_VERSION: i64 = 1;

/// The serve tier's plan map: canonical key → (payload, LRU stamp).
pub type PlanMap = BTreeMap<String, (Arc<Json>, u64)>;

/// Read a plan-cache file. `None` means "no usable cache" — missing
/// file, version mismatch, or corruption anywhere in it; the caller
/// starts cold and re-searches, which is always safe.
pub fn load(path: &Path) -> Option<(PlanMap, u64)> {
    let text = std::fs::read_to_string(path).ok()?;
    parse(&text)
}

fn parse(text: &str) -> Option<(PlanMap, u64)> {
    let doc = Json::parse(text).ok()?;
    if doc.get("version")?.as_i64()? != PLAN_CACHE_VERSION {
        return None;
    }
    // version-skew fault: a well-formed file written by an incompatible
    // future version — discard wholesale exactly like a real bump
    if crate::util::failpoint::should_trip("plan_cache.version_skew") {
        return None;
    }
    let mut clock = doc.get("clock")?.as_u64()?;
    let mut plans = PlanMap::new();
    for entry in doc.get("plans")?.as_arr()? {
        let key = entry.get("key")?.as_str()?;
        let stamp = entry.get("stamp")?.as_u64()?;
        let payload = entry.get("payload")?;
        if key.is_empty() || payload.as_obj().is_none() {
            return None; // plan payloads are always objects; anything else is corruption
        }
        clock = clock.max(stamp);
        plans.insert(key.to_string(), (Arc::new(payload.clone()), stamp));
    }
    Some((plans, clock))
}

/// Flush the plan map: lock, read-merge with whatever another server
/// already persisted (our entries win on key conflict — payloads for
/// one canonical key are bit-identical by the determinism invariant),
/// evict to `max_entries` by smallest stamp, write `tmp.{pid}`, rename.
pub fn save(path: &Path, plans: &PlanMap, clock: u64, max_entries: usize) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let _lock = acquire_save_lock(path, LOCK_STALE, LOCK_WAIT);
    let mut merged = plans.clone();
    let mut clock = clock;
    if let Some((disk, disk_clock)) = load(path) {
        for (k, v) in disk {
            merged.entry(k).or_insert(v);
        }
        clock = clock.max(disk_clock);
    }
    if max_entries > 0 {
        while merged.len() > max_entries {
            let lru = merged.iter().min_by_key(|(_, (_, stamp))| *stamp).map(|(k, _)| k.clone());
            match lru {
                Some(k) => merged.remove(&k),
                None => break,
            };
        }
    }
    let entries: Vec<Json> = merged
        .iter()
        .map(|(k, (payload, stamp))| {
            Json::obj(vec![
                ("key", Json::str(k.as_str())),
                ("stamp", Json::num(*stamp as f64)),
                ("payload", (**payload).clone()),
            ])
        })
        .collect();
    let doc = Json::obj(vec![
        ("version", Json::num(PLAN_CACHE_VERSION as f64)),
        ("clock", Json::num(clock as f64)),
        ("plans", Json::Arr(entries)),
    ]);
    let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
    let text = doc.to_string();
    // torn-write fault: a record truncated mid-write survives the
    // rename; load() must refuse the whole file, never the readable half
    let bytes: &[u8] = if crate::util::failpoint::should_trip("plan_cache.torn_save") {
        &text.as_bytes()[..text.len() / 2]
    } else {
        text.as_bytes()
    };
    std::fs::write(&tmp, bytes)?;
    std::fs::rename(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payload(marker: f64) -> Arc<Json> {
        Arc::new(Json::obj(vec![
            ("kind", Json::str("plan")),
            ("time_us", Json::num(marker)),
        ]))
    }

    fn tmp_file(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("cfp-plancache-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("plans.json")
    }

    #[test]
    fn round_trip_preserves_payload_bytes_stamps_and_clock() {
        let path = tmp_file("rt");
        let mut plans = PlanMap::new();
        plans.insert("k1".into(), (payload(12.0), 3));
        plans.insert("k2".into(), (payload(7.5), 9));
        save(&path, &plans, 9, 0).unwrap();
        let (loaded, clock) = load(&path).expect("round trip");
        assert_eq!(clock, 9);
        assert_eq!(loaded.len(), 2);
        for (k, (p, stamp)) in &plans {
            let (lp, lstamp) = &loaded[k];
            assert_eq!(lstamp, stamp, "stamp for {k}");
            assert_eq!(lp.to_string(), p.to_string(), "payload bytes for {k}");
        }
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn torn_file_is_discarded_wholesale() {
        let path = tmp_file("torn");
        let mut plans = PlanMap::new();
        plans.insert("k1".into(), (payload(1.0), 1));
        plans.insert("k2".into(), (payload(2.0), 2));
        save(&path, &plans, 2, 0).unwrap();
        let full = std::fs::read(&path).unwrap();
        // truncate mid-document: a torn write must invalidate everything,
        // including the entries whose bytes are still intact
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        assert!(load(&path).is_none(), "torn file must not load partially");
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn version_mismatch_discards_wholesale() {
        let path = tmp_file("ver");
        std::fs::write(
            &path,
            r#"{"version": 99, "clock": 1, "plans": [{"key": "k", "stamp": 1, "payload": {}}]}"#,
        )
        .unwrap();
        assert!(load(&path).is_none());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn single_malformed_entry_discards_wholesale() {
        let path = tmp_file("entry");
        // valid JSON overall, but one entry's payload is not an object —
        // never serve the "good" siblings of corrupt data
        std::fs::write(
            &path,
            concat!(
                r#"{"version": 1, "clock": 2, "plans": ["#,
                r#"{"key": "good", "stamp": 1, "payload": {"kind": "plan"}}, "#,
                r#"{"key": "bad", "stamp": 2, "payload": 42}]}"#,
            ),
        )
        .unwrap();
        assert!(load(&path).is_none());
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn save_merges_with_a_concurrent_writer_and_evicts_lru() {
        let path = tmp_file("merge");
        let mut a = PlanMap::new();
        a.insert("a".into(), (payload(1.0), 5));
        save(&path, &a, 5, 0).unwrap();
        // a second server persists its own map to the same file
        let mut b = PlanMap::new();
        b.insert("b".into(), (payload(2.0), 8));
        save(&path, &b, 8, 0).unwrap();
        let (merged, clock) = load(&path).unwrap();
        assert_eq!(merged.len(), 2, "read-merge keeps the other writer's entries");
        assert_eq!(clock, 8);
        // a capped save evicts the smallest stamp
        let mut c = PlanMap::new();
        c.insert("c".into(), (payload(3.0), 9));
        save(&path, &c, 9, 2).unwrap();
        let (capped, _) = load(&path).unwrap();
        assert_eq!(capped.len(), 2);
        assert!(!capped.contains_key("a"), "stamp-5 entry was the LRU victim");
        assert!(capped.contains_key("b") && capped.contains_key("c"));
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }
}
