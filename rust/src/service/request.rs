//! The `cfp serve` wire format: NDJSON request parsing, canonical plan
//! keys, and the deterministic result payloads.
//!
//! A request is one JSON object per line. Planning fields carry the CLI
//! flag names (`-` spelled `_`), and are converted to a synthetic
//! [`Args`] fed to the same [`CfpOptions::from_args`] builder as the
//! `cfp` subcommands — the CLI and the server cannot interpret the same
//! request differently, because there is only one interpretation path.
//!
//! ```text
//! {"id": 1, "type": "plan", "model": "gpt-2.6b", "layers": 4, "platform": "a100-pcie"}
//! {"id": 2, "type": "pipeline", "model": "llama-7b", "scaled": true,
//!  "microbatches": 8, "mem_cap": 12.5, "recompute": "auto"}
//! {"type": "stats"}
//! {"id": 3, "type": "plan", "model": "gpt-tiny", "client": "trainer-1"}
//! {"type": "drain"}
//! ```
//!
//! `client` is a quota identity only — it feeds per-client admission,
//! never the plan key. `drain` is the admin request that moves the
//! service to the draining lifecycle state.
//!
//! Unknown fields are rejected (a typo silently ignored by a server is a
//! plan the client did not ask for), and so is any field the service
//! owns rather than the request: thread budget and cache placement are
//! `cfp serve` configuration.

use crate::coordinator::{CfpOptions, CfpResult, PlannerKind, TwoLevelResult};
use crate::interop::{PipelinePlan, StageSpec};
use crate::util::cli::Args;
use crate::util::Json;

/// What a request line asks for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestKind {
    /// single-level plan search (the `cfp search` economics)
    Plan,
    /// two-level inter-op × intra-op planning (`cfp pipeline`)
    Pipeline,
    /// service counters snapshot (never planned, never cached)
    Stats,
    /// admin: stop accepting, finish in-flight, flush, report
    Drain,
}

impl RequestKind {
    pub fn as_str(self) -> &'static str {
        match self {
            RequestKind::Plan => "plan",
            RequestKind::Pipeline => "pipeline",
            RequestKind::Stats => "stats",
            RequestKind::Drain => "drain",
        }
    }

    /// The planner (and therefore option defaults) this kind drives.
    pub fn planner(self) -> PlannerKind {
        match self {
            RequestKind::Pipeline => PlannerKind::TwoLevel,
            RequestKind::Plan | RequestKind::Stats | RequestKind::Drain => {
                PlannerKind::SingleLevel
            }
        }
    }
}

/// One parsed NDJSON request line.
pub struct PlanRequest {
    /// client token echoed verbatim in the response (any JSON value)
    pub id: Option<Json>,
    pub kind: RequestKind,
    /// quota identity for per-client admission; not plan identity (it
    /// must never split the plan cache)
    pub client: Option<String>,
    /// shared-secret credential checked against `--auth-token` at
    /// admission; like `client`, never plan identity
    pub auth: Option<String>,
    /// the planning fields in CLI-flag form, ready for
    /// [`CfpOptions::from_args`]
    pub args: Args,
}

/// Every field a request line may carry. The service's own knobs
/// (worker count, thread budget, cache placement) are deliberately NOT
/// requestable — they are `cfp serve` configuration.
const FIELDS: &[&str] = &[
    "id",
    "type",
    "model",
    "layers",
    "batch",
    "scaled",
    "platform",
    "stages",
    "microbatches",
    "mem_cap",
    "recompute",
    "engine",
    "client",
    "auth",
];

/// Parse one request line. Every failure is a `String` destined for a
/// structured error response — this path must never panic.
pub fn parse_request(line: &str) -> Result<PlanRequest, String> {
    let j = Json::parse(line).map_err(|e| format!("invalid JSON: {e}"))?;
    let obj = j.as_obj().ok_or_else(|| "request must be a JSON object".to_string())?;
    for key in obj.keys() {
        if !FIELDS.contains(&key.as_str()) {
            return Err(format!("unknown request field {key:?} (known: {FIELDS:?})"));
        }
    }
    let kind = match j.get("type") {
        None => RequestKind::Plan,
        Some(t) => match t.as_str() {
            Some("plan") => RequestKind::Plan,
            Some("pipeline") => RequestKind::Pipeline,
            Some("stats") => RequestKind::Stats,
            Some("drain") => RequestKind::Drain,
            Some(other) => {
                return Err(format!(
                    "unknown request type {other:?} (want plan|pipeline|stats|drain)"
                ))
            }
            None => return Err("\"type\" must be a string".to_string()),
        },
    };
    let mut args = Args::default();
    for field in ["model", "platform", "stages", "recompute", "engine"] {
        if let Some(v) = j.get(field) {
            let s = v.as_str().ok_or_else(|| format!("{field:?} must be a string"))?;
            args.options.insert(field.to_string(), s.to_string());
        }
    }
    for field in ["layers", "batch", "microbatches"] {
        if let Some(v) = j.get(field) {
            let n = v.as_u64().ok_or_else(|| format!("{field:?} must be a non-negative integer"))?;
            args.options.insert(field.to_string(), n.to_string());
        }
    }
    if let Some(v) = j.get("mem_cap") {
        let gb = v.as_f64().ok_or_else(|| "\"mem_cap\" must be a number (GB)".to_string())?;
        args.options.insert("mem-cap".to_string(), format!("{gb}"));
    }
    if let Some(v) = j.get("scaled") {
        if v.as_bool().ok_or_else(|| "\"scaled\" must be a boolean".to_string())? {
            args.flags.push("scaled".to_string());
        }
    }
    let client = match j.get("client") {
        None => None,
        Some(v) => {
            Some(v.as_str().ok_or_else(|| "\"client\" must be a string".to_string())?.to_string())
        }
    };
    let auth = match j.get("auth") {
        None => None,
        Some(v) => {
            Some(v.as_str().ok_or_else(|| "\"auth\" must be a string".to_string())?.to_string())
        }
    };
    Ok(PlanRequest { id: j.get("id").cloned(), kind, client, auth, args })
}

/// Deterministic identity of a planning request: every *resolved* option
/// that can change the planned output, nothing that cannot (thread
/// budget, cache placement). Semantically identical requests — however
/// spelled — therefore share one plan-cache slot and one in-flight
/// search. Fields the single-level planner ignores (stages,
/// microbatches, recompute) are normalized out of `plan` keys so they
/// cannot split the cache.
pub fn canonical_key(kind: RequestKind, opts: &CfpOptions) -> String {
    let m = &opts.model;
    let cap = opts.mem_cap.map_or_else(|| "none".to_string(), |b| b.to_string());
    let (stages, mb, rec) = match kind {
        RequestKind::Plan | RequestKind::Stats | RequestKind::Drain => {
            ("-".to_string(), "-".to_string(), "-")
        }
        RequestKind::Pipeline => (
            match opts.stages {
                StageSpec::Single => "single".to_string(),
                StageSpec::Auto => "auto".to_string(),
                StageSpec::Fixed(k) => format!("k{k}"),
            },
            opts.microbatches.to_string(),
            if opts.recompute.is_auto() { "auto" } else { "off" },
        ),
    };
    let cm = opts.compute.as_ref().map_or_else(|| "default".to_string(), |c| c.signature());
    // the engine picks the ComposeSearch searcher for BOTH kinds (the
    // two-level planner's single-stage leg runs through it), so it is
    // always plan identity
    let eng = opts.engine.as_str();
    // segment-DAG topology: expert-branched MoE models plan through the
    // spdag lanes, so the chain/DAG shape is plan identity — derived from
    // the model config alone (matches `SpTopology::signature()`) so the
    // key never needs a graph build
    let topo = if m.expert_branches && m.experts >= 2 && m.layers >= 2 {
        format!("sp-dag{}", m.experts)
    } else {
        "chain".to_string()
    };
    format!(
        "{kind};model={name}/{arch:?}/h{h}/l{l}/hd{hd}/f{f}/v{v}/s{s}/b{b}/e{e}/do{dp};\
         plat={plat};mesh={mi}x{mn};cap={cap};stages={stages};mb={mb};rec={rec};cm={cm};\
         eng={eng};topo={topo}",
        kind = kind.as_str(),
        name = m.name,
        arch = m.arch,
        h = m.hidden,
        l = m.layers,
        hd = m.heads,
        f = m.ffn,
        v = m.vocab,
        s = m.seq,
        b = m.batch,
        e = m.experts,
        dp = m.dropout,
        plat = opts.platform.signature(),
        mi = opts.mesh.intra,
        mn = opts.mesh.nodes,
    )
}

/// Result payload for a single-level plan: a pure function of the
/// [`CfpResult`], shared by the serving path and the bit-identity tests
/// against the one-shot CLI path. Wall-clock timings are deliberately
/// absent — the payload must be byte-identical however the plan was
/// obtained (cold, profile-warm, plan-cache hit, coalesced).
pub fn plan_payload(r: &CfpResult) -> Json {
    Json::obj(vec![
        ("time_us", Json::num(r.plan.time_us)),
        ("mem_bytes", Json::num(r.plan.mem_bytes as f64)),
        ("choice", Json::Arr(r.plan.choice.iter().map(|&c| Json::num(c as f64)).collect())),
        ("segments", Json::Arr(r.describe_plan().into_iter().map(Json::str).collect())),
        ("blocks", Json::num(r.blocks.num_blocks() as f64)),
        ("unique_segments", Json::num(r.segments.num_unique() as f64)),
        ("profile_space", Json::num(r.db.profile_space() as f64)),
    ])
}

/// Result payload for a two-level plan — see [`plan_payload`] for the
/// determinism contract. An infeasible cap is an answer (`feasible:
/// false`), not an error: it is deterministic and cacheable.
pub fn pipeline_payload(r: &TwoLevelResult) -> Json {
    Json::obj(vec![
        ("single_time_us", Json::num(r.single.plan.time_us)),
        ("feasible", Json::Bool(r.pipeline.is_some())),
        ("pipeline", r.pipeline.as_ref().map_or(Json::Null, stage_json)),
        ("naive", r.naive.as_ref().map_or(Json::Null, stage_json)),
    ])
}

fn stage_json(p: &PipelinePlan) -> Json {
    Json::obj(vec![
        ("stages", Json::num(p.num_stages() as f64)),
        ("devices_per_stage", Json::num(p.devices_per_stage as f64)),
        ("step_time_us", Json::num(p.step_time_us)),
        ("peak_mem_bytes", Json::num(p.peak_mem_bytes as f64)),
        ("bubble", Json::num(p.bubble_fraction)),
        ("describe", Json::Arr(p.describe().into_iter().map(Json::str).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Platform;
    use crate::models::ModelCfg;

    fn opts() -> CfpOptions {
        CfpOptions::new(ModelCfg::preset("gpt-tiny"), Platform::a100_pcie(4))
    }

    #[test]
    fn parse_accepts_the_documented_forms() {
        let r = parse_request(
            "{\"id\": 7, \"type\": \"plan\", \"model\": \"gpt-tiny\", \"layers\": 3, \
             \"scaled\": true}",
        )
        .unwrap();
        assert_eq!(r.kind, RequestKind::Plan);
        assert_eq!(r.id, Some(Json::num(7.0)));
        assert_eq!(r.args.get("model"), Some("gpt-tiny"));
        assert_eq!(r.args.get("layers"), Some("3"));
        assert!(r.args.has_flag("scaled"));

        let r = parse_request("{\"type\": \"pipeline\", \"mem_cap\": 12.5}").unwrap();
        assert_eq!(r.kind, RequestKind::Pipeline);
        assert_eq!(r.args.get("mem-cap"), Some("12.5"));

        let r = parse_request("{\"engine\": \"exact\"}").unwrap();
        assert_eq!(r.args.get("engine"), Some("exact"));

        // type defaults to plan
        assert_eq!(parse_request("{}").unwrap().kind, RequestKind::Plan);
        assert_eq!(parse_request("{\"type\": \"stats\"}").unwrap().kind, RequestKind::Stats);
        assert_eq!(parse_request("{\"type\": \"drain\"}").unwrap().kind, RequestKind::Drain);

        // client is quota identity: carried on the request, kept out of
        // the planning args so it can never split the plan cache
        let r = parse_request("{\"model\": \"gpt-tiny\", \"client\": \"trainer-1\"}").unwrap();
        assert_eq!(r.client.as_deref(), Some("trainer-1"));
        assert!(r.args.get("client").is_none());
        assert!(parse_request("{}").unwrap().client.is_none());

        // auth is an admission credential: carried on the request, kept
        // out of the planning args (it must never split the plan cache)
        let r = parse_request("{\"model\": \"gpt-tiny\", \"auth\": \"s3cret\"}").unwrap();
        assert_eq!(r.auth.as_deref(), Some("s3cret"));
        assert!(r.args.get("auth").is_none());
        assert!(parse_request("{}").unwrap().auth.is_none());
    }

    #[test]
    fn canonical_key_carries_the_dag_topology() {
        let chain = opts();
        assert!(canonical_key(RequestKind::Plan, &chain).ends_with(";topo=chain"));
        let moe =
            CfpOptions::new(ModelCfg::preset("moe-ep-tiny"), Platform::a100_pcie(4));
        assert!(canonical_key(RequestKind::Plan, &moe).ends_with(";topo=sp-dag4"));
        // the un-branched MoE preset stays a chain: expert parallelism
        // without per-expert branches is planned on the linear chain
        let moe_chain =
            CfpOptions::new(ModelCfg::preset("moe-tiny"), Platform::a100_pcie(4));
        assert!(canonical_key(RequestKind::Plan, &moe_chain).ends_with(";topo=chain"));
    }

    #[test]
    fn parse_rejects_malformed_requests() {
        for bad in [
            "{not json",
            "[1, 2]",
            "\"just a string\"",
            "{\"type\": \"wat\"}",
            "{\"type\": 3}",
            "{\"typ\": \"plan\"}",       // unknown field (typo)
            "{\"threads\": 8}",          // service-owned knob
            "{\"layers\": \"four\"}",    // wrong type
            "{\"layers\": -1}",          // negative
            "{\"mem_cap\": \"big\"}",    // wrong type
            "{\"scaled\": \"yes\"}",     // wrong type
            "{\"client\": 5}",           // wrong type
            "{\"auth\": 5}",             // wrong type
        ] {
            assert!(parse_request(bad).is_err(), "{bad:?} must be rejected");
        }
    }

    #[test]
    fn canonical_key_ignores_what_cannot_change_the_plan() {
        let a = opts();
        let mut b = opts();
        b.threads = 8;
        b.cache_path = Some("/tmp/x.json".into());
        b.cache_max_entries = Some(4);
        assert_eq!(
            canonical_key(RequestKind::Plan, &a),
            canonical_key(RequestKind::Plan, &b),
            "thread budget and cache placement are not plan identity"
        );
        // the single-level planner ignores pipeline-only fields
        b.microbatches = 2;
        b.stages = StageSpec::Fixed(2);
        assert_eq!(canonical_key(RequestKind::Plan, &a), canonical_key(RequestKind::Plan, &b));
        assert_ne!(
            canonical_key(RequestKind::Pipeline, &a),
            canonical_key(RequestKind::Pipeline, &b),
            "the two-level planner does not"
        );
    }

    #[test]
    fn canonical_key_separates_what_does() {
        let a = opts();
        for (label, b) in [
            ("layers", CfpOptions::new(ModelCfg::preset("gpt-tiny").with_layers(3), a.platform)),
            ("batch", CfpOptions::new(ModelCfg::preset("gpt-tiny").with_batch(8), a.platform)),
            ("platform", CfpOptions::new(ModelCfg::preset("gpt-tiny"), Platform::a100_pcie(8))),
            ("mem_cap", opts().with_mem_cap(1 << 30)),
            ("engine", opts().with_engine(crate::cost::SearchEngine::Exact)),
        ] {
            assert_ne!(
                canonical_key(RequestKind::Plan, &a),
                canonical_key(RequestKind::Plan, &b),
                "{label} must split the key"
            );
        }
    }
}
