//! Always-on serving telemetry: log-bucketed latency histograms and
//! stage-time samplers drained by a background aggregator thread.
//!
//! The shape is the channel-plus-collector profiler pattern: request
//! threads do nothing but a lock-guarded `Sender::send` per event; one
//! aggregator thread ("cfp-serve-telemetry") owns every histogram and
//! ring buffer, so the hot path never contends on shared counters and
//! the data structures need no synchronization of their own. Snapshots
//! are a request/response round trip through the same channel, which
//! makes them causally consistent: a snapshot observes every event the
//! requesting thread sent before asking.
//!
//! Determinism contract (pinned by `prop_histogram_determinism`):
//! [`Histogram`] buckets are fixed powers of two of a microsecond, so
//! `bucket_of` is a pure function of the value and `merge` is
//! element-wise `u64` addition — associative, commutative, and
//! bit-stable however many threads recorded and in whatever order their
//! shards are merged.

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;
use std::thread::JoinHandle;

use crate::util::Json;

/// Fixed bucket count: bucket 0 holds exact zeros, bucket `i` holds
/// values in `[2^(i-1), 2^i)` µs, and the last bucket absorbs the tail.
pub const HIST_BUCKETS: usize = 64;

/// Log-bucketed latency histogram over microsecond values (pure std,
/// fixed `u64` bucket counts — merging is element-wise addition).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Histogram {
    counts: [u64; HIST_BUCKETS],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram { counts: [0; HIST_BUCKETS], count: 0, sum_us: 0, max_us: 0 }
    }

    /// The bucket index for `us`: 0 for 0, else `floor(log2(us)) + 1`
    /// capped at the last bucket — a pure function of the value, so the
    /// bucket boundaries cannot drift with thread count or merge order.
    pub fn bucket_of(us: u64) -> usize {
        if us == 0 {
            return 0;
        }
        (64 - us.leading_zeros() as usize).min(HIST_BUCKETS - 1)
    }

    /// Inclusive upper bound of bucket `i` in µs (the value `quantile`
    /// reports when the quantile falls in bucket `i`).
    pub fn bucket_bound(i: usize) -> u64 {
        if i == 0 {
            0
        } else if i >= HIST_BUCKETS - 1 {
            u64::MAX
        } else {
            (1u64 << i) - 1
        }
    }

    pub fn record(&mut self, us: u64) {
        self.counts[Histogram::bucket_of(us)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.max_us = self.max_us.max(us);
    }

    /// Element-wise bucket addition — associative and commutative, so a
    /// histogram assembled from per-thread shards is bit-identical to
    /// one recorded sequentially, in any merge order.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Upper bound (µs) of the bucket containing the `q`-quantile
    /// observation (`0 < q <= 1`); 0 on an empty histogram. A pure
    /// function of the bucket counts, so merged shards report the same
    /// quantiles as a sequential recording — except the true maximum is
    /// reported for the last occupied bucket instead of `u64::MAX`.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                let bound = Histogram::bucket_bound(i);
                return bound.min(self.max_us);
            }
        }
        self.max_us
    }

    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .counts
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| Json::Arr(vec![Json::num(i as f64), Json::num(c as f64)]))
            .collect();
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("sum_us", Json::num(self.sum_us as f64)),
            ("max_us", Json::num(self.max_us as f64)),
            ("p50_us", Json::num(self.quantile(0.5) as f64)),
            ("p90_us", Json::num(self.quantile(0.9) as f64)),
            ("p99_us", Json::num(self.quantile(0.99) as f64)),
            ("buckets", Json::Arr(buckets)),
        ])
    }
}

/// Named ring buffer of recent stage-time samples (the named-sampler
/// shape): bounded memory however long the daemon runs, `last`/recent
/// mean for the stats view, a total count for reconciliation.
#[derive(Clone, Debug)]
pub struct Sampler {
    cap: usize,
    samples: VecDeque<f64>,
    total: u64,
}

impl Sampler {
    pub fn new(cap: usize) -> Sampler {
        Sampler { cap: cap.max(1), samples: VecDeque::new(), total: 0 }
    }

    pub fn record(&mut self, v: f64) {
        if self.samples.len() == self.cap {
            self.samples.pop_front();
        }
        self.samples.push_back(v);
        self.total += 1;
    }

    pub fn summary(&self) -> StageSummary {
        let n = self.samples.len();
        StageSummary {
            count: self.total,
            last: self.samples.back().copied().unwrap_or(0.0),
            mean_recent: if n == 0 {
                0.0
            } else {
                self.samples.iter().sum::<f64>() / n as f64
            },
        }
    }
}

/// One stage sampler's stats view.
#[derive(Clone, Debug, Default)]
pub struct StageSummary {
    /// samples ever recorded (not just the retained window)
    pub count: u64,
    pub last: f64,
    pub mean_recent: f64,
}

impl StageSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::num(self.count as f64)),
            ("last", Json::num(self.last)),
            ("mean_recent", Json::num(self.mean_recent)),
        ])
    }
}

/// Aggregator state copied out by [`Telemetry::snapshot`] — everything
/// the `stats` request and the drain report expose.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    /// per-request latency histograms by outcome stream
    /// (`plan`/`pipeline`/`stats`/`drain`/`error`/`rejected`)
    pub latency: BTreeMap<String, Histogram>,
    /// stage-time samplers (`search_us`, `profiling_us`, `analysis_us`)
    pub stages: BTreeMap<String, StageSummary>,
}

impl Snapshot {
    pub fn to_json(&self) -> Json {
        let latency =
            self.latency.iter().map(|(k, h)| (k.clone(), h.to_json())).collect::<BTreeMap<_, _>>();
        let stages =
            self.stages.iter().map(|(k, s)| (k.clone(), s.to_json())).collect::<BTreeMap<_, _>>();
        Json::Obj(BTreeMap::from([
            ("latency".to_string(), Json::Obj(latency)),
            ("stages".to_string(), Json::Obj(stages)),
        ]))
    }
}

enum Event {
    Latency { stream: &'static str, us: u64 },
    Stage { name: &'static str, us: f64 },
    Snapshot(Sender<Snapshot>),
}

/// The always-on telemetry hub: a channel into the aggregator thread.
/// Dropping the hub closes the channel and joins the thread.
#[derive(Debug)]
pub struct Telemetry {
    tx: Mutex<Option<Sender<Event>>>,
    handle: Option<JoinHandle<()>>,
}

impl Telemetry {
    pub fn start() -> Telemetry {
        let (tx, rx) = channel();
        let handle = std::thread::Builder::new()
            .name("cfp-serve-telemetry".into())
            .spawn(move || aggregate(rx))
            .ok();
        Telemetry { tx: Mutex::new(Some(tx)), handle }
    }

    fn send(&self, ev: Event) {
        let guard = self.tx.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(tx) = guard.as_ref() {
            let _ = tx.send(ev);
        }
    }

    pub fn record_latency(&self, stream: &'static str, us: u64) {
        self.send(Event::Latency { stream, us });
    }

    pub fn record_stage(&self, name: &'static str, us: f64) {
        self.send(Event::Stage { name, us });
    }

    /// Round-trip snapshot: observes every event this thread sent before
    /// asking (the channel is FIFO per sender).
    pub fn snapshot(&self) -> Snapshot {
        let (reply_tx, reply_rx) = channel();
        self.send(Event::Snapshot(reply_tx));
        reply_rx.recv().unwrap_or_default()
    }
}

impl Drop for Telemetry {
    fn drop(&mut self) {
        // close the channel first, or the join below would never return
        drop(self.tx.lock().unwrap_or_else(|e| e.into_inner()).take());
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn aggregate(rx: Receiver<Event>) {
    let mut latency: BTreeMap<&'static str, Histogram> = BTreeMap::new();
    let mut stages: BTreeMap<&'static str, Sampler> = BTreeMap::new();
    while let Ok(ev) = rx.recv() {
        match ev {
            Event::Latency { stream, us } => latency.entry(stream).or_default().record(us),
            Event::Stage { name, us } => {
                stages.entry(name).or_insert_with(|| Sampler::new(64)).record(us)
            }
            Event::Snapshot(reply) => {
                let snap = Snapshot {
                    latency: latency.iter().map(|(k, h)| (k.to_string(), h.clone())).collect(),
                    stages: stages.iter().map(|(k, s)| (k.to_string(), s.summary())).collect(),
                };
                let _ = reply.send(snap);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_powers_of_two() {
        assert_eq!(Histogram::bucket_of(0), 0);
        assert_eq!(Histogram::bucket_of(1), 1);
        assert_eq!(Histogram::bucket_of(2), 2);
        assert_eq!(Histogram::bucket_of(3), 2);
        assert_eq!(Histogram::bucket_of(4), 3);
        assert_eq!(Histogram::bucket_of(7), 3);
        assert_eq!(Histogram::bucket_of(8), 4);
        assert_eq!(Histogram::bucket_of(u64::MAX), HIST_BUCKETS - 1);
        // every bucket's bound lands back in that bucket
        for i in 1..HIST_BUCKETS - 1 {
            assert_eq!(Histogram::bucket_of(Histogram::bucket_bound(i)), i, "bucket {i}");
            assert_eq!(Histogram::bucket_of(Histogram::bucket_bound(i) + 1), i + 1);
        }
    }

    #[test]
    fn quantiles_walk_the_buckets() {
        let mut h = Histogram::new();
        for us in [1u64, 1, 1, 1, 1, 1, 1, 1, 1, 1000] {
            h.record(us);
        }
        assert_eq!(h.count(), 10);
        assert_eq!(h.quantile(0.5), 1);
        // the p99 observation is the 1000µs outlier; its bucket bound is
        // 1023 but the histogram knows its true max
        assert_eq!(h.quantile(0.99), 1000);
        assert_eq!(h.max_us(), 1000);
        let empty = Histogram::new();
        assert_eq!(empty.quantile(0.5), 0);
    }

    #[test]
    fn merge_equals_sequential_recording() {
        let vals = [0u64, 1, 2, 3, 5, 8, 100, 1000, 65_535, 65_536];
        let mut whole = Histogram::new();
        for &v in &vals {
            whole.record(v);
        }
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        for (i, &v) in vals.iter().enumerate() {
            if i % 2 == 0 { &mut a } else { &mut b }.record(v);
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab, whole, "merge == sequential");
        assert_eq!(ba, whole, "merge is commutative");
    }

    #[test]
    fn sampler_window_is_bounded_but_counts_everything() {
        let mut s = Sampler::new(4);
        for i in 0..10 {
            s.record(i as f64);
        }
        let sum = s.summary();
        assert_eq!(sum.count, 10);
        assert_eq!(sum.last, 9.0);
        assert_eq!(sum.mean_recent, (6.0 + 7.0 + 8.0 + 9.0) / 4.0);
    }

    #[test]
    fn hub_round_trips_events_through_the_aggregator() {
        let t = Telemetry::start();
        t.record_latency("plan", 5);
        t.record_latency("plan", 9);
        t.record_stage("search_us", 123.0);
        let snap = t.snapshot();
        let h = snap.latency.get("plan").expect("plan stream present");
        assert_eq!(h.count(), 2);
        assert_eq!(snap.stages.get("search_us").unwrap().count, 1);
        // snapshot JSON is well-formed and carries the quantile keys
        let j = snap.to_json();
        assert!(j.get("latency").unwrap().get("plan").unwrap().get("p50_us").is_some());
        drop(t); // joins the aggregator thread
    }
}
