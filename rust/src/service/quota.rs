//! Per-client admission control: token-bucket quotas keyed by the
//! optional `client` field of a serve request.
//!
//! Buckets are classic leaky tokens — `rate_per_s` tokens accrue per
//! second up to a `burst` cap, one token admits one plan/pipeline
//! request — and refill arithmetic runs on integer microsecond
//! timestamps so the same request trace admits the same prefix on every
//! run ([`TokenBucket::try_admit`] is a pure function of `(state,
//! now_us)`). `stats`/`drain` admin requests are never charged; a
//! request refused here gets a structured `overloaded` rejection, not a
//! dropped connection.

use std::collections::HashMap;
use std::time::Instant;

/// One client's token bucket. Starts full (a quiet client can always
/// burst up to `burst` requests immediately).
#[derive(Clone, Debug)]
pub struct TokenBucket {
    rate_per_s: f64,
    burst: f64,
    tokens: f64,
    last_us: u64,
}

impl TokenBucket {
    pub fn new(rate_per_s: f64, burst: f64) -> TokenBucket {
        let burst = burst.max(1.0);
        TokenBucket { rate_per_s: rate_per_s.max(0.0), burst, tokens: burst, last_us: 0 }
    }

    /// Refill for the elapsed time and spend one token if available.
    /// Deterministic in `(self, now_us)`; `now_us` must not decrease
    /// (a lagging clock is clamped to no refill, never a debit).
    pub fn try_admit(&mut self, now_us: u64) -> bool {
        let dt_us = now_us.saturating_sub(self.last_us);
        self.last_us = self.last_us.max(now_us);
        self.tokens = (self.tokens + self.rate_per_s * dt_us as f64 / 1e6).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    pub fn tokens(&self) -> f64 {
        self.tokens
    }
}

/// Bound on distinct client buckets; past it the most-replenished
/// (i.e. most idle) bucket is evicted, which can only ever *grant* a
/// forgotten client a fresh burst — never over-throttle.
const MAX_CLIENTS: usize = 4096;

/// Admission gate over all clients. The empty string is the bucket for
/// requests that carry no `client` field.
#[derive(Debug)]
pub struct QuotaGate {
    rate_per_s: f64,
    burst: f64,
    epoch: Instant,
    buckets: HashMap<String, TokenBucket>,
}

impl QuotaGate {
    pub fn new(rate_per_s: f64, burst: f64) -> QuotaGate {
        QuotaGate { rate_per_s, burst, epoch: Instant::now(), buckets: HashMap::new() }
    }

    /// Admit `client` at the current wall-clock offset.
    pub fn admit(&mut self, client: &str) -> bool {
        let now_us = self.epoch.elapsed().as_micros() as u64;
        self.admit_at(client, now_us)
    }

    /// Deterministic entry point used by tests: admit at an explicit
    /// microsecond offset from the gate's epoch.
    pub fn admit_at(&mut self, client: &str, now_us: u64) -> bool {
        if !self.buckets.contains_key(client) {
            if self.buckets.len() >= MAX_CLIENTS {
                self.evict_most_idle();
            }
            let mut fresh = TokenBucket::new(self.rate_per_s, self.burst);
            fresh.last_us = now_us;
            self.buckets.insert(client.to_string(), fresh);
        }
        self.buckets.get_mut(client).map_or(false, |b| b.try_admit(now_us))
    }

    fn evict_most_idle(&mut self) {
        let victim = self
            .buckets
            .iter()
            .max_by(|a, b| {
                a.1.tokens.partial_cmp(&b.1.tokens).unwrap_or(std::cmp::Ordering::Equal)
            })
            .map(|(k, _)| k.clone());
        if let Some(k) = victim {
            self.buckets.remove(&k);
        }
    }

    pub fn clients(&self) -> usize {
        self.buckets.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn refill_is_exact_at_the_boundary() {
        // rate 2 tokens/s from an empty bucket: 499_999µs accrues
        // 0.999998 tokens (deny); 500_000µs accrues exactly 1.0 (admit).
        // 0.5 * 2.0 is exact in binary floating point, so the boundary
        // is sharp, not approximate.
        let mut b = TokenBucket::new(2.0, 4.0);
        for _ in 0..4 {
            assert!(b.try_admit(0), "burst drains the full bucket");
        }
        assert!(!b.try_admit(0), "empty bucket denies");
        let mut just_under = b.clone();
        assert!(!just_under.try_admit(499_999), "0.999998 tokens is not one");
        let mut at = b.clone();
        assert!(at.try_admit(500_000), "exactly 1.0 token admits");
        assert_eq!(at.tokens(), 0.0, "the boundary admit spends the whole token");
    }

    #[test]
    fn refill_caps_at_burst() {
        let mut b = TokenBucket::new(1000.0, 2.0);
        assert!(b.try_admit(0));
        // an hour of refill still caps at burst=2
        assert!(b.try_admit(3_600_000_000));
        assert!(b.try_admit(3_600_000_000));
        assert!(!b.try_admit(3_600_000_000), "cap held: only 2 tokens were available");
    }

    #[test]
    fn clock_regression_never_debits() {
        let mut b = TokenBucket::new(1.0, 1.0);
        assert!(b.try_admit(5_000_000));
        assert!(!b.try_admit(1_000), "lagging clock refills nothing");
        assert!(b.tokens() >= 0.0);
    }

    #[test]
    fn clients_are_throttled_independently() {
        let mut g = QuotaGate::new(1.0, 2.0);
        // greedy drains its bucket; quiet's bucket is untouched
        assert!(g.admit_at("greedy", 0));
        assert!(g.admit_at("greedy", 0));
        assert!(!g.admit_at("greedy", 0));
        assert!(g.admit_at("quiet", 0));
        assert!(g.admit_at("quiet", 0));
        assert_eq!(g.clients(), 2);
        // greedy recovers after a full second
        assert!(g.admit_at("greedy", 1_000_000));
    }

    #[test]
    fn anonymous_requests_share_one_bucket() {
        let mut g = QuotaGate::new(1.0, 1.0);
        assert!(g.admit_at("", 0));
        assert!(!g.admit_at("", 0));
        assert_eq!(g.clients(), 1);
    }

    #[test]
    fn fresh_clients_start_full_not_back_dated() {
        let mut g = QuotaGate::new(1.0, 1.0);
        // first contact late in the gate's life must not grant
        // `now * rate` phantom tokens beyond burst
        assert!(g.admit_at("late", 100_000_000));
        assert!(!g.admit_at("late", 100_000_000), "burst=1: second request denied");
    }
}
