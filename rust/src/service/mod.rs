//! Plan-serving subsystem: the long-running `cfp serve` daemon.
//!
//! CFP's economics make plan search cheap enough to run routinely
//! (paper §5.5) — this module makes it cheap enough to *serve*: a
//! process that answers planning requests over NDJSON (stdin/stdout and
//! a `--listen` TCP socket, [`PlanService::listen`]) with three layers
//! of reuse stacked on the planner:
//!
//! ```text
//!   line ──▶ parse ──▶ canonicalize ──▶ plan cache ──▶ single-flight ──▶ worker
//!              │             │            (LRU)        (coalesce N      (run_cfp*
//!              ▼             ▼               │          identical        via shared
//!          structured   CfpOptions::         │          in-flight        ProfileDb)
//!          error        from_args            ▼          requests)           │
//!          response     (same builder     hit: reply        │               ▼
//!                        as the CLI)      immediately       ▼            respond
//!                                                      followers wait,
//!                                                      leader searches
//! ```
//!
//! * **Plan cache** — completed payloads keyed by
//!   [`request::canonical_key`], LRU-bounded (`--plan-cache`). A hit
//!   answers without planning at all.
//! * **Single-flight coalescing** — N identical in-flight requests
//!   trigger exactly one search; followers block on the leader's flight
//!   and receive the same `Arc`'d, bit-identical payload.
//! * **Shared profile cache** — every search profiles through one
//!   process-wide [`SharedProfileCache`], so concurrent plans for
//!   overlapping segments reuse each other's profiles instead of
//!   re-profiling (and persist across restarts with `--cache`).
//!
//! The production serving tier wraps that core in four layers:
//!
//! * **Lifecycle** — `accepting → draining → drained`. A
//!   `{"type": "drain"}` admin request (or stdin EOF, the pure-std
//!   SIGTERM equivalent) moves the service to *draining*: admission
//!   stops with structured `draining` rejections, every in-flight
//!   search finishes and is answered, state is flushed, and a
//!   [`DrainReport`] summarizes the run. See [`PlanService::drain`].
//! * **Persistent plan cache** (`--plan-cache-file`, [`plancache`]) —
//!   the LRU plan map flushed through the `profiler::cache` lock-file +
//!   atomic-rename machinery, so a warm restart serves byte-identical
//!   plans with zero searches.
//! * **Quotas and backpressure** ([`quota`]) — per-`client` token-bucket
//!   admission (`--quota`/`--quota-burst`) plus a bounded pending queue
//!   (`--max-pending`) that rejects with structured `overloaded`
//!   responses instead of queueing without bound. A shared-secret
//!   credential gate (`--auth-token`) sits between the lifecycle and
//!   quota gates and refuses mismatches with structured `unauthorized`
//!   rejections.
//! * **Always-on telemetry** ([`telemetry`]) — per-request latency
//!   histograms and stage-time samplers drained by a background
//!   aggregator thread, surfaced in `stats` responses and the drain
//!   report.
//!
//! Determinism contract: for any request, the served payload is
//! byte-identical to what the one-shot CLI path produces for the same
//! options — guarded by `rust/tests/integration_service.rs` and
//! `integration_serve_faults.rs` (which extends the property across
//! restarts). Counters (`requests`, `received`, `admitted`, `rejected`,
//! `plan_hits`, `plan_misses`, `coalesced`, `searches`, `profile_hits`,
//! `profile_misses`, `errors`) surface in every response's `cache` tag
//! and in the `stats` request type, and reconcile exactly:
//! `received == admitted + rejected + coalesced`.

pub mod plancache;
pub mod quota;
pub mod request;
mod server;
pub mod telemetry;

pub use request::{
    canonical_key, parse_request, pipeline_payload, plan_payload, PlanRequest, RequestKind,
};
pub use server::{shared_writer, SharedWriter};
pub use telemetry::{Histogram, Snapshot, Telemetry};

use std::collections::{BTreeMap, HashMap};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::AtomicUsize;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use crate::coordinator::{
    run_cfp_shared, run_cfp_two_level_shared, validate_pipeline_args, CfpOptions,
};
use crate::profiler::SharedProfileCache;
use crate::util::{Json, ThreadPool};

use quota::QuotaGate;

/// `cfp serve` configuration (all CLI flags of the subcommand).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// bounded worker pool executing searches (`--workers`)
    pub workers: usize,
    /// LRU bound on cached plan payloads; 0 disables (`--plan-cache`)
    pub plan_cache_entries: usize,
    /// persistent profile-cache file shared by every worker (`--cache`)
    pub cache_path: Option<std::path::PathBuf>,
    /// LRU bound on the profile cache (`--cache-max-entries`)
    pub cache_max_entries: Option<usize>,
    /// profiling threads per search (`--threads`) — a service-level
    /// knob, deliberately not requestable per request
    pub search_threads: usize,
    /// persistent plan-cache file (`--plan-cache-file`): loaded at
    /// startup, flushed after every search and at drain, so plans
    /// survive restarts
    pub plan_cache_file: Option<std::path::PathBuf>,
    /// per-client token-bucket admission as `(rate_per_s, burst)`
    /// (`--quota`/`--quota-burst`); `None` admits everything
    pub quota: Option<(f64, f64)>,
    /// bound on requests queued ahead of the worker pool
    /// (`--max-pending`); past it plan work is rejected `overloaded`
    /// inline instead of queueing without bound; 0 disables the gate
    pub max_pending: usize,
    /// shared-secret admission credential (`--auth-token`): when set,
    /// plan/pipeline requests must carry a matching `auth` field or are
    /// refused with a structured `unauthorized` rejection; `None`
    /// admits everything (admin requests are never gated — operators
    /// must always be able to observe and drain)
    pub auth_token: Option<String>,
    /// Chrome trace-event output (`--trace-out`): the service's shared
    /// obs trace is rewritten to this file after every executed search,
    /// so the file always holds the run-to-date spans and counters.
    /// Served plan payloads are byte-identical with or without it.
    pub trace_out: Option<std::path::PathBuf>,
    /// TCP read deadline per connection (`--read-timeout`, seconds;
    /// 0 = none). `None` by default: idle interactive clients are legal
    /// and must not be disconnected.
    pub read_timeout: Option<std::time::Duration>,
    /// TCP write deadline per connection (`--write-timeout`, seconds;
    /// 0 = none). Defaults to 30s so a dead or wedged peer that stops
    /// reading can never hang a worker forever mid-response.
    pub write_timeout: Option<std::time::Duration>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            workers: 4,
            plan_cache_entries: 128,
            cache_path: None,
            cache_max_entries: None,
            search_threads: 1,
            plan_cache_file: None,
            quota: None,
            max_pending: 1024,
            auth_token: None,
            trace_out: None,
            read_timeout: None,
            write_timeout: Some(std::time::Duration::from_secs(30)),
        }
    }
}

/// Where the service is in its life. Admission is only open in
/// `Accepting`; `drain` moves through `Draining` (finish in-flight,
/// flush) to `Drained` (terminal).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lifecycle {
    Accepting,
    Draining,
    Drained,
}

impl Lifecycle {
    pub fn as_str(self) -> &'static str {
        match self {
            Lifecycle::Accepting => "accepting",
            Lifecycle::Draining => "draining",
            Lifecycle::Drained => "drained",
        }
    }
}

/// Service counters (the `stats` request type and the harness's
/// cache-effectiveness columns).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServiceStats {
    pub requests: u64,
    /// plan/pipeline requests that reached admission (valid requests;
    /// parse/validation failures never get here)
    pub received: u64,
    /// requests admitted past the lifecycle and quota gates that were
    /// answered by a cache hit or by leading a search;
    /// `received == admitted + rejected + coalesced` always
    pub admitted: u64,
    /// requests refused with a structured rejection (`reason` field);
    /// `rejected == rejected_overload + rejected_draining +
    /// rejected_unauthorized`
    pub rejected: u64,
    /// rejections from the quota gate or the bounded pending queue
    pub rejected_overload: u64,
    /// rejections because the service was draining/drained
    pub rejected_draining: u64,
    /// rejections from the `--auth-token` credential gate (missing or
    /// mismatched `auth` field)
    pub rejected_unauthorized: u64,
    /// answered from the plan cache without planning
    pub plan_hits: u64,
    /// requests that claimed a flight (each runs one search)
    pub plan_misses: u64,
    /// requests that joined an existing in-flight search
    pub coalesced: u64,
    /// searches actually executed (== plan_misses; both kept so the
    /// single-flight invariant is externally checkable)
    pub searches: u64,
    /// structured error responses (parse, validation, planner panic)
    pub errors: u64,
    /// unique segments served from the shared profile cache, summed
    /// over every executed search
    pub profile_hits: u64,
    /// unique segments actually profiled, summed over every search
    pub profile_misses: u64,
    /// cumulative wall-clock µs spent inside plan search (ComposeSearch
    /// + inter-op planning), summed over every executed search — lets a
    /// serving deployment observe search-side speedups; plan hits and
    /// coalesced followers add nothing here
    pub search_us: u64,
}

impl ServiceStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("received", Json::num(self.received as f64)),
            ("admitted", Json::num(self.admitted as f64)),
            ("rejected", Json::num(self.rejected as f64)),
            ("rejected_overload", Json::num(self.rejected_overload as f64)),
            ("rejected_draining", Json::num(self.rejected_draining as f64)),
            ("rejected_unauthorized", Json::num(self.rejected_unauthorized as f64)),
            ("plan_hits", Json::num(self.plan_hits as f64)),
            ("plan_misses", Json::num(self.plan_misses as f64)),
            ("coalesced", Json::num(self.coalesced as f64)),
            ("searches", Json::num(self.searches as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("profile_hits", Json::num(self.profile_hits as f64)),
            ("profile_misses", Json::num(self.profile_misses as f64)),
            ("search_us", Json::num(self.search_us as f64)),
        ])
    }
}

/// A search's published outcome: the payload, or an error message (a
/// planner panic turned structured — never cached).
type Payload = Result<Arc<Json>, String>;

/// One in-flight search. The leader computes and publishes into `slot`;
/// followers wait on `done`.
struct Flight {
    slot: Mutex<Option<Payload>>,
    done: Condvar,
}

struct PlanState {
    /// completed payloads by canonical key, with LRU stamps
    plans: BTreeMap<String, (Arc<Json>, u64)>,
    clock: u64,
    /// searches currently running, by canonical key
    inflight: HashMap<String, Arc<Flight>>,
    stats: ServiceStats,
    lifecycle: Lifecycle,
    /// admitted plan/pipeline requests between admission and response —
    /// what `drain` waits to reach zero
    active_plans: usize,
    /// per-client token buckets (`None` admits everything)
    quota: Option<QuotaGate>,
}

struct ServiceInner {
    cfg: ServeConfig,
    profiles: SharedProfileCache,
    state: Mutex<PlanState>,
    pool: ThreadPool,
    telemetry: Telemetry,
    /// paired with `state`: signaled when `active_plans`/`inflight`
    /// shrink or the lifecycle advances
    quiesced: Condvar,
    /// requests dispatched to the pool but not yet answered — the
    /// bounded pending queue's gauge (see `server.rs`)
    pending: AtomicUsize,
    /// always-on shared obs trace: every search counts into it, and
    /// `stats` responses surface the counter snapshot under `obs`.
    /// Counters are deterministic sums, so the snapshot after a fixed
    /// request set is identical whichever worker ran which search.
    trace: crate::obs::Trace,
    /// test instrumentation — see [`PlanService::set_search_hook`]
    hook: Mutex<Option<Arc<dyn Fn() + Send + Sync>>>,
}

/// The plan-serving daemon. Cheap to clone (one `Arc`); every clone
/// shares the caches, counters, and worker pool.
#[derive(Clone)]
pub struct PlanService {
    inner: Arc<ServiceInner>,
}

impl PlanService {
    pub fn new(cfg: ServeConfig) -> PlanService {
        let profiles = match &cfg.cache_path {
            Some(p) => SharedProfileCache::open(p),
            None => SharedProfileCache::in_memory(),
        };
        profiles.set_max_entries(cfg.cache_max_entries);
        let pool = ThreadPool::new(cfg.workers.max(1));
        // warm start: a persisted plan cache makes every plan it holds a
        // zero-search hit. A missing/torn/mismatched file loads as
        // nothing at all (plancache::load) — a restart can cost
        // re-searching, never a wrong plan.
        let (mut plans, mut clock) = (BTreeMap::new(), 0u64);
        if cfg.plan_cache_entries > 0 {
            if let Some(path) = &cfg.plan_cache_file {
                if let Some((loaded, loaded_clock)) = plancache::load(path) {
                    plans = loaded;
                    clock = loaded_clock;
                    while plans.len() > cfg.plan_cache_entries {
                        let lru =
                            plans.iter().min_by_key(|(_, v)| v.1).map(|(k, _)| k.clone());
                        let Some(k) = lru else { break };
                        plans.remove(&k);
                    }
                }
            }
        }
        let gate = cfg.quota.map(|(rate, burst)| QuotaGate::new(rate, burst));
        PlanService {
            inner: Arc::new(ServiceInner {
                cfg,
                profiles,
                state: Mutex::new(PlanState {
                    plans,
                    clock,
                    inflight: HashMap::new(),
                    stats: ServiceStats::default(),
                    lifecycle: Lifecycle::Accepting,
                    active_plans: 0,
                    quota: gate,
                }),
                pool,
                telemetry: Telemetry::start(),
                quiesced: Condvar::new(),
                pending: AtomicUsize::new(0),
                trace: crate::obs::Trace::enabled(),
                hook: Mutex::new(None),
            }),
        }
    }

    /// Handle one NDJSON request line synchronously and return the
    /// response line (no trailing newline). Never panics: parse errors,
    /// invalid options, and planner panics all become structured error
    /// responses. Every line's wall-clock is recorded into the latency
    /// histogram of its outcome stream.
    pub fn handle_line(&self, line: &str) -> String {
        let t0 = std::time::Instant::now();
        let (resp, stream) = self.dispatch(line);
        self.inner.telemetry.record_latency(stream, t0.elapsed().as_micros() as u64);
        resp
    }

    fn dispatch(&self, line: &str) -> (String, &'static str) {
        self.lock_state().stats.requests += 1;
        let req = match request::parse_request(line) {
            Ok(r) => r,
            Err(e) => {
                // best-effort id echo so clients matching responses by id
                // can attribute the failure (line must still be JSON)
                let id = Json::parse(line).ok().and_then(|j| j.get("id").cloned());
                return (self.error_response(id.as_ref(), None, &e), "error");
            }
        };
        match req.kind {
            RequestKind::Stats => {
                let payload = self.stats_payload();
                (envelope(req.id.as_ref(), RequestKind::Stats, None, &payload), "stats")
            }
            RequestKind::Drain => {
                let report = self.drain();
                (envelope(req.id.as_ref(), RequestKind::Drain, None, &report.to_json()), "drain")
            }
            RequestKind::Plan | RequestKind::Pipeline => self.handle_plan(req),
        }
    }

    fn handle_plan(&self, req: PlanRequest) -> (String, &'static str) {
        let built = match CfpOptions::from_args(&req.args, req.kind.planner()) {
            Ok(b) => b,
            Err(e) => return (self.error_response(req.id.as_ref(), None, &e), "error"),
        };
        if !built.warnings.is_empty() {
            // the CLI warns, falls back to defaults, and proceeds; a
            // server must never silently reinterpret a request, so the
            // same findings reject it outright
            let msg = format!("invalid request: {}", built.warnings.join("; "));
            return (self.error_response(req.id.as_ref(), None, &msg), "error");
        }
        if req.kind == RequestKind::Pipeline {
            if let Err(e) = validate_pipeline_args(&req.args, &built.opts) {
                return (self.error_response(req.id.as_ref(), None, &e), "error");
            }
        }
        let mut opts = built.opts;
        // searches run on the service's thread budget and through its
        // shared profile cache; per-request cache flags were rejected at
        // parse time (not in the request schema)
        opts.threads = self.inner.cfg.search_threads;
        opts.cache_path = None;
        opts.cache_max_entries = None;
        let key = request::canonical_key(req.kind, &opts);
        // admission: one lock hold makes the lifecycle gate, the quota
        // charge, and the in-flight accounting a single atomic decision
        let client = req.client.as_deref().unwrap_or("");
        {
            let mut guard = self.lock_state();
            let st = &mut *guard;
            st.stats.received += 1;
            if st.lifecycle != Lifecycle::Accepting {
                st.stats.rejected += 1;
                st.stats.rejected_draining += 1;
                let resp = reject_response(
                    req.id.as_ref(),
                    "draining",
                    "service is draining; new requests are not accepted",
                );
                return (resp, "rejected");
            }
            // credential gate sits before the quota gate: a request with
            // a bad secret must not drain the client's token bucket
            if let Some(token) = self.inner.cfg.auth_token.as_deref() {
                if req.auth.as_deref() != Some(token) {
                    st.stats.rejected += 1;
                    st.stats.rejected_unauthorized += 1;
                    let resp = reject_response(
                        req.id.as_ref(),
                        "unauthorized",
                        "missing or invalid auth token",
                    );
                    return (resp, "rejected");
                }
            }
            if let Some(gate) = st.quota.as_mut() {
                if !gate.admit(client) {
                    st.stats.rejected += 1;
                    st.stats.rejected_overload += 1;
                    let resp = reject_response(
                        req.id.as_ref(),
                        "overloaded",
                        &format!("client {client:?} is over its admission quota; retry later"),
                    );
                    return (resp, "rejected");
                }
            }
            st.active_plans += 1;
        }
        let (payload, tag) = self.get_or_compute(&key, req.kind, &opts);
        {
            let mut st = self.lock_state();
            st.active_plans -= 1;
            // a drain may be waiting for the in-flight count to reach 0
            self.inner.quiesced.notify_all();
        }
        match payload {
            Ok(p) => (envelope(req.id.as_ref(), req.kind, Some(tag), &p), req.kind.as_str()),
            Err(e) => (self.error_response(req.id.as_ref(), Some(tag), &e), "error"),
        }
    }

    /// The plan-cache + single-flight core. Exactly one caller per key
    /// computes at a time; the rest are answered from the cache or from
    /// the in-flight leader's published payload.
    fn get_or_compute(
        &self,
        key: &str,
        kind: RequestKind,
        opts: &CfpOptions,
    ) -> (Payload, &'static str) {
        enum Role {
            Hit(Arc<Json>),
            Lead(Arc<Flight>),
            Wait(Arc<Flight>),
        }
        let role = {
            let mut guard = self.lock_state();
            let st = &mut *guard;
            st.clock += 1;
            let clock = st.clock;
            if let Some(entry) = st.plans.get_mut(key) {
                entry.1 = clock;
                st.stats.plan_hits += 1;
                st.stats.admitted += 1;
                Role::Hit(entry.0.clone())
            } else if let Some(flight) = st.inflight.get(key) {
                st.stats.coalesced += 1;
                Role::Wait(flight.clone())
            } else {
                st.stats.plan_misses += 1;
                st.stats.admitted += 1;
                let flight = Arc::new(Flight { slot: Mutex::new(None), done: Condvar::new() });
                st.inflight.insert(key.to_string(), flight.clone());
                Role::Lead(flight)
            }
        };
        match role {
            Role::Hit(p) => (Ok(p), "hit"),
            Role::Wait(flight) => {
                let mut slot = flight.slot.lock().unwrap_or_else(|e| e.into_inner());
                while slot.is_none() {
                    slot = flight.done.wait(slot).unwrap_or_else(|e| e.into_inner());
                }
                // the wait loop only exits once the leader published, so
                // an empty slot is unreachable — but it must degrade to a
                // structured error, not a worker panic (flight_drop fault
                // forces this path)
                let published = slot
                    .clone()
                    .filter(|_| !crate::util::failpoint::should_trip("serve.flight_drop"));
                let payload = published.unwrap_or_else(|| {
                    Err("internal_error: flight closed without publishing".to_string())
                });
                (payload, "coalesced")
            }
            Role::Lead(flight) => {
                let hook = self.inner.hook.lock().unwrap_or_else(|e| e.into_inner()).clone();
                if let Some(h) = hook {
                    h();
                }
                self.lock_state().stats.searches += 1;
                let outcome = catch_unwind(AssertUnwindSafe(|| self.run_planner(kind, opts)));
                let payload: Payload = match outcome {
                    Ok(json) => Ok(Arc::new(json)),
                    Err(p) => Err(format!("planner panicked: {}", panic_msg(&p))),
                };
                {
                    let mut slot = flight.slot.lock().unwrap_or_else(|e| e.into_inner());
                    *slot = Some(payload.clone());
                    flight.done.notify_all();
                }
                {
                    let mut guard = self.lock_state();
                    let st = &mut *guard;
                    st.inflight.remove(key);
                    // a drain may be waiting for in-flight searches
                    self.inner.quiesced.notify_all();
                    if let Ok(p) = &payload {
                        if self.inner.cfg.plan_cache_entries > 0 {
                            st.clock += 1;
                            st.plans.insert(key.to_string(), (p.clone(), st.clock));
                            while st.plans.len() > self.inner.cfg.plan_cache_entries {
                                let lru = st
                                    .plans
                                    .iter()
                                    .min_by_key(|(_, v)| v.1)
                                    .map(|(k, _)| k.clone());
                                let Some(k) = lru else { break };
                                st.plans.remove(&k);
                            }
                        }
                    }
                }
                // durability for a long-running daemon: persist freshly
                // profiled segments and freshly planned payloads after
                // every search (no-ops without backing files; failure is
                // logged, never fatal)
                if payload.is_ok() {
                    if let Err(e) = self.inner.profiles.save() {
                        crate::obs::diag::diag(&format!(
                            "cfp serve: could not persist profile cache: {e}"
                        ));
                    }
                    self.save_plan_cache();
                }
                (payload, "miss")
            }
        }
    }

    fn run_planner(&self, kind: RequestKind, opts: &CfpOptions) -> Json {
        // the shared obs trace rides along on a clone of the options —
        // it is not part of the plan-cache key and never shapes the
        // payload (pinned by `prop_trace_determinism`)
        let opts = opts.clone().with_trace(self.inner.trace.clone());
        let payload = self.run_planner_traced(kind, &opts);
        if let Some(path) = &self.inner.cfg.trace_out {
            if let Err(e) = self.inner.trace.write_chrome(path) {
                crate::obs::diag::diag(&format!(
                    "cfp serve: could not write trace to {}: {e}",
                    path.display()
                ));
            }
        }
        payload
    }

    fn run_planner_traced(&self, kind: RequestKind, opts: &CfpOptions) -> Json {
        match kind {
            RequestKind::Plan => {
                let r = run_cfp_shared(opts, &self.inner.profiles);
                self.absorb_search_stats(
                    r.db.stats.cache_hits,
                    r.db.stats.cache_misses,
                    r.timings.compose_search_s * 1e6,
                );
                self.inner
                    .telemetry
                    .record_stage("profiling_us", (r.timings.metrics_profiling_s * 1e6).max(0.0));
                self.inner
                    .telemetry
                    .record_stage("analysis_us", (r.timings.analysis_passes_s * 1e6).max(0.0));
                request::plan_payload(&r)
            }
            RequestKind::Pipeline => {
                let r = run_cfp_two_level_shared(opts, &self.inner.profiles);
                self.absorb_search_stats(r.profile_hits, r.profile_misses, r.search_us);
                request::pipeline_payload(&r)
            }
            RequestKind::Stats | RequestKind::Drain => {
                unreachable!("admin requests are answered without planning")
            }
        }
    }

    fn absorb_search_stats(&self, hits: usize, misses: usize, search_us: f64) {
        {
            let mut st = self.lock_state();
            st.stats.profile_hits += hits as u64;
            st.stats.profile_misses += misses as u64;
            st.stats.search_us += search_us.max(0.0) as u64;
        }
        self.inner.telemetry.record_stage("search_us", search_us.max(0.0));
    }

    /// Structured response for a request whose worker died before
    /// `handle_line` could run (pool-level `catch_unwind`). The request
    /// never reached admission, so only `requests` and `errors` move —
    /// the admission ledger (`received == admitted + rejected +
    /// coalesced`) is untouched and still reconciles exactly.
    fn internal_error_line(&self, line: &str, panic: &str) -> String {
        self.lock_state().stats.requests += 1;
        let id = Json::parse(line).ok().and_then(|j| j.get("id").cloned());
        self.error_response(id.as_ref(), None, &format!("internal_error: {panic}"))
    }

    fn error_response(&self, id: Option<&Json>, tag: Option<&'static str>, msg: &str) -> String {
        self.lock_state().stats.errors += 1;
        let mut pairs = vec![("ok", Json::Bool(false)), ("error", Json::str(msg))];
        if let Some(id) = id {
            pairs.push(("id", id.clone()));
        }
        if let Some(tag) = tag {
            pairs.push(("cache", Json::str(tag)));
        }
        Json::obj(pairs).to_string()
    }

    /// Counters snapshot.
    pub fn stats(&self) -> ServiceStats {
        self.lock_state().stats.clone()
    }

    /// The `stats` response body: the counters plus the lifecycle state
    /// and a telemetry snapshot.
    fn stats_payload(&self) -> Json {
        let (stats, lifecycle) = {
            let st = self.lock_state();
            (st.stats.clone(), st.lifecycle)
        };
        let mut j = annotate(stats.to_json(), lifecycle, &self.inner.telemetry.snapshot());
        // fold the obs counter snapshot into the ledger (stats responses
        // only — plan payload envelopes stay byte-identical)
        if let Json::Obj(m) = &mut j {
            let counters: Vec<(&str, Json)> = self
                .inner
                .trace
                .snapshot()
                .into_iter()
                .map(|(k, v)| (k, Json::num(v as f64)))
                .collect();
            m.insert("obs".to_string(), Json::obj(counters));
            // per-site fault-injection audit: present only when armed,
            // so disarmed stats responses stay byte-identical
            if let Some(faults) = crate::obs::fault_counters_json() {
                m.insert("faults".to_string(), faults);
            }
        }
        j
    }

    /// Current lifecycle state.
    pub fn lifecycle(&self) -> Lifecycle {
        self.lock_state().lifecycle
    }

    /// Drain the service: stop admitting plan work (structured
    /// `draining` rejections), wait for every in-flight search to finish
    /// and answer, flush the profile and plan caches, and report.
    /// Idempotent — concurrent and repeated drains all block until the
    /// service is quiesced and return the same-shaped report. `stats`
    /// and further `drain` requests keep working after the drain.
    pub fn drain(&self) -> DrainReport {
        {
            let mut st = self.lock_state();
            if st.lifecycle == Lifecycle::Accepting {
                st.lifecycle = Lifecycle::Draining;
            }
            // every request admitted before the gate closed still gets
            // its answer: wait for admitted work and in-flight searches
            while st.active_plans > 0 || !st.inflight.is_empty() {
                st = self.inner.quiesced.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        }
        // flush outside the state lock — savers take their own file locks
        self.flush();
        let telemetry = self.inner.telemetry.snapshot();
        let mut st = self.lock_state();
        st.lifecycle = Lifecycle::Drained;
        self.inner.quiesced.notify_all();
        DrainReport { stats: st.stats.clone(), telemetry }
    }

    /// Block until a drain (triggered elsewhere: admin request, stdin
    /// EOF) has fully completed.
    pub fn wait_drained(&self) {
        let mut st = self.lock_state();
        while st.lifecycle != Lifecycle::Drained {
            st = self.inner.quiesced.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// The process-wide profile cache every search shares.
    pub fn profile_cache(&self) -> &SharedProfileCache {
        &self.inner.profiles
    }

    /// Persist the shared profile cache (also done after every search).
    pub fn save(&self) -> std::io::Result<()> {
        self.inner.profiles.save()
    }

    /// Persist both caches (profile + plan); failures are logged, never
    /// fatal — persistence is an optimization, correctness never
    /// depends on it.
    fn flush(&self) {
        if let Err(e) = self.inner.profiles.save() {
            crate::obs::diag::diag(&format!("cfp serve: could not persist profile cache: {e}"));
        }
        self.save_plan_cache();
    }

    fn save_plan_cache(&self) {
        let Some(path) = &self.inner.cfg.plan_cache_file else { return };
        let (plans, clock) = {
            let st = self.lock_state();
            (st.plans.clone(), st.clock)
        };
        if let Err(e) = plancache::save(path, &plans, clock, self.inner.cfg.plan_cache_entries) {
            crate::obs::diag::diag(&format!("cfp serve: could not persist plan cache: {e}"));
        }
    }

    /// The bounded-pending-queue rejection path, used by `serve_stream`
    /// when the pool's backlog exceeds `max_pending`: plan/pipeline work
    /// is refused inline with a structured `overloaded` response;
    /// admin requests (`stats`, `drain`) and unparseable lines return
    /// `None` and are dispatched normally — backpressure must never
    /// block the operator's view or the drain path.
    fn reject_overloaded_line(&self, line: &str) -> Option<String> {
        let req = request::parse_request(line).ok()?;
        if !matches!(req.kind, RequestKind::Plan | RequestKind::Pipeline) {
            return None;
        }
        {
            let mut st = self.lock_state();
            st.stats.requests += 1;
            st.stats.received += 1;
            st.stats.rejected += 1;
            st.stats.rejected_overload += 1;
        }
        Some(reject_response(req.id.as_ref(), "overloaded", "pending queue is full; retry later"))
    }

    /// Test instrumentation: run `hook` on the single-flight leader
    /// after it has claimed the flight and before its search runs. The
    /// concurrency suite uses it to hold the leader until every follower
    /// has registered, making `coalesced == N - 1` deterministic rather
    /// than timing-dependent.
    #[doc(hidden)]
    pub fn set_search_hook(&self, hook: Arc<dyn Fn() + Send + Sync>) {
        *self.inner.hook.lock().unwrap_or_else(|e| e.into_inner()) = Some(hook);
    }

    fn lock_state(&self) -> MutexGuard<'_, PlanState> {
        self.inner.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Success envelope. Key order in the output is alphabetical (the JSON
/// writer sorts object keys), so envelopes are byte-stable too.
fn envelope(id: Option<&Json>, kind: RequestKind, tag: Option<&str>, result: &Json) -> String {
    let mut pairs = vec![
        ("ok", Json::Bool(true)),
        ("kind", Json::str(kind.as_str())),
        ("result", result.clone()),
    ];
    if let Some(tag) = tag {
        pairs.push(("cache", Json::str(tag)));
    }
    if let Some(id) = id {
        pairs.push(("id", id.clone()));
    }
    Json::obj(pairs).to_string()
}

/// Structured rejection: `ok: false` with a machine-readable `reason`
/// (`draining` | `overloaded` | `unauthorized`). Distinct from
/// [`PlanService::error_response`]
/// — a rejection is the service refusing valid work, not the request
/// being wrong, so it does not count as an error.
fn reject_response(id: Option<&Json>, reason: &str, msg: &str) -> String {
    let mut pairs = vec![
        ("ok", Json::Bool(false)),
        ("error", Json::str(msg)),
        ("reason", Json::str(reason)),
    ];
    if let Some(id) = id {
        pairs.push(("id", id.clone()));
    }
    Json::obj(pairs).to_string()
}

/// Extend a counters object with the lifecycle state and a telemetry
/// snapshot (the shared body of `stats` responses and drain reports).
fn annotate(stats: Json, lifecycle: Lifecycle, telemetry: &Snapshot) -> Json {
    let mut m = match stats {
        Json::Obj(m) => m,
        other => return other,
    };
    m.insert("lifecycle".to_string(), Json::str(lifecycle.as_str()));
    m.insert("telemetry".to_string(), telemetry.to_json());
    Json::Obj(m)
}

/// What a completed drain hands back: the final counters and the full
/// telemetry picture of the run.
#[derive(Clone, Debug)]
pub struct DrainReport {
    pub stats: ServiceStats,
    pub telemetry: Snapshot,
}

impl DrainReport {
    pub fn to_json(&self) -> Json {
        annotate(self.stats.to_json(), Lifecycle::Drained, &self.telemetry)
    }

    /// One human-readable line for stderr at process exit.
    pub fn summary_line(&self) -> String {
        let s = &self.stats;
        let (p50, p99) = self
            .telemetry
            .latency
            .get("plan")
            .map_or((0, 0), |h| (h.quantile(0.5), h.quantile(0.99)));
        format!(
            "cfp serve: drained — {} requests ({} admitted, {} rejected, {} coalesced), \
             {} searches ({} µs searching), plan latency p50 {p50} µs p99 {p99} µs",
            s.requests, s.admitted, s.rejected, s.coalesced, s.searches, s.search_us
        )
    }
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ServeConfig {
        ServeConfig { workers: 2, ..ServeConfig::default() }
    }

    fn line() -> &'static str {
        "{\"id\": 1, \"type\": \"plan\", \"model\": \"gpt-tiny\", \"platform\": \"a100-pcie\"}"
    }

    #[test]
    fn miss_then_hit_with_identical_payload() {
        let svc = PlanService::new(tiny());
        let a = Json::parse(&svc.handle_line(line())).unwrap();
        let b = Json::parse(&svc.handle_line(line())).unwrap();
        assert_eq!(a.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(a.get("cache").and_then(Json::as_str), Some("miss"));
        assert_eq!(b.get("cache").and_then(Json::as_str), Some("hit"));
        assert_eq!(a.get("id"), b.get("id"));
        assert_eq!(
            a.get("result").unwrap().to_string(),
            b.get("result").unwrap().to_string(),
            "hit serves the bit-identical payload"
        );
        let s = svc.stats();
        assert_eq!((s.plan_misses, s.plan_hits, s.searches), (1, 1, 1));
        assert_eq!(s.requests, 2);
    }

    #[test]
    fn stats_request_reports_counters() {
        let svc = PlanService::new(tiny());
        svc.handle_line(line());
        let resp = Json::parse(&svc.handle_line("{\"type\": \"stats\", \"id\": 9}")).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(resp.get("kind").and_then(Json::as_str), Some("stats"));
        let r = resp.get("result").unwrap();
        assert_eq!(r.get("searches").and_then(Json::as_u64), Some(1));
        assert_eq!(r.get("requests").and_then(Json::as_u64), Some(2));
        assert!(r.get("profile_misses").and_then(Json::as_u64).unwrap() > 0);
        // cumulative search time is reported (a cache hit adds nothing)
        let search_us = r.get("search_us").and_then(Json::as_u64).expect("search_us counter");
        svc.handle_line(line());
        assert_eq!(svc.stats().search_us, search_us, "plan hits never search");
    }

    #[test]
    fn errors_are_structured_and_counted() {
        let svc = PlanService::new(tiny());
        let resp = svc.handle_line("{\"model\": \"no-such-model\", \"id\": 3}");
        let j = Json::parse(&resp).expect("error responses are valid JSON");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
        assert!(j.get("error").and_then(Json::as_str).unwrap().contains("no-such-model"));
        assert_eq!(j.get("id").and_then(Json::as_i64), Some(3));
        assert_eq!(svc.stats().errors, 1);
        assert_eq!(svc.stats().searches, 0, "bad requests never reach the planner");
    }

    #[test]
    fn plan_cache_lru_bound_holds() {
        let svc = PlanService::new(ServeConfig {
            workers: 1,
            plan_cache_entries: 2,
            ..ServeConfig::default()
        });
        let req = |layers: usize| {
            format!("{{\"type\": \"plan\", \"model\": \"gpt-tiny\", \"layers\": {layers}}}")
        };
        for layers in [2usize, 3, 4] {
            svc.handle_line(&req(layers));
        }
        // layers=2 was evicted (LRU); layers=4 is still cached
        let again4 = svc.handle_line(&req(4));
        assert_eq!(Json::parse(&again4).unwrap().get("cache").and_then(Json::as_str), Some("hit"));
        let again2 = svc.handle_line(&req(2));
        assert_eq!(
            Json::parse(&again2).unwrap().get("cache").and_then(Json::as_str),
            Some("miss"),
            "evicted entries are planned again"
        );
        // ...but the profile cache still makes the re-plan warm
        let s = svc.stats();
        assert!(s.profile_hits > 0, "re-planning reuses shared profiles");
    }

    fn reconciles(s: &ServiceStats) {
        assert_eq!(
            s.received,
            s.admitted + s.rejected + s.coalesced,
            "admission counters must reconcile exactly: {s:?}"
        );
        assert_eq!(
            s.rejected,
            s.rejected_overload + s.rejected_draining + s.rejected_unauthorized,
            "{s:?}"
        );
        assert_eq!(s.admitted, s.plan_hits + s.plan_misses, "{s:?}");
    }

    #[test]
    fn drain_quiesces_rejects_new_work_and_is_idempotent() {
        let svc = PlanService::new(tiny());
        svc.handle_line(line());
        assert_eq!(svc.lifecycle(), Lifecycle::Accepting);
        let report = svc.drain();
        assert_eq!(svc.lifecycle(), Lifecycle::Drained);
        assert_eq!(report.stats.admitted, 1);
        assert_eq!(report.stats.rejected, 0);
        assert!(report.telemetry.latency.contains_key("plan"), "latency was recorded");
        assert!(report.summary_line().contains("drained"));

        // new plan work is refused with a structured `draining` reason,
        // and is a rejection, not an error
        let resp = Json::parse(&svc.handle_line(line())).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(resp.get("reason").and_then(Json::as_str), Some("draining"));
        let s = svc.stats();
        assert_eq!((s.rejected, s.rejected_draining, s.errors), (1, 1, 0));
        reconciles(&s);

        // admin requests still work; a second drain returns, not hangs
        let stats_resp = Json::parse(&svc.handle_line("{\"type\": \"stats\"}")).unwrap();
        assert_eq!(
            stats_resp.get("result").unwrap().get("lifecycle").and_then(Json::as_str),
            Some("drained")
        );
        let again = Json::parse(&svc.handle_line("{\"type\": \"drain\"}")).unwrap();
        assert_eq!(again.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(again.get("kind").and_then(Json::as_str), Some("drain"));
    }

    #[test]
    fn greedy_client_is_throttled_while_quiet_client_succeeds() {
        // a near-zero refill rate makes the outcome deterministic: each
        // client has exactly its burst of 2 tokens for the whole test
        let svc = PlanService::new(ServeConfig {
            workers: 2,
            quota: Some((0.001, 2.0)),
            ..ServeConfig::default()
        });
        let req = |client: &str, n: usize| {
            format!(
                "{{\"id\": {n}, \"type\": \"plan\", \"model\": \"gpt-tiny\", \
                 \"client\": \"{client}\"}}"
            )
        };
        let mut greedy_ok = 0;
        let mut greedy_overloaded = 0;
        for n in 0..5 {
            let resp = Json::parse(&svc.handle_line(&req("greedy", n))).unwrap();
            match resp.get("reason").and_then(Json::as_str) {
                Some("overloaded") => greedy_overloaded += 1,
                None => {
                    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
                    greedy_ok += 1;
                }
                other => panic!("unexpected reason {other:?}"),
            }
        }
        assert_eq!((greedy_ok, greedy_overloaded), (2, 3), "burst=2 admits exactly 2");
        // the quiet client's bucket is untouched by greedy's overload
        for n in 0..2 {
            let resp = Json::parse(&svc.handle_line(&req("quiet", n))).unwrap();
            assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true), "quiet req {n}");
        }
        let s = svc.stats();
        assert_eq!(s.received, 7);
        assert_eq!(s.admitted, 4);
        assert_eq!((s.rejected, s.rejected_overload), (3, 3));
        assert_eq!(s.errors, 0, "rejections are not errors");
        reconciles(&s);
    }

    #[test]
    fn stats_payload_carries_lifecycle_and_telemetry() {
        let svc = PlanService::new(tiny());
        svc.handle_line(line());
        let resp = Json::parse(&svc.handle_line("{\"type\": \"stats\"}")).unwrap();
        let r = resp.get("result").unwrap();
        assert_eq!(r.get("lifecycle").and_then(Json::as_str), Some("accepting"));
        let plan_hist = r.get("telemetry").unwrap().get("latency").unwrap().get("plan");
        let plan_hist = plan_hist.expect("plan latency stream present");
        assert_eq!(plan_hist.get("count").and_then(Json::as_u64), Some(1));
        assert!(plan_hist.get("p50_us").is_some());
        let stages = r.get("telemetry").unwrap().get("stages").unwrap();
        assert!(
            stages.get("search_us").is_some(),
            "stage samplers are drained by the aggregator: {stages:?}"
        );
        // counter fields stay top-level (back-compat with PR 4 clients)
        assert_eq!(r.get("received").and_then(Json::as_u64), Some(1));
        assert_eq!(r.get("admitted").and_then(Json::as_u64), Some(1));
        // the obs counter snapshot rides in stats responses only
        let obs = r.get("obs").expect("obs counters in stats");
        assert!(obs.get("segment_instances").and_then(Json::as_u64).unwrap() > 0);
        assert!(obs.get("pareto_states").and_then(Json::as_u64).unwrap() > 0);
    }

    #[test]
    fn auth_token_gates_admission_and_counts_rejections() {
        let svc = PlanService::new(ServeConfig {
            workers: 1,
            auth_token: Some("s3cret".to_string()),
            ..ServeConfig::default()
        });
        // missing credential → structured unauthorized rejection
        let resp = Json::parse(&svc.handle_line(line())).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
        assert_eq!(resp.get("reason").and_then(Json::as_str), Some("unauthorized"));
        // wrong credential → same rejection
        let wrong = "{\"type\": \"plan\", \"model\": \"gpt-tiny\", \"auth\": \"nope\"}";
        let resp = Json::parse(&svc.handle_line(wrong)).unwrap();
        assert_eq!(resp.get("reason").and_then(Json::as_str), Some("unauthorized"));
        // matching credential is admitted and planned
        let ok = "{\"type\": \"plan\", \"model\": \"gpt-tiny\", \"auth\": \"s3cret\"}";
        let resp = Json::parse(&svc.handle_line(ok)).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        // admin requests are never gated — the operator can always look
        let stats = Json::parse(&svc.handle_line("{\"type\": \"stats\"}")).unwrap();
        assert_eq!(stats.get("ok").and_then(Json::as_bool), Some(true));
        let s = svc.stats();
        assert_eq!((s.rejected, s.rejected_unauthorized), (2, 2));
        assert_eq!(s.admitted, 1);
        assert_eq!(s.errors, 0, "an auth rejection is not an error");
        reconciles(&s);
        // the ledger surfaces the new counter
        let r = stats.get("result").unwrap();
        assert_eq!(r.get("rejected_unauthorized").and_then(Json::as_u64), Some(2));
    }

    #[test]
    fn without_a_configured_token_auth_fields_are_ignored() {
        let svc = PlanService::new(tiny());
        let with_auth = "{\"type\": \"plan\", \"model\": \"gpt-tiny\", \"auth\": \"whatever\"}";
        let resp = Json::parse(&svc.handle_line(with_auth)).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(svc.stats().rejected_unauthorized, 0);
    }

    #[test]
    fn queue_gate_rejects_only_plan_work() {
        let svc = PlanService::new(tiny());
        let rej = svc.reject_overloaded_line(line()).expect("plan work is rejectable");
        let j = Json::parse(&rej).unwrap();
        assert_eq!(j.get("reason").and_then(Json::as_str), Some("overloaded"));
        assert_eq!(j.get("id").and_then(Json::as_i64), Some(1), "id echoed");
        assert!(svc.reject_overloaded_line("{\"type\": \"stats\"}").is_none());
        assert!(svc.reject_overloaded_line("{\"type\": \"drain\"}").is_none());
        assert!(svc.reject_overloaded_line("{not json").is_none());
        let s = svc.stats();
        assert_eq!((s.received, s.rejected_overload), (1, 1));
        reconciles(&s);
    }
}
