//! Plan-serving subsystem: the long-running `cfp serve` daemon.
//!
//! CFP's economics make plan search cheap enough to run routinely
//! (paper §5.5) — this module makes it cheap enough to *serve*: a
//! process that answers planning requests over NDJSON (stdin/stdout and
//! a `--listen` TCP socket, [`PlanService::listen`]) with three layers
//! of reuse stacked on the planner:
//!
//! ```text
//!   line ──▶ parse ──▶ canonicalize ──▶ plan cache ──▶ single-flight ──▶ worker
//!              │             │            (LRU)        (coalesce N      (run_cfp*
//!              ▼             ▼               │          identical        via shared
//!          structured   CfpOptions::         │          in-flight        ProfileDb)
//!          error        from_args            ▼          requests)           │
//!          response     (same builder     hit: reply        │               ▼
//!                        as the CLI)      immediately       ▼            respond
//!                                                      followers wait,
//!                                                      leader searches
//! ```
//!
//! * **Plan cache** — completed payloads keyed by
//!   [`request::canonical_key`], LRU-bounded (`--plan-cache`). A hit
//!   answers without planning at all.
//! * **Single-flight coalescing** — N identical in-flight requests
//!   trigger exactly one search; followers block on the leader's flight
//!   and receive the same `Arc`'d, bit-identical payload.
//! * **Shared profile cache** — every search profiles through one
//!   process-wide [`SharedProfileCache`], so concurrent plans for
//!   overlapping segments reuse each other's profiles instead of
//!   re-profiling (and persist across restarts with `--cache`).
//!
//! Determinism contract: for any request, the served payload is
//! byte-identical to what the one-shot CLI path produces for the same
//! options — guarded by `rust/tests/integration_service.rs`. Counters
//! (`requests`, `plan_hits`, `plan_misses`, `coalesced`, `searches`,
//! `profile_hits`, `profile_misses`, `errors`) surface in every
//! response's `cache` tag and in the `stats` request type.

pub mod request;
mod server;

pub use request::{
    canonical_key, parse_request, pipeline_payload, plan_payload, PlanRequest, RequestKind,
};
pub use server::{shared_writer, SharedWriter};

use std::collections::{BTreeMap, HashMap};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use crate::coordinator::{
    run_cfp_shared, run_cfp_two_level_shared, validate_pipeline_args, CfpOptions,
};
use crate::profiler::SharedProfileCache;
use crate::util::{Json, ThreadPool};

/// `cfp serve` configuration (all CLI flags of the subcommand).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// bounded worker pool executing searches (`--workers`)
    pub workers: usize,
    /// LRU bound on cached plan payloads; 0 disables (`--plan-cache`)
    pub plan_cache_entries: usize,
    /// persistent profile-cache file shared by every worker (`--cache`)
    pub cache_path: Option<std::path::PathBuf>,
    /// LRU bound on the profile cache (`--cache-max-entries`)
    pub cache_max_entries: Option<usize>,
    /// profiling threads per search (`--threads`) — a service-level
    /// knob, deliberately not requestable per request
    pub search_threads: usize,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            workers: 4,
            plan_cache_entries: 128,
            cache_path: None,
            cache_max_entries: None,
            search_threads: 1,
        }
    }
}

/// Service counters (the `stats` request type and the harness's
/// cache-effectiveness columns).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServiceStats {
    pub requests: u64,
    /// answered from the plan cache without planning
    pub plan_hits: u64,
    /// requests that claimed a flight (each runs one search)
    pub plan_misses: u64,
    /// requests that joined an existing in-flight search
    pub coalesced: u64,
    /// searches actually executed (== plan_misses; both kept so the
    /// single-flight invariant is externally checkable)
    pub searches: u64,
    /// structured error responses (parse, validation, planner panic)
    pub errors: u64,
    /// unique segments served from the shared profile cache, summed
    /// over every executed search
    pub profile_hits: u64,
    /// unique segments actually profiled, summed over every search
    pub profile_misses: u64,
    /// cumulative wall-clock µs spent inside plan search (ComposeSearch
    /// + inter-op planning), summed over every executed search — lets a
    /// serving deployment observe search-side speedups; plan hits and
    /// coalesced followers add nothing here
    pub search_us: u64,
}

impl ServiceStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("plan_hits", Json::num(self.plan_hits as f64)),
            ("plan_misses", Json::num(self.plan_misses as f64)),
            ("coalesced", Json::num(self.coalesced as f64)),
            ("searches", Json::num(self.searches as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("profile_hits", Json::num(self.profile_hits as f64)),
            ("profile_misses", Json::num(self.profile_misses as f64)),
            ("search_us", Json::num(self.search_us as f64)),
        ])
    }
}

/// A search's published outcome: the payload, or an error message (a
/// planner panic turned structured — never cached).
type Payload = Result<Arc<Json>, String>;

/// One in-flight search. The leader computes and publishes into `slot`;
/// followers wait on `done`.
struct Flight {
    slot: Mutex<Option<Payload>>,
    done: Condvar,
}

struct PlanState {
    /// completed payloads by canonical key, with LRU stamps
    plans: BTreeMap<String, (Arc<Json>, u64)>,
    clock: u64,
    /// searches currently running, by canonical key
    inflight: HashMap<String, Arc<Flight>>,
    stats: ServiceStats,
}

struct ServiceInner {
    cfg: ServeConfig,
    profiles: SharedProfileCache,
    state: Mutex<PlanState>,
    pool: ThreadPool,
    /// test instrumentation — see [`PlanService::set_search_hook`]
    hook: Mutex<Option<Arc<dyn Fn() + Send + Sync>>>,
}

/// The plan-serving daemon. Cheap to clone (one `Arc`); every clone
/// shares the caches, counters, and worker pool.
#[derive(Clone)]
pub struct PlanService {
    inner: Arc<ServiceInner>,
}

impl PlanService {
    pub fn new(cfg: ServeConfig) -> PlanService {
        let profiles = match &cfg.cache_path {
            Some(p) => SharedProfileCache::open(p),
            None => SharedProfileCache::in_memory(),
        };
        profiles.set_max_entries(cfg.cache_max_entries);
        let pool = ThreadPool::new(cfg.workers.max(1));
        PlanService {
            inner: Arc::new(ServiceInner {
                cfg,
                profiles,
                state: Mutex::new(PlanState {
                    plans: BTreeMap::new(),
                    clock: 0,
                    inflight: HashMap::new(),
                    stats: ServiceStats::default(),
                }),
                pool,
                hook: Mutex::new(None),
            }),
        }
    }

    /// Handle one NDJSON request line synchronously and return the
    /// response line (no trailing newline). Never panics: parse errors,
    /// invalid options, and planner panics all become structured error
    /// responses.
    pub fn handle_line(&self, line: &str) -> String {
        self.lock_state().stats.requests += 1;
        let req = match request::parse_request(line) {
            Ok(r) => r,
            Err(e) => {
                // best-effort id echo so clients matching responses by id
                // can attribute the failure (line must still be JSON)
                let id = Json::parse(line).ok().and_then(|j| j.get("id").cloned());
                return self.error_response(id.as_ref(), None, &e);
            }
        };
        if req.kind == RequestKind::Stats {
            let stats = self.stats();
            return envelope(req.id.as_ref(), RequestKind::Stats, None, &stats.to_json());
        }
        self.handle_plan(req)
    }

    fn handle_plan(&self, req: PlanRequest) -> String {
        let built = match CfpOptions::from_args(&req.args, req.kind.planner()) {
            Ok(b) => b,
            Err(e) => return self.error_response(req.id.as_ref(), None, &e),
        };
        if !built.warnings.is_empty() {
            // the CLI warns, falls back to defaults, and proceeds; a
            // server must never silently reinterpret a request, so the
            // same findings reject it outright
            let msg = format!("invalid request: {}", built.warnings.join("; "));
            return self.error_response(req.id.as_ref(), None, &msg);
        }
        if req.kind == RequestKind::Pipeline {
            if let Err(e) = validate_pipeline_args(&req.args, &built.opts) {
                return self.error_response(req.id.as_ref(), None, &e);
            }
        }
        let mut opts = built.opts;
        // searches run on the service's thread budget and through its
        // shared profile cache; per-request cache flags were rejected at
        // parse time (not in the request schema)
        opts.threads = self.inner.cfg.search_threads;
        opts.cache_path = None;
        opts.cache_max_entries = None;
        let key = request::canonical_key(req.kind, &opts);
        let (payload, tag) = self.get_or_compute(&key, req.kind, &opts);
        match payload {
            Ok(p) => envelope(req.id.as_ref(), req.kind, Some(tag), &p),
            Err(e) => self.error_response(req.id.as_ref(), Some(tag), &e),
        }
    }

    /// The plan-cache + single-flight core. Exactly one caller per key
    /// computes at a time; the rest are answered from the cache or from
    /// the in-flight leader's published payload.
    fn get_or_compute(
        &self,
        key: &str,
        kind: RequestKind,
        opts: &CfpOptions,
    ) -> (Payload, &'static str) {
        enum Role {
            Hit(Arc<Json>),
            Lead(Arc<Flight>),
            Wait(Arc<Flight>),
        }
        let role = {
            let mut guard = self.lock_state();
            let st = &mut *guard;
            st.clock += 1;
            let clock = st.clock;
            if let Some(entry) = st.plans.get_mut(key) {
                entry.1 = clock;
                st.stats.plan_hits += 1;
                Role::Hit(entry.0.clone())
            } else if let Some(flight) = st.inflight.get(key) {
                st.stats.coalesced += 1;
                Role::Wait(flight.clone())
            } else {
                st.stats.plan_misses += 1;
                let flight = Arc::new(Flight { slot: Mutex::new(None), done: Condvar::new() });
                st.inflight.insert(key.to_string(), flight.clone());
                Role::Lead(flight)
            }
        };
        match role {
            Role::Hit(p) => (Ok(p), "hit"),
            Role::Wait(flight) => {
                let mut slot = flight.slot.lock().unwrap_or_else(|e| e.into_inner());
                while slot.is_none() {
                    slot = flight.done.wait(slot).unwrap_or_else(|e| e.into_inner());
                }
                (slot.clone().expect("flight published"), "coalesced")
            }
            Role::Lead(flight) => {
                let hook = self.inner.hook.lock().unwrap_or_else(|e| e.into_inner()).clone();
                if let Some(h) = hook {
                    h();
                }
                self.lock_state().stats.searches += 1;
                let outcome = catch_unwind(AssertUnwindSafe(|| self.run_planner(kind, opts)));
                let payload: Payload = match outcome {
                    Ok(json) => Ok(Arc::new(json)),
                    Err(p) => Err(format!("planner panicked: {}", panic_msg(&p))),
                };
                {
                    let mut slot = flight.slot.lock().unwrap_or_else(|e| e.into_inner());
                    *slot = Some(payload.clone());
                    flight.done.notify_all();
                }
                {
                    let mut guard = self.lock_state();
                    let st = &mut *guard;
                    st.inflight.remove(key);
                    if let Ok(p) = &payload {
                        if self.inner.cfg.plan_cache_entries > 0 {
                            st.clock += 1;
                            st.plans.insert(key.to_string(), (p.clone(), st.clock));
                            while st.plans.len() > self.inner.cfg.plan_cache_entries {
                                let lru = st
                                    .plans
                                    .iter()
                                    .min_by_key(|(_, v)| v.1)
                                    .map(|(k, _)| k.clone());
                                let Some(k) = lru else { break };
                                st.plans.remove(&k);
                            }
                        }
                    }
                }
                // durability for a long-running daemon: persist freshly
                // profiled segments after every search (no-op without a
                // backing file; failure is logged, never fatal)
                if payload.is_ok() {
                    if let Err(e) = self.inner.profiles.save() {
                        eprintln!("cfp serve: could not persist profile cache: {e}");
                    }
                }
                (payload, "miss")
            }
        }
    }

    fn run_planner(&self, kind: RequestKind, opts: &CfpOptions) -> Json {
        match kind {
            RequestKind::Plan => {
                let r = run_cfp_shared(opts, &self.inner.profiles);
                self.absorb_search_stats(
                    r.db.stats.cache_hits,
                    r.db.stats.cache_misses,
                    r.timings.compose_search_s * 1e6,
                );
                request::plan_payload(&r)
            }
            RequestKind::Pipeline => {
                let r = run_cfp_two_level_shared(opts, &self.inner.profiles);
                self.absorb_search_stats(r.profile_hits, r.profile_misses, r.search_us);
                request::pipeline_payload(&r)
            }
            RequestKind::Stats => unreachable!("stats requests are answered without planning"),
        }
    }

    fn absorb_search_stats(&self, hits: usize, misses: usize, search_us: f64) {
        let mut st = self.lock_state();
        st.stats.profile_hits += hits as u64;
        st.stats.profile_misses += misses as u64;
        st.stats.search_us += search_us.max(0.0) as u64;
    }

    fn error_response(&self, id: Option<&Json>, tag: Option<&'static str>, msg: &str) -> String {
        self.lock_state().stats.errors += 1;
        let mut pairs = vec![("ok", Json::Bool(false)), ("error", Json::str(msg))];
        if let Some(id) = id {
            pairs.push(("id", id.clone()));
        }
        if let Some(tag) = tag {
            pairs.push(("cache", Json::str(tag)));
        }
        Json::obj(pairs).to_string()
    }

    /// Counters snapshot.
    pub fn stats(&self) -> ServiceStats {
        self.lock_state().stats.clone()
    }

    /// The process-wide profile cache every search shares.
    pub fn profile_cache(&self) -> &SharedProfileCache {
        &self.inner.profiles
    }

    /// Persist the shared profile cache (also done after every search).
    pub fn save(&self) -> std::io::Result<()> {
        self.inner.profiles.save()
    }

    /// Test instrumentation: run `hook` on the single-flight leader
    /// after it has claimed the flight and before its search runs. The
    /// concurrency suite uses it to hold the leader until every follower
    /// has registered, making `coalesced == N - 1` deterministic rather
    /// than timing-dependent.
    #[doc(hidden)]
    pub fn set_search_hook(&self, hook: Arc<dyn Fn() + Send + Sync>) {
        *self.inner.hook.lock().unwrap_or_else(|e| e.into_inner()) = Some(hook);
    }

    fn lock_state(&self) -> MutexGuard<'_, PlanState> {
        self.inner.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Success envelope. Key order in the output is alphabetical (the JSON
/// writer sorts object keys), so envelopes are byte-stable too.
fn envelope(id: Option<&Json>, kind: RequestKind, tag: Option<&str>, result: &Json) -> String {
    let mut pairs = vec![
        ("ok", Json::Bool(true)),
        ("kind", Json::str(kind.as_str())),
        ("result", result.clone()),
    ];
    if let Some(tag) = tag {
        pairs.push(("cache", Json::str(tag)));
    }
    if let Some(id) = id {
        pairs.push(("id", id.clone()));
    }
    Json::obj(pairs).to_string()
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ServeConfig {
        ServeConfig { workers: 2, ..ServeConfig::default() }
    }

    fn line() -> &'static str {
        "{\"id\": 1, \"type\": \"plan\", \"model\": \"gpt-tiny\", \"platform\": \"a100-pcie\"}"
    }

    #[test]
    fn miss_then_hit_with_identical_payload() {
        let svc = PlanService::new(tiny());
        let a = Json::parse(&svc.handle_line(line())).unwrap();
        let b = Json::parse(&svc.handle_line(line())).unwrap();
        assert_eq!(a.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(a.get("cache").and_then(Json::as_str), Some("miss"));
        assert_eq!(b.get("cache").and_then(Json::as_str), Some("hit"));
        assert_eq!(a.get("id"), b.get("id"));
        assert_eq!(
            a.get("result").unwrap().to_string(),
            b.get("result").unwrap().to_string(),
            "hit serves the bit-identical payload"
        );
        let s = svc.stats();
        assert_eq!((s.plan_misses, s.plan_hits, s.searches), (1, 1, 1));
        assert_eq!(s.requests, 2);
    }

    #[test]
    fn stats_request_reports_counters() {
        let svc = PlanService::new(tiny());
        svc.handle_line(line());
        let resp = Json::parse(&svc.handle_line("{\"type\": \"stats\", \"id\": 9}")).unwrap();
        assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(resp.get("kind").and_then(Json::as_str), Some("stats"));
        let r = resp.get("result").unwrap();
        assert_eq!(r.get("searches").and_then(Json::as_u64), Some(1));
        assert_eq!(r.get("requests").and_then(Json::as_u64), Some(2));
        assert!(r.get("profile_misses").and_then(Json::as_u64).unwrap() > 0);
        // cumulative search time is reported (a cache hit adds nothing)
        let search_us = r.get("search_us").and_then(Json::as_u64).expect("search_us counter");
        svc.handle_line(line());
        assert_eq!(svc.stats().search_us, search_us, "plan hits never search");
    }

    #[test]
    fn errors_are_structured_and_counted() {
        let svc = PlanService::new(tiny());
        let resp = svc.handle_line("{\"model\": \"no-such-model\", \"id\": 3}");
        let j = Json::parse(&resp).expect("error responses are valid JSON");
        assert_eq!(j.get("ok").and_then(Json::as_bool), Some(false));
        assert!(j.get("error").and_then(Json::as_str).unwrap().contains("no-such-model"));
        assert_eq!(j.get("id").and_then(Json::as_i64), Some(3));
        assert_eq!(svc.stats().errors, 1);
        assert_eq!(svc.stats().searches, 0, "bad requests never reach the planner");
    }

    #[test]
    fn plan_cache_lru_bound_holds() {
        let svc = PlanService::new(ServeConfig {
            workers: 1,
            plan_cache_entries: 2,
            ..ServeConfig::default()
        });
        let req = |layers: usize| {
            format!("{{\"type\": \"plan\", \"model\": \"gpt-tiny\", \"layers\": {layers}}}")
        };
        for layers in [2usize, 3, 4] {
            svc.handle_line(&req(layers));
        }
        // layers=2 was evicted (LRU); layers=4 is still cached
        let again4 = svc.handle_line(&req(4));
        assert_eq!(Json::parse(&again4).unwrap().get("cache").and_then(Json::as_str), Some("hit"));
        let again2 = svc.handle_line(&req(2));
        assert_eq!(
            Json::parse(&again2).unwrap().get("cache").and_then(Json::as_str),
            Some("miss"),
            "evicted entries are planned again"
        );
        // ...but the profile cache still makes the re-plan warm
        let s = svc.stats();
        assert!(s.profile_hits > 0, "re-planning reuses shared profiles");
    }
}
