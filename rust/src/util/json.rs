//! Minimal JSON parser + writer (serde_json is not in the vendor set).
//!
//! Covers the full JSON grammar; used for `artifacts/manifest.json`, plan
//! files, and experiment logs. Numbers are kept as f64 (i64 fast-path via
//! [`Json::as_i64`]), which is sufficient for every schema in this repo.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---------------------------------------------------------------- parse

    pub fn parse(s: &str) -> Result<Json, String> {
        let bytes = s.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    // ------------------------------------------------------------ accessors

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    /// Strict non-negative integer accessor: rejects fractional values and
    /// anything above 2^53 (where f64 stops being exact) rather than
    /// truncating/saturating — corrupt data must fail parsing, not flow on.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64()
            .filter(|f| *f >= 0.0 && f.fract() == 0.0 && *f <= 9_007_199_254_740_992.0)
            .map(|f| f as u64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // -------------------------------------------------------------- build

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // -------------------------------------------------------------- write

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run
                    let start = self.i;
                    while self
                        .peek()
                        .map_or(false, |c| c != b'"' && c != b'\\')
                    {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            map.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""hi\nthere""#).unwrap(), Json::Str("hi\nthere".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"c"}],"d":{}}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(1).unwrap().as_i64(), Some(2));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn round_trips() {
        let src = r#"{"arr":[1,2.5,"x",null,true],"obj":{"k":"v \"q\""},"n":-7}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn u64_accessor_is_strict() {
        assert_eq!(Json::parse("42").unwrap().as_u64(), Some(42));
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("3.7").unwrap().as_u64(), None, "no truncation");
        assert_eq!(Json::parse("1e30").unwrap().as_u64(), None, "no saturation");
        let big = (1u64 << 52) + 3;
        assert_eq!(Json::parse(&big.to_string()).unwrap().as_u64(), Some(big));
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap(), Json::Str("Aé".into()));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"[{"name":"calib_matmul_64x64x64","file":"f.hlo.txt",
                       "kind":"calib_matmul","inputs":[{"name":"a","shape":[64,64],
                       "dtype":"float32"}],"outputs":[],"meta":{"flops":524288}}]"#;
        let j = Json::parse(src).unwrap();
        let e = j.idx(0).unwrap();
        assert_eq!(e.get("kind").unwrap().as_str(), Some("calib_matmul"));
        assert_eq!(e.get("meta").unwrap().get("flops").unwrap().as_i64(), Some(524288));
        assert_eq!(
            e.get("inputs").unwrap().idx(0).unwrap().get("shape").unwrap().idx(0).unwrap().as_i64(),
            Some(64)
        );
    }
}
