//! Work-stealing-free but fully functional scoped thread pool (tokio is not
//! in the vendor set; the profiler's compile∥profile overlap from paper
//! §4.3 runs on this).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                thread::Builder::new()
                    .name(format!("cfp-pool-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break,
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool {
            tx: Some(tx),
            workers,
            size,
        }
    }

    /// Pool sized to the machine (leaving one core for the coordinator).
    pub fn with_default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        Self::new(n.saturating_sub(1).max(1))
    }

    pub fn size(&self) -> usize {
        self.size
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("pool send");
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        let f = Arc::new(f);
        let (tx, rx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|r| r.expect("worker died")).collect()
    }

    /// [`ThreadPool::map`] with batched dispatch: items are split into
    /// ~4 chunks per worker so sub-millisecond jobs amortize the per-job
    /// channel overhead (§Perf: fine-grained dispatch made threads=4
    /// SLOWER than serial). Order is preserved.
    pub fn map_chunked<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        if items.is_empty() {
            return Vec::new();
        }
        let chunk = (items.len() / (self.size * 4)).max(1);
        let mut chunks: Vec<Vec<T>> = Vec::new();
        let mut items = items.into_iter();
        loop {
            let c: Vec<T> = items.by_ref().take(chunk).collect();
            if c.is_empty() {
                break;
            }
            chunks.push(c);
        }
        let f = Arc::new(f);
        self.map(chunks, move |chunk: Vec<T>| -> Vec<R> {
            chunk.into_iter().map(|t| (*f)(t)).collect()
        })
        .into_iter()
        .flatten()
        .collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        drop(tx);
        for _ in 0..100 {
            rx.recv().unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..64).collect::<Vec<i32>>(), |x| x * x);
        assert_eq!(out, (0..64).map(|x| x * x).collect::<Vec<_>>());
    }

    #[test]
    fn map_chunked_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map_chunked((0..257).collect::<Vec<i64>>(), |x| x * 2 + 1);
        assert_eq!(out, (0..257).map(|x| x * 2 + 1).collect::<Vec<_>>());
        let empty = pool.map_chunked(Vec::<i64>::new(), |x| x);
        assert!(empty.is_empty());
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(2);
        pool.execute(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }
}
