//! Deterministic PRNG (PCG-XSH-RR 64/32 extended to 64-bit output).
//!
//! Used everywhere randomness is needed: synthetic workloads, parameter
//! init for the e2e trainer, property-test input generation. Seeded — every
//! experiment in EXPERIMENTS.md is reproducible bit-for-bit.

#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MULT: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        let mut p = Pcg64 {
            state: 0,
            inc: ((seed as u128) << 1) | 1,
        };
        p.next_u64();
        p.state = p.state.wrapping_add(0xda3e39cb94b95bdb ^ (seed as u128));
        p.next_u64();
        p
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, n). Unbiased via rejection sampling.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_in_unit_interval_with_sane_mean() {
        let mut r = Pcg64::new(3);
        let xs: Vec<f64> = (0..10_000).map(|_| r.f64()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
