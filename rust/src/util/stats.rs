//! Small numeric helpers shared by the profiler and the bench harness.

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

pub fn rmse(pred: &[f64], actual: &[f64]) -> f64 {
    assert_eq!(pred.len(), actual.len());
    if pred.is_empty() {
        return 0.0;
    }
    let se: f64 = pred.iter().zip(actual).map(|(p, a)| (p - a).powi(2)).sum();
    (se / pred.len() as f64).sqrt()
}

/// Least-squares fit y ≈ a·x + b. Returns (a, b).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    if xs.is_empty() {
        return (0.0, 0.0);
    }
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-30 {
        return (0.0, sy / n);
    }
    let a = (n * sxy - sx * sy) / denom;
    (a, (sy - a * sx) / n)
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    let mx = mean(xs);
    let my = mean(ys);
    let cov: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let vx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    let vy: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    if vx <= 0.0 || vy <= 0.0 {
        return 0.0;
    }
    cov / (vx.sqrt() * vy.sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert!((stddev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.138).abs() < 1e-2);
    }

    #[test]
    fn rmse_zero_for_exact() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn linfit_recovers_line() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 * x + 7.0).collect();
        let (a, b) = linfit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9 && (b - 7.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_signs() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let up: Vec<f64> = xs.iter().map(|x| 2.0 * x).collect();
        let down: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!((pearson(&xs, &up) - 1.0).abs() < 1e-12);
        assert!((pearson(&xs, &down) + 1.0).abs() < 1e-12);
    }
}
