//! Tiny CLI argument parser (clap is not in the vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positionals —
//! enough for the `cfp` binary and every example/bench driver.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map_or(false, |n| !n.starts_with("--")) {
                    out.options.insert(body.to_string(), it.next().unwrap());
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Optional integer option with no default (e.g.
    /// `--cache-max-entries N`): None when absent or unparseable.
    pub fn get_usize_opt(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    /// Optional float option with no default (e.g. `--mem-cap 12.5`):
    /// None when absent or unparseable.
    pub fn get_f64_opt(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Filesystem-path option (e.g. `--cache .cfp/profiles.json`).
    pub fn get_path(&self, key: &str) -> Option<std::path::PathBuf> {
        self.get(key).map(std::path::PathBuf::from)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_mixed_forms() {
        // note: `--flag value`-style ambiguity resolves to an option, so
        // bare flags go last or use `--k=v` for following options.
        let a = parse("search --model gpt --gpus=8 extra --verbose");
        assert_eq!(a.positional, vec!["search", "extra"]);
        assert_eq!(a.get("model"), Some("gpt"));
        assert_eq!(a.get_usize("gpus", 0), 8);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_or("platform", "a100-pcie"), "a100-pcie");
        assert_eq!(a.get_f64("lr", 0.1), 0.1);
    }

    #[test]
    fn optional_usize() {
        let a = parse("search --cache-max-entries 64");
        assert_eq!(a.get_usize_opt("cache-max-entries"), Some(64));
        assert_eq!(a.get_usize_opt("missing"), None);
        let b = parse("search --cache-max-entries lots");
        assert_eq!(b.get_usize_opt("cache-max-entries"), None);
    }

    #[test]
    fn optional_f64() {
        let a = parse("pipeline --mem-cap 12.5");
        assert_eq!(a.get_f64_opt("mem-cap"), Some(12.5));
        assert_eq!(a.get_f64_opt("missing"), None);
        assert_eq!(parse("pipeline --mem-cap lots").get_f64_opt("mem-cap"), None);
    }

    #[test]
    fn path_option() {
        let a = parse("search --cache .cfp/profiles.json");
        assert_eq!(a.get_path("cache"), Some(std::path::PathBuf::from(".cfp/profiles.json")));
        assert_eq!(a.get_path("other"), None);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--x --y v");
        assert!(a.has_flag("x"));
        assert_eq!(a.get("y"), Some("v"));
    }
}
