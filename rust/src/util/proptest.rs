//! Seeded property-testing harness (the proptest crate is not in the
//! vendor set). No shrinking — failures print the seed + case index so a
//! failing case is reproducible with `PROP_SEED`/`PROP_CASES`.

use super::prng::Pcg64;

pub struct Prop {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        let cases = std::env::var("PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        Prop { cases, seed }
    }
}

impl Prop {
    pub fn new(cases: usize, seed: u64) -> Self {
        Prop { cases, seed }
    }

    /// Run `f(case_rng)` for each case; panics with seed info on failure.
    pub fn check<F: FnMut(&mut Pcg64)>(&self, name: &str, mut f: F) {
        for case in 0..self.cases {
            let mut rng = Pcg64::new(self.seed ^ ((case as u64) << 17) ^ 0x9E3779B97F4A7C15);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                f(&mut rng);
            }));
            if let Err(err) = result {
                eprintln!(
                    "property '{name}' failed at case {case} (PROP_SEED={} PROP_CASES={})",
                    self.seed, self.cases
                );
                std::panic::resume_unwind(err);
            }
        }
    }
}

/// Random subset of sizes usable as tensor dims (powers of 2 mostly, some odd).
pub fn dim(rng: &mut Pcg64) -> usize {
    *rng.choice(&[1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 64])
}

pub fn shape(rng: &mut Pcg64, max_rank: usize) -> Vec<usize> {
    let rank = 1 + rng.below(max_rank as u64) as usize;
    (0..rank).map(|_| dim(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        Prop::new(10, 1).check("count", |_| n += 1);
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failures() {
        Prop::new(5, 1).check("fail", |rng| {
            assert!(rng.below(1000) != 999 || false, "boom");
            if rng.below(2) == 0 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn shapes_are_nonempty() {
        let mut rng = Pcg64::new(1);
        for _ in 0..50 {
            let s = shape(&mut rng, 4);
            assert!(!s.is_empty() && s.len() <= 4);
            assert!(s.iter().all(|&d| d >= 1));
        }
    }
}
