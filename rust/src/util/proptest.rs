//! Seeded property-testing harness (the proptest crate is not in the
//! vendor set). No shrinking — instead every failure is *replayable*:
//! the panic message prints the failing case's **derived** `Pcg64` seed,
//! and setting `CFP_PROP_SEED=<that value>` reruns exactly that one
//! case (the whole-suite knobs `PROP_SEED`/`PROP_CASES` still work for
//! the default harness). `CFP_PROP_CASES=<k>` multiplies the case count
//! of every [`Prop::fuzz`] harness — the CI fuzz job sets it to 10.

use super::prng::Pcg64;

pub struct Prop {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Prop {
    fn default() -> Self {
        let seed = std::env::var("PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xC0FFEE);
        let cases = std::env::var("PROP_CASES")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(64);
        Prop { cases, seed }
    }
}

/// The derived per-case seed [`Prop::check`] feeds to `Pcg64` — also the
/// value `CFP_PROP_SEED` replays verbatim.
fn case_seed(seed: u64, case: usize) -> u64 {
    seed ^ ((case as u64) << 17) ^ 0x9E3779B97F4A7C15
}

fn env_u64(name: &str) -> Option<u64> {
    std::env::var(name).ok().and_then(|s| s.parse().ok())
}

impl Prop {
    pub fn new(cases: usize, seed: u64) -> Self {
        Prop { cases, seed }
    }

    /// [`Prop::new`] with the case count scaled by `CFP_PROP_CASES`
    /// (default ×1) — the entry point every randomized *test file* should
    /// use, so the CI fuzz job can raise coverage ~10× without touching
    /// per-test constants. Unit tests that assert exact case counts keep
    /// using [`Prop::new`], which ignores the multiplier.
    pub fn fuzz(cases: usize, seed: u64) -> Self {
        let mult = env_u64("CFP_PROP_CASES").unwrap_or(1).max(1) as usize;
        Prop { cases: cases.saturating_mul(mult), seed }
    }

    /// Run `f(case_rng)` for each case; panics with replay info on
    /// failure. With `CFP_PROP_SEED=<derived seed>` set, runs exactly one
    /// case with that seed instead — the replay loop for a failure some
    /// earlier run printed.
    pub fn check<F: FnMut(&mut Pcg64)>(&self, name: &str, f: F) {
        self.check_impl(name, f, env_u64("CFP_PROP_SEED"));
    }

    fn check_impl<F: FnMut(&mut Pcg64)>(&self, name: &str, mut f: F, replay: Option<u64>) {
        if let Some(derived) = replay {
            eprintln!("property '{name}': replaying single case CFP_PROP_SEED={derived}");
            let mut rng = Pcg64::new(derived);
            f(&mut rng);
            return;
        }
        for case in 0..self.cases {
            let derived = case_seed(self.seed, case);
            let mut rng = Pcg64::new(derived);
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                f(&mut rng);
            }));
            if let Err(err) = result {
                eprintln!(
                    "property '{name}' failed at case {case} (PROP_SEED={} PROP_CASES={}); \
                     replay just this case with CFP_PROP_SEED={derived}",
                    self.seed, self.cases
                );
                std::panic::resume_unwind(err);
            }
        }
    }
}

/// Random subset of sizes usable as tensor dims (powers of 2 mostly, some odd).
pub fn dim(rng: &mut Pcg64) -> usize {
    *rng.choice(&[1, 2, 3, 4, 6, 8, 12, 16, 24, 32, 64])
}

pub fn shape(rng: &mut Pcg64, max_rank: usize) -> Vec<usize> {
    let rank = 1 + rng.below(max_rank as u64) as usize;
    (0..rank).map(|_| dim(rng)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_runs_all_cases() {
        let mut n = 0;
        Prop::new(10, 1).check("count", |_| n += 1);
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic]
    fn check_propagates_failures() {
        Prop::new(5, 1).check("fail", |rng| {
            assert!(rng.below(1000) != 999 || false, "boom");
            if rng.below(2) == 0 {
                panic!("boom");
            }
        });
    }

    #[test]
    fn replay_runs_exactly_the_derived_case() {
        // harvest the stream the failing case would see...
        let derived = case_seed(7, 3);
        let mut want = Pcg64::new(derived);
        let want: Vec<u64> = (0..4).map(|_| want.next_u64()).collect();
        // ...then replay it through check_impl (env handled by the public
        // wrapper; injected here so parallel tests never mutate the env)
        let mut got = Vec::new();
        let mut ran = 0;
        Prop::new(10, 7).check_impl(
            "replay",
            |rng| {
                ran += 1;
                got = (0..4).map(|_| rng.next_u64()).collect();
            },
            Some(derived),
        );
        assert_eq!(ran, 1, "replay runs the one case, not the whole suite");
        assert_eq!(got, want, "replay sees the identical Pcg64 stream");
    }

    #[test]
    fn fuzz_defaults_to_the_plain_case_count() {
        // without CFP_PROP_CASES in the environment the multiplier is 1
        if std::env::var("CFP_PROP_CASES").is_err() {
            let mut n = 0;
            Prop::fuzz(6, 1).check("fuzz", |_| n += 1);
            assert_eq!(n, 6);
        }
    }

    #[test]
    fn shapes_are_nonempty() {
        let mut rng = Pcg64::new(1);
        for _ in 0..50 {
            let s = shape(&mut rng, 4);
            assert!(!s.is_empty() && s.len() <= 4);
            assert!(s.iter().all(|&d| d >= 1));
        }
    }
}
