//! Deterministic fault-injection registry (PR 10).
//!
//! Every failure domain the system claims to survive — torn cache
//! writes, lock races, dead TCP clients, worker panics, exact-lane
//! budget exhaustion — carries a *named failpoint*: a site in the code
//! that asks [`should_trip`] whether to simulate its fault right now.
//! Sites are always compiled in, and **free when disarmed**: with no
//! schedule armed, a site costs one relaxed atomic load and can never
//! change an output byte (pinned by the disarmed lanes of
//! `integration_chaos` and the pre-existing byte-identity suites).
//!
//! # Spec grammar
//!
//! Schedules arm from the `CFP_FAULTS` environment variable or the
//! `--faults` CLI flag (both use the same grammar, flag wins):
//!
//! ```text
//! CFP_FAULTS="site:mode[,site:mode...]"
//!
//! mode := off          never trips (site stays registered + audited)
//!       | always       trips every evaluation
//!       | once         trips the 1st evaluation only (= first=1)
//!       | first=N      trips evaluations 1..=N, then passes
//!       | after=N      passes evaluations 1..=N, then trips forever
//!       | every=N      trips evaluations N, 2N, 3N, ...
//!       | p=F@SEED     trips with probability F per evaluation, drawn
//!                      from a per-site Pcg64 seeded by SEED mixed with
//!                      the site name (deterministic replay)
//! ```
//!
//! # Determinism argument
//!
//! A site's trip decision is a pure function of its *evaluation index*
//! (per-site, 1-based) and, for `p=`, of a per-site seeded [`Pcg64`]
//! stream — never of wall-clock time or thread identity. For a fixed
//! workload the number of evaluations each site sees is fixed, so the
//! trip *count* per site is replayable from the spec alone; which
//! concurrent request absorbs trip #k may vary with scheduling, which
//! is exactly the nondeterminism the chaos invariants are quantified
//! over ("every response is the fault-free bytes or a structured
//! error, for *any* interleaving"). This mirrors how `CFP_PROP_SEED`
//! replays property-suite failures.
//!
//! # Auditability
//!
//! Per-site evaluation and trip counters are exported through the obs
//! layer ([`crate::obs::fault_counters`] → `stats` responses and the
//! Chrome trace), so a chaos run can prove every armed site actually
//! fired — a failpoint that never trips is a dead failpoint, and the
//! acceptance suite treats it as a bug.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};

use super::prng::Pcg64;

/// When a schedule trips a site, one of these is simulated at the site.
/// (The behaviour lives at the site; the registry only answers yes/no.)
#[derive(Clone, Debug, PartialEq)]
enum Mode {
    Off,
    Always,
    First(u64),
    After(u64),
    Every(u64),
    Prob { p: f64, seed: u64 },
}

/// One armed site's schedule plus its audit counters.
struct Site {
    mode: Mode,
    evals: AtomicU64,
    trips: AtomicU64,
    /// per-site deterministic stream for `p=` mode (lazily seeded from
    /// the spec seed mixed with the site name)
    rng: Mutex<Pcg64>,
}

/// Registry state: the armed schedule, keyed by site name.
struct Registry {
    sites: Mutex<BTreeMap<String, Site>>,
}

/// Fast disarmed-path gate, tri-state so the very first evaluation in a
/// process consults `CFP_FAULTS` exactly once. After that, every
/// [`armed`] check is one relaxed load — the whole cost of the
/// framework when off.
const STATE_UNINIT: u8 = 0;
const STATE_DISARMED: u8 = 1;
const STATE_ARMED: u8 = 2;
static STATE: AtomicU8 = AtomicU8::new(STATE_UNINIT);

static REGISTRY: OnceLock<Registry> = OnceLock::new();

fn registry() -> &'static Registry {
    REGISTRY.get_or_init(|| Registry { sites: Mutex::new(BTreeMap::new()) })
}

/// Cold path of [`armed`]: consume `CFP_FAULTS` once. An unset or
/// unparseable variable leaves the process disarmed.
#[cold]
fn init_from_env() -> bool {
    let spec = std::env::var("CFP_FAULTS").unwrap_or_default();
    if !spec.trim().is_empty() {
        if let Err(e) = install(&spec) {
            crate::obs::diag::diag(&format!("cfp: ignoring CFP_FAULTS: {e}"));
        }
    }
    // `install` settled the state on success; an unset or rejected spec
    // leaves it UNINIT — settle to DISARMED (a concurrent explicit
    // `arm()` that already settled it wins, which is the right answer)
    let _ = STATE.compare_exchange(
        STATE_UNINIT,
        STATE_DISARMED,
        Ordering::AcqRel,
        Ordering::Acquire,
    );
    STATE.load(Ordering::Acquire) == STATE_ARMED
}

/// FNV-1a over the site name — mixes the spec seed so distinct sites
/// sharing one `p=F@SEED` spec draw independent streams.
fn site_hash(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

fn parse_mode(spec: &str) -> Result<Mode, String> {
    let spec = spec.trim();
    if let Some((key, val)) = spec.split_once('=') {
        let key = key.trim();
        let val = val.trim();
        return match key {
            "first" | "after" | "every" => {
                let n: u64 =
                    val.parse().map_err(|_| format!("{key}= wants an integer, got {val:?}"))?;
                match key {
                    "first" => Ok(Mode::First(n)),
                    "after" => Ok(Mode::After(n)),
                    _ if n == 0 => Err("every=0 is meaningless".to_string()),
                    _ => Ok(Mode::Every(n)),
                }
            }
            "p" => {
                let (prob, seed) = match val.split_once('@') {
                    Some((p, s)) => (p, s),
                    None => (val, "0"),
                };
                let p: f64 =
                    prob.parse().map_err(|_| format!("p= wants a float, got {prob:?}"))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(format!("p={p} out of [0, 1]"));
                }
                let seed: u64 =
                    seed.parse().map_err(|_| format!("p=F@SEED wants an integer seed, got {seed:?}"))?;
                Ok(Mode::Prob { p, seed })
            }
            _ => Err(format!("unknown fault mode {spec:?}")),
        };
    }
    match spec {
        "off" => Ok(Mode::Off),
        "always" => Ok(Mode::Always),
        "once" => Ok(Mode::First(1)),
        _ => Err(format!("unknown fault mode {spec:?}")),
    }
}

/// Arm a fault schedule, replacing any schedule armed before. The spec
/// grammar is the module-level `site:mode[,...]` one; an empty spec
/// disarms everything. Errors reject the whole spec (no partial arm).
pub fn arm(spec: &str) -> Result<(), String> {
    install(spec)
}

fn install(spec: &str) -> Result<(), String> {
    let mut parsed: BTreeMap<String, Site> = BTreeMap::new();
    for entry in spec.split(',') {
        let entry = entry.trim();
        if entry.is_empty() {
            continue;
        }
        let (name, mode) = entry
            .split_once(':')
            .ok_or_else(|| format!("fault entry {entry:?} is not site:mode"))?;
        let name = name.trim();
        if name.is_empty() {
            return Err(format!("fault entry {entry:?} has an empty site name"));
        }
        let mode = parse_mode(mode)?;
        let seed = match mode {
            Mode::Prob { seed, .. } => seed ^ site_hash(name),
            _ => 0,
        };
        parsed.insert(
            name.to_string(),
            Site {
                mode,
                evals: AtomicU64::new(0),
                trips: AtomicU64::new(0),
                rng: Mutex::new(Pcg64::new(seed)),
            },
        );
    }
    let reg = registry();
    let mut sites = reg.sites.lock().unwrap_or_else(|e| e.into_inner());
    let state = if parsed.is_empty() { STATE_DISARMED } else { STATE_ARMED };
    *sites = parsed;
    STATE.store(state, Ordering::Release);
    Ok(())
}

/// Disarm every site (the chaos suite's RAII cleanup).
pub fn disarm_all() {
    let reg = registry();
    let mut sites = reg.sites.lock().unwrap_or_else(|e| e.into_inner());
    sites.clear();
    STATE.store(STATE_DISARMED, Ordering::Release);
}

/// Whether any fault schedule is armed. One relaxed load (after the
/// one-time `CFP_FAULTS` consultation on a process's first call).
#[inline]
pub fn armed() -> bool {
    match STATE.load(Ordering::Relaxed) {
        STATE_DISARMED => false,
        STATE_ARMED => true,
        _ => init_from_env(),
    }
}

/// Evaluate the failpoint `name`: `true` means the site should simulate
/// its fault now. Disarmed (the production default) this is a single
/// relaxed atomic load; armed, the per-site evaluation counter advances
/// and the schedule decides.
pub fn should_trip(name: &str) -> bool {
    if !armed() {
        return false;
    }
    let reg = registry();
    let sites = reg.sites.lock().unwrap_or_else(|e| e.into_inner());
    let Some(site) = sites.get(name) else { return false };
    let n = site.evals.fetch_add(1, Ordering::Relaxed) + 1; // 1-based
    let trip = match site.mode {
        Mode::Off => false,
        Mode::Always => true,
        Mode::First(k) => n <= k,
        Mode::After(k) => n > k,
        Mode::Every(k) => n % k == 0,
        Mode::Prob { p, .. } => {
            site.rng.lock().unwrap_or_else(|e| e.into_inner()).f64() < p
        }
    };
    if trip {
        site.trips.fetch_add(1, Ordering::Relaxed);
    }
    trip
}

/// Evaluate `name` and panic if it trips — the injected-worker-panic
/// site shape (the panic is then caught by the domain's `catch_unwind`
/// isolation, which is exactly what the chaos suite is proving).
pub fn trip_panic(name: &str) {
    if should_trip(name) {
        panic!("injected fault: {name}");
    }
}

/// Times `name` has tripped under the current schedule.
pub fn trip_count(name: &str) -> u64 {
    let reg = registry();
    let sites = reg.sites.lock().unwrap_or_else(|e| e.into_inner());
    sites.get(name).map_or(0, |s| s.trips.load(Ordering::Relaxed))
}

/// Times `name` has been evaluated under the current schedule.
pub fn eval_count(name: &str) -> u64 {
    let reg = registry();
    let sites = reg.sites.lock().unwrap_or_else(|e| e.into_inner());
    sites.get(name).map_or(0, |s| s.evals.load(Ordering::Relaxed))
}

/// `(site, evals, trips)` for every armed site, in name order — the
/// audit surface [`crate::obs::fault_counters`] re-exports. Empty when
/// disarmed, so the obs outputs it feeds stay byte-identical to a
/// build without the framework.
pub fn snapshot() -> Vec<(String, u64, u64)> {
    if !armed() {
        return Vec::new();
    }
    let reg = registry();
    let sites = reg.sites.lock().unwrap_or_else(|e| e.into_inner());
    sites
        .iter()
        .map(|(name, s)| {
            (name.clone(), s.evals.load(Ordering::Relaxed), s.trips.load(Ordering::Relaxed))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global, so every test uses site names
    // unique to itself (suffix `.ut`) and arms/disarms around a shared
    // guard; production site names never appear here.
    static GUARD: Mutex<()> = Mutex::new(());

    struct Armed;
    impl Drop for Armed {
        fn drop(&mut self) {
            disarm_all();
        }
    }

    fn armed_guard(spec: &str) -> (std::sync::MutexGuard<'static, ()>, Armed) {
        let g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
        arm(spec).expect("test spec parses");
        (g, Armed)
    }

    #[test]
    fn disarmed_sites_never_trip_and_report_nothing() {
        let g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
        disarm_all();
        assert!(!armed());
        assert!(!should_trip("nonexistent.ut"));
        assert!(snapshot().is_empty());
        assert_eq!(trip_count("nonexistent.ut"), 0);
        drop(g);
    }

    #[test]
    fn first_after_every_schedules_are_exact() {
        let (_g, _a) = armed_guard("a.ut:first=2,b.ut:after=3,c.ut:every=3");
        let fire = |name: &str| (1..=9).map(|_| should_trip(name)).collect::<Vec<_>>();
        assert_eq!(fire("a.ut"), [true, true, false, false, false, false, false, false, false]);
        assert_eq!(fire("b.ut"), [false, false, false, true, true, true, true, true, true]);
        assert_eq!(fire("c.ut"), [false, false, true, false, false, true, false, false, true]);
        assert_eq!(trip_count("a.ut"), 2);
        assert_eq!(trip_count("b.ut"), 6);
        assert_eq!(trip_count("c.ut"), 3);
        assert_eq!(eval_count("a.ut"), 9);
    }

    #[test]
    fn once_always_off_modes() {
        let (_g, _a) = armed_guard("x.ut:once, y.ut:always , z.ut:off");
        assert!(should_trip("x.ut") && !should_trip("x.ut"));
        assert!(should_trip("y.ut") && should_trip("y.ut"));
        assert!(!should_trip("z.ut") && !should_trip("z.ut"));
        // off sites still audit their evaluations (dead-site detection)
        assert_eq!(eval_count("z.ut"), 2);
        assert_eq!(trip_count("z.ut"), 0);
        // unarmed sites pass even while the registry is armed
        assert!(!should_trip("unlisted.ut"));
    }

    #[test]
    fn probabilistic_schedule_replays_bit_identically() {
        let run = || -> Vec<bool> {
            let (_g, _a) = armed_guard("p.ut:p=0.5@42,q.ut:p=0.5@42");
            (0..64).map(|_| should_trip("p.ut")).collect()
        };
        let first = run();
        assert_eq!(first, run(), "same seed, same site, same trips");
        assert!(first.iter().any(|&b| b) && !first.iter().all(|&b| b), "p=0.5 mixes");
        // distinct sites sharing a seed draw independent streams
        let (_g, _a) = armed_guard("p.ut:p=0.5@42,q.ut:p=0.5@42");
        let p: Vec<bool> = (0..64).map(|_| should_trip("p.ut")).collect();
        let q: Vec<bool> = (0..64).map(|_| should_trip("q.ut")).collect();
        assert_ne!(p, q, "site name is mixed into the stream seed");
    }

    #[test]
    fn snapshot_lists_sites_in_name_order_with_counts() {
        let (_g, _a) = armed_guard("b.ut:always,a.ut:off");
        assert!(should_trip("b.ut"));
        assert!(!should_trip("a.ut"));
        let snap = snapshot();
        assert_eq!(
            snap,
            vec![("a.ut".to_string(), 1, 0), ("b.ut".to_string(), 1, 1)],
            "name-ordered (evals, trips) audit rows"
        );
    }

    #[test]
    fn trip_panic_panics_only_when_tripped() {
        let (_g, _a) = armed_guard("boom.ut:after=1");
        trip_panic("boom.ut"); // eval 1: passes
        let caught = std::panic::catch_unwind(|| trip_panic("boom.ut"));
        let msg = *caught.expect_err("eval 2 trips").downcast::<String>().unwrap();
        assert!(msg.contains("injected fault: boom.ut"), "{msg}");
    }

    #[test]
    fn bad_specs_are_rejected_wholesale() {
        let g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
        disarm_all();
        for bad in [
            "siteonly",
            "s.ut:nope",
            "s.ut:first=x",
            "s.ut:every=0",
            "s.ut:p=1.5",
            "s.ut:p=0.5@x",
            ":always",
        ] {
            assert!(arm(bad).is_err(), "{bad:?} must be rejected");
        }
        assert!(!armed(), "a rejected spec arms nothing");
        // empty specs disarm
        arm("").unwrap();
        assert!(!armed());
        drop(g);
    }
}
