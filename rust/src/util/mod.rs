//! Infrastructure substrates built from scratch for the offline environment
//! (no tokio / clap / serde / criterion / proptest in the vendor set —
//! see DESIGN.md §Substitutions).

pub mod bench;
pub mod cli;
pub mod failpoint;
pub mod json;
pub mod pool;
pub mod prng;
pub mod proptest;
pub mod stats;

pub use json::Json;
pub use pool::ThreadPool;
pub use prng::Pcg64;
