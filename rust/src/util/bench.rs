//! Micro-benchmark harness (criterion is not in the vendor set).
//!
//! Each `rust/benches/*.rs` target sets `harness = false` and drives this:
//! warmup, timed iterations until a budget, median/σ report, and the same
//! rows/series printing the paper figures need.

use std::time::{Duration, Instant};

use super::stats;

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<48} {:>12} iters   median {:>12}   mean {:>12}  ±{}",
            self.name,
            self.iters,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.stddev_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Run `f` repeatedly for ~`budget` (after warmup) and report.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup: a few runs, also estimates per-iter cost.
    let warm_start = Instant::now();
    let mut warm_iters = 0u64;
    while warm_start.elapsed() < budget / 10 || warm_iters < 3 {
        f();
        warm_iters += 1;
        if warm_iters >= 1000 {
            break;
        }
    }
    let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
    // Sample in batches sized so each sample is ≥ ~1ms but ≤ budget/20.
    let batch = ((1e-3 / per_iter.max(1e-9)).ceil() as u64).clamp(1, 1_000_000);
    let mut samples = Vec::new();
    let start = Instant::now();
    let mut total_iters = 0u64;
    while start.elapsed() < budget && samples.len() < 200 {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64() * 1e9 / batch as f64;
        samples.push(dt);
        total_iters += batch;
    }
    let r = BenchResult {
        name: name.to_string(),
        iters: total_iters,
        median_ns: stats::median(&samples),
        mean_ns: stats::mean(&samples),
        stddev_ns: stats::stddev(&samples),
    };
    r.report();
    r
}

/// Prevent the optimizer from deleting a computed value.
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", Duration::from_millis(50), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters > 0);
        assert!(r.median_ns > 0.0);
        assert!(r.median_ns < 1e7, "100-element sum should be well under 10ms");
    }
}
