//! Micro-benchmark harness (criterion is not in the vendor set).
//!
//! Each `rust/benches/*.rs` target sets `harness = false` and drives this:
//! warmup, timed iterations until a budget, median/σ report, and the same
//! rows/series printing the paper figures need.

use std::time::{Duration, Instant};

use super::stats;

pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub stddev_ns: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<48} {:>12} iters   median {:>12}   mean {:>12}  ±{}",
            self.name,
            self.iters,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.stddev_ns),
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Run `f` repeatedly for ~`budget` (after warmup) and report.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warmup: a few runs, also estimates per-iter cost.
    let warm_start = Instant::now();
    let mut warm_iters = 0u64;
    while warm_start.elapsed() < budget / 10 || warm_iters < 3 {
        f();
        warm_iters += 1;
        if warm_iters >= 1000 {
            break;
        }
    }
    let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;
    // Sample in batches sized so each sample is ≥ ~1ms but ≤ budget/20.
    let batch = ((1e-3 / per_iter.max(1e-9)).ceil() as u64).clamp(1, 1_000_000);
    let mut samples = Vec::new();
    let start = Instant::now();
    let mut total_iters = 0u64;
    while start.elapsed() < budget && samples.len() < 200 {
        let t0 = Instant::now();
        for _ in 0..batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64() * 1e9 / batch as f64;
        samples.push(dt);
        total_iters += batch;
    }
    let r = BenchResult {
        name: name.to_string(),
        iters: total_iters,
        median_ns: stats::median(&samples),
        mean_ns: stats::mean(&samples),
        stddev_ns: stats::stddev(&samples),
    };
    r.report();
    r
}

/// Prevent the optimizer from deleting a computed value.
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66
    std::hint::black_box(x)
}

/// One machine-readable bench row for the repo-root `BENCH_*.json`
/// trajectory files (name, problem size, ns/iter, speedup vs the
/// recorded baseline — `None` for rows that *are* a baseline).
///
/// `unit: None` keeps the classic ns/iter schema. Rows whose metric is
/// not a per-iteration time (latency quantiles, throughput) set `unit`;
/// they serialize as `{"value": v, "unit": "..."}` instead of
/// `"ns_per_iter"`, so trajectory tooling never misreads a req/s figure
/// as nanoseconds.
pub struct JsonRow {
    pub name: String,
    pub layers: usize,
    pub ns_per_iter: f64,
    pub unit: Option<&'static str>,
    pub speedup: Option<f64>,
}

impl JsonRow {
    fn to_json(&self) -> super::Json {
        use super::Json;
        let mut pairs = vec![
            ("name", Json::str(self.name.clone())),
            ("layers", Json::num(self.layers as f64)),
        ];
        match self.unit {
            None => pairs.push(("ns_per_iter", Json::num(self.ns_per_iter))),
            Some(unit) => {
                pairs.push(("value", Json::num(self.ns_per_iter)));
                pairs.push(("unit", Json::str(unit)));
            }
        }
        pairs.push(("speedup", self.speedup.map_or(Json::Null, Json::num)));
        Json::obj(pairs)
    }
}

/// Merge `rows` into the JSON bench file at `path` (`{"rows": [...]}`):
/// existing rows with the same name are replaced, everything else is
/// kept, output is name-sorted and written atomically (tmp + rename) —
/// so `cargo bench --bench search` and `--bench memory` can both feed
/// one trajectory file, in any order, without clobbering each other.
pub fn merge_bench_json(path: &std::path::Path, rows: &[JsonRow]) -> std::io::Result<()> {
    use super::Json;
    let mut by_name: std::collections::BTreeMap<String, Json> = std::collections::BTreeMap::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Ok(j) = Json::parse(&text) {
            if let Some(arr) = j.get("rows").and_then(Json::as_arr) {
                for row in arr {
                    if let Some(name) = row.get("name").and_then(Json::as_str) {
                        by_name.insert(name.to_string(), row.clone());
                    }
                }
            }
        }
    }
    for r in rows {
        by_name.insert(r.name.clone(), r.to_json());
    }
    let out = Json::obj(vec![("rows", Json::Arr(by_name.into_values().collect()))]);
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, out.to_string_pretty() + "\n")?;
    std::fs::rename(&tmp, path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_json_merges_by_name_and_round_trips() {
        let path = std::env::temp_dir()
            .join(format!("cfp_bench_json_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let row = |name: &str, ns: f64, sp: Option<f64>| JsonRow {
            name: name.into(),
            layers: 32,
            ns_per_iter: ns,
            unit: None,
            speedup: sp,
        };
        merge_bench_json(&path, &[row("a", 100.0, None), row("b", 50.0, Some(2.0))]).unwrap();
        // a re-run replaces matching rows and keeps the rest
        merge_bench_json(&path, &[row("b", 40.0, Some(2.5))]).unwrap();
        let j = crate::util::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("name").unwrap().as_str(), Some("a"));
        assert_eq!(rows[0].get("speedup"), Some(&crate::util::Json::Null));
        assert_eq!(rows[1].get("ns_per_iter").unwrap().as_f64(), Some(40.0));
        assert_eq!(rows[1].get("speedup").unwrap().as_f64(), Some(2.5));
        // a unit-carrying row serializes as value+unit, not ns_per_iter
        let thr = JsonRow {
            name: "thr".into(),
            layers: 1,
            ns_per_iter: 1234.5,
            unit: Some("req_per_s"),
            speedup: None,
        };
        merge_bench_json(&path, &[thr]).unwrap();
        let j = crate::util::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        let t = rows.iter().find(|r| r.get("name").unwrap().as_str() == Some("thr")).unwrap();
        assert_eq!(t.get("value").unwrap().as_f64(), Some(1234.5));
        assert_eq!(t.get("unit").unwrap().as_str(), Some("req_per_s"));
        assert!(t.get("ns_per_iter").is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn bench_reports_sane_numbers() {
        let r = bench("noop-ish", Duration::from_millis(50), || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters > 0);
        assert!(r.median_ns > 0.0);
        assert!(r.median_ns < 1e7, "100-element sum should be well under 10ms");
    }
}
