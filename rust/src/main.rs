//! `cfp` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   search    run the CFP pipeline on a model and print the chosen plan
//!   pipeline  two-level planner: inter-op stages over the intra-op DP
//!   compare   CFP vs Alpa/Megatron/DDP on one model+platform
//!   train     e2e training via the PJRT train-step artifact
//!   calibrate measure calib artifacts and print the fitted compute model
//!   space     print ParallelBlock/segment/profile-space statistics

use cfp::cluster::Platform;
use cfp::coordinator::{compare_frameworks, run_cfp, run_cfp_two_level, CfpOptions};
use cfp::harness::{fmt_bytes, fmt_us, Table};
use cfp::interop::{candidate_stage_counts, StageSpec};
use cfp::memory::RecomputeSpec;
use cfp::models::ModelCfg;
use cfp::runtime::Runtime;
use cfp::trainer::Trainer;
use cfp::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "search" => cmd_search(&args),
        "pipeline" => cmd_pipeline(&args),
        "compare" => cmd_compare(&args),
        "train" => cmd_train(&args),
        "calibrate" => cmd_calibrate(&args),
        "space" => cmd_space(&args),
        _ => {
            eprintln!(
                "usage: cfp <search|pipeline|compare|train|calibrate|space> \
                 [--model gpt-2.6b] [--layers N] [--batch N] \
                 [--platform a100-pcie|a100-pcie-8|a100-2node|v100-nvlink] \
                 [--threads N] [--cache FILE] [--cache-max-entries N] \
                 [--stages auto|K] [--microbatches M] [--mem-cap GB] \
                 [--recompute auto|off] [--steps N] [--lr F]"
            );
            1
        }
    };
    std::process::exit(code);
}

fn parse_model(args: &Args) -> ModelCfg {
    let name = args.get_or("model", "gpt-2.6b");
    let mut cfg = ModelCfg::preset(name);
    if let Some(l) = args.get("layers") {
        let fallback = cfg.layers;
        cfg = cfg.with_layers(l.parse().unwrap_or(fallback));
    }
    let batch = args.get_usize("batch", cfg.batch);
    cfg = cfg.with_batch(batch);
    if args.has_flag("scaled") {
        cfg = cfg.scaled_for_eval();
    }
    cfg
}

fn parse_platform(args: &Args) -> Platform {
    Platform::by_name(args.get_or("platform", "a100-pcie")).unwrap_or_else(|| {
        eprintln!("unknown platform, using a100-pcie");
        Platform::a100_pcie(4)
    })
}

fn parse_common(args: &Args, opts: &mut CfpOptions) {
    opts.threads = args.get_usize("threads", 1);
    opts.cache_path = args.get_path("cache");
    opts.cache_max_entries = args.get_usize_opt("cache-max-entries");
    opts.microbatches = args.get_usize("microbatches", 8);
    if let Some(s) = args.get("stages") {
        match StageSpec::parse(s) {
            Some(spec) => opts.stages = spec,
            None => eprintln!("unknown --stages value {s:?} (want auto|single|K), ignoring"),
        }
    }
    // --mem-cap is given in GB (fractions allowed: --mem-cap 12.5)
    if let Some(mc) = args.get("mem-cap") {
        match mc.parse::<f64>() {
            Ok(gb) if gb > 0.0 => opts.mem_cap = Some((gb * (1u64 << 30) as f64) as u64),
            _ => eprintln!("invalid --mem-cap value {mc:?} (want GB, e.g. 12.5), ignoring"),
        }
    }
    if let Some(r) = args.get("recompute") {
        match RecomputeSpec::parse(r) {
            Some(spec) => opts.recompute = spec,
            None => eprintln!("unknown --recompute value {r:?} (want auto|off), ignoring"),
        }
    }
}

/// Strict validation of the `pipeline` subcommand's flags: a stage count
/// that cannot tile the cluster, or zero microbatches, is a user error —
/// exit with a message instead of silently normalizing.
fn validate_pipeline_args(args: &Args, opts: &CfpOptions) -> Result<(), String> {
    if let Some(mb) = args.get("microbatches") {
        match mb.parse::<usize>() {
            Ok(0) => {
                return Err(
                    "--microbatches must be ≥ 1 (0 microbatches cannot fill a pipeline)".into()
                )
            }
            Ok(_) => {}
            Err(_) => return Err(format!("--microbatches {mb:?} is not a number")),
        }
    }
    if let Some(s) = args.get("stages") {
        if let Ok(k) = s.parse::<usize>() {
            let valid = candidate_stage_counts(StageSpec::Auto, opts.mesh);
            if k == 0 || (k > 1 && !valid.contains(&k)) {
                return Err(format!(
                    "--stages {k} does not tile the {}-device cluster \
                     (valid stage counts: {valid:?})",
                    opts.mesh.total()
                ));
            }
        }
    }
    if let Some(mc) = args.get("mem-cap") {
        match mc.parse::<f64>() {
            Ok(gb) if gb > 0.0 => {}
            _ => return Err(format!("--mem-cap {mc:?} is not a positive GB value")),
        }
    }
    Ok(())
}

fn cmd_search(args: &Args) -> i32 {
    let model = parse_model(args);
    let platform = parse_platform(args);
    let mut opts = CfpOptions::new(model, platform);
    parse_common(args, &mut opts);
    if let Ok(rt) = Runtime::open_default() {
        if let Ok(cm) = rt.calibrate_compute(&platform) {
            println!("(compute model calibrated from PJRT measurements)");
            opts.compute = Some(cm);
        }
    }
    let r = run_cfp(&opts);
    println!(
        "model {}  platform {}  gpus {}",
        opts.model.name,
        platform.name,
        opts.mesh.total()
    );
    println!(
        "blocks {}  segments {} ({} unique)  profile space {} programs",
        r.blocks.num_blocks(),
        r.segments.instances.len(),
        r.segments.num_unique(),
        r.db.profile_space()
    );
    println!(
        "plan: step {}  memory/device {}",
        fmt_us(r.plan.time_us),
        fmt_bytes(r.plan.mem_bytes)
    );
    for line in r.describe_plan() {
        println!("  {line}");
    }
    println!(
        "timings: analysis {:.3}s  profiling {:.3}s  search {:.3}s  \
         (est. real testbed: compile {:.1}s profile {:.1}s -> optimized {:.1}s)",
        r.timings.analysis_passes_s,
        r.timings.exec_compiling_s + r.timings.metrics_profiling_s,
        r.timings.compose_search_s,
        r.timings.est_compile_s,
        r.timings.est_profile_s,
        r.timings.est_optimized_s,
    );
    if opts.cache_path.is_some() {
        println!(
            "profile cache: {} segment hit(s), {} profiled this run \
             (MetricsProfiling {:.4}s)",
            r.db.stats.cache_hits,
            r.db.stats.cache_misses,
            r.timings.metrics_profiling_s,
        );
    }
    0
}

fn cmd_pipeline(args: &Args) -> i32 {
    let model = parse_model(args);
    let platform = parse_platform(args);
    let mut opts = CfpOptions::new(model, platform);
    opts.stages = StageSpec::Auto;
    // the pipeline planner defaults to memory-aware planning against the
    // device capacity; `--recompute off` restores the PR 2 behaviour
    opts.recompute = RecomputeSpec::Auto;
    parse_common(args, &mut opts);
    if let Err(msg) = validate_pipeline_args(args, &opts) {
        eprintln!("cfp pipeline: {msg}");
        return 2;
    }
    let r = run_cfp_two_level(&opts);
    println!(
        "model {}  platform {}  gpus {}  microbatches {}  cap {}  recompute {}",
        opts.model.name,
        platform.name,
        opts.mesh.total(),
        opts.microbatches,
        fmt_bytes(opts.mem_cap.unwrap_or_else(|| platform.mem_capacity())),
        if opts.recompute.is_auto() { "auto" } else { "off" },
    );
    let Some(pipeline) = r.pipeline.as_ref() else {
        eprintln!(
            "cfp pipeline: no stage split fits the per-device memory cap \
             (even with recomputation) — raise --mem-cap or add devices"
        );
        return 1;
    };
    let mut t = Table::new(&["planner", "stages", "step time", "peak mem/dev", "vs two-level"]);
    t.row(vec![
        "CFP single-stage".into(),
        "1".into(),
        fmt_us(r.single.plan.time_us),
        fmt_bytes(r.single.plan.mem_bytes),
        format!("{:.2}x", r.single.plan.time_us / pipeline.step_time_us),
    ]);
    t.row(vec![
        "CFP two-level".into(),
        pipeline.num_stages().to_string(),
        fmt_us(pipeline.step_time_us),
        fmt_bytes(pipeline.peak_mem_bytes),
        "1.00x".into(),
    ]);
    match r.naive.as_ref() {
        Some(naive) => t.row(vec![
            "naive equal-split".into(),
            naive.num_stages().to_string(),
            fmt_us(naive.step_time_us),
            fmt_bytes(naive.peak_mem_bytes),
            format!("{:.2}x", naive.step_time_us / pipeline.step_time_us),
        ]),
        None => t.row(vec![
            "naive equal-split".into(),
            "-".into(),
            "over cap".into(),
            "-".into(),
            "-".into(),
        ]),
    }
    t.print();
    println!(
        "two-level plan: {} stage(s) × {} device(s), bubble {:.1}%, 1F1B peak {}",
        pipeline.num_stages(),
        pipeline.devices_per_stage,
        pipeline.bubble_fraction * 100.0,
        fmt_bytes(pipeline.peak_mem_bytes),
    );
    for line in pipeline.describe() {
        println!("  {line}");
    }
    0
}

fn cmd_compare(args: &Args) -> i32 {
    let model = parse_model(args);
    let platform = parse_platform(args);
    let mut opts = CfpOptions::new(model, platform);
    parse_common(args, &mut opts);
    let c = compare_frameworks(&opts);
    let mut t = Table::new(&["framework", "step time", "memory/dev", "vs CFP"]);
    for (name, p) in [
        ("PyTorch-DDP", &c.ddp),
        ("DeepSpeed-Megatron", &c.megatron),
        ("Alpa (volume model)", &c.alpa),
        ("CFP", &c.cfp),
    ] {
        t.row(vec![
            name.into(),
            fmt_us(p.time_us),
            fmt_bytes(p.mem_bytes),
            format!("{:.2}x", p.time_us / c.cfp.time_us),
        ]);
    }
    t.print();
    0
}

fn cmd_train(args: &Args) -> i32 {
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("no artifacts ({e}); run `make artifacts` first");
            return 1;
        }
    };
    let steps = args.get_usize("steps", 100);
    let lr = args.get_f64("lr", 0.05) as f32;
    let artifact = args.get_or("artifact", "train_step_gpt");
    let mut tr = match Trainer::new(&rt, artifact, 42) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("trainer: {e}");
            return 1;
        }
    };
    println!("training {artifact}: {} params, {steps} steps, lr {lr}", tr.num_params());
    match tr.train(steps, lr, (steps / 20).max(1)) {
        Ok(curve) => {
            println!(
                "loss {:.4} -> {:.4}",
                curve.first().unwrap_or(&0.0),
                curve.last().unwrap_or(&0.0)
            );
            0
        }
        Err(e) => {
            eprintln!("train: {e}");
            1
        }
    }
}

fn cmd_calibrate(args: &Args) -> i32 {
    let platform = parse_platform(args);
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("no artifacts ({e})");
            return 1;
        }
    };
    match rt.calibrate_compute(&platform) {
        Ok(cm) => {
            println!(
                "calibrated compute model: peak {} TFLOP/s, sat {:.2e} flops, max eff {:.2}",
                cm.peak_tflops, cm.sat_flops, cm.max_eff
            );
            0
        }
        Err(e) => {
            eprintln!("calibrate: {e}");
            1
        }
    }
}

fn cmd_space(args: &Args) -> i32 {
    let model = parse_model(args);
    let platform = parse_platform(args);
    let mut opts = CfpOptions::new(model, platform);
    parse_common(args, &mut opts);
    let r = run_cfp(&opts);
    let mut t = Table::new(&["segment", "fingerprint", "blocks", "configs", "instances"]);
    for u in &r.segments.unique {
        let inst = &r.segments.instances[u.rep];
        t.row(vec![
            format!("u{}", u.id),
            format!("{:016x}", cfp::segment::fingerprint_digest(&u.fingerprint)),
            inst.blocks.len().to_string(),
            r.db.segments[u.id].configs.len().to_string(),
            u.count.to_string(),
        ]);
    }
    t.print();
    println!("total profile space: {} programs", r.db.profile_space());
    0
}
