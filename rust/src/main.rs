//! `cfp` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   search      run the CFP pipeline on a model and print the chosen plan
//!   pipeline    two-level planner: inter-op stages over the intra-op DP
//!   explain     per-segment plan provenance (winner, runner-up, cost split)
//!   compare     CFP vs Alpa/Megatron/DDP on one model+platform
//!   serve       plan-serving daemon: NDJSON over stdin and --listen TCP
//!   bench-serve load generator against `serve` (in-process or --connect)
//!   train       e2e training via the PJRT train-step artifact
//!   calibrate   measure calib artifacts and print the fitted compute model
//!   space       print ParallelBlock/segment/profile-space statistics
//!
//! Flag parsing for every planning subcommand goes through
//! [`CfpOptions::from_args`] — the same builder `cfp serve` uses — so
//! the CLI and the server cannot interpret one request differently.

use cfp::cluster::Platform;
use cfp::coordinator::{
    compare_frameworks, run_cfp, run_cfp_two_level, validate_pipeline_args, CfpOptions,
    PlannerKind,
};
use cfp::harness::{fmt_bytes, fmt_us, CacheEffect, Table};
use cfp::runtime::Runtime;
use cfp::service::{shared_writer, PlanService, ServeConfig};
use cfp::trainer::Trainer;
use cfp::util::bench::{merge_bench_json, JsonRow};
use cfp::util::cli::Args;
use cfp::util::Json;

fn main() {
    let args = Args::from_env();
    if args.has_flag("quiet") {
        cfp::obs::diag::set_quiet(true);
    }
    // deterministic fault injection (chaos testing): arm named failpoint
    // sites before any subsystem can consult them; a bad spec is a hard
    // usage error, same convention as unknown models/platforms
    if let Some(spec) = args.get("faults") {
        if let Err(e) = cfp::util::failpoint::arm(spec) {
            eprintln!("cfp: invalid --faults spec: {e}");
            std::process::exit(2);
        }
    }
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let code = match cmd {
        "search" => cmd_search(&args),
        "pipeline" => cmd_pipeline(&args),
        "explain" => cmd_explain(&args),
        "compare" => cmd_compare(&args),
        "serve" => cmd_serve(&args),
        "bench-serve" => cmd_bench_serve(&args),
        "train" => cmd_train(&args),
        "calibrate" => cmd_calibrate(&args),
        "space" => cmd_space(&args),
        _ => {
            eprintln!(
                "usage: cfp \
                 <search|pipeline|explain|compare|serve|bench-serve|train|calibrate|space> \
                 [--model gpt-2.6b] [--layers N] [--batch N] \
                 [--platform a100-pcie|a100-pcie-8|a100-2node|v100-nvlink] \
                 [--threads N] [--cache FILE] [--cache-max-entries N] \
                 [--stages auto|K] [--microbatches M] [--mem-cap GB] \
                 [--recompute auto|off] [--engine dp|exact|auto] \
                 [--trace-out FILE] [--steps N] [--lr F] \
                 [--listen ADDR] [--workers N] [--plan-cache N] \
                 [--plan-cache-file FILE] [--quota RATE] [--quota-burst N] \
                 [--max-pending N] [--auth-token SECRET] \
                 [--read-timeout SECS] [--write-timeout SECS] \
                 [--connect ADDR] [--requests N] [--clients N] [--distinct N] \
                 [--faults SITE:SPEC,...] [--quiet]"
            );
            1
        }
    };
    std::process::exit(code);
}

/// Shared builder + CLI error convention: warnings go to stderr and the
/// run proceeds; hard errors (unknown model/platform) exit with code 2.
fn build_opts(args: &Args, kind: PlannerKind) -> Result<CfpOptions, i32> {
    match CfpOptions::from_args(args, kind) {
        Ok(built) => {
            for w in &built.warnings {
                eprintln!("cfp: {w} — flag ignored, default kept");
            }
            Ok(built.opts)
        }
        Err(e) => {
            eprintln!("cfp: {e}");
            Err(2)
        }
    }
}

/// `--trace-out FILE`: arm the run's trace sink and return the path the
/// Chrome trace JSON is written to after the run.
fn trace_out(args: &Args, opts: &mut CfpOptions) -> Option<std::path::PathBuf> {
    let path = args.get_path("trace-out")?;
    opts.trace = cfp::obs::Trace::enabled();
    Some(path)
}

fn write_trace(trace: &cfp::obs::Trace, path: &std::path::Path) {
    match trace.write_chrome(path) {
        Ok(()) => cfp::obs::diag::diag(&format!(
            "trace written to {} (chrome://tracing / Perfetto)",
            path.display()
        )),
        Err(e) => cfp::obs::diag::diag(&format!(
            "cfp: could not write trace to {}: {e}",
            path.display()
        )),
    }
}

fn cmd_search(args: &Args) -> i32 {
    let mut opts = match build_opts(args, PlannerKind::SingleLevel) {
        Ok(o) => o,
        Err(code) => return code,
    };
    let trace_path = trace_out(args, &mut opts);
    if let Ok(rt) = Runtime::open_default() {
        if let Ok(cm) = rt.calibrate_compute(&opts.platform) {
            println!("(compute model calibrated from PJRT measurements)");
            opts.compute = Some(cm);
        }
    }
    let r = run_cfp(&opts);
    println!(
        "model {}  platform {}  gpus {}",
        opts.model.name,
        opts.platform.name,
        opts.mesh.total()
    );
    println!(
        "blocks {}  segments {} ({} unique)  profile space {} programs",
        r.blocks.num_blocks(),
        r.segments.instances.len(),
        r.segments.num_unique(),
        r.db.profile_space()
    );
    println!(
        "plan: step {}  memory/device {}",
        fmt_us(r.plan.time_us),
        fmt_bytes(r.plan.mem_bytes)
    );
    for line in r.describe_plan() {
        println!("  {line}");
    }
    println!(
        "timings: analysis {:.3}s  profiling {:.3}s  search {:.3}s  \
         (est. real testbed: compile {:.1}s profile {:.1}s -> optimized {:.1}s)",
        r.timings.analysis_passes_s,
        r.timings.exec_compiling_s + r.timings.metrics_profiling_s,
        r.timings.compose_search_s,
        r.timings.est_compile_s,
        r.timings.est_profile_s,
        r.timings.est_optimized_s,
    );
    if opts.cache_path.is_some() {
        println!(
            "profile cache: {} segment hit(s), {} profiled this run \
             (MetricsProfiling {:.4}s)",
            r.db.stats.cache_hits,
            r.db.stats.cache_misses,
            r.timings.metrics_profiling_s,
        );
    }
    if let Some(p) = &trace_path {
        write_trace(&opts.trace, p);
    }
    0
}

fn cmd_pipeline(args: &Args) -> i32 {
    let mut opts = match build_opts(args, PlannerKind::TwoLevel) {
        Ok(o) => o,
        Err(code) => return code,
    };
    let trace_path = trace_out(args, &mut opts);
    if let Err(msg) = validate_pipeline_args(args, &opts) {
        eprintln!("cfp pipeline: {msg}");
        return 2;
    }
    let r = run_cfp_two_level(&opts);
    if let Some(p) = &trace_path {
        write_trace(&opts.trace, p);
    }
    println!(
        "model {}  platform {}  gpus {}  microbatches {}  cap {}  recompute {}",
        opts.model.name,
        opts.platform.name,
        opts.mesh.total(),
        opts.microbatches,
        fmt_bytes(opts.mem_cap.unwrap_or_else(|| opts.platform.mem_capacity())),
        if opts.recompute.is_auto() { "auto" } else { "off" },
    );
    let Some(pipeline) = r.pipeline.as_ref() else {
        eprintln!(
            "cfp pipeline: no stage split fits the per-device memory cap \
             (even with recomputation) — raise --mem-cap or add devices"
        );
        return 1;
    };
    let mut t = Table::new(&["planner", "stages", "step time", "peak mem/dev", "vs two-level"]);
    t.row(vec![
        "CFP single-stage".into(),
        "1".into(),
        fmt_us(r.single.plan.time_us),
        fmt_bytes(r.single.plan.mem_bytes),
        format!("{:.2}x", r.single.plan.time_us / pipeline.step_time_us),
    ]);
    t.row(vec![
        "CFP two-level".into(),
        pipeline.num_stages().to_string(),
        fmt_us(pipeline.step_time_us),
        fmt_bytes(pipeline.peak_mem_bytes),
        "1.00x".into(),
    ]);
    match r.naive.as_ref() {
        Some(naive) => t.row(vec![
            "naive equal-split".into(),
            naive.num_stages().to_string(),
            fmt_us(naive.step_time_us),
            fmt_bytes(naive.peak_mem_bytes),
            format!("{:.2}x", naive.step_time_us / pipeline.step_time_us),
        ]),
        None => t.row(vec![
            "naive equal-split".into(),
            "-".into(),
            "over cap".into(),
            "-".into(),
            "-".into(),
        ]),
    }
    t.print();
    println!(
        "two-level plan: {} stage(s) × {} device(s), bubble {:.1}%, 1F1B peak {}, \
         search {}",
        pipeline.num_stages(),
        pipeline.devices_per_stage,
        pipeline.bubble_fraction * 100.0,
        fmt_bytes(pipeline.peak_mem_bytes),
        fmt_us(r.search_us),
    );
    for line in pipeline.describe() {
        println!("  {line}");
    }
    if opts.cache_path.is_some() {
        println!(
            "profile cache: {} segment hit(s), {} profiled across all stage contexts",
            r.profile_hits, r.profile_misses,
        );
    }
    0
}

/// `cfp explain` — run the planner with tracing armed and print the
/// per-segment provenance report. Dispatches on `--stages` exactly like
/// the `search`/`pipeline` split; the report text is deterministic
/// (bit-identical across `--threads` values), while `--trace-out` adds
/// the wall-clock Chrome trace alongside.
fn cmd_explain(args: &Args) -> i32 {
    let two_level = args.get("stages").is_some();
    let kind = if two_level { PlannerKind::TwoLevel } else { PlannerKind::SingleLevel };
    let mut opts = match build_opts(args, kind) {
        Ok(o) => o,
        Err(code) => return code,
    };
    opts.trace = cfp::obs::Trace::enabled();
    let trace_path = args.get_path("trace-out");
    let text = if two_level {
        if let Err(msg) = validate_pipeline_args(args, &opts) {
            eprintln!("cfp explain: {msg}");
            return 2;
        }
        let r = run_cfp_two_level(&opts);
        cfp::obs::explain::render_explain_pipeline(&r, &opts)
    } else {
        let r = run_cfp(&opts);
        cfp::obs::explain::render_explain(&r, &opts)
    };
    print!("{text}");
    if let Some(p) = &trace_path {
        write_trace(&opts.trace, p);
    }
    0
}

fn cmd_compare(args: &Args) -> i32 {
    let opts = match build_opts(args, PlannerKind::SingleLevel) {
        Ok(o) => o,
        Err(code) => return code,
    };
    let c = compare_frameworks(&opts);
    let mut t = Table::new(&["framework", "step time", "memory/dev", "vs CFP"]);
    for (name, p) in [
        ("PyTorch-DDP", &c.ddp),
        ("DeepSpeed-Megatron", &c.megatron),
        ("Alpa (volume model)", &c.alpa),
        ("CFP", &c.cfp),
    ] {
        t.row(vec![
            name.into(),
            fmt_us(p.time_us),
            fmt_bytes(p.mem_bytes),
            format!("{:.2}x", p.time_us / c.cfp.time_us),
        ]);
    }
    t.print();
    0
}

/// `cfp serve` flags shared with bench-serve's in-process lane.
fn serve_config(args: &Args, workers: usize) -> ServeConfig {
    ServeConfig {
        workers,
        plan_cache_entries: args.get_usize("plan-cache", 128),
        cache_path: args.get_path("cache"),
        cache_max_entries: args.get_usize_opt("cache-max-entries"),
        search_threads: args.get_usize("threads", 1),
        plan_cache_file: args.get_path("plan-cache-file"),
        quota: args
            .get_f64_opt("quota")
            .map(|rate| (rate, args.get_f64("quota-burst", (2.0 * rate).max(1.0)))),
        max_pending: args.get_usize("max-pending", 1024),
        auth_token: args.get("auth-token").map(|s| s.to_string()),
        trace_out: args.get_path("trace-out"),
        read_timeout: socket_timeout(args, "read-timeout", None),
        write_timeout: socket_timeout(
            args,
            "write-timeout",
            Some(std::time::Duration::from_secs(30)),
        ),
    }
}

/// `--read-timeout`/`--write-timeout` in seconds; explicit 0 disables
/// the deadline, absent keeps the service default.
fn socket_timeout(
    args: &Args,
    flag: &str,
    default: Option<std::time::Duration>,
) -> Option<std::time::Duration> {
    match args.get_f64_opt(flag) {
        None => default,
        Some(s) if s <= 0.0 => None,
        Some(s) => Some(std::time::Duration::from_secs_f64(s)),
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let svc = PlanService::new(serve_config(args, args.get_usize("workers", 4)));
    let listening = match args.get("listen") {
        Some(addr) => match svc.listen(addr) {
            Ok(local) => {
                eprintln!("cfp serve: listening on {local}");
                true
            }
            Err(e) => {
                eprintln!("cfp serve: cannot listen on {addr}: {e}");
                return 1;
            }
        },
        None => false,
    };
    eprintln!("cfp serve: NDJSON requests on stdin, responses on stdout");
    // Pure std has no signal handling, so stdin EOF is the documented
    // SIGTERM equivalent: closing stdin (or a `{"type": "drain"}`
    // request on any stream) drains the service — in-flight searches
    // finish and are answered, new work gets structured `draining`
    // rejections, caches flush — and the process exits with a summary.
    if listening {
        let stdin_svc = svc.clone();
        let spawned =
            std::thread::Builder::new().name("cfp-serve-stdin".into()).spawn(move || {
                stdin_svc
                    .serve_stream(std::io::stdin().lock(), shared_writer(std::io::stdout()));
                stdin_svc.drain();
            });
        match spawned {
            Ok(_) => svc.wait_drained(),
            Err(e) => {
                eprintln!("cfp serve: cannot serve stdin: {e}");
                svc.wait_drained();
            }
        }
    } else {
        svc.serve_stream(std::io::stdin().lock(), shared_writer(std::io::stdout()));
    }
    let report = svc.drain();
    eprintln!("{}", report.summary_line());
    0
}

/// Load generator for `cfp serve`: fires `--requests` requests from
/// `--clients` concurrent clients over mixed-model streams (the
/// requested `--model` alternating with a second tiny preset), cycling
/// `--distinct` layer variants per model. By default both lanes run —
/// in-process dispatch, then a TCP loopback against the same warm
/// service — and p50/p99/throughput rows are merged into
/// `BENCH_serve.json`; `--connect ADDR` instead drives a live daemon
/// over TCP only.
fn cmd_bench_serve(args: &Args) -> i32 {
    let requests = args.get_usize("requests", 32).max(1);
    let clients = args.get_usize("clients", 4).max(1);
    let distinct = args.get_usize("distinct", 2).max(1);
    let model = args.get_or("model", "gpt-tiny");
    let platform = args.get_or("platform", "a100-pcie");
    let moe_first = ["moe-tiny", "gpt-tiny"];
    let mixed = [model, "moe-tiny"];
    let models: &[&str] = if model == "moe-tiny" { &moe_first } else { &mixed };
    let lines: Vec<String> = (0..requests)
        .map(|i| {
            format!(
                "{{\"id\": {i}, \"type\": \"plan\", \"model\": \"{}\", \
                 \"layers\": {}, \"platform\": \"{platform}\", \"client\": \"c{}\"}}",
                models[i % models.len()],
                2 + (i / models.len()) % distinct,
                i % clients,
            )
        })
        .collect();
    let mut rows = Vec::new();
    let stats = match args.get("connect") {
        Some(addr) => {
            let t0 = std::time::Instant::now();
            match bench_serve_tcp(addr, &lines, clients) {
                Ok((lat, stats)) => {
                    summarize_lane("tcp", lat, t0.elapsed().as_secs_f64(), clients, &mut rows);
                    stats
                }
                Err(e) => {
                    eprintln!("cfp bench-serve: {e}");
                    return 1;
                }
            }
        }
        None => {
            let svc = PlanService::new(serve_config(args, clients));
            let t0 = std::time::Instant::now();
            let lat = bench_serve_local(&svc, &lines, clients);
            summarize_lane("inproc", lat, t0.elapsed().as_secs_f64(), clients, &mut rows);
            // second lane: the same (now warm) service over real sockets
            match svc.listen("127.0.0.1:0") {
                Ok(local) => {
                    let t0 = std::time::Instant::now();
                    match bench_serve_tcp(&local.to_string(), &lines, clients) {
                        Ok((lat, _)) => summarize_lane(
                            "tcp",
                            lat,
                            t0.elapsed().as_secs_f64(),
                            clients,
                            &mut rows,
                        ),
                        Err(e) => eprintln!("cfp bench-serve: tcp lane skipped: {e}"),
                    }
                }
                Err(e) => eprintln!("cfp bench-serve: tcp lane skipped: {e}"),
            }
            svc.stats().to_json()
        }
    };
    let g = |k: &str| stats.get(k).and_then(Json::as_u64).unwrap_or(0);
    let eff = CacheEffect {
        received: g("received"),
        admitted: g("admitted"),
        rejected: g("rejected"),
        plan_hits: g("plan_hits"),
        plan_misses: g("plan_misses"),
        coalesced: g("coalesced"),
        profile_hits: g("profile_hits"),
        profile_misses: g("profile_misses"),
        search_us: g("search_us"),
    };
    let mut t = Table::new(CacheEffect::headers());
    t.row(eff.cells());
    t.print();
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_serve.json");
    match merge_bench_json(&path, &rows) {
        Ok(()) => {
            println!("bench rows updated in {}", path.display());
            0
        }
        Err(e) => {
            eprintln!("cfp bench-serve: could not write {}: {e}", path.display());
            1
        }
    }
}

/// Sort one lane's latencies, print the distribution, and push
/// p50/p99/throughput rows for `BENCH_serve.json`.
fn summarize_lane(
    mode: &str,
    mut lat_us: Vec<f64>,
    wall: f64,
    clients: usize,
    rows: &mut Vec<JsonRow>,
) {
    lat_us.sort_by(|a, b| a.total_cmp(b));
    let n = lat_us.len();
    let thr = n as f64 / wall.max(1e-9);
    println!("[{mode}] {n} requests, {clients} clients: {wall:.2}s wall, {thr:.1} req/s");
    if n > 0 {
        let q = |p: usize| lat_us[(n - 1) * p / 100];
        println!(
            "[{mode}] latency: min {}  p50 {}  p99 {}  max {}",
            fmt_us(lat_us[0]),
            fmt_us(q(50)),
            fmt_us(q(99)),
            fmt_us(lat_us[n - 1]),
        );
        for (metric, value, unit) in [
            ("p50_us", q(50), "us"),
            ("p99_us", q(99), "us"),
            ("throughput", thr, "req_per_s"),
        ] {
            rows.push(JsonRow {
                name: format!("bench_serve/{mode}/{metric}"),
                layers: n,
                ns_per_iter: value,
                unit: Some(unit),
                speedup: None,
            });
        }
    }
}

fn bench_serve_local(svc: &PlanService, lines: &[String], clients: usize) -> Vec<f64> {
    let latencies = std::sync::Mutex::new(Vec::new());
    std::thread::scope(|s| {
        for c in 0..clients {
            let svc = svc.clone();
            let my: Vec<&String> = lines.iter().skip(c).step_by(clients).collect();
            let latencies = &latencies;
            s.spawn(move || {
                for line in my {
                    let t = std::time::Instant::now();
                    svc.handle_line(line);
                    latencies
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .push(t.elapsed().as_secs_f64() * 1e6);
                }
            });
        }
    });
    latencies.into_inner().unwrap_or_else(|e| e.into_inner())
}

/// Bounded-backoff connect for `--connect`: a freshly spawned daemon may
/// not be accepting yet, so retry for ~5s (25ms doubling to 250ms)
/// before surfacing the last error. Fixes the daemon-then-bench
/// scripting race without masking a genuinely absent server for long.
fn connect_with_retry(addr: &str) -> std::io::Result<std::net::TcpStream> {
    let mut delay = std::time::Duration::from_millis(25);
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
    loop {
        match std::net::TcpStream::connect(addr) {
            Ok(s) => return Ok(s),
            Err(e) => {
                if std::time::Instant::now() + delay >= deadline {
                    return Err(e);
                }
                std::thread::sleep(delay);
                delay = (delay * 2).min(std::time::Duration::from_millis(250));
            }
        }
    }
}

fn bench_serve_tcp(
    addr: &str,
    lines: &[String],
    clients: usize,
) -> std::io::Result<(Vec<f64>, Json)> {
    use std::io::{BufRead, BufReader, Write};
    let latencies = std::sync::Mutex::new(Vec::new());
    std::thread::scope(|s| -> std::io::Result<()> {
        let mut joins = Vec::new();
        for c in 0..clients {
            let my: Vec<&String> = lines.iter().skip(c).step_by(clients).collect();
            let latencies = &latencies;
            joins.push(s.spawn(move || -> std::io::Result<()> {
                let mut stream = connect_with_retry(addr)?;
                let mut reader = BufReader::new(stream.try_clone()?);
                for line in my {
                    let t = std::time::Instant::now();
                    writeln!(stream, "{line}")?;
                    let mut resp = String::new();
                    reader.read_line(&mut resp)?;
                    latencies
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .push(t.elapsed().as_secs_f64() * 1e6);
                }
                Ok(())
            }));
        }
        for j in joins {
            match j.join() {
                Ok(outcome) => outcome?,
                Err(_) => {
                    return Err(std::io::Error::other("bench client thread panicked"));
                }
            }
        }
        Ok(())
    })?;
    let mut stream = connect_with_retry(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    writeln!(stream, "{{\"type\": \"stats\"}}")?;
    let mut resp = String::new();
    reader.read_line(&mut resp)?;
    let stats = Json::parse(resp.trim())
        .ok()
        .and_then(|j| j.get("result").cloned())
        .unwrap_or(Json::Null);
    Ok((latencies.into_inner().unwrap_or_else(|e| e.into_inner()), stats))
}

fn cmd_train(args: &Args) -> i32 {
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("no artifacts ({e}); run `make artifacts` first");
            return 1;
        }
    };
    let steps = args.get_usize("steps", 100);
    let lr = args.get_f64("lr", 0.05) as f32;
    let artifact = args.get_or("artifact", "train_step_gpt");
    let mut tr = match Trainer::new(&rt, artifact, 42) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("trainer: {e}");
            return 1;
        }
    };
    println!("training {artifact}: {} params, {steps} steps, lr {lr}", tr.num_params());
    match tr.train(steps, lr, (steps / 20).max(1)) {
        Ok(curve) => {
            println!(
                "loss {:.4} -> {:.4}",
                curve.first().unwrap_or(&0.0),
                curve.last().unwrap_or(&0.0)
            );
            0
        }
        Err(e) => {
            eprintln!("train: {e}");
            1
        }
    }
}

fn cmd_calibrate(args: &Args) -> i32 {
    let pname = args.get_or("platform", "a100-pcie");
    let Some(platform) = Platform::by_name(pname) else {
        eprintln!("cfp: unknown platform {pname:?}");
        return 2;
    };
    let rt = match Runtime::open_default() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("no artifacts ({e})");
            return 1;
        }
    };
    match rt.calibrate_compute(&platform) {
        Ok(cm) => {
            println!(
                "calibrated compute model: peak {} TFLOP/s, sat {:.2e} flops, max eff {:.2}",
                cm.peak_tflops, cm.sat_flops, cm.max_eff
            );
            0
        }
        Err(e) => {
            eprintln!("calibrate: {e}");
            1
        }
    }
}

fn cmd_space(args: &Args) -> i32 {
    let opts = match build_opts(args, PlannerKind::SingleLevel) {
        Ok(o) => o,
        Err(code) => return code,
    };
    let r = run_cfp(&opts);
    let mut t = Table::new(&["segment", "fingerprint", "blocks", "configs", "instances"]);
    for u in &r.segments.unique {
        let inst = &r.segments.instances[u.rep];
        t.row(vec![
            format!("u{}", u.id),
            format!("{:016x}", cfp::segment::fingerprint_digest(&u.fingerprint)),
            inst.blocks.len().to_string(),
            r.db.segments[u.id].configs.len().to_string(),
            u.count.to_string(),
        ]);
    }
    t.print();
    println!("total profile space: {} programs", r.db.profile_space());
    0
}
