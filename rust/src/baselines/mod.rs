//! Baseline parallelization frameworks (paper §5): PyTorch-DDP (data
//! parallelism), DeepSpeed-Megatron (fixed TP/DP templates), ZeRO stage-1,
//! and an Alpa-style automatic searcher driven by a *symbolic,
//! communication-volume* cost model. All baselines produce plans over the
//! SAME config space and are evaluated on the SAME simulator — the
//! difference is purely how they choose, which is exactly the paper's
//! comparison design ("CFP's space still includes the data parallel
//! configurations used by PyTorch, the tensor parallel configurations of
//! DeepSpeed-Megatron, and the volume-optimal configurations of Alpa").

use crate::cost::{plan_cost, Plan};
use crate::graph::Graph;
use crate::pblock::BlockSet;
use crate::profiler::ProfileDb;
use crate::segment::SegmentSet;

/// Find the segment-config index matching a per-block label preference
/// (falls back to the first strategy when a label is unavailable/pinned).
fn find_config<F: Fn(&str) -> &'static str>(
    g: &Graph,
    bs: &BlockSet,
    blocks: &[usize],
    configs: &[crate::profiler::SegmentConfig],
    want: F,
) -> usize {
    let desired: Vec<usize> = blocks
        .iter()
        .map(|&b| {
            let blk = &bs.blocks[b];
            let label = want(&g.ops[blk.entry].name);
            blk.strategies.iter().position(|s| s.label == label).unwrap_or(0)
        })
        .collect();
    // choose the enumerated config closest to desired (exact when possible)
    configs
        .iter()
        .enumerate()
        .max_by_key(|(_, c)| {
            c.strategy.iter().zip(&desired).filter(|(a, b)| a == b).count()
        })
        .map(|(i, _)| i)
        .unwrap_or(0)
}

fn choice_for_all_instances(
    g: &Graph,
    bs: &BlockSet,
    ss: &SegmentSet,
    db: &ProfileDb,
    want: impl Fn(&str) -> &'static str + Copy,
) -> Vec<usize> {
    let per_unique: Vec<usize> = ss
        .unique
        .iter()
        .map(|u| {
            let inst = &ss.instances[u.rep];
            find_config(g, bs, &inst.blocks, &db.segments[u.id].configs, want)
        })
        .collect();
    ss.instances.iter().map(|i| per_unique[i.unique_id]).collect()
}

/// PyTorch data parallelism: every block M/batch-split.
pub fn ddp_plan(g: &Graph, bs: &BlockSet, ss: &SegmentSet, db: &ProfileDb) -> Plan {
    let choice = choice_for_all_instances(g, bs, ss, db, |_| "m");
    let (time_us, mem_bytes) = plan_cost(ss, db, &choice);
    Plan { choice, time_us, mem_bytes }
}

/// DeepSpeed-Megatron template: column-parallel qkv/fc1 (+expert fc1),
/// row-parallel wo/fc2 (+expert fc2), everything else data parallel.
pub fn megatron_plan(g: &Graph, bs: &BlockSet, ss: &SegmentSet, db: &ProfileDb) -> Plan {
    let want = |name: &str| -> &'static str {
        if name.contains("qkv") || name.contains("fc1") || name.contains("gate")
            && !name.contains("gate_logits")
        {
            "n"
        } else if name.contains("out_proj")
            || name.contains("fc2")
            || name.contains("down")
        {
            "k"
        } else if name.contains("lm_head") {
            "n" // vocab-parallel output head
        } else {
            "m"
        }
    };
    let choice = choice_for_all_instances(g, bs, ss, db, want);
    let (time_us, mem_bytes) = plan_cost(ss, db, &choice);
    Plan { choice, time_us, mem_bytes }
}

/// Alpa-style search: minimize the SYMBOLIC communication volume
/// (segment volumes + boundary volumes) with a min-cost DP, then evaluate
/// the chosen plan on the real (profiled) tables. No memory constraint —
/// Alpa "chose parallelism configurations without integrating memory
/// constraints into the search" (§5.4).
pub fn alpa_plan(ss: &SegmentSet, db: &ProfileDb) -> Plan {
    let n = ss.instances.len();
    assert!(n > 0);
    let cfgs = |i: usize| db.segments[ss.instances[i].unique_id].configs.len();

    // dp[cfg] = (volume, backpointer chain)
    let mut dp: Vec<(f64, Vec<usize>)> = (0..cfgs(0))
        .map(|c| {
            let u = ss.instances[0].unique_id;
            (db.segments[u].symbolic_volume[c] as f64, vec![c])
        })
        .collect();
    for i in 1..n {
        let u = ss.instances[i].unique_id;
        let pu = ss.instances[i - 1].unique_id;
        let mut next: Vec<(f64, Vec<usize>)> = Vec::with_capacity(cfgs(i));
        for c in 0..cfgs(i) {
            let seg_vol = db.segments[u].symbolic_volume[c] as f64;
            let mut best: Option<(f64, usize)> = None;
            for (pc, (pvol, _)) in dp.iter().enumerate() {
                let tr = db
                    .reshard
                    .get(&(pu, u))
                    .map(|t| t.sym_vol[pc][c] as f64)
                    .unwrap_or(0.0);
                let v = pvol + tr + seg_vol;
                if best.map_or(true, |(bv, _)| v < bv) {
                    best = Some((v, pc));
                }
            }
            let (v, pc) = best.unwrap();
            let mut chain = dp[pc].1.clone();
            chain.push(c);
            next.push((v, chain));
        }
        dp = next;
    }
    let (_, choice) = dp
        .into_iter()
        .min_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
        .unwrap();
    let (time_us, mem_bytes) = plan_cost(ss, db, &choice);
    Plan { choice, time_us, mem_bytes }
}

/// The symbolic volume Alpa believes its chosen plan costs (for Fig. 9's
/// x-axis ordering).
pub fn symbolic_cost(ss: &SegmentSet, db: &ProfileDb, choice: &[usize]) -> u64 {
    let mut vol = 0u64;
    for (i, inst) in ss.instances.iter().enumerate() {
        vol += db.segments[inst.unique_id].symbolic_volume[choice[i]];
        if i > 0 {
            let pu = ss.instances[i - 1].unique_id;
            if let Some(t) = db.reshard.get(&(pu, inst.unique_id)) {
                vol += t.sym_vol[choice[i - 1]][choice[i]];
            }
        }
    }
    vol
}

/// Naive pipeline baseline (GPipe/Megatron default recipe): equal-layer
/// stage split with plain data parallelism inside every stage, composed
/// with the same 1F1B schedule arithmetic as the two-level planner —
/// delegates to [`crate::interop::naive_equal_split`] so the comparison
/// isolates plan quality (split choice + intra-op configs), not the
/// schedule model. This is the bar the two-level CFP planner must clear.
pub fn naive_pipeline_plan(
    g: &Graph,
    ctxs: &crate::interop::StageContexts,
    opts: &crate::interop::PipelineOptions,
) -> Option<crate::interop::PipelinePlan> {
    crate::interop::naive_equal_split(g, ctxs, opts)
}

/// ZeRO stage-1 on top of DP: optimizer states sharded across all devices;
/// gradient AllReduce becomes ReduceScatter + AllGather of updated params.
/// Approximated on top of the DP plan's profile: memory drops by the
/// optimizer-shard factor; comm time rises by the AllGather half.
pub fn zero1_plan(
    g: &Graph,
    bs: &BlockSet,
    ss: &SegmentSet,
    db: &ProfileDb,
    total_devices: usize,
    opt_factor: f64,
) -> Plan {
    let dp = ddp_plan(g, bs, ss, db);
    // params fully replicated under DP: param bytes ≈ Σ weights
    let param_bytes: u64 = g.params().iter().map(|&p| g.ops[p].bytes() as u64).sum();
    let opt_bytes = (param_bytes as f64 * opt_factor) as u64;
    let saved = opt_bytes - opt_bytes / total_devices as u64;
    // AllGather of updated params each step ≈ one more pass over params —
    // comm roughly 1.5× the grad sync (RS is half an AR, AG adds a half,
    // plus per-shard update gathers fragment poorly)
    Plan {
        choice: dp.choice,
        time_us: dp.time_us + 0.6 * dp.time_us.min(f64::MAX) * comm_share(ss, db),
        mem_bytes: dp.mem_bytes.saturating_sub(saved),
    }
}

fn comm_share(ss: &SegmentSet, db: &ProfileDb) -> f64 {
    let mut c = 0.0;
    let mut t = 0.0;
    for inst in &ss.instances {
        let p = &db.segments[inst.unique_id];
        let best = p.best_config();
        c += p.t_c_us[best];
        t += p.t_c_us[best] + p.t_p_us[best];
    }
    if t > 0.0 {
        c / t
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Platform;
    use crate::models::{build_training, ModelCfg};
    use crate::pblock::build_parallel_blocks;
    use crate::profiler::{profile_model, ProfileOptions};
    use crate::segment::extract_segments;
    use crate::spmd::Mesh;

    fn setup(preset: &str) -> (Graph, BlockSet, SegmentSet, ProfileDb) {
        let cfg = ModelCfg::preset(preset).with_layers(2);
        let g = build_training(&cfg);
        let bs = build_parallel_blocks(&g, 4);
        let ss = extract_segments(&g, &bs);
        let opts = ProfileOptions::new(Platform::a100_pcie(4), Mesh::flat(4));
        let db = profile_model(&g, &bs, &ss, &opts);
        (g, bs, ss, db)
    }

    #[test]
    fn cfp_never_loses_to_baselines() {
        // CFP searches the measured tables; every baseline's plan lives in
        // the same space, so CFP's cost is a lower bound (§5.2's setup).
        let (g, bs, ss, db) = setup("gpt-tiny");
        let cfp = crate::cost::search(&ss, &db, None).unwrap();
        for (name, plan) in [
            ("ddp", ddp_plan(&g, &bs, &ss, &db)),
            ("megatron", megatron_plan(&g, &bs, &ss, &db)),
            ("alpa", alpa_plan(&ss, &db)),
        ] {
            assert!(
                cfp.time_us <= plan.time_us + 1e-6,
                "{name}: cfp {} vs {}",
                cfp.time_us,
                plan.time_us
            );
        }
    }

    #[test]
    fn alpa_minimizes_volume_not_time() {
        let (_, _, ss, db) = setup("gpt-tiny");
        let alpa = alpa_plan(&ss, &db);
        let cfp = crate::cost::search(&ss, &db, None).unwrap();
        let alpa_vol = symbolic_cost(&ss, &db, &alpa.choice);
        let cfp_vol = symbolic_cost(&ss, &db, &cfp.choice);
        // Alpa's plan has the (weakly) smallest symbolic volume
        assert!(alpa_vol <= cfp_vol, "alpa vol {alpa_vol} vs cfp vol {cfp_vol}");
    }

    #[test]
    fn megatron_uses_tensor_parallel_strategies() {
        let (g, bs, ss, db) = setup("gpt-tiny");
        let plan = megatron_plan(&g, &bs, &ss, &db);
        // at least one block in the layer segment must be 'n' or 'k'
        let inst = &ss.instances[0];
        let cfg = &db.segments[inst.unique_id].configs[plan.choice[0]];
        let labels: Vec<&str> = inst
            .blocks
            .iter()
            .zip(&cfg.strategy)
            .map(|(&b, &s)| bs.blocks[b].strategies[s].label.as_str())
            .collect();
        assert!(
            labels.iter().any(|l| *l == "n") && labels.iter().any(|l| *l == "k"),
            "{labels:?}"
        );
    }

    #[test]
    fn zero1_trades_time_for_memory() {
        let (g, bs, ss, db) = setup("gpt-tiny");
        let dp = ddp_plan(&g, &bs, &ss, &db);
        let z = zero1_plan(&g, &bs, &ss, &db, 4, 2.0);
        assert!(z.mem_bytes < dp.mem_bytes, "zero1 saves memory");
        assert!(z.time_us >= dp.time_us, "zero1 pays communication");
    }
}
